"""Serving driver: batched prefill + decode loop with either the dense
bf16 KV cache or the paper-technique RCLL-KV (block-anchored quantized)
cache. Reports tokens/s and cache bytes - the decode-path equivalent of
the paper's fp64-vs-fp16 NNPS comparison.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \
      --batch 4 --prompt-len 64 --gen 32 --kv-mode anchored
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import registry


def cache_bytes(cache) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(cache))


@dataclasses.dataclass
class ServeRun:
    arch: str
    smoke: bool = True
    batch: int = 4
    prompt_len: int = 64
    gen: int = 32
    max_len: int = 0  # 0 -> prompt_len + gen (rounded to kv_block)
    kv_mode: str = "dense"  # dense | anchored
    seed: int = 0
    greedy: bool = True

    def run(self) -> dict:
        cfg = registry.get_config(self.arch, smoke=self.smoke)
        cfg = dataclasses.replace(cfg, kv_mode=self.kv_mode)
        mod = registry.get_module(cfg)
        params = mod.init_params(jax.random.key(self.seed), cfg)
        rng = np.random.default_rng(self.seed)
        max_len = self.max_len or self.prompt_len + self.gen
        if cfg.kv_mode == "anchored":
            max_len = -(-max_len // cfg.kv_block) * cfg.kv_block
        tokens = jnp.asarray(
            rng.integers(0, cfg.vocab, (self.batch, self.prompt_len)),
            jnp.int32)
        kw = {}
        if cfg.family == "encdec":
            kw["frames"] = jax.random.normal(
                jax.random.key(7),
                (self.batch, cfg.src_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm":
            kw["patch_embeds"] = jax.random.normal(
                jax.random.key(8),
                (self.batch, cfg.n_patches, cfg.d_model), jnp.bfloat16)

        prefill = jax.jit(
            lambda p, t: mod.prefill(p, t, cfg, max_len, **kw))
        decode = jax.jit(lambda p, t, c: mod.decode_step(p, t, c, cfg))

        t0 = time.time()
        lg, cache = prefill(params, tokens)
        jax.block_until_ready(lg)
        t_prefill = time.time() - t0

        out_tokens = [jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)]
        # warm up decode compile off the clock
        _ = decode(params, out_tokens[0], cache)
        t1 = time.time()
        cur = out_tokens[0]
        for _ in range(self.gen - 1):
            lg2, cache = decode(params, cur, cache)
            cur = jnp.argmax(lg2, axis=-1).astype(jnp.int32)
            out_tokens.append(cur)
        jax.block_until_ready(cur)
        t_decode = time.time() - t1
        toks = jnp.concatenate(out_tokens, axis=1)
        return {
            "tokens": np.asarray(toks),
            "t_prefill_s": t_prefill,
            "t_decode_s": t_decode,
            "decode_tok_s": self.batch * (self.gen - 1) / max(t_decode,
                                                              1e-9),
            "cache_bytes": cache_bytes(cache),
            "kv_mode": cfg.kv_mode,
        }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--kv-mode", default="dense",
                    choices=["dense", "anchored"])
    args = ap.parse_args()
    run = ServeRun(arch=args.arch, smoke=args.smoke, batch=args.batch,
                   prompt_len=args.prompt_len, gen=args.gen,
                   kv_mode=args.kv_mode)
    out = run.run()
    print(f"[serve] {args.arch} kv={out['kv_mode']} "
          f"prefill {out['t_prefill_s']*1e3:.0f}ms "
          f"decode {out['decode_tok_s']:.1f} tok/s "
          f"cache {out['cache_bytes']/2**20:.1f} MiB")


if __name__ == "__main__":
    main()
