"""Sharding assignment for step-function inputs/outputs.

Parameters go through models/partitioning.py rules (TP on "model", FSDP
on "data" for large models). Batches shard their leading axis over the
DP axes. Caches use a shape heuristic (works uniformly across the five
cache types): batch axis over DP if divisible, else the longest
sequence-like axis over "data"; a heads-like axis over "model" when it
divides.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import partitioning as pt


def dp_axes(mesh):
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def replicated(mesh):
    return NamedSharding(mesh, P())


def batch_shardings(mesh, batch_abs):
    """Leading axis of every batch leaf -> DP axes (must divide)."""
    dp = dp_axes(mesh)

    def per_leaf(x):
        if x.ndim >= 1 and x.shape[0] % dp_size(mesh) == 0:
            return NamedSharding(mesh, P(dp, *([None] * (x.ndim - 1))))
        return replicated(mesh)

    return jax.tree.map(per_leaf, batch_abs)


def cache_shardings(mesh, cache_abs, batch: int, seq_len: int):
    """Heuristic per-leaf cache sharding (see module docstring).

    Cache leaves are (n_layers, B, ...) stacked. Axis 1 is batch.
    """
    dp = dp_axes(mesh)
    dpn = dp_size(mesh)
    model_n = mesh.shape["model"]

    def per_leaf(x):
        spec = [None] * x.ndim
        used_model = False
        if x.ndim >= 2 and x.shape[1] == batch and batch % dpn == 0:
            spec[1] = dp
        elif x.ndim >= 3:
            # batch too small: shard the sequence-like axis over data
            for ax in range(2, x.ndim):
                if x.shape[ax] >= seq_len // 2 and x.shape[ax] % dpn == 0:
                    spec[ax] = dp
                    break
        # heads-like axis on model (first remaining axis that divides and
        # looks like heads: small-ish, divisible)
        for ax in range(2, x.ndim):
            if spec[ax] is None and 1 < x.shape[ax] <= 4096 \
                    and x.shape[ax] % model_n == 0:
                spec[ax] = "model"
                used_model = True
                break
        del used_model
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(per_leaf, cache_abs)


def param_shardings(mesh, params_abs, *, fsdp: bool):
    return pt.tree_shardings(params_abs, mesh, fsdp=fsdp)


def opt_shardings(mesh, opt_abs, p_shardings):
    """Optimizer moments shard exactly like their parameters."""
    from repro.optim.adamw import OptState

    return OptState(
        step=replicated(mesh),
        mu=jax.tree.map(lambda _, s: s, opt_abs.mu, p_shardings),
        nu=jax.tree.map(lambda _, s: s, opt_abs.nu, p_shardings),
    )
