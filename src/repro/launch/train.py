"""Training driver: data pipeline -> jit train_step -> checkpoint/
restart -> heartbeat + straggler watchdog -> (optional) elastic resize.

Runs end-to-end on CPU with reduced configs (examples/train_lm.py) and
unchanged on a pod: the mesh is the only thing that grows.

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint.manager import CheckpointManager, reshard
from repro.data.pipeline import DataConfig, make_batch
from repro.launch import shardings as sh
from repro.launch.mesh import make_mesh
from repro.models import registry
from repro.optim import adamw
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, HeartbeatWriter, StragglerWatchdog, TrainGuard,
    plan_elastic_mesh)


@dataclasses.dataclass
class TrainRun:
    """Reusable programmatic entry (examples + tests drive this)."""

    arch: str
    smoke: bool = True
    steps: int = 50
    batch: int = 8
    seq: int = 128
    ckpt_dir: str | None = None
    ckpt_every: int = 20
    ckpt_async: bool = True
    mesh_shape: tuple = ()  # () -> single device
    seed: int = 0
    lr: float = 1e-3
    log_every: int = 10
    heartbeat_dir: str | None = None

    def build(self):
        cfg = registry.get_config(self.arch, smoke=self.smoke)
        mod = registry.get_module(cfg)
        mesh = None
        if self.mesh_shape:
            mesh = make_mesh(self.mesh_shape, ("data", "model"))
            jax.set_mesh(mesh)
        params = mod.init_params(jax.random.key(self.seed), cfg)
        opt_state = adamw.init(params)
        ocfg = adamw.OptConfig(lr=self.lr, warmup_steps=20,
                               total_steps=self.steps)
        dcfg = DataConfig(vocab=cfg.vocab, seq_len=self.seq,
                          global_batch=self.batch, seed=self.seed)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                lambda p: mod.loss_fn(p, self._with_stubs(batch, cfg), cfg),
                has_aux=True)(params)
            new_p, new_o, om = adamw.apply_updates(
                ocfg, params, grads, opt_state)
            return new_p, new_o, {"loss": loss, **om}

        return cfg, mod, mesh, params, opt_state, dcfg, jax.jit(train_step)

    @staticmethod
    def _with_stubs(batch, cfg):
        """Synthesize deterministic modality-stub inputs from tokens."""
        out = dict(batch)
        B = batch["tokens"].shape[0]
        if cfg.family == "encdec" and "frames" not in out:
            key = jax.random.key(0)
            out["frames"] = jax.random.normal(
                key, (B, cfg.src_len, cfg.d_model), jnp.bfloat16)
        if cfg.family == "vlm" and "patch_embeds" not in out:
            key = jax.random.key(1)
            out["patch_embeds"] = jax.random.normal(
                key, (B, cfg.n_patches, cfg.d_model), jnp.bfloat16)
        return out

    def run(self, on_step=None) -> dict:
        cfg, mod, mesh, params, opt_state, dcfg, train_step = self.build()
        start_step = 0
        ckpt = CheckpointManager(self.ckpt_dir) if self.ckpt_dir else None
        if ckpt is not None:
            restored, at = ckpt.restore((params, opt_state))
            if restored is not None:
                params, opt_state = restored
                params = jax.tree.map(jnp.asarray, params)
                opt_state = jax.tree.map(jnp.asarray, opt_state)
                start_step = at
                print(f"[train] resumed from step {at}")

        guard = None
        if self.heartbeat_dir:
            guard = TrainGuard(
                heartbeat=HeartbeatWriter(self.heartbeat_dir, 0),
                watchdog=StragglerWatchdog(),
                monitor=HeartbeatMonitor(self.heartbeat_dir),
                expected_hosts=1)

        losses = []
        for step in range(start_step, self.steps):
            t0 = time.time()
            batch = make_batch(dcfg, step)
            params, opt_state, m = train_step(params, opt_state, batch)
            loss = float(m["loss"])
            losses.append(loss)
            dt = time.time() - t0
            if guard:
                guard.on_step(step, dt)
            if on_step:
                on_step(step, loss)
            if step % self.log_every == 0:
                print(f"[train] step {step:5d} loss {loss:.4f} "
                      f"({dt*1e3:.0f} ms)")
            if ckpt and (step + 1) % self.ckpt_every == 0:
                ckpt.save(step + 1, (params, opt_state),
                          blocking=not self.ckpt_async)
        if ckpt:
            ckpt.save(self.steps, (params, opt_state), blocking=True)
        return {"losses": losses, "params": params,
                "final_loss": losses[-1] if losses else None}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--heartbeat-dir")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()
    run = TrainRun(arch=args.arch, smoke=args.smoke, steps=args.steps,
                   batch=args.batch, seq=args.seq, ckpt_dir=args.ckpt_dir,
                   heartbeat_dir=args.heartbeat_dir, lr=args.lr)
    out = run.run()
    print(f"[train] done; final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
