"""Production mesh definition (factory function - importing this module
never touches jax device state).

Target: TPU v5e, 256 chips/pod. Single pod = (16, 16) ("data", "model");
two pods = (2, 16, 16) ("pod", "data", "model") - the "pod" axis carries
pure data parallelism (gradient all-reduce crosses DCN, everything else
stays on-pod ICI).
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit axis types on the mesh
    from jax.sharding import AxisType

    def _axis_kwargs(n_axes: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n_axes}

except ImportError:  # older jax: meshes are implicitly Auto-typed
    AxisType = None

    def _axis_kwargs(n_axes: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic restarts, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes), **_axis_kwargs(len(axes)))


def host_device_counts():
    return {
        "n_devices": jax.device_count(),
        "n_local": jax.local_device_count(),
        "process_index": jax.process_index(),
        "process_count": jax.process_count(),
    }
