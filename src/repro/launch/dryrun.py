import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.

"""Multi-pod dry-run: AOT .lower().compile() for every assigned
(architecture x input-shape) cell on the production meshes, plus the
memory / cost / collective analysis the roofline reads.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod|--both] [--smoke]
  python -m repro.launch.dryrun --all --out experiments/dryrun

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with:
  flops / bytes from compiled.cost_analysis()
  per-device memory from compiled.memory_analysis()
  collective bytes by op type, parsed from the partitioned HLO
  the three roofline terms (TPU v5e constants; see EXPERIMENTS.md).

(note: no `from __future__ import annotations` here - the XLA_FLAGS
lines above must stay the first statements in the file.)
"""
import argparse
import json
import re
import time
import traceback

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES
from repro.launch import shardings as sh
from repro.launch.mesh import make_production_mesh
from repro.models import registry
from repro.optim import adamw

# ---- TPU v5e hardware constants (roofline) --------------------------------
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link (aggregate per-chip figure used as-is)

FSDP_THRESHOLD = 1_000_000_000  # params >= 1B: shard params over "data"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """bytes of one 'dtype[d0,d1,...]' HLO type string."""
    m = re.match(r"(\w+)\[([\d,]*)\]", type_str)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in partitioned HLO.

    Convention (documented in EXPERIMENTS.md): the cost of a collective
    is its RESULT size - a uniform, parseable proxy for wire bytes
    (exact wire cost differs by algorithm; ratios between configs are
    what the perf loop optimizes).
    """
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    # result type(s) appear between '=' and the op name
    pat = re.compile(
        r"=\s+((?:\([^)]*\))|(?:\w+\[[^\]]*\]\S*))\s+(%?)("
        + "|".join(_COLLECTIVES) + r")(-start)?\(")
    for m in pat.finditer(hlo_text):
        types, _, op, _ = m.groups()
        b = 0
        for t in re.findall(r"\w+\[[\d,]*\]", types):
            b += _shape_bytes(t)
        out[op] += b
        counts[op] += 1
    out_total = sum(out.values())
    return {"by_op": out, "counts": counts, "total": out_total}


def _flatten_cost(ca) -> dict:
    if ca is None:
        return {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


def _memory(ma) -> dict:
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def model_flops(cfg, n_params: int, shape) -> float:
    """MODEL_FLOPS = 6ND train / 2ND per generated token (active params)."""
    if cfg.n_routed:
        emb = cfg.vocab * cfg.d_model * (1 if cfg.tied_embeddings else 2)
        expert_p = 3 * cfg.d_model * cfg.d_expert * cfg.n_layers
        inactive = (cfg.n_routed - cfg.top_k) * expert_p
        n_active = n_params - inactive
    else:
        n_active = n_params
    tokens = shape.global_batch * (
        shape.seq_len if shape.kind != "decode" else 1)
    return (6.0 if shape.kind == "train" else 2.0) * n_active * tokens


def logits_sharding(mesh, cfg, batch: int):
    """(B, L, V) sharding honoring divisibility on both axes."""
    dp = sh.dp_axes(mesh)
    b_ax = dp if batch % sh.dp_size(mesh) == 0 else None
    v_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
    return NamedSharding(mesh, P(b_ax, None, v_ax))


# Perf variants (EXPERIMENTS.md section Perf). Each entry: (config
# overrides, step options). "opt" is the beyond-paper combination.
VARIANTS = {
    "baseline": ({}, {}),
    "A1": ({"attn_kv_hoist": True}, {}),
    "A2": ({}, {"cast_bf16": True}),
    "A3": ({"moe_cap_shard": True}, {}),
    "A12": ({"attn_kv_hoist": True}, {"cast_bf16": True}),
    "A123": ({"attn_kv_hoist": True, "moe_cap_shard": True},
             {"cast_bf16": True}),
    "B1": ({"kv_mode": "anchored"}, {}),
    "B2": ({"kv_mode": "anchored"}, {"serve_bf16": True}),
    "C1": ({"ssd_compute": "bf16"}, {}),
    "opt": ({"attn_kv_hoist": True, "moe_cap_shard": True,
             "ssd_compute": "bf16"}, {"cast_bf16": True}),
}


def build_cell(arch: str, shape_name: str, *, smoke: bool, mesh,
               variant: str = "baseline"):
    """Returns (fn, in_args, in_shardings, out_shardings)."""
    import dataclasses
    cfg = registry.get_config(arch, smoke=smoke)
    overrides, step_opts = VARIANTS[variant]
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mod = registry.get_module(cfg)
    specs = registry.input_specs(cfg, shape)
    params_abs = registry.abstract_params(cfg)
    if step_opts.get("serve_bf16") and shape.kind != "train":
        # Perf B2: serving params live in bf16 with TP-only sharding -
        # no FSDP gathers on the decode critical path (a serving system
        # never holds fp32 masters).
        params_abs = jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(
                x.shape, jnp.bfloat16 if x.dtype == jnp.float32
                else x.dtype),
            params_abs)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params_abs))
    fsdp = (n_params >= FSDP_THRESHOLD
            and not (step_opts.get("serve_bf16") and shape.kind != "train"))
    p_sh = sh.param_shardings(mesh, params_abs, fsdp=fsdp)
    repl = sh.replicated(mesh)
    dp = sh.dp_axes(mesh)

    if shape.kind == "train":
        batch_abs = specs["batch"]
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        o_sh = sh.opt_shardings(mesh, opt_abs, p_sh)
        b_sh = sh.batch_shardings(mesh, batch_abs)
        ocfg = adamw.OptConfig()

        cast_bf16 = step_opts.get("cast_bf16", False)

        def train_step(params, opt_state, batch):
            if cast_bf16:
                # Perf A2: cast the fp32 master to bf16 BEFORE use so the
                # FSDP all-gathers move 2-byte words; grads come back
                # bf16 and are accumulated fp32 in the optimizer.
                def fwd(p):
                    pb = jax.tree.map(
                        lambda x: x.astype(jnp.bfloat16)
                        if x.dtype == jnp.float32 and x.ndim >= 2 else x,
                        p)
                    return mod.loss_fn(pb, batch, cfg)
            else:
                def fwd(p):
                    return mod.loss_fn(p, batch, cfg)
            (loss, _), grads = jax.value_and_grad(
                fwd, has_aux=True)(params)
            new_p, new_o, metrics = adamw.apply_updates(
                ocfg, params, grads, opt_state)
            return new_p, new_o, loss

        fn = train_step
        in_args = (params_abs, opt_abs, batch_abs)
        in_sh = (p_sh, o_sh, b_sh)
        out_sh = (p_sh, o_sh, repl)
    elif shape.kind == "prefill":
        tok = specs["tokens"]
        extra = {k: v for k, v in specs.items() if k != "tokens"}
        e_sh = sh.batch_shardings(mesh, extra)
        cache_abs = jax.eval_shape(
            lambda p, t, **kw: mod.prefill(p, t, cfg, shape.seq_len, **kw),
            params_abs, tok, **extra)[1]
        c_sh = sh.cache_shardings(mesh, cache_abs, shape.global_batch,
                                  shape.seq_len)

        def prefill_step(params, tokens, **kw):
            return mod.prefill(params, tokens, cfg, shape.seq_len, **kw)

        fn = prefill_step
        in_args = (params_abs, tok)
        logits_sh = logits_sharding(mesh, cfg, shape.global_batch)
        in_sh = (p_sh, sh.batch_shardings(mesh, tok))
        if extra:
            fn2 = fn

            def fn(params, tokens, extra_in):
                return fn2(params, tokens, **extra_in)

            in_args = (params_abs, tok, extra)
            in_sh = (p_sh, sh.batch_shardings(mesh, tok), e_sh)
        out_sh = (logits_sh, c_sh)
    else:  # decode
        tok = specs["tokens"]
        cache_abs = specs["cache"]
        c_sh = sh.cache_shardings(mesh, cache_abs, shape.global_batch,
                                  shape.seq_len)
        tok_sh = sh.batch_shardings(mesh, tok)
        logits_sh = logits_sharding(mesh, cfg, shape.global_batch)

        def serve_step(params, tokens, cache):
            return mod.decode_step(params, tokens, cache, cfg)

        fn = serve_step
        in_args = (params_abs, tok, cache_abs)
        in_sh = (p_sh, tok_sh, c_sh)
        out_sh = (logits_sh, c_sh)
    return cfg, fn, in_args, in_sh, out_sh, n_params


def _analyze(compiled, mesh):
    cost = _flatten_cost(compiled.cost_analysis())
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    del hlo
    return cost.get("flops", 0.0), cost.get("bytes accessed", 0.0), coll


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, smoke: bool,
             out_dir: str | None, probe: str = "unrolled",
             variant: str = "baseline") -> dict:
    mesh_name = "2x16x16" if multi_pod else "16x16"
    shape = SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": shape.kind, "variant": variant, "ok": False}
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        jax.set_mesh(mesh)
        cfg, fn, in_args, in_sh, out_sh, n_params = build_cell(
            arch, shape_name, smoke=smoke, mesh=mesh, variant=variant)
        rec["n_params"] = n_params
        with mesh:
            lowered = jax.jit(fn, in_shardings=in_sh,
                              out_shardings=out_sh).lower(*in_args)
            rec["t_lower_s"] = round(time.time() - t0, 2)
            t1 = time.time()
            compiled = lowered.compile()
            rec["t_compile_s"] = round(time.time() - t1, 2)
        mem = _memory(compiled.memory_analysis())
        print(f"[{arch} {shape_name} {mesh_name}] "
              f"mem={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB tmp "
              f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB")
        flops, byts, coll = _analyze(compiled, mesh)
        rec.update({"raw_flops_per_device": flops,
                    "raw_bytes_per_device": byts,
                    "raw_collectives": coll})

        # cost probe: XLA counts while-loop bodies once (verified 1/L on
        # a scanned matmul); the fully-unrolled probe compile reports the
        # true per-step flops/bytes/collective totals. Memory analysis
        # stays with the loop form (the artifact that would run).
        # probe: "unrolled" | "analytic" | "none"
        if probe == "unrolled":
            from repro.models import scan_config
            t2 = time.time()
            try:
                with scan_config.full_unroll(), mesh:
                    cfg2, fn2, in2, ish2, osh2, _ = build_cell(
                        arch, shape_name, smoke=smoke, mesh=mesh,
                        variant=variant)
                    probe_c = jax.jit(
                        fn2, in_shardings=ish2,
                        out_shardings=osh2).lower(*in2).compile()
                flops, byts, coll = _analyze(probe_c, mesh)
                rec["probe"] = "unrolled"
                del probe_c
            except Exception as e:
                probe = "analytic"
                rec["probe_error"] = type(e).__name__
            rec["t_probe_s"] = round(time.time() - t2, 2)
        if probe == "analytic":
            # layer-count scaling of the loop-form costs: exact for the
            # layer-dominated portion, ignores the (small) outside-scan
            # part; used where the unrolled compile is intractable.
            rec["probe"] = "analytic"
            scale = cfg.n_layers + getattr(cfg, "n_enc_layers", 0)
            flops, byts = flops * scale, byts * scale
            coll = {"by_op": {k: v * scale
                              for k, v in coll["by_op"].items()},
                    "counts": coll["counts"],
                    "total": coll["total"] * scale}
        elif probe == "none":
            rec["probe"] = "none"

        n_chips = mesh.devices.size
        mf = model_flops(cfg, n_params, shape)
        rec.update({
            "ok": True,
            "memory": mem,
            "flops_per_device": flops,
            "bytes_per_device": byts,
            "collectives": coll,
            "n_chips": n_chips,
            "model_flops_global": mf,
            # terms in seconds (cost_analysis is per-device for SPMD =>
            # no /chips on flops/bytes; collective result-bytes likewise)
            "t_compute": flops / PEAK_FLOPS,
            "t_memory": byts / HBM_BW,
            "t_collective": coll["total"] / ICI_BW,
            "useful_flops_frac": (mf / n_chips) / flops if flops else None,
        })
        terms = {"compute": rec["t_compute"], "memory": rec["t_memory"],
                 "collective": rec["t_collective"]}
        rec["bottleneck"] = max(terms, key=terms.get)
        print(f"  flops/dev={flops:.3e} bytes/dev={byts:.3e} "
              f"coll={coll['total']:.3e}B -> {rec['bottleneck']}-bound "
              f"(c={rec['t_compute']*1e3:.1f}ms m={rec['t_memory']*1e3:.1f}ms "
              f"x={rec['t_collective']*1e3:.1f}ms) "
              f"probe={rec.get('probe')}")
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()
        print(f"[{arch} {shape_name} {mesh_name}] FAIL {rec['error']}")
    rec["t_total_s"] = round(time.time() - t0, 2)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = "" if variant == "baseline" else f"__{variant}"
        safe = f"{arch}__{shape_name}__{mesh_name}{suffix}".replace(
            "/", "_")
        with open(os.path.join(out_dir, safe + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run single-pod AND multi-pod meshes")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-done", action="store_true")
    ap.add_argument("--probe-mode", default="unrolled",
                    choices=["unrolled", "analytic", "none"])
    ap.add_argument("--variant", default="baseline",
                    choices=list(VARIANTS))
    args = ap.parse_args()

    cells = (registry.runnable_cells(smoke=args.smoke) if args.all
             else [(args.arch, args.shape)])
    meshes = [False, True] if args.both else [args.multi_pod]
    n_ok = n_fail = 0
    for arch, shape_name in cells:
        for mp in meshes:
            if args.skip_done and args.out:
                mesh_name = "2x16x16" if mp else "16x16"
                p = os.path.join(
                    args.out, f"{arch}__{shape_name}__{mesh_name}.json")
                if os.path.exists(p):
                    with open(p) as f:
                        if json.load(f).get("ok"):
                            n_ok += 1
                            continue
            rec = run_cell(arch, shape_name, multi_pod=mp,
                           smoke=args.smoke, out_dir=args.out,
                           probe=args.probe_mode,
                           variant=args.variant)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
