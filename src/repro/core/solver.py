"""Mixed-precision SPH solver (paper Fig. 6 flowchart).

One jit-able ``step`` covering the paper's three approaches (Table 4):
  I   : cell-list NNPS in hi precision, absolute fp32 positions.
  II  : cell-list NNPS in fp16 *absolute* coordinates, fp32 positions.
  III : RCLL - positions live permanently as (int cell, fp16 relative);
        NNPS in fp16 relative coordinates (Eq. 7); positions advanced in
        relative form (Eq. 8). No absolute round-trip after init.

The physics tier (density/momentum/EOS/integration) is always the
policy's ``physics`` dtype (fp32 here; fp64 on CPU for the accuracy
benchmarks via scoped x64).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cells as cells_lib
from repro.core import nnps, rcll, sph
from repro.core.domain import Domain
from repro.core.precision import PrecisionPolicy

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SPHConfig:
    domain: Domain
    ds: float  # particle spacing
    dt: float
    rho0: float = 1.0
    c0: float = 1.25  # speed of sound (>= 10 * v_max for WCSPH)
    mu: float = 1.0  # dynamic viscosity (rho0 * nu)
    body_force: tuple[float, ...] = (0.0, 0.0)
    max_neighbors: int = 40
    capacity: int | None = None
    algo: str = "rcll"  # "all" | "cell" | "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()

    @property
    def h(self) -> float:
        return self.domain.h

    def cap(self, n: int) -> int:
        return self.capacity or cells_lib.default_capacity(self.domain, n)


class SPHState(NamedTuple):
    """Particle system state. ``xn`` is the normalized-absolute position
    (source of truth for algos all/cell); ``rc`` is the RCLL state (source
    of truth for algo rcll). The inactive representation is frozen at its
    initial value and never read."""

    xn: Array  # (N, d) fp32 normalized absolute positions
    rc: rcll.RCLLState
    fluid: sph.FluidState
    fixed: Array  # (N,) bool - wall/dummy particles (v pinned to 0)
    t: Array  # () fp32 simulation time


def init_state(
    cfg: SPHConfig, x_phys, v, m, rho, fixed=None
) -> SPHState:
    xn = cfg.domain.normalize(jnp.asarray(x_phys), dtype=jnp.float32)
    rc = rcll.init_state(cfg.domain, xn, dtype=cfg.policy.coords_dtype)
    n = xn.shape[0]
    fluid = sph.FluidState(
        v=jnp.asarray(v, jnp.float32),
        rho=jnp.asarray(rho, jnp.float32),
        m=jnp.asarray(m, jnp.float32),
    )
    if fixed is None:
        fixed = jnp.zeros((n,), bool)
    return SPHState(xn=xn, rc=rc, fluid=fluid, fixed=fixed,
                    t=jnp.zeros((), jnp.float32))


def positions(cfg: SPHConfig, state: SPHState, dtype=jnp.float32) -> Array:
    """Physical positions decoded from the active representation."""
    if cfg.algo == "rcll":
        xn = rcll.to_normalized(cfg.domain, state.rc, dtype=dtype)
    else:
        xn = state.xn
    return cfg.domain.denormalize(xn, dtype=dtype)


def _neighbors_and_pairs(cfg: SPHConfig, state: SPHState):
    """NNPS (low-precision tier) + pair geometry (physics tier)."""
    dom, pol = cfg.domain, cfg.policy
    n = state.xn.shape[0]
    k = cfg.max_neighbors
    if cfg.algo == "rcll":
        nl, _ = rcll.neighbors(
            dom, state.rc, dtype=pol.nnps_dtype, k=k, capacity=cfg.cap(n)
        )
        disp, r = rcll.pair_displacements(dom, state.rc, nl,
                                          dtype=pol.physics_dtype)
        return nl, disp, r
    if cfg.algo == "cell":
        nl = nnps.cell_list_neighbors(
            dom, state.xn, dtype=pol.nnps_dtype, k=k, capacity=cfg.cap(n)
        )
    elif cfg.algo == "all":
        nl = nnps.all_list_neighbors(
            state.xn, dom.radius_norm, dtype=pol.nnps_dtype, k=k, domain=dom
        )
    else:
        raise ValueError(cfg.algo)
    # Physics-tier pair geometry from hi-precision absolute positions.
    xi = state.xn[:, None, :]
    xj = state.xn[nl.idx]
    diff = (xi - xj).astype(pol.physics_dtype)
    span = [
        (2.0 * s / dom.h_d) if p else 0.0
        for s, p in zip(dom.spans, dom.periodic)
    ]
    if any(dom.periodic):
        sp = jnp.asarray(span, diff.dtype)
        wrapped = diff - jnp.round(diff / jnp.where(sp > 0, sp, 1)) * sp
        diff = jnp.where(sp > 0, wrapped, diff)
    disp = diff * (dom.h_d / 2.0)  # physical units
    r = jnp.sqrt(jnp.sum(disp * disp, axis=-1))
    return nl, disp, r


def step(cfg: SPHConfig, state: SPHState) -> SPHState:
    """One mixed-precision WCSPH step (symplectic Euler)."""
    dom = cfg.domain
    dim = dom.dim
    nl, disp, r = _neighbors_and_pairs(cfg, state)
    gw = sph.grad_w(disp, r, cfg.h, dim, nl.mask)

    fl = state.fluid
    # Continuity -> density (physics tier).
    drho = sph.continuity_rhs(fl, nl.idx, nl.mask, gw)
    rho = fl.rho + cfg.dt * drho
    p = sph.eos_tait(rho, cfg.rho0, cfg.c0)

    # Momentum -> velocity. Wall particles stay pinned.
    bf = jnp.asarray(cfg.body_force, jnp.float32)
    fl2 = sph.FluidState(v=fl.v, rho=rho, m=fl.m)
    acc = sph.momentum_rhs(
        fl2, p, nl.idx, nl.mask, gw, disp, r,
        h=cfg.h, mu=cfg.mu, body_force=bf,
    )
    v = fl.v + cfg.dt * acc
    v = jnp.where(state.fixed[:, None], 0.0, v)

    # Kick positions (active representation only).
    dx_phys = v * cfg.dt
    dxn = dx_phys * (2.0 / dom.h_d)
    if cfg.algo == "rcll":
        rc = rcll.advance(dom, state.rc, dxn, dtype=cfg.policy.coords_dtype)
        xn = state.xn
    else:
        xn = state.xn + dxn
        # wrap periodic axes back into the box
        lo = jnp.asarray([-s / dom.h_d for s in dom.spans], jnp.float32) * 0 - 1.0
        span = jnp.asarray(
            [2.0 * s / dom.h_d if p else 0.0
             for s, p in zip(dom.spans, dom.periodic)], jnp.float32)
        org = jnp.asarray(dom.origin_norm, jnp.float32)
        wrapped = org + jnp.mod(xn - org, jnp.where(span > 0, span, 1.0))
        xn = jnp.where(span > 0, wrapped, xn)
        rc = state.rc
    return SPHState(
        xn=xn, rc=rc,
        fluid=sph.FluidState(v=v, rho=rho, m=fl.m),
        fixed=state.fixed, t=state.t + cfg.dt,
    )


@partial(jax.jit, static_argnums=(0, 2))
def simulate(cfg: SPHConfig, state: SPHState, nsteps: int) -> SPHState:
    """Run ``nsteps`` steps under lax.scan (single fused XLA program)."""
    def body(s, _):
        return step(cfg, s), None

    out, _ = jax.lax.scan(body, state, None, length=nsteps)
    return out
