"""Mixed-precision SPH solver (paper Fig. 6 flowchart).

One jit-able ``step`` covering the paper's three approaches (Table 4):
  I   : cell-list NNPS in hi precision, absolute fp32 positions.
  II  : cell-list NNPS in fp16 *absolute* coordinates, fp32 positions.
  III : RCLL - positions live permanently as (int cell, fp16 relative);
        NNPS in fp16 relative coordinates (Eq. 7); positions advanced in
        relative form (Eq. 8). No absolute round-trip after init.

The physics tier (density/momentum/EOS/integration) is always the
policy's ``physics`` dtype (fp32 here; fp64 on CPU for the accuracy
benchmarks via scoped x64).

Persistent cell-packed pipeline (the production RCLL path)
----------------------------------------------------------
The RCLL path no longer re-bins and re-searches every step. Instead the
scan carry holds a *cell-packed* state (all per-particle arrays physically
reordered by flat cell id - the paper's Thrust xy-sort locality
optimization made persistent) plus a Verlet-skin neighbor list:

  * at (re)build time, particles are stably sorted by flat cell id
    (``rcll.pack_state``) and neighbors are searched with the radius
    inflated to ``r + skin``;
  * between rebuilds only pair geometry (Eq. 7 decode) and the physics
    sums run; the neighbor list is reused verbatim. Extra skin pairs are
    exactly harmless because the B-spline kernel and its derivative vanish
    beyond the true support ``2h``;
  * per-particle displacement since the last rebuild is accumulated in
    fp32 and the list is rebuilt (via ``lax.cond`` inside the scanned
    step) only when ``max_i |disp_i| > skin/2`` - the classic Verlet-list
    criterion. ``skin=0`` degenerates to per-step rebuild (the seed
    behavior); ``rebuild_every=n`` forces a static cadence for
    benchmarking.

Fused force pass (this PR's tentpole)
-------------------------------------
``backend`` now selects the whole NNPS + force pipeline, not just the
neighbor producer:

  * ``"reference"`` - the gather path: per-particle neighbor list,
    ``rcll.pair_displacements`` (N, K, d), ``sph.gather_pair_fields``.
    Every pair intermediate round-trips through HBM; kept as the oracle.
  * ``"xla"`` - jnp neighbor search + the fused cell-blocked force pass
    (``core/fused.py``): pair geometry decoded and consumed in chunks of
    packed (cell-sorted) rows, peak pair memory O(chunk*K*d).
  * ``"pallas"`` - Pallas neighbor tables + Pallas fused force kernels
    (``kernels/rcll_force.py``): per (cell, neighbor-cell) tile, Eq. 7
    decode + B-spline gradient + continuity/momentum accumulation in
    VMEM; no neighbor list is consumed at all (compact support masks
    out-of-range candidates exactly).

The default is pallas on TPU and xla elsewhere, so CPU tests always
exercise the fused path with the reference path as the test oracle.
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cells as cells_lib
from repro.core import fused, health, nnps, rcll, sph, statepack
from repro.core import scheme as scheme_lib
from repro.core.domain import Domain
from repro.core.precision import PrecisionPolicy

_log = logging.getLogger(__name__)

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SPHConfig:
    domain: Domain
    ds: float  # particle spacing
    dt: float
    rho0: float = 1.0
    c0: float = 1.25  # speed of sound (>= 10 * v_max for WCSPH)
    mu: float = 1.0  # dynamic viscosity (rho0 * nu)
    body_force: tuple[float, ...] = (0.0, 0.0)
    max_neighbors: int = 40
    capacity: int | None = None
    algo: str = "rcll"  # "all" | "cell" | "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    # Physics-term specification (core/scheme.py). None builds the
    # legacy WCSPH scheme from rho0/c0/mu/body_force above, so every
    # pre-scheme call site keeps its exact behavior; cases that want a
    # different EOS / viscosity model pass a Scheme directly (the
    # legacy scalar fields are then ignored by the solver).
    scheme: scheme_lib.Scheme | None = None
    # Clamp wall-particle density at >= rho0 after the continuity
    # update (the DualSPHysics dummy-particle treatment): free-surface
    # cases (dam break) otherwise develop tensile wall underpressure
    # that sticks fluid to the walls.
    wall_rho_clamp: bool = False
    # --- persistent-pipeline knobs (RCLL path only) ---
    skin: float = 0.0  # physical Verlet-skin width added to the search radius
    rebuild_every: int | None = None  # static rebuild cadence (overrides skin)
    backend: str | None = None  # None=auto | "reference" | "xla" | "pallas"
    # Rows per chunk of the fused XLA force pass (0 = auto). Static.
    force_chunk: int = 0
    # Merged candidate budget per particle of the table-free window
    # search (the production rebuild path). 0 = auto: the 3^dim-block
    # lattice bound from ``ds`` (``nnps.auto_window``);
    # ``3^dim * capacity`` reproduces the dense-table coverage
    # guarantee exactly. Tighter windows cut search bandwidth;
    # truncation is flagged through the overflow plumbing. ``None``
    # selects the dense-table candidate search (``nnps.rcll_neighbors``
    # over the (C, cap) table) as the oracle path. Static.
    window: int | None = 0
    # DEPRECATED alias for the strict guard policy: raise
    # (health.SimulationDiverged) from simulate / simulate_stats when
    # any cell-table or neighbor-list capacity overflowed during the
    # run. The check is ONE host read of the overflow flag after the
    # scan returns — the in-scan jax.debug.callback sync point it used
    # to cost is gone. New code should run under the health guard
    # (core/recovery.py), which detects AND recovers. See README for
    # the ``max_neighbors`` sizing rule.
    check_overflow: bool = False
    # Deterministic fault-injection hook (health.FaultSpec) driven by
    # the recovery tests and the CI guard smoke: None in production.
    # Fires inside step_persistent when the step counter matches.
    fault: health.FaultSpec | None = None

    @property
    def h(self) -> float:
        return self.domain.h

    def cap(self, n: int) -> int:
        """Per-cell table capacity: explicit override or the robust
        estimate (``cells.robust_capacity`` — covers BOTH the
        domain-mean occupancy and the close-packed lattice bound, so a
        mostly-empty free-surface domain cannot silently under-size its
        cells; see the dam-break post-mortem in cells.py)."""
        return self.capacity or cells_lib.robust_capacity(
            self.domain, self.ds, n
        )

    def resolved_window(self) -> int:
        """The window search's merged candidate budget (window == 0 ->
        the ds-derived 3^dim-block lattice bound)."""
        if self.window is None:
            raise ValueError("window=None selects the table oracle path")
        if self.window > 0:
            return self.window
        return nnps.auto_window(self.domain, ds=self.ds)

    @property
    def skin_norm(self) -> float:
        """Skin width in normalized (Eq. 5) units."""
        return 2.0 * self.skin / self.domain.h_d

    @property
    def search_radius_cell(self) -> float:
        """Inflated search radius in reference-cell units (r + skin)."""
        return float(
            (self.domain.radius_norm + self.skin_norm) / self.domain.hc_ref
        )

    @property
    def resolved_scheme(self) -> scheme_lib.Scheme:
        """The physics-term spec the force backends consume (static)."""
        if self.scheme is not None:
            return self.scheme
        return scheme_lib.wcsph(
            self.c0, self.rho0, self.mu, self.body_force
        )

    @property
    def resolved_backend(self) -> str:
        if self.backend is not None:
            if self.backend not in ("reference", "xla", "pallas"):
                raise ValueError(
                    f"unknown backend {self.backend!r}; one of "
                    "'reference', 'xla', 'pallas'"
                )
            return self.backend
        return "pallas" if jax.default_backend() == "tpu" else "xla"

    def validate_skin(self) -> None:
        """The 3^dim cell neighborhood only guarantees coverage up to one
        cell edge: pairs separated by >= min(cell_sizes) can be missed.
        The inflated radius must stay inside that guarantee - build the
        Domain with ``cell_factor >= (r + skin) / r`` to use a skin."""
        if self.skin < 0:
            raise ValueError(f"skin must be >= 0, got {self.skin}")
        limit = min(self.domain.cell_sizes)
        if self.domain.radius + self.skin > limit * (1 + 1e-9):
            raise ValueError(
                f"skin {self.skin} too large: r + skin = "
                f"{self.domain.radius + self.skin:.6g} exceeds the cell "
                f"coverage guarantee {limit:.6g}; increase cell_factor to "
                f">= {(self.domain.radius + self.skin) / self.domain.radius:.3f}"
            )


class SPHState(NamedTuple):
    """Particle system state. ``xn`` is the normalized-absolute position
    (source of truth for algos all/cell); ``rc`` is the RCLL state (source
    of truth for algo rcll). The inactive representation is frozen at its
    initial value and never read.

    Boundary fields (core/boundaries.py): ``fixed`` marks wall/dummy
    particles — they ride every pair sum (density, pressure, viscosity)
    through the same arrays/record rows as fluid particles but are never
    advected, and their velocity is PRESCRIBED: ``v_wall`` where given
    (moving lids), 0 otherwise. ``kind`` is the int8 classification the
    mask derives from (boundaries.FLUID/WALL), carried for observables
    and future kinds; None on legacy states (then fixed is authoritative).
    """

    xn: Array  # (N, d) fp32 normalized absolute positions
    rc: rcll.RCLLState
    fluid: sph.FluidState
    fixed: Array  # (N,) bool - wall/dummy particles (never advected)
    t: Array  # () fp32 simulation time
    kind: Array | None = None  # (N,) int8 boundaries.FLUID / WALL
    v_wall: Array | None = None  # (N, d) fp32 prescribed wall velocity


class PersistentCarry(NamedTuple):
    """Scan carry of the packed persistent pipeline.

    All per-particle arrays inside ``st`` are in PACKED (cell-sorted)
    order; ``order`` maps packed position -> original particle id so the
    API boundary (``finalize``) can restore user indexing. ``nl`` is in
    packed indexing and was built with the skin-inflated radius.
    """

    st: SPHState
    order: Array  # (N,) int32 packed -> original
    nl: nnps.NeighborList  # packed indexing, radius r + skin
    disp_acc: Array  # (N, d) fp32 normalized displacement since rebuild
    rebuilds: Array  # () int32 number of bin+search rebuilds so far
    steps: Array  # () int32 steps taken since init
    overflow: Array  # () bool any cell-table/neighbor-list overflow seen
    # The packed-state binning of the last rebuild (all rcll backends).
    # Between rebuilds it is stale but exact to decode against: the
    # pallas force kernels re-anchor migrated particles against its
    # (C, cap) slot structure, and the next rebuild's counting-sort
    # pack reuses its near-sorted run structure for the O(N) stable
    # rank (cells.pack_particles prev=...).
    binning: cells_lib.CellBinning | None = None
    # XLA fused backend only (None otherwise): neighbor ids with invalid
    # slots redirected to the dummy row N. The production window search
    # emits this layout directly (sort compaction pads with N); the
    # table-oracle path sanitizes once per rebuild. Static between
    # rebuilds either way.
    idx_dummy: Array | None = None
    # Half-record mass normalizer (fused.mass_scale), computed ONCE at
    # init: masses never change during a run, so the per-step O(N)
    # reduction (a sync point in the chunked sweep) is hoisted out of
    # the scan entirely. None on paths that don't consume it.
    m_scale: Array | None = None
    # Pallas backend only: the static cell-major mass tile
    # (ops.mass_table). Masses never change, so it is rebuilt only when
    # the packed ORDER changes (i.e. at rebuild) — the per-step tile
    # refresh then touches exactly the coordinate/velocity/density
    # halves of the record stream.
    m_table: Array | None = None
    # () uint32 accumulated health bits (health.CELL_OVERFLOW /
    # WINDOW_TRUNC) ORed in at every rebuild — unlike the live binning
    # and list sentinels, this sees overflow in ANY intermediate
    # rebuild. The guarded-block driver clears it at block entry to get
    # per-block semantics; ``overflow`` above stays the run-sticky bool
    # every existing consumer reads.
    flags: Array | None = None


class SimStats(NamedTuple):
    """Diagnostics of a persistent-pipeline run (see simulate_stats)."""

    rebuilds: Array  # () int32
    steps: Array  # () int32
    overflow: Array  # () bool


def init_state(
    cfg: SPHConfig, x_phys, v, m, rho, fixed=None, kind=None, v_wall=None
) -> SPHState:
    xn = cfg.domain.normalize(jnp.asarray(x_phys), dtype=jnp.float32)
    rc = rcll.init_state(cfg.domain, xn, dtype=cfg.policy.coords_dtype)
    n = xn.shape[0]
    fluid = sph.FluidState(
        v=jnp.asarray(v, jnp.float32),
        rho=jnp.asarray(rho, jnp.float32),
        m=jnp.asarray(m, jnp.float32),
    )
    if kind is not None:
        kind = jnp.asarray(kind, jnp.int8)
        if fixed is None:
            fixed = kind != 0  # boundaries.FLUID
    if fixed is None:
        fixed = jnp.zeros((n,), bool)
    fixed = jnp.asarray(fixed, bool)
    if kind is None:
        kind = fixed.astype(jnp.int8)  # boundaries.WALL == 1
    if v_wall is not None:
        v_wall = jnp.asarray(v_wall, jnp.float32)
    return SPHState(xn=xn, rc=rc, fluid=fluid, fixed=fixed,
                    t=jnp.zeros((), jnp.float32), kind=kind, v_wall=v_wall)


def positions(cfg: SPHConfig, state: SPHState, dtype=jnp.float32) -> Array:
    """Physical positions decoded from the active representation."""
    if cfg.algo == "rcll":
        xn = rcll.to_normalized(cfg.domain, state.rc, dtype=dtype)
    else:
        xn = state.xn
    return cfg.domain.denormalize(xn, dtype=dtype)


# --------------------------------------------------------------------------
# Persistent cell-packed RCLL pipeline
# --------------------------------------------------------------------------
def _permute_state(st: SPHState, perm: Array, rc: rcll.RCLLState) -> SPHState:
    """Reorder every per-particle array by ``perm`` (rc supplied pre-sorted).

    One gather per field — the readable oracle form, used at the API
    boundary (``finalize_persistent``) and as the test reference for the
    fused row permutation the hot rebuild runs (``_permute_state_fused``).
    """
    return SPHState(
        xn=st.xn[perm],
        rc=rc,
        fluid=sph.FluidState(
            v=st.fluid.v[perm], rho=st.fluid.rho[perm], m=st.fluid.m[perm]
        ),
        fixed=st.fixed[perm],
        t=st.t,
        kind=None if st.kind is None else st.kind[perm],
        v_wall=None if st.v_wall is None else st.v_wall[perm],
    )


def _permute_state_fused(
    st: SPHState, perm: Array, rc: rcll.RCLLState, order: Array
) -> tuple[SPHState, Array]:
    """Reorder the whole per-particle state (and ``order``) by ONE gather.

    All fields are bit-packed into one contiguous u32 row buffer and
    permuted together (``statepack.permute_fields``) — bit-identical to
    :func:`_permute_state` plus ``order[perm]``, at a single row gather
    instead of ~8 strided per-field gathers. ``rc`` arrives pre-sorted
    from the counting-sort pack (its gathers live inside
    ``rcll.pack_state``).
    """
    xn, v, rho, m, fixed, kind, v_wall, order = statepack.permute_fields(
        (st.xn, st.fluid.v, st.fluid.rho, st.fluid.m, st.fixed,
         st.kind, st.v_wall, order),
        perm,
    )
    st2 = SPHState(
        xn=xn, rc=rc, fluid=sph.FluidState(v=v, rho=rho, m=m),
        fixed=fixed, t=st.t, kind=kind, v_wall=v_wall,
    )
    return st2, order


def _packed_neighbor_list(
    cfg: SPHConfig, ps: rcll.PackedState
) -> nnps.NeighborList:
    """Produce the (packed-indexing) neighbor list at rebuild time.

    Production (``cfg.window`` int): the table-free merged-window search
    (``nnps.rcll_neighbors_windows``) — no (C, cap, K) candidate table,
    no candidate-id gather, dummy-padded ids. Oracle (``window=None``):
    the dense-table candidate search over the (C, cap) cell table.
    One arithmetic dtype either way: the path choice must never change
    neighbor sets (asserted by the window-vs-table suite).
    """
    pol = cfg.policy
    if cfg.window is None:  # dense-table oracle
        return nnps.rcll_neighbors(
            cfg.domain,
            ps.rc.rel,
            ps.rc.cell_xy,
            dtype=pol.nnps_dtype,
            compute_dtype=pol.nnps_compute_dtype,
            k=cfg.max_neighbors,
            binning=ps.packing.binning,
            radius_cell=cfg.search_radius_cell,
        )
    return rcll.packed_neighbors(
        cfg.domain,
        ps,
        dtype=pol.nnps_dtype,
        compute_dtype=pol.nnps_compute_dtype,
        k=cfg.max_neighbors,
        radius_cell=cfg.search_radius_cell,
        window=cfg.resolved_window(),
    )


def _empty_neighbor_list(n: int) -> nnps.NeighborList:
    """Zero-capacity list for backends that never consume one."""
    return nnps.NeighborList(
        idx=jnp.zeros((n, 0), jnp.int32),
        mask=jnp.zeros((n, 0), bool),
        count=jnp.zeros((n,), jnp.int32),
    )


def _rebuild(cfg: SPHConfig, carry: PersistentCarry) -> PersistentCarry:
    """Re-sort by cell, re-bin, and re-search with the inflated radius.

    The minimal-bandwidth rebuild pipeline: counting-sort pack -> ONE
    fused state permutation -> merged-window search.

      * The re-sort is the counting-sort pack: the carried binning
        describes the run structure the arrays are currently in (the
        previous rebuild's), which turns the stable re-sort into O(N)
        bincount + exclusive-scan + rank passes
        (``cells.pack_particles``) — no argsort on the hot path (a
        ``lax.cond`` falls back to it if any particle out-ran the 3^dim
        neighborhood since the last rebuild).
      * The whole per-particle state rides one bit-packed u32 row
        buffer through a SINGLE gather (``_permute_state_fused``)
        instead of one strided gather per field.
      * The search is the table-free merged-window search: candidate
        ids are counting-sort range arithmetic (never gathered), the
        distance filter gathers one bit-packed row per candidate, and
        the sort compaction emits dummy-padded ids — so the fused force
        pass needs no per-slot sanitize (``idx_dummy`` is the list
        itself). The dense-table oracle (``window=None``) still
        sanitizes its select_k output.

    The pallas force path walks the 3^dim cell neighborhood directly and
    never reads a neighbor list, so its rebuild skips the search
    entirely and carries a zero-capacity list; its overflow flag then
    means exactly "cell table dropped particles" (K truncation cannot
    happen - the fused kernel sees every in-support pair).
    """
    n = carry.order.shape[0]
    ps = rcll.pack_state(
        cfg.domain, carry.st.rc, cfg.cap(n), prev=carry.binning
    )
    perm = ps.packing.order  # current-packed -> new-packed
    st, order = _permute_state_fused(carry.st, perm, ps.rc, carry.order)
    cell_over = ps.packing.binning.overflow > 0
    overflow = carry.overflow | cell_over
    flags = health.fold_flag(carry.flags, cell_over, health.CELL_OVERFLOW)
    binning = ps.packing.binning
    m_table = carry.m_table
    if cfg.resolved_backend == "pallas":
        from repro.kernels import ops  # deferred: core stays kernel-free

        nl = _empty_neighbor_list(n)
        idx_dummy = None
        m_table = ops.mass_table(
            binning, st.fluid.m, cfg.policy.records_dtype, carry.m_scale
        )
    else:
        nl = _packed_neighbor_list(cfg, ps)
        overflow = overflow | nl.overflowed
        win_bad = nl.overflowed
        if nl.trunc is not None:
            win_bad = win_bad | nl.trunc
        flags = health.fold_flag(flags, win_bad, health.WINDOW_TRUNC)
        # The window search already pads invalid slots with the dummy
        # id N — the fused sweep reads nl.idx directly (idx_dummy stays
        # None: carrying nl.idx twice would alias two donated buffers).
        # Only the table-oracle list (garbage invalid slots) sanitizes.
        idx_dummy = (
            fused._sanitized_idx(nl, n)
            if cfg.resolved_backend == "xla" and cfg.window is None
            else None
        )
    return PersistentCarry(
        st=st,
        order=order,
        nl=nl,
        disp_acc=jnp.zeros_like(carry.disp_acc),
        rebuilds=carry.rebuilds + 1,
        steps=carry.steps,
        overflow=overflow,
        binning=binning,
        idx_dummy=idx_dummy,
        m_scale=carry.m_scale,
        m_table=m_table,
        flags=flags,
    )


def init_persistent(cfg: SPHConfig, state: SPHState) -> PersistentCarry:
    """Pack the state and build the first skin-inflated neighbor list."""
    cfg.validate_skin()
    n = state.xn.shape[0]
    # Masses are constant over a run: the half-record normalizer is
    # computed once here and carried, never re-reduced inside the scan.
    m_scale = (
        fused.mass_scale(state.fluid.m)
        if cfg.policy.half_records and cfg.resolved_backend != "reference"
        else None
    )
    carry = PersistentCarry(
        st=state,
        order=jnp.arange(n, dtype=jnp.int32),
        nl=nnps.NeighborList(
            idx=jnp.zeros((n, cfg.max_neighbors), jnp.int32),
            mask=jnp.zeros((n, cfg.max_neighbors), bool),
            count=jnp.zeros((n,), jnp.int32),
        ),
        disp_acc=jnp.zeros((n, cfg.domain.dim), jnp.float32),
        rebuilds=jnp.zeros((), jnp.int32),
        steps=jnp.zeros((), jnp.int32),
        overflow=jnp.zeros((), bool),
        m_scale=m_scale,
        flags=jnp.zeros((), jnp.uint32),
    )
    carry = _rebuild(cfg, carry)
    # _rebuild hands the SAME array to st.rc.cell_xy and binning.cell_xy
    # (they only diverge once a step migrates particles). run_persistent
    # donates the carry, and XLA refuses to donate one buffer through two
    # arguments — materialize a distinct copy at this eager boundary.
    rc = carry.st.rc
    return carry._replace(
        st=carry.st._replace(rc=rc._replace(cell_xy=jnp.copy(rc.cell_xy)))
    )


def finalize_persistent(cfg: SPHConfig, carry: PersistentCarry) -> SPHState:
    """Restore original particle indexing at the API boundary."""
    inverse = cells_lib.inverse_permutation(carry.order)
    rc = rcll.RCLLState(
        cell_xy=carry.st.rc.cell_xy[inverse], rel=carry.st.rc.rel[inverse]
    )
    return _permute_state(carry.st, inverse, rc)


def _needs_rebuild(cfg: SPHConfig, carry: PersistentCarry) -> Array:
    """The Verlet-list criterion (or the static-cadence fallback)."""
    if cfg.rebuild_every is not None:
        return (carry.steps > 0) & (carry.steps % cfg.rebuild_every == 0)
    if cfg.skin == 0.0:
        # Degenerate skin: any movement invalidates the list.
        return jnp.max(jnp.abs(carry.disp_acc)) > 0.0
    max_disp = jnp.sqrt(
        jnp.max(jnp.sum(carry.disp_acc * carry.disp_acc, axis=-1))
    )
    return max_disp > 0.5 * cfg.skin_norm


def _gathered_pair_rhs(
    sch: scheme_lib.Scheme,
    dom: Domain,
    fl: sph.FluidState,
    nl: nnps.NeighborList,
    disp: Array,  # (N, K, d) x_i - x_j
    r: Array,  # (N, K)
    gw: Array,  # (N, K, d) masked kernel gradient
):
    """(drho, acc) pair sums of ``sch`` on gathered (N, K) pair arrays.

    The gather-path evaluation of the scheme's two momentum channels —
    the same ∇W/dv split as ``fused._pair_rhs`` and the Pallas force
    kernel, on the materialized pair arrays. Shared by the reference
    RCLL backend and the absolute-coordinate step, so every path in the
    solver consumes ONE scheme definition. Densities enter as
    reciprocals exactly like the fused layouts (N divisions, none per
    pair).
    """
    # Gather pair fields ONCE; continuity + momentum share them.
    pf = sph.gather_pair_fields(fl.v, fl.m, nl.idx, nl.mask)
    drho = sph.continuity_rhs_pairs(pf, gw)
    inv = (1.0 / fl.rho).astype(jnp.float32)
    por2 = sch.por2_inv(inv)
    inv_i, inv_j = inv[:, None], inv[nl.idx]
    r2 = r * r
    dv_dot_disp = jnp.sum(pf.dv * disp, axis=-1)
    gc = sch.gradw_pair_coef(
        pf.mj, por2[:, None], por2[nl.idx], inv_i, inv_j,
        dv_dot_disp, r2, h=dom.h,
    )
    acc = -jnp.sum(gc[..., None] * gw, axis=-2)
    if sch.has_dv_term or sch.has_delta_term:
        x_dot_gw = jnp.sum(disp * gw, axis=-1)
    if sch.has_dv_term:
        vc = sch.dv_pair_coef(pf.mj, x_dot_gw, inv_i, inv_j, r2, h=dom.h)
        acc = acc + jnp.sum(vc[..., None] * pf.dv, axis=-2)
    if sch.has_delta_term:
        drho = drho + jnp.sum(
            sch.drho_pair_term(
                pf.mj, inv_i, inv_j, x_dot_gw, r2, h=dom.h
            ),
            axis=-1,
        )
    return drho, acc


def _force_rhs_reference(cfg: SPHConfig, carry: PersistentCarry):
    """Gather path: per-pair arrays materialized in HBM (the oracle).

    Returns (drho, acc), both evaluated at the CURRENT state (standard
    explicit WCSPH: every RHS term from the common state, DualSPHysics-
    style symplectic Euler) - the property that lets the fused backends
    compute the entire right-hand side in one cell-blocked pass.
    """
    dom, pol = cfg.domain, cfg.policy
    st, nl = carry.st, carry.nl
    disp, r = rcll.pair_displacements(dom, st.rc, nl, dtype=pol.physics_dtype)
    gw = sph.grad_w(disp, r, cfg.h, dom.dim, nl.mask)
    return _gathered_pair_rhs(
        cfg.resolved_scheme, dom, st.fluid, nl, disp, r, gw
    )


def _resolved_records(cfg: SPHConfig) -> str:
    """The record layout the fused XLA pass actually runs.

    Half-width rows anchor coordinates in 16-bit cell columns, which
    caps the grid per axis (``fused.HALF_CELL_LIMIT``); past the cap the
    solver falls back to the fp32 layout rather than erroring — the
    policy's dtype is a bandwidth knob, not a correctness contract.
    """
    records = cfg.policy.records
    if records != "fp32":
        limit = fused.HALF_CELL_LIMIT.get(jnp.dtype(cfg.policy.records_dtype))
        if limit is not None and max(cfg.domain.ncells) >= limit:
            # Build-time fallback, loud once per compile (this helper
            # runs at trace time, not per step).
            _log.warning(
                "half-record layout %r disabled: grid %s exceeds the "
                "%d-cell anchor range; using fp32 records",
                records, tuple(cfg.domain.ncells), limit,
            )
            return "fp32"
    return records


def _force_rhs_fused_xla(cfg: SPHConfig, carry: PersistentCarry):
    """Fused cell-blocked force pass over packed row chunks (core/fused)."""
    st, nl, fl = carry.st, carry.nl, carry.st.fluid
    idx_dummy = carry.idx_dummy
    if idx_dummy is None and cfg.window is not None:
        # Window-search lists are dummy-padded by construction: the
        # list IS the sanitized id array, no extra buffer carried.
        idx_dummy = nl.idx
    return fused.force_rhs(
        cfg.domain, st.rc, nl, fl.v, fl.m, fl.rho,
        scheme=cfg.resolved_scheme, chunk=cfg.force_chunk,
        records=_resolved_records(cfg), idx_dummy=idx_dummy,
        m_scale=carry.m_scale,
    )


def _force_rhs_fused_pallas(cfg: SPHConfig, carry: PersistentCarry):
    """Fused Pallas tile kernels over the (stale-binning) cell tables."""
    from repro.kernels import ops  # deferred: core stays kernel-free

    dom = cfg.domain
    st, fl = carry.st, carry.st.fluid
    return ops.rcll_force_particles(
        dom, carry.binning, st.rc, fl.v, fl.m, fl.rho,
        scheme=cfg.resolved_scheme,
        records_dtype=cfg.policy.records_dtype,
        m_scale=carry.m_scale,
        m_table=carry.m_table,
    )


_FORCE_BACKENDS = {
    "reference": _force_rhs_reference,
    "xla": _force_rhs_fused_xla,
    "pallas": _force_rhs_fused_pallas,
}


def _physics_step(
    cfg: SPHConfig, carry: PersistentCarry, dt: Array | float | None = None
) -> PersistentCarry:
    """One WCSPH step on the packed state, reusing ``carry.nl``.

    Pair geometry is decoded fresh from the *current* RCLL state (exact
    cell deltas + relative payloads), so only the neighbor LIST is stale -
    and the skin guarantees it remains a superset of the true neighbors.
    The continuity + momentum pair sums run through the backend-selected
    force path (see module docstring); EOS/integration/boundary terms are
    per-particle and shared.

    ``dt`` optionally overrides ``cfg.dt`` with a TRACED value — the
    batched ensemble engine (core/ensemble.py) threads a per-member
    timestep through one shared compiled program so a single member can
    back off its dt without recompiling (or perturbing) the batch. The
    force pass itself never consumes dt, so this touches only the
    per-particle update below.
    """
    dom, pol = cfg.domain, cfg.policy
    sch = cfg.resolved_scheme
    if dt is None:
        dt = cfg.dt
    st, fl = carry.st, carry.st.fluid
    drho, acc = _FORCE_BACKENDS[cfg.resolved_backend](cfg, carry)
    rho = fl.rho + dt * drho
    if cfg.wall_rho_clamp:
        rho = jnp.where(st.fixed, jnp.maximum(rho, sch.rho0), rho)

    bf = sch.body_force_vec(dom.dim)
    v = fl.v + dt * (acc + bf)
    # Walls: prescribed velocity (0 or v_wall), never advected. The
    # prescribed values flow into the next step's pair sums through the
    # same v array (and thus the fused record rows) as fluid velocities.
    vw = 0.0 if st.v_wall is None else st.v_wall
    v = jnp.where(st.fixed[:, None], vw, v)

    dxn = jnp.where(
        st.fixed[:, None], 0.0, v * dt * (2.0 / dom.h_d)
    ).astype(jnp.float32)
    rc = rcll.advance(dom, st.rc, dxn, dtype=pol.coords_dtype)
    st2 = SPHState(
        xn=st.xn,
        rc=rc,
        fluid=sph.FluidState(v=v, rho=rho, m=fl.m),
        fixed=st.fixed,
        t=st.t + dt,
        kind=st.kind,
        v_wall=st.v_wall,
    )
    return PersistentCarry(
        st=st2,
        order=carry.order,
        nl=carry.nl,
        disp_acc=carry.disp_acc + dxn,
        rebuilds=carry.rebuilds,
        steps=carry.steps + 1,
        overflow=carry.overflow,
        binning=carry.binning,
        idx_dummy=carry.idx_dummy,
        m_scale=carry.m_scale,
        m_table=carry.m_table,
        flags=carry.flags,
    )


def exact_neighbor_list(
    cfg: SPHConfig, carry: PersistentCarry
) -> nnps.NeighborList:
    """Exact-radius neighbor sets (packed indexing) from the reused list.

    Refilters the skin-inflated ``carry.nl`` with the true support radius
    using the same Eq. (7) arithmetic as a fresh search - the result's
    neighbor SETS are identical to rebuilding at the current positions
    whenever the skin invariant (max displacement < skin/2) holds.

    Requires a list-producing backend: the pallas force path carries no
    neighbor list (its rebuild skips the search entirely).
    """
    if cfg.resolved_backend == "pallas":
        raise ValueError(
            "exact_neighbor_list needs backend='reference' or 'xla'; the "
            "pallas force path does not carry a neighbor list"
        )
    pol = cfg.policy
    d2 = rcll.pair_r2_cell(
        cfg.domain, carry.st.rc, carry.nl,
        dtype=pol.nnps_dtype, compute_dtype=pol.nnps_compute_dtype,
    )
    r_exact = nnps.rcll_radius_cell_units(cfg.domain)
    r2 = jnp.asarray(r_exact, d2.dtype) ** 2
    return nnps.refilter(carry.nl, d2, r2)


def step_persistent(cfg: SPHConfig, carry: PersistentCarry) -> PersistentCarry:
    """Rebuild-if-needed (lax.cond) + one physics step."""
    if cfg.fault is not None:
        # Injection precedes the rebuild decision so a teleported
        # particle's spiked displacement can trigger the Verlet rebuild
        # in the SAME step (the overlap must reach the neighbor list).
        carry = health.inject_fault(cfg.fault, carry)
    carry = jax.lax.cond(
        _needs_rebuild(cfg, carry),
        lambda c: _rebuild(cfg, c),
        lambda c: c,
        carry,
    )
    return _physics_step(cfg, carry)


def _scan_steps(
    cfg: SPHConfig, carry: PersistentCarry, nsteps: int
) -> PersistentCarry:
    """``nsteps`` persistent steps under one lax.scan (shared hot loop)."""

    def body(c, _):
        return step_persistent(cfg, c), None

    carry, _ = jax.lax.scan(body, carry, None, length=nsteps)
    return carry


@partial(jax.jit, static_argnums=(0, 2), donate_argnums=(1,))
def run_persistent(
    cfg: SPHConfig, carry: PersistentCarry, nsteps: int
) -> PersistentCarry:
    """Production scan entry point: advances a carry IN PLACE.

    The carry argument is donated, so the packed state buffers are
    updated without a second copy resident in HBM (honored on CPU and
    TPU) — call as ``carry = run_persistent(cfg, carry, n)`` and never
    touch the old carry again: its buffers are invalidated, INCLUDING
    arrays it aliases from the ``SPHState`` that ``init_persistent``
    consumed. Chain segments to checkpoint or stream diagnostics:

        carry = init_persistent(cfg, state)
        for _ in range(segments):
            carry = run_persistent(cfg, carry, steps_per_segment)
        state = finalize_persistent(cfg, carry)

    ``simulate``/``simulate_stats`` stay non-donating (callers reuse
    their ``state`` argument freely).
    """
    return _scan_steps(cfg, carry, nsteps)


def _raise_on_overflow(overflow, max_neighbors: int) -> None:
    """Strict-mode overflow raise (the deprecated check_overflow alias).

    Runs HOST-side after the jitted scan returns — the jax.debug.callback
    this used to ride (an in-scan device sync point) is retired; the
    health guard (core/recovery.py) is the recovering superset.
    """
    if overflow:
        raise health.SimulationDiverged(
            "neighbor capacity overflow: some particle saw more "
            f"candidates than max_neighbors={max_neighbors} (or a cell "
            "table row filled). Results silently dropped pairs - raise "
            "max_neighbors (see the sizing rule in README) or enlarge "
            "capacity.",
            checks=("window_trunc", "cell_overflow"),
            word=health.CAPACITY_CHECKS,
        )


# --------------------------------------------------------------------------
# Legacy absolute-coordinate path (algos "all" / "cell")
# --------------------------------------------------------------------------
def _neighbors_and_pairs(cfg: SPHConfig, state: SPHState):
    """NNPS (low-precision tier) + pair geometry (physics tier)."""
    dom, pol = cfg.domain, cfg.policy
    n = state.xn.shape[0]
    k = cfg.max_neighbors
    if cfg.algo == "cell":
        nl = nnps.cell_list_neighbors(
            dom, state.xn, dtype=pol.nnps_dtype, k=k, capacity=cfg.cap(n)
        )
    elif cfg.algo == "all":
        nl = nnps.all_list_neighbors(
            state.xn, dom.radius_norm, dtype=pol.nnps_dtype, k=k, domain=dom
        )
    else:
        raise ValueError(cfg.algo)
    # Physics-tier pair geometry from hi-precision absolute positions.
    xi = state.xn[:, None, :]
    xj = state.xn[nl.idx]
    diff = nnps.min_image(
        (xi - xj).astype(pol.physics_dtype), nnps.wrap_span_norm(dom)
    )
    disp = diff * (dom.h_d / 2.0)  # physical units
    r = jnp.sqrt(jnp.sum(disp * disp, axis=-1))
    return nl, disp, r


def _step_absolute(cfg: SPHConfig, state: SPHState) -> SPHState:
    """One mixed-precision WCSPH step on absolute positions.

    Same explicit update as the RCLL backends: continuity AND momentum
    evaluated at the current state (p from the pre-update density), so
    every algo integrates the identical scheme.
    """
    dom = cfg.domain
    sch = cfg.resolved_scheme
    nl, disp, r = _neighbors_and_pairs(cfg, state)
    gw = sph.grad_w(disp, r, cfg.h, dom.dim, nl.mask)

    fl = state.fluid
    drho, acc = _gathered_pair_rhs(sch, dom, fl, nl, disp, r, gw)
    rho = fl.rho + cfg.dt * drho
    if cfg.wall_rho_clamp:
        rho = jnp.where(state.fixed, jnp.maximum(rho, sch.rho0), rho)

    v = fl.v + cfg.dt * (acc + sch.body_force_vec(dom.dim))
    vw = 0.0 if state.v_wall is None else state.v_wall
    v = jnp.where(state.fixed[:, None], vw, v)

    dxn = jnp.where(state.fixed[:, None], 0.0, v * cfg.dt * (2.0 / dom.h_d))
    xn = state.xn + dxn
    # wrap periodic axes back into the box
    span = jnp.asarray(
        [2.0 * s / dom.h_d if p else 0.0
         for s, p in zip(dom.spans, dom.periodic)], jnp.float32)
    org = jnp.asarray(dom.origin_norm, jnp.float32)
    wrapped = org + jnp.mod(xn - org, jnp.where(span > 0, span, 1.0))
    xn = jnp.where(span > 0, wrapped, xn)
    return SPHState(
        xn=xn, rc=state.rc,
        fluid=sph.FluidState(v=v, rho=rho, m=fl.m),
        fixed=state.fixed, t=state.t + cfg.dt,
        kind=state.kind, v_wall=state.v_wall,
    )


def step(cfg: SPHConfig, state: SPHState) -> SPHState:
    """One WCSPH step from/to original particle indexing.

    The RCLL path packs, builds a fresh neighbor list, steps once, and
    unpacks - identical physics to one ``simulate`` iteration (reuse
    across steps requires carrying ``PersistentCarry`` via
    ``step_persistent``; this wrapper is the stateless convenience form).
    """
    if cfg.algo == "rcll":
        carry = init_persistent(cfg, state)
        return finalize_persistent(cfg, _physics_step(cfg, carry))
    return _step_absolute(cfg, state)


@partial(jax.jit, static_argnums=(0, 2))
def _simulate_stats_jit(
    cfg: SPHConfig, state: SPHState, nsteps: int
) -> tuple[SPHState, SimStats]:
    if cfg.algo == "rcll":
        carry = init_persistent(cfg, state)
        carry = _scan_steps(cfg, carry, nsteps)
        stats = SimStats(
            rebuilds=carry.rebuilds, steps=carry.steps,
            overflow=carry.overflow,
        )
        return finalize_persistent(cfg, carry), stats

    def body(s, _):
        return _step_absolute(cfg, s), None

    out, _ = jax.lax.scan(body, state, None, length=nsteps)
    stats = SimStats(
        rebuilds=jnp.asarray(nsteps, jnp.int32),
        steps=jnp.asarray(nsteps, jnp.int32),
        overflow=jnp.zeros((), bool),
    )
    return out, stats


def simulate_stats(
    cfg: SPHConfig, state: SPHState, nsteps: int
) -> tuple[SPHState, SimStats]:
    """Run ``nsteps`` steps; also report rebuild/overflow diagnostics.

    With ``cfg.check_overflow`` (the deprecated strict-guard alias) the
    run raises :class:`health.SimulationDiverged` on any capacity
    overflow — via one host read of the overflow flag AFTER the scan
    returns, not the in-scan callback sync point this used to cost.
    """
    out, stats = _simulate_stats_jit(cfg, state, nsteps)
    if cfg.check_overflow and bool(stats.overflow):
        _raise_on_overflow(True, cfg.max_neighbors)
    return out, stats


def simulate(cfg: SPHConfig, state: SPHState, nsteps: int) -> SPHState:
    """Run ``nsteps`` steps under lax.scan (single fused XLA program)."""
    return simulate_stats(cfg, state, nsteps)[0]
