"""Anchored mixed-precision arrays: the RCLL decomposition, generalized.

RCLL stores ``position = cell_center(int index) + h_c/2 * residual(fp16)``
with the residual normalized to [-1, 1]. The identical decomposition
applies to any memory-bound tensor whose values are *locally clustered*:

    value = anchor(block, fp32) + scale(block, fp32) * residual(lo)

with the residual normalized into [-1, 1] per block. We use it in three
places (DESIGN.md section 2):
  1. SPH coordinates (the paper, via core.rcll - specialized because the
     anchor grid is spatial);
  2. RCLL-KV: block-anchored quantized KV caches for LM decode;
  3. anchored gradient compression for data-parallel all-reduce.

Residual dtypes: fp16 / bf16 / int8 (symmetric, 127 levels).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.precision import NNPS_STORE

Array = jnp.ndarray


class Anchored(NamedTuple):
    """Block-anchored representation of an array.

    The blocked axis is folded as (..., nblocks, block_size, trailing...).
    anchor/scale have block_size dim of 1 (broadcastable).
    """

    anchor: Array  # fp32, (..., nblocks, 1, ...)
    scale: Array  # fp32, (..., nblocks, 1, ...)
    residual: Array  # lo dtype, (..., nblocks, block_size, ...)
    axis: int  # original blocked axis (static metadata)
    orig_len: int  # original length along axis (for unpadding)


def _to_blocks(x: Array, axis: int, block: int) -> tuple[Array, int]:
    axis = axis % x.ndim
    n = x.shape[axis]
    pad = (-n) % block
    if pad:
        widths = [(0, 0)] * x.ndim
        widths[axis] = (0, pad)
        # edge padding keeps padded entries inside the data range, so
        # they never inflate the per-block scale (zero-padding would
        # wreck blocks whose data sits far from zero - the exact failure
        # mode anchoring exists to avoid).
        x = jnp.pad(x, widths, mode="edge")
    shape = list(x.shape)
    shape[axis : axis + 1] = [shape[axis] // block, block]
    return x.reshape(shape), n


def encode(
    x: Array,
    *,
    block: int,
    axis: int = -1,
    dtype=NNPS_STORE,
    eps: float = 1e-30,
) -> Anchored:
    """Encode x into anchor + scaled low-precision residual.

    anchor = per-block mean, scale = per-block max|x - anchor| (so the
    residual exactly spans [-1, 1], maximizing low-precision mantissa use -
    the same normalization the paper applies in Eqs. 5-6).
    """
    axis = axis % x.ndim
    xb, orig_len = _to_blocks(x.astype(jnp.float32), axis, block)
    bax = axis + 1  # the within-block axis after reshape
    anchor = jnp.mean(xb, axis=bax, keepdims=True)
    dev = xb - anchor
    scale = jnp.max(jnp.abs(dev), axis=bax, keepdims=True)
    scale = jnp.maximum(scale, eps)
    resid = dev / scale
    if jnp.dtype(dtype) == jnp.int8:
        resid = jnp.clip(jnp.round(resid * 127.0), -127, 127).astype(jnp.int8)
    else:
        resid = resid.astype(dtype)
    return Anchored(anchor, scale, resid, axis, orig_len)


def decode(a: Anchored, dtype=jnp.float32) -> Array:
    """Reconstruct the original array (high precision)."""
    resid = a.residual
    if resid.dtype == jnp.int8:
        resid = resid.astype(jnp.float32) / 127.0
    else:
        resid = resid.astype(jnp.float32)
    xb = a.anchor + a.scale * resid
    shape = list(xb.shape)
    shape[a.axis : a.axis + 2] = [shape[a.axis] * shape[a.axis + 1]]
    x = xb.reshape(shape)
    idx = [slice(None)] * x.ndim
    idx[a.axis] = slice(0, a.orig_len)
    return x[tuple(idx)].astype(dtype)


def quantization_error_bound(a: Anchored) -> Array:
    """Per-block worst-case absolute reconstruction error."""
    if a.residual.dtype == jnp.int8:
        step = 1.0 / 127.0
    else:
        step = float(jnp.finfo(a.residual.dtype).eps)
    return a.scale * step
