"""SPH discretization: B-spline kernel (Eq. 3), gradient operators
(Eq. 2 / Appendix A5), and the discretized governing equations (Eq. 4).

Everything takes explicit neighbor lists (idx, mask) plus pair
displacements, so the same physics runs on top of any NNPS backend
(all-list / cell-list / RCLL) and any precision policy - the paper's
mixed-precision split is: neighbors found in fp16, these sums in high
precision.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import bspline

Array = jnp.ndarray

# Single-source B-spline (see core/bspline.py); the old names stay public
# because benchmarks/tests call them directly.
alpha_d = bspline.alpha_d
bspline_w = bspline.w
bspline_dw_dr = bspline.dw_dr


def grad_w(disp: Array, r: Array, h: float, dim: int, mask: Array) -> Array:
    """∂W_ij/∂x_i = (dW/dr) * (x_i - x_j)/r, masked, (N, K, d).

    disp = x_i - x_j (note sign: gradient w.r.t. particle i's position).
    """
    g = bspline.dw_over_r(r, h, dim)[..., None] * disp
    return jnp.where(mask[..., None], g, 0.0)


# --------------------------------------------------------------------------
# Gradient operators
# --------------------------------------------------------------------------
def gradient_standard(
    f: Array, vol: Array, nl_idx: Array, gw: Array
) -> Array:
    """Standard SPH gradient (Eq. 2): Σ_j V_j f_j ∂W/∂x. (N, d)."""
    fj = f[nl_idx]  # (N, K)
    vj = vol[nl_idx]
    return jnp.sum((vj * fj)[..., None] * gw, axis=1)


def gradient_normalized(
    f: Array, x: Array, nl_idx: Array, nl_mask: Array, gw: Array,
    eps: float = 1e-12,
) -> Array:
    """1st-order consistent volume-free gradient (Appendix Eq. A5).

    <f_i^a> = Σ_j (f_j - f_i) ∂W/∂x^a  /  Σ_j (x_j^a - x_i^a) ∂W/∂x^a

    Per-axis normalization exactly as in the paper's appendix. This is the
    operator whose 1st-order accuracy is *independent of neighbor
    selection* - the key robustness property behind Table 3.
    """
    df = (f[nl_idx] - f[:, None]) * nl_mask  # (N, K)
    dx = x[nl_idx] - x[:, None, :]  # (N, K, d)
    dx = dx * nl_mask[..., None]
    num = jnp.sum(df[..., None] * gw, axis=1)  # (N, d)
    den = jnp.sum(dx * gw, axis=1)  # (N, d)
    den = jnp.where(jnp.abs(den) > eps, den, jnp.where(den >= 0, eps, -eps))
    return num / den


def gradient_normalized_pairs(
    f: Array, disp: Array, r: Array, nl_idx: Array, nl_mask: Array,
    h: float, dim: int, eps: float = 1e-12,
) -> Array:
    """A5 gradient taking pair displacements directly (RCLL path: positions
    are never materialized absolutely; disp comes from Eq. 7 decode).

    disp = x_i - x_j, so x_j - x_i = -disp.
    """
    gw = grad_w(disp, r, h, dim, nl_mask)
    df = (f[nl_idx] - f[:, None]) * nl_mask
    num = jnp.sum(df[..., None] * gw, axis=1)
    den = jnp.sum((-disp) * nl_mask[..., None] * gw, axis=1)
    den = jnp.where(jnp.abs(den) > eps, den, jnp.where(den >= 0, eps, -eps))
    return num / den


# --------------------------------------------------------------------------
# Governing equations (Eq. 4) for weakly-compressible flow
# --------------------------------------------------------------------------
class FluidState(NamedTuple):
    """Per-particle physical state (high-precision tier)."""

    v: Array  # (N, d) velocity
    rho: Array  # (N,) density
    m: Array  # (N,) constant particle mass


def eos_tait(rho: Array, rho0: float, c0: float) -> Array:
    """Linearized weakly-compressible EOS p = c0^2 (rho - rho0)."""
    return c0 * c0 * (rho - rho0)


def eos_tait_por2_inv(inv_rho: Array, rho0: float, c0: float) -> Array:
    """p/ρ² of the linear Tait EOS from the RECIPROCAL density.

    p/ρ² = c0²(ρ−ρ0)/ρ² = c0²(1/ρ − ρ0/ρ²) — division-free given 1/ρ.
    The fused sweeps gather 1/ρ as their single fp32 density field and
    evaluate this per PAIR: the flops are free on a bandwidth-bound
    sweep, and unlike the ρ form there is no per-pair division (the
    full-width layout precomputes p/ρ² per particle, so a per-pair
    division would be pure overhead for the half-width layout). Both
    fused layouts evaluate this identical expression on the identical
    gathered 1/ρ, so their fp32 coefficients are bitwise equal.
    """
    return c0 * c0 * (inv_rho - rho0 * inv_rho * inv_rho)


def viscosity_pair_coef_inv(
    mj: Array, x_dot_gw: Array, inv_i: Array, inv_j: Array, r2: Array,
    *, h: float, mu: float,
) -> Array:
    """Morris-viscosity pair coefficient from RECIPROCAL densities.

    ``viscosity_pair_coef`` with 1/(ρ_i ρ_j) supplied as inv_i·inv_j —
    the form the fused sweeps use (they carry 1/ρ, see
    ``eos_tait_por2_inv``); one division per pair either way (the
    Morris h² regularizer), the ρ-product division disappears.
    """
    return mj * (2.0 * mu) * x_dot_gw * inv_i * inv_j / (r2 + 0.01 * h * h)


class PairFields(NamedTuple):
    """Per-pair quantities gathered ONCE per step from the neighbor list.

    The persistent-pipeline step computes these a single time and feeds
    every RHS term from them - the seed path re-gathered v/m per term,
    which doubles the dominant (N, K) HBM traffic for no reason.

    dv:  (N, K, d) v_i - v_j.
    mj:  (N, K) neighbor mass, zeroed where ~mask.
    """

    dv: Array
    mj: Array


def gather_pair_fields(
    v: Array, m: Array, nl_idx: Array, nl_mask: Array
) -> PairFields:
    """Gather the velocity/mass pair terms shared by continuity+momentum."""
    dv = v[:, None, :] - v[nl_idx]
    mj = jnp.where(nl_mask, m[nl_idx], 0.0)
    return PairFields(dv=dv, mj=mj)


def continuity_rhs_pairs(pf: PairFields, gw: Array) -> Array:
    """Dρ_i/Dt = Σ_j m_j (v_i - v_j)·∂W_ij/∂x_i (Eq. 4, first row)."""
    return jnp.sum(pf.mj * jnp.sum(pf.dv * gw, axis=-1), axis=-1)


# --- per-tile pair primitives ---------------------------------------------
# These take already-gathered pair-shaped arrays (any leading shape: an
# (N, K) neighbor matrix, a (chunk, K) slab of the fused XLA pass, or a
# (cap_i, cap_j) Pallas tile), so every backend evaluates the SAME
# arithmetic — the reference path below is a thin wrapper over them.
def pressure_pair_coef(mj: Array, por2_i: Array, por2_j: Array) -> Array:
    """m_j (p_i/ρ_i² + p_j/ρ_j²), the symmetric pressure-term coefficient."""
    return mj * (por2_i + por2_j)


def viscosity_pair_coef(
    mj: Array, x_dot_gw: Array, rho_i: Array, rho_j: Array, r2: Array,
    *, h: float, mu: float,
) -> Array:
    """Morris-viscosity pair coefficient (multiplies v_i - v_j).

    x_dot_gw = (x_i - x_j)·∇W; the 0.01 h² denominator guard is Morris'.
    """
    return mj * (2.0 * mu) * x_dot_gw / (rho_i * rho_j * (r2 + 0.01 * h * h))


def momentum_rhs_terms(
    dv: Array,  # (..., K, d) v_i - v_j
    mj: Array,  # (..., K) neighbor mass, zeroed where invalid
    por2_i: Array,  # (..., K) or broadcastable: p_i / ρ_i²
    por2_j: Array,  # (..., K) p_j / ρ_j²
    rho_i: Array,
    rho_j: Array,
    gw: Array,  # (..., K, d) ∂W/∂x_i, masked
    disp: Array,  # (..., K, d) x_i - x_j
    r2: Array,  # (..., K) squared pair distance
    *,
    h: float,
    mu: float,
) -> Array:
    """Dv_i/Dt pair sums (pressure + Morris viscosity), reduced over K."""
    acc_p = -jnp.sum(
        pressure_pair_coef(mj, por2_i, por2_j)[..., None] * gw, axis=-2
    )
    x_dot_gw = jnp.sum(disp * gw, axis=-1)
    coef = viscosity_pair_coef(mj, x_dot_gw, rho_i, rho_j, r2, h=h, mu=mu)
    return acc_p + jnp.sum(coef[..., None] * dv, axis=-2)


def momentum_rhs_pairs(
    pf: PairFields,
    rho: Array,
    p: Array,
    nl_idx: Array,
    gw: Array,
    disp: Array,
    r: Array,
    *,
    h: float,
    mu: float,
    body_force: Array,
) -> Array:
    """Dv_i/Dt from pre-gathered pair fields (pressure + Morris viscosity).

    rho/p are gathered here exactly once (they change between continuity
    and momentum within a step, so they cannot ride in ``pf``).
    """
    p_over_rho2 = p / (rho * rho)
    acc = momentum_rhs_terms(
        pf.dv, pf.mj,
        p_over_rho2[:, None], p_over_rho2[nl_idx],
        rho[:, None], rho[nl_idx],
        gw, disp, r * r, h=h, mu=mu,
    )
    return acc + body_force


def continuity_rhs(
    st: FluidState, nl_idx: Array, nl_mask: Array, gw: Array
) -> Array:
    """Eq. 4 continuity (compat wrapper over the pair-field core)."""
    return continuity_rhs_pairs(
        gather_pair_fields(st.v, st.m, nl_idx, nl_mask), gw
    )


def momentum_rhs(
    st: FluidState,
    p: Array,
    nl_idx: Array,
    nl_mask: Array,
    gw: Array,
    disp: Array,
    r: Array,
    *,
    h: float,
    mu: float,
    body_force: Array,
) -> Array:
    """Dv_i/Dt: pressure-gradient + Morris laminar viscosity + body force.

    Pressure term (Eq. 4, symmetric form): -Σ m_j (p_i/ρ_i² + p_j/ρ_j²) ∇W.
    Viscous term (Morris et al. 1997, the standard for Poiseuille):
        Σ_j m_j (μ_i + μ_j) (x_ij·∇W) / (ρ_i ρ_j (r² + 0.01 h²)) v_ij
    (Compat wrapper over the pair-field core.)
    """
    pf = gather_pair_fields(st.v, st.m, nl_idx, nl_mask)
    return momentum_rhs_pairs(
        pf, st.rho, p, nl_idx, gw, disp, r, h=h, mu=mu, body_force=body_force
    )


def energy_rhs(
    st: FluidState, p: Array, nl_idx: Array, nl_mask: Array, gw: Array
) -> Array:
    """De_i/Dt = 1/2 Σ m_j (p_i/ρ_i² + p_j/ρ_j²)(v_i - v_j)·∇W (Eq. 4)."""
    pi = (p / (st.rho * st.rho))[:, None]
    pj = (p / (st.rho * st.rho))[nl_idx]
    mj = jnp.where(nl_mask, st.m[nl_idx], 0.0)
    dv = st.v[:, None, :] - st.v[nl_idx]
    return 0.5 * jnp.sum(mj * (pi + pj) * jnp.sum(dv * gw, axis=-1), axis=1)


def density_summation(
    st: FluidState, nl_idx: Array, nl_mask: Array, r: Array,
    h: float, dim: int,
) -> Array:
    """ρ_i = Σ_j m_j W_ij including self (used for (re)initialization)."""
    w = bspline_w(r, h, dim)
    mj = jnp.where(nl_mask, st.m[nl_idx], 0.0)
    self_w = bspline_w(jnp.zeros_like(st.m), h, dim) * st.m
    return jnp.sum(mj * w, axis=1) + self_w
