"""The paper's contribution: mixed-precision NNPS with cell-based relative
coordinates (RCLL), plus the SPH discretization it serves and the
generalized anchored mixed-precision representation.

Layout:
  domain.py    - Eq. 5/6 coordinate normalization, cell geometry
  cells.py     - static-capacity background-cell binning ('link list')
  nnps.py      - all-list / cell-list / RCLL searches, any precision
  rcll.py      - persistent RCLL state (Eq. 7 distances, Eq. 8 updates)
  anchored.py  - anchor+residual mixed precision, generalized
  sph.py       - B-spline kernel, gradient operators, governing equations
  scheme.py    - pluggable physics schemes (EOS/viscosity pair-term specs)
  boundaries.py- dummy/wall-particle kinds + wall lattice generators
  solver.py    - mixed-precision SPH stepper (paper Fig. 6)
  fused.py     - fused cell-blocked force pass (record-row sweeps)
  cases.py     - scenario case registry (poiseuille, dam_break, cavity,
                 taylor_green) + gradient-accuracy benchmark fields
  api.py       - Simulation facade + in-scan Observables
  precision.py - precision policies (Table 4 approaches I/II/III)

``repro.sph`` re-exports the scenario layer and hosts the CLI
(``python -m repro.sph run <case>``).
"""
