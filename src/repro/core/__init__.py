"""The paper's contribution: mixed-precision NNPS with cell-based relative
coordinates (RCLL), plus the SPH discretization it serves and the
generalized anchored mixed-precision representation.

Layout:
  domain.py    - Eq. 5/6 coordinate normalization, cell geometry
  cells.py     - static-capacity background-cell binning ('link list')
  nnps.py      - all-list / cell-list / RCLL searches, any precision
  rcll.py      - persistent RCLL state (Eq. 7 distances, Eq. 8 updates)
  anchored.py  - anchor+residual mixed precision, generalized
  sph.py       - B-spline kernel, gradient operators, governing equations
  solver.py    - mixed-precision WCSPH stepper (paper Fig. 6)
  cases.py     - Poiseuille flow + gradient-accuracy benchmark fields
  precision.py - precision policies (Table 4 approaches I/II/III)
"""
