"""Pluggable physics schemes: the declarative pair-term layer.

Through PR 3 the WCSPH right-hand side (linear Tait EOS + Morris
viscosity + constant body force) was hardwired three times over — in the
reference gather path (``solver._force_rhs_reference``), the fused XLA
sweep (``core/fused.py``), and the Pallas force kernel
(``kernels/rcll_force.py``). A :class:`Scheme` factors those physics
choices out into ONE static (trace-time) specification that every
backend consumes, so adding an EOS or a viscosity model is a change to
this module alone.

Design constraints, inherited from the fused force pass:

  * a Scheme is a frozen dataclass of floats/strings — hashable, so it
    rides through ``jax.jit`` as a static argument exactly like Domain;
  * every pair term is expressed through two coefficient channels (the
    shape the single-sweep algebra supports):

      - the **∇W channel** (:meth:`gradw_pair_coef`): terms of the form
        ``-Σ_j C_ij ∇W_ij`` — symmetric pressure, Monaghan artificial
        viscosity;
      - the **dv channel** (:meth:`dv_pair_coef`): terms of the form
        ``+Σ_j C_ij (v_i - v_j)`` — Morris laminar viscosity;

    both channels are elementwise over pair-shaped arrays of ANY leading
    shape — an (N, K) neighbor matrix, a (chunk, K) fused slab, or a
    (cap, cap) Pallas tile — which is what lets one definition serve all
    three backends;
  * densities enter as RECIPROCALS (the PR 3 bandwidth decision): the
    fused layouts gather one fp32 ``1/ρ`` field and recompute ``p/ρ²``
    division-free per pair (:meth:`por2_inv`).

The default scheme (:func:`wcsph`) reproduces the PR 2/3 physics term
for term — for ``eos="linear"`` the EOS/viscosity expressions delegate
to the exact ``core/sph.py`` primitives the backends used before, so
the refactor is bit-preserving on the existing test suite.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import sph

Array = jnp.ndarray

EOS_KINDS = ("linear", "tait")
VISCOSITY_KINDS = ("morris", "none")


@dataclasses.dataclass(frozen=True)
class Scheme:
    """Static description of the SPH physics terms of one simulation.

    Attributes:
      c0: speed of sound of the weakly-compressible EOS.
      rho0: reference density.
      eos: ``"linear"`` — p = c0²(ρ − ρ0) (the PR 2/3 EOS) — or
        ``"tait"`` — p = B[(ρ/ρ0)^γ − 1], B = c0²ρ0/γ (the classic
        dam-break EOS).
      gamma: Tait exponent (ignored for the linear EOS).
      viscosity: ``"morris"`` — Morris et al. 1997 laminar viscosity
        with dynamic viscosity ``mu`` — or ``"none"``.
      mu: dynamic viscosity (rho0 * nu) of the Morris term.
      alpha: Monaghan artificial-viscosity coefficient (0 disables the
        term). Standard for shock/impact flows (dam break); rides the
        ∇W channel next to the pressure term.
      delta: delta-SPH density-diffusion coefficient (Molteni &
        Colagrossi 2009; 0 disables). A CONTINUITY-channel pair term
        that diffuses the density field along density differences —
        without it, continuity-integrated density drifts under particle
        disorder and the stiff Tait pressure amplifies the drift into
        blowup on free-surface flows. Typical value 0.1.
      body_force: constant acceleration vector, () = zeros.
    """

    c0: float
    rho0: float = 1.0
    eos: str = "linear"
    gamma: float = 7.0
    viscosity: str = "morris"
    mu: float = 0.0
    alpha: float = 0.0
    delta: float = 0.0
    body_force: tuple[float, ...] = ()

    def __post_init__(self):
        if self.eos not in EOS_KINDS:
            raise ValueError(
                f"unknown eos {self.eos!r}; one of {EOS_KINDS}")
        if self.viscosity not in VISCOSITY_KINDS:
            raise ValueError(
                f"unknown viscosity {self.viscosity!r}; one of "
                f"{VISCOSITY_KINDS}")

    # ---- per-particle EOS -------------------------------------------------
    def pressure(self, rho: Array) -> Array:
        """p(ρ) — the per-particle EOS (diagnostics / legacy callers)."""
        if self.eos == "linear":
            return sph.eos_tait(rho, self.rho0, self.c0)
        B = self.c0 * self.c0 * self.rho0 / self.gamma
        return B * ((rho / self.rho0) ** self.gamma - 1.0)

    def por2_inv(self, inv_rho: Array) -> Array:
        """p/ρ² from the RECIPROCAL density (the fused layouts' density
        field — see ``sph.eos_tait_por2_inv`` for why)."""
        if self.eos == "linear":
            return sph.eos_tait_por2_inv(inv_rho, self.rho0, self.c0)
        B = self.c0 * self.c0 * self.rho0 / self.gamma
        ratio = self.rho0 * inv_rho  # ρ0/ρ
        return B * (ratio ** -self.gamma - 1.0) * inv_rho * inv_rho

    # ---- pair-term channels ----------------------------------------------
    @property
    def has_dv_term(self) -> bool:
        """Trace-time: does the dv channel contribute at all?"""
        return self.viscosity == "morris" and self.mu != 0.0

    @property
    def has_av_term(self) -> bool:
        return self.alpha != 0.0

    @property
    def has_delta_term(self) -> bool:
        return self.delta != 0.0

    def gradw_pair_coef(
        self,
        mj: Array,  # (...,) neighbor mass, 0 on invalid slots
        por2_i: Array,  # (...,) p_i/ρ_i² (layouts precompute or fold this)
        por2_j: Array,
        inv_i: Array,  # (...,) reciprocal densities
        inv_j: Array,
        dv_dot_disp: Array,  # (...,) (v_i - v_j)·(x_i - x_j)
        r2: Array,  # (...,) squared pair distance
        *,
        h: float,
    ) -> Array:
        """Coefficient of ∇W in the momentum sum: acc -= Σ C ∇W.

        Pressure (always) + Monaghan artificial viscosity (alpha > 0):
          Π_ij = -α c0 h (dv·dx) / [ρ̄_ij (r² + 0.01 h²)]  for dv·dx < 0
        with 1/ρ̄ = 2 inv_i inv_j / (inv_i + inv_j) — reciprocal form,
        finite on the dummy row (inv > 0) and killed there by mj = 0.
        """
        coef = sph.pressure_pair_coef(mj, por2_i, por2_j)
        if self.has_av_term:
            mu_ij = dv_dot_disp / (r2 + 0.01 * h * h)
            rho_bar_inv = 2.0 * inv_i * inv_j / (inv_i + inv_j)
            pi_ij = -self.alpha * self.c0 * h * mu_ij * rho_bar_inv
            coef = coef + mj * jnp.where(dv_dot_disp < 0.0, pi_ij, 0.0)
        return coef

    def dv_pair_coef(
        self,
        mj: Array,
        x_dot_gw: Array,  # (...,) (x_i - x_j)·∇W
        inv_i: Array,
        inv_j: Array,
        r2: Array,
        *,
        h: float,
    ) -> Array:
        """Coefficient of (v_i − v_j) in the momentum sum: acc += Σ C dv.

        Only call when :attr:`has_dv_term` (callers skip the whole
        channel at trace time otherwise — no zero-multiplied work).
        """
        return sph.viscosity_pair_coef_inv(
            mj, x_dot_gw, inv_i, inv_j, r2, h=h, mu=self.mu
        )

    def drho_pair_term(
        self,
        mj: Array,
        inv_i: Array,
        inv_j: Array,
        x_dot_gw: Array,  # (...,) (x_i - x_j)·∇W  (= coef·r² unfolded)
        r2: Array,
        *,
        h: float,
    ) -> Array:
        """Extra continuity-channel pair term: delta-SPH diffusion.

        dρ_i/dt += δ h c0 Σ_j 2(ρ_j − ρ_i) (x_ji·∇W)/(r² + 0.01h²) V_j
        with V_j = m_j/ρ_j and x_ji·∇W = −x_dot_gw. Reciprocal form:
        ρ_j − ρ_i = (inv_i − inv_j)/(inv_i inv_j), V_j = m_j inv_j.
        Only call when :attr:`has_delta_term`.
        """
        rho_diff = (inv_i - inv_j) / (inv_i * inv_j)  # ρ_j − ρ_i
        return (2.0 * self.delta * h * self.c0) * mj * inv_j * rho_diff * (
            -x_dot_gw
        ) / (r2 + 0.01 * h * h)

    def body_force_vec(self, dim: int) -> Array:
        bf = self.body_force or (0.0,) * dim
        if len(bf) != dim:
            raise ValueError(
                f"body_force {self.body_force} has {len(bf)} components; "
                f"domain is {dim}-D")
        return jnp.asarray(bf, jnp.float32)


def wcsph(
    c0: float,
    rho0: float = 1.0,
    mu: float = 0.0,
    body_force: tuple[float, ...] = (),
) -> Scheme:
    """The PR 2/3 hardwired physics as a Scheme (linear EOS + Morris)."""
    return Scheme(
        c0=c0, rho0=rho0, eos="linear", viscosity="morris", mu=mu,
        body_force=tuple(body_force),
    )
