"""Self-healing guarded runner: rollback + escalation over the health word.

The driver advances a persistent carry in guarded blocks. Each block is
ONE jitted, carry-donating program: ``nsteps`` solver steps followed by
the fused health reduction (``health.check_carry``) — detection costs no
host sync beyond the per-block read of the tiny HealthWord scalars the
driver was going to pause at anyway. After every healthy block the carry
is snapshotted to host memory (the rollback point, and the payload of
the optional CheckpointManager integration). A tripped word rolls the
run back to the last healthy snapshot and retries under an escalation
ladder:

  1. **disarm** — if a fault-injection spec is armed and the policy
     treats faults as transient, strip it and replay the block clean
     (pure rollback-retry: the recovered run is bit-identical to one
     that never faulted).
  2. **regrow** (capacity bits) — re-size ``capacity`` / ``window`` /
     ``max_neighbors`` from the OBSERVED demand of the tripped carry
     (max cell occupancy, max 3^dim-neighborhood occupancy — see
     ``cells.max_neighborhood_occupancy``), rebuild the carry from the
     snapshot under the new config (recompile, loud log). Because cell
     capacity never enters the window-search trajectory, a cap-regrown
     run bit-matches an unfaulted adequately-sized run.
  3. **halve dt** (numeric bits) — bounded backoff for CFL / density /
     NaN blowups (the v0 water-hammer incident, PR 5). Shapes are
     unchanged, so the snapshot restores directly; the new static dt
     recompiles the block.
  4. **degrade records** — fp16 -> fp32 record rows, the runtime
     extension of ``solver._resolved_records``'s build-time fallback.
     Applied eagerly at guard init when the >2^11-cells/axis anchor
     guard or the rel-coordinate quantization bound trips (loud log),
     and as the last rung after dt backoff exhausts.
  5. **raise** — a structured :class:`health.SimulationDiverged`
     carrying the step, tripped checks, and offending-field stats.

``check_overflow`` on the config is the deprecated strict alias: the
solver's ``simulate_stats`` maps it to one post-run host check; guarded
runs get the same strictness with ``GuardPolicy(strict=True)``.
"""
from __future__ import annotations

import dataclasses
import logging
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import cells as cells_lib
from repro.core import health, solver

log = logging.getLogger("repro.recovery")

Array = jnp.ndarray

SimulationDiverged = health.SimulationDiverged  # re-export


@dataclasses.dataclass(frozen=True)
class GuardPolicy:
    """Escalation policy of a guarded run (hashable: static jit arg).

    block:            steps per guarded block (detection granularity and
                      rollback cost; observe_every overrides it so
                      observable rows keep uniform spacing).
    checks:           bitmask of enabled health checks (health.ALL_CHECKS).
    rho_dev_limit:    density-deviation trip point |rho/rho0 - 1|.
    cfl_limit:        advective CFL trip point vmax * dt / h.
    max_dt_halvings:  dt backoff budget for numeric trips.
    max_regrows:      capacity/window regrow budget for overflow trips.
    growth:           minimum geometric growth factor per regrow.
    demand_safety:    multiplier on the observed demand when re-sizing.
    degrade_records:  allow the fp16 -> fp32 record fallback (at guard
                      init for the static anchor/quantization bounds,
                      and as the rung after dt backoff exhausts).
    quant_frac:       rel-coordinate quantization bound as a fraction of
                      the particle spacing ds (init-time static check).
    disarm_faults:    treat an armed FaultSpec as transient — strip it
                      on first trip and replay (models one-shot
                      corruption; False models a persistent fault, which
                      drives the policy to exhaustion in tests).
    strict:           raise on the first tripped word, no recovery (the
                      check_overflow alias semantics, generalized).
    snapshot_every:   healthy blocks between host snapshots (rollback
                      granularity vs snapshot bandwidth).
    """

    block: int = 32
    checks: int = health.ALL_CHECKS
    rho_dev_limit: float = health.DEFAULT_RHO_DEV_LIMIT
    cfl_limit: float = health.DEFAULT_CFL_LIMIT
    max_dt_halvings: int = 4
    max_regrows: int = 3
    growth: float = 1.5
    demand_safety: float = 1.25
    degrade_records: bool = True
    quant_frac: float = 0.02
    disarm_faults: bool = True
    strict: bool = False
    snapshot_every: int = 1


@dataclasses.dataclass
class GuardEvent:
    """One detection + recovery action (host-side record)."""

    step: int  # last healthy step count (the rollback point)
    word: int  # tripped-check bitmask
    checks: tuple[str, ...]
    action: str  # "disarm" | "regrow" | "halve_dt" | "degrade_records"
    detail: str
    stats: dict

    def to_json(self) -> dict:
        """Plain-JSON form (machine-readable CLI / serve replies)."""
        return {
            "step": int(self.step),
            "word": int(self.word),
            "checks": list(self.checks),
            "action": self.action,
            "detail": self.detail,
            "stats": {k: (float(v) if isinstance(v, float) else int(v))
                      for k, v in (self.stats or {}).items()},
        }


@dataclasses.dataclass
class GuardReport:
    """What a guarded run did: escalations taken and the final config."""

    cfg: solver.SPHConfig  # final (possibly escalated) config
    events: list
    blocks: int = 0
    retries: int = 0
    dt_halvings: int = 0
    regrows: int = 0
    records_degraded: bool = False
    # Observable rows discarded by rollbacks: rows sampled in blocks
    # that were later rolled back describe a trajectory that never
    # happened, so they are dropped — but dropping them SILENTLY made
    # `repro.sph run --guard` tables look gap-free. The count makes the
    # discard visible (the CLI prints it in the recovery report).
    dropped_obs_rows: int = 0

    @property
    def recovered(self) -> bool:
        return bool(self.events)

    def to_json(self) -> dict:
        """Plain-JSON form; drops ``cfg`` (an opaque jit-static struct)
        in favor of the fields a client can act on."""
        return {
            "recovered": self.recovered,
            "blocks": int(self.blocks),
            "retries": int(self.retries),
            "dt_halvings": int(self.dt_halvings),
            "regrows": int(self.regrows),
            "records_degraded": bool(self.records_degraded),
            "final_dt": float(self.cfg.dt),
            "dropped_obs_rows": int(self.dropped_obs_rows),
            "events": [e.to_json() for e in self.events],
        }


@partial(jax.jit, static_argnums=(0, 2, 3, 4), donate_argnums=(1,))
def _guarded_block(
    cfg: solver.SPHConfig,
    carry: solver.PersistentCarry,
    nsteps: int,
    policy: GuardPolicy,
    observe: bool,
):
    """One donated guarded block: clear flags, step, reduce health.

    Clearing the accumulated overflow flags at block ENTRY gives the
    word per-block semantics (a regrown capacity isn't haunted by the
    bits its undersized predecessor set); the init-time flags are read
    separately by :func:`_check_init` before the first block runs.
    """
    if carry.flags is not None:
        carry = carry._replace(flags=jnp.zeros((), jnp.uint32))
    carry = solver._scan_steps(cfg, carry, nsteps)
    hw = health.check_carry(
        cfg, carry, rho_dev_limit=policy.rho_dev_limit,
        cfl_limit=policy.cfl_limit, enabled=policy.checks,
    )
    row = health.observe_state(cfg, carry.st) if observe else ()
    return carry, hw, row


@partial(jax.jit, static_argnums=(0, 2))
def _check_init(cfg: solver.SPHConfig, carry, policy: GuardPolicy):
    """Step-0 health word (sees init-time rebuild overflow; no donation)."""
    return health.check_carry(
        cfg, carry, rho_dev_limit=policy.rho_dev_limit,
        cfl_limit=policy.cfl_limit, enabled=policy.checks,
    )


def _host_snapshot(carry: solver.PersistentCarry):
    """Host copy of the carry (None subtrees preserved by jax.tree.map)."""
    return jax.tree.map(np.asarray, carry)


def _to_device(snap):
    return jax.tree.map(jnp.asarray, snap)


def _dt_equivalent(a: solver.SPHConfig, b: solver.SPHConfig) -> bool:
    """True when ``b`` differs from ``a`` only in dt / fault — i.e. the
    snapshot's carry shapes, dtypes and packing remain valid under b."""
    return dataclasses.replace(a, dt=b.dt, fault=b.fault) == b


def _restore(snap, snap_cfg: solver.SPHConfig, cfg: solver.SPHConfig):
    """Rebuild a device carry for ``cfg`` from a host snapshot.

    Shape-preserving escalations (dt halve, disarm) restore the exact
    carry; shape-changing ones (regrow, records degrade) unpack the
    snapshot to an SPHState and re-init the persistent pipeline under
    the new config, preserving the step/rebuild counters so step-keyed
    fault injection and cadence stay aligned with the trajectory.
    """
    dev = _to_device(snap)
    if _dt_equivalent(snap_cfg, cfg):
        return dev
    state = solver.finalize_persistent(snap_cfg, dev)
    carry = solver.init_persistent(cfg, state)
    return carry._replace(
        steps=jnp.asarray(snap.steps),
        rebuilds=carry.rebuilds + jnp.asarray(snap.rebuilds),
    )


def rel_quantization_error(domain, coords_dtype) -> float:
    """Worst-case physical position error of storing rel coords in
    ``coords_dtype``: half an ulp at |rel| ~ 1 across the largest cell
    (rel in [-1, 1] spans one cell, so one rel unit = cell_size / 2)."""
    ulp = 2.0 ** (-jnp.finfo(jnp.dtype(coords_dtype)).nmant)
    return float(max(domain.cell_sizes)) * 0.5 * ulp * 0.5


def _resolve_precision(cfg, policy, events):
    """Init-time static precision guard: the runtime extension of
    ``solver._resolved_records``. Degrades the record layout LOUDLY (the
    build-time fallback is silent) when the half-record cell-anchor
    limit or the rel quantization bound trips."""
    if not policy.degrade_records or cfg.policy.records == "fp32":
        return cfg, False
    reasons = []
    if solver._resolved_records(cfg) != cfg.policy.records:
        reasons.append(
            f"grid max(ncells)={max(cfg.domain.ncells)} exceeds the "
            "half-record cell-anchor range (fused.HALF_CELL_LIMIT)"
        )
    q = rel_quantization_error(cfg.domain, cfg.policy.coords_dtype)
    if q > policy.quant_frac * cfg.ds:
        reasons.append(
            f"rel-coordinate quantization {q:.3g} exceeds "
            f"{policy.quant_frac:.0%} of ds={cfg.ds:.3g} "
            "(note: stored coords keep the policy dtype; full-width "
            "records stop the error compounding through the force pass)"
        )
    if not reasons:
        return cfg, False
    detail = "; ".join(reasons)
    log.warning(
        "health guard: degrading records %s -> fp32 at init (%s)",
        cfg.policy.records, detail,
    )
    events.append(GuardEvent(
        step=0, word=0, checks=(), action="degrade_records",
        detail=detail, stats={},
    ))
    return dataclasses.replace(
        cfg, policy=cfg.policy.with_records("fp32")
    ), True


def apply_named_fault(
    cfg: solver.SPHConfig, name: str, nsteps: int, n_particles: int
) -> solver.SPHConfig:
    """Arm one of the named CI/CLI fault injections on a config.

    "nan"/"teleport" arm an in-scan FaultSpec a third of the way in;
    "cap"/"window"/"dt" corrupt the static config itself (undersized
    cell capacity, undersized search window, overscale timestep).
    """
    step = max(1, nsteps // 3)
    if name == "nan":
        return dataclasses.replace(
            cfg, fault=health.FaultSpec("nan_v", step=step)
        )
    if name == "teleport":
        return dataclasses.replace(
            cfg, fault=health.FaultSpec(
                "teleport", step=step, particle=0,
                target=max(1, n_particles // 2),
            )
        )
    if name == "cap":
        return dataclasses.replace(cfg, capacity=2)
    if name == "window":
        return dataclasses.replace(cfg, window=8)
    if name == "dt":
        return dataclasses.replace(cfg, dt=cfg.dt * 8.0)
    raise ValueError(
        f"unknown fault {name!r}; one of nan, teleport, cap, window, dt"
    )


def run_guarded(
    cfg: solver.SPHConfig,
    state: solver.SPHState,
    nsteps: int,
    policy: GuardPolicy | None = None,
    *,
    observe_every: int = 0,
    checkpoint=None,
    checkpoint_every: int = 0,
):
    """Advance ``nsteps`` guarded steps from ``state``.

    Returns ``(state, stats, report, obs_rows)`` — the final SPHState in
    original indexing, the run SimStats, the :class:`GuardReport`, and
    (t, ekin, vmax, rho_err) observable rows (one per healthy block)
    when ``observe_every > 0``. Raises :class:`SimulationDiverged` when
    the policy is exhausted. ``checkpoint`` (a CheckpointManager) saves
    the healthy host snapshot every ``checkpoint_every`` blocks, keyed
    by the carry's step counter — the cross-process resume path.
    """
    if cfg.algo != "rcll":
        raise ValueError("run_guarded requires the persistent rcll pipeline")
    policy = policy or GuardPolicy()
    events: list[GuardEvent] = []
    cfg, degraded = _resolve_precision(cfg, policy, events)
    if policy.strict and degraded:
        _raise_exhausted(events[-1], 0, events, policy)

    block = observe_every if observe_every > 0 else max(1, policy.block)
    halvings = regrows = blocks = retries = 0
    dropped_rows = 0
    obs_rows: list[tuple] = []  # (steps_done_after_block, row)

    carry = solver.init_persistent(cfg, state)
    # The init carry is freshly gathered EXCEPT the scalar ``t``, which
    # rides through un-gathered and aliases ``state.t``. Sever it so the
    # donated guarded blocks never invalidate the caller's state —
    # unlike run_persistent, run_guarded is non-donating at its API
    # boundary (callers re-run from the same state, e.g. benchmarks).
    carry = carry._replace(st=carry.st._replace(t=jnp.copy(carry.st.t)))
    snap, snap_cfg, snap_steps = _host_snapshot(carry), cfg, 0
    steps_done = 0

    def escalate(hw, tripped_carry, fault_possible=True):
        """Pick a recovery action, log it, return the restored carry."""
        nonlocal cfg, halvings, regrows, retries, degraded
        word = int(hw.word)
        checks = health.check_names(word)
        stats = hw.host_stats()
        if policy.strict:
            _raise_strict(word, checks, stats, snap_steps, events, policy)
        retries += 1
        # ``fault_possible`` is False for the step-0 init check: no step
        # has run, so an armed fault cannot be the cause — don't waste
        # the disarm rung on it.
        if fault_possible and cfg.fault is not None and policy.disarm_faults:
            action, detail = "disarm", (
                f"stripped injected fault {cfg.fault.kind!r}; replaying "
                f"block from step {snap_steps}"
            )
            cfg = dataclasses.replace(cfg, fault=None)
        elif word & health.CAPACITY_CHECKS and regrows < policy.max_regrows:
            action = "regrow"
            changes = []
            s = policy.demand_safety
            n = int(tripped_carry.order.shape[0])
            if word & health.CELL_OVERFLOW:
                occ = int(hw.max_cell)
                cap_new = max(
                    int(np.ceil(s * occ)),
                    int(np.ceil(policy.growth * cfg.cap(n))),
                )
                changes.append(f"capacity {cfg.cap(n)} -> {cap_new}")
                cfg = dataclasses.replace(cfg, capacity=cap_new)
            if word & health.WINDOW_TRUNC:
                # Size window AND max_neighbors from the exact demand
                # bound: no particle can have more candidates (hence
                # neighbors) than its 3^dim-neighborhood occupancy.
                nb = int(cells_lib.max_neighborhood_occupancy(
                    cfg.domain, tripped_carry.binning.counts
                ))
                k = cfg.max_neighbors
                if cfg.window is not None:
                    w_new = max(
                        int(np.ceil(s * nb)),
                        int(np.ceil(policy.growth * cfg.resolved_window())),
                    )
                    changes.append(
                        f"window {cfg.resolved_window()} -> {w_new}"
                    )
                    cfg = dataclasses.replace(cfg, window=w_new)
                if int(hw.max_count) > k:
                    changes.append(f"max_neighbors {k} -> {nb}")
                    cfg = dataclasses.replace(cfg, max_neighbors=nb)
            regrows += 1
            detail = (
                ", ".join(changes) + f" (regrow {regrows}/"
                f"{policy.max_regrows}; shapes change: recompiling)"
            )
        elif word & health.NUMERIC_CHECKS:
            if halvings < policy.max_dt_halvings:
                halvings += 1
                action, detail = "halve_dt", (
                    f"dt {cfg.dt:.3e} -> {cfg.dt / 2:.3e} "
                    f"(backoff {halvings}/{policy.max_dt_halvings})"
                )
                cfg = dataclasses.replace(cfg, dt=cfg.dt / 2.0)
            elif (policy.degrade_records and not degraded
                  and cfg.policy.records != "fp32"):
                degraded = True
                action, detail = "degrade_records", (
                    f"records {cfg.policy.records} -> fp32 after dt "
                    "backoff exhausted (shapes change: recompiling)"
                )
                cfg = dataclasses.replace(
                    cfg, policy=cfg.policy.with_records("fp32")
                )
            else:
                _raise_exhausted_trip(
                    word, checks, stats, snap_steps, events, policy,
                    halvings, regrows,
                )
        else:
            _raise_exhausted_trip(
                word, checks, stats, snap_steps, events, policy,
                halvings, regrows,
            )
        ev = GuardEvent(
            step=snap_steps, word=word, checks=checks, action=action,
            detail=detail, stats=stats,
        )
        events.append(ev)
        log.warning(
            "health guard tripped %s at step %d (vmax=%.3g rho_dev=%.3g "
            "cfl=%.3g): %s — %s",
            checks, snap_steps, stats["vmax"], stats["rho_dev"],
            stats["cfl"], action, detail,
        )
        return _restore(snap, snap_cfg, cfg)

    # Step-0 check: an undersized capacity overflows at the INIT
    # rebuild, before any block runs.
    hw = _check_init(cfg, carry, policy)
    while int(hw.word):
        carry = escalate(hw, carry, fault_possible=False)
        hw = _check_init(cfg, carry, policy)
    snap, snap_cfg = _host_snapshot(carry), cfg

    observe = observe_every > 0
    while steps_done < nsteps:
        n = min(block, nsteps - steps_done)
        carry, hw, row = _guarded_block(cfg, carry, n, policy, observe)
        blocks += 1
        if int(hw.word):
            carry = escalate(hw, carry)
            steps_done = snap_steps
            kept = [r for r in obs_rows if r[0] <= snap_steps]
            dropped_rows += len(obs_rows) - len(kept)
            obs_rows = kept
            continue
        steps_done += n
        if observe:
            obs_rows.append((steps_done, tuple(np.asarray(x) for x in row)))
        if blocks % max(1, policy.snapshot_every) == 0:
            snap, snap_cfg, snap_steps = (
                _host_snapshot(carry), cfg, steps_done
            )
            if checkpoint is not None and checkpoint_every and (
                    blocks % checkpoint_every == 0):
                checkpoint.save(int(snap.steps), snap)

    # Surface any deferred async-save error before returning: a failed
    # checkpoint silently dropped here would defeat the resume path.
    if checkpoint is not None:
        checkpoint.wait()
    stats = solver.SimStats(
        rebuilds=carry.rebuilds, steps=carry.steps, overflow=carry.overflow
    )
    out = solver.finalize_persistent(cfg, carry)
    report = GuardReport(
        cfg=cfg, events=events, blocks=blocks, retries=retries,
        dt_halvings=halvings, regrows=regrows, records_degraded=degraded,
        dropped_obs_rows=dropped_rows,
    )
    return out, stats, report, [r for _, r in obs_rows]


def _raise_strict(word, checks, stats, step, events, policy):
    raise SimulationDiverged(
        f"health guard (strict) tripped {checks} at step {step}: "
        f"stats={stats}",
        step=step, checks=checks, word=word, stats=stats, events=events,
    )


def _raise_exhausted(event, step, events, policy):
    raise SimulationDiverged(
        f"health guard: strict policy forbids recovery action "
        f"{event.action!r} ({event.detail})",
        step=step, checks=event.checks, word=event.word, events=events,
    )


def _raise_exhausted_trip(
    word, checks, stats, step, events, policy, halvings, regrows
):
    raise SimulationDiverged(
        f"simulation diverged at step {step}: checks={checks} "
        f"stats={stats}; recovery exhausted (dt halvings "
        f"{halvings}/{policy.max_dt_halvings}, regrows "
        f"{regrows}/{policy.max_regrows})",
        step=step, checks=checks, word=word, stats=stats, events=events,
    )
