"""Benchmark cases: 2D Poiseuille flow (Morris 1997 / paper refs 40,42)
and the cubic-function gradient-accuracy field (paper Table 3).

Poiseuille: flow between plates y=0 and y=L driven by body force F, no-slip
walls, periodic in x. Analytic transient (series) solution:

  v_x(y,t) = F/(2 nu) * y (L - y)
           - sum_n 4 F L^2 / (nu pi^3 (2n+1)^3) * sin(pi y (2n+1)/L)
             * exp(-(2n+1)^2 pi^2 nu t / L^2)

Nondimensional defaults: L=1, nu=1, v_max = F L^2 / (8 nu).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import solver as solver_lib
from repro.core.domain import Domain
from repro.core.precision import PrecisionPolicy

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class PoiseuilleCase:
    ds: float = 0.025
    L: float = 1.0  # channel width (y)
    Lx: float = 0.4  # periodic streamwise extent
    nu: float = 1.0
    rho0: float = 1.0
    v_max: float = 0.125
    n_wall: int = 3  # dummy-particle wall layers per side
    algo: str = "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    max_neighbors: int = 40
    cfl: float = 0.125
    # Persistent-pipeline knobs: a Verlet skin needs cells that cover the
    # inflated radius, so cell_factor must be >= (r + skin) / r.
    skin: float = 0.0
    cell_factor: float = 1.0
    rebuild_every: int | None = None
    backend: str | None = None  # None=auto | "reference" | "xla" | "pallas"
    force_chunk: int = 0
    check_overflow: bool = False

    @property
    def F(self) -> float:
        return 8.0 * self.nu * self.v_max / (self.L * self.L)

    @property
    def c0(self) -> float:
        return 10.0 * self.v_max

    @property
    def h(self) -> float:
        return 1.2 * self.ds

    @property
    def dt(self) -> float:
        dt_visc = self.cfl * self.h * self.h / self.nu
        dt_acoustic = 0.25 * self.h / self.c0
        dt_force = 0.25 * np.sqrt(self.h / max(self.F, 1e-12))
        return float(min(dt_visc, dt_acoustic, dt_force))

    def domain(self) -> Domain:
        wall = self.n_wall * self.ds
        return Domain(
            lo=(0.0, -wall),
            hi=(self.Lx, self.L + wall),
            h=self.h,
            cell_factor=self.cell_factor,
            periodic=(True, False),
        )

    def build(self) -> tuple[solver_lib.SPHConfig, solver_lib.SPHState]:
        ds, L = self.ds, self.L
        nx = int(round(self.Lx / ds))
        xs = (np.arange(nx) + 0.5) * ds
        # fluid rows in (0, L); wall rows outside
        ys_fluid = (np.arange(int(round(L / ds))) + 0.5) * ds
        ys_wall_lo = -(np.arange(self.n_wall) + 0.5) * ds
        ys_wall_hi = L + (np.arange(self.n_wall) + 0.5) * ds
        ys = np.concatenate([ys_fluid, ys_wall_lo, ys_wall_hi])
        fixed_rows = np.concatenate(
            [np.zeros_like(ys_fluid, bool),
             np.ones_like(ys_wall_lo, bool),
             np.ones_like(ys_wall_hi, bool)]
        )
        X, Y = np.meshgrid(xs, ys, indexing="ij")
        pos = np.stack([X.ravel(), Y.ravel()], axis=-1)
        fixed = np.broadcast_to(fixed_rows[None, :], X.shape).ravel().copy()
        n = pos.shape[0]
        m = np.full((n,), self.rho0 * ds * ds)
        rho = np.full((n,), self.rho0)
        v = np.zeros((n, 2))
        cfg = solver_lib.SPHConfig(
            domain=self.domain(),
            ds=ds,
            dt=self.dt,
            rho0=self.rho0,
            c0=self.c0,
            mu=self.rho0 * self.nu,
            body_force=(self.F, 0.0),
            max_neighbors=self.max_neighbors,
            algo=self.algo,
            policy=self.policy,
            skin=self.skin,
            rebuild_every=self.rebuild_every,
            backend=self.backend,
            force_chunk=self.force_chunk,
            check_overflow=self.check_overflow,
        )
        state = solver_lib.init_state(
            cfg, pos, v, m, rho, fixed=jnp.asarray(fixed)
        )
        return cfg, state

    def analytic_vx(self, y: Array, t: float, nterms: int = 60) -> Array:
        """Transient series solution (paper ref [42], Morris 1997)."""
        F, nu, L = self.F, self.nu, self.L
        y = jnp.asarray(y)
        steady = F / (2.0 * nu) * y * (L - y)
        total = steady
        for n in range(nterms):
            k = 2 * n + 1
            term = (
                4.0 * F * L * L / (nu * np.pi**3 * k**3)
                * jnp.sin(np.pi * y * k / L)
                * np.exp(-(k**2) * np.pi**2 * nu * t / (L * L))
            )
            total = total - term
        return total

    def analytic_displacement(self, y: Array, t: float,
                              nterms: int = 60) -> Array:
        """x-displacement = integral of analytic_vx over [0, t] (Table 5)."""
        F, nu, L = self.F, self.nu, self.L
        y = jnp.asarray(y)
        disp = F / (2.0 * nu) * y * (L - y) * t
        for n in range(nterms):
            k = 2 * n + 1
            lam = (k**2) * np.pi**2 * nu / (L * L)
            term = (
                4.0 * F * L * L / (nu * np.pi**3 * k**3)
                * jnp.sin(np.pi * y * k / L)
                * (1.0 - np.exp(-lam * t)) / lam
            )
            disp = disp - term
        return disp


def gradient_test_particles(
    ds: float, jitter: float = 0.2, seed: int = 0, dim: int = 2
) -> tuple[Domain, np.ndarray]:
    """Unit-domain particle set for the f(x)=x^3 gradient study (Table 3).

    Jitter breaks lattice symmetry so the gradient operator is actually
    exercised off the trivial symmetric case (and avoids exact-boundary
    distance ties that make low-precision comparisons ill-posed).
    """
    h = 1.2 * ds
    if dim == 2:
        dom = Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=h)
    else:
        dom = Domain(lo=(0.0,) * dim, hi=(1.0,) * dim, h=h)
    axes = [np.arange(ds / 2, 1.0, ds) for _ in range(dim)]
    grid = np.meshgrid(*axes, indexing="ij")
    x = np.stack([g.ravel() for g in grid], axis=-1).astype(np.float64)
    rng = np.random.default_rng(seed)
    x = x + rng.uniform(-jitter * ds, jitter * ds, size=x.shape)
    x = np.clip(x, 1e-6, 1.0 - 1e-6)
    return dom, x


def cubic_field(x: Array) -> Array:
    """f = x^3 (the paper's Table 3 test function, applied to axis 0)."""
    return x[..., 0] ** 3


def cubic_gradient_x(x: Array) -> Array:
    return 3.0 * x[..., 0] ** 2
