"""Scenario cases: the case registry plus the shipped benchmark suite.

A *case* is a frozen dataclass implementing the :class:`CaseSpec`
protocol — ``build()`` returns a ready ``(SPHConfig, SPHState)`` pair,
and class metadata (``boundary``, ``validation``, ``default_nsteps``)
feeds the ``python -m repro.sph`` CLI and the docs gallery. Cases are
registered by name (:func:`register_case`) and instantiated with field
overrides through :func:`build_case`; :func:`resolve_ds` maps a target
particle count to a spacing so benchmarks/CI can scale any case.

Shipped cases:

  * ``poiseuille`` — 2-D channel flow (Morris 1997 / paper refs 40,42):
    periodic-x, no-slip dummy walls, analytic transient profile.
  * ``dam_break`` — 2-D collapsing water column (Tait EOS + Monaghan
    artificial viscosity, DualSPHysics-style dynamic walls): non-periodic
    tank, open top, surge-front position vs the shallow-water scaling.
  * ``cavity`` — lid-driven cavity: fully enclosed box with a MOVING lid
    (prescribed wall velocity through ``SPHState.v_wall``).
  * ``taylor_green`` — 2-D Taylor–Green vortex: fully periodic, analytic
    viscous kinetic-energy decay rate (the validation oracle).

Poiseuille analytic transient (series) solution:

  v_x(y,t) = F/(2 nu) * y (L - y)
           - sum_n 4 F L^2 / (nu pi^3 (2n+1)^3) * sin(pi y (2n+1)/L)
             * exp(-(2n+1)^2 pi^2 nu t / L^2)

Nondimensional defaults: L=1, nu=1, v_max = F L^2 / (8 nu).
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import boundaries
from repro.core import scheme as scheme_lib
from repro.core import solver as solver_lib
from repro.core.domain import Domain
from repro.core.precision import PrecisionPolicy

Array = jnp.ndarray


# --------------------------------------------------------------------------
# Case registry
# --------------------------------------------------------------------------
@runtime_checkable
class CaseSpec(Protocol):
    """What the scenario layer requires of a case.

    Required: ``build()``. The CLI/gallery additionally read the class
    metadata attributes (``boundary``, ``validation``,
    ``default_nsteps``, ``fluid_area``) and, when present, call
    ``validate(times, ekin)`` for case-specific analytic checks.
    """

    name: str

    def build(self) -> tuple["solver_lib.SPHConfig", "solver_lib.SPHState"]:
        ...


CASES: dict[str, type] = {}


def register_case(name: str):
    """Class decorator: register a CaseSpec under ``name``."""

    def deco(cls):
        cls.name = name
        CASES[name] = cls
        return cls

    return deco


def case_names() -> list[str]:
    return sorted(CASES)


def build_case(name: str, **overrides):
    """Instantiate a registered case with dataclass-field overrides."""
    try:
        cls = CASES[name]
    except KeyError:
        raise ValueError(
            f"unknown case {name!r}; registered: {case_names()}"
        ) from None
    return cls(**overrides)


def resolve_ds(name: str, n_target: int, **overrides) -> float:
    """Spacing that puts ~``n_target`` particles in the case's fluid body."""
    case = build_case(name, **overrides)
    return float(np.sqrt(case.fluid_area / max(1, n_target)))


@register_case("poiseuille")
@dataclasses.dataclass(frozen=True)
class PoiseuilleCase:
    ds: float = 0.025
    L: float = 1.0  # channel width (y)
    Lx: float = 0.4  # periodic streamwise extent
    nu: float = 1.0
    rho0: float = 1.0
    v_max: float = 0.125
    n_wall: int = 3  # dummy-particle wall layers per side
    algo: str = "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    max_neighbors: int = 40
    cfl: float = 0.125
    # Persistent-pipeline knobs: a Verlet skin needs cells that cover the
    # inflated radius, so cell_factor must be >= (r + skin) / r.
    skin: float = 0.0
    cell_factor: float = 1.0
    rebuild_every: int | None = None
    backend: str | None = None  # None=auto | "reference" | "xla" | "pallas"
    force_chunk: int = 0
    check_overflow: bool = False

    # --- CLI / gallery metadata ---
    boundary = "periodic x; no-slip dummy walls y (3 layers/side)"
    validation = "transient velocity profile vs Morris 1997 series"
    default_nsteps = 400

    @property
    def fluid_area(self) -> float:
        return self.L * self.Lx

    @property
    def F(self) -> float:
        return 8.0 * self.nu * self.v_max / (self.L * self.L)

    @property
    def c0(self) -> float:
        return 10.0 * self.v_max

    @property
    def h(self) -> float:
        return 1.2 * self.ds

    @property
    def dt(self) -> float:
        dt_visc = self.cfl * self.h * self.h / self.nu
        dt_acoustic = 0.25 * self.h / self.c0
        dt_force = 0.25 * np.sqrt(self.h / max(self.F, 1e-12))
        return float(min(dt_visc, dt_acoustic, dt_force))

    def domain(self) -> Domain:
        wall = self.n_wall * self.ds
        return Domain(
            lo=(0.0, -wall),
            hi=(self.Lx, self.L + wall),
            h=self.h,
            cell_factor=self.cell_factor,
            periodic=(True, False),
        )

    def build(self) -> tuple[solver_lib.SPHConfig, solver_lib.SPHState]:
        ds, L = self.ds, self.L
        nx = int(round(self.Lx / ds))
        xs = (np.arange(nx) + 0.5) * ds
        # fluid rows in (0, L); wall rows outside
        ys_fluid = (np.arange(int(round(L / ds))) + 0.5) * ds
        ys_wall_lo = -(np.arange(self.n_wall) + 0.5) * ds
        ys_wall_hi = L + (np.arange(self.n_wall) + 0.5) * ds
        ys = np.concatenate([ys_fluid, ys_wall_lo, ys_wall_hi])
        fixed_rows = np.concatenate(
            [np.zeros_like(ys_fluid, bool),
             np.ones_like(ys_wall_lo, bool),
             np.ones_like(ys_wall_hi, bool)]
        )
        X, Y = np.meshgrid(xs, ys, indexing="ij")
        pos = np.stack([X.ravel(), Y.ravel()], axis=-1)
        fixed = np.broadcast_to(fixed_rows[None, :], X.shape).ravel().copy()
        n = pos.shape[0]
        m = np.full((n,), self.rho0 * ds * ds)
        rho = np.full((n,), self.rho0)
        v = np.zeros((n, 2))
        cfg = solver_lib.SPHConfig(
            domain=self.domain(),
            ds=ds,
            dt=self.dt,
            rho0=self.rho0,
            c0=self.c0,
            mu=self.rho0 * self.nu,
            body_force=(self.F, 0.0),
            max_neighbors=self.max_neighbors,
            algo=self.algo,
            policy=self.policy,
            skin=self.skin,
            rebuild_every=self.rebuild_every,
            backend=self.backend,
            force_chunk=self.force_chunk,
            check_overflow=self.check_overflow,
        )
        state = solver_lib.init_state(
            cfg, pos, v, m, rho, fixed=jnp.asarray(fixed)
        )
        return cfg, state

    def analytic_vx(self, y: Array, t: float, nterms: int = 60) -> Array:
        """Transient series solution (paper ref [42], Morris 1997)."""
        F, nu, L = self.F, self.nu, self.L
        y = jnp.asarray(y)
        steady = F / (2.0 * nu) * y * (L - y)
        total = steady
        for n in range(nterms):
            k = 2 * n + 1
            term = (
                4.0 * F * L * L / (nu * np.pi**3 * k**3)
                * jnp.sin(np.pi * y * k / L)
                * np.exp(-(k**2) * np.pi**2 * nu * t / (L * L))
            )
            total = total - term
        return total

    def analytic_displacement(self, y: Array, t: float,
                              nterms: int = 60) -> Array:
        """x-displacement = integral of analytic_vx over [0, t] (Table 5)."""
        F, nu, L = self.F, self.nu, self.L
        y = jnp.asarray(y)
        disp = F / (2.0 * nu) * y * (L - y) * t
        for n in range(nterms):
            k = 2 * n + 1
            lam = (k**2) * np.pi**2 * nu / (L * L)
            term = (
                4.0 * F * L * L / (nu * np.pi**3 * k**3)
                * jnp.sin(np.pi * y * k / L)
                * (1.0 - np.exp(-lam * t)) / lam
            )
            disp = disp - term
        return disp


# --------------------------------------------------------------------------
# Dam break (free surface, non-periodic tank, Tait EOS + artificial visc)
# --------------------------------------------------------------------------
@register_case("dam_break")
@dataclasses.dataclass(frozen=True)
class DamBreakCase:
    """2-D collapsing water column in an open-topped tank.

    The classic free-surface benchmark (Monaghan 1994; DualSPHysics,
    arXiv:1110.3711): a column of width ``col_w`` and height ``col_h``
    held against the left wall collapses under gravity and surges along
    the floor. Physics follow the standard dam-break recipe: Tait EOS
    (γ=7), Monaghan artificial viscosity (no laminar term), hydrostatic
    density initialization, and the DualSPHysics wall-density clamp.

    Validation: the surge-front position; after the initial transient
    the front advances at ~2√(g·col_h) (the shallow-water dam-break
    front speed — Ritter's solution), which the CLI reports against the
    measured front trajectory.
    """

    ds: float = 0.05
    width: float = 2.0  # tank inner width
    height: float = 1.3  # tank inner height (open top, splash headroom)
    col_w: float = 0.5
    col_h: float = 1.0
    g: float = 1.0
    rho0: float = 1.0
    alpha: float = 0.1  # Monaghan artificial-viscosity coefficient
    delta: float = 0.1  # delta-SPH density diffusion
    gamma: float = 7.0
    n_wall: int = 3
    algo: str = "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    max_neighbors: int = 48
    backend: str | None = None
    check_overflow: bool = False
    # Verlet-skin reuse knobs (the --dynamic benchmark's amortized-
    # rebuild mode): a skin needs cells covering r + skin, so
    # cell_factor must be >= (r + skin) / r. Defaults keep the legacy
    # per-step-rebuild behavior.
    skin: float = 0.0
    cell_factor: float = 1.0
    # Initial downward fluid speed (the "dropped column" start). The
    # collapse from rest needs O(sqrt(col_h/g)) of physical time before
    # anything moves a cell — thousands of steps at fine ds — so
    # benchmarks that must observe rebuilds inside a short timed window
    # start the column already falling at a collapse-representative
    # speed instead. 0 = the validated classic quiescent start.
    v0: float = 0.0

    boundary = "no-slip walls x-lo/x-hi/y-lo (3 layers), open top"
    validation = "surge-front speed vs 2*sqrt(g*col_h) (Ritter)"
    default_nsteps = 600

    @property
    def c0(self) -> float:
        # WCSPH rule: c0 >= 10 * max flow speed ~ sqrt(2 g col_h)
        return 10.0 * float(np.sqrt(2.0 * self.g * self.col_h))

    @property
    def h(self) -> float:
        return 1.2 * self.ds

    @property
    def dt(self) -> float:
        # The c0 rule (10x the gravity speed scale) does not cover the
        # dropped-column start: a whole column impacting the floor at
        # v0 develops local speeds ~2 v0 and a water-hammer pressure
        # spike, which blows the acoustic CFL at fine ds. Augment the
        # signal speed by the same 10x rule applied to the impact
        # scale; v0 = 0 keeps the classic dt exactly.
        dt_acoustic = 0.25 * self.h / (self.c0 + 20.0 * self.v0)
        dt_force = 0.25 * float(np.sqrt(self.h / self.g))
        return float(min(dt_acoustic, dt_force))

    @property
    def fluid_area(self) -> float:
        return self.col_w * self.col_h

    @property
    def sides(self) -> tuple[tuple[int, int], ...]:
        return ((0, 0), (0, 1), (1, 0))  # x-lo, x-hi, floor

    def scheme(self) -> scheme_lib.Scheme:
        return scheme_lib.Scheme(
            c0=self.c0, rho0=self.rho0, eos="tait", gamma=self.gamma,
            viscosity="none", alpha=self.alpha, delta=self.delta,
            body_force=(0.0, -self.g),
        )

    def domain(self) -> Domain:
        lo, hi = boundaries.wall_extent(
            (0.0, 0.0), (self.width, self.height), self.ds, self.n_wall,
            self.sides,
        )
        return Domain(
            lo=lo, hi=hi, h=self.h, cell_factor=self.cell_factor,
            periodic=(False, False),
        )

    def build(self) -> tuple[solver_lib.SPHConfig, solver_lib.SPHState]:
        fluid = boundaries.fluid_lattice(
            (0.0, 0.0), (self.col_w, self.col_h), self.ds
        )
        walls, _ = boundaries.box_wall_particles(
            (0.0, 0.0), (self.width, self.height), self.ds, self.n_wall,
            self.sides,
        )
        pos = np.concatenate([fluid, walls])
        kind = np.concatenate([
            np.full(len(fluid), boundaries.FLUID, np.int8),
            np.full(len(walls), boundaries.WALL, np.int8),
        ])
        n = pos.shape[0]
        sch = self.scheme()
        # Hydrostatic column init (Tait-inverted): ρ(y) = ρ0 (1 + γ p_h /
        # (ρ0 c0²))^(1/γ), p_h = ρ0 g (col_h − y). Starting in mechanical
        # equilibrium removes the startup pressure shock.
        p_h = self.rho0 * self.g * np.maximum(self.col_h - pos[:, 1], 0.0)
        rho = self.rho0 * (
            1.0 + self.gamma * p_h / (self.rho0 * self.c0**2)
        ) ** (1.0 / self.gamma)
        rho = np.where(kind == boundaries.WALL, self.rho0, rho)
        m = np.full((n,), self.rho0 * self.ds * self.ds)
        v = np.zeros((n, 2))
        if self.v0:
            v[:len(fluid), 1] = -self.v0
        dom = self.domain()
        cfg = solver_lib.SPHConfig(
            domain=dom,
            ds=self.ds,
            dt=self.dt,
            rho0=self.rho0,
            c0=self.c0,
            mu=0.0,
            body_force=(0.0, -self.g),
            max_neighbors=self.max_neighbors,
            # capacity: the default robust rule (cells.robust_capacity)
            # already covers the DENSE column in the mostly-empty tank —
            # no per-case override to forget.
            algo=self.algo,
            policy=self.policy,
            backend=self.backend,
            scheme=sch,
            wall_rho_clamp=True,
            skin=self.skin,
            check_overflow=self.check_overflow,
        )
        state = solver_lib.init_state(cfg, pos, v, m, rho, kind=kind)
        return cfg, state

    def front_position(self, cfg, state) -> float:
        """Surge-front x: rightmost fluid particle (the CLI's metric)."""
        pos = np.asarray(solver_lib.positions(cfg, state))
        fl = ~np.asarray(state.fixed)
        return float(pos[fl, 0].max())


# --------------------------------------------------------------------------
# Lid-driven cavity (enclosed box, moving wall)
# --------------------------------------------------------------------------
@register_case("cavity")
@dataclasses.dataclass(frozen=True)
class LidCavityCase:
    """Lid-driven cavity: enclosed unit box, top lid sliding at ``U``.

    The standard internal-flow benchmark (Ghia et al. 1982). The lid is
    a MOVING wall: its dummy layers carry the prescribed velocity (U, 0)
    through ``SPHState.v_wall`` — they drag the fluid through the
    viscous pair term via the same per-particle v array (and fused
    record rows) as everything else, but never advect. The lid owns its
    corners (listed first in ``sides``), matching the usual SPH cavity
    setup.
    """

    ds: float = 0.05
    L: float = 1.0
    U: float = 1.0  # lid speed
    Re: float = 100.0
    rho0: float = 1.0
    # delta-SPH density diffusion: the lid corners are genuine pressure
    # singularities; continuity-integrated density drifts there and the
    # run blows up by ~500 steps without diffusion (rho_err stays ~1%
    # with it).
    delta: float = 0.1
    n_wall: int = 3
    algo: str = "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    max_neighbors: int = 48
    backend: str | None = None
    check_overflow: bool = False

    boundary = "no-slip walls all sides; MOVING lid y-hi (v_wall=(U,0))"
    validation = "spin-up to steady recirculation (KE plateau, |v|<=U)"
    default_nsteps = 600

    @property
    def nu(self) -> float:
        return self.U * self.L / self.Re

    @property
    def c0(self) -> float:
        return 10.0 * self.U

    @property
    def h(self) -> float:
        return 1.2 * self.ds

    @property
    def dt(self) -> float:
        dt_acoustic = 0.25 * self.h / self.c0
        dt_visc = 0.125 * self.h * self.h / self.nu
        return float(min(dt_acoustic, dt_visc))

    @property
    def fluid_area(self) -> float:
        return self.L * self.L

    @property
    def sides(self) -> tuple[tuple[int, int], ...]:
        # lid FIRST: corner particles belong to the moving lid
        return ((1, 1), (1, 0), (0, 0), (0, 1))

    def scheme(self) -> scheme_lib.Scheme:
        return scheme_lib.Scheme(
            c0=self.c0, rho0=self.rho0, viscosity="morris",
            mu=self.rho0 * self.nu, delta=self.delta,
        )

    def domain(self) -> Domain:
        lo, hi = boundaries.wall_extent(
            (0.0, 0.0), (self.L, self.L), self.ds, self.n_wall, self.sides
        )
        return Domain(lo=lo, hi=hi, h=self.h, periodic=(False, False))

    def build(self) -> tuple[solver_lib.SPHConfig, solver_lib.SPHState]:
        box = ((0.0, 0.0), (self.L, self.L))
        fluid = boundaries.fluid_lattice(*box, self.ds)
        walls, v_walls = boundaries.box_wall_particles(
            *box, self.ds, self.n_wall, self.sides,
            velocities={(1, 1): (self.U, 0.0)},
        )
        pos = np.concatenate([fluid, walls])
        kind = np.concatenate([
            np.full(len(fluid), boundaries.FLUID, np.int8),
            np.full(len(walls), boundaries.WALL, np.int8),
        ])
        v_wall = np.concatenate([
            np.zeros((len(fluid), 2), np.float32), v_walls
        ])
        n = pos.shape[0]
        m = np.full((n,), self.rho0 * self.ds * self.ds)
        rho = np.full((n,), self.rho0)
        # walls START at their prescribed velocity so the first force
        # evaluation already sees the moving lid
        v = v_wall.copy()
        cfg = solver_lib.SPHConfig(
            domain=self.domain(),
            ds=self.ds,
            dt=self.dt,
            rho0=self.rho0,
            c0=self.c0,
            mu=self.rho0 * self.nu,
            body_force=(0.0, 0.0),
            max_neighbors=self.max_neighbors,
            algo=self.algo,
            policy=self.policy,
            backend=self.backend,
            scheme=self.scheme(),
            check_overflow=self.check_overflow,
        )
        state = solver_lib.init_state(
            cfg, pos, v, m, rho, kind=kind, v_wall=v_wall
        )
        return cfg, state


# --------------------------------------------------------------------------
# Taylor–Green vortex (fully periodic, analytic viscous decay)
# --------------------------------------------------------------------------
@register_case("taylor_green")
@dataclasses.dataclass(frozen=True)
class TaylorGreenCase:
    """2-D Taylor–Green vortex: the analytic-decay validation case.

    Fully periodic box, initial field
        u =  U sin(kx) cos(ky),  v = -U cos(kx) sin(ky),  k = 2π/L,
    an exact Navier–Stokes solution decaying as exp(−2νk²t) in velocity,
    i.e. kinetic energy ∝ exp(−4νk²t) (:meth:`decay_rate`). Density is
    initialized through the linear EOS from the analytic pressure
    p = −ρ0U²/4 (cos 2kx + cos 2ky), which suppresses the acoustic
    startup transient that a uniform-density start would ring with.

    The measured KE decay includes SPH's resolution-dependent numerical
    dissipation, so validation windows/resolutions matter: at the
    defaults (ds=1/32, Re=20) the log-KE slope over t ∈ [0.02, 0.1]
    matches 4νk² within a few percent.
    """

    ds: float = 1.0 / 32.0
    L: float = 1.0
    U: float = 1.0
    Re: float = 20.0
    rho0: float = 1.0
    algo: str = "rcll"
    policy: PrecisionPolicy = PrecisionPolicy()
    max_neighbors: int = 48
    backend: str | None = None
    check_overflow: bool = False

    boundary = "fully periodic (no walls)"
    validation = "KE decay rate vs analytic 4*nu*k^2 (<5%)"
    default_nsteps = 600

    @property
    def nu(self) -> float:
        return self.U * self.L / self.Re

    @property
    def c0(self) -> float:
        return 10.0 * self.U

    @property
    def h(self) -> float:
        return 1.2 * self.ds

    @property
    def dt(self) -> float:
        dt_acoustic = 0.25 * self.h / self.c0
        dt_visc = 0.125 * self.h * self.h / self.nu
        return float(min(dt_acoustic, dt_visc))

    @property
    def fluid_area(self) -> float:
        return self.L * self.L

    @property
    def k(self) -> float:
        return 2.0 * np.pi / self.L

    @property
    def decay_rate(self) -> float:
        """Analytic kinetic-energy decay rate: KE(t) = KE(0) e^{-λt}."""
        return 4.0 * self.nu * self.k * self.k

    def scheme(self) -> scheme_lib.Scheme:
        return scheme_lib.wcsph(self.c0, self.rho0, self.rho0 * self.nu)

    def domain(self) -> Domain:
        return Domain(
            lo=(0.0, 0.0), hi=(self.L, self.L), h=self.h,
            periodic=(True, True),
        )

    def build(self) -> tuple[solver_lib.SPHConfig, solver_lib.SPHState]:
        pos = boundaries.fluid_lattice((0.0, 0.0), (self.L, self.L), self.ds)
        n = pos.shape[0]
        kx, ky = self.k * pos[:, 0], self.k * pos[:, 1]
        v = self.U * np.stack(
            [np.sin(kx) * np.cos(ky), -np.cos(kx) * np.sin(ky)], axis=-1
        )
        p0 = -self.rho0 * self.U**2 / 4.0 * (np.cos(2 * kx) + np.cos(2 * ky))
        rho = self.rho0 + p0 / self.c0**2  # linear-EOS-consistent init
        m = np.full((n,), self.rho0 * self.ds * self.ds)
        cfg = solver_lib.SPHConfig(
            domain=self.domain(),
            ds=self.ds,
            dt=self.dt,
            rho0=self.rho0,
            c0=self.c0,
            mu=self.rho0 * self.nu,
            body_force=(0.0, 0.0),
            max_neighbors=self.max_neighbors,
            algo=self.algo,
            policy=self.policy,
            backend=self.backend,
            scheme=self.scheme(),
            check_overflow=self.check_overflow,
        )
        state = solver_lib.init_state(cfg, pos, v, m, rho)
        return cfg, state

    def analytic_ekin(self, ekin0: float, t) -> np.ndarray:
        return ekin0 * np.exp(-self.decay_rate * np.asarray(t))

    def fit_decay_rate(self, times, ekin, frac_window: float = 0.5) -> float:
        """Least-squares slope of −log KE(t) over the validated window.

        The window is the first KE *half-life* (samples with KE >=
        ``frac_window`` × the back-extrapolated KE(0)): beyond it the
        particle lattice has disordered and SPH's resolution-dependent
        numerical dissipation steepens the decay — a real SPH property,
        not a solver bug, so validation compares where the analytic
        solution is the dominant physics (within ~3% at the defaults).
        """
        t = np.asarray(times, np.float64)
        e = np.asarray(ekin, np.float64)
        e0 = e[0] / np.exp(-self.decay_rate * t[0])
        keep = (e > 0) & (e >= frac_window * e0)
        if keep.sum() < 2:
            # observation window starts past the first half-life (e.g. a
            # warm-started sim): fall back to fitting every positive
            # sample — no crash, though the fit then includes the
            # disorder-dissipation regime.
            keep = e > 0
        a = np.polyfit(t[keep], np.log(e[keep]), 1)
        return float(-a[0])

    def validate(self, times, ekin) -> dict:
        """CLI hook: measured vs analytic KE decay (first half-life)."""
        lam = self.fit_decay_rate(times, ekin)
        ana = self.decay_rate
        return {
            "decay_rate_measured": lam,
            "decay_rate_analytic": ana,
            "decay_rate_rel_err": abs(lam - ana) / ana,
        }


def gradient_test_particles(
    ds: float, jitter: float = 0.2, seed: int = 0, dim: int = 2
) -> tuple[Domain, np.ndarray]:
    """Unit-domain particle set for the f(x)=x^3 gradient study (Table 3).

    Jitter breaks lattice symmetry so the gradient operator is actually
    exercised off the trivial symmetric case (and avoids exact-boundary
    distance ties that make low-precision comparisons ill-posed).
    """
    h = 1.2 * ds
    if dim == 2:
        dom = Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=h)
    else:
        dom = Domain(lo=(0.0,) * dim, hi=(1.0,) * dim, h=h)
    axes = [np.arange(ds / 2, 1.0, ds) for _ in range(dim)]
    grid = np.meshgrid(*axes, indexing="ij")
    x = np.stack([g.ravel() for g in grid], axis=-1).astype(np.float64)
    rng = np.random.default_rng(seed)
    x = x + rng.uniform(-jitter * ds, jitter * ds, size=x.shape)
    x = np.clip(x, 1e-6, 1.0 - 1e-6)
    return dom, x


def cubic_field(x: Array) -> Array:
    """f = x^3 (the paper's Table 3 test function, applied to axis 0)."""
    return x[..., 0] ** 3


def cubic_gradient_x(x: Array) -> Array:
    return 3.0 * x[..., 0] ** 2
