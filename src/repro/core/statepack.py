"""Fused per-particle state permutation (the rebuild's record-row trick).

A rebuild reorders EVERY per-particle array by the same permutation.
Done per field, that is one strided gather per array (x, v, rho, m,
kind, v_wall, order, ...) — each a separate walk over the permutation
with its own kernel launch. Mirroring the PR 3 record-row trick, all
fields are instead bit-packed into one contiguous u32 row buffer,
permuted by a SINGLE gather (rows are contiguous, cache-line friendly),
and unbundled back to their original dtypes — bitcasts and integer
widening only, no value ever rounds.

Column mapping per field (trailing dims flattened into columns):

  * 4-byte dtypes (f32 / i32 / u32): one bitcast column per component.
  * 2-byte dtypes (f16 / bf16): bitcast to u16, widened to one u32
    column (zero-extend; exact round trip via truncation).
  * 1-byte dtypes (bool / i8 / u8): widened to one u32 column
    (modular; exact round trip via truncation).

The pack/unpack pair is exact for every supported dtype — asserted by
the round-trip test — so a fused permutation is bit-identical to the
per-field one. The buffer is transient inside the jitted rebuild: XLA
fuses the pack into the gather, and the donated scan carry reuses the
old field buffers for the unbundled outputs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def _ncols(x: Array) -> int:
    """u32 columns a field occupies (one per trailing component)."""
    comps = 1
    for s in x.shape[1:]:
        comps *= s
    return comps


def _to_u32_cols(x: Array) -> Array:
    """(N, comps) u32 view of a per-particle field (exact, see module doc)."""
    n = x.shape[0]
    flat = x.reshape(n, -1)
    size = jnp.dtype(x.dtype).itemsize
    if size == 4:
        return jax.lax.bitcast_convert_type(flat, jnp.uint32)
    if size == 2:
        return jax.lax.bitcast_convert_type(flat, jnp.uint16).astype(
            jnp.uint32
        )
    if size == 1:
        if x.dtype == jnp.dtype(bool):
            return flat.astype(jnp.uint32)
        return jax.lax.bitcast_convert_type(flat, jnp.uint8).astype(
            jnp.uint32
        )
    raise ValueError(f"unsupported statepack dtype {x.dtype}")


def _from_u32_cols(cols: Array, like: Array) -> Array:
    """Inverse of :func:`_to_u32_cols` for a field shaped/typed as ``like``."""
    shape = (cols.shape[0],) + like.shape[1:]
    size = jnp.dtype(like.dtype).itemsize
    if size == 4:
        out = jax.lax.bitcast_convert_type(cols, like.dtype)
    elif size == 2:
        out = jax.lax.bitcast_convert_type(
            cols.astype(jnp.uint16), like.dtype
        )
    elif size == 1:
        if like.dtype == jnp.dtype(bool):
            out = cols != 0
        else:
            out = jax.lax.bitcast_convert_type(
                cols.astype(jnp.uint8), like.dtype
            )
    else:
        raise ValueError(f"unsupported statepack dtype {like.dtype}")
    return out.reshape(shape)


def permute_fields(fields: tuple, perm: Array) -> tuple:
    """Permute every per-particle array in ``fields`` by ONE fused gather.

    ``fields`` may contain ``None`` entries (optional state fields);
    they pass through as ``None``. Equivalent to ``tuple(f[perm] for f
    in fields)`` bit-for-bit, at one row gather instead of one gather
    per field.
    """
    present = [f for f in fields if f is not None]
    if not present:
        return fields
    buf = jnp.concatenate([_to_u32_cols(f) for f in present], axis=1)
    buf = buf[perm]
    out, col = [], 0
    for f in fields:
        if f is None:
            out.append(None)
            continue
        c = _ncols(f)
        out.append(_from_u32_cols(buf[:, col:col + c], f))
        col += c
    return tuple(out)
