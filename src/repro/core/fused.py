"""Fused cell-blocked WCSPH force pass (the ``backend="xla"`` hot path).

The reference step (``backend="reference"``) round-trips every pair
intermediate through HBM — ``pair_displacements`` (N, K, d), ``grad_w``
(N, K, d), the gathered pair fields (N, K)x3, one (N, K) coefficient per
RHS term — and pays 5-6 *separate* neighbor gathers (rel, cell, v, m,
rho, p/ρ²), each a strided walk over the particle arrays. Profiling
(paper Table 6) identifies exactly this pattern as bandwidth-bound.

This module evaluates the same sums with two structural changes:

**One record gather per sweep.** All per-particle inputs of a sweep are
packed into a single record row (Domínguez et al.'s float4-texture
trick, arXiv:1110.3711). A sweep then gathers ``rec[idx]`` once —
contiguous rows, cache-line friendly — instead of 5-6 scalar gathers.
Two layouts, selected by ``PrecisionPolicy.records``:

  * ``records="fp32"`` (the accuracy oracle): one fp32 row
    ``[q | v | m | 1/ρ | p/ρ²]`` where ``q = I + x/2`` is the position
    in per-axis *cell units*, built from the RCLL state by exact fp32
    arithmetic: the integer cell coordinate is exact in fp32 and the
    fp16 payload halving is exact, so ``q_i - q_j`` reproduces the
    Eq. (7) anchored decode to ~1 ulp of q — two orders of magnitude
    below the fp16 *storage* granularity that bounds both decodes.
  * ``records="fp16"``/``"bf16"`` (the half-width production layout —
    the bandwidth round): one 16-bit row ``[I | rel | v | m]`` plus a
    single separate fp32 ``1/ρ`` gather. The coordinate payload is the
    RAW RCLL storage value (fp16 rel — lossless by construction,
    exactly the paper's point that cell-relative values are fp16-safe)
    next to its integer cell anchor (see ``_records_half`` for the two
    row encodings); v is quantized to the records dtype, m is stored
    normalized by ``mass_scale`` (raw SPH masses go subnormal in fp16
    at fine ds — every pair term is linear in m, so the sweep rescales
    its outputs once); the density tier stays fp32 as the reciprocal,
    and ``p/ρ² = c0²(1/ρ − ρ0/ρ²)`` is recomputed *division-free*
    in-register through the linearized Tait EOS
    (``sph.eos_tait_por2_inv``) instead of being gathered — the flops
    are free on a bandwidth-bound sweep and 4 bytes per pair disappear.
    Everything upcasts to fp32 before any pair arithmetic
    (``q = I + rel/2`` is the SAME exact fp32 value as the fp32 layout
    stores), so the only deviation from the oracle is the v/m storage
    quantization itself. 2-D bytes per pair: 7×16-bit + 1×fp32 = 18 vs
    7×fp32 = 28.

Periodic axes wrap by minimum image on the integer cell span.

**Chunked reduction, no pair HBM round-trip.** Particles are cell-sorted
in the persistent pipeline, so a contiguous run of packed rows IS a
contiguous run of background cells — ``lax.map`` over chunks of packed
rows is the cell-blocked traversal with zero empty-slot padding (the
dense (C, cap, K) cell tables pad by cap/mean-occupancy; packed rows
visit the same cells in the same order without the padding). Each chunk
decodes pair geometry, evaluates the B-spline gradient and the
continuity/momentum terms through the SAME primitives as the reference
path (``core/bspline.py`` + ``sph.momentum_rhs_terms``), and reduces
over K immediately: peak pair-intermediate memory is O(chunk · K · d) —
cache-resident — instead of O(N · K · d) in HBM.

Physics ordering note: the solver integrates the standard explicit
WCSPH scheme (symplectic Euler, as in DualSPHysics): continuity AND
momentum are evaluated at the common current state, with the Tait
pressure of the pre-update density. That is what makes a SINGLE pass
possible — a semi-implicit rho-then-momentum ordering would force all
drho to exist (a global barrier) before any momentum term, i.e. a
second full geometry sweep.

Masking note: there is no per-pair mask at all. Invalid neighbor slots
are redirected to a dummy record row (index N) holding ``m = 0`` (with
the density field kept positive so denominators stay finite): every
pair term carries an m_j factor, and the B-spline derivative vanishes
identically beyond the support 2h and at r = 0, so invalid slots,
padding rows, the self pair, and Verlet-skin extras all contribute an
exact 0.0 without any per-term select or (N, K) boolean traffic in the
hot loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bspline, rcll, sph
from repro.core import scheme as scheme_lib
from repro.core.domain import Domain
from repro.core.nnps import NeighborList
from repro.core.precision import dtype_of

Array = jnp.ndarray

#: Default rows per chunk of the mapped sweep. At K = 64, d = 2 this
#: bounds live pair intermediates to a few MB — cache-resident on CPU
#: hosts (measured best among {2048..16384} at N = 64k).
DEFAULT_CHUNK = 8192

#: Below this row count the sweep runs as ONE chunk (no lax.map): the
#: intermediates fit in cache anyway and skipping the loop + pad was
#: measurably faster at N = 8k.
SINGLE_CHUNK_MAX = 12288


def resolve_chunk(n: int, chunk: int = 0) -> int:
    """Static chunk size: ``chunk`` (0 = auto), equalized.

    Auto picks one chunk for small n (<= SINGLE_CHUNK_MAX) and
    DEFAULT_CHUNK above. The requested size fixes the number of chunks;
    the returned size is the smallest that still covers n in that many —
    e.g. n=8455 with a 4096 request becomes 3 chunks of 2819 instead of
    2x4096+263 (which would waste ~93% of the last chunk's pair work on
    padding).
    """
    if chunk <= 0:
        chunk = n if n <= SINGLE_CHUNK_MAX else DEFAULT_CHUNK
    c = max(1, min(n, chunk))
    nchunk = -(-n // c)
    return -(-n // nchunk)


def _chunk_rows(x: Array, nchunk: int, chunk: int, pad_row: Array) -> Array:
    """Pad axis 0 to nchunk*chunk with ``pad_row`` rows and reshape to
    (nchunk, chunk, ...)."""
    pad = nchunk * chunk - x.shape[0]
    if pad:
        x = jnp.concatenate(
            [x, jnp.broadcast_to(pad_row, (pad,) + x.shape[1:])], axis=0
        )
    return x.reshape((nchunk, chunk) + x.shape[1:])


def _map_chunks(body, row_args: tuple, pad_rows: tuple, n: int, chunk: int):
    """lax.map ``body`` over row-chunks of every array in ``row_args``.

    Short final chunks are padded with the caller-supplied ``pad_rows``
    (one per row arg) — the force pass pads the id rows with the dummy
    index N and the record rows with the dummy record itself, so pad
    rows evaluate all-dummy pairs: exactly zero, finite, no NaN. The
    pad is sliced off the output. Returns the per-row results, (n, ...).
    """
    chunk = resolve_chunk(n, chunk)
    nchunk = -(-n // chunk)
    if nchunk == 1:  # chunk covers all rows: no pad, no map
        return body(row_args)
    chunked = tuple(
        _chunk_rows(a, nchunk, chunk, p) for a, p in zip(row_args, pad_rows)
    )
    out = jax.lax.map(body, chunked)
    return jax.tree_util.tree_map(
        lambda o: o.reshape((nchunk * chunk,) + o.shape[2:])[:n], out
    )


def cell_coords_f32(rc: rcll.RCLLState) -> Array:
    """(N, d) fp32 positions in per-axis CELL units: q = I + x/2.

    Integer cell coordinates are exact in fp32 (grids are far below
    2^24 cells per axis) and halving the fp16 payload is exact, so q
    carries the full information of the RCLL state to ~1 ulp — the
    storage quantization of ``rel`` remains the dominant error exactly
    as in the anchored Eq. (7) decode.
    """
    return rc.cell_xy.astype(jnp.float32) + rc.rel.astype(jnp.float32) * 0.5


def _pair_geometry(domain: Domain, q_i, q_j):
    """Physical pair displacement / distance factors from cell-unit coords.

    disp_a = (q_i - q_j)_a * hc_phys_a — the same per-axis scaling as the
    Pallas tile decode (``kernels/tiling.tile_phys_disp``). The minimum
    image is applied per-axis at trace time (only periodic axes pay it),
    in select form: true pairs sit in adjacent cells, so |du| > span/2
    happens only across the periodic seam and a single +-span correction
    is exact. Returns (disp, r2, coef) with coef = (dW/dr)/r — the shared
    scalar factor of every gradient component (gw_a = coef * disp_a).
    """
    du = q_i - q_j
    cols = []
    for a, (per, ncell, hc) in enumerate(
        zip(domain.periodic, domain.ncells, domain.cell_sizes)
    ):
        da = du[..., a]
        if per:
            span = jnp.float32(ncell)
            half = jnp.float32(ncell / 2.0)
            da = da - span * (da > half).astype(jnp.float32) \
                + span * (da < -half).astype(jnp.float32)
        cols.append(da * jnp.float32(hc))
    disp = jnp.stack(cols, axis=-1)
    r2 = jnp.sum(disp * disp, axis=-1)
    # Unmasked: dW/dr vanishes beyond 2h and at r = 0, and every consumer
    # multiplies by mj (0 on invalid slots) — no select needed.
    coef = bspline.dw_over_r(jnp.sqrt(r2), domain.h, domain.dim)
    return disp, r2, coef


def _pair_rhs(
    domain: Domain,
    q_i, q_j,  # (..., d) fp32 cell-unit coords
    v_i, v_j,  # (..., d) fp32
    mj,  # (...,) fp32, 0 on invalid slots
    por2_i, por2_j,  # (...,) fp32 p/ρ²
    inv_i, inv_j,  # (...,) fp32 reciprocal densities 1/ρ
    *,
    scheme: scheme_lib.Scheme,
):
    """(drho, acc) pair sums over the trailing K axis.

    The ONE arithmetic body both record layouts decode into: the pair
    algebra folds the shared scalar coefficient first (s = coef *
    pair-coefficient, then s * disp_a / s * dv_a), an exact regrouping
    of ``sph.momentum_rhs_terms`` / ``continuity_rhs_pairs`` — same
    terms, fewer per-axis multiplies. Densities enter as reciprocals
    (see ``sph.eos_tait_por2_inv``). The physics terms themselves come
    from the static ``scheme`` (core/scheme.py): the ∇W channel
    (pressure + optional artificial viscosity) and the dv channel
    (Morris viscosity), each skipped entirely at trace time when the
    scheme disables it.
    """
    disp, r2, coef = _pair_geometry(domain, q_i, q_j)
    dv = v_i - v_j
    dv_dot_disp = jnp.sum(dv * disp, axis=-1)
    # Σ m_j (dv·∇W): ∇W_a = coef·disp_a -> fold coef out of the dot.
    drho = jnp.sum(mj * coef * dv_dot_disp, axis=-1)
    if scheme.has_delta_term:
        # continuity channel: delta-SPH diffusion (x·∇W = coef·r2)
        drho = drho + jnp.sum(
            scheme.drho_pair_term(
                mj, inv_i, inv_j, coef * r2, r2, h=domain.h
            ),
            axis=-1,
        )
    # ∇W channel: -Σ [C_ij coef] disp_a (pressure + artificial visc).
    gc = scheme.gradw_pair_coef(
        mj, por2_i, por2_j, inv_i, inv_j, dv_dot_disp, r2, h=domain.h
    ) * coef
    if scheme.has_dv_term:
        # dv channel: x·∇W = coef·r2 (already folded in the shared coef).
        vc = scheme.dv_pair_coef(
            mj, coef * r2, inv_i, inv_j, r2, h=domain.h
        )
        acc = jnp.sum(vc[..., None] * dv - gc[..., None] * disp, axis=-2)
    else:
        acc = -jnp.sum(gc[..., None] * disp, axis=-2)
    return drho, acc


def _records(rc: rcll.RCLLState, v: Array, m: Array, *extra: Array) -> Array:
    """(N+1, 2d+1+len(extra)) fp32 record rows [q | v | m | extra...].

    Row N is the dummy target of invalid neighbor slots: m = 0 zeroes
    every pair term exactly; extras default to 1.0 so denominator fields
    (rho) stay positive — callers overwrite columns that must be 0.
    """
    cols = [cell_coords_f32(rc), v.astype(jnp.float32),
            m.astype(jnp.float32)[:, None]]
    cols += [e.astype(jnp.float32)[:, None] for e in extra]
    rec = jnp.concatenate(cols, axis=1)
    dummy = jnp.zeros((1, rec.shape[1]), jnp.float32)
    dummy = dummy.at[0, 2 * v.shape[1] + 1:].set(1.0)
    return jnp.concatenate([rec, dummy], axis=0)


def _u16(x: Array) -> Array:
    return jax.lax.bitcast_convert_type(x, jnp.uint16)


#: Largest per-axis cell count whose integer coordinates are exactly
#: representable in the half-record coordinate column (fp16 integers are
#: exact through 2^11; bf16 rides in a uint16 row, exact through 2^16).
HALF_CELL_LIMIT = {jnp.dtype(jnp.float16): 1 << 11,  # sphlint: disable=dtype-literal
                   jnp.dtype(jnp.bfloat16): 1 << 16}  # sphlint: disable=dtype-literal


def mass_scale(m: Array) -> Array:
    """Normalizer for the half-record mass column: mean |m|.

    SPH masses are ~rho0·ds^dim — far below fp16's normal range once ds
    is small (ds=1e-3 in 2-D gives m~1e-6: subnormal, ~0.2-3%
    quantization; below 6e-8 it flushes to exactly 0). Every pair term
    is LINEAR in m_j, so the record stores m/scale (O(1), full fp16
    precision) and the sweep multiplies its outputs by scale once —
    resolution-independent accuracy for two O(N) multiplies.
    """
    return jnp.maximum(
        jnp.mean(jnp.abs(m)).astype(jnp.float32), jnp.float32(1e-30)
    )


def _records_half(
    rc: rcll.RCLLState, v: Array, m: Array, records_dtype
) -> Array:
    """(N+1, 3d+1) half-width record rows [I | rel | v | m].

    ``m`` must arrive pre-normalized by ``mass_scale`` (callers rescale
    the sweep outputs).

    Two encodings of the same 16-bit row, chosen by the records dtype:

      * fp16: one PLAIN fp16 array — the cell coordinate is stored as an
        fp16 VALUE (exact: grids are guarded to < 2^11 cells per axis),
        rel is the raw RCLL storage value, v/m are fp16. The sweep then
        decodes with a single upconvert and zero bitcasts — measured
        ~25% faster than a bitcast row on CPU, and TPU VPUs upconvert
        fp16 storage for free.
      * bf16: a uint16-bitcast row — rel must stay fp16 (bf16's 8-bit
        mantissa would quantize the coordinate), so the row mixes uint16
        cell values, fp16 rel bits, and bf16 v/m bits.

    Either way the decode reconstructs the IDENTICAL fp32 values. Row N
    is the all-zero dummy row (m = 0 kills every term).
    """
    d = rc.rel.shape[1]
    if jnp.dtype(records_dtype) == jnp.float16:  # sphlint: disable=dtype-literal
        rec = jnp.concatenate(
            [
                rc.cell_xy.astype(jnp.float16),  # sphlint: disable=dtype-literal
                rc.rel.astype(jnp.float16),  # sphlint: disable=dtype-literal
                v.astype(jnp.float16),  # sphlint: disable=dtype-literal
                m.astype(jnp.float16)[:, None],  # sphlint: disable=dtype-literal
            ],
            axis=1,
        )
        pad = jnp.zeros((1, 3 * d + 1), jnp.float16)  # sphlint: disable=dtype-literal
    else:
        rec = jnp.concatenate(
            [
                rc.cell_xy.astype(jnp.uint16),
                _u16(rc.rel.astype(jnp.float16)),  # sphlint: disable=dtype-literal
                _u16(v.astype(records_dtype)),
                _u16(m.astype(records_dtype))[:, None],
            ],
            axis=1,
        )
        pad = jnp.zeros((1, 3 * d + 1), jnp.uint16)
    return jnp.concatenate([rec, pad], axis=0)


def _sanitized_idx(nl: NeighborList, n: int) -> Array:
    """Neighbor ids with invalid slots redirected to the dummy row N."""
    return jnp.where(nl.mask, nl.idx, jnp.int32(n))


@partial(
    jax.jit,
    static_argnames=(
        "domain", "chunk", "mu", "c0", "rho0", "records", "scheme"
    ),
)
def force_rhs(
    domain: Domain,
    rc: rcll.RCLLState,  # packed (N, d) state
    nl: NeighborList,  # packed indexing, K-compacted
    v: Array,  # (N, d) f32
    m: Array,  # (N,) f32
    rho: Array,  # (N,) f32 current density
    *,
    c0: float | None = None,  # legacy WCSPH shorthand (see ``scheme``)
    rho0: float = 1.0,
    chunk: int = 0,
    mu: float = 0.0,
    records: str = "fp32",
    idx_dummy: Array | None = None,
    scheme: scheme_lib.Scheme | None = None,
    m_scale: Array | None = None,
) -> tuple[Array, Array]:
    """The full SPH pair RHS in ONE cell-blocked pass.

    Returns (drho (N,), acc (N, d)): the continuity sum and the momentum
    sum (∇W channel + dv channel of the ``scheme``), both at the current
    state. One record gather (plus, in the half-width layout, one fp32
    rho gather) and one geometry decode feed both sums; no (N, K)
    intermediate exists outside the live chunk. Body force and the
    wall-particle mask are applied by the caller (per-particle terms —
    nothing pairwise about them).

    ``scheme`` (static) selects the physics terms (core/scheme.py).
    The legacy ``c0``/``rho0``/``mu`` kwargs build the PR 2/3 WCSPH
    scheme (linear Tait + Morris) when ``scheme`` is omitted — existing
    callers are unchanged.

    ``records`` selects the record layout (see module docstring):
    "fp32" is the full-width accuracy oracle, "fp16"/"bf16" the
    half-width production layout. Both run the identical fp32 pair
    arithmetic (``_pair_rhs``) on their decoded slabs, so half-width
    results are bit-identical to fp32-record results whenever v and m
    are exactly representable in the records dtype.

    ``idx_dummy``: optional pre-sanitized neighbor ids (invalid -> N).
    The persistent solver computes them once per REBUILD (the list is
    static between rebuilds) instead of once per step — and the window
    search emits this layout directly.

    ``m_scale``: optional precomputed half-record mass normalizer
    (``mass_scale(m)``). Masses are constant over a run, so the
    persistent solver computes it ONCE at init instead of reducing m
    every step.
    """
    if scheme is None:
        if c0 is None:
            raise ValueError("pass either scheme= or the legacy c0=")
        scheme = scheme_lib.wcsph(c0, rho0, mu)
    rho0 = scheme.rho0
    d = domain.dim
    n = rc.rel.shape[0]
    rdt = dtype_of(records)
    half = jnp.dtype(rdt).itemsize == 2
    if half and max(domain.ncells) >= HALF_CELL_LIMIT[jnp.dtype(rdt)]:
        raise ValueError(
            "half-width records store cell coordinates in 16-bit rows "
            f"(exact through {HALF_CELL_LIMIT[jnp.dtype(rdt)]} cells per "
            f"axis for records={records!r}); grid {domain.ncells} exceeds "
            "that — use records='fp32'"
        )
    idx = _sanitized_idx(nl, n) if idx_dummy is None else idx_dummy
    # The single fp32 density field of BOTH layouts is the reciprocal:
    # p/ρ² becomes division-free per pair (sph.eos_tait_por2_inv) and
    # the viscosity ρ-product division disappears. N divisions once
    # instead of N·K per sweep.
    inv = (1.0 / rho).astype(jnp.float32)

    if not half:
        rec = _records(rc, v, m, inv, scheme.por2_inv(inv))
        rec = rec.at[n, 2 * d + 2].set(0.0)  # dummy p/ρ² (1/ρ stays 1)

        def body(args):
            idx_c, rec_i = args
            rec_j = rec[idx_c]  # ONE gather: (chunk, K, 2d+3)
            return _pair_rhs(
                domain,
                rec_i[:, None, :d], rec_j[..., :d],
                rec_i[:, None, d:2 * d], rec_j[..., d:2 * d],
                rec_j[..., 2 * d],  # m_j: 0 on the dummy row
                rec_i[:, None, 2 * d + 2], rec_j[..., 2 * d + 2],
                rec_i[:, None, 2 * d + 1], rec_j[..., 2 * d + 1],
                scheme=scheme,
            )

        pad_rows = (jnp.full((idx.shape[1],), n, jnp.int32), rec[n])
        return _map_chunks(body, (idx, rec[:n]), pad_rows, n, chunk)

    if m_scale is None:
        m_scale = mass_scale(m)
    rec16 = _records_half(rc, v, m.astype(jnp.float32) / m_scale, rdt)
    # Dummy 1/ρ = 1/ρ0: p/ρ² decodes to ~0 and denominators stay
    # positive; m = 0 on the dummy row kills every pair term regardless.
    inv32 = jnp.concatenate(
        [inv, jnp.full((1,), 1.0 / rho0, jnp.float32)]
    )

    plain = jnp.dtype(rdt) == jnp.float16  # plain-fp16 row, no bitcasts  # sphlint: disable=dtype-literal

    def decode(r16):
        """ONE upconvert of the whole gathered row -> (q, v, m) fp32.

        q = I + rel/2 is the exact fp32 value the full-width row
        stores, so past this point the body is the fp32 body.
        """
        if plain:
            r32 = r16.astype(jnp.float32)
        else:  # bf16: mixed-bits row [u16 cell | f16 rel | bf16 v m]
            r32 = jnp.concatenate(
                [
                    r16[..., :d].astype(jnp.float32),
                    jax.lax.bitcast_convert_type(
                        r16[..., d:2 * d], jnp.float16  # sphlint: disable=dtype-literal
                    ).astype(jnp.float32),
                    jax.lax.bitcast_convert_type(
                        r16[..., 2 * d:], rdt
                    ).astype(jnp.float32),
                ],
                axis=-1,
            )
        q = r32[..., :d] + r32[..., d:2 * d] * 0.5
        return q, r32[..., 2 * d:3 * d], r32[..., 3 * d]

    def body(args):
        idx_c, r16_i, inv_i = args
        r16_j = rec16[idx_c]  # ONE half-width gather: (chunk, K, 3d+1)
        inv_j = inv32[idx_c]  # the single fp32 pair field
        q_i, v_i, _ = decode(r16_i)
        q_j, v_j, m_j = decode(r16_j)
        return _pair_rhs(
            domain,
            q_i[:, None, :], q_j,
            v_i[:, None, :], v_j,
            m_j,
            scheme.por2_inv(inv_i)[:, None],
            scheme.por2_inv(inv_j),
            inv_i[:, None], inv_j,
            scheme=scheme,
        )

    pad_rows = (
        jnp.full((idx.shape[1],), n, jnp.int32), rec16[n], inv32[n]
    )
    drho, acc = _map_chunks(
        body, (idx, rec16[:n], inv32[:n]), pad_rows, n, chunk
    )
    return drho * m_scale, acc * m_scale  # undo the mass normalization


def record_bytes_per_pair(d: int, records: str = "fp32") -> int:
    """Record bytes gathered per neighbor pair under a record layout.

    fp32: one (2d+3)-column fp32 row. Half-width: one (3d+1)-column
    uint16 row plus the single fp32 rho gather (p/ρ² is recomputed
    in-register from 1/rho — see ``sph.eos_tait_por2_inv``).
    """
    if jnp.dtype(dtype_of(records)).itemsize == 2:
        return (3 * d + 1) * 2 + 4
    return (2 * d + 3) * 4


def estimate_hbm_bytes_per_step(
    n: int, k: int, d: int, fused: bool = True, records: str = "fp32"
) -> int:
    """Back-of-envelope HBM pair-traffic model for one physics step.

    Gather (reference) path materializes, per step: disp (N,K,d), r
    (N,K), gw (N,K,d), dv (N,K,d), mj (N,K), plus per-term coefficient
    arrays pij/x_dot_gw/rho_ij/coef (N,K) — ~(6d + 9) N·K fp32 write+read
    round-trips — and performs ~6 scalar neighbor gathers.

    Fused path, per step: ONE sanitized-id read per pair (int32 — the
    sanitize itself, idx + mask read and idx_dummy write, happens once
    per REBUILD since PR 2 and is amortized out of the per-step model,
    which the PR 2 model overcounted), the record gather
    (``record_bytes_per_pair`` — layout-dependent), and O(N)
    per-particle traffic (record build write + self-row read + drho/acc
    out); pair intermediates never leave cache.
    """
    nk = n * k
    if fused:
        ids = nk * 4  # sanitized idx read, one sweep
        rec = record_bytes_per_pair(d, records)
        gathers = nk * rec
        per_particle = n * (2 * rec + (d + 1) * 4)
        return ids + gathers + per_particle
    round_trips = 2 * (6 * d + 9)  # write + read back of each pair array
    gathers = nk * (2 * d + 3 + d) * 4  # rel/cell/v/m/rho/p scalar
    return nk * round_trips * 4 + gathers
