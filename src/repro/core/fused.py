"""Fused cell-blocked WCSPH force pass (the ``backend="xla"`` hot path).

The reference step (``backend="reference"``) round-trips every pair
intermediate through HBM — ``pair_displacements`` (N, K, d), ``grad_w``
(N, K, d), the gathered pair fields (N, K)x3, one (N, K) coefficient per
RHS term — and pays 5-6 *separate* neighbor gathers (rel, cell, v, m,
rho, p/ρ²), each a strided walk over the particle arrays. Profiling
(paper Table 6) identifies exactly this pattern as bandwidth-bound.

This module evaluates the same sums with two structural changes:

**One record gather per sweep.** All per-particle inputs of a sweep are
packed into a single fp32 record row (Domínguez et al.'s float4-texture
trick, arXiv:1110.3711): ``[q (d) | v (d) | m]`` for the continuity
sweep, plus ``[rho | p/ρ²]`` for the momentum sweep. A sweep then gathers
``rec[idx]`` once — contiguous rows, cache-line friendly — instead of
5-6 scalar gathers. ``q = I + x/2`` is the particle position in per-axis
*cell units*, built from the RCLL state by exact fp32 arithmetic: the
integer cell coordinate is exact in fp32 and the fp16 payload halving is
exact, so ``q_i - q_j`` reproduces the Eq. (7) anchored decode to ~1 ulp
of q — two orders of magnitude below the fp16 *storage* granularity that
bounds both decodes. Periodic axes wrap by minimum image on the integer
cell span.

**Chunked reduction, no pair HBM round-trip.** Particles are cell-sorted
in the persistent pipeline, so a contiguous run of packed rows IS a
contiguous run of background cells — ``lax.map`` over chunks of packed
rows is the cell-blocked traversal with zero empty-slot padding (the
dense (C, cap, K) cell tables pad by cap/mean-occupancy; packed rows
visit the same cells in the same order without the padding). Each chunk
decodes pair geometry, evaluates the B-spline gradient and the
continuity/momentum terms through the SAME primitives as the reference
path (``core/bspline.py`` + ``sph.momentum_rhs_terms``), and reduces
over K immediately: peak pair-intermediate memory is O(chunk · K · d) —
cache-resident — instead of O(N · K · d) in HBM.

Physics ordering note: the solver integrates the standard explicit
WCSPH scheme (symplectic Euler, as in DualSPHysics): continuity AND
momentum are evaluated at the common current state, with the Tait
pressure of the pre-update density. That is what makes a SINGLE pass
possible — a semi-implicit rho-then-momentum ordering would force all
drho to exist (a global barrier) before any momentum term, i.e. a
second full geometry sweep.

Masking note: there is no per-pair mask at all. Invalid neighbor slots
are redirected to a dummy record row (index N) holding ``m = 0`` (and
``rho = 1`` so denominators stay positive): every pair term carries an
m_j factor, and the B-spline derivative vanishes identically beyond the
support 2h and at r = 0, so invalid slots, padding rows, the self pair,
and Verlet-skin extras all contribute an exact 0.0 without any per-term
select or (N, K) boolean traffic in the hot loop.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core import bspline, rcll, sph
from repro.core.domain import Domain
from repro.core.nnps import NeighborList

Array = jnp.ndarray

#: Default rows per chunk. At K = 64, d = 2 this bounds live pair
#: intermediates to a few MB — L2/L3-resident on CPU hosts.
DEFAULT_CHUNK = 8192


def resolve_chunk(n: int, chunk: int = 0) -> int:
    """Static chunk size: ``chunk`` (or DEFAULT_CHUNK), equalized.

    The requested size fixes the number of chunks; the returned size is
    the smallest that still covers n in that many — e.g. n=8455 with a
    8192 request becomes 2 chunks of 4228 instead of 8192+263 (which
    would waste ~48% of the second chunk's pair work on padding).
    """
    c = max(1, min(n, chunk if chunk > 0 else DEFAULT_CHUNK))
    nchunk = -(-n // c)
    return -(-n // nchunk)


def _chunk_rows(x: Array, nchunk: int, chunk: int, pad_row: Array) -> Array:
    """Pad axis 0 to nchunk*chunk with ``pad_row`` rows and reshape to
    (nchunk, chunk, ...)."""
    pad = nchunk * chunk - x.shape[0]
    if pad:
        x = jnp.concatenate(
            [x, jnp.broadcast_to(pad_row, (pad,) + x.shape[1:])], axis=0
        )
    return x.reshape((nchunk, chunk) + x.shape[1:])


def _map_chunks(body, row_args: tuple, pad_rows: tuple, n: int, chunk: int):
    """lax.map ``body`` over row-chunks of every array in ``row_args``.

    Short final chunks are padded with the caller-supplied ``pad_rows``
    (one per row arg) — the force pass pads the id rows with the dummy
    index N and the record rows with the dummy record itself, so pad
    rows evaluate all-dummy pairs: exactly zero, finite, no NaN. The
    pad is sliced off the output. Returns the per-row results, (n, ...).
    """
    chunk = resolve_chunk(n, chunk)
    nchunk = -(-n // chunk)
    if nchunk == 1:  # chunk covers all rows: no pad, no map
        return body(row_args)
    chunked = tuple(
        _chunk_rows(a, nchunk, chunk, p) for a, p in zip(row_args, pad_rows)
    )
    out = jax.lax.map(body, chunked)
    return jax.tree_util.tree_map(
        lambda o: o.reshape((nchunk * chunk,) + o.shape[2:])[:n], out
    )


def cell_coords_f32(rc: rcll.RCLLState) -> Array:
    """(N, d) fp32 positions in per-axis CELL units: q = I + x/2.

    Integer cell coordinates are exact in fp32 (grids are far below
    2^24 cells per axis) and halving the fp16 payload is exact, so q
    carries the full information of the RCLL state to ~1 ulp — the
    storage quantization of ``rel`` remains the dominant error exactly
    as in the anchored Eq. (7) decode.
    """
    return rc.cell_xy.astype(jnp.float32) + rc.rel.astype(jnp.float32) * 0.5


def _pair_geometry(domain: Domain, q_i, q_j):
    """Physical pair displacement / distance factors from cell-unit coords.

    disp_a = (q_i - q_j)_a * hc_phys_a — the same per-axis scaling as the
    Pallas tile decode (``kernels/tiling.tile_phys_disp``). The minimum
    image is applied per-axis at trace time (only periodic axes pay it),
    in select form: true pairs sit in adjacent cells, so |du| > span/2
    happens only across the periodic seam and a single +-span correction
    is exact. Returns (disp, r2, coef) with coef = (dW/dr)/r — the shared
    scalar factor of every gradient component (gw_a = coef * disp_a).
    """
    du = q_i - q_j
    cols = []
    for a, (per, ncell, hc) in enumerate(
        zip(domain.periodic, domain.ncells, domain.cell_sizes)
    ):
        da = du[..., a]
        if per:
            span = jnp.float32(ncell)
            half = jnp.float32(ncell / 2.0)
            da = da - span * (da > half).astype(jnp.float32) \
                + span * (da < -half).astype(jnp.float32)
        cols.append(da * jnp.float32(hc))
    disp = jnp.stack(cols, axis=-1)
    r2 = jnp.sum(disp * disp, axis=-1)
    # Unmasked: dW/dr vanishes beyond 2h and at r = 0, and every consumer
    # multiplies by mj (0 on invalid slots) — no select needed.
    coef = bspline.dw_over_r(jnp.sqrt(r2), domain.h, domain.dim)
    return disp, r2, coef


def _records(rc: rcll.RCLLState, v: Array, m: Array, *extra: Array) -> Array:
    """(N+1, 2d+1+len(extra)) record rows [q | v | m | extra...].

    Row N is the dummy target of invalid neighbor slots: m = 0 zeroes
    every pair term exactly; extras default to 1.0 so denominator fields
    (rho) stay positive — callers overwrite columns that must be 0.
    """
    cols = [cell_coords_f32(rc), v.astype(jnp.float32),
            m.astype(jnp.float32)[:, None]]
    cols += [e.astype(jnp.float32)[:, None] for e in extra]
    rec = jnp.concatenate(cols, axis=1)
    dummy = jnp.zeros((1, rec.shape[1]), jnp.float32)
    dummy = dummy.at[0, 2 * v.shape[1] + 1:].set(1.0)
    return jnp.concatenate([rec, dummy], axis=0)


def _sanitized_idx(nl: NeighborList, n: int) -> Array:
    """Neighbor ids with invalid slots redirected to the dummy row N."""
    return jnp.where(nl.mask, nl.idx, jnp.int32(n))


@partial(jax.jit, static_argnames=("domain", "chunk", "mu"))
def force_rhs(
    domain: Domain,
    rc: rcll.RCLLState,  # packed (N, d) state
    nl: NeighborList,  # packed indexing, K-compacted
    v: Array,  # (N, d) f32
    m: Array,  # (N,) f32
    rho: Array,  # (N,) f32 current density
    p: Array,  # (N,) f32 EOS pressure of ``rho``
    chunk: int = 0,
    mu: float = 0.0,
    idx_dummy: Array | None = None,
) -> tuple[Array, Array]:
    """The full WCSPH pair RHS in ONE cell-blocked pass.

    Returns (drho (N,), acc (N, d)): the continuity sum and the momentum
    sum (pressure + Morris viscosity), both at the current state. One
    record gather and one geometry decode feed both sums; no (N, K)
    intermediate exists outside the live chunk. Body force and the
    fixed-particle mask are applied by the caller (per-particle terms —
    nothing pairwise about them).

    ``idx_dummy``: optional pre-sanitized neighbor ids (invalid -> N).
    The persistent solver computes them once per REBUILD (the list is
    static between rebuilds) instead of once per step.

    The pair algebra folds the shared scalar coefficient first
    (s = coef * pair-coefficient, then s * disp_a / s * dv_a), which is
    an exact regrouping of ``sph.momentum_rhs_terms`` /
    ``continuity_rhs_pairs`` — same terms, fewer per-axis multiplies.
    """
    d = domain.dim
    hh = domain.h  # smoothing length: gradient and viscosity guard alike
    n = rc.rel.shape[0]
    rec = _records(rc, v, m, rho, p / (rho * rho))
    rec = rec.at[n, 2 * d + 2].set(0.0)  # dummy p/ρ² (rho stays 1)
    idx = _sanitized_idx(nl, n) if idx_dummy is None else idx_dummy

    def body(args):
        idx_c, rec_i = args
        rec_j = rec[idx_c]  # ONE gather: (chunk, K, 2d+3)
        disp, r2, coef = _pair_geometry(
            domain, rec_i[:, None, :d], rec_j[..., :d]
        )
        dv = rec_i[:, None, d:2 * d] - rec_j[..., d:2 * d]
        mj = rec_j[..., 2 * d]  # 0 on the dummy row
        # Σ m_j (dv·∇W): ∇W_a = coef·disp_a -> fold coef out of the dot.
        drho = jnp.sum(mj * coef * jnp.sum(dv * disp, axis=-1), axis=-1)
        # Pressure: -Σ [m_j (p/ρ²_i + p/ρ²_j) coef] disp_a.
        pc = sph.pressure_pair_coef(
            mj, rec_i[:, None, 2 * d + 2], rec_j[..., 2 * d + 2]
        ) * coef
        # Viscosity: x·∇W = coef·r2 (already folded in the shared coef).
        vc = sph.viscosity_pair_coef(
            mj, coef * r2,
            rec_i[:, None, 2 * d + 1], rec_j[..., 2 * d + 1],
            r2, h=hh, mu=mu,
        )
        acc = jnp.sum(vc[..., None] * dv - pc[..., None] * disp, axis=-2)
        return drho, acc

    pad_rows = (jnp.full((idx.shape[1],), n, jnp.int32), rec[n])
    return _map_chunks(body, (idx, rec[:n]), pad_rows, n, chunk)


def estimate_hbm_bytes_per_step(
    n: int, k: int, d: int, fused: bool, itemsize: int = 4
) -> int:
    """Back-of-envelope HBM pair-traffic model for one physics step.

    Gather (reference) path materializes, per step: disp (N,K,d), r
    (N,K), gw (N,K,d), dv (N,K,d), mj (N,K), plus per-term coefficient
    arrays pij/x_dot_gw/rho_ij/coef (N,K) — ~(6d + 9) N·K fp32 write+read
    round-trips — and performs ~6 scalar neighbor gathers. Fused path
    touches the neighbor ids once (idx int32 + mask bool in the
    sanitize, sanitized idx write + read back), ONE record-row gather
    for the single sweep ((2d+3) fp32 per pair), and O(N) per-particle
    in/out; pair intermediates never leave cache.
    """
    nk = n * k
    if fused:
        ids = nk * (4 + 1 + 2 * 4)  # idx+mask read, idx_s write+read
        gathers = nk * (2 * d + 3) * itemsize  # one record row, one sweep
        per_particle = n * (2 * (2 * d + 3) + d + 1) * itemsize
        return ids + gathers + per_particle
    round_trips = 2 * (6 * d + 9)  # write + read back of each pair array
    gathers = nk * (2 * d + 3 + d) * itemsize  # rel/cell/v/m/rho/p scalar
    return nk * round_trips * itemsize + gathers
