"""Domain specification and coordinate normalization (paper Eqs. 5-6).

The paper normalizes all coordinates twice:
  1. Eq. (5): absolute coordinates -> [-1, 1] over the *longest* domain span
     h_d, so every axis shares one scale (preserves isotropy of distances).
  2. Eq. (6): within each background cell, coordinates are re-expressed
     relative to the cell center and normalized to [-1, 1] by the cell size.

Cell sizes are *per axis*: on periodic axes the grid must tile the span
exactly (ncells = floor(span/target), cell = span/ncells >= radius), on
wall axes we use ceil with cell = cell_factor * radius and let the grid
overhang the box (harmless without wrap). RCLL distance math works in
"reference cell units" with O(1) per-axis anisotropy weights
w_a = hc_a / hc_ref, so fp16 never sees tiny absolute scales (DESIGN.md
section 2).

All functions take an explicit ``dtype`` so that precision is a *policy*,
never an ambient global (see repro.core.precision).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.precision import NNPS_STORE

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class Domain:
    """Static (trace-time) description of the simulation box.

    Attributes:
      lo / hi: physical bounds per axis, python floats (static).
      h: SPH smoothing length (physical units). Search radius is ``2*h``.
      cell_factor: target cell size as a multiple of the search radius (>=1).
      periodic: per-axis periodic wrap flags.
    """

    lo: tuple[float, ...]
    hi: tuple[float, ...]
    h: float
    cell_factor: float = 1.0
    periodic: tuple[bool, ...] = ()

    def __post_init__(self):
        if not self.periodic:
            object.__setattr__(self, "periodic", (False,) * self.dim)
        assert len(self.lo) == len(self.hi) == len(self.periodic)
        assert self.cell_factor >= 1.0
        for a, p in enumerate(self.periodic):
            if p:
                assert self.ncells[a] >= 3, (
                    f"periodic axis {a} needs >= 3 cells "
                    f"(span {self.spans[a]}, radius {self.radius}); the "
                    "3-cell neighborhood would alias otherwise"
                )

    # ---- static geometry -------------------------------------------------
    @property
    def dim(self) -> int:
        return len(self.lo)

    @property
    def spans(self) -> tuple[float, ...]:
        return tuple(h - l for l, h in zip(self.lo, self.hi))

    @property
    def h_d(self) -> float:
        """Maximum domain span (the paper's h_d, Eq. 5)."""
        return max(self.spans)

    @property
    def radius(self) -> float:
        """Physical search radius 2h."""
        return 2.0 * self.h

    @property
    def radius_norm(self) -> float:
        """Search radius in normalized coordinates (length L -> 2L/h_d)."""
        return 2.0 * self.radius / self.h_d

    @property
    def ncells(self) -> tuple[int, ...]:
        """Cells per axis: exact tiling (floor) on periodic axes, ceil on
        wall axes. Cell size >= search radius is preserved either way."""
        target = self.cell_factor * self.radius
        out = []
        for s, p in zip(self.spans, self.periodic):
            if p:
                out.append(max(1, int(np.floor(s / target + 1e-9))))
            else:
                out.append(max(1, int(np.ceil(s / target - 1e-9))))
        return tuple(out)

    @property
    def cell_sizes(self) -> tuple[float, ...]:
        """Physical cell edge per axis (>= search radius)."""
        target = self.cell_factor * self.radius
        return tuple(
            s / n if p else target
            for s, n, p in zip(self.spans, self.ncells, self.periodic)
        )

    @property
    def ncells_total(self) -> int:
        return int(np.prod(self.ncells))

    @property
    def hc_norm_axes(self) -> tuple[float, ...]:
        """Cell edges in normalized coordinates (the paper's h_c, per axis)."""
        return tuple(2.0 * c / self.h_d for c in self.cell_sizes)

    @property
    def hc_ref(self) -> float:
        """Reference (minimum) normalized cell edge for RCLL cell units."""
        return min(self.hc_norm_axes)

    @property
    def cell_weights(self) -> tuple[float, ...]:
        """O(1) anisotropy weights w_a = hc_a / hc_ref (>= 1, ~1)."""
        ref = self.hc_ref
        return tuple(c / ref for c in self.hc_norm_axes)

    # ---- Eq. (5): absolute -> normalized [-1, 1] --------------------------
    def normalize(self, x: Array, dtype=jnp.float32) -> Array:
        """x' = (2 x0 - (xmax + xmin)) / h_d  (paper Eq. 5), per axis."""
        lo = jnp.asarray(self.lo, dtype=dtype)
        hi = jnp.asarray(self.hi, dtype=dtype)
        hd = jnp.asarray(self.h_d, dtype=dtype)
        x = x.astype(dtype)
        return (2.0 * x - (hi + lo)) / hd

    def denormalize(self, xn: Array, dtype=jnp.float32) -> Array:
        lo = jnp.asarray(self.lo, dtype=dtype)
        hi = jnp.asarray(self.hi, dtype=dtype)
        hd = jnp.asarray(self.h_d, dtype=dtype)
        return (xn.astype(dtype) * hd + (hi + lo)) / 2.0

    # Normalized lower corner of the cell grid (cells tile from the lo corner).
    @property
    def origin_norm(self) -> tuple[float, ...]:
        lo = np.asarray(self.lo)
        hi = np.asarray(self.hi)
        hd = self.h_d
        return tuple((2.0 * lo - (hi + lo)) / hd)

    # ---- Eq. (6): normalized absolute -> cell-relative [-1, 1] -----------
    def cell_center_norm(self, cell_coords: Array, dtype=jnp.float32) -> Array:
        """Normalized coordinates of a cell center given integer cell coords."""
        org = jnp.asarray(self.origin_norm, dtype=dtype)
        hc = jnp.asarray(self.hc_norm_axes, dtype=dtype)
        return org + (cell_coords.astype(dtype) + 0.5) * hc

    def to_relative(
        self, xn: Array, cell_coords: Array, dtype=NNPS_STORE
    ) -> Array:
        """x = 2 (x' - x'_cc) / h_c (paper Eq. 6); result nominally in [-1,1].

        The subtraction happens in fp32 (exact to fp32 precision), only the
        *storage* of the small relative value is low precision - this is the
        entire point of RCLL: relative values are O(1) so fp16's ~3 decimal
        digits are plenty.
        """
        cc = self.cell_center_norm(cell_coords, dtype=jnp.float32)
        hc = jnp.asarray(self.hc_norm_axes, dtype=jnp.float32)
        rel = 2.0 * (xn.astype(jnp.float32) - cc) / hc
        return rel.astype(dtype)

    def from_relative(
        self, rel: Array, cell_coords: Array, dtype=jnp.float32
    ) -> Array:
        """Inverse of Eq. (6): x' = x'_cc + x * h_c / 2 (hi-precision decode)."""
        cc = self.cell_center_norm(cell_coords, dtype=dtype)
        hc = jnp.asarray(self.hc_norm_axes, dtype=dtype)
        return cc + rel.astype(dtype) * (hc / 2.0)

    # ---- cell arithmetic ---------------------------------------------------
    def cell_coords_of(self, xn: Array) -> Array:
        """Integer cell coordinates of normalized positions (clipped)."""
        org = jnp.asarray(self.origin_norm, dtype=jnp.float32)
        hc = jnp.asarray(self.hc_norm_axes, dtype=jnp.float32)
        c = jnp.floor((xn.astype(jnp.float32) - org) / hc)
        n = jnp.asarray(self.ncells, dtype=jnp.int32)
        return jnp.clip(c.astype(jnp.int32), 0, n - 1)

    def flat_cell_id(self, cell_coords: Array) -> Array:
        """Row-major flatten of per-axis cell coordinates.

        Row-major order of a regular grid is itself the paper's 'sort by x
        then y' locality optimization (see DESIGN.md section 2).
        """
        n = self.ncells
        flat = cell_coords[..., 0].astype(jnp.int32)
        for a in range(1, self.dim):
            flat = flat * n[a] + cell_coords[..., a].astype(jnp.int32)
        return flat

    def unflatten_cell_id(self, flat: Array) -> Array:
        n = self.ncells
        coords = []
        rem = flat.astype(jnp.int32)
        for a in range(self.dim - 1, 0, -1):
            coords.append(rem % n[a])
            rem = rem // n[a]
        coords.append(rem)
        return jnp.stack(coords[::-1], axis=-1)

    def wrap_cell_delta(self, delta: Array) -> Array:
        """Minimum-image wrap of integer cell-coordinate deltas (periodic axes)."""
        n = np.asarray(self.ncells, dtype=np.int32)
        per = np.asarray(self.periodic)
        half = jnp.asarray(n // 2, dtype=jnp.int32)
        nn = jnp.asarray(n, dtype=jnp.int32)
        wrapped = ((delta + half) % nn) - half
        return jnp.where(jnp.asarray(per), wrapped, delta)


def unit_square(h: float, **kw) -> Domain:
    return Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=h, **kw)


def unit_cube(h: float, **kw) -> Domain:
    return Domain(lo=(0.0, 0.0, 0.0), hi=(1.0, 1.0, 1.0), h=h, **kw)


def lattice_positions(domain: Domain, ds: float, jitter: float = 0.0,
                      seed: int = 0) -> np.ndarray:
    """Regular particle lattice with optional jitter (numpy, host-side)."""
    axes = [np.arange(lo + ds / 2, hi, ds) for lo, hi in zip(domain.lo, domain.hi)]
    grid = np.meshgrid(*axes, indexing="ij")
    x = np.stack([g.ravel() for g in grid], axis=-1).astype(np.float64)
    if jitter > 0.0:
        rng = np.random.default_rng(seed)
        x = x + rng.uniform(-jitter * ds, jitter * ds, size=x.shape)
    return x
