"""Public simulation facade: ``Simulation`` + in-scan ``Observables``.

The PR 1-3 entry points (``solver.simulate`` / ``init_persistent`` +
``run_persistent`` + ``finalize_persistent``) stay as the low-level API;
this module wraps them behind one object that every scenario case,
example, and the ``python -m repro.sph`` CLI drive:

    sim = Simulation.from_case("taylor_green", ds=1/32)
    res = sim.run(nsteps=600, observe_every=20)
    res.observables.ekin  # (S,) device array, sampled IN the scan

**In-scan observables.** Diagnostics sampled every ``observe_every``
steps are computed INSIDE the jitted scan (an outer ``lax.scan`` over
sample blocks whose body advances ``observe_every`` solver steps and
reduces the carry to a handful of scalars). Nothing syncs to the host
until the run returns — the observable rows cost O(S) scalars of HBM,
not S device round-trips, preserving the donated-carry hot loop.

Observables (per sample, fluid particles only — walls are excluded by
the ``kind``/``fixed`` mask):

  * ``ekin``    — total kinetic energy 0.5 Σ m |v|²;
  * ``vmax``    — max |v|;
  * ``rho_err`` — max |ρ/ρ0 − 1| (the weak-compressibility monitor).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import cases as cases_lib
from repro.core import recovery, solver
from repro.core.health import observe_state  # noqa: F401  (public re-export)

Array = jnp.ndarray


class Observables(NamedTuple):
    """Time series of in-scan diagnostics, one row per sample."""

    t: Array  # (S,) fp32 simulation time at the sample
    ekin: Array  # (S,) fp32 total fluid kinetic energy
    vmax: Array  # (S,) fp32 max fluid |v|
    rho_err: Array  # (S,) fp32 max fluid |rho/rho0 - 1|


class SimResult(NamedTuple):
    state: solver.SPHState  # final state, original particle indexing
    stats: solver.SimStats
    observables: Observables | None
    # GuardReport of a guarded run (recovery actions taken, final
    # escalated config); None on unguarded runs.
    report: recovery.GuardReport | None = None


@partial(jax.jit, static_argnums=(0, 2, 3), donate_argnums=(1,))
def _run_observed_rcll(
    cfg: solver.SPHConfig, carry: solver.PersistentCarry,
    nblocks: int, every: int,
):
    """(nblocks × every) persistent steps, one observable row per block."""

    def body(c, _):
        c = solver._scan_steps(cfg, c, every)
        return c, observe_state(cfg, c.st)

    carry, rows = jax.lax.scan(body, carry, None, length=nblocks)
    return carry, Observables(*rows)


@partial(jax.jit, static_argnums=(0, 2, 3))
def _run_observed_absolute(
    cfg: solver.SPHConfig, state: solver.SPHState, nblocks: int, every: int
):
    def body(s, _):
        def inner(ss, _):
            return solver._step_absolute(cfg, ss), None

        s, _ = jax.lax.scan(inner, s, None, length=every)
        return s, observe_state(cfg, s)

    state, rows = jax.lax.scan(body, state, None, length=nblocks)
    return state, Observables(*rows)


@dataclasses.dataclass
class Simulation:
    """Stateful driver around one (SPHConfig, SPHState) pair.

    ``run`` advances the held state in place and returns a
    :class:`SimResult`; chaining runs continues the same simulation.
    Works for every ``cfg.algo`` — the RCLL persistent pipeline is used
    where available, the absolute-coordinate stepper otherwise.
    """

    cfg: solver.SPHConfig
    state: solver.SPHState
    case: object | None = None  # the CaseSpec that built this, if any

    @classmethod
    def from_case(cls, name_or_case, **overrides) -> "Simulation":
        """Build from a registered case name (or a CaseSpec instance)."""
        case = (
            cases_lib.build_case(name_or_case, **overrides)
            if isinstance(name_or_case, str)
            else name_or_case
        )
        cfg, state = case.build()
        return cls(cfg=cfg, state=state, case=case)

    @property
    def n_particles(self) -> int:
        return int(self.state.xn.shape[0])

    def run(
        self, nsteps: int, observe_every: int = 0, guard=None
    ) -> SimResult:
        """Advance ``nsteps`` steps; sample observables every ``observe_every``.

        ``observe_every=0`` disables sampling (``observables=None``) and
        is then exactly ``solver.simulate_stats``. Otherwise the run
        takes ``nsteps`` rounded DOWN to a whole number of sample blocks
        (at least one), so every returned row has uniform spacing.

        ``guard`` enables the self-healing health guard (RCLL only):
        ``True`` for the default :class:`recovery.GuardPolicy`, or a
        policy instance. The run then detects divergence in-scan,
        recovers by rollback + escalation (dt backoff, capacity regrow,
        precision degrade), updates ``self.cfg`` to the escalated config,
        and raises :class:`recovery.SimulationDiverged` only when the
        policy is exhausted. The report rides ``SimResult.report``.

        The observed RCLL path donates its scan carry (the
        ``run_persistent`` production semantics): the SPHState this
        Simulation previously held is invalidated — keep using
        ``sim.state`` / the returned result, never a state captured
        before the call.
        """
        cfg = self.cfg
        if guard:
            if cfg.algo != "rcll":
                raise ValueError(
                    "guard requires the persistent rcll pipeline"
                )
            policy = guard if isinstance(guard, recovery.GuardPolicy) \
                else None
            every = min(observe_every, nsteps) if observe_every > 0 else 0
            n = max(1, nsteps // every) * every if every else nsteps
            out, stats, report, rows = recovery.run_guarded(
                cfg, self.state, n, policy, observe_every=every
            )
            obs = None
            if every:
                cols = [jnp.stack(c) for c in zip(*rows)]
                obs = Observables(*cols)
            self.cfg = report.cfg  # keep escalations for chained runs
            self.state = out
            return SimResult(out, stats, obs, report)
        if observe_every <= 0:
            out, stats = solver.simulate_stats(cfg, self.state, nsteps)
            self.state = out
            return SimResult(out, stats, None)

        every = min(observe_every, nsteps)
        nblocks = max(1, nsteps // every)
        if cfg.algo == "rcll":
            carry = solver.init_persistent(cfg, self.state)
            carry, obs = _run_observed_rcll(cfg, carry, nblocks, every)
            stats = solver.SimStats(
                rebuilds=carry.rebuilds, steps=carry.steps,
                overflow=carry.overflow,
            )
            out = solver.finalize_persistent(cfg, carry)
        else:
            out, obs = _run_observed_absolute(
                cfg, self.state, nblocks, every
            )
            n = jnp.asarray(nblocks * every, jnp.int32)
            stats = solver.SimStats(
                rebuilds=n, steps=n, overflow=jnp.zeros((), bool)
            )
        self.state = out
        return SimResult(out, stats, obs)

    def run_timed(
        self, nsteps: int, observe_every: int = 0, guard=None
    ) -> tuple[SimResult, float]:
        """``run`` twice (same shapes — the first call pays the compile)
        and report steps/sec of the second; returns its SimResult."""
        warm = self.run(nsteps, observe_every, guard=guard)
        jax.block_until_ready(warm.state)
        t0 = time.perf_counter()
        res = self.run(nsteps, observe_every, guard=guard)
        jax.block_until_ready(res.state)
        dt_wall = time.perf_counter() - t0
        return res, nsteps / dt_wall
