"""Static-capacity background-cell binning (the TPU-native 'link list').

CUDA link lists are pointer-chasing structures; XLA/TPU need static shapes.
A cell table of shape (ncells_total, capacity) holding particle indices
(-1 = empty) is the dense equivalent. Building it via a stable sort by flat
cell id doubles as the paper's Thrust xy-sort locality optimization: after
binning, particles that share a cell are contiguous, and row-major cell
order means adjacent cells are adjacent in memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domain import Domain

Array = jnp.ndarray


class CellBinning(NamedTuple):
    """Result of binning N particles into the background grid.

    table:     (ncells_total, capacity) int32 particle ids, -1 padded.
    counts:    (ncells_total,) int32 occupancy (may exceed capacity; the
               table silently drops overflow - check ``overflow`` at runtime).
    cell_id:   (N,) int32 flat cell id per particle.
    cell_xy:   (N, dim) int32 per-axis cell coordinates per particle.
    order:     (N,) int32 spatial sort permutation (particles sorted by cell).
    overflow:  () int32 number of particles dropped from the table.
    """

    table: Array
    counts: Array
    cell_id: Array
    cell_xy: Array
    order: Array
    overflow: Array


def bin_particles(domain: Domain, xn: Array, capacity: int) -> CellBinning:
    """Assign particles (normalized coords ``xn``) to cells.

    Args:
      domain: static Domain.
      xn: (N, dim) normalized absolute coordinates (fp32+; binning is a
          hi-precision operation - only *distances* go low-precision).
      capacity: static max particles per cell.
    """
    cell_xy = domain.cell_coords_of(xn)
    cell_id = domain.flat_cell_id(cell_xy)
    return bin_by_cell_id(domain, cell_id, cell_xy, capacity)


def bin_by_cell_id(
    domain: Domain, cell_id: Array, cell_xy: Array, capacity: int
) -> CellBinning:
    """Bin from a *precomputed* cell assignment (the RCLL persistent path).

    RCLL maintains (cell index, relative coordinate) as the source of truth
    (paper Eq. 8); binning must respect that assignment rather than
    recomputing it from absolute positions (which RCLL never materializes).
    """
    n_total = domain.ncells_total
    npart = cell_id.shape[0]

    # Stable sort by cell id == spatial sort (paper's locality optimization).
    order = jnp.argsort(cell_id, stable=True).astype(jnp.int32)
    sorted_cid = cell_id[order]

    counts = jnp.bincount(cell_id, length=n_total).astype(jnp.int32)
    starts = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)[:-1]]
    )
    slot = jnp.arange(npart, dtype=jnp.int32) - starts[sorted_cid]

    keep = slot < capacity
    overflow = jnp.sum(~keep).astype(jnp.int32)
    # Route dropped entries to a scratch row we slice off afterwards.
    safe_cid = jnp.where(keep, sorted_cid, n_total)
    safe_slot = jnp.where(keep, slot, 0)
    table = jnp.full((n_total + 1, capacity), -1, dtype=jnp.int32)
    table = table.at[safe_cid, safe_slot].set(order, mode="drop")
    return CellBinning(
        table=table[:n_total],
        counts=counts,
        cell_id=cell_id,
        cell_xy=cell_xy,
        order=order,
        overflow=overflow,
    )


def neighbor_cell_offsets(dim: int) -> np.ndarray:
    """All 3^dim offsets in {-1,0,1}^dim (static, host-side)."""
    grids = np.meshgrid(*([np.array([-1, 0, 1])] * dim), indexing="ij")
    return np.stack([g.ravel() for g in grids], axis=-1).astype(np.int32)


def candidate_cells(domain: Domain, cell_xy: Array) -> tuple[Array, Array]:
    """For each particle, the flat ids of its 3^dim neighborhood cells.

    Returns (nb_flat (N, 3^dim) int32, nb_valid (N, 3^dim) bool). Periodic
    axes wrap; non-periodic out-of-range cells are flagged invalid.
    """
    offs = jnp.asarray(neighbor_cell_offsets(domain.dim))  # (M, dim)
    nb = cell_xy[:, None, :] + offs[None, :, :]  # (N, M, dim)
    n = jnp.asarray(domain.ncells, dtype=jnp.int32)
    per = jnp.asarray(np.asarray(domain.periodic))
    wrapped = jnp.where(per, nb % n, nb)
    valid = jnp.all((wrapped >= 0) & (wrapped < n), axis=-1)
    clipped = jnp.clip(wrapped, 0, n - 1)
    flat = clipped[..., 0]
    for a in range(1, domain.dim):
        flat = flat * domain.ncells[a] + clipped[..., a]
    return flat.astype(jnp.int32), valid


def gather_candidates(
    domain: Domain, binning: CellBinning
) -> tuple[Array, Array]:
    """Candidate particle ids from each particle's 3^dim cell neighborhood.

    Returns:
      cand: (N, 3^dim * capacity) int32 particle ids (invalid -> 0, masked).
      mask: (N, 3^dim * capacity) bool validity (slot occupied & cell valid).
    """
    nb_flat, nb_valid = candidate_cells(domain, binning.cell_xy)
    cand = binning.table[nb_flat]  # (N, M, cap)
    mask = (cand >= 0) & nb_valid[:, :, None]
    npart = binning.cell_id.shape[0]
    cand = jnp.where(mask, cand, 0)
    return cand.reshape(npart, -1), mask.reshape(npart, -1)


def default_capacity(domain: Domain, n_particles: int, safety: float = 3.0) -> int:
    """Static per-cell capacity estimate: mean occupancy x safety, >= 4."""
    mean = n_particles / max(1, domain.ncells_total)
    cap = int(np.ceil(mean * safety)) + 2
    return max(4, cap)
