"""Static-capacity background-cell binning (the TPU-native 'link list').

CUDA link lists are pointer-chasing structures; XLA/TPU need static shapes.
A cell table of shape (ncells_total, capacity) holding particle indices
(-1 = empty) is the dense equivalent. Building it via a stable sort by flat
cell id doubles as the paper's Thrust xy-sort locality optimization: after
binning, particles that share a cell are contiguous, and row-major cell
order means adjacent cells are adjacent in memory.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.domain import Domain

Array = jnp.ndarray


class CellBinning(NamedTuple):
    """Result of binning N particles into the background grid.

    table:     (ncells_total, capacity) int32 particle ids, -1 padded.
    counts:    (ncells_total,) int32 occupancy (may exceed capacity; the
               table silently drops overflow - check ``overflow`` at runtime).
    cell_id:   (N,) int32 flat cell id per particle.
    cell_xy:   (N, dim) int32 per-axis cell coordinates per particle.
    order:     (N,) int32 spatial sort permutation (particles sorted by cell).
    overflow:  () int32 number of particles dropped from the table.
    """

    table: Array
    counts: Array
    cell_id: Array
    cell_xy: Array
    order: Array
    overflow: Array


def bin_particles(domain: Domain, xn: Array, capacity: int) -> CellBinning:
    """Assign particles (normalized coords ``xn``) to cells.

    Args:
      domain: static Domain.
      xn: (N, dim) normalized absolute coordinates (fp32+; binning is a
          hi-precision operation - only *distances* go low-precision).
      capacity: static max particles per cell.
    """
    cell_xy = domain.cell_coords_of(xn)
    cell_id = domain.flat_cell_id(cell_xy)
    return bin_by_cell_id(domain, cell_id, cell_xy, capacity)


def _table_from_sorted(
    n_total: int, sorted_cid: Array, values: Array, capacity: int
) -> tuple[Array, Array, Array]:
    """Scatter cell-sorted per-particle ``values`` into the (C, cap) table.

    Shared core of ``bin_by_cell_id`` and ``pack_particles``: computes the
    per-cell slot of each (sorted) particle, drops overflow past
    ``capacity`` via a scratch row, and returns (table, counts, overflow).
    """
    npart = sorted_cid.shape[0]
    counts = jnp.bincount(sorted_cid, length=n_total).astype(jnp.int32)
    slot = jnp.arange(npart, dtype=jnp.int32) - exclusive_cumsum(counts)[sorted_cid]
    keep = slot < capacity
    overflow = jnp.sum(~keep).astype(jnp.int32)
    # Route dropped entries to a scratch row we slice off afterwards.
    safe_cid = jnp.where(keep, sorted_cid, n_total)
    safe_slot = jnp.where(keep, slot, 0)
    table = jnp.full((n_total + 1, capacity), -1, dtype=jnp.int32)
    table = table.at[safe_cid, safe_slot].set(values, mode="drop")
    return table[:n_total], counts, overflow


def bin_by_cell_id(
    domain: Domain, cell_id: Array, cell_xy: Array, capacity: int
) -> CellBinning:
    """Bin from a *precomputed* cell assignment (the RCLL persistent path).

    RCLL maintains (cell index, relative coordinate) as the source of truth
    (paper Eq. 8); binning must respect that assignment rather than
    recomputing it from absolute positions (which RCLL never materializes).
    """
    # Stable sort by cell id == spatial sort (paper's locality optimization).
    order = jnp.argsort(cell_id, stable=True).astype(jnp.int32)
    table, counts, overflow = _table_from_sorted(
        domain.ncells_total, cell_id[order], order, capacity
    )
    return CellBinning(
        table=table,
        counts=counts,
        cell_id=cell_id,
        cell_xy=cell_xy,
        order=order,
        overflow=overflow,
    )


def neighbor_cell_offsets(dim: int) -> np.ndarray:
    """All 3^dim offsets in {-1,0,1}^dim (static, host-side)."""
    grids = np.meshgrid(*([np.array([-1, 0, 1])] * dim), indexing="ij")
    return np.stack([g.ravel() for g in grids], axis=-1).astype(np.int32)


def candidate_cells(domain: Domain, cell_xy: Array) -> tuple[Array, Array]:
    """For each particle, the flat ids of its 3^dim neighborhood cells.

    Returns (nb_flat (N, 3^dim) int32, nb_valid (N, 3^dim) bool). Periodic
    axes wrap; non-periodic out-of-range cells are flagged invalid.
    """
    offs = jnp.asarray(neighbor_cell_offsets(domain.dim))  # (M, dim)
    nb = cell_xy[:, None, :] + offs[None, :, :]  # (N, M, dim)
    n = jnp.asarray(domain.ncells, dtype=jnp.int32)
    per = jnp.asarray(np.asarray(domain.periodic))
    wrapped = jnp.where(per, nb % n, nb)
    valid = jnp.all((wrapped >= 0) & (wrapped < n), axis=-1)
    clipped = jnp.clip(wrapped, 0, n - 1)
    flat = clipped[..., 0]
    for a in range(1, domain.dim):
        flat = flat * domain.ncells[a] + clipped[..., a]
    return flat.astype(jnp.int32), valid


def gather_candidates(
    domain: Domain, binning: CellBinning
) -> tuple[Array, Array]:
    """Candidate particle ids from each particle's 3^dim cell neighborhood.

    Returns:
      cand: (N, 3^dim * capacity) int32 particle ids (invalid -> 0, masked).
      mask: (N, 3^dim * capacity) bool validity (slot occupied & cell valid).
    """
    nb_flat, nb_valid = candidate_cells(domain, binning.cell_xy)
    cand = binning.table[nb_flat]  # (N, M, cap)
    mask = (cand >= 0) & nb_valid[:, :, None]
    npart = binning.cell_id.shape[0]
    cand = jnp.where(mask, cand, 0)
    return cand.reshape(npart, -1), mask.reshape(npart, -1)


# --------------------------------------------------------------------------
# Cell-packed ("spatially sorted") particle layout
# --------------------------------------------------------------------------
class CellPacking(NamedTuple):
    """Spatial-sort permutation + binning of the *packed* particle arrays.

    This is the persistent-pipeline layout (the paper's Thrust xy-sort
    locality optimization made stateful): all per-particle arrays are
    physically reordered by flat cell id, so particles sharing a cell are
    contiguous in memory and the cell table's gathers are near-contiguous.

    order:    (N,) int32, packed position -> original particle id.
    inverse:  (N,) int32, original particle id -> packed position.
    binning:  CellBinning over the PACKED arrays - ``binning.table`` holds
              packed indices (its own ``order`` is the identity), so a
              neighbor list built from it is in packed indexing.
    """

    order: Array
    inverse: Array
    binning: CellBinning

    @property
    def npart(self) -> int:
        return self.order.shape[0]

    def pack(self, x: Array) -> Array:
        """Reorder a per-particle array (original -> packed indexing)."""
        return x[self.order]

    def unpack(self, x: Array) -> Array:
        """Reorder a per-particle array (packed -> original indexing)."""
        return x[self.inverse]


def inverse_permutation(order: Array) -> Array:
    """Inverse of a permutation given as an int32 index array."""
    n = order.shape[0]
    inv = jnp.zeros((n,), jnp.int32)
    return inv.at[order].set(jnp.arange(n, dtype=jnp.int32))


def exclusive_cumsum(counts: Array) -> Array:
    """Exclusive prefix sum of per-cell counts: packed start of each cell."""
    return jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts).astype(jnp.int32)[:-1]]
    )


def _packed_table(n_total: int, counts: Array, capacity: int):
    """(C, cap) table of consecutive packed ids — pure arithmetic, no sort
    and no scatter.

    Packed ids are cell-sorted by construction, so cell c's occupants are
    exactly ``starts[c] .. starts[c] + counts[c] - 1``; slots past the
    occupancy (or past ``capacity``) are -1. Returns
    (table, starts, overflow).
    """
    starts = exclusive_cumsum(counts)
    slot = jnp.arange(capacity, dtype=jnp.int32)[None, :]
    occ = slot < jnp.minimum(counts, capacity)[:, None]
    table = jnp.where(occ, starts[:, None] + slot, -1)
    overflow = jnp.sum(jnp.maximum(counts - capacity, 0)).astype(jnp.int32)
    return table, starts, overflow


def _counting_sort_positions(
    domain: Domain,
    cell_id: Array,  # (N,) flat cell id per particle, current order
    cell_xy: Array,  # (N, d) per-axis cell coords
    prev_cell_id: Array,  # (N,) PREVIOUS flat cell id (non-decreasing)
    prev_counts: Array,  # (C,) previous per-cell occupancy
    prev_cell_xy: Array,  # (N, d) previous per-axis cell coords
) -> Array:
    """Stable counting-sort positions: bincount → exclusive scan → rank.

    Computes, for every particle, its slot under a STABLE sort by
    ``cell_id`` (ties broken by current array position) — the identical
    permutation to ``jnp.argsort(cell_id, stable=True)`` — in O(3^d · N)
    vectorized passes with no sort anywhere.

    The O(N) rank trick reuses the previous rebuild's near-sorted order:
    the current arrays are grouped by ``prev_cell_id`` (runs), and under
    the Verlet-skin invariant every particle's cell moved by at most one
    cell per axis (min-image) since then, so a particle's stable rank
    within its new cell splits into (a) whole earlier runs that sent
    particles to the same cell — a (C, 3^d) arrival histogram read — and
    (b) a within-run exclusive prefix count over the 3^d migration
    offsets — one cumsum per offset.

    PRECONDITION (guarded by the caller's ``lax.cond``): per-axis
    min-image cell deltas all in {-1, 0, 1}.
    """
    dim = domain.dim
    m = 3**dim
    c_total = domain.ncells_total
    offs = jnp.asarray(neighbor_cell_offsets(dim))  # (m, d)
    delta = domain.wrap_cell_delta(cell_xy - prev_cell_xy)  # (N, d)
    # Categorical migration-offset index, matching offs enumeration order.
    o = delta[:, 0] + 1
    for a in range(1, dim):
        o = o * 3 + (delta[:, a] + 1)
    # Arrival histogram: D[c, k] = particles that moved into cell c via
    # offset k. Row sums are the new per-cell counts.
    d_hist = jnp.bincount(
        cell_id * m + o, length=c_total * m
    ).astype(jnp.int32).reshape(c_total, m)
    # (a) whole-run term: arrivals into my new cell from strictly earlier
    # runs. Source run of offset k is src = wrap(new_xy - offs[k]).
    src = cell_xy[:, None, :] - offs[None, :, :]  # (N, m, d)
    n_ax = jnp.asarray(domain.ncells, dtype=jnp.int32)
    per = jnp.asarray(np.asarray(domain.periodic))
    wrapped = jnp.where(per, src % n_ax, src)
    valid = jnp.all((wrapped >= 0) & (wrapped < n_ax), axis=-1)  # (N, m)
    clipped = jnp.clip(wrapped, 0, n_ax - 1)
    src_flat = clipped[..., 0]
    for a in range(1, dim):
        src_flat = src_flat * domain.ncells[a] + clipped[..., a]
    g = prev_cell_id
    before = jnp.sum(
        jnp.where(valid & (src_flat < g[:, None]), d_hist[cell_id], 0), axis=1
    ).astype(jnp.int32)
    # (b) within-run term: earlier particles of MY run with my offset
    # (same run + same offset <=> same new cell, since runs share a
    # source cell and distinct offsets land in distinct cells).
    seg_start = exclusive_cumsum(prev_counts)[g]  # (N,)
    within = jnp.zeros_like(cell_id)
    for k in range(m):
        mk = (o == k).astype(jnp.int32)
        ex = jnp.cumsum(mk).astype(jnp.int32) - mk  # exclusive prefix
        within = within + jnp.where(o == k, ex - ex[seg_start], 0)
    starts_new = exclusive_cumsum(jnp.sum(d_hist, axis=1))
    return starts_new[cell_id] + before + within


def _argsort_positions(cell_id: Array) -> Array:
    """Oracle path: stable-argsort positions (new packed slot of each row)."""
    order = jnp.argsort(cell_id, stable=True).astype(jnp.int32)
    return inverse_permutation(order)


def pack_particles(
    domain: Domain,
    cell_id: Array,
    cell_xy: Array,
    capacity: int,
    prev: CellBinning | None = None,
) -> CellPacking:
    """Spatially sort particles by flat cell id and bin the sorted set.

    ``prev=None`` (cold start / unknown order) stable-argsorts: that IS
    the paper's locality sort, and because the sorted set becomes
    cell-contiguous the cell table holds consecutive packed indices
    (``table[c, s] = starts[c] + s``) built without any scatter.

    With ``prev`` — the binning of the order the input arrays are
    CURRENTLY in (the persistent pipeline's previous rebuild) — the sort
    is replaced by a counting-sort pack (bincount → exclusive scan →
    stable rank → one scatter): the previous near-sorted order bounds
    every migration to the 3^d cell neighborhood, making stable ranks an
    O(N) computation. A ``lax.cond`` falls back to the argsort oracle if
    any particle moved further (the permutation is identical either way).
    """
    npart = cell_id.shape[0]
    if prev is None:
        pos = _argsort_positions(cell_id)
    else:
        delta = domain.wrap_cell_delta(cell_xy - prev.cell_xy)
        adjacent = jnp.max(jnp.abs(delta)) <= 1
        pos = jax.lax.cond(
            adjacent,
            lambda args: _counting_sort_positions(domain, *args),
            lambda args: _argsort_positions(args[0]),
            (cell_id, cell_xy, prev.cell_id, prev.counts, prev.cell_xy),
        )
    inverse = pos
    order = jnp.zeros((npart,), jnp.int32).at[pos].set(
        jnp.arange(npart, dtype=jnp.int32)
    )
    counts = jnp.bincount(cell_id, length=domain.ncells_total).astype(
        jnp.int32
    )
    table, _, overflow = _packed_table(domain.ncells_total, counts, capacity)
    binning = CellBinning(
        table=table,
        counts=counts,
        cell_id=cell_id[order],
        cell_xy=cell_xy[order],
        order=jnp.arange(npart, dtype=jnp.int32),  # already cell-sorted
        overflow=overflow,
    )
    return CellPacking(order=order, inverse=inverse, binning=binning)


def to_cell_major(binning: CellBinning, x: Array, fill=0) -> Array:
    """Scatter a per-particle array into the lane-padded (C, cap, ...) layout.

    x: (N, ...) indexed the same way as ``binning.table``'s entries.
    Empty slots are filled with ``fill``.
    """
    safe = jnp.maximum(binning.table, 0)
    occ = binning.table >= 0
    out = x[safe]
    shape = occ.shape + (1,) * (out.ndim - 2)
    return jnp.where(occ.reshape(shape), out, fill)


def from_cell_major(binning: CellBinning, table_vals: Array) -> Array:
    """Gather per-particle values back out of a (C, cap, ...) table.

    Inverse of :func:`to_cell_major` for occupied slots. Requires no
    overflow (dropped particles have no slot to gather from).
    """
    n = binning.cell_id.shape[0]
    flat = table_vals.reshape((-1,) + table_vals.shape[2:])
    ids = binning.table.reshape(-1)
    tpos = jnp.arange(ids.shape[0], dtype=jnp.int32)
    safe_ids = jnp.where(ids >= 0, ids, n)  # empty slots -> dropped
    slot_of = jnp.zeros((n,), jnp.int32).at[safe_ids].set(tpos, mode="drop")
    return flat[slot_of]


def _shifted_zero(grid: Array, off: int, axis: int) -> Array:
    """Shift ``grid`` so out[i] = grid[i + off] along ``axis``, zero-filled."""
    if off == 0:
        return grid
    sl = [slice(None)] * grid.ndim
    pad = [(0, 0)] * grid.ndim
    if off > 0:
        sl[axis] = slice(off, None)
        pad[axis] = (0, off)
    else:
        sl[axis] = slice(None, off)
        pad[axis] = (-off, 0)
    return jnp.pad(grid[tuple(sl)], pad)


def max_neighborhood_occupancy(domain: Domain, counts: Array) -> Array:
    """Max over cells of the total 3^dim-neighborhood occupancy (traceable).

    This is the EXACT per-particle candidate-demand bound of the merged-
    window search (and an upper bound on any particle's true neighbor
    count): a particle in cell c can only see candidates in c's 3^dim
    neighborhood. The health guard's regrow escalation sizes ``window``
    and ``max_neighbors`` from this observed demand instead of blind
    doubling — one regrow recovers any truncation the current
    configuration can exhibit.
    """
    grid = counts.reshape(domain.ncells)
    total = jnp.zeros_like(grid)
    for off in neighbor_cell_offsets(domain.dim):
        g = grid
        for a, o in enumerate(off):
            if o == 0:
                continue
            if domain.periodic[a]:
                g = jnp.roll(g, -int(o), axis=a)
            else:
                g = _shifted_zero(g, int(o), axis=a)
        total = total + g
    return jnp.max(total)


def default_capacity(domain: Domain, n_particles: int, safety: float = 3.0) -> int:
    """Static per-cell capacity estimate: mean occupancy x safety, >= 4.

    Calibrated for particle sets that FILL the domain. A mostly-empty
    domain (free-surface cases: a dam-break column in a large tank)
    drags the mean far below the dense-region occupancy and silently
    drops particles — use :func:`dense_capacity` there.
    """
    mean = n_particles / max(1, domain.ncells_total)
    cap = int(np.ceil(mean * safety)) + 2
    return max(4, cap)


def dense_capacity(domain: Domain, ds: float, safety: float = 1.5) -> int:
    """Per-cell capacity for a CLOSE-PACKED region at lattice spacing ds.

    Upper-bounds a cell's occupancy by the lattice count of its largest
    edge plus one straddle row per axis, times a compression safety —
    independent of how much of the domain the fluid occupies.
    """
    edge = max(domain.cell_sizes) / ds + 1.0
    return max(4, int(np.ceil(edge**domain.dim * safety)))


def robust_capacity(domain: Domain, ds: float, n_particles: int) -> int:
    """THE per-cell capacity rule for solver configs (single source).

    The larger of the two estimates: :func:`default_capacity` (domain-
    mean occupancy x 3 — right for domain-filling flows, catastrophic
    for mostly-empty ones) and :func:`dense_capacity` (the close-packed
    lattice bound at spacing ``ds`` — right for free-surface cases like
    the dam break, whose dense column sits in a mostly-empty tank).
    Taking the max means a new case cannot silently re-introduce the
    dam-break under-sizing by forgetting to pick the dense estimate;
    for the shipped domain-filling cases the mean estimate dominates,
    so their capacities are unchanged.
    """
    return max(
        default_capacity(domain, n_particles), dense_capacity(domain, ds)
    )
