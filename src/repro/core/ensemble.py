"""Fault-isolated batched ensemble engine: one device, many simulations.

The ROADMAP's serving direction needs one accelerator to step MANY
simulations at once (parameter sweeps, perturbed ensembles, campaign
runs). PR 6's health guard made a SINGLE run self-healing, but its
all-or-nothing rollback is wrong for a batch: one diverged member must
not roll back — or recompile, or replay — the other B-1. This module
builds the batched engine with MEMBER-level fault isolation:

  * B same-shape members are stacked into one batch-leading
    :class:`solver.PersistentCarry` and advanced by ONE donated jitted
    block program (``_ensemble_block``): a vmapped static-cadence
    rebuild at block entry, ``block`` vmapped physics steps under
    per-member masks, then ``health.check_batch`` — every member gets
    its OWN HealthWord + attribution stats from the same fused
    reduction, and the driver pays a single device→host sync per block
    for the whole batch.

  * Per-member escalation runs the PR 6 ladder as MASKED LANES: a
    tripped member is rolled back to its own last-healthy snapshot
    (a per-row host splice; other rows pass through bit-exact) and
    retried with its fault disarmed or its dt halved — both ride
    dynamic (B,) lane vectors (``armed``, ``dt_scale``), so healthy
    members never recompile, never replay, and never see a changed
    program. Config-changing rungs (capacity/window regrow, record
    degrade) cannot be masked — those members are EVICTED to a solo
    ``recovery.run_guarded`` probation run and either re-admitted
    (shape-compatible recovery: splice back at a block boundary) or
    completed solo / permanently quarantined, with a structured
    :class:`MemberReport` either way.

  * The hard guarantee: members that never trip are BIT-IDENTICAL to
    their solo unguarded runs under :func:`member_config` (the same
    config with the ensemble's static rebuild cadence). Masking is
    pure ``jnp.where`` lane selection — selected bits pass through
    exactly — and the per-member dt rides the solver's traced-dt
    path multiplied by an exact 1.0 for healthy lanes.

  * Durability: the per-member last-healthy snapshot batch IS the
    checkpoint payload — written through ``CheckpointManager`` at
    block boundaries together with the lane vectors, so a sweep killed
    mid-run (SIGKILL, OOM) resumes from the latest valid checkpoint
    and finishes bit-identical to the uninterrupted run. The seed's
    ``runtime.fault_tolerance`` StragglerWatchdog/HeartbeatWriter wire
    into the block loop: anomalously slow blocks are flagged and a
    dead predecessor process is detected at resume time, both reported
    in the :class:`EnsembleReport`.

Cadence note: the batched block can only rebuild at block entry (a
``lax.cond`` under vmap would execute BOTH branches every step for
every member), so ensemble members run the solver's STATIC rebuild
cadence ``rebuild_every = policy.block``. With ``skin == 0`` the
neighbor list is stale between rebuilds — size a Verlet skin for the
cadence (``cfg.validate_skin`` enforces this) or keep blocks short.
"""
from __future__ import annotations

import dataclasses
import logging
import os
import time
from functools import partial

import numpy as np

import jax
import jax.numpy as jnp

from repro.core import health, recovery, solver
from repro.core.recovery import GuardPolicy
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    HeartbeatWriter,
    StragglerWatchdog,
)

log = logging.getLogger("repro.ensemble")

Array = jnp.ndarray

# Member status lifecycle (host-side ints so they checkpoint as a (B,)
# vector): HEALTHY -> RECOVERED on any in-batch masked-lane recovery;
# EVICTED lanes leave the batch for a solo guarded run (completed at
# sweep end), READMITTED ones splice back in; QUARANTINED is terminal.
HEALTHY, RECOVERED, EVICTED, READMITTED, QUARANTINED = range(5)
STATUS_NAMES = ("healthy", "recovered", "evicted", "readmitted",
                "quarantined")


@dataclasses.dataclass
class MemberReport:
    """Per-member outcome of an ensemble run (host-side record)."""

    member: int
    status: str  # one of STATUS_NAMES
    steps: int  # steps of trajectory in the returned final state
    events: list  # in-batch GuardEvents (rollback/disarm/halve_dt/evict)
    retries: int = 0
    dt_halvings: int = 0
    dt_scale: float = 1.0
    solo_report: recovery.GuardReport | None = None  # eviction leg
    error: health.SimulationDiverged | None = None  # quarantine cause


@dataclasses.dataclass
class EnsembleReport:
    """What a batched guarded run did, member by member."""

    cfg: solver.SPHConfig  # the shared (batch) config
    members: list  # list[MemberReport], index == member
    blocks: int = 0  # ensemble block programs executed
    slow_blocks: int = 0  # straggler watchdog trips
    straggler_flagged: bool = False  # persistent straggler
    resumed_from: int | None = None  # checkpoint block index, if resumed
    dead_process_detected: bool = False  # stale heartbeat found at resume
    # How the previous owner of the checkpoint dir exited, judged from
    # its heartbeat file at resume time: "dead" (stale file left behind
    # — SIGKILL/OOM), "clean" (file removed on exit, checkpoints
    # present), or None (not a resume / nothing to judge).
    predecessor: str | None = None

    @property
    def healthy(self) -> int:
        return sum(1 for m in self.members if m.status == "healthy")

    def counts(self) -> dict:
        out = {name: 0 for name in STATUS_NAMES}
        for m in self.members:
            out[m.status] += 1
        return out


def member_config(cfg: solver.SPHConfig, policy: GuardPolicy | None = None
                  ) -> solver.SPHConfig:
    """The solo-equivalent config of an ensemble member.

    The batched block rebuilds at block entry only, i.e. the static
    cadence ``rebuild_every = policy.block`` — healthy members are
    bit-identical to a solo unguarded run under THIS config (it is also
    the config the eviction path hands to ``run_guarded``, so cadence
    stays aligned across evict/re-admit). An explicit conflicting
    ``rebuild_every`` is rejected rather than silently overridden.
    """
    policy = policy or GuardPolicy()
    if cfg.algo != "rcll":
        raise ValueError("ensemble runs require the persistent rcll pipeline")
    if cfg.rebuild_every is not None and cfg.rebuild_every != policy.block:
        raise ValueError(
            f"cfg.rebuild_every={cfg.rebuild_every} conflicts with the "
            f"ensemble cadence policy.block={policy.block}; leave it None "
            "or match the block length"
        )
    return dataclasses.replace(cfg, rebuild_every=policy.block, fault=None)


def stack_states(states) -> solver.SPHState:
    """Stack same-shape member states into one batch-leading SPHState."""
    states = list(states)
    if not states:
        raise ValueError("empty ensemble")
    try:
        return jax.tree.map(lambda *xs: jnp.stack(xs), *states)
    except ValueError as e:
        raise ValueError(
            "ensemble members must share array shapes and pytree "
            f"structure (same case family / particle count): {e}"
        ) from e


def _select_members(pred: Array, a, b):
    """Per-member lane select over a batch-leading pytree.

    ``pred`` is (B,); every leaf broadcasts it across its trailing
    axes. Where the predicate is False the output leaf row is ``b``'s
    row BIT-EXACTLY (select passes bits through) — this is what keeps
    masked recovery invisible to healthy members.
    """
    def sel(x, y):
        p = pred.reshape(pred.shape + (1,) * (x.ndim - 1))
        return jnp.where(p, x, y)

    return jax.tree.map(sel, a, b)


@partial(jax.jit, static_argnums=(0, 3, 4, 5, 6), donate_argnums=(1,))
def _ensemble_block(
    cfg: solver.SPHConfig,
    carry: solver.PersistentCarry,
    lanes,
    nsteps: int,
    policy: GuardPolicy,
    fault,
    observe: bool = False,
):
    """One donated batched guarded block.

    ``lanes = (dt_scale, armed, active, target)`` — dynamic (B,)
    vectors, NOT donated, so per-member recovery (disarm a fault, halve
    a dt), admission and retirement never change the compiled program.
    ``target`` is the per-lane step target (the serving layer admits
    requests of different lengths into one batch); frozen members
    (inactive, or already at their target) pass through every step
    bit-exactly under the lane select. Ordering per step matches
    ``solver.step_persistent``: inject -> rebuild-if-due -> physics;
    rebuild can only be due at block entry (members sit on
    block-aligned step counts), so it is hoisted out of the scan — a
    ``lax.cond`` under vmap would run the rebuild EVERY step for EVERY
    member. ``observe`` additionally returns one per-lane observable
    row (t, ekin, vmax, rho_err) from the block-exit state.
    """
    dt_scale, armed, active, target = lanes
    dt = jnp.float32(cfg.dt) * dt_scale  # exact for healthy lanes (x1.0)

    if carry.flags is not None:
        carry = carry._replace(flags=jnp.zeros_like(carry.flags))

    def inject(c, live):
        if fault is None:
            return c
        hit = jax.vmap(lambda ci: health.inject_fault(fault, ci))(c)
        return _select_members(armed & live, hit, c)

    live0 = active & (carry.steps < target)
    carry = inject(carry, live0)
    due = live0 & jax.vmap(lambda c: solver._needs_rebuild(cfg, c))(carry)
    rebuilt = jax.vmap(lambda c: solver._rebuild(cfg, c))(carry)
    carry = _select_members(due, rebuilt, carry)

    def physics(c):
        live = active & (c.steps < target)
        stepped = jax.vmap(
            lambda ci, di: solver._physics_step(cfg, ci, di)
        )(c, dt)
        return _select_members(live, stepped, c)

    carry = physics(carry)  # block entry step (already injected above)

    def body(c, _):
        live = active & (c.steps < target)
        return physics(inject(c, live)), None

    carry, _ = jax.lax.scan(body, carry, None, length=nsteps - 1)

    hw = health.check_batch(
        cfg, carry, rho_dev_limit=policy.rho_dev_limit,
        cfl_limit=policy.cfl_limit, enabled=policy.checks, dt=dt,
    )
    obs = (
        jax.vmap(lambda c: health.observe_state(cfg, c.st))(carry)
        if observe else ()
    )
    return carry, hw, obs


@partial(jax.jit, static_argnums=(0,))
def _batch_init(cfg: solver.SPHConfig, states: solver.SPHState):
    return jax.vmap(lambda s: solver.init_persistent(cfg, s))(states)


@partial(jax.jit, static_argnums=(0, 2))
def _batch_check(cfg, carry, policy: GuardPolicy):
    """Step-0 batched health word (init-time overflow; no donation)."""
    return health.check_batch(
        cfg, carry, rho_dev_limit=policy.rho_dev_limit,
        cfl_limit=policy.cfl_limit, enabled=policy.checks,
    )


@partial(jax.jit, static_argnums=(0,))
def _batch_finalize(cfg: solver.SPHConfig, carry):
    return jax.vmap(lambda c: solver.finalize_persistent(cfg, c))(carry)


def _lane(tree, i):
    """Row ``i`` of a batch-leading pytree (host or device)."""
    return jax.tree.map(lambda x: x[i], tree)


def _splice_lane(carry, i: int, lane):
    """Write solo-carry ``lane`` into batch row ``i`` (eager: fresh
    buffers, never aliases into the next donated block call)."""
    return jax.tree.map(
        lambda d, s: d.at[i].set(jnp.asarray(s)), carry, lane
    )


def _update_snapshot(snap, host, mask: np.ndarray):
    """Refresh the per-member host snapshot rows where ``mask``."""
    if not mask.any():
        return snap
    def upd(s, h):
        out = np.array(s)
        out[mask] = h[mask]
        return out
    return jax.tree.map(upd, snap, host)


def _hw_member(hw, i) -> dict:
    """Host stats dict of member ``i`` of a batched HealthWord."""
    return {
        "vmax": float(np.asarray(hw.vmax)[i]),
        "rho_dev": float(np.asarray(hw.rho_dev)[i]),
        "cfl": float(np.asarray(hw.cfl)[i]),
        "bad_x": int(np.asarray(hw.bad_x)[i]),
        "bad_v": int(np.asarray(hw.bad_v)[i]),
        "bad_rho": int(np.asarray(hw.bad_rho)[i]),
        "max_count": int(np.asarray(hw.max_count)[i]),
        "max_cell": int(np.asarray(hw.max_cell)[i]),
    }


def _rekey_fault(fault: health.FaultSpec | None, offset: int):
    """Shift a step-keyed fault into a solo run's restarted counter."""
    if fault is None:
        return None
    step = fault.step - offset
    if step < 0:
        return None  # already fired (and was recovered) before eviction
    return dataclasses.replace(fault, step=step)


# Solo probation length (in blocks) before an evicted member is either
# re-admitted to the batch or left to finish solo.
READMIT_BLOCKS = 4


def run_ensemble(
    cfg: solver.SPHConfig,
    states,
    nsteps: int,
    policy: GuardPolicy | None = None,
    *,
    fault: health.FaultSpec | None = None,
    fault_members=(),
    checkpoint=None,
    checkpoint_every: int = 0,
    resume: bool = False,
    heartbeat_timeout_s: float = 60.0,
):
    """Advance B member states ``nsteps`` guarded steps as one batch.

    Returns ``(states, stats, report)`` — per-member final SPHStates
    (original indexing), per-member :class:`solver.SimStats`, and the
    :class:`EnsembleReport`. Unlike ``run_guarded`` this NEVER raises
    :class:`SimulationDiverged`: a member that exhausts recovery is
    quarantined (its report carries the structured error and its state
    is returned at its last healthy step) while the rest of the batch
    finishes untouched.

    ``fault`` arms one deterministic FaultSpec on the members listed in
    ``fault_members`` (every member if empty) — lane-masked, so
    disarming it recovers ONE member without touching the compiled
    program. A fault already armed on ``cfg.fault`` is adopted the same
    way.

    ``checkpoint`` (a CheckpointManager) + ``checkpoint_every`` (in
    blocks) persist the per-member snapshot batch and lane vectors at
    block boundaries; ``resume=True`` restores the latest VALID
    checkpoint — the continuation is bit-identical to the uninterrupted
    run because the snapshot batch is the driver's only mutable state.
    Eviction legs are deferred to the end of the batch loop and
    re-derived from the snapshot, so a crash during (or before) them
    resumes without loss; per-member event lists from before the crash
    are not replayed (statuses and lane vectors are).
    """
    policy = policy or GuardPolicy()
    if cfg.fault is not None and fault is None:
        fault = cfg.fault
    cfg = member_config(cfg, policy)
    states = list(states)
    B = len(states)
    batch0 = stack_states(states)
    del states

    armed0 = np.zeros(B, bool)
    if fault is not None:
        members = tuple(fault_members)
        armed0[list(members) if members else slice(None)] = True

    carry = _batch_init(cfg, batch0)
    # Like run_guarded: the batched init aliases the stacked t scalar;
    # sever it so donated blocks never invalidate the caller's states.
    carry = carry._replace(st=carry.st._replace(t=jnp.copy(carry.st.t)))

    # ---- driver state (the checkpoint payload) ------------------------
    snap = recovery._host_snapshot(carry)
    meta = {
        "dt_scale": np.ones(B, np.float32),
        "armed": armed0,
        "active": np.ones(B, bool),
        "halvings": np.zeros(B, np.int32),
        "retries": np.zeros(B, np.int32),
        "status": np.full(B, HEALTHY, np.int32),
        "snap_steps": np.zeros(B, np.int64),
        "blocks": np.zeros((), np.int64),
    }
    events: list[list] = [[] for _ in range(B)]
    errors: dict[int, health.SimulationDiverged] = {}
    solo_reports: dict[int, recovery.GuardReport] = {}
    report = EnsembleReport(cfg=cfg, members=[])

    watchdog = StragglerWatchdog()
    hb = None
    if checkpoint is not None:
        if resume:
            # A heartbeat file with no live writer = the previous sweep
            # process died (SIGKILL / OOM); a CLEAN exit removes the
            # file (HeartbeatWriter.clear), so "absent with checkpoints
            # present" means the predecessor shut down in good order.
            monitor = HeartbeatMonitor(
                checkpoint.dir, timeout_s=heartbeat_timeout_s)
            status = monitor.host_status(0)
            if status == "dead":
                report.dead_process_detected = True
                report.predecessor = "dead"
                log.warning(
                    "ensemble: stale heartbeat in %s — previous sweep "
                    "process died; resuming from latest checkpoint",
                    checkpoint.dir,
                )
            elif status == "absent" and checkpoint.latest_step() is not None:
                report.predecessor = "clean"
            restored, ck_step = checkpoint.restore(
                {"carry": snap, "meta": meta})
            if restored is not None:
                snap, meta = restored["carry"], restored["meta"]
                carry = recovery._to_device(snap)
                report.resumed_from = int(ck_step)
                log.warning(
                    "ensemble: resumed from checkpoint block %d "
                    "(member steps %s)", int(ck_step),
                    meta["snap_steps"].tolist(),
                )
        hb = HeartbeatWriter(checkpoint.dir, host_id=0)

    dt_scale, armed = meta["dt_scale"], meta["armed"]
    active, halvings = meta["active"], meta["halvings"]
    retries, status = meta["retries"], meta["status"]
    snap_steps = meta["snap_steps"]
    cur_steps = snap_steps.copy()

    hw_member = _hw_member

    def record(i, word, stats, action, detail):
        ev = recovery.GuardEvent(
            step=int(snap_steps[i]), word=int(word),
            checks=health.check_names(int(word)), action=action,
            detail=detail, stats=stats,
        )
        events[i].append(ev)
        log.warning(
            "ensemble member %d tripped %s at step %d: %s — %s",
            i, ev.checks, ev.step, action, detail,
        )
        return ev

    def rollback(i):
        nonlocal carry
        carry = _splice_lane(carry, i, _lane(snap, i))
        cur_steps[i] = snap_steps[i]

    def solo_cfg(i):
        f = _rekey_fault(fault, int(snap_steps[i])) if armed[i] else None
        return dataclasses.replace(
            cfg, dt=float(cfg.dt * dt_scale[i]), fault=f)

    def try_readmit(i):
        """Solo probation leg straight after an eviction: if the member
        recovers under shape-compatible rungs only (disarm / dt halve),
        splice it back into the batch at the next block boundary."""
        nonlocal carry, snap
        remaining = int(nsteps - snap_steps[i])
        probe = policy.block * READMIT_BLOCKS
        if probe >= remaining:
            return  # too close to the end: just finish solo
        lane = recovery._to_device(_lane(snap, i))
        state_i = solver.finalize_persistent(cfg, lane)
        try:
            st1, stats1, rep1, _ = recovery.run_guarded(
                solo_cfg(i), state_i, probe, policy)
        except health.SimulationDiverged as e:
            errors[i] = e
            status[i] = QUARANTINED
            record(i, e.word, e.stats, "quarantine",
                   f"solo probation diverged: {e}")
            return
        if not recovery._dt_equivalent(cfg, rep1.cfg):
            solo_reports[i] = rep1
            log.warning(
                "ensemble member %d: probation recovery changed shapes "
                "(%s); completing solo", i,
                "; ".join(ev.action for ev in rep1.events),
            )
            return
        lane2 = solver.init_persistent(cfg, st1)
        if int(np.asarray(recovery._check_init(cfg, lane2, policy).word)):
            solo_reports[i] = rep1
            return  # still unhealthy under the batch config: stay solo
        new_steps = int(snap_steps[i]) + probe
        lane2 = lane2._replace(
            steps=jnp.asarray(new_steps, jnp.int32),
            rebuilds=lane2.rebuilds + jnp.asarray(lane.rebuilds)
            + jnp.asarray(stats1.rebuilds),
        )
        carry = _splice_lane(carry, i, lane2)

        def set_row(s, h):
            out = np.array(s)
            out[i] = np.asarray(h)
            return out

        snap = jax.tree.map(set_row, snap, lane2)
        snap_steps[i] = cur_steps[i] = new_steps
        dt_scale[i] = np.float32(rep1.cfg.dt / cfg.dt)
        halvings[i] += rep1.dt_halvings
        armed[i] = bool(
            rep1.cfg.fault is not None and fault is not None
            and fault.step >= new_steps
        )
        status[i], active[i] = READMITTED, True
        solo_reports[i] = rep1
        record(i, 0, {}, "readmit",
               f"solo probation ({probe} steps) recovered with "
               "shape-compatible actions "
               f"[{', '.join(ev.action for ev in rep1.events)}]; "
               f"re-admitted to the batch at step {new_steps}")

    def run_solo(i):
        """Deferred eviction leg: finish the member solo from its last
        healthy snapshot (deterministically re-derivable on resume)."""
        lane = recovery._to_device(_lane(snap, i))
        state_i = solver.finalize_persistent(cfg, lane)
        remaining = int(nsteps - snap_steps[i])
        try:
            st, stats, rep, _ = recovery.run_guarded(
                solo_cfg(i), state_i, remaining, policy)
        except health.SimulationDiverged as e:
            errors[i] = e
            status[i] = QUARANTINED
            record(i, e.word, e.stats, "quarantine",
                   f"solo continuation diverged: {e}")
            return None
        solo_reports[i] = rep
        return st, stats

    # ---- step-0 check: init-time capacity overflow etc. ---------------
    if report.resumed_from is None:
        hw0 = _batch_check(cfg, carry, policy)
        words0 = np.asarray(hw0.word)
        for i in np.nonzero(words0)[0]:
            # No step has run, so no masked rung applies — evict. The
            # solo run_guarded regrows capacity (or raises) per member.
            status[i], active[i] = EVICTED, False
            record(i, int(words0[i]), hw_member(hw0, i), "evict",
                   "init-time health trip; deferring to solo guarded run")

    # ---- batched block loop -------------------------------------------
    target_vec = jnp.full(B, nsteps, jnp.int32)
    while np.any(active & (cur_steps < nsteps)):
        lanes = (jnp.asarray(dt_scale), jnp.asarray(armed),
                 jnp.asarray(active), target_vec)
        stepped = active & (cur_steps < nsteps)
        t0 = time.perf_counter()
        carry, hw, _ = _ensemble_block(
            cfg, carry, lanes, max(1, policy.block), policy, fault
        )
        words = np.asarray(hw.word)  # the one per-block host sync
        wall = time.perf_counter() - t0
        meta["blocks"] += 1
        report.blocks += 1
        if watchdog.observe(wall):
            report.slow_blocks += 1
        report.straggler_flagged = watchdog.flagged
        if hb is not None:
            hb.beat(int(meta["blocks"]))

        steps_np = np.asarray(carry.steps)
        cur_steps[:] = np.where(stepped, steps_np, cur_steps)
        tripped = stepped & (words != 0)

        for i in np.nonzero(tripped)[0]:
            word = int(words[i])
            stats_i = hw_member(hw, i)
            retries[i] += 1
            if policy.strict:
                errors[i] = health.SimulationDiverged(
                    f"member {i}: health guard (strict) tripped "
                    f"{health.check_names(word)} at step "
                    f"{int(snap_steps[i])}",
                    step=int(snap_steps[i]),
                    checks=health.check_names(word), word=word,
                    stats=stats_i, events=events[i],
                )
                status[i], active[i] = QUARANTINED, False
                record(i, word, stats_i, "quarantine", "strict policy")
                rollback(i)
                continue
            if armed[i] and policy.disarm_faults:
                armed[i] = False
                record(i, word, stats_i, "disarm",
                       f"stripped injected fault for member {i}; "
                       f"replaying block from step {int(snap_steps[i])} "
                       "(lane-masked, no recompile)")
                rollback(i)
                if status[i] == HEALTHY:
                    status[i] = RECOVERED
                continue
            if (word & health.NUMERIC_CHECKS
                    and halvings[i] < policy.max_dt_halvings):
                halvings[i] += 1
                dt_scale[i] *= 0.5
                record(i, word, stats_i, "halve_dt",
                       f"member dt scale -> {dt_scale[i]:g} (backoff "
                       f"{int(halvings[i])}/{policy.max_dt_halvings}; "
                       "lane-masked, no recompile)")
                rollback(i)
                if status[i] == HEALTHY:
                    status[i] = RECOVERED
                continue
            # Config-changing rungs (capacity/window regrow, record
            # degrade, dt exhaustion) cannot ride a lane mask — evict,
            # then try to re-admit after a solo probation.
            status[i], active[i] = EVICTED, False
            record(i, word, stats_i, "evict",
                   "masked rungs exhausted or capacity trip; evicting "
                   "member to a solo guarded run")
            rollback(i)
            try_readmit(i)

        healthy = stepped & (words == 0)
        if healthy.any() and (
                int(meta["blocks"]) % max(1, policy.snapshot_every) == 0):
            host = jax.tree.map(np.asarray, carry)
            snap = _update_snapshot(snap, host, healthy)
            snap_steps[healthy] = steps_np[healthy]
            if (checkpoint is not None and checkpoint_every
                    and int(meta["blocks"]) % checkpoint_every == 0):
                checkpoint.save(
                    int(meta["blocks"]), {"carry": snap, "meta": meta},
                    blocking=False,
                )

    # A failed async save must never be silently dropped — join (and
    # surface any deferred error) before leaving the loop.
    if checkpoint is not None:
        checkpoint.wait()
    if hb is not None:
        # Clean exit removes the heartbeat file: a later resume must be
        # able to tell "predecessor shut down" from "predecessor died".
        hb.clear()

    # ---- deferred eviction legs ---------------------------------------
    solo_out: dict[int, tuple] = {}
    for i in range(B):
        if status[i] == EVICTED:
            out = run_solo(i)
            if out is not None:
                solo_out[i] = out

    # ---- assemble results ---------------------------------------------
    fin = _batch_finalize(cfg, carry)
    steps_np = np.asarray(carry.steps)
    rebuilds_np = np.asarray(carry.rebuilds)
    overflow_np = np.asarray(carry.overflow)
    out_states, out_stats = [], []
    for i in range(B):
        if i in solo_out:
            st, stats = solo_out[i]
            out_states.append(st)
            out_stats.append(stats)
            final_steps = int(nsteps)
        elif status[i] == QUARANTINED:
            # last healthy trajectory point, from the snapshot
            lane = recovery._to_device(_lane(snap, i))
            out_states.append(solver.finalize_persistent(cfg, lane))
            out_stats.append(solver.SimStats(
                rebuilds=lane.rebuilds, steps=lane.steps,
                overflow=lane.overflow))
            final_steps = int(snap_steps[i])
        else:
            out_states.append(_lane(fin, i))
            out_stats.append(solver.SimStats(
                rebuilds=rebuilds_np[i], steps=steps_np[i],
                overflow=overflow_np[i]))
            final_steps = int(steps_np[i])
        report.members.append(MemberReport(
            member=i, status=STATUS_NAMES[int(status[i])],
            steps=final_steps, events=events[i],
            retries=int(retries[i]), dt_halvings=int(halvings[i]),
            dt_scale=float(dt_scale[i]),
            solo_report=solo_reports.get(i), error=errors.get(i),
        ))
    return out_states, out_stats, report


# --------------------------------------------------------------------------
# Live lane engine: standby-slot admission / retirement over ONE program
# --------------------------------------------------------------------------
class EngineFull(RuntimeError):
    """No free lane: the caller should queue or shed the request."""


class FaultBusy(RuntimeError):
    """The engine's static FaultSpec slot is held by live armed lanes;
    admitting a request with a DIFFERENT fault would recompile under
    them. The caller should re-queue until the armed lanes drain."""


class AdmissionError(RuntimeError):
    """A request failed its init-time health check (e.g. the admission
    rebuild overflowed an undersized capacity) — structured so a server
    can reply with the tripped checks instead of admitting a lane that
    is known-bad before its first step."""

    def __init__(self, word: int, stats: dict):
        checks = health.check_names(word)
        super().__init__(
            f"request failed init-time health checks {checks}: {stats}")
        self.word = int(word)
        self.checks = checks
        self.stats = dict(stats)


@dataclasses.dataclass
class LaneEvent:
    """One per-lane outcome of a :meth:`LaneEngine.step_block` call."""

    lane: int
    kind: str  # "obs" | "recovered" | "done" | "diverged"
    step: int  # lane step count the event refers to
    obs: dict | None = None  # observable row (kind "obs"/"done")
    action: str | None = None  # recovery rung taken (kind "recovered")
    detail: str = ""
    word: int = 0
    checks: tuple = ()
    stats: dict | None = None
    state: object | None = None  # finalized SPHState (kind "done")
    events: list | None = None  # lane GuardEvents (kind "done"/"diverged")


class LaneEngine:
    """Standby-slot live batch: one compiled block program, ``slots``
    lanes, requests admitted and retired at block boundaries.

    The serving counterpart of :func:`run_ensemble`: instead of a fixed
    member list advanced to one shared target, the engine keeps a fixed
    batch WIDTH whose lanes are individually occupied by requests.
    Free lanes sit inactive (masked — every step passes their bits
    through unchanged, a ``dt_scale=0``-style no-op that costs no
    recompile), :meth:`admit` warm-starts a request on a free lane
    (solo ``init_persistent`` + an eager row splice: neighbors' buffers
    are rebuilt by the splice but their VALUES pass through bit-exact),
    and completion / divergence / retirement frees the slot the same
    way. Because per-lane step targets ride a traced ``(B,)`` vector,
    admitting a 64-step request next to a half-finished 512-step one
    never recompiles.

    Health is the PR 6/7 ladder restricted to its MASKED rungs —
    disarm-fault and per-lane dt backoff (rollback to the lane's own
    last-healthy snapshot; other lanes pass through bit-exact). The
    config-changing rungs (capacity/window regrow, record degrade)
    cannot ride a lane mask; a lane that needs them is reported
    ``diverged`` with the structured word/stats and its slot is freed —
    a serving layer sheds that request rather than recompiling under
    its neighbors. Healthy lanes are bit-identical to solo runs under
    :func:`member_config` (the run_ensemble guarantee, test-enforced).

    One FaultSpec at a time: the fault is a static argument of the
    block program, so the engine holds a single spec, re-armable per
    lane. Admitting a different spec while armed lanes are live raises
    :class:`FaultBusy` (re-queue); once no lane is armed the spec may
    be replaced (one recompile, loud log).
    """

    def __init__(self, cfg: solver.SPHConfig, slots: int,
                 policy: GuardPolicy | None = None):
        self.policy = policy or GuardPolicy()
        self.cfg = member_config(cfg, self.policy)
        self.slots = int(slots)
        if self.slots < 1:
            raise ValueError("LaneEngine needs at least one slot")
        self.fault: health.FaultSpec | None = None
        B = self.slots
        self.carry = None  # batch carry, built lazily at first admit
        self.snap = None  # per-lane last-healthy host snapshot rows
        self.dt_scale = np.ones(B, np.float32)
        self.armed = np.zeros(B, bool)
        self.disarmable = np.ones(B, bool)
        self.active = np.zeros(B, bool)
        self.target = np.zeros(B, np.int64)
        self.halvings = np.zeros(B, np.int32)
        self.retries = np.zeros(B, np.int32)
        self.snap_steps = np.zeros(B, np.int64)
        self.lane_events: list[list] = [[] for _ in range(B)]
        self.blocks = 0
        # lanes whose snapshot/ladder-meta changed since take_dirty():
        # the continuous per-block checkpoint work list (serve workers)
        self.dirty: set[int] = set()

    # ---- introspection ------------------------------------------------
    @property
    def free_lanes(self) -> list[int]:
        return [i for i in range(self.slots) if not self.active[i]]

    @property
    def live_lanes(self) -> list[int]:
        return [i for i in range(self.slots) if self.active[i]]

    # ---- admission / retirement ---------------------------------------
    def _ensure_batch(self, carry0):
        if self.carry is None:
            self.carry = jax.tree.map(
                lambda x: jnp.stack([x] * self.slots), carry0)
            self.snap = recovery._host_snapshot(self.carry)

    def _set_fault(self, fault: health.FaultSpec | None):
        if fault is None or fault == self.fault:
            return
        if any(self.armed[i] for i in self.live_lanes):
            raise FaultBusy(
                f"engine fault slot holds {self.fault} with armed live "
                f"lanes; cannot admit {fault} without recompiling them")
        if self.fault is not None:
            log.warning(
                "lane engine: replacing static fault %s -> %s "
                "(recompiles the block program)", self.fault, fault)
        self.fault = fault

    def admit(
        self,
        state: solver.SPHState | None,
        nsteps: int,
        *,
        fault: health.FaultSpec | None = None,
        disarmable: bool = True,
        dt_scale: float = 1.0,
        halvings: int = 0,
        carry_row=None,
        steps_done: int = 0,
    ) -> int:
        """Warm-start a request on a free lane; returns the lane index.

        ``state`` is a fresh SPHState (same shapes as every other lane —
        the bucket invariant); ``carry_row`` instead splices a raw host
        carry snapshot (the drain/resume path: bit-identical
        continuation from a checkpointed lane, ``steps_done`` of its
        ``nsteps`` already taken). ``fault`` arms the engine's
        FaultSpec on this lane; ``disarmable=False`` models a
        poisoned request payload (the server cannot "fix" the client's
        own poison, so the disarm rung is skipped and the ladder runs
        dt backoff straight to a structured divergence).

        Raises :class:`EngineFull` (no free lane — queue or shed),
        :class:`FaultBusy` (static fault slot held), or
        :class:`AdmissionError` (init-time health trip).
        """
        free = self.free_lanes
        if not free:
            raise EngineFull(f"all {self.slots} lanes busy")
        self._set_fault(fault)
        i = free[0]
        if carry_row is not None:
            carry0 = recovery._to_device(carry_row)
        else:
            carry0 = solver.init_persistent(self.cfg, state)
            # Sever the ``t`` alias (init_persistent passes it through
            # un-gathered): the donated block must never invalidate the
            # caller's state.
            carry0 = carry0._replace(
                st=carry0.st._replace(t=jnp.copy(carry0.st.t)))
            hw0 = recovery._check_init(self.cfg, carry0, self.policy)
            word0 = int(np.asarray(hw0.word))
            if word0:
                raise AdmissionError(word0, hw0.host_stats())
        self._ensure_batch(carry0)
        self.carry = _splice_lane(self.carry, i, carry0)
        row = jax.tree.map(np.asarray, carry0)

        def set_row(s, h):
            out = np.array(s)
            out[i] = h
            return out

        self.snap = jax.tree.map(set_row, self.snap, row)
        self.snap_steps[i] = int(steps_done)
        self.dt_scale[i] = np.float32(dt_scale)
        self.armed[i] = fault is not None
        self.disarmable[i] = bool(disarmable)
        self.active[i] = True
        self.target[i] = int(nsteps)
        self.halvings[i] = int(halvings)
        self.retries[i] = 0
        self.lane_events[i] = []
        self.dirty.add(i)
        return i

    def retire(self, lane: int):
        """Free a slot (cancellation / deadline expiry). The lane's
        rows stay in the batch as frozen bits until the next admission
        overwrites them — retirement itself touches no device buffer,
        so neighbors are untouched by construction."""
        self.active[lane] = False
        self.armed[lane] = False
        self.dirty.discard(lane)

    def take_dirty(self) -> list[int]:
        """Drain the set of lanes whose last-healthy snapshot (or
        ladder meta: dt_scale/halvings/armed) moved since the previous
        call. A serving worker checkpoints exactly these lanes after
        each block, so a crash loses at most one block of progress;
        retired/done lanes are dropped from the set (their checkpoint
        dirs are deleted, not refreshed)."""
        out = sorted(self.dirty)
        self.dirty.clear()
        return out

    def lane_snapshot(self, lane: int):
        """(host carry row, meta) at the lane's last healthy block
        boundary — the drain checkpoint payload. Resume by passing the
        row back to :meth:`admit` as ``carry_row``."""
        return _lane(self.snap, lane), {
            "steps_done": int(self.snap_steps[lane]),
            "target": int(self.target[lane]),
            "dt_scale": float(self.dt_scale[lane]),
            "halvings": int(self.halvings[lane]),
            "armed": bool(self.armed[lane]),
            "disarmable": bool(self.disarmable[lane]),
        }

    # ---- the block program --------------------------------------------
    def _record(self, i, word, stats, action, detail):
        ev = recovery.GuardEvent(
            step=int(self.snap_steps[i]), word=int(word),
            checks=health.check_names(int(word)), action=action,
            detail=detail, stats=stats,
        )
        self.lane_events[i].append(ev)
        log.warning("lane %d tripped %s at step %d: %s — %s",
                    i, ev.checks, ev.step, action, detail)
        return ev

    def _rollback(self, i):
        self.carry = _splice_lane(self.carry, i, _lane(self.snap, i))

    def step_block(self) -> list[LaneEvent]:
        """Advance every live lane one block; returns per-lane events.

        Healthy live lanes yield "obs" (still running), "recovered"
        (masked rung taken, replay scheduled) or "done" (target
        reached: finalized state attached, slot freed); a lane whose
        masked rungs are exhausted yields "diverged" (structured
        word/checks/stats + the lane's event log, slot freed)."""
        if self.carry is None or not self.live_lanes:
            return []
        lanes = (
            jnp.asarray(self.dt_scale), jnp.asarray(self.armed),
            jnp.asarray(self.active),
            jnp.asarray(self.target, jnp.int32),
        )
        self.carry, hw, obs = _ensemble_block(
            self.cfg, self.carry, lanes, max(1, self.policy.block),
            self.policy, self.fault, True,
        )
        self.blocks += 1
        words = np.asarray(hw.word)  # the one per-block host sync
        steps = np.asarray(self.carry.steps)
        obs_rows = [np.asarray(o) for o in obs]
        live = self.active & (self.snap_steps < self.target)
        healthy = live & (words == 0)
        tripped = live & (words != 0)
        # Refresh healthy snapshots BEFORE processing trips: rollbacks
        # splice from snap rows, which tripped lanes must keep.
        if healthy.any():
            host = jax.tree.map(np.asarray, self.carry)
            self.snap = _update_snapshot(self.snap, host, healthy)
            self.snap_steps[healthy] = steps[healthy]
            self.dirty.update(int(i) for i in np.nonzero(healthy)[0])
        events: list[LaneEvent] = []
        for i in np.nonzero(tripped)[0]:
            events.append(self._escalate(int(i), int(words[i]),
                                         _hw_member(hw, i)))
            # a surviving tripped lane changed ladder meta (dt_scale /
            # halvings / armed): re-checkpoint so a crash replays the
            # same rung instead of re-deriving it from stale meta
            if self.active[int(i)]:
                self.dirty.add(int(i))
        for i in np.nonzero(healthy)[0]:
            i = int(i)
            row = {
                "t": float(obs_rows[0][i]), "ekin": float(obs_rows[1][i]),
                "vmax": float(obs_rows[2][i]),
                "rho_err": float(obs_rows[3][i]),
            }
            if steps[i] >= self.target[i]:
                state = solver.finalize_persistent(
                    self.cfg, _lane(self.carry, i))
                events.append(LaneEvent(
                    lane=i, kind="done", step=int(steps[i]), obs=row,
                    state=state, events=self.lane_events[i],
                ))
                self.retire(i)
            else:
                events.append(LaneEvent(
                    lane=i, kind="obs", step=int(steps[i]), obs=row))
        return events

    def _escalate(self, i: int, word: int, stats: dict) -> LaneEvent:
        """The masked rungs of the PR 6 ladder for one tripped lane."""
        self.retries[i] += 1
        policy = self.policy
        if (self.armed[i] and self.disarmable[i] and policy.disarm_faults
                and not policy.strict):
            self.armed[i] = False
            self._record(i, word, stats, "disarm",
                         "stripped injected fault; replaying block from "
                         f"step {int(self.snap_steps[i])} (lane-masked)")
            self._rollback(i)
            return LaneEvent(
                lane=i, kind="recovered", step=int(self.snap_steps[i]),
                action="disarm", word=word, stats=stats)
        if (word & health.NUMERIC_CHECKS and not policy.strict
                and self.halvings[i] < policy.max_dt_halvings):
            self.halvings[i] += 1
            self.dt_scale[i] *= 0.5
            self._record(
                i, word, stats, "halve_dt",
                f"lane dt scale -> {self.dt_scale[i]:g} (backoff "
                f"{int(self.halvings[i])}/{policy.max_dt_halvings})")
            self._rollback(i)
            return LaneEvent(
                lane=i, kind="recovered", step=int(self.snap_steps[i]),
                action="halve_dt", word=word, stats=stats)
        detail = ("strict policy" if policy.strict else
                  "masked rungs exhausted (config-changing recovery "
                  "cannot run under live neighbor lanes)")
        self._record(i, word, stats, "quarantine", detail)
        self._rollback(i)  # park the lane rows at its last healthy step
        ev = LaneEvent(
            lane=i, kind="diverged", step=int(self.snap_steps[i]),
            word=word, checks=health.check_names(word), stats=stats,
            detail=detail, events=self.lane_events[i],
        )
        self.retire(i)
        return ev


# --------------------------------------------------------------------------
# Durable sweep service: shape-bucketed batches + per-bucket checkpoints
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SweepRequest:
    """One sweep member: a named (cfg, state) pair, optionally faulted."""

    name: str
    cfg: solver.SPHConfig
    state: solver.SPHState
    fault: health.FaultSpec | None = None


@dataclasses.dataclass
class SweepResult:
    """Per-request outputs (request order) + per-bucket ensemble reports."""

    names: list
    states: list
    stats: list
    members: list  # MemberReport per request
    reports: list  # EnsembleReport per bucket
    buckets: list  # request indices per bucket

    def counts(self) -> dict:
        out = {name: 0 for name in STATUS_NAMES}
        for m in self.members:
            out[m.status] += 1
        return out


def run_sweep(
    requests,
    nsteps: int,
    policy: GuardPolicy | None = None,
    *,
    checkpoint_dir: str | None = None,
    checkpoint_every: int = 1,
    keep: int = 3,
    resume: bool = False,
):
    """Run a sweep of :class:`SweepRequest`s as shape-bucketed ensembles.

    Requests sharing a (normalized) config land in ONE batched
    ``run_ensemble`` call — one compiled program per distinct config,
    never one per member. Each bucket checkpoints into its own
    ``<checkpoint_dir>/bucket_<j>`` subdirectory (plus a human-readable
    ``sweep.json`` manifest at the root), so ``resume=True`` restarts an
    interrupted sweep — completed buckets replay from their final
    checkpoint, the interrupted one from its latest valid step — and
    finishes bit-identical to the uninterrupted run. Bucket order is
    the requests' first-appearance order: a resumed sweep must present
    the SAME request list to map buckets back to directories.

    At most one distinct FaultSpec per bucket (it is a static argument
    of the shared block program); which members it arms is free.
    """
    from repro.checkpoint.manager import CheckpointManager

    policy = policy or GuardPolicy()
    requests = list(requests)
    buckets: dict = {}
    order: list = []
    faults: dict = {}
    for idx, r in enumerate(requests):
        fault = r.fault if r.fault is not None else r.cfg.fault
        key = member_config(r.cfg, policy)
        if key not in buckets:
            buckets[key] = []
            order.append(key)
        buckets[key].append(idx)
        if fault is not None:
            faults[idx] = fault
    for key in order:
        distinct = {faults[i] for i in buckets[key] if i in faults}
        if len(distinct) > 1:
            raise ValueError(
                "at most one distinct FaultSpec per sweep bucket (it is "
                f"a static argument of the shared program); got {distinct}"
            )

    if checkpoint_dir is not None:
        os.makedirs(checkpoint_dir, exist_ok=True)
        manifest = {
            "nsteps": int(nsteps),
            "buckets": [
                {"dir": f"bucket_{j:02d}",
                 "members": [requests[i].name for i in buckets[key]]}
                for j, key in enumerate(order)
            ],
        }
        import json as _json
        with open(os.path.join(checkpoint_dir, "sweep.json"), "w") as f:
            _json.dump(manifest, f, indent=2)

    names = [r.name for r in requests]
    states: list = [None] * len(requests)
    stats: list = [None] * len(requests)
    members: list = [None] * len(requests)
    reports: list = []
    bucket_idx: list = []
    for j, key in enumerate(order):
        idxs = buckets[key]
        bucket_idx.append(list(idxs))
        distinct = {faults[i] for i in idxs if i in faults}
        fault = next(iter(distinct)) if distinct else None
        fmembers = tuple(k for k, i in enumerate(idxs) if i in faults)
        ckpt = None
        if checkpoint_dir is not None:
            ckpt = CheckpointManager(
                os.path.join(checkpoint_dir, f"bucket_{j:02d}"), keep=keep)
        log.info(
            "sweep bucket %d: %d member(s)%s", j, len(idxs),
            f", fault {fault.kind!r} on lanes {fmembers}" if fault else "",
        )
        outs, st, rep = run_ensemble(
            key, [requests[i].state for i in idxs], nsteps, policy,
            fault=fault, fault_members=fmembers, checkpoint=ckpt,
            checkpoint_every=checkpoint_every, resume=resume,
        )
        reports.append(rep)
        for k, i in enumerate(idxs):
            states[i] = outs[k]
            stats[i] = st[k]
            members[i] = rep.members[k]
    return SweepResult(
        names=names, states=states, stats=stats, members=members,
        reports=reports, buckets=bucket_idx,
    )
