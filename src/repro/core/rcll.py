"""Persistent RCLL state: the paper's Eqs. (6)-(8).

The mixed-precision framework never round-trips through absolute
coordinates after initialization. State per particle:

  * ``cell_xy``  (N, d) int32   integer cell coordinates (exact).
  * ``rel``      (N, d) fp16    cell-relative coordinate in [-1, 1].

Time stepping (Eq. 8): rel += 2*dx/h_c, then *migrate*: if |rel| > 1 the
particle moved to an adjacent cell -> shift cell_xy by floor((rel+1)/2) and
re-center rel into [-1, 1]. Critically, the Eq. (8) increment is
accumulated in fp32 and only *stored* in fp16 (matching the paper's rule
that accumulators stay high precision; storage is the low-precision part).

Periodic axes wrap the integer cell coordinate - the fp16 payload never
sees the domain size, which is the whole point: significant digits scale
with the *cell*, not the domain.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.precision import NNPS_STORE

from repro.core import cells as cells_lib
from repro.core import nnps
from repro.core.domain import Domain

Array = jnp.ndarray


class RCLLState(NamedTuple):
    cell_xy: Array  # (N, d) int32
    rel: Array  # (N, d) low-precision storage dtype


def init_state(domain: Domain, xn: Array, dtype=NNPS_STORE) -> RCLLState:
    """One-time transform from normalized absolute coordinates (Eqs. 5-6)."""
    cell_xy = domain.cell_coords_of(xn)
    rel = domain.to_relative(xn, cell_xy, dtype=dtype)
    return RCLLState(cell_xy=cell_xy, rel=rel)


def to_normalized(domain: Domain, state: RCLLState, dtype=jnp.float32) -> Array:
    """Decode back to normalized absolute coordinates (hi precision)."""
    return domain.from_relative(state.rel, state.cell_xy, dtype=dtype)


def _migrate(domain: Domain, cell_xy: Array, rel_hi: Array, dtype):
    """Re-center relative coords into [-1,1], shifting cell indices.

    rel in cell units spans 2 per cell; a particle at rel=1+e is e/2 into
    the next cell: shift = floor((rel+1)/2), rel -= 2*shift. Handles moves
    of more than one cell per step (fast particles) exactly.
    """
    shift = jnp.floor((rel_hi + 1.0) * 0.5).astype(jnp.int32)
    rel_new = rel_hi - 2.0 * shift.astype(rel_hi.dtype)
    cell_new = cell_xy + shift
    n = jnp.asarray(domain.ncells, dtype=jnp.int32)
    per = jnp.asarray(np.asarray(domain.periodic))
    wrapped = jnp.where(per, cell_new % n, cell_new)
    # Non-periodic: clamp to the boundary cell and pin rel at the NEAR
    # edge by clipping the un-recentered value (clipping rel_new would
    # teleport the particle to the boundary cell's far edge - a full-cell
    # jump that breaks the Verlet-skin displacement invariant). Physical
    # walls are enforced by the solver's boundary conditions, not by the
    # coordinate system.
    clamped = jnp.clip(wrapped, 0, n - 1)
    rel_out = jnp.where(
        (wrapped == clamped), rel_new, jnp.clip(rel_hi, -1.0, 1.0)
    )
    return clamped, rel_out.astype(dtype)


def advance(
    domain: Domain,
    state: RCLLState,
    dxn: Array,
    *,
    dtype=NNPS_STORE,
) -> RCLLState:
    """Eq. (8): advance relative coordinates by a *normalized* displacement.

    dxn: (N, d) displacement in normalized (Eq. 5) coordinates, high
         precision (= v * dt * 2 / h_d, computed by the solver).
    """
    # Accumulate in fp32: rel(t) decoded up, increment added exactly, then
    # re-stored low. Guarantees no drift from repeated low-precision adds.
    rel_hi = state.rel.astype(jnp.float32)
    hc = jnp.asarray(domain.hc_norm_axes, jnp.float32)
    incr = 2.0 * dxn.astype(jnp.float32) / hc
    rel_hi = rel_hi + incr
    cell_xy, rel = _migrate(domain, state.cell_xy, rel_hi, dtype)
    return RCLLState(cell_xy=cell_xy, rel=rel)


def advance_ef(
    domain: Domain,
    state: RCLLState,
    dxn: Array,
    carry: Array,
    *,
    dtype=NNPS_STORE,
) -> tuple[RCLLState, Array]:
    """Eq. (8) with error feedback (beyond-paper refinement).

    ``advance`` re-quantizes the relative coordinate every step, so each
    step contributes ~ulp/2 of storage rounding - a random walk that the
    Table 5 long runs surface (0.3 ds over 2.8k steps at ds=0.025), and
    a hard stall when per-step displacements drop below the fp16 ulp.
    Carrying the rounding error in fp32 and re-adding it next step (the
    optim/compress.py trick) makes the quantization unbiased: positions
    track the exact trajectory to fp32 accuracy indefinitely.

    carry: (N, d) fp32, zeros at t=0. Returns (new state, new carry).
    """
    rel_hi = state.rel.astype(jnp.float32) + carry
    hc = jnp.asarray(domain.hc_norm_axes, jnp.float32)
    rel_hi = rel_hi + 2.0 * dxn.astype(jnp.float32) / hc
    shift = jnp.floor((rel_hi + 1.0) * 0.5).astype(jnp.int32)
    rel_new = rel_hi - 2.0 * shift.astype(jnp.float32)
    cell_new = state.cell_xy + shift
    n = jnp.asarray(domain.ncells, dtype=jnp.int32)
    per = jnp.asarray(np.asarray(domain.periodic))
    wrapped = jnp.where(per, cell_new % n, cell_new)
    clamped = jnp.clip(wrapped, 0, n - 1)
    # Pin escapers at the near edge (see _migrate).
    rel_exact = jnp.where(
        wrapped == clamped, rel_new, jnp.clip(rel_hi, -1.0, 1.0))
    rel_stored = rel_exact.astype(dtype)
    new_carry = rel_exact - rel_stored.astype(jnp.float32)
    return RCLLState(cell_xy=clamped, rel=rel_stored), new_carry


def neighbors(
    domain: Domain,
    state: RCLLState,
    *,
    dtype=NNPS_STORE,
    k: int,
    capacity: int | None = None,
    include_self: bool = False,
    radius_cell: float | None = None,
) -> tuple[nnps.NeighborList, cells_lib.CellBinning]:
    """Search neighbors from persistent state; also returns the binning."""
    n = state.rel.shape[0]
    capacity = capacity or cells_lib.default_capacity(domain, n)
    cell_id = domain.flat_cell_id(state.cell_xy)
    binning = cells_lib.bin_by_cell_id(domain, cell_id, state.cell_xy, capacity)
    nl = nnps.rcll_neighbors(
        domain,
        state.rel,
        state.cell_xy,
        dtype=dtype,
        k=k,
        binning=binning,
        include_self=include_self,
        radius_cell=radius_cell,
    )
    return nl, binning


# --------------------------------------------------------------------------
# Cell-packed persistent state (the spatial-sort pipeline)
# --------------------------------------------------------------------------
class PackedState(NamedTuple):
    """RCLL state physically reordered by flat cell id.

    ``rc``'s arrays are in *packed* (cell-sorted) order; ``packing`` carries
    the order/inverse permutation back to original particle indexing plus
    the binning of the packed arrays (whose cell table therefore holds
    packed indices). Neighbor lists built from this state are in packed
    indexing - translate with ``packing.order`` / ``packing.inverse`` at
    the API boundary.
    """

    rc: RCLLState
    packing: cells_lib.CellPacking


def pack_state(
    domain: Domain,
    state: RCLLState,
    capacity: int,
    prev: cells_lib.CellBinning | None = None,
) -> PackedState:
    """Spatially sort an RCLL state by flat cell id.

    ``prev`` — the binning describing the order ``state``'s arrays are
    currently in (the persistent pipeline's previous rebuild) — switches
    the re-pack from a stable argsort to the O(N) counting-sort pack
    (see ``cells.pack_particles``); the resulting permutation is
    identical.
    """
    cell_id = domain.flat_cell_id(state.cell_xy)
    packing = cells_lib.pack_particles(
        domain, cell_id, state.cell_xy, capacity, prev=prev
    )
    rc = RCLLState(
        cell_xy=packing.binning.cell_xy, rel=packing.pack(state.rel)
    )
    return PackedState(rc=rc, packing=packing)


def packed_neighbors(
    domain: Domain,
    pstate: PackedState,
    *,
    dtype=NNPS_STORE,
    compute_dtype=None,
    k: int,
    include_self: bool = False,
    radius_cell: float | None = None,
    window: int | None = None,
    ds: float | None = None,
    chunk: int = 0,
) -> nnps.NeighborList:
    """Neighbor search on the packed arrays (returns packed indexing).

    Packed ids are consecutive per cell, so the search runs table-free
    over contiguous index ranges computed from the counting-sort
    starts/counts, merged into one front-packed candidate block per
    particle (``nnps.rcll_neighbors_windows``): no candidate-id gather
    at all, one bit-packed row gather per candidate, and the coordinate
    gather reads near-contiguous memory — this is where the paper's
    2.7x locality win comes from. Invalid slots of the returned ``idx``
    hold exactly the dummy id N (sort compaction), so the fused force
    pass consumes it with no per-slot sanitize.

    window: static MERGED candidate budget per particle across the
    whole 3^dim neighborhood (see ``nnps.auto_window``). The default
    derives from the lattice spacing ``ds`` when given (the tight
    3^dim-block occupancy bound), else ``4 * capacity``;
    ``3^dim * capacity`` reproduces the dense-table coverage guarantee
    exactly. Truncation is flagged loudly through
    ``NeighborList.overflowed``/the solver overflow plumbing. NOTE:
    unlike the dense table, the window search never drops particles at
    per-CELL capacity — coverage is bounded by the merged budget only.
    """
    cap = pstate.packing.binning.table.shape[1]
    if window is None:
        window = nnps.auto_window(domain, ds=ds, capacity=cap)
    return nnps.rcll_neighbors_windows(
        domain,
        pstate.rc.rel,
        pstate.rc.cell_xy,
        pstate.packing.binning.counts,
        dtype=dtype,
        compute_dtype=compute_dtype,
        k=k,
        window=window,
        include_self=include_self,
        radius_cell=radius_cell,
        chunk=chunk,
    )


def pair_r2_cell(
    domain: Domain,
    state: RCLLState,
    nl: nnps.NeighborList,
    *,
    dtype=NNPS_STORE,
    compute_dtype=None,
) -> Array:
    """Eq. (7) squared pair distances in reference-cell units for ``nl``.

    Uses exactly the arithmetic of :func:`nnps.rcll_neighbors`, so
    filtering these against a radius reproduces a fresh search's boundary
    decisions bit-for-bit (the Verlet-skin exactness argument).
    """
    cdt = compute_dtype or dtype
    rel = state.rel.astype(dtype)
    delta = state.cell_xy[:, None, :] - state.cell_xy[nl.idx]
    delta = domain.wrap_cell_delta(delta)
    w = jnp.asarray(domain.cell_weights)
    return nnps.rcll_r2_cell_units(
        rel[:, None, :], rel[nl.idx], delta, w, dtype=cdt
    )


def decode_pair_disp(
    domain: Domain,
    rel_i: Array,  # (..., d) relative coords of i (storage dtype)
    rel_j: Array,  # (..., d) relative coords of j
    delta: Array,  # (..., d) int32 cell delta I - J, already min-image wrapped
    dtype=jnp.float32,
) -> tuple[Array, Array]:
    """Eq. (7) reconstruction of physical pair displacement x_i - x_j.

    The ONE decode every force path uses (reference gather, fused XLA
    chunks): per-axis cell units -> normalized units -> physical units,
    with the relative payload difference halved exactly and the integer
    cell delta added at ``dtype``. Returns (disp (..., d), r (...,)).
    """
    du = (rel_i.astype(dtype) - rel_j.astype(dtype)) * 0.5 + delta.astype(dtype)
    hc = jnp.asarray(domain.hc_norm_axes, dtype)
    disp_norm = du * hc
    disp_phys = disp_norm * (domain.h_d / 2.0)
    r = jnp.sqrt(jnp.sum(disp_phys * disp_phys, axis=-1))
    return disp_phys, r


def pair_displacements(
    domain: Domain,
    state: RCLLState,
    nl: nnps.NeighborList,
    dtype=jnp.float32,
) -> tuple[Array, Array]:
    """(x_i - x_j) displacement vectors and distances for neighbor pairs.

    Decoded at ``dtype`` (high precision) in *physical* units for the SPH
    force evaluation - Eq. (7) reconstruction: exact integer cell delta
    (minimum-image wrapped) + relative payload difference.

    Returns (disp (N,K,d), r (N,K)).
    """
    delta = state.cell_xy[:, None, :] - state.cell_xy[nl.idx]
    delta = domain.wrap_cell_delta(delta)
    return decode_pair_disp(
        domain, state.rel[:, None, :], state.rel[nl.idx], delta, dtype=dtype
    )
