"""In-scan simulation health word + fault injection hooks.

Every failure mode this repo has hit so far was discovered by a human
staring at NaNs: the dam-break capacity blowup (PR 4), the fp16
subnormal mass flush (PR 3), the v0-driven water-hammer CFL blowup
(PR 5), silent window truncation. This module makes detection a
first-class, in-scan operation:

  * a small bitmask of health CHECKS (non-finite x/v/rho, density
    deviation beyond the weak-compressibility bound, vmax*dt/h CFL
    violation, neighbor-window truncation, cell-capacity overflow);
  * :func:`check_carry` — ONE fused reduction over the persistent carry
    producing a :class:`HealthWord` (the bitmask plus the offending-field
    stats), evaluated inside the jitted guarded block with zero host
    sync (the same pattern as the in-scan ``Observables``);
  * :class:`FaultSpec` + :func:`inject_fault` — the deterministic fault
    hook the recovery tests and CI drive (``SPHConfig.fault``);
  * :class:`SimulationDiverged` — the structured failure raised when a
    recovery policy is exhausted, carrying step / tripped checks /
    stats instead of a NaN-filled array.

The escalation machinery that CONSUMES the health word (rollback, dt
backoff, capacity regrow, precision degrade) lives in
``core/recovery.py``; this module deliberately imports nothing from the
solver so both the solver and the recovery driver can depend on it.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

# --------------------------------------------------------------------------
# Check bits
# --------------------------------------------------------------------------
NAN_X = 1 << 0  # non-finite relative coordinates (position representation)
NAN_V = 1 << 1  # non-finite velocity component
NAN_RHO = 1 << 2  # non-finite density
RHO_DEV = 1 << 3  # |rho/rho0 - 1| beyond the weak-compressibility bound
CFL = 1 << 4  # vmax * dt / h beyond the advective CFL bound
WINDOW_TRUNC = 1 << 5  # neighbor list truncated (window or K budget)
CELL_OVERFLOW = 1 << 6  # cell table dropped particles (capacity)

ALL_CHECKS = (
    NAN_X | NAN_V | NAN_RHO | RHO_DEV | CFL | WINDOW_TRUNC | CELL_OVERFLOW
)
#: The bits that dt backoff can plausibly cure (numeric blowups).
NUMERIC_CHECKS = NAN_X | NAN_V | NAN_RHO | RHO_DEV | CFL
#: The bits cured by regrowing static capacities (recompile).
CAPACITY_CHECKS = WINDOW_TRUNC | CELL_OVERFLOW

CHECK_NAMES = (
    (NAN_X, "nan_x"),
    (NAN_V, "nan_v"),
    (NAN_RHO, "nan_rho"),
    (RHO_DEV, "rho_dev"),
    (CFL, "cfl"),
    (WINDOW_TRUNC, "window_trunc"),
    (CELL_OVERFLOW, "cell_overflow"),
)

# Default thresholds. The WCSPH design point is |drho/rho0| ~ (v/c0)^2
# (~1% at Ma 0.1), so a 25% deviation is unambiguously divergence, not
# compression. A healthy acoustic-CFL run sits at vmax*dt/h ~ 0.025
# (dt = 0.25 h/c0, vmax ~ 0.1 c0); 0.5 means velocities have blown up
# by >~20x past the speed the dt was sized for.
DEFAULT_RHO_DEV_LIMIT = 0.25
DEFAULT_CFL_LIMIT = 0.5


def check_names(word: int) -> tuple[str, ...]:
    """Human-readable names of the set bits of a (host) health word."""
    return tuple(name for bit, name in CHECK_NAMES if word & bit)


class SimulationDiverged(RuntimeError):
    """A guarded run exhausted its recovery policy (or strict mode hit).

    Carries the structured context a NaN-filled array never could:

      step:   last healthy step count (the rollback point).
      checks: names of the tripped health checks.
      word:   the raw bitmask.
      stats:  dict of offending-field stats (vmax, rho_dev, cfl,
              non-finite counts) at detection time.
      events: the recovery actions attempted before giving up.
    """

    def __init__(self, message: str, *, step: int | None = None,
                 checks: tuple[str, ...] = (), word: int = 0,
                 stats: dict | None = None, events: list | None = None):
        super().__init__(message)
        self.step = step
        self.checks = tuple(checks)
        self.word = int(word)
        self.stats = dict(stats or {})
        self.events = list(events or [])


class HealthWord(NamedTuple):
    """The in-scan health reduction: bitmask + offending-field stats.

    All fields are device scalars; nothing syncs until the driver reads
    the word at a block boundary. Stats are computed with non-finite
    values masked out so they stay meaningful under NaN poisoning (the
    non-finite COUNTS carry that signal separately).
    """

    word: Array  # () uint32 tripped-check bitmask
    vmax: Array  # () fp32 max fluid |v| (finite entries only)
    rho_dev: Array  # () fp32 max fluid |rho/rho0 - 1| (finite only)
    cfl: Array  # () fp32 vmax * dt / h
    bad_x: Array  # () int32 particles with non-finite coordinates
    bad_v: Array  # () int32 particles with non-finite velocity
    bad_rho: Array  # () int32 particles with non-finite density
    max_count: Array  # () int32 max neighbor count seen (may be K+1 sentinel)
    max_cell: Array  # () int32 max cell occupancy at last rebuild

    def host_stats(self) -> dict:
        """The stats as a plain host dict (for logs / SimulationDiverged)."""
        return {
            "vmax": float(self.vmax),
            "rho_dev": float(self.rho_dev),
            "cfl": float(self.cfl),
            "bad_x": int(self.bad_x),
            "bad_v": int(self.bad_v),
            "bad_rho": int(self.bad_rho),
            "max_count": int(self.max_count),
            "max_cell": int(self.max_cell),
        }


def _bit(cond: Array, bit: int) -> Array:
    return jnp.where(cond, jnp.uint32(bit), jnp.uint32(0))


def fold_flag(flags: Array | None, cond: Array, bit: int) -> Array | None:
    """OR ``bit`` into an accumulated uint32 flag word where ``cond``."""
    if flags is None:
        return None
    return flags | _bit(cond, bit)


def check_carry(
    cfg,
    carry,
    *,
    rho_dev_limit: float = DEFAULT_RHO_DEV_LIMIT,
    cfl_limit: float = DEFAULT_CFL_LIMIT,
    enabled: int = ALL_CHECKS,
    dt: Array | float | None = None,
) -> HealthWord:
    """One fused health reduction over a persistent carry (traceable).

    Numeric checks read the packed state directly; the overflow checks
    fold the carry's accumulated per-block ``flags`` (set at rebuild
    time, so an overflow in ANY intermediate rebuild of the block is
    seen) with the live neighbor-list/binning sentinels. ``enabled``
    masks the final word, so disabled checks can never trip.

    ``cfg``/``carry`` are duck-typed (SPHConfig / PersistentCarry): this
    module must not import the solver.

    ``dt`` optionally overrides ``cfg.dt`` in the CFL term — the batched
    ensemble steps members under per-member (traced) timesteps, and the
    CFL check must judge each member against the dt it actually stepped
    with, not the config's.
    """
    st = carry.st
    fl = st.fluid
    fluid = ~st.fixed

    x_fin = jnp.all(jnp.isfinite(st.rc.rel), axis=-1)
    v_fin = jnp.all(jnp.isfinite(fl.v), axis=-1)
    rho_fin = jnp.isfinite(fl.rho)
    bad_x = jnp.sum(~x_fin).astype(jnp.int32)
    bad_v = jnp.sum(~v_fin).astype(jnp.int32)
    bad_rho = jnp.sum(~rho_fin).astype(jnp.int32)

    v2 = jnp.sum(fl.v.astype(jnp.float32) ** 2, axis=-1)
    vmax = jnp.sqrt(jnp.max(jnp.where(fluid & v_fin, v2, 0.0)))
    rho0 = cfg.resolved_scheme.rho0
    dev = jnp.abs(fl.rho.astype(jnp.float32) / rho0 - 1.0)
    rho_dev = jnp.max(jnp.where(fluid & rho_fin, dev, 0.0))
    cfl = vmax * ((cfg.dt if dt is None else dt) / cfg.h)

    nl = carry.nl
    k = nl.mask.shape[1]
    win_bad = jnp.any(nl.count > k)
    trunc = getattr(nl, "trunc", None)
    if trunc is not None:
        win_bad = win_bad | trunc
    max_count = jnp.max(nl.count).astype(jnp.int32)
    if carry.binning is not None:
        cell_bad = carry.binning.overflow > 0
        max_cell = jnp.max(carry.binning.counts).astype(jnp.int32)
    else:
        cell_bad = jnp.zeros((), bool)
        max_cell = jnp.zeros((), jnp.int32)

    word = (
        _bit(bad_x > 0, NAN_X)
        | _bit(bad_v > 0, NAN_V)
        | _bit(bad_rho > 0, NAN_RHO)
        | _bit(rho_dev > rho_dev_limit, RHO_DEV)
        | _bit(cfl > cfl_limit, CFL)
        | _bit(win_bad, WINDOW_TRUNC)
        | _bit(cell_bad, CELL_OVERFLOW)
    )
    if carry.flags is not None:
        word = word | carry.flags
    word = word & jnp.uint32(enabled)
    return HealthWord(
        word=word, vmax=vmax, rho_dev=rho_dev, cfl=cfl,
        bad_x=bad_x, bad_v=bad_v, bad_rho=bad_rho,
        max_count=max_count, max_cell=max_cell,
    )


def check_batch(
    cfg,
    carry,
    *,
    rho_dev_limit: float = DEFAULT_RHO_DEV_LIMIT,
    cfl_limit: float = DEFAULT_CFL_LIMIT,
    enabled: int = ALL_CHECKS,
    dt: Array | None = None,
) -> HealthWord:
    """:func:`check_carry` over a stacked (batch-leading) carry.

    Returns a :class:`HealthWord` whose every leaf is a (B,) vector —
    one word + attribution stats PER MEMBER, from the same fused
    reduction vmap'd across the batch axis, so the ensemble driver pays
    a single device→host sync for the whole batch. ``dt`` is an
    optional (B,) per-member timestep vector (see :func:`check_carry`).
    """
    kw = dict(rho_dev_limit=rho_dev_limit, cfl_limit=cfl_limit,
              enabled=enabled)
    if dt is None:
        return jax.vmap(lambda c: check_carry(cfg, c, **kw))(carry)
    return jax.vmap(lambda c, d: check_carry(cfg, c, dt=d, **kw))(carry, dt)


def observe_state(cfg, st):
    """One observable row from a state (any particle ordering).

    The in-scan diagnostics row (t, ekin, vmax, rho_err) over fluid
    particles only — shared by the API's ``Observables`` scan and the
    guarded-block driver. Lives here (not api.py) so the recovery layer
    can sample it without a circular import.
    """
    fl = st.fluid
    fluid = ~st.fixed
    w = fluid.astype(jnp.float32)
    v2 = jnp.sum(fl.v * fl.v, axis=-1)
    rho0 = cfg.resolved_scheme.rho0
    return (
        st.t,
        0.5 * jnp.sum(w * fl.m * v2),
        jnp.sqrt(jnp.max(jnp.where(fluid, v2, 0.0))),
        jnp.max(jnp.where(fluid, jnp.abs(fl.rho / rho0 - 1.0), 0.0)),
    )


# --------------------------------------------------------------------------
# Deterministic fault injection (the recovery-path test harness)
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """A deterministic in-scan fault, armed via ``SPHConfig.fault``.

    Hashable (SPHConfig is a static jit argument). The fault fires when
    the carry's step counter equals ``step`` — and fires AGAIN on every
    rolled-back retry that replays that step, modeling a persistent
    fault; the recovery driver's ``disarm_faults`` policy models the
    transient kind by stripping the spec from the config after the
    first trip.

    kinds:
      "nan_v":    poison one velocity component of packed particle
                  ``particle`` with NaN (spreads through the pair sums).
      "teleport": move packed particle ``particle`` next to packed
                  particle ``target`` and give it the large apparent
                  velocity of the jump (``vkick``) — the corrupted-
                  position event. The kick matters: continuity-form
                  WCSPH density only changes through RELATIVE motion
                  (dρ ∝ dv·∇W), so a matched-velocity overlap is
                  dynamically inert; the kick detonates the density at
                  close range exactly like a real position/velocity
                  inconsistency.
    """

    kind: str
    step: int
    particle: int = 0
    target: int = 1
    vkick: float = 8.0

    def __post_init__(self):
        if self.kind not in ("nan_v", "teleport"):
            raise ValueError(f"unknown fault kind {self.kind!r}")


def inject_fault(fault: FaultSpec, carry):
    """Apply ``fault`` to the carry when its step counter matches.

    Traceable; indices are in PACKED order (which packed particle a
    given index lands on is deterministic for a fixed trajectory, and a
    rollback restores the same packing — so retries replay the same
    fault, which is the property the escalation tests rely on).
    """
    trip = carry.steps == fault.step
    st = carry.st
    p = fault.particle
    if fault.kind == "nan_v":
        fl = st.fluid
        bad = jnp.where(trip, jnp.asarray(jnp.nan, fl.v.dtype), fl.v[p, 0])
        v = fl.v.at[p, 0].set(bad)
        return carry._replace(st=st._replace(fluid=fl._replace(v=v)))
    # teleport: adopt target's cell + relative coords plus an offset
    # that lands in the STEEP region of the kernel gradient (~0.25 h for
    # typical cell factors — a tiny offset would park the pair at the
    # B-spline gradient's r->0 zero where overlapping particles feel
    # nothing), and spike the particle's accumulated displacement so the
    # Verlet criterion forces a rebuild — the overlap must enter the
    # neighbor list to detonate.
    rc = st.rc
    q = fault.target
    off = jnp.asarray(0.2, rc.rel.dtype)
    rel = rc.rel.at[p].set(jnp.where(trip, rc.rel[q] + off, rc.rel[p]))
    cxy = rc.cell_xy.at[p].set(
        jnp.where(trip, rc.cell_xy[q], rc.cell_xy[p])
    )
    disp = carry.disp_acc.at[p].set(
        jnp.where(trip, 1.0, carry.disp_acc[p])
    )
    fl = st.fluid
    v = fl.v.at[p, 0].set(
        jnp.where(trip, jnp.asarray(fault.vkick, fl.v.dtype), fl.v[p, 0])
    )
    return carry._replace(
        st=st._replace(
            rc=rc._replace(rel=rel, cell_xy=cxy), fluid=fl._replace(v=v)
        ),
        disp_acc=disp,
    )
