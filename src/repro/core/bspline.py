"""Cubic B-spline SPH kernel (paper Eq. 3): the ONE source of truth.

Every consumer of the kernel function or its derivative — the reference
pair physics (``core/sph.py``), the fused XLA force pass
(``core/fused.py``), and the Pallas tile kernels
(``kernels/sph_gradient.py`` / ``kernels/rcll_force.py``) — evaluates it
through these functions, so a constant or branch-point tweak cannot make
the fused kernels drift from the reference physics.

All functions are plain elementwise jnp: they trace identically inside a
``pallas_call`` body (on a (cap, cap) tile) and in bulk XLA (on an
(N, K) pair array). ``h``/``dim`` are static Python numbers, so the
normalization constants fold at trace time.
"""
from __future__ import annotations

import math

import jax.numpy as jnp

Array = jnp.ndarray

#: The kernel support radius in units of h: W(r) = 0 for r >= SUPPORT * h.
SUPPORT = 2.0


def alpha_d(dim: int, h: float) -> float:
    """Normalization factor of the cubic B-spline (paper Eq. 3)."""
    if dim == 1:
        return 1.0 / h
    if dim == 2:
        return 15.0 / (7.0 * math.pi * h * h)
    if dim == 3:
        return 3.0 / (2.0 * math.pi * h**3)
    raise ValueError(dim)


def w(r: Array, h: float, dim: int) -> Array:
    """Kernel value W(R, h), R = r/h (paper Eq. 3)."""
    R = r / h
    a = alpha_d(dim, h)
    w1 = 2.0 / 3.0 - R * R + 0.5 * R**3
    w2 = (2.0 - R) ** 3 / 6.0
    return a * jnp.where(R < 1.0, w1, jnp.where(R < 2.0, w2, 0.0))


def dw_dr(r: Array, h: float, dim: int) -> Array:
    """dW/dr. Vanishes identically for r >= 2h (compact support) and at
    r = 0 — the property the fused force pass relies on: pairs beyond the
    true support (Verlet-skin extras) and the self pair contribute an
    exact 0.0 to every force sum."""
    R = r / h
    a = alpha_d(dim, h) / h
    d1 = -2.0 * R + 1.5 * R * R
    d2 = -0.5 * (2.0 - R) ** 2
    return a * jnp.where(R < 1.0, d1, jnp.where(R < 2.0, d2, 0.0))


def dw_over_r(r: Array, h: float, dim: int) -> Array:
    """(dW/dr) / r with the r -> 0 guard, the common factor of every
    gradient term: ∂W/∂x_a = dw_over_r(r) * disp_a."""
    rsafe = jnp.where(r > 1e-12, r, 1.0)
    return dw_dr(r, h, dim) / rsafe
