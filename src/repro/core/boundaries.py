"""Static dummy/wall-particle boundary subsystem.

The paper's framework (like its DualSPHysics lineage, arXiv:1110.3711)
treats solid boundaries as layers of *dummy particles*: wall particles
carry mass/density and contribute to every density/pressure pair sum
exactly like fluid particles — through the same record rows and cell
tables, with no pairwise special-casing — but are never advected, and
their velocity is *prescribed* (0 for no-slip walls, a constant for
moving lids) rather than integrated.

This module owns the per-particle ``kind`` classification and the wall
lattice generators the scenario cases build from:

  * ``kind`` — (N,) int8, :data:`FLUID` or :data:`WALL`. Threaded through
    the solver state (``SPHState.kind``), the packing permutations, and
    the integrator (``solver._physics_step``: walls get ``v := v_wall``
    and a zero advection step). Because wall velocities live in the SAME
    per-particle ``v`` array as fluid velocities, they flow through the
    fused force pass's half-width record rows and the Pallas v-tiles
    with zero layout changes — a moving lid is just a wall row whose
    velocity column is nonzero.
  * wall lattices — :func:`box_wall_particles` generates ``n_layers``
    dummy layers outside any chosen subset of box faces (corners
    included once), the geometry every wall-bounded case (dam break,
    cavity, Poiseuille) needs. The enclosing :class:`Domain` must extend
    over the wall band (walls are particles like any other).

The wall band width must cover the kernel support (``n_layers * ds >=
2h``, i.e. ``n_layers >= 2·1.2 = 3`` at the default ``h = 1.2 ds``) so
fluid near a wall never sees a truncated kernel through it.
"""
from __future__ import annotations

import numpy as np

FLUID = 0
WALL = 1


def wall_extent(
    lo: tuple[float, ...],
    hi: tuple[float, ...],
    ds: float,
    n_layers: int,
    sides: tuple[tuple[int, int], ...],
) -> tuple[tuple[float, ...], tuple[float, ...]]:
    """Domain bounds padded by the wall band on each walled side.

    ``sides`` is a tuple of (axis, side) pairs with side 0 = lo face,
    1 = hi face. The returned (lo, hi) is what the :class:`Domain`
    enclosing fluid + walls should use.
    """
    w = n_layers * ds
    lo2 = list(lo)
    hi2 = list(hi)
    for axis, side in sides:
        if side == 0:
            lo2[axis] -= w
        else:
            hi2[axis] += w
    return tuple(lo2), tuple(hi2)


def box_wall_particles(
    lo: tuple[float, ...],
    hi: tuple[float, ...],
    ds: float,
    n_layers: int,
    sides: tuple[tuple[int, int], ...],
    velocities: dict[tuple[int, int], tuple[float, ...]] | None = None,
) -> tuple[np.ndarray, np.ndarray]:
    """Dummy-particle wall layers outside the box faces in ``sides``.

    Generates the lattice over the padded bounding box and keeps every
    node outside the open box ``(lo, hi)`` — wall bands and their corner
    overlaps appear exactly once each. Points are classified to the
    FIRST side (in ``sides`` order) whose band contains them, which
    fixes the corner ambiguity deterministically: list the moving lid
    first to have it own its corners (the standard cavity convention).

    Args:
      lo / hi: the FLUID box (walls are generated outside it).
      ds: particle spacing (lattice pitch, offset ds/2 like the fluid).
      n_layers: wall thickness in particle layers (>= ceil(2h/ds)).
      sides: (axis, side) faces to wall; side 0 = lo face, 1 = hi face.
      velocities: optional prescribed wall velocity per face (default 0).

    Returns (pos (Nw, d), v_wall (Nw, d)) as float64/float32 numpy.
    """
    dim = len(lo)
    velocities = velocities or {}
    pad_lo, pad_hi = wall_extent(lo, hi, ds, n_layers, sides)
    axes = [
        np.arange(pl + ds / 2, ph, ds)
        for pl, ph in zip(pad_lo, pad_hi)
    ]
    grid = np.meshgrid(*axes, indexing="ij")
    pts = np.stack([g.ravel() for g in grid], axis=-1).astype(np.float64)

    eps = 1e-9 * ds
    inside = np.all(
        (pts > np.asarray(lo) + eps) & (pts < np.asarray(hi) - eps), axis=-1
    )
    side_of = np.full(pts.shape[0], -1, np.int32)
    for si, (axis, side) in enumerate(sides):
        band = (
            pts[:, axis] < lo[axis] + eps
            if side == 0
            else pts[:, axis] > hi[axis] - eps
        )
        take = band & ~inside & (side_of < 0)
        side_of[take] = si
    keep = side_of >= 0
    pos = pts[keep]
    v_wall = np.zeros((pos.shape[0], dim), np.float32)
    for si, face in enumerate(sides):
        vf = velocities.get(face)
        if vf is not None:
            v_wall[side_of[keep] == si] = np.asarray(vf, np.float32)
    return pos, v_wall


def fluid_lattice(
    lo: tuple[float, ...], hi: tuple[float, ...], ds: float
) -> np.ndarray:
    """Regular fluid lattice filling the open box (nodes at ds/2 offsets)."""
    axes = [np.arange(l + ds / 2, h, ds) for l, h in zip(lo, hi)]
    grid = np.meshgrid(*axes, indexing="ij")
    return np.stack([g.ravel() for g in grid], axis=-1).astype(np.float64)
