"""Nearest Neighboring Particle Search (NNPS) algorithms.

Three searches, as in the paper:
  * ``all_list_*``   - O(N^2) brute force (paper Fig. 3a), any dtype.
  * ``cell_list_*``  - background-cell candidates + *absolute* normalized
                       coordinates in the search dtype (paper approach II
                       when dtype=fp16).
  * ``rcll_*``       - background-cell candidates + *cell-relative*
                       coordinates stored in the search dtype (the paper's
                       contribution, approach III).

Distance semantics: searches faithfully model the low-precision pipeline -
coordinates are *stored* in ``dtype`` and differences/squares/sums are
computed in ``dtype`` (fp16 hardware arithmetic on the A100; VPU fp32-with-
fp16-storage on TPU is the adaptation, but interpretation here keeps the
paper's arithmetic so accuracy tables reproduce).

RCLL distances use cell units (Eq. 7 divided by the constant h_c/2):

    du = (x_i - x_j)/2 + (I - J)        # I, J integer cell coords
    r_cell^2 = du^2 + dv^2 (+ dw^2)
    neighbor  <=>  r_cell <= radius/(h_c/2)

which is the paper's Eq. (7) up to one exact global scale. Working in cell
units is strictly better for fp16: all quantities are O(1), no tiny
products. Periodic axes use minimum-image on the integer cell delta - an
*exact* wrap (the paper's domains are non-periodic; this is needed for the
Poiseuille channel).

Outputs are static-shape neighbor lists (idx, mask, count) - XLA/TPU have
no dynamic shapes, so K = max_neighbors is a static capacity and ``count``
lets callers detect overflow.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cells as cells_lib
from repro.core.domain import Domain
from repro.core.precision import NNPS_STORE

Array = jnp.ndarray


class NeighborList(NamedTuple):
    """Static-capacity neighbor list.

    idx:   (N, K) int32 neighbor particle ids (garbage where ~mask).
    mask:  (N, K) bool valid-slot flags.
    count: (N,)   int32 true neighbor count (may exceed K -> overflow).
    trunc: () bool, window searches only: some particle's merged
           candidate total exceeded the window budget (its true count is
           then UNKNOWN — the ``k + 1`` sentinel folds it into
           ``overflowed``, but this bit lets the health guard tell
           "window too small" apart from "more true neighbors than K"
           and escalate the right knob). None for searches without a
           window budget.
    """

    idx: Array
    mask: Array
    count: Array
    trunc: Array | None = None

    @property
    def overflowed(self) -> Array:
        return jnp.any(self.count > self.mask.shape[1])


def select_k(cand: Array, ok: Array, k: int) -> tuple[Array, Array]:
    """Pick (up to) k true entries of ``ok`` per row, returning gathered ids.

    Uses top_k on the boolean mask: ties broken by lowest index, so the
    selection is deterministic (first k valid candidates in candidate
    order). Returns (idx (N,k) int32, mask (N,k) bool). When the row has
    fewer than k candidate slots, outputs are padded (mask False).
    """
    kk = min(k, cand.shape[1])
    score = ok.astype(jnp.float32)
    vals, pos = jax.lax.top_k(score, kk)  # (N, kk)
    idx = jnp.take_along_axis(cand, pos, axis=1).astype(jnp.int32)
    mask = vals > 0.5
    if kk < k:
        pad = ((0, 0), (0, k - kk))
        idx = jnp.pad(idx, pad)
        mask = jnp.pad(mask, pad)
    return idx, mask


def min_image(diff: Array, wrap_span: Array | None) -> Array:
    """Minimum-image wrap of coordinate differences (single source of truth).

    diff: (..., d) coordinate differences in any float dtype.
    wrap_span: optional (d,) per-axis spans; 0 disables the wrap on that
        axis (non-periodic). None -> identity.
    """
    if wrap_span is None:
        return diff
    span = wrap_span.astype(diff.dtype)
    wrapped = diff - jnp.round(diff / jnp.where(span > 0, span, 1)) * span
    return jnp.where(span > 0, wrapped, diff)


def wrap_span_norm(domain: Domain) -> Array | None:
    """Per-axis periodic spans in normalized (Eq. 5) units; None if none."""
    if not any(domain.periodic):
        return None
    spans = [
        (2.0 * s / domain.h_d) if p else 0.0
        for s, p in zip(domain.spans, domain.periodic)
    ]
    return jnp.asarray(spans, dtype=jnp.float32)


# Back-compat private alias (pre-packed-pipeline name).
_wrap_span_norm = wrap_span_norm


def _pairwise_r2(a: Array, b: Array, wrap_span: Array | None) -> Array:
    """Squared distances between row sets a (N,d) and b (M,d), in a.dtype.

    wrap_span: optional (d,) same-dtype spans for minimum-image wrap on
    periodic axes (0 -> no wrap on that axis).
    """
    diff = min_image(a[:, None, :] - b[None, :, :], wrap_span)
    return jnp.sum(diff * diff, axis=-1)


# --------------------------------------------------------------------------
# All-list (O(N^2))
# --------------------------------------------------------------------------
def all_list_neighbors(
    xn: Array,
    radius_norm: float,
    *,
    dtype=jnp.float32,
    k: int,
    domain: Domain | None = None,
    include_self: bool = False,
    block: int = 2048,
) -> NeighborList:
    """Brute-force neighbor search on normalized absolute coordinates.

    xn: (N, d) normalized coordinates (any float dtype; cast to ``dtype``
        to model low-precision storage). Row-blocked to bound memory.
    """
    n = xn.shape[0]
    x_lo = xn.astype(dtype)
    r2 = jnp.asarray(radius_norm, dtype=dtype) ** 2
    wrap = _wrap_span_norm(domain) if domain is not None else None
    ids = jnp.arange(n, dtype=jnp.int32)

    def row_block(lo):
        a = jax.lax.dynamic_slice_in_dim(x_lo, lo, block, axis=0)
        d2 = _pairwise_r2(a, x_lo, wrap)
        ok = d2 <= r2
        if not include_self:
            rows = lo + jnp.arange(block, dtype=jnp.int32)
            ok = ok & (rows[:, None] != ids[None, :])
        cand = jnp.broadcast_to(ids[None, :], ok.shape)
        idx, mask = select_k(cand, ok, k)
        return idx, mask, jnp.sum(ok, axis=1).astype(jnp.int32)

    if n <= block:
        d2 = _pairwise_r2(x_lo, x_lo, wrap)
        ok = d2 <= r2
        if not include_self:
            ok = ok & ~jnp.eye(n, dtype=bool)
        cand = jnp.broadcast_to(ids[None, :], (n, n))
        idx, mask = select_k(cand, ok, k)
        return NeighborList(idx, mask, jnp.sum(ok, axis=1).astype(jnp.int32))

    nblk = -(-n // block)
    pad = nblk * block - n
    xp = jnp.pad(x_lo, ((0, pad), (0, 0)))
    x_lo_p = xp
    starts = jnp.arange(nblk, dtype=jnp.int32) * block

    def body(lo):
        a = jax.lax.dynamic_slice_in_dim(x_lo_p, lo, block, axis=0)
        d2 = _pairwise_r2(a, x_lo, wrap)
        ok = d2 <= r2
        if not include_self:
            rows = lo + jnp.arange(block, dtype=jnp.int32)
            ok = ok & (rows[:, None] != ids[None, :])
        cand = jnp.broadcast_to(ids[None, :], ok.shape)
        idx, mask = select_k(cand, ok, k)
        return idx, mask, jnp.sum(ok, axis=1).astype(jnp.int32)

    idx, mask, count = jax.lax.map(body, starts)
    return NeighborList(
        idx.reshape(-1, k)[:n], mask.reshape(-1, k)[:n], count.reshape(-1)[:n]
    )


def all_list_count(
    xn: Array,
    radius_norm: float,
    *,
    dtype=jnp.float32,
    domain: Domain | None = None,
    include_self: bool = False,
    block: int = 1024,
) -> Array:
    """Count-only all-list search (used by scaling benchmarks; O(block*N) mem)."""
    n = xn.shape[0]
    x_lo = xn.astype(dtype)
    r2 = jnp.asarray(radius_norm, dtype=dtype) ** 2
    wrap = _wrap_span_norm(domain) if domain is not None else None
    nblk = -(-n // block)
    pad = nblk * block - n
    xp = jnp.pad(x_lo, ((0, pad), (0, 0)), constant_values=1e4)
    ids = jnp.arange(n, dtype=jnp.int32)

    def body(lo):
        a = jax.lax.dynamic_slice_in_dim(xp, lo, block, axis=0)
        d2 = _pairwise_r2(a, x_lo, wrap)
        ok = d2 <= r2
        if not include_self:
            rows = lo + jnp.arange(block, dtype=jnp.int32)
            ok = ok & (rows[:, None] != ids[None, :])
        return jnp.sum(ok, axis=1).astype(jnp.int32)

    counts = jax.lax.map(body, jnp.arange(nblk, dtype=jnp.int32) * block)
    return counts.reshape(-1)[:n]


# --------------------------------------------------------------------------
# Cell link-list (absolute coordinates in `dtype` -> paper approach II)
# --------------------------------------------------------------------------
def cell_list_neighbors(
    domain: Domain,
    xn: Array,
    *,
    dtype=jnp.float32,
    k: int,
    capacity: int | None = None,
    binning: cells_lib.CellBinning | None = None,
    include_self: bool = False,
) -> NeighborList:
    """Cell-candidate search with absolute normalized coordinates.

    The binning itself always runs in fp32 (cell assignment is an integer
    decision the paper also keeps exact); only the *distance filter* runs
    in ``dtype``. This is exactly the paper's approach II pipeline when
    dtype=fp16: coordinates truncated to fp16, distances in fp16.
    """
    n = xn.shape[0]
    if binning is None:
        capacity = capacity or cells_lib.default_capacity(domain, n)
        binning = cells_lib.bin_particles(domain, xn, capacity)
    cand, cmask = cells_lib.gather_candidates(domain, binning)  # (N, M)
    x_lo = xn.astype(dtype)
    xi = x_lo[:, None, :]  # (N, 1, d)
    xj = x_lo[cand]  # (N, M, d)
    diff = min_image(xi - xj, wrap_span_norm(domain))
    d2 = jnp.sum(diff * diff, axis=-1)
    r2 = jnp.asarray(domain.radius_norm, dtype=dtype) ** 2
    ok = cmask & (d2 <= r2)
    if not include_self:
        ok = ok & (cand != jnp.arange(n, dtype=jnp.int32)[:, None])
    idx, mask = select_k(cand, ok, k)
    return NeighborList(idx, mask, jnp.sum(ok, axis=1).astype(jnp.int32))


# --------------------------------------------------------------------------
# RCLL (cell-relative coordinates in `dtype` -> the paper's approach III)
# --------------------------------------------------------------------------
def rcll_r2_cell_units(
    rel_i: Array,
    rel_j: Array,
    cell_delta: Array,
    weights: Array | None = None,
    *,
    dtype=NNPS_STORE,
) -> Array:
    """Eq. (7) in reference-cell units from relative coords + cell delta.

    rel_i: (..., d) relative coords of i in [-1,1], storage dtype.
    rel_j: (..., d) relative coords of j.
    cell_delta: (..., d) int32 exact cell-coordinate delta I - J
                (minimum-image wrapped for periodic axes by the caller).
    weights: (d,) O(1) per-axis anisotropy weights hc_a / hc_ref (None = 1).

    ``dtype`` is the *arithmetic* dtype. Paper-faithful fp16 NNPS passes
    fp16 (A100 half ALUs); the TPU adaptation stores fp16 but computes in
    fp32 (the VPU upconverts for free), which removes arithmetic rounding
    entirely - storage quantization is then the only error source.
    """
    rel_i = rel_i.astype(dtype)
    rel_j = rel_j.astype(dtype)
    # (x_i - x_j)/2: halving is exact in binary fp; difference of two
    # in-[-1,1] numbers stays well-scaled. Cell delta is an exact small int.
    du = (rel_i - rel_j) * jnp.asarray(0.5, dtype) + cell_delta.astype(dtype)
    if weights is not None:
        du = du * weights.astype(dtype)
    return jnp.sum(du * du, axis=-1)


def rcll_radius_cell_units(domain: Domain) -> float:
    """Search radius in reference-cell units (= 1/cell_factor when square)."""
    return float(domain.radius_norm / domain.hc_ref)


def rcll_neighbors(
    domain: Domain,
    rel: Array,
    cell_xy: Array,
    *,
    dtype=NNPS_STORE,
    compute_dtype=None,
    k: int,
    capacity: int | None = None,
    binning: cells_lib.CellBinning | None = None,
    include_self: bool = False,
    radius_cell: float | None = None,
) -> NeighborList:
    """RCLL search from stored relative coordinates + integer cell coords.

    rel: (N, d) cell-relative coordinates in [-1, 1], already stored in the
         low-precision dtype (the state maintained by rcll.RCLLState).
    cell_xy: (N, d) int32 per-axis cell coordinates.
    compute_dtype: arithmetic dtype for Eq. (7). Defaults to ``dtype``
         (paper-faithful); fp32 is the TPU-native mode (fp16 storage, VPU
         fp32 arithmetic) with zero arithmetic rounding.
    radius_cell: search radius override in reference-cell units (used by
         the Verlet-skin pipeline to search with an inflated radius
         r + skin). Defaults to the exact kernel-support radius. Must not
         exceed the 3^dim-neighborhood coverage guarantee (one cell edge).
    """
    n = rel.shape[0]
    cdt = compute_dtype or dtype
    if binning is None:
        capacity = capacity or cells_lib.default_capacity(domain, n)
        cell_id = domain.flat_cell_id(cell_xy)
        binning = cells_lib.bin_by_cell_id(domain, cell_id, cell_xy, capacity)
    cand, cmask = cells_lib.gather_candidates(domain, binning)  # (N, M)
    delta = cell_xy[:, None, :] - cell_xy[cand]  # (N, M, d) int32
    delta = domain.wrap_cell_delta(delta)
    w = jnp.asarray(domain.cell_weights)
    rel = rel.astype(dtype)  # storage quantization
    d2 = rcll_r2_cell_units(rel[:, None, :], rel[cand], delta, w, dtype=cdt)
    if radius_cell is None:
        radius_cell = rcll_radius_cell_units(domain)
    rcell = jnp.asarray(radius_cell, dtype=cdt)
    ok = cmask & (d2 <= rcell * rcell)
    if not include_self:
        ok = ok & (cand != jnp.arange(n, dtype=jnp.int32)[:, None])
    idx, mask = select_k(cand, ok, k)
    return NeighborList(idx, mask, jnp.sum(ok, axis=1).astype(jnp.int32))


#: Rows per chunk of the mapped window search (the lax.map tile that
#: keeps every (chunk, window) candidate intermediate cache-resident
#: instead of materializing (N, window) slabs in HBM).
SEARCH_CHUNK = 4096


def auto_window(
    domain: Domain,
    ds: float | None = None,
    capacity: int | None = None,
    safety: float = 1.25,
) -> int:
    """Static merged-candidate budget for :func:`rcll_neighbors_windows`.

    With the particle spacing ``ds`` known, bound the 3^dim-cell
    neighborhood occupancy by its lattice count — ``prod_a (3 hc_a / ds
    + 1)`` — times a compression safety. This is the 3^dim-block
    analogue of :func:`cells.dense_capacity`: it is independent of how
    much of the domain the fluid fills (the mean-occupancy estimate that
    burned the dam break) and much tighter than summing per-cell
    capacities, because a whole 3x3(x3) block cannot straddle an extra
    lattice row per cell per axis. Without ``ds``, fall back to
    ``ceil(4/3 * 3^(dim-1)) * capacity`` (~1.33x the mean 3^dim-block
    occupancy when capacity carries the default 3x per-cell safety).

    Truncation is always flagged loudly (the ``k + 1`` count sentinel),
    so an underestimate surfaces through the overflow plumbing instead
    of silently dropping pairs.
    """
    if ds is not None:
        est = 1.0
        for c in domain.cell_sizes:
            est *= 3.0 * c / ds + 1.0
        return max(8, int(np.ceil(safety * est)))
    if capacity is None:
        raise ValueError("auto_window needs ds or capacity")
    return max(8, int(np.ceil(4 / 3 * 3 ** (domain.dim - 1))) * capacity)


def _bits_dtype(dtype):
    """Unsigned carrier of a storage dtype's bit width (u16 / u32)."""
    size = jnp.dtype(dtype).itemsize
    if size == 2:
        return jnp.uint16
    if size == 4:
        return jnp.uint32
    raise ValueError(f"unsupported search storage dtype {dtype}")


def rcll_neighbors_windows(
    domain: Domain,
    rel: Array,  # (N, d) CELL-SORTED relative coords (storage dtype)
    cell_xy: Array,  # (N, d) int32 cell coords, cell-sorted
    counts: Array,  # (C,) int32 per-cell occupancy of the sorted arrays
    *,
    dtype=NNPS_STORE,
    compute_dtype=None,
    k: int,
    window: int,
    radius_cell: float | None = None,
    include_self: bool = False,
    chunk: int = 0,
) -> NeighborList:
    """Table-free RCLL search over cell-SORTED particle arrays.

    The counting-sort byproducts are the whole data structure: packed
    ids are contiguous per cell (and row-major cell order makes runs of
    last-axis-adjacent cells contiguous too), so every particle's
    candidate set is 3^(d-1) contiguous index ranges. The ranges are
    MERGED arithmetically into one front-packed block of ``window``
    candidate slots per particle — slot t maps to run r(t) and candidate
    id ``begin_r + t - B_r`` (B_r = exclusive prefix of the run
    lengths), so padding never exceeds ``window - total`` regardless of
    how occupancy splits across runs, and no (C, cap) table or
    candidate-id gather exists anywhere. (A periodic LAST axis breaks
    3-cell contiguity at the seam; those runs degrade to 3^d single-cell
    ranges, where every axis' cell delta is a known per-run constant.)

    Three structural costs are gone relative to a table search:

      * ONE row gather per candidate: the distance test needs rel
        (storage bits) and, when the last axis is aperiodic, the
        last-axis cell coordinate — lead-axis deltas are per-run
        constants, never gathered. Both ride in a single bit-packed row
        (u16 columns for 16-bit storage), gathered once per candidate.
      * chunked evaluation: a ``lax.map`` over row chunks keeps the
        (chunk, window) candidate intermediates cache-resident instead
        of materializing (N, window, d) slabs in HBM.
      * sort compaction: valid candidates are compacted by an ascending
        keyed sort (invalid slots key to the dummy id N) — measurably
        cheaper than top_k selection on CPU, emits neighbor ids in
        ascending order (near-contiguous record gathers for the
        consuming force sweep), and yields DUMMY-PADDED ids: invalid
        slots hold exactly N, so the fused force pass consumes ``idx``
        directly with no per-slot sanitize.

    The Eq. (7) arithmetic (subtract, halve, add exact integer cell
    delta, weight, square — all in ``compute_dtype``) is operation-for-
    operation the one :func:`rcll_r2_cell_units` runs, so boundary
    decisions agree with the dense-table oracle bit-for-bit.

    window: static merged candidate budget per particle (see
    :func:`auto_window`). ``3^dim * capacity`` reproduces the dense
    table's coverage guarantee exactly; a particle whose 3^dim
    neighborhood holds more candidates than ``window`` is flagged with
    the ``k + 1`` count sentinel through ``NeighborList.overflowed``.
    """
    n, dim = rel.shape
    cdt = compute_dtype or dtype
    starts = cells_lib.exclusive_cumsum(counts)
    nc = domain.ncells
    ncy = nc[-1]
    if radius_cell is None:
        radius_cell = rcll_radius_cell_units(domain)
    rcell = jnp.asarray(radius_cell, dtype=cdt)
    r2 = rcell * rcell
    w = np.asarray(domain.cell_weights)

    # Runs: contiguous 3-cell bands on an aperiodic last axis (the seam
    # would break contiguity), single cells otherwise. Banded runs read
    # the candidate's last-axis cell coordinate from the gathered row;
    # single-cell runs know every axis' delta statically.
    banded = not domain.periodic[-1]
    if banded:
        offs = (cells_lib.neighbor_cell_offsets(dim - 1)
                if dim > 1 else np.zeros((1, 0), np.int32))
    else:
        offs = cells_lib.neighbor_cell_offsets(dim)
    nrun = offs.shape[0]
    naxes = offs.shape[1]  # axes with a statically known delta
    per = jnp.asarray(np.asarray(domain.periodic[:naxes]))
    n_ax = jnp.asarray(nc[:naxes], jnp.int32)
    cy = cell_xy[:, -1]

    begins, lengths = [], []
    for off in offs:
        if naxes:
            nb = cell_xy[:, :naxes] + jnp.asarray(off, jnp.int32)
            wrapped = jnp.where(per, nb % n_ax, nb)
            valid = jnp.all((wrapped >= 0) & (wrapped < n_ax), axis=-1)
            nb = jnp.clip(wrapped, 0, n_ax - 1)
            flat = nb[..., 0]
            for a in range(1, naxes):
                flat = flat * nc[a] + nb[..., a]
        else:
            valid = jnp.ones((n,), bool)
            flat = jnp.zeros_like(cy)
        if banded:
            ylo = jnp.clip(cy - 1, 0, ncy - 1)
            yhi = jnp.clip(cy + 1, 0, ncy - 1)
            c_lo = flat * ncy + ylo if dim > 1 else ylo
            c_hi = flat * ncy + yhi if dim > 1 else yhi
        else:
            c_lo = c_hi = flat  # offs covered all axes: full flat id
        begin = starts[c_lo]
        end = starts[c_hi] + counts[c_hi]
        begins.append(begin)
        lengths.append(jnp.where(valid, end - begin, 0))
    begin = jnp.stack(begins, axis=1)  # (N, R)
    # Exclusive prefix of run lengths: B[:, r] = merged-slot base of run r.
    bounds = jnp.concatenate(
        [jnp.zeros((n, 1), jnp.int32),
         jnp.cumsum(jnp.stack(lengths, axis=1), axis=1).astype(jnp.int32)],
        axis=1,
    )  # (N, R + 1)
    total = bounds[:, -1]

    # Statically known per-run deltas I - J = -off (min-image exact: a
    # periodic axis has >= 3 cells, so wrapping I - (I + off) gives -off
    # itself; invalid aperiodic runs carry length 0 and are never read).
    dlt = jnp.asarray(-offs.astype(np.float32))  # (R, naxes)

    # Bit-packed search row: [rel bits (d) | last-axis cell (banded)].
    bits = _bits_dtype(dtype)
    rel_lo = rel.astype(dtype)
    cols = [jax.lax.bitcast_convert_type(rel_lo, bits)]
    if banded:
        if ncy >= jnp.iinfo(bits).max:
            raise ValueError(
                f"last axis has {ncy} cells; the packed search row "
                f"caps it at {jnp.iinfo(bits).max}"
            )
        cols.append(cy.astype(bits)[:, None])
    srow = jnp.concatenate(cols, axis=1)
    rows_all = jnp.arange(n, dtype=jnp.int32)

    def body(args):
        b, bb, tot, ri, cyi, rows = args
        c = b.shape[0]
        t = jnp.arange(window, dtype=jnp.int32)[None, :]  # (1, S)
        # Source run of merged slot t: r = #(runs whose base <= t).
        rsel = jnp.zeros((c, window), jnp.int32)
        for r in range(1, nrun):
            rsel = rsel + (t >= bb[:, r:r + 1]).astype(jnp.int32)
        ids = (jnp.take_along_axis(b, rsel, axis=1) + t
               - jnp.take_along_axis(bb[:, :nrun], rsel, axis=1))
        okw = t < tot[:, None]
        idsc = jnp.clip(ids, 0, n - 1)
        sj = srow[idsc]  # ONE row gather: (c, S, d [+1])
        rjc = jax.lax.bitcast_convert_type(sj[..., :dim], dtype).astype(cdt)
        ric = ri.astype(cdt)
        half = jnp.asarray(0.5, cdt)
        d2 = jnp.zeros((c, window), cdt)
        for a in range(naxes):  # per-run constant deltas
            da = dlt[:, a].astype(cdt)[rsel]
            du = (ric[:, a:a + 1] - rjc[..., a]) * half + da
            du = du * jnp.asarray(w[a], cdt)
            d2 = d2 + du * du
        if banded:  # last axis: exact integer cell delta, gathered
            cyj = sj[..., dim].astype(jnp.int32)
            dy = (cyi[:, None] - cyj).astype(cdt)
            du = (ric[:, dim - 1:dim] - rjc[..., dim - 1]) * half + dy
            du = du * jnp.asarray(w[dim - 1], cdt)
            d2 = d2 + du * du
        ok = okw & (d2 <= r2)
        if not include_self:
            ok = ok & (idsc != rows[:, None])
        count = jnp.sum(ok, axis=1).astype(jnp.int32)
        count = jnp.where(tot > window, jnp.maximum(count, k + 1), count)
        # Keyed-sort compaction: ascending ids first, dummy id N padding.
        key = jnp.where(ok, idsc, n)
        key = jnp.sort(key, axis=1)
        if window < k:
            key = jnp.pad(key, ((0, 0), (0, k - window)),
                          constant_values=n)
        idx = key[:, :k]
        return idx, idx < n, count, tot > window

    chunk = chunk if chunk > 0 else SEARCH_CHUNK
    row_args = (begin, bounds, total, rel_lo, cy, rows_all)
    nchunk = -(-n // min(n, chunk))
    csize = -(-n // nchunk)
    nchunk = -(-n // csize)
    if nchunk == 1:
        idx, mask, count, trow = body(row_args)
        return NeighborList(idx, mask, count, trunc=jnp.any(trow))
    pad = nchunk * csize - n

    def padded(x, fill):
        if pad == 0:
            return x
        return jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0
        )

    fills = (0, 0, 0, jnp.asarray(0, rel_lo.dtype), 0, n)
    chunked = tuple(
        padded(x, f).reshape((nchunk, csize) + x.shape[1:])
        for x, f in zip(row_args, fills)
    )
    idx, mask, count, trow = jax.lax.map(body, chunked)

    def unpad(x):
        return x.reshape((nchunk * csize,) + x.shape[2:])[:n]

    return NeighborList(unpad(idx), unpad(mask), unpad(count),
                        trunc=jnp.any(unpad(trow)))


def refilter(nl: NeighborList, d2: Array, r2: Array | float) -> NeighborList:
    """Narrow a (possibly skin-inflated) list to pairs with d2 <= r2.

    The Verlet-reuse pipeline searches with radius r + skin; the exact-
    radius neighbor set is recovered by masking with the true radius. The
    caller supplies d2 computed with the SAME arithmetic as the original
    search (e.g. Eq. 7 cell units) so boundary decisions are bit-identical
    to a fresh search. idx is left uncompacted: mask carries the set.
    """
    ok = nl.mask & (d2 <= r2)
    return NeighborList(
        idx=nl.idx, mask=ok, count=jnp.sum(ok, axis=1).astype(jnp.int32)
    )


# --------------------------------------------------------------------------
# Convenience: exact (fp64-on-CPU / fp32) reference determinations
# --------------------------------------------------------------------------
def reference_neighbors(
    domain: Domain, xn: Array, *, k: int, include_self: bool = False
) -> NeighborList:
    """High-precision ground-truth determinations (cell-list in fp32 or
    fp64 when x64 is enabled by the caller's entry point)."""
    dt = jnp.float64 if jax.config.read("jax_enable_x64") else jnp.float32
    return cell_list_neighbors(
        domain, xn, dtype=dt, k=k, include_self=include_self
    )


def neighbor_sets_equal(a: NeighborList, b: NeighborList) -> Array:
    """Per-particle boolean: identical neighbor *sets* (order-insensitive)."""
    def canon(nl: NeighborList) -> Array:
        big = jnp.iinfo(jnp.int32).max
        vals = jnp.where(nl.mask, nl.idx, big)
        return jnp.sort(vals, axis=1)

    return jnp.all(canon(a) == canon(b), axis=1) & (a.count == b.count)


def count_wrong_determinations(
    truth: NeighborList, test: NeighborList
) -> Array:
    """Total |symmetric difference| of neighbor sets across all particles.

    This matches the paper's 'count of incorrect neighbor determinations':
    every missed true neighbor and every spurious neighbor counts once.
    """
    k = max(truth.idx.shape[1], test.idx.shape[1])

    def canon(nl):
        big = jnp.iinfo(jnp.int32).max
        vals = jnp.where(nl.mask, nl.idx, big)
        pad = ((0, 0), (0, k - nl.idx.shape[1]))
        return jnp.sort(jnp.pad(vals, pad, constant_values=big), axis=1)

    a, b = canon(truth), canon(test)

    def row_sym_diff(ra, rb):
        in_b = jnp.isin(ra, rb)
        in_a = jnp.isin(rb, ra)
        valid_a = ra != jnp.iinfo(jnp.int32).max
        valid_b = rb != jnp.iinfo(jnp.int32).max
        return jnp.sum(valid_a & ~in_b) + jnp.sum(valid_b & ~in_a)

    return jnp.sum(jax.vmap(row_sym_diff)(a, b))
