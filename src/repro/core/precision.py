"""Precision policies: which subsystem runs in which dtype.

The paper's framework is *mixed*-precision by construction: NNPS runs in a
low dtype (fp16), everything accuracy-critical (integration, density,
forces) runs in a high dtype (fp64 on the A100; fp32 on TPU which has no
fp64 ALUs — see DESIGN.md section 2/7). We make this a first-class policy
object so precision is never ambient global state.

fp64 note: library code never flips ``jax_enable_x64`` globally. CPU-side
accuracy benchmarks that need true fp64 references enable it explicitly in
their own entry points (benchmarks/_x64.py) before importing jax arrays.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# Canonical dtype table, keyed by the names used throughout configs/CLIs.
DTYPES = {
    "fp64": jnp.float64,
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "fp16": jnp.float16,
}

# Canonical storage dtypes. THE sanctioned spellings of the half/full
# tiers — library code outside this module must use these (or a
# PrecisionPolicy) instead of jnp.float16/jnp.bfloat16 literals, so that
# `sphlint check` can prove every precision decision flows through one
# place. NNPS_STORE is the paper's fp16 coordinate/neighbor storage tier
# (RCLL relative coordinates live exactly here); HALF_STORE/BF16_STORE
# are the two 16-bit record layouts of the fused force pass; HIGH_STORE
# is the TPU high tier (DESIGN.md section 7).
NNPS_STORE = DTYPES["fp16"]
HALF_STORE = DTYPES["fp16"]
BF16_STORE = DTYPES["bf16"]
HIGH_STORE = DTYPES["fp32"]


def dtype_of(name: str):
    try:
        return DTYPES[name]
    except KeyError:
        raise ValueError(f"unknown dtype name {name!r}; one of {sorted(DTYPES)}")


def name_of(dtype) -> str:
    for k, v in DTYPES.items():
        if v == jnp.dtype(dtype):
            return k
    return str(jnp.dtype(dtype))


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """Per-subsystem dtype assignment for the mixed-precision SPH step.

    Attributes:
      nnps: dtype of the neighbor-search distance pipeline (paper: fp16).
      coords: dtype in which *positions* are stored for NNPS. For RCLL this
        is the storage dtype of the cell-relative coordinates; for
        absolute-coordinate searches it is the storage dtype of the
        normalized absolute coordinates (paper approach II).
      physics: dtype of density/momentum/energy updates (paper: fp64;
        TPU default: fp32).
      accum: dtype of reductions/accumulators inside physics ops.
      nnps_compute: ARITHMETIC dtype of the Eq. (7) distance pipeline in
        the production solver (storage stays ``nnps``/``coords``). The
        default fp32 is the TPU-native mode (the VPU upconverts fp16
        storage for free, zero arithmetic rounding) and is what makes the
        xla and pallas neighbor backends agree bit-for-bit; set "fp16"
        for the paper's A100 half-ALU arithmetic.
      records: STORAGE dtype of the velocity/mass columns of the fused
        force pass's record rows (and of the Pallas force kernel's v/m
        cell tables). "fp16"/"bf16" is the half-width production layout:
        the coordinate payload rides as the raw fp16 RCLL relative
        coordinate (lossless — it IS the storage dtype) next to an
        integer cell anchor, v and m are quantized to ``records``, and
        the density tier (rho, p/ρ²) stays fp32. All pair arithmetic
        upcasts to fp32 in-register; accumulators stay fp32 — only the
        per-pair HBM bytes shrink. "fp32" is the full-width layout, kept
        selectable as the accuracy oracle.
    """

    nnps: str = "fp16"
    coords: str = "fp16"
    physics: str = "fp32"
    accum: str = "fp32"
    nnps_compute: str = "fp32"
    records: str = "fp16"

    @property
    def nnps_dtype(self):
        return dtype_of(self.nnps)

    @property
    def nnps_compute_dtype(self):
        return dtype_of(self.nnps_compute)

    @property
    def coords_dtype(self):
        return dtype_of(self.coords)

    @property
    def physics_dtype(self):
        return dtype_of(self.physics)

    @property
    def accum_dtype(self):
        return dtype_of(self.accum)

    @property
    def records_dtype(self):
        return dtype_of(self.records)

    @property
    def half_records(self) -> bool:
        """True when the fused force pass uses the 16-bit record layout."""
        return jnp.dtype(self.records_dtype).itemsize == 2

    def with_records(self, records: str) -> "PrecisionPolicy":
        """This policy with the record storage dtype replaced — the
        runtime precision-degrade step of the health guard (fp16 ->
        fp32 when the grid outgrows the half-record cell-anchor range
        or the rel-coordinate quantization bound trips)."""
        dtype_of(records)  # validate eagerly
        return dataclasses.replace(self, records=records)


# The paper's three experiment configurations (Table 4), adapted per
# DESIGN.md section 7 (fp64 -> fp32 as the TPU high tier; the CPU accuracy
# benchmarks still build true-fp64 references).
APPROACH_I = PrecisionPolicy(
    nnps="fp32", coords="fp32", physics="fp32", records="fp32"
)
APPROACH_II = PrecisionPolicy(nnps="fp16", coords="fp16", physics="fp32")
APPROACH_III = PrecisionPolicy(nnps="fp16", coords="fp16", physics="fp32")

# The full-width record layout (the PR 2 behavior): exact cross-backend
# agreement oracle for the fused force pass.
FP32_RECORDS = PrecisionPolicy(records="fp32")

APPROACHES = {"I": APPROACH_I, "II": APPROACH_II, "III": APPROACH_III}
