"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every ``attn_every`` layers (arXiv:2411.15242).

The shared block's weights are reused at every application site (Zamba2's
parameter-sharing trick; per-site LoRA deltas are omitted - noted in
DESIGN.md). Its input is concat(hidden, original embedding) in 2*d_model,
attention + MLP run in 2*d_model, and a down projection brings the result
back to d_model as a residual add.

Structure for scan-friendliness: the first ``n_sites * attn_every``
mamba layers are scanned as (n_sites, attn_every) groups - shared
attention fires after each group - and the remaining tail layers are
scanned without attention. All caches come out stacked.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers, mamba2
from repro.models import partitioning as pt
from repro.models import scan_config
from repro.models import transformer as tf

Array = jnp.ndarray


def n_sites(cfg) -> int:
    return cfg.n_layers // cfg.attn_every


def tail_layers(cfg) -> int:
    return cfg.n_layers - n_sites(cfg) * cfg.attn_every


def shared_d(cfg) -> int:
    return 2 * cfg.d_model


def init_shared_block(key, cfg):
    d2 = shared_d(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_rmsnorm(d2),
        "attn": attn_lib.init_attention(
            k1, d2, cfg.n_heads, cfg.n_kv, d2 // cfg.n_heads, out_dim=d2),
        "ln2": layers.init_rmsnorm(d2),
        "mlp": layers.init_swiglu(k2, d2, cfg.d_ff),
        "w_down": layers.dense_init(k3, d2, cfg.d_model),
    }


def init_params(key, cfg):
    ke, kl, ks = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    return {
        "embed_tokens": layers.init_embed(
            ke, cfg.vocab, cfg.d_model, tied=cfg.tied_embeddings),
        "layers": jax.vmap(lambda k: tf.init_layer(k, cfg))(layer_keys),
        "shared_attn": init_shared_block(ks, cfg),
        "final_norm": layers.init_rmsnorm(cfg.d_model),
    }


def _shared_forward(p, h, emb0, positions, cfg):
    """Full-seq shared block. Returns (residual for h, (k, v) cache)."""
    x = jnp.concatenate([h, emb0], axis=-1)
    xn = layers.rms_norm(p["ln1"], x)
    out, (k, v) = attn_lib.attention_full(
        p["attn"], xn, positions, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        d_head=shared_d(cfg) // cfg.n_heads, rope_theta=cfg.rope_theta)
    x = x + out
    x = x + layers.swiglu(p["mlp"], layers.rms_norm(p["ln2"], x))
    dtype = h.dtype
    return (x.astype(dtype) @ p["w_down"].astype(dtype)), (k, v)


def _shared_decode(p, h, emb0, cache_s, cfg):
    x = jnp.concatenate([h, emb0], axis=-1)
    xn = layers.rms_norm(p["ln1"], x)
    out, new_cache = attn_lib.decode_attention_dense(
        p["attn"], xn, cache_s, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
        d_head=shared_d(cfg) // cfg.n_heads, rope_theta=cfg.rope_theta)
    x = x + out
    x = x + layers.swiglu(p["mlp"], layers.rms_norm(p["ln2"], x))
    dtype = h.dtype
    return (x.astype(dtype) @ p["w_down"].astype(dtype)), new_cache


class HybridCache(NamedTuple):
    mamba: mamba2.Mamba2Cache  # stacked (n_layers, ...)
    shared: attn_lib.DenseKVCache  # stacked (n_sites, ...)


def _split_stack(params_layers, cfg):
    ns, ae = n_sites(cfg), cfg.attn_every
    head = jax.tree.map(
        lambda x: x[: ns * ae].reshape((ns, ae) + x.shape[1:]),
        params_layers)
    tail = jax.tree.map(lambda x: x[ns * ae:], params_layers)
    return head, tail


def forward(params, tokens, cfg, *, patch_embeds=None, return_cache=False):
    B, L = tokens.shape
    h = layers.embed(params["embed_tokens"], tokens)
    emb0 = h
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))
    head, tail = _split_stack(params["layers"], cfg)

    def mamba_body(hh, p_l):
        out, cache = mamba2.mamba2_forward(
            p_l["mixer"], layers.rms_norm(p_l["ln1"], hh), cfg.ssm_dims,
            chunk=cfg.ssd_chunk)
        return pt.act_seq(hh + out), cache

    if cfg.remat == "full":
        mamba_body = jax.checkpoint(mamba_body)

    def group_body(hh, p_group):
        hh, caches = jax.lax.scan(mamba_body, hh, p_group,
                                  unroll=scan_config.unroll())
        res, kv = _shared_forward(params["shared_attn"], hh, emb0,
                                  positions, cfg)
        return hh + res, (caches, kv)

    h, (m_caches, s_caches) = jax.lax.scan(
        group_body, h, head, unroll=scan_config.unroll())
    # tail layers without shared attention
    h, t_caches = jax.lax.scan(mamba_body, h, tail,
                               unroll=scan_config.unroll())
    h = layers.rms_norm(params["final_norm"], h)
    lg = layers.logits(params["embed_tokens"], h)
    if not return_cache:
        return lg, None, jnp.zeros((), jnp.float32)
    flat = jax.tree.map(
        lambda a, b: jnp.concatenate(
            [a.reshape((-1,) + a.shape[2:]), b], axis=0),
        m_caches, t_caches)
    return lg, (flat, s_caches), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg):
    lg, _, aux = forward(params, batch["tokens"], cfg)
    loss = layers.cross_entropy(lg[:, :-1], batch["labels"][:, 1:])
    return loss, {"ce": loss, "aux": aux}


def init_cache(cfg, batch: int, max_len: int) -> HybridCache:
    ns = n_sites(cfg)

    def stack(x, n):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n,) + a.shape).copy(), x)

    return HybridCache(
        mamba=stack(mamba2.Mamba2Cache.init(batch, cfg.ssm_dims),
                    cfg.n_layers),
        shared=stack(attn_lib.DenseKVCache.init(
            batch, max_len, cfg.n_kv, shared_d(cfg) // cfg.n_heads),
            ns),
    )


def prefill(params, tokens, cfg, max_len: int, *, patch_embeds=None):
    B, L = tokens.shape
    lg, (m_cache, s_kv), _ = forward(params, tokens, cfg, return_cache=True)
    k, v = s_kv  # (n_sites, B, L, Hkv, Dh2)
    pad = max_len - L
    k = jnp.pad(k.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    length = jnp.full((n_sites(cfg), B), L, jnp.int32)
    return lg, HybridCache(
        mamba=m_cache,
        shared=attn_lib.DenseKVCache(k=k, v=v, length=length))


def decode_step(params, tokens, cache: HybridCache, cfg):
    B = tokens.shape[0]
    h = layers.embed(params["embed_tokens"], tokens)
    emb0 = h
    ns, ae = n_sites(cfg), cfg.attn_every
    head, tail = _split_stack(params["layers"], cfg)
    m_head = jax.tree.map(
        lambda x: x[: ns * ae].reshape((ns, ae) + x.shape[1:]), cache.mamba)
    m_tail = jax.tree.map(lambda x: x[ns * ae:], cache.mamba)

    def mamba_step(hh, xs):
        p_l, c_l = xs
        out, nc = mamba2.mamba2_decode(
            p_l["mixer"], layers.rms_norm(p_l["ln1"], hh), c_l,
            cfg.ssm_dims)
        return hh + out, nc

    def group_step(carry, xs):
        hh = carry
        p_group, c_group, c_shared = xs
        hh, new_m = jax.lax.scan(mamba_step, hh, (p_group, c_group),
                                 unroll=scan_config.unroll())
        res, new_s = _shared_decode(params["shared_attn"], hh, emb0,
                                    c_shared, cfg)
        return hh + res, (new_m, new_s)

    h, (new_m_head, new_shared) = jax.lax.scan(
        group_step, h, (head, m_head, cache.shared),
        unroll=scan_config.unroll())
    h, new_m_tail = jax.lax.scan(mamba_step, h, (tail, m_tail),
                                 unroll=scan_config.unroll())
    new_mamba = jax.tree.map(
        lambda a, b: jnp.concatenate(
            [a.reshape((-1,) + a.shape[2:]), b], axis=0),
        new_m_head, new_m_tail)
    h = layers.rms_norm(params["final_norm"], h)
    lg = layers.logits(params["embed_tokens"], h)
    return lg, HybridCache(mamba=new_mamba, shared=new_shared)
