"""Global scan-unroll switch for cost analysis.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE,
regardless of trip count (verified: a 10-iteration scanned matmul
reports exactly 1/10 of the true flops). The dry-run therefore compiles
each cell twice: the production loop form (true memory analysis, the
artifact that would run) and a fully-unrolled "cost probe" (true flops /
bytes / collective counts). This module is the switch the model code
reads at trace time.
"""
from __future__ import annotations

import contextlib

_FULL_UNROLL = False


def set_full_unroll(v: bool):
    global _FULL_UNROLL
    _FULL_UNROLL = bool(v)


def unroll():
    """Value for lax.scan's ``unroll=`` parameter."""
    return True if _FULL_UNROLL else 1


@contextlib.contextmanager
def full_unroll():
    set_full_unroll(True)
    try:
        yield
    finally:
        set_full_unroll(False)
