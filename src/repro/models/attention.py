"""GQA attention: train/prefill (full-sequence causal) and single-token
decode against a KV cache.

Two cache representations:
  * ``DenseKVCache``   - plain bf16 (B, Hkv, L, Dh) ring buffer (baseline).
  * ``AnchoredKVCache``- the paper's technique (RCLL-KV): closed 128-token
    blocks live as anchor(fp32) + scale(fp32) + residual(int8/fp16); the
    open block is an fp32 tail buffer. Block closure is a pure function of
    ``length % block`` so the decode step stays shape-static.

The XLA attention path is the default (dry-run / CPU); kernels/
flash_attention.py and kernels/rcll_kv_attention.py are the TPU hot-spot
implementations validated against the same math.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import anchored
from repro.models import scan_config
from repro.models import layers
from repro.models import partitioning as pt

Array = jnp.ndarray

NEG_INF = -1e30


def init_attention(key, d_model, n_heads, n_kv, d_head, out_dim=None):
    kq, kk, kv, ko = jax.random.split(key, 4)
    out_dim = out_dim or d_model
    return {
        "wq": layers.dense_init(kq, d_model, n_heads * d_head),
        "wk": layers.dense_init(kk, d_model, n_kv * d_head),
        "wv": layers.dense_init(kv, d_model, n_kv * d_head),
        "wo": layers.dense_init(ko, n_heads * d_head, out_dim),
    }


def _qkv(p, x, n_heads, n_kv, d_head, compute_dtype):
    B, L, _ = x.shape
    xc = x.astype(compute_dtype)
    q = (xc @ p["wq"].astype(compute_dtype)).reshape(B, L, n_heads, d_head)
    k = (xc @ p["wk"].astype(compute_dtype)).reshape(B, L, n_kv, d_head)
    v = (xc @ p["wv"].astype(compute_dtype)).reshape(B, L, n_kv, d_head)
    q = pt.act(q, "batch", None, "model", None)
    return q, k, v


def sdpa(q, k, v, *, causal: bool, length: Array | None = None,
         q_offset: Array | int = 0):
    """Scaled dot-product attention, fp32 accumulation, GQA via reshape.

    q: (B, Lq, H, Dh); k/v: (B, Lk, Hkv, Dh).
    length: optional (B,) valid KV length (decode masking).
    q_offset: position of q[0] within the KV timeline (causal masking).
    """
    B, Lq, H, Dh = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Lq, Hkv, rep, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    # s: (B, Hkv, rep, Lq, Lk)
    s = jnp.einsum("blgrd,bmgd->bgrlm", qg, kf) / np.sqrt(Dh)
    rows = (jnp.asarray(q_offset) + jnp.arange(Lq))[:, None]
    cols = jnp.arange(Lk)[None, :]
    mask = jnp.ones((Lq, Lk), bool)
    if causal:
        mask = mask & (rows >= cols)
    if length is not None:
        mask = mask[None] & (cols[None] < length[:, None, None])
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    else:
        s = jnp.where(mask[None, None, None], s, NEG_INF)
    p_ = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrlm,bmgd->blgrd", p_, v.astype(jnp.float32))
    return out.reshape(B, Lq, H, Dh)


ATTN_CHUNK = 512  # q-block size for memory-linear (flash-style) attention


def sdpa_chunked(q, k, v, *, causal: bool, chunk: int = ATTN_CHUNK,
                 length=None, kv_hoist: bool = False):
    """Query-blocked attention: materializes (B,H,chunk,Lk) scores instead
    of (B,H,Lq,Lk) - the XLA-level equivalent of the flash tiling in
    kernels/flash_attention.py (O(L) activation memory, exact math).

    kv_hoist: force K/V to the attention-ready sharding ONCE before the
    chunk loop. Without it GSPMD re-gathers the sequence-sharded K/V on
    every chunk iteration (measured: 3507 all-gathers / 565 GB per step
    on llama3-3b train_4k - EXPERIMENTS.md Perf iteration A1)."""
    B, Lq, H, Dh = q.shape
    if kv_hoist:
        # batch-sharded, sequence gathered: the layout every chunk reads
        k = pt.act(k, "batch", None, None, None)
        v = pt.act(v, "batch", None, None, None)
    if Lq <= chunk or Lq % chunk != 0:
        return sdpa(q, k, v, causal=causal, length=length)
    nc = Lq // chunk
    qc = q.reshape(B, nc, chunk, H, Dh).transpose(1, 0, 2, 3, 4)

    def one(_, args):
        i, qi = args
        return None, sdpa(qi, k, v, causal=causal, length=length,
                          q_offset=i * chunk)

    _, out = jax.lax.scan(one, None, (jnp.arange(nc), qc),
                          unroll=scan_config.unroll())
    return out.transpose(1, 0, 2, 3, 4).reshape(B, Lq, H, Dh)


def attention_full(p, x, positions, *, n_heads, n_kv, d_head,
                   rope_theta=10000.0, causal=True,
                   compute_dtype=layers.DEFAULT_COMPUTE, use_rope=True,
                   kv_hoist: bool = False):
    """Train/prefill self-attention. Returns (out, (k, v) for caching)."""
    B, L, _ = x.shape
    q, k, v = _qkv(p, x, n_heads, n_kv, d_head, compute_dtype)
    if use_rope:
        q = layers.apply_rope(q, positions, rope_theta)
        k = layers.apply_rope(k, positions, rope_theta)
    out = sdpa_chunked(q, k, v, causal=causal, kv_hoist=kv_hoist)
    out = out.astype(compute_dtype).reshape(B, L, n_heads * d_head)
    return out @ p["wo"].astype(compute_dtype), (k, v)


def cross_attention(p, x, kv_src, *, n_heads, n_kv, d_head,
                    compute_dtype=layers.DEFAULT_COMPUTE):
    """Encoder-decoder cross attention (no RoPE, non-causal)."""
    B, L, _ = x.shape
    S = kv_src.shape[1]
    xc = x.astype(compute_dtype)
    sc = kv_src.astype(compute_dtype)
    q = (xc @ p["wq"].astype(compute_dtype)).reshape(B, L, n_heads, d_head)
    k = (sc @ p["wk"].astype(compute_dtype)).reshape(B, S, n_kv, d_head)
    v = (sc @ p["wv"].astype(compute_dtype)).reshape(B, S, n_kv, d_head)
    out = sdpa_chunked(q, k, v, causal=False)
    out = out.astype(compute_dtype).reshape(B, L, n_heads * d_head)
    return out @ p["wo"].astype(compute_dtype)


# --------------------------------------------------------------------------
# KV caches
# --------------------------------------------------------------------------
class DenseKVCache(NamedTuple):
    k: Array  # (B, L, Hkv, Dh) cache dtype
    v: Array
    length: Array  # (B,) int32

    @classmethod
    def init(cls, batch, max_len, n_kv, d_head, dtype=jnp.bfloat16):
        shape = (batch, max_len, n_kv, d_head)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )


class AnchoredKVCache(NamedTuple):
    """RCLL-KV: closed blocks anchored+quantized, open block fp32 tail.

    k_resid/v_resid: (B, nblk, blk, Hkv, Dh) residual dtype
    k_anchor/k_scale/...: (B, nblk, 1, Hkv, Dh) fp32
    tail_k/tail_v: (B, blk, Hkv, Dh) fp32 - the open (unquantized) block
    length: (B,) int32 total tokens
    """

    k_resid: Array
    k_anchor: Array
    k_scale: Array
    v_resid: Array
    v_anchor: Array
    v_scale: Array
    tail_k: Array
    tail_v: Array
    length: Array

    @classmethod
    def init(cls, batch, max_len, n_kv, d_head, block=128,
             resid_dtype=jnp.int8):
        nblk = max_len // block
        rs = (batch, nblk, block, n_kv, d_head)
        an = (batch, nblk, 1, n_kv, d_head)
        tl = (batch, block, n_kv, d_head)
        z = jnp.zeros
        return cls(
            k_resid=z(rs, resid_dtype), k_anchor=z(an, jnp.float32),
            k_scale=z(an, jnp.float32), v_resid=z(rs, resid_dtype),
            v_anchor=z(an, jnp.float32), v_scale=z(an, jnp.float32),
            tail_k=z(tl, jnp.float32), tail_v=z(tl, jnp.float32),
            length=z((batch,), jnp.int32),
        )

    @property
    def block(self) -> int:
        return self.tail_k.shape[1]


def dense_cache_update(cache: DenseKVCache, k_new, v_new):
    """Insert one token's k/v at position `length` (per batch row)."""
    B = k_new.shape[0]
    idx = cache.length  # (B,)
    k = jax.vmap(
        lambda c, kn, i: jax.lax.dynamic_update_slice_in_dim(c, kn, i, 0)
    )(cache.k, k_new.astype(cache.k.dtype), idx)
    v = jax.vmap(
        lambda c, vn, i: jax.lax.dynamic_update_slice_in_dim(c, vn, i, 0)
    )(cache.v, v_new.astype(cache.v.dtype), idx)
    return DenseKVCache(k=k, v=v, length=cache.length + 1)


def decode_attention_dense(p, x, cache: DenseKVCache, *, n_heads, n_kv,
                           d_head, rope_theta=10000.0,
                           compute_dtype=layers.DEFAULT_COMPUTE,
                           use_rope=True):
    """One-token decode with a dense cache. x: (B, 1, d_model)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(p, x, n_heads, n_kv, d_head, compute_dtype)
    pos = cache.length[:, None]  # (B, 1)
    if use_rope:
        q = layers.apply_rope(q, pos, rope_theta)
        k_new = layers.apply_rope(k_new, pos, rope_theta)
    cache = dense_cache_update(cache, k_new, v_new)
    out = sdpa(q, cache.k, cache.v, causal=False, length=cache.length)
    out = out.astype(compute_dtype).reshape(B, 1, n_heads * d_head)
    return out @ p["wo"].astype(compute_dtype), cache


def _quantize_block(tail, resid_dtype):
    """anchor/scale/residual for one (B, blk, Hkv, Dh) block.

    Returns anchor/scale (B, 1, Hkv, Dh) and residual (B, blk, Hkv, Dh) -
    the same math as core.anchored.encode, specialized to this layout.
    """
    a, s, r = _quant_blocks(tail[:, None], resid_dtype)
    return a[:, 0], s[:, 0], r[:, 0]


def anchored_cache_update(cache: AnchoredKVCache, k_new, v_new):
    """Append one token. When the tail fills, quantize it into its block
    slot (branch-free: the conditional is a jnp.where on `full`)."""
    B, blk = cache.tail_k.shape[0], cache.block
    pos_in_blk = cache.length % blk  # (B,)
    blk_idx = cache.length // blk

    def upd_tail(tail, new):
        return jax.vmap(
            lambda t, n, i: jax.lax.dynamic_update_slice_in_dim(
                t, n.astype(t.dtype), i, 0)
        )(tail, new, pos_in_blk)

    tail_k = upd_tail(cache.tail_k, k_new)
    tail_v = upd_tail(cache.tail_v, v_new)

    full = (pos_in_blk == blk - 1)  # (B,) tail just completed a block
    ka, ks, kr = _quantize_block(tail_k, cache.k_resid.dtype)
    va, vs, vr = _quantize_block(tail_v, cache.v_resid.dtype)

    def put(dst, src, flag):
        cur = jax.vmap(lambda d, i: jax.lax.dynamic_index_in_dim(
            d, i, 0, keepdims=True))(dst, blk_idx)
        new = jnp.where(flag[:, None, None, None, None],
                        src[:, None], cur)
        return jax.vmap(lambda d, n, i: jax.lax.dynamic_update_slice_in_dim(
            d, n, i, 0))(dst, new.astype(dst.dtype), blk_idx)

    out = AnchoredKVCache(
        k_resid=put(cache.k_resid, kr, full),
        k_anchor=put(cache.k_anchor, ka, full),
        k_scale=put(cache.k_scale, ks, full),
        v_resid=put(cache.v_resid, vr, full),
        v_anchor=put(cache.v_anchor, va, full),
        v_scale=put(cache.v_scale, vs, full),
        tail_k=tail_k, tail_v=tail_v,
        length=cache.length + 1,
    )
    return out


def anchored_cache_from_prefill(k, v, length, block=128,
                                resid_dtype=jnp.int8):
    """Quantize prefill K/V (B, L, Hkv, Dh) into an AnchoredKVCache."""
    B, L, Hkv, Dh = k.shape
    nblk = L // block
    kb = k.astype(jnp.float32).reshape(B, nblk, block, Hkv, Dh)
    vb = v.astype(jnp.float32).reshape(B, nblk, block, Hkv, Dh)
    ka, ks, kr = _quant_blocks(kb, resid_dtype)
    va, vs, vr = _quant_blocks(vb, resid_dtype)
    tail = jnp.zeros((B, block, Hkv, Dh), jnp.float32)
    return AnchoredKVCache(
        k_resid=kr, k_anchor=ka, k_scale=ks,
        v_resid=vr, v_anchor=va, v_scale=vs,
        tail_k=tail, tail_v=tail, length=length,
    )


def _quant_blocks(xb, resid_dtype):
    """xb: (B, nblk, blk, Hkv, Dh) -> anchors (B,nblk,1,...), residuals."""
    anchor = jnp.mean(xb, axis=2, keepdims=True)
    dev = xb - anchor
    scale = jnp.maximum(jnp.max(jnp.abs(dev), axis=2, keepdims=True), 1e-30)
    resid = dev / scale
    if jnp.dtype(resid_dtype) == jnp.int8:
        resid = jnp.clip(jnp.round(resid * 127.0), -127, 127).astype(jnp.int8)
    else:
        resid = resid.astype(resid_dtype)
    return anchor, scale, resid


def _dequant(resid, anchor, scale):
    if resid.dtype == jnp.int8:
        r = resid.astype(jnp.float32) * (1.0 / 127.0)
    else:
        r = resid.astype(jnp.float32)
    return anchor + scale * r


def decode_attention_anchored(p, x, cache: AnchoredKVCache, *, n_heads,
                              n_kv, d_head, rope_theta=10000.0,
                              compute_dtype=layers.DEFAULT_COMPUTE,
                              use_rope=True):
    """One-token decode over the RCLL-KV cache (XLA path; the Pallas
    kernel kernels/rcll_kv_attention.py implements the same math)."""
    B = x.shape[0]
    q, k_new, v_new = _qkv(p, x, n_heads, n_kv, d_head, compute_dtype)
    pos = cache.length[:, None]
    if use_rope:
        q = layers.apply_rope(q, pos, rope_theta)
        k_new = layers.apply_rope(k_new, pos, rope_theta)
    cache = anchored_cache_update(cache, k_new.astype(jnp.float32),
                                  v_new.astype(jnp.float32))
    B_, nblk, blk, Hkv, Dh = cache.k_resid.shape
    k_closed = _dequant(cache.k_resid, cache.k_anchor, cache.k_scale)
    v_closed = _dequant(cache.v_resid, cache.v_anchor, cache.v_scale)
    k_closed = k_closed.reshape(B, nblk * blk, Hkv, Dh)
    v_closed = v_closed.reshape(B, nblk * blk, Hkv, Dh)
    # closed blocks cover [0, length - length%blk); tail covers the rest
    closed_len = (cache.length // blk) * blk
    kk = jnp.concatenate([k_closed, cache.tail_k], axis=1)
    vv = jnp.concatenate([v_closed, cache.tail_v], axis=1)
    # mask: closed region < closed_len, tail region < length%blk
    Lk = kk.shape[1]
    cols = jnp.arange(Lk)[None, :]
    in_closed = (cols < closed_len[:, None])
    in_tail = (cols >= nblk * blk) & (
        (cols - nblk * blk) < (cache.length - closed_len)[:, None])
    valid = in_closed | in_tail
    out = _sdpa_masked(q, kk, vv, valid)
    out = out.astype(compute_dtype).reshape(B, 1, n_heads * d_head)
    return out @ p["wo"].astype(compute_dtype), cache


def _sdpa_masked(q, k, v, valid):
    B, Lq, H, Dh = q.shape
    Lk, Hkv = k.shape[1], k.shape[2]
    rep = H // Hkv
    qg = q.reshape(B, Lq, Hkv, rep, Dh).astype(jnp.float32)
    s = jnp.einsum("blgrd,bmgd->bgrlm", qg, k.astype(jnp.float32))
    s = s / np.sqrt(Dh)
    s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p_ = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bgrlm,bmgd->blgrd", p_, v.astype(jnp.float32))
    return out.reshape(B, Lq, H, Dh)
