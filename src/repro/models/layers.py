"""Shared building blocks: norms, RoPE, MLPs, embeddings, losses.

Functional style throughout: ``init_*(key, ...) -> params dict`` and pure
apply functions. Explicit dtypes: params are stored fp32 (master) and cast
to the compute dtype at use; normalization/softmax/loss accumulate fp32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import partitioning as pt

Array = jnp.ndarray

DEFAULT_COMPUTE = jnp.bfloat16


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -3.0, 3.0, shape, dtype)


def dense_init(key, d_in, d_out, dtype=jnp.float32):
    return truncated_normal(key, (d_in, d_out), 1.0 / np.sqrt(d_in), dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def init_rmsnorm(d):
    return {"norm_w": jnp.ones((d,), jnp.float32)}


def rms_norm(p, x, eps=1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["norm_w"]
    return out.astype(x.dtype)


def init_layernorm(d):
    return {"norm_w": jnp.ones((d,), jnp.float32),
            "norm_bias": jnp.zeros((d,), jnp.float32)}


def layer_norm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["norm_w"] + p["norm_bias"]
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float = 10000.0) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, jnp.float32) / d_head))


def apply_rope(x: Array, positions: Array, theta: float = 10000.0) -> Array:
    """x: (..., L, H, Dh) or (..., L, Dh); positions: (..., L)."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)  # (dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, dh/2)
    if x.ndim == ang.ndim + 1:  # head axis present
        ang = ang[..., None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(length: int, d: int) -> Array:
    pos = np.arange(length)[:, None]
    div = np.exp(np.arange(0, d, 2) * (-np.log(10000.0) / d))
    pe = np.zeros((length, d), np.float32)
    pe[:, 0::2] = np.sin(pos * div)
    pe[:, 1::2] = np.cos(pos * div)
    return jnp.asarray(pe)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------
def init_swiglu(key, d_model, d_ff):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff),
        "w_up": dense_init(k2, d_model, d_ff),
        "w_down": dense_init(k3, d_ff, d_model),
    }


def _act_hidden(h):
    """Constrain an MLP hidden activation of any rank: leading axis on
    the DP axes, trailing (ffn) axis on "model"."""
    return pt.act(h, "batch", *([None] * (h.ndim - 2)), "model")


def swiglu(p, x, compute_dtype=DEFAULT_COMPUTE):
    xc = x.astype(compute_dtype)
    g = xc @ p["w_gate"].astype(compute_dtype)
    u = xc @ p["w_up"].astype(compute_dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    h = _act_hidden(h)
    return h @ p["w_down"].astype(compute_dtype)


def init_gelu_mlp(key, d_model, d_ff):
    k1, k2 = jax.random.split(key)
    return {
        "w_up": dense_init(k1, d_model, d_ff),
        "w_down": dense_init(k2, d_ff, d_model),
    }


def gelu_mlp(p, x, compute_dtype=DEFAULT_COMPUTE):
    xc = x.astype(compute_dtype)
    h = xc @ p["w_up"].astype(compute_dtype)
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(compute_dtype)
    h = _act_hidden(h)
    return h @ p["w_down"].astype(compute_dtype)


# --------------------------------------------------------------------------
# Embedding / logits
# --------------------------------------------------------------------------
def init_embed(key, vocab, d_model, tied: bool = True):
    # 1/sqrt(d) scale keeps tied-unembedding logits O(1) at init.
    p = {"embed": truncated_normal(key, (vocab, d_model),
                                   1.0 / np.sqrt(d_model))}
    if not tied:
        p["unembed"] = truncated_normal(
            jax.random.fold_in(key, 1), (vocab, d_model), 1.0 / np.sqrt(d_model)
        )
    return p


def embed(p, tokens, compute_dtype=DEFAULT_COMPUTE):
    out = jnp.take(p["embed"].astype(compute_dtype), tokens, axis=0)
    return pt.act(out, "batch", None, None)


def logits(p, x, compute_dtype=DEFAULT_COMPUTE):
    w = p.get("unembed", p["embed"]).astype(compute_dtype)
    out = x.astype(compute_dtype) @ w.T
    out = pt.act_vocab(out)
    return out.astype(jnp.float32)


def cross_entropy(lg: Array, labels: Array, z_loss: float = 1e-4):
    """Mean token cross-entropy with optional z-loss, fp32 accumulation.

    The label pick is an iota-compare reduction, not take_along_axis: a
    gather along the vocab axis would force GSPMD to all-gather the
    vocab-sharded logits (measured: +30GiB/device on llama3-3b train);
    the masked sum partitions cleanly (each vocab shard sums its slice).
    """
    lg = lg.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, lg.ndim - 1)
    ll = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], lg, 0.0), axis=-1)
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * lse**2
    return jnp.mean(loss)
