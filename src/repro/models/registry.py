"""Architecture registry: --arch <id> -> configs, module entry points,
and ShapeDtypeStruct input specs for every (arch x shape) dry-run cell.
"""
from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, runnable
from repro.models import encdec, hybrid, transformer

ARCH_MODULES = {
    "granite-3-8b": "repro.configs.granite_3_8b",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "internlm2-20b": "repro.configs.internlm2_20b",
    "llama3.2-3b": "repro.configs.llama3_2_3b",
    "deepseek-v2-236b": "repro.configs.deepseek_v2_236b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "zamba2-1.2b": "repro.configs.zamba2_1_2b",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "mamba2-130m": "repro.configs.mamba2_130m",
}

ARCH_IDS = list(ARCH_MODULES)


def get_config(arch: str, smoke: bool = False) -> transformer.ArchConfig:
    mod = importlib.import_module(ARCH_MODULES[arch])
    return mod.SMOKE if smoke else mod.CONFIG


def get_module(cfg: transformer.ArchConfig):
    """The model module implementing this family's entry points."""
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "encdec":
        return encdec
    return transformer


def abstract_params(cfg: transformer.ArchConfig):
    """ShapeDtypeStruct params pytree (no allocation - jax.eval_shape)."""
    mod = get_module(cfg)
    return jax.eval_shape(
        lambda k: mod.init_params(k, cfg), jax.random.key(0))


def init_params(key, cfg):
    return get_module(cfg).init_params(key, cfg)


# --------------------------------------------------------------------------
# Input specs per (arch, shape): ShapeDtypeStructs only.
# --------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: transformer.ArchConfig, shape: ShapeSpec) -> dict:
    """Abstract inputs for the step function selected by shape.kind.

    train:   {"tokens","labels"} (+frames/patch_embeds stubs)
    prefill: {"tokens"} (+stubs)
    decode:  {"tokens" (B,1), "cache": pytree}
    """
    B, L = shape.global_batch, shape.seq_len
    mod = get_module(cfg)
    if shape.kind == "train":
        batch = {
            "tokens": _sds((B, L), jnp.int32),
            "labels": _sds((B, L), jnp.int32),
        }
        if cfg.family == "encdec":
            batch["frames"] = _sds((B, cfg.src_len, cfg.d_model),
                                   jnp.bfloat16)
        if cfg.family == "vlm":
            batch["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                         jnp.bfloat16)
        return {"batch": batch}
    if shape.kind == "prefill":
        out: dict[str, Any] = {"tokens": _sds((B, L), jnp.int32)}
        if cfg.family == "encdec":
            out["frames"] = _sds((B, cfg.src_len, cfg.d_model),
                                 jnp.bfloat16)
        if cfg.family == "vlm":
            out["patch_embeds"] = _sds((B, cfg.n_patches, cfg.d_model),
                                       jnp.bfloat16)
        return out
    # decode: abstract cache of size L
    cache = jax.eval_shape(lambda: mod.init_cache(cfg, B, L))
    return {"tokens": _sds((B, 1), jnp.int32), "cache": cache}


def runnable_cells(smoke: bool = False):
    """All (arch, shape) pairs that must lower+compile (the 32 cells)."""
    cells = []
    for arch in ARCH_IDS:
        cfg = get_config(arch, smoke=smoke)
        for sname, sp in SHAPES.items():
            if runnable(cfg.family, sname):
                cells.append((arch, sname))
    return cells
