"""Mamba2 / SSD (state-space duality, arXiv:2405.21060).

Chunked SSD for training/prefill (the "quadratic-in-chunk, linear-across-
chunks" algorithm of Listing 1 in the paper), and the O(1)-per-token
recurrent form for decode. State is fp32 (an accumulator - the SPH
paper's own rule: integrators stay high precision; see DESIGN.md
section 4 on why RCLL-style quantization is *not* applied here).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models import partitioning as pt
from repro.models import scan_config

Array = jnp.ndarray


class SSMDims(NamedTuple):
    d_model: int
    d_inner: int  # expand * d_model
    n_heads: int  # d_inner / head_dim
    head_dim: int
    d_state: int
    n_groups: int
    d_conv: int


def make_dims(d_model, d_state, *, expand=2, head_dim=64, n_groups=1,
              d_conv=4) -> SSMDims:
    d_inner = expand * d_model
    return SSMDims(d_model, d_inner, d_inner // head_dim, head_dim,
                   d_state, n_groups, d_conv)


def init_mamba2(key, dims: SSMDims):
    ks = jax.random.split(key, 4)
    d_in_proj = (2 * dims.d_inner + 2 * dims.n_groups * dims.d_state
                 + dims.n_heads)
    conv_dim = dims.d_inner + 2 * dims.n_groups * dims.d_state
    return {
        "in_proj": layers.dense_init(ks[0], dims.d_model, d_in_proj),
        "conv_w": layers.truncated_normal(
            ks[1], (dims.d_conv, conv_dim), 1.0 / np.sqrt(dims.d_conv)),
        "conv_bias": jnp.zeros((conv_dim,), jnp.float32),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, dims.n_heads)),
        "dt_bias": jnp.zeros((dims.n_heads,), jnp.float32),
        "d_skip": jnp.ones((dims.n_heads,), jnp.float32),
        "out_norm": layers.init_rmsnorm(dims.d_inner),
        "out_proj": layers.dense_init(ks[3], dims.d_inner, dims.d_model),
    }


def _split_proj(z_xbc_dt, dims: SSMDims):
    di, g, n, h = dims.d_inner, dims.n_groups, dims.d_state, dims.n_heads
    z = z_xbc_dt[..., :di]
    xbc = z_xbc_dt[..., di : 2 * di + 2 * g * n]
    dt = z_xbc_dt[..., 2 * di + 2 * g * n :]
    return z, xbc, dt


def _segsum(a):
    """Stable segment-sum: out[..., i, j] = sum_{j < s <= i} a[..., s]."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, a, B, C, dims: SSMDims, chunk: int,
                init_state=None, einsum_dtype=None):
    """Chunked SSD scan.

    x:  (b, L, h, p) head inputs
    dt: (b, L, h) softplus'd timesteps
    a:  (h,) negative decay rates (-exp(a_log))
    B, C: (b, L, g, n)
    Returns (y (b, L, h, p), final_state (b, h, p, n)).
    """
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    chunk = min(chunk, L)
    pad = (-L) % chunk
    if pad:
        # dt=0 padding is exact: decay exp(0)=1, contribution dt*x*B=0,
        # so the final state is untouched and padded outputs are sliced.
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad)) + ((0, 0),) * (t.ndim - 2))
        x, dt, B, C = zpad(x), zpad(dt), zpad(B), zpad(C)
        L = L + pad
    nc = L // chunk
    rep = h // g

    xb = x.reshape(b, nc, chunk, h, p)
    dtb = dt.reshape(b, nc, chunk, h)
    Bb = B.reshape(b, nc, chunk, g, n)
    Cb = C.reshape(b, nc, chunk, g, n)
    Bh = jnp.repeat(Bb, rep, axis=3)  # (b,nc,l,h,n)
    Ch = jnp.repeat(Cb, rep, axis=3)

    da = dtb * a[None, None, None, :]  # (b,nc,l,h)
    da_t = da.transpose(0, 1, 3, 2)  # (b,nc,h,l)
    Lmat = jnp.exp(_segsum(da_t))  # (b,nc,h,l,l)

    # intra-chunk (quadratic within chunk). Perf C1: the two big
    # einsums optionally run in bf16 (decay/cumsum math stays fp32 -
    # the paper's accumulator rule); fp32 is the faithful default.
    ed = einsum_dtype or jnp.float32
    s = jnp.einsum("bclhn,bcmhn->bchlm", Ch.astype(ed), Bh.astype(ed))
    y_diag = jnp.einsum(
        "bchlm,bchlm,bcmh,bcmhp->bclhp",
        s.astype(ed), Lmat.astype(ed), dtb.astype(ed), xb.astype(ed)
    ).astype(jnp.float32)

    # chunk-final states
    cums = jnp.cumsum(da_t, axis=-1)
    decay_to_end = jnp.exp(cums[..., -1:] - cums)  # (b,nc,h,l)
    states = jnp.einsum("bclhn,bchl,bclh,bclhp->bchpn",
                        Bh.astype(ed), decay_to_end.astype(ed),
                        dtb.astype(ed), xb.astype(ed)).astype(jnp.float32)

    # inter-chunk recurrence (sequential scan over nc chunks)
    chunk_decay = jnp.exp(cums[..., -1])  # (b,nc,h) total decay per chunk
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def scan_fn(carry, inp):
        st, dec = inp  # st (b,h,p,n), dec (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry  # emit state *entering* this chunk

    final, entering = jax.lax.scan(
        scan_fn, init_state,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
        unroll=scan_config.unroll(),
    )
    entering = entering.transpose(1, 0, 2, 3, 4)  # (b,nc,h,p,n)

    # contribution of the entering state to each position
    decay_from_start = jnp.exp(cums)  # (b,nc,h,l)
    y_off = jnp.einsum("bclhn,bchl,bchpn->bclhp",
                       Ch.astype(ed), decay_from_start.astype(ed),
                       entering.astype(ed)).astype(jnp.float32)
    y = (y_diag + y_off).reshape(b, L, h, p)
    if pad:
        y = y[:, : L - pad]
    return y, final


def mamba2_forward(p, x, dims: SSMDims, *, chunk=128,
                   compute_dtype=layers.DEFAULT_COMPUTE,
                   ssd_compute: str = "fp32"):
    """Full-sequence Mamba2 block. x: (B, L, d_model).

    Returns (out, Mamba2Cache) - the cache is decode-ready (final SSM
    state + the last d_conv-1 raw conv inputs)."""
    Bsz, L, _ = x.shape
    proj = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    proj = pt.act(proj, "batch", None, "model")
    z, xbc, dt = _split_proj(proj, dims)
    # causal depthwise conv over xbc
    w = p["conv_w"].astype(jnp.float32)  # (d_conv, conv_dim)
    xbc_f = xbc.astype(jnp.float32)
    conv_tail = xbc_f[:, L - (dims.d_conv - 1):, :]  # decode conv history
    pad = jnp.pad(xbc_f, ((0, 0), (dims.d_conv - 1, 0), (0, 0)))
    conv = sum(
        pad[:, i : i + L] * w[i][None, None, :]
        for i in range(dims.d_conv)
    ) + p["conv_bias"]
    xbc = jax.nn.silu(conv)
    xs = xbc[..., : dims.d_inner]
    Bc = xbc[..., dims.d_inner : dims.d_inner + dims.n_groups * dims.d_state]
    Cc = xbc[..., dims.d_inner + dims.n_groups * dims.d_state :]
    xh = xs.reshape(Bsz, L, dims.n_heads, dims.head_dim)
    Bm = Bc.reshape(Bsz, L, dims.n_groups, dims.d_state)
    Cm = Cc.reshape(Bsz, L, dims.n_groups, dims.d_state)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y, state = ssd_chunked(xh.astype(jnp.float32), dt_, a, Bm, Cm, dims,
                           chunk, einsum_dtype=(
                               jnp.bfloat16 if ssd_compute == "bf16"
                               else jnp.float32))
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(Bsz, L, dims.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32))  # gated
    y = layers.rms_norm(p["out_norm"], y.astype(compute_dtype))
    out = y @ p["out_proj"].astype(compute_dtype)
    return out, Mamba2Cache(state=state, conv_buf=conv_tail)


class Mamba2Cache(NamedTuple):
    state: Array  # (B, h, p, n) fp32 SSM state
    conv_buf: Array  # (B, d_conv-1, conv_dim) fp32 conv history

    @classmethod
    def init(cls, batch, dims: SSMDims):
        conv_dim = dims.d_inner + 2 * dims.n_groups * dims.d_state
        return cls(
            state=jnp.zeros(
                (batch, dims.n_heads, dims.head_dim, dims.d_state),
                jnp.float32),
            conv_buf=jnp.zeros((batch, dims.d_conv - 1, conv_dim),
                               jnp.float32),
        )


def mamba2_decode(p, x, cache: Mamba2Cache, dims: SSMDims,
                  compute_dtype=layers.DEFAULT_COMPUTE):
    """Single-token recurrent step. x: (B, 1, d_model)."""
    Bsz = x.shape[0]
    proj = x.astype(compute_dtype) @ p["in_proj"].astype(compute_dtype)
    z, xbc, dt = _split_proj(proj[:, 0], dims)  # (B, *)
    w = p["conv_w"].astype(jnp.float32)
    hist = jnp.concatenate(
        [cache.conv_buf, xbc.astype(jnp.float32)[:, None]], axis=1)
    conv = jnp.einsum("btc,tc->bc", hist, w) + p["conv_bias"]
    conv_buf = hist[:, 1:]
    xbc_a = jax.nn.silu(conv)
    xs = xbc_a[..., : dims.d_inner]
    Bc = xbc_a[..., dims.d_inner : dims.d_inner + dims.n_groups * dims.d_state]
    Cc = xbc_a[..., dims.d_inner + dims.n_groups * dims.d_state :]
    xh = xs.reshape(Bsz, dims.n_heads, dims.head_dim)
    rep = dims.n_heads // dims.n_groups
    Bm = jnp.repeat(Bc.reshape(Bsz, dims.n_groups, dims.d_state), rep, 1)
    Cm = jnp.repeat(Cc.reshape(Bsz, dims.n_groups, dims.d_state), rep, 1)
    dt_ = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,h)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt_ * a[None, :])  # (B,h)
    state = (cache.state * decay[..., None, None]
             + jnp.einsum("bh,bhp,bhn->bhpn", dt_, xh, Bm))
    y = jnp.einsum("bhpn,bhn->bhp", state, Cm)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(Bsz, dims.d_inner) * jax.nn.silu(z.astype(jnp.float32))
    y = layers.rms_norm(p["out_norm"], y.astype(compute_dtype))
    out = (y @ p["out_proj"].astype(compute_dtype))[:, None]
    return out, Mamba2Cache(state=state, conv_buf=conv_buf)
