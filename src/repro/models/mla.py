"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

KV compression: the cache holds only the latent c_kv (kv_lora_rank dims)
plus a shared decoupled RoPE key (qk_rope dims) per token - 576 floats
per token for the 236B config, independent of the 128 heads. Decode uses
the absorbed form: W_uk is folded into the query so attention runs in the
latent space directly; W_uv is applied after the value aggregation.

The latent cache is itself a natural RCLL-KV target: block-anchored int8
latents cut decode bytes a further ~4x (see AnchoredKVCache; wired in
transformer.py when kv_mode='anchored').
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models import partitioning as pt
from repro.models import scan_config

Array = jnp.ndarray


def init_mla(key, d_model, n_heads, *, q_lora, kv_lora, qk_nope, qk_rope,
             v_head):
    ks = jax.random.split(key, 7)
    dqk = qk_nope + qk_rope
    return {
        # query path: d -> q_lora -> heads*(qk_nope + qk_rope)
        "wq_a": layers.dense_init(ks[0], d_model, q_lora),
        "q_norm": layers.init_rmsnorm(q_lora),
        "wq_b": layers.dense_init(ks[1], q_lora, n_heads * dqk),
        # kv path: d -> kv_lora (cached) + shared rope key (cached)
        "wkv_a": layers.dense_init(ks[2], d_model, kv_lora + qk_rope),
        "kv_norm": layers.init_rmsnorm(kv_lora),
        # up-projections from the latent
        "wkv_b": layers.dense_init(
            ks[3], kv_lora, n_heads * (qk_nope + v_head)),
        "wo": layers.dense_init(ks[4], n_heads * v_head, d_model),
    }


class MLADims(NamedTuple):
    n_heads: int
    q_lora: int
    kv_lora: int
    qk_nope: int
    qk_rope: int
    v_head: int


class MLACache(NamedTuple):
    c_kv: Array  # (B, L, kv_lora) latent cache
    k_rope: Array  # (B, L, qk_rope) shared rope key
    length: Array  # (B,) int32

    @classmethod
    def init(cls, batch, max_len, kv_lora, qk_rope, dtype=jnp.bfloat16):
        return cls(
            c_kv=jnp.zeros((batch, max_len, kv_lora), dtype),
            k_rope=jnp.zeros((batch, max_len, qk_rope), dtype),
            length=jnp.zeros((batch,), jnp.int32),
        )


def _project_q(p, x, dims: MLADims, compute_dtype):
    B, L, _ = x.shape
    xc = x.astype(compute_dtype)
    cq = xc @ p["wq_a"].astype(compute_dtype)
    cq = layers.rms_norm(p["q_norm"], cq)
    q = (cq @ p["wq_b"].astype(compute_dtype)).reshape(
        B, L, dims.n_heads, dims.qk_nope + dims.qk_rope)
    return q[..., : dims.qk_nope], q[..., dims.qk_nope:]


def _project_kv_latent(p, x, dims: MLADims, compute_dtype):
    xc = x.astype(compute_dtype)
    ckv = xc @ p["wkv_a"].astype(compute_dtype)
    c_kv, k_rope = ckv[..., : dims.kv_lora], ckv[..., dims.kv_lora:]
    c_kv = layers.rms_norm(p["kv_norm"], c_kv)
    return c_kv, k_rope


def mla_full(p, x, positions, dims: MLADims, *, rope_theta=10000.0,
             compute_dtype=layers.DEFAULT_COMPUTE, kv_hoist: bool = False):
    """Train/prefill MLA (naive materialized form). Returns (out, cache
    tensors (c_kv, k_rope))."""
    B, L, _ = x.shape
    H, dn, dr, dv = dims.n_heads, dims.qk_nope, dims.qk_rope, dims.v_head
    q_nope, q_rope = _project_q(p, x, dims, compute_dtype)
    q_rope = layers.apply_rope(q_rope, positions, rope_theta)
    c_kv, k_rope = _project_kv_latent(p, x, dims, compute_dtype)
    k_rope = layers.apply_rope(k_rope[..., None, :], positions, rope_theta)[
        ..., 0, :]
    kv = (c_kv @ p["wkv_b"].astype(compute_dtype)).reshape(
        B, L, H, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]
    q_nope = pt.act(q_nope, "batch", None, "model", None)
    if kv_hoist:  # gather once before the q-chunk loop (Perf A1)
        k_nope = pt.act(k_nope, "batch", None, "model", None)
        v = pt.act(v, "batch", None, "model", None)
        k_rope = pt.act(k_rope, "batch", None, None)
    scale = 1.0 / np.sqrt(dn + dr)

    # query-blocked (memory-linear) attention; scores never materialize
    # beyond (B, H, chunk, L). Exact math, same tiling as attention.py.
    chunk = 256 if (L % 256 == 0 and L > 256) else L
    nc = L // chunk

    def one(args):
        i, qn, qr = args  # qn (B, chunk, H, dn), qr (B, chunk, H, dr)
        s = (
            jnp.einsum("blhd,bmhd->bhlm", qn.astype(jnp.float32),
                       k_nope.astype(jnp.float32))
            + jnp.einsum("blhd,bmd->bhlm", qr.astype(jnp.float32),
                         k_rope.astype(jnp.float32))
        ) * scale
        rows = i * chunk + jnp.arange(chunk)[:, None]
        cols = jnp.arange(L)[None, :]
        s = jnp.where((rows >= cols)[None, None], s, -1e30)
        attn = jax.nn.softmax(s, axis=-1)
        return jnp.einsum("bhlm,bmhd->blhd", attn,
                          v.astype(jnp.float32))

    qn_c = q_nope.reshape(B, nc, chunk, H, dn).transpose(1, 0, 2, 3, 4)
    qr_c = q_rope.reshape(B, nc, chunk, H, dr).transpose(1, 0, 2, 3, 4)
    _, out = jax.lax.scan(lambda c, a: (None, one(a)), None,
                          (jnp.arange(nc), qn_c, qr_c),
                          unroll=scan_config.unroll())
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, L, H, dv)
    out = out.astype(compute_dtype).reshape(B, L, H * dv)
    return out @ p["wo"].astype(compute_dtype), (c_kv, k_rope)


def mla_decode(p, x, cache: MLACache, dims: MLADims, *, rope_theta=10000.0,
               compute_dtype=layers.DEFAULT_COMPUTE):
    """Absorbed-form single-token decode: attention in the latent space.

    score_h(t) = q_nope_h . (W_uk_h c_t) + q_rope_h . k_rope_t
               = (W_uk_h^T q_nope_h) . c_t + q_rope_h . k_rope_t
    out_h      = W_uv_h (sum_t a_t c_t)
    """
    B = x.shape[0]
    H, dn, dr, dv = dims.n_heads, dims.qk_nope, dims.qk_rope, dims.v_head
    kvl = dims.kv_lora
    q_nope, q_rope = _project_q(p, x, dims, compute_dtype)  # (B,1,H,*)
    pos = cache.length[:, None]
    q_rope = layers.apply_rope(q_rope, pos, rope_theta)
    c_new, kr_new = _project_kv_latent(p, x, dims, compute_dtype)
    kr_new = layers.apply_rope(kr_new[..., None, :], pos, rope_theta)[..., 0, :]

    upd = lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
        c, n.astype(c.dtype), i, 0)
    c_kv = jax.vmap(upd)(cache.c_kv, c_new, cache.length)
    k_rope = jax.vmap(upd)(cache.k_rope, kr_new, cache.length)
    cache = MLACache(c_kv=c_kv, k_rope=k_rope, length=cache.length + 1)

    wkv_b = p["wkv_b"].astype(compute_dtype).reshape(kvl, H, dn + dv)
    w_uk, w_uv = wkv_b[..., :dn], wkv_b[..., dn:]  # (kvl, H, dn/dv)
    # absorb: q_lat (B, H, kvl)
    q_lat = jnp.einsum("bhd,chd->bhc", q_nope[:, 0].astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    s = (
        jnp.einsum("bhc,btc->bht", q_lat, c_kv.astype(jnp.float32))
        + jnp.einsum("bhd,btd->bht", q_rope[:, 0].astype(jnp.float32),
                     k_rope.astype(jnp.float32))
    ) / np.sqrt(dn + dr)
    t = jnp.arange(c_kv.shape[1])[None, None, :]
    s = jnp.where(t < cache.length[:, None, None], s, -1e30)
    attn = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bht,btc->bhc", attn, c_kv.astype(jnp.float32))
    out = jnp.einsum("bhc,chd->bhd", ctx, w_uv.astype(jnp.float32))
    out = out.astype(compute_dtype).reshape(B, 1, H * dv)
    return out @ p["wo"].astype(compute_dtype), cache
