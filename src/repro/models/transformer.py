"""Decoder-only LM assembly: dense / MoE / MLA-MoE / SSM / hybrid
families behind one config + three entry points (forward, prefill,
decode_step), all scan-over-layers (one compiled layer body).

Caches are NamedTuples of stacked (n_layers, ...) arrays so the decode
step scans over layers with the cache as carry.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models import layers, mamba2, mla, moe
from repro.models import partitioning as pt
from repro.models import scan_config

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | mla_moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    d_head: int = 0  # 0 -> d_model // n_heads
    rope_theta: float = 500000.0
    tied_embeddings: bool = True
    norm: str = "rms"
    mlp: str = "swiglu"
    # moe
    n_routed: int = 0
    n_shared: int = 0
    top_k: int = 0
    d_expert: int = 0
    first_k_dense: int = 0
    dense_ff: int = 0  # d_ff of the first_k_dense layers
    capacity_factor: float = 1.25
    # mla
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # ssm / hybrid
    d_state: int = 0
    expand: int = 2
    ssm_head_dim: int = 64
    n_groups: int = 1
    d_conv: int = 4
    attn_every: int = 6  # hybrid: shared attn block period
    # encdec
    n_enc_layers: int = 0
    src_len: int = 1500
    # vlm
    n_patches: int = 0
    # execution
    remat: str = "none"  # none | full
    kv_mode: str = "dense"  # dense | anchored (RCLL-KV)
    kv_block: int = 128
    ssd_chunk: int = 128
    # perf variants (EXPERIMENTS.md section Perf; default = baseline)
    attn_kv_hoist: bool = False  # gather K/V once, not per q-chunk
    ssd_compute: str = "fp32"  # fp32 | bf16 intra-chunk SSD einsums
    moe_cap_shard: bool = False  # shard MoE buffers (E on model, cap on data)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def ssm_dims(self) -> mamba2.SSMDims:
        return mamba2.make_dims(
            self.d_model, self.d_state, expand=self.expand,
            head_dim=self.ssm_head_dim, n_groups=self.n_groups,
            d_conv=self.d_conv)

    @property
    def mla_dims(self) -> mla.MLADims:
        return mla.MLADims(self.n_heads, self.q_lora, self.kv_lora,
                           self.qk_nope, self.qk_rope, self.v_head)

    def param_count(self, params) -> int:
        return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))


def _init_norm(cfg):
    return (layers.init_rmsnorm(cfg.d_model) if cfg.norm == "rms"
            else layers.init_layernorm(cfg.d_model))


def _norm(cfg, p, x):
    return (layers.rms_norm(p, x) if cfg.norm == "rms"
            else layers.layer_norm(p, x))


def _init_mlp(key, cfg, d_ff):
    return (layers.init_swiglu(key, cfg.d_model, d_ff)
            if cfg.mlp == "swiglu"
            else layers.init_gelu_mlp(key, cfg.d_model, d_ff))


def _mlp(cfg, p, x):
    return (layers.swiglu(p, x) if cfg.mlp == "swiglu"
            else layers.gelu_mlp(p, x))


# --------------------------------------------------------------------------
# Layer bodies (full-sequence + decode variants per family)
# --------------------------------------------------------------------------
def init_layer(key, cfg: ArchConfig):
    """One layer's params (to be vmapped into a (n_layers, ...) stack)."""
    k1, k2, k3 = jax.random.split(key, 3)
    p: dict[str, Any] = {"ln1": _init_norm(cfg)}
    if cfg.family in ("dense", "vlm", "moe"):
        p["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim)
    elif cfg.family == "mla_moe":
        p["attn"] = mla.init_mla(
            k1, cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora,
            kv_lora=cfg.kv_lora, qk_nope=cfg.qk_nope,
            qk_rope=cfg.qk_rope, v_head=cfg.v_head)
    elif cfg.family in ("ssm", "hybrid"):
        p["mixer"] = mamba2.init_mamba2(k1, cfg.ssm_dims)
    else:
        raise ValueError(cfg.family)

    if cfg.family in ("dense", "vlm", "mla_moe", "moe"):
        p["ln2"] = _init_norm(cfg)
        if cfg.family in ("moe", "mla_moe"):
            p["moe"] = moe.init_moe(
                k2, cfg.d_model, cfg.d_expert, cfg.n_routed,
                cfg.n_shared, d_shared=cfg.n_shared * cfg.d_expert)
        else:
            p["mlp"] = _init_mlp(k2, cfg, cfg.d_ff)
    return p


def layer_forward(cfg: ArchConfig, p, h, positions):
    """Full-sequence layer. Returns (h, cache_tensors, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if cfg.family in ("ssm", "hybrid"):
        out, cache = mamba2.mamba2_forward(
            p["mixer"], _norm(cfg, p["ln1"], h), cfg.ssm_dims,
            chunk=cfg.ssd_chunk, ssd_compute=cfg.ssd_compute)
        return h + out, cache, aux
    if cfg.family == "mla_moe":
        out, (c_kv, k_rope) = mla.mla_full(
            p["attn"], _norm(cfg, p["ln1"], h), positions, cfg.mla_dims,
            rope_theta=cfg.rope_theta, kv_hoist=cfg.attn_kv_hoist)
        h = h + out
        mo, metrics = moe.moe_block(
            p["moe"], _norm(cfg, p["ln2"], h), top_k=cfg.top_k,
            n_routed=cfg.n_routed, capacity_factor=cfg.capacity_factor,
            cap_shard=cfg.moe_cap_shard)
        return h + mo, (c_kv, k_rope), metrics["aux_loss"]
    # dense / vlm / moe: GQA attention
    out, (k, v) = attn_lib.attention_full(
        p["attn"], _norm(cfg, p["ln1"], h), positions,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
        rope_theta=cfg.rope_theta, kv_hoist=cfg.attn_kv_hoist)
    h = h + out
    if cfg.family == "moe":
        mo, metrics = moe.moe_block(
            p["moe"], _norm(cfg, p["ln2"], h), top_k=cfg.top_k,
            n_routed=cfg.n_routed, capacity_factor=cfg.capacity_factor,
            cap_shard=cfg.moe_cap_shard)
        return h + mo, (k, v), metrics["aux_loss"]
    return h + _mlp(cfg, p["mlp"], _norm(cfg, p["ln2"], h)), (k, v), aux


def layer_decode(cfg: ArchConfig, p, h, cache_l):
    """Single-token decode layer. cache_l: this layer's cache slice."""
    if cfg.family in ("ssm", "hybrid"):
        out, new_cache = mamba2.mamba2_decode(
            p["mixer"], _norm(cfg, p["ln1"], h), cache_l, cfg.ssm_dims)
        return h + out, new_cache
    if cfg.family == "mla_moe":
        out, new_cache = mla.mla_decode(
            p["attn"], _norm(cfg, p["ln1"], h), cache_l, cfg.mla_dims,
            rope_theta=cfg.rope_theta)
        h = h + out
        mo, _ = moe.moe_block(
            p["moe"], _norm(cfg, p["ln2"], h), top_k=cfg.top_k,
            n_routed=cfg.n_routed, capacity_factor=cfg.capacity_factor)
        return h + mo, new_cache
    dec = (attn_lib.decode_attention_anchored
           if cfg.kv_mode == "anchored"
           else attn_lib.decode_attention_dense)
    out, new_cache = dec(
        p["attn"], _norm(cfg, p["ln1"], h), cache_l,
        n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
        rope_theta=cfg.rope_theta)
    h = h + out
    if cfg.family == "moe":
        mo, _ = moe.moe_block(
            p["moe"], _norm(cfg, p["ln2"], h), top_k=cfg.top_k,
            n_routed=cfg.n_routed, capacity_factor=cfg.capacity_factor)
        return h + mo, new_cache
    return h + _mlp(cfg, p["mlp"], _norm(cfg, p["ln2"], h)), new_cache


# --------------------------------------------------------------------------
# Model init / forward / decode
# --------------------------------------------------------------------------
def init_params(key, cfg: ArchConfig):
    ke, kl, kp = jax.random.split(key, 3)
    layer_keys = jax.random.split(kl, cfg.n_layers)
    p = {
        "embed_tokens": layers.init_embed(
            ke, cfg.vocab, cfg.d_model, tied=cfg.tied_embeddings),
        "layers": jax.vmap(lambda k: init_layer(k, cfg))(layer_keys),
        "final_norm": _init_norm(cfg),
    }
    if cfg.family == "vlm":
        p["w_patch"] = layers.dense_init(kp, cfg.d_model, cfg.d_model)
    return p


def _scan_layers(cfg, params_stacked, h, body):
    wrapped = jax.checkpoint(body) if cfg.remat == "full" else body

    def f(carry, p_l):
        h, aux = carry
        h2, cache_l, aux_l = wrapped(p_l, h)
        h2 = pt.act_seq(h2)  # sequence-parallel inter-layer carry
        return (h2, aux + aux_l), cache_l

    (h, aux), caches = jax.lax.scan(f, (h, jnp.zeros((), jnp.float32)),
                                    params_stacked,
                                    unroll=scan_config.unroll())
    return h, caches, aux


def forward(params, tokens, cfg: ArchConfig, *, patch_embeds=None,
            return_cache=False):
    """Full-sequence forward. tokens: (B, L). Returns (logits, caches, aux).

    vlm: patch_embeds (B, n_patches, d_model) replace the first n_patches
    positions (the modality-frontend stub per the assignment)."""
    B, L = tokens.shape
    h = layers.embed(params["embed_tokens"], tokens)
    if cfg.family == "vlm" and patch_embeds is not None:
        pe = patch_embeds.astype(h.dtype) @ params["w_patch"].astype(h.dtype)
        h = jnp.concatenate([pe, h[:, cfg.n_patches:]], axis=1)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    def body(p_l, hh):
        h2, cache_l, aux = layer_forward(cfg, p_l, hh, positions)
        return h2, cache_l, aux

    h, caches, aux = _scan_layers(cfg, params["layers"], h, body)
    h = _norm(cfg, params["final_norm"], h)
    lg = layers.logits(params["embed_tokens"], h)
    return lg, (caches if return_cache else None), aux


def loss_fn(params, batch, cfg: ArchConfig):
    lg, _, aux = forward(params, batch["tokens"], cfg,
                         patch_embeds=batch.get("patch_embeds"))
    loss = layers.cross_entropy(lg[:, :-1], batch["labels"][:, 1:])
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}


# ---- decode ---------------------------------------------------------------
def init_cache(cfg: ArchConfig, batch: int, max_len: int):
    """Stacked (n_layers leading axis) cache pytree."""
    L = cfg.n_layers

    def stack(x):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (L,) + a.shape).copy(), x)

    if cfg.family in ("ssm", "hybrid"):
        return stack(mamba2.Mamba2Cache.init(batch, cfg.ssm_dims))
    if cfg.family == "mla_moe":
        return stack(mla.MLACache.init(batch, max_len, cfg.kv_lora,
                                       cfg.qk_rope))
    if cfg.kv_mode == "anchored":
        return stack(attn_lib.AnchoredKVCache.init(
            batch, max_len, cfg.n_kv, cfg.head_dim, block=cfg.kv_block))
    return stack(attn_lib.DenseKVCache.init(
        batch, max_len, cfg.n_kv, cfg.head_dim))


def decode_step(params, tokens, cache, cfg: ArchConfig):
    """One-token decode. tokens: (B, 1). Returns (logits, new cache)."""
    h = layers.embed(params["embed_tokens"], tokens)

    def f(h, xs):
        p_l, cache_l = xs
        h2, new_cache = layer_decode(cfg, p_l, h, cache_l)
        return h2, new_cache

    h, new_cache = jax.lax.scan(f, h, (params["layers"], cache),
                                unroll=scan_config.unroll())
    h = _norm(cfg, params["final_norm"], h)
    return layers.logits(params["embed_tokens"], h), new_cache


def prefill(params, tokens, cfg: ArchConfig, max_len: int, *,
            patch_embeds=None):
    """Prefill: forward + build a decode-ready cache of size max_len."""
    B, L = tokens.shape
    lg, caches, _ = forward(params, tokens, cfg, patch_embeds=patch_embeds,
                            return_cache=True)
    length = jnp.full((B,), L, jnp.int32)
    if cfg.family in ("ssm", "hybrid"):
        return lg, caches  # stacked Mamba2Cache (state + conv tail)
    if cfg.family == "mla_moe":
        c_kv, k_rope = caches  # (n_layers, B, L, *)
        pad = max_len - L
        c_kv = jnp.pad(c_kv.astype(jnp.bfloat16),
                       ((0, 0), (0, 0), (0, pad), (0, 0)))
        k_rope = jnp.pad(k_rope.astype(jnp.bfloat16),
                         ((0, 0), (0, 0), (0, pad), (0, 0)))
        return lg, mla.MLACache(
            c_kv=c_kv, k_rope=k_rope,
            length=jnp.broadcast_to(length, (cfg.n_layers, B)))
    k, v = caches  # (n_layers, B, L, Hkv, Dh)
    pad = max_len - L
    k = jnp.pad(k.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if cfg.kv_mode == "anchored":
        cache = jax.vmap(
            lambda kk, vv: attn_lib.anchored_cache_from_prefill(
                kk, vv, length, block=cfg.kv_block)
        )(k, v)
        return lg, cache
    return lg, attn_lib.DenseKVCache(
        k=k, v=v, length=jnp.broadcast_to(length, (cfg.n_layers, B)))
