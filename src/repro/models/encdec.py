"""Whisper-style encoder-decoder backbone (arXiv:2212.04356).

The audio frontend (log-mel + conv downsampling) is a STUB per the
assignment: ``input_specs()`` feeds precomputed frame embeddings
(B, src_len, d_model) straight into the encoder. Decoder = causal
self-attention + cross-attention + GELU MLP, LayerNorm, sinusoidal
positions (simplification of Whisper's learned decoder embeddings,
noted in DESIGN.md).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers
from repro.models import partitioning as pt
from repro.models import scan_config
from repro.models import transformer as tf

Array = jnp.ndarray


def init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {
        "ln1": layers.init_layernorm(cfg.d_model),
        "attn": attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim),
        "ln2": layers.init_layernorm(cfg.d_model),
        "mlp": layers.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff),
    }


def init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": layers.init_layernorm(cfg.d_model),
        "attn": attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim),
        "ln_x": layers.init_layernorm(cfg.d_model),
        "xattn": attn_lib.init_attention(
            k2, cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim),
        "ln2": layers.init_layernorm(cfg.d_model),
        "mlp": layers.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff),
    }


def init_params(key, cfg):
    ke, k1, k2 = jax.random.split(key, 3)
    enc_keys = jax.random.split(k1, cfg.n_enc_layers)
    dec_keys = jax.random.split(k2, cfg.n_layers)
    return {
        "embed_tokens": layers.init_embed(
            ke, cfg.vocab, cfg.d_model, tied=cfg.tied_embeddings),
        "enc_layers": jax.vmap(lambda k: init_enc_layer(k, cfg))(enc_keys),
        "layers": jax.vmap(lambda k: init_dec_layer(k, cfg))(dec_keys),
        "enc_norm": layers.init_layernorm(cfg.d_model),
        "final_norm": layers.init_layernorm(cfg.d_model),
    }


def encode(params, frames, cfg):
    """frames: (B, S, d_model) stub embeddings -> encoder output."""
    B, S, _ = frames.shape
    h = frames.astype(layers.DEFAULT_COMPUTE)
    h = h + layers.sinusoidal_positions(S, cfg.d_model).astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def body(hh, p_l):
        out, _ = attn_lib.attention_full(
            p_l["attn"], layers.layer_norm(p_l["ln1"], hh), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
            causal=False, use_rope=False)
        hh = hh + out
        hh = hh + layers.gelu_mlp(
            p_l["mlp"], layers.layer_norm(p_l["ln2"], hh))
        return pt.act_seq(hh), None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, _ = jax.lax.scan(body, h, params["enc_layers"],
                        unroll=scan_config.unroll())
    return layers.layer_norm(params["enc_norm"], h)


def decoder_forward(params, tokens, enc_out, cfg, *, return_cache=False):
    B, L = tokens.shape
    h = layers.embed(params["embed_tokens"], tokens)
    h = h + layers.sinusoidal_positions(L, cfg.d_model).astype(h.dtype)
    positions = jnp.broadcast_to(jnp.arange(L)[None], (B, L))

    def body(hh, p_l):
        out, (k, v) = attn_lib.attention_full(
            p_l["attn"], layers.layer_norm(p_l["ln1"], hh), positions,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
            use_rope=False)
        hh = hh + out
        hh = hh + attn_lib.cross_attention(
            p_l["xattn"], layers.layer_norm(p_l["ln_x"], hh), enc_out,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim)
        hh = hh + layers.gelu_mlp(
            p_l["mlp"], layers.layer_norm(p_l["ln2"], hh))
        return pt.act_seq(hh), (k, v)

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    h, kv = jax.lax.scan(body, h, params["layers"],
                         unroll=scan_config.unroll())
    h = layers.layer_norm(params["final_norm"], h)
    return layers.logits(params["embed_tokens"], h), kv


def forward(params, tokens, cfg, *, frames=None, return_cache=False):
    enc_out = encode(params, frames, cfg)
    lg, kv = decoder_forward(params, tokens, enc_out, cfg)
    return lg, (kv if return_cache else None), jnp.zeros((), jnp.float32)


def loss_fn(params, batch, cfg):
    lg, _, _ = forward(params, batch["tokens"], cfg,
                       frames=batch["frames"])
    loss = layers.cross_entropy(lg[:, :-1], batch["labels"][:, 1:])
    return loss, {"ce": loss, "aux": jnp.zeros((), jnp.float32)}


class EncDecCache(NamedTuple):
    self_kv: attn_lib.DenseKVCache  # stacked (n_layers, ...)
    enc_out: Array  # (B, S, d_model)


def init_cache(cfg, batch: int, max_len: int) -> EncDecCache:
    def stack(x):
        return jax.tree.map(
            lambda a: jnp.broadcast_to(
                a, (cfg.n_layers,) + a.shape).copy(), x)

    return EncDecCache(
        self_kv=stack(attn_lib.DenseKVCache.init(
            batch, max_len, cfg.n_kv, cfg.head_dim)),
        enc_out=jnp.zeros((batch, cfg.src_len, cfg.d_model),
                          jnp.bfloat16),
    )


def prefill(params, tokens, cfg, max_len: int, *, frames=None):
    B, L = tokens.shape
    enc_out = encode(params, frames, cfg)
    lg, (k, v) = decoder_forward(params, tokens, enc_out, cfg,
                                 return_cache=True)
    pad = max_len - L
    k = jnp.pad(k.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v = jnp.pad(v.astype(jnp.bfloat16),
                ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    length = jnp.full((cfg.n_layers, B), L, jnp.int32)
    return lg, EncDecCache(
        self_kv=attn_lib.DenseKVCache(k=k, v=v, length=length),
        enc_out=enc_out.astype(jnp.bfloat16))


def decode_step(params, tokens, cache: EncDecCache, cfg):
    B = tokens.shape[0]
    h = layers.embed(params["embed_tokens"], tokens)
    # sinusoidal position of the current token
    pos = cache.self_kv.length[0]  # (B,) all layers share length
    pe_all = layers.sinusoidal_positions(cache.self_kv.k.shape[2],
                                         cfg.d_model)
    h = h + pe_all[pos][:, None, :].astype(h.dtype)

    def body(hh, xs):
        p_l, c_l = xs
        out, nc = attn_lib.decode_attention_dense(
            p_l["attn"], layers.layer_norm(p_l["ln1"], hh), c_l,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv, d_head=cfg.head_dim,
            use_rope=False)
        hh = hh + out
        hh = hh + attn_lib.cross_attention(
            p_l["xattn"], layers.layer_norm(p_l["ln_x"], hh),
            cache.enc_out, n_heads=cfg.n_heads, n_kv=cfg.n_kv,
            d_head=cfg.head_dim)
        hh = hh + layers.gelu_mlp(
            p_l["mlp"], layers.layer_norm(p_l["ln2"], hh))
        return hh, nc

    h, new_kv = jax.lax.scan(body, h, (params["layers"], cache.self_kv),
                             unroll=scan_config.unroll())
    h = layers.layer_norm(params["final_norm"], h)
    lg = layers.logits(params["embed_tokens"], h)
    return lg, EncDecCache(self_kv=new_kv, enc_out=cache.enc_out)
