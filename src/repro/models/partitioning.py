"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Parallelism mapping (DESIGN.md section 3):
  * batch        -> ("pod", "data")   pure DP across pods and the data axis
  * TP           -> "model"           heads / ffn-hidden / vocab / experts
  * FSDP (ZeRO-3)-> "data"            parameter+optimizer sharding for big
                                      models, on top of TP

Everything here is *mesh-shape agnostic*: specs reference axis names; the
same model code lowers on (16,16) "data","model", on (2,16,16)
"pod","data","model", or on no mesh at all (CPU tests - ``constrain``
no-ops when there is no ambient mesh).
"""
from __future__ import annotations

import re
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

Array = Any


def current_mesh():
    # jax.sharding.get_abstract_mesh only exists in newer jax releases;
    # older ones expose it under jax._src.mesh. No ambient mesh -> None.
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is None:
        try:
            from jax._src import mesh as _mesh

            m = _mesh.get_abstract_mesh()
        except (ImportError, AttributeError):
            return None
    else:
        m = get()
    if m is None or getattr(m, "empty", True):
        return None
    return m


def mesh_axis(name: str) -> bool:
    m = current_mesh()
    return m is not None and name in m.axis_names


def batch_axes():
    """The DP axes present on the current mesh ('pod' only if multi-pod)."""
    if mesh_axis("pod"):
        return ("pod", "data")
    return "data"


def constrain(x: Array, spec: P | None) -> Array:
    """with_sharding_constraint that no-ops without an ambient mesh and
    drops axis names the mesh doesn't have (e.g. 'pod' on single-pod)."""
    m = current_mesh()
    if m is None or spec is None:
        return x

    def fix(entry):
        if entry is None:
            return None
        if isinstance(entry, tuple):
            kept = tuple(e for e in entry if e in m.axis_names)
            return kept if kept else None
        return entry if entry in m.axis_names else None

    spec = P(*(fix(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


def act(x: Array, *axes) -> Array:
    """Constrain an activation; 'batch' expands to the DP axes."""
    spec = tuple(batch_axes() if a == "batch" else a for a in axes)
    return constrain(x, P(*spec))


def act_vocab(x: Array) -> Array:
    """Constrain logits (B, L, V): vocab on "model" only when divisible
    (several assigned vocabs - 49155/50280/51866/92544 - are not)."""
    m = current_mesh()
    if m is None:
        return x
    if "model" in m.axis_names and x.shape[-1] % m.shape["model"] == 0:
        return act(x, "batch", *([None] * (x.ndim - 2)), "model")
    return act(x, "batch", *([None] * (x.ndim - 1)))


def act_seq(x: Array, seq_axis: int = 1) -> Array:
    """Sequence-parallel constraint for inter-layer activations
    (B, L, d): batch over DP, sequence over "model". Cuts the per-layer
    remat carry by the TP degree; attention re-gathers K/V internally.
    No-ops when L doesn't divide the model axis."""
    m = current_mesh()
    if m is None or "model" not in m.axis_names:
        return x
    if x.shape[seq_axis] % m.shape["model"] != 0:
        return act(x, "batch", *([None] * (x.ndim - 1)))
    spec = ["batch"] + [None] * (x.ndim - 1)
    spec[seq_axis] = "model"
    return act(x, *spec)


# --------------------------------------------------------------------------
# Parameter sharding rules: regex on the param path.
# --------------------------------------------------------------------------
# Order matters: first match wins. Written for (pod?, data, model) meshes.
# fsdp=True additionally shards the non-TP dim over "data" (ZeRO-3).
_RULES: list[tuple[str, tuple]] = [
    # embeddings / unembedding: vocab dim on model (TP), d_model on data (FSDP)
    (r".*embed.*", ("model", "fsdp")),
    (r".*unembed.*|.*lm_head.*", ("fsdp", "model")),
    # attention: q/k/v column-parallel, o row-parallel
    (r".*\.(wq|wk|wv|wkv_a|wq_a|wq_b|wkv_b|w_patch).*", ("fsdp", "model")),
    (r".*\.wo.*", ("model", "fsdp")),
    # mlp: up/gate column-parallel, down row-parallel
    (r".*\.(w_up|w_gate).*", ("fsdp", "model")),
    (r".*\.w_down.*", ("model", "fsdp")),
    # MoE experts: expert axis over model (EP); expert mats unsharded inside
    (r".*experts.*\.(w_up|w_gate)$", ("model", "fsdp", None)),
    (r".*experts.*\.w_down$", ("model", None, "fsdp")),
    (r".*router.*", ("fsdp", None)),
    # mamba2 / ssm: big in/out projections column/row parallel
    (r".*\.in_proj.*", ("fsdp", "model")),
    (r".*\.out_proj.*", ("model", "fsdp")),
    (r".*\.conv_w.*", (None, None, None)),
    # norms, biases, scalars: replicated
    (r".*(norm|bias|scale|a_log|dt_bias|d_skip).*", None),
]


def spec_for(path: str, shape: tuple[int, ...], *, fsdp: bool) -> P:
    """PartitionSpec for a parameter path. Layer-stacked params (leading
    scan dim) get a None prepended automatically by the caller."""
    for pat, axes in _RULES:
        if re.fullmatch(pat, path):
            if axes is None:
                return P()
            out = []
            for a in axes[: len(shape)]:
                if a == "fsdp":
                    out.append("data" if fsdp else None)
                else:
                    out.append(a)
            out += [None] * (len(shape) - len(out))
            return P(*out)
    return P()  # default: replicated


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _flatten(v, f"{prefix}.{k}" if prefix else k)
    else:
        yield prefix, tree


def tree_specs(params, *, fsdp: bool, stacked_prefixes=("layers",)):
    """PartitionSpec pytree matching a params dict pytree.

    Params under a ``layers`` subtree are scan-stacked: their leading dim
    is the layer index -> prepend None to the spec.
    """

    def rec(tree, prefix=""):
        if isinstance(tree, dict):
            return {
                k: rec(v, f"{prefix}.{k}" if prefix else k)
                for k, v in tree.items()
            }
        stacked = any(
            prefix.startswith(p + ".") or ("." + p + ".") in prefix
            for p in stacked_prefixes
        )
        shape = tree.shape
        if stacked:
            inner = spec_for(prefix, shape[1:], fsdp=fsdp)
            return P(None, *inner)
        return spec_for(prefix, shape, fsdp=fsdp)

    return rec(params)


def tree_shardings(params, mesh, *, fsdp: bool):
    from jax.sharding import NamedSharding

    specs = tree_specs(params, fsdp=fsdp)

    def fix_spec(leaf_spec, leaf):
        # drop axes that don't divide the dim (GSPMD would pad; we prefer
        # clean replication for e.g. kv heads < model axis)
        out = []
        for dim, entry in zip(leaf.shape, tuple(leaf_spec) + (None,) * 99):
            if entry is None:
                out.append(None)
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            names = tuple(n for n in names if n in mesh.axis_names)
            size = 1
            for n in names:
                size *= mesh.shape[n]
            if size and dim % size == 0 and names:
                out.append(names if len(names) > 1 else names[0])
            else:
                out.append(None)
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix_spec, specs, params,
                        is_leaf=lambda x: isinstance(x, P))
