"""Mixture-of-Experts: shared + routed experts, top-k, sort-based
static-capacity dispatch (DeepSeek-MoE / DeepSeek-V2 style).

Dispatch is the XLA-friendly sort formulation: flatten (token, slot)
assignments, argsort by expert id, take position-in-expert ranks, and
scatter into an (E, capacity, d) buffer. All shapes static; tokens beyond
an expert's capacity are dropped (standard GShard semantics) and the drop
fraction is returned as a metric.

EP sharding: the (E, cap, d) buffer and the expert weights carry the
"model" axis on E - GSPMD turns the scatter/gather into all-to-alls
(baseline path; the §Perf hillclimb measures and optimizes this).

Router runs in fp32 (scores are compared within a block of experts - the
paper's 'relative values are safe in low precision' argument applies to
the *inputs*, bf16 hidden states, not to the comparison accumulator).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models import partitioning as pt

Array = jnp.ndarray


def init_moe(key, d_model, d_expert, n_routed, n_shared, d_shared=None):
    """Routed experts stored stacked on a leading E axis."""
    k_r, k_s, k_g = jax.random.split(key, 3)
    ks = jax.random.split(k_r, 3)
    d_shared = d_shared or d_expert * n_shared
    p = {
        "router": layers.truncated_normal(
            k_g, (d_model, n_routed), 1.0 / np.sqrt(d_model)),
        "experts": {
            "w_gate": layers.truncated_normal(
                ks[0], (n_routed, d_model, d_expert), 1.0 / np.sqrt(d_model)),
            "w_up": layers.truncated_normal(
                ks[1], (n_routed, d_model, d_expert), 1.0 / np.sqrt(d_model)),
            "w_down": layers.truncated_normal(
                ks[2], (n_routed, d_expert, d_model), 1.0 / np.sqrt(d_expert)),
        },
    }
    if n_shared:
        p["shared"] = layers.init_swiglu(k_s, d_model, d_shared)
    return p


def router_topk(p, x, top_k: int, *, bias=None):
    """Softmax-then-topk router (DeepSeek style). x: (T, d). Returns
    (weights (T, k) fp32, experts (T, k) int32, aux load-balance loss)."""
    logits_ = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits_, axis=-1)  # (T, E)
    score = probs if bias is None else probs + bias
    w, idx = jax.lax.top_k(score, top_k)
    if bias is not None:
        w = jnp.take_along_axis(probs, idx, axis=1)
    # aux loss (Switch): E * mean_e(frac_tokens_e * mean_prob_e)
    E = probs.shape[-1]
    hits = jnp.zeros((E,), jnp.float32).at[idx.reshape(-1)].add(1.0)
    frac = hits / jnp.maximum(hits.sum(), 1.0)
    aux = E * jnp.sum(frac * probs.mean(axis=0))
    return w, idx.astype(jnp.int32), aux


def dispatch_sort(x, expert_idx, weights, n_experts: int, capacity: int,
                  cap_shard: bool = False):
    """Sort-based dispatch. x: (T, d); expert_idx/weights: (T, k).

    Returns (buf (E, cap, d), combine-info) where combine-info lets
    ``combine_sort`` gather expert outputs back per (token, slot).
    """
    T, d = x.shape
    k = expert_idx.shape[1]
    flat_e = expert_idx.reshape(-1)  # (T*k,)
    order = jnp.argsort(flat_e, stable=True)  # stable: token order kept
    sorted_e = flat_e[order]
    # position of each sorted entry within its expert group
    counts = jnp.bincount(flat_e, length=n_experts)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(T * k) - starts[sorted_e]
    keep = pos < capacity
    slot = jnp.where(keep, sorted_e * capacity + pos, n_experts * capacity)
    token_of = order // k  # original token per sorted entry
    buf = jnp.zeros((n_experts * capacity + 1, d), x.dtype)
    buf = buf.at[slot].set(x[token_of], mode="drop")
    buf = buf[:-1].reshape(n_experts, capacity, d)
    # Perf A3: sharding capacity over the data axis keeps the dispatch
    # scatter fully distributed (E on "model" alone makes GSPMD gather
    # the token buffer to every expert shard).
    buf = (pt.act(buf, "model", "batch", None) if cap_shard
           else pt.act(buf, "model", None, None))
    drop_frac = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return buf, (order, slot, keep, token_of, drop_frac)


def combine_sort(y_buf, info, weights, T: int):
    """Gather expert outputs back and weight-combine. y_buf: (E, cap, d)."""
    order, slot, keep, token_of, _ = info
    E, cap, d = y_buf.shape
    flat = jnp.concatenate(
        [y_buf.reshape(E * cap, d), jnp.zeros((1, d), y_buf.dtype)], axis=0)
    y_sorted = flat[jnp.minimum(slot, E * cap)]  # (T*k, d), dropped -> 0
    y_sorted = jnp.where(keep[:, None], y_sorted, 0)
    w_flat = weights.reshape(-1)[order].astype(y_buf.dtype)  # (T*k,)
    out = jnp.zeros((T, d), y_buf.dtype)
    out = out.at[token_of].add(y_sorted * w_flat[:, None])
    return out


def expert_ffn(p_experts, buf, compute_dtype=layers.DEFAULT_COMPUTE,
               cap_shard: bool = False):
    """Batched SwiGLU over the (E, cap, d) buffer."""
    xc = buf.astype(compute_dtype)
    wg = p_experts["w_gate"].astype(compute_dtype)
    wu = p_experts["w_up"].astype(compute_dtype)
    wd = p_experts["w_down"].astype(compute_dtype)
    g = jnp.einsum("ecd,edf->ecf", xc, wg)
    u = jnp.einsum("ecd,edf->ecf", xc, wu)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u
    h = (pt.act(h, "model", "batch", None) if cap_shard
         else pt.act(h, "model", None, None))
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_block(p, x, *, top_k: int, n_routed: int,
              capacity_factor: float = 1.25,
              compute_dtype=layers.DEFAULT_COMPUTE,
              cap_shard: bool = False):
    """Full MoE block on (B, L, d). Returns (out, metrics dict)."""
    B, L, d = x.shape
    T = B * L
    xf = x.reshape(T, d)
    w, idx, aux = router_topk(p, xf, top_k)
    capacity = int(np.ceil(T * top_k / n_routed * capacity_factor))
    capacity = max(8, -(-capacity // 8) * 8)  # pad to 8 for tiling
    buf, info = dispatch_sort(xf, idx, w, n_routed, capacity,
                              cap_shard=cap_shard)
    y_buf = expert_ffn(p["experts"], buf, compute_dtype,
                       cap_shard=cap_shard)
    out = combine_sort(y_buf, info, w, T)
    if "shared" in p:
        out = out + layers.swiglu(p["shared"], xf, compute_dtype)
    return out.reshape(B, L, d), {"aux_loss": aux, "drop_frac": info[4]}
