"""AdamW + cosine schedule + global-norm clipping, hand-rolled in JAX
(no optax dependency). Moments are fp32 regardless of param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


class OptState(NamedTuple):
    step: Array  # () int32
    mu: dict  # first moments (fp32)
    nu: dict  # second moments (fp32)


def init(params) -> OptState:
    zeros = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree.map(jnp.copy, zeros))


def schedule(cfg: OptConfig, step: Array) -> Array:
    s = step.astype(jnp.float32)
    warm = s / jnp.maximum(1.0, cfg.warmup_steps)
    prog = (s - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


def _decay_mask(path_leaf):
    """No weight decay on norms/biases/scalars (1-D params)."""
    return path_leaf.ndim >= 2


def apply_updates(cfg: OptConfig, params, grads, state: OptState):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g,
                      state.nu, grads)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if _decay_mask(p):
            u = u + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, OptState(step=step, mu=mu, nu=nu), {
        "lr": lr, "grad_norm": gnorm}
