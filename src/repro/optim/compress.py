"""Anchored gradient compression for data-parallel all-reduce.

The third application of the paper's decomposition (DESIGN.md section 2):
per 256-element block, gradient = anchor(fp32 mean) + scale(fp32) *
residual(int8). DP all-reduce then moves ~4x fewer bytes: int8 residuals
are summed in int32 (exact - no quantization drift in the reduction
itself) alongside tiny fp32 anchor/scale reductions.

Error feedback: the per-worker quantization error is carried to the next
step (Seide et al. / 1-bit SGD trick), making the compression unbiased
in the long run.

Two entry points:
  * ``compress / decompress`` - pure local transforms (unit-testable).
  * ``all_reduce_compressed`` - shard_map collective over a named axis.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jnp.ndarray

BLOCK = 256


class Compressed(NamedTuple):
    anchor: Array  # (nblk,) fp32 per-block mean
    scale: Array  # (nblk,) fp32
    resid: Array  # (nblk, BLOCK) int8
    n: int  # original length


def compress(g: Array, carry: Array | None = None):
    """Quantize a flat fp32 gradient; returns (Compressed, new_carry)."""
    flat = g.reshape(-1).astype(jnp.float32)
    if carry is not None:
        flat = flat + carry.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    x = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    anchor = jnp.mean(x, axis=1)
    dev = x - anchor[:, None]
    scale = jnp.maximum(jnp.max(jnp.abs(dev), axis=1), 1e-30)
    resid = jnp.clip(jnp.round(dev / scale[:, None] * 127.0), -127, 127)
    err = dev - resid * (scale[:, None] / 127.0)  # quantization error
    new_carry = err.reshape(-1)[:n].reshape(g.shape)
    return Compressed(anchor, scale, resid.astype(jnp.int8), n), new_carry


def decompress(c: Compressed, shape) -> Array:
    x = c.anchor[:, None] + c.resid.astype(jnp.float32) * (
        c.scale[:, None] / 127.0)
    return x.reshape(-1)[: c.n].reshape(shape)


def compression_ratio(shape) -> float:
    import numpy as np

    n = int(np.prod(shape))
    nblk = -(-n // BLOCK)
    raw = 4 * n
    packed = nblk * (4 + 4 + BLOCK)
    return raw / packed


def all_reduce_compressed(g: Array, axis_name: str,
                          carry: Array | None = None):
    """Mean-all-reduce of `g` over `axis_name`, int8 on the wire.

    Must run inside shard_map with `axis_name` un-visible sharding.
    Residuals psum exactly in int32; anchors/scales psum'd per-worker
    (each worker's blocks decode with its own scale, so the sum over
    workers of decode(c_w) equals decode-sum only if done per-worker:
    we therefore psum the *decoded* per-block reconstruction in two
    parts - int32 resid-sum needs a shared scale. We instead all-gather
    nothing: psum(anchor), psum(scale-weighted residuals) where the
    residual term uses each worker's scale folded in *before* the wire
    as int8 x (scale/127): that would be fp32 again. The honest wire
    format: psum int32 residuals + psum fp32 (anchor, scale); decode
    uses the *summed* anchors and *max* scale bound. To keep exactness
    we use per-worker scale normalization: residuals are quantized
    against the *global* scale obtained by one tiny fp32 psum(max) of
    block scales first (2 collectives, both tiny vs the int8 payload).
    """
    size = jax.lax.psum(1, axis_name)
    flat = g.reshape(-1).astype(jnp.float32)
    if carry is not None:
        flat = flat + carry.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % BLOCK
    x = jnp.pad(flat, (0, pad)).reshape(-1, BLOCK)
    anchor = jnp.mean(x, axis=1)
    dev = x - anchor[:, None]
    local_scale = jnp.max(jnp.abs(dev), axis=1)
    # tiny fp32 collective: shared per-block scale = max over workers
    scale = jnp.maximum(jax.lax.pmax(local_scale, axis_name), 1e-30)
    resid = jnp.clip(jnp.round(dev / scale[:, None] * 127.0), -127, 127)
    err = dev - resid * (scale[:, None] / 127.0)
    new_carry = err.reshape(-1)[:n].reshape(g.shape)
    # the big collective: int8 payload summed exactly in int32
    resid_sum = jax.lax.psum(resid.astype(jnp.int32), axis_name)
    anchor_sum = jax.lax.psum(anchor, axis_name)
    total = anchor_sum[:, None] + resid_sum.astype(jnp.float32) * (
        scale[:, None] / 127.0)
    mean = (total / size).reshape(-1)[:n].reshape(g.shape)
    return mean, new_carry
