"""deepseek-moe-16b [moe]: 28L d2048 16H (kv=16) ff(expert)=1408
vocab102400, 2 shared + 64 routed top-6, fine-grained. [arXiv:2401.06066]
Assignment-exact: all layers MoE (HF uses first_k_dense_replace=1)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe", n_layers=28, d_model=2048,
    n_heads=16, n_kv=16, d_ff=1408, vocab=102400, d_head=128,
    n_routed=64, n_shared=2, top_k=6, d_expert=1408,
    rope_theta=10000.0, tied_embeddings=False, remat="full",
)

SMOKE = ArchConfig(
    name="deepseek-moe-16b-smoke", family="moe", n_layers=2, d_model=64,
    n_heads=4, n_kv=4, d_ff=32, vocab=512, d_head=16,
    n_routed=8, n_shared=1, top_k=2, d_expert=32,
    rope_theta=10000.0, tied_embeddings=False,
)
