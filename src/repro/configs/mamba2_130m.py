"""mamba2-130m [ssm]: 24L d768 (attention-free) ssm_state=128
vocab50280 - SSD (state-space duality). [arXiv:2405.21060]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-130m", family="ssm", n_layers=24, d_model=768,
    n_heads=0, n_kv=0, d_ff=0, vocab=50280,
    d_state=128, expand=2, ssm_head_dim=64, n_groups=1,
    tied_embeddings=True, remat="full",
)

SMOKE = ArchConfig(
    name="mamba2-130m-smoke", family="ssm", n_layers=2, d_model=64,
    n_heads=0, n_kv=0, d_ff=0, vocab=512,
    d_state=16, expand=2, ssm_head_dim=16, n_groups=1,
    tied_embeddings=True,
)
