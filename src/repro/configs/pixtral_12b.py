"""pixtral-12b [vlm]: 40L d5120 32H (GQA kv=8) ff14336 vocab131072 -
mistral-nemo backbone; pixtral-ViT frontend is a stub (input_specs()
provides precomputed patch embeddings). [hf:mistralai/Pixtral-12B-2409]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="pixtral-12b", family="vlm", n_layers=40, d_model=5120,
    n_heads=32, n_kv=8, d_ff=14336, vocab=131072, d_head=128,
    n_patches=256, rope_theta=1000000.0, tied_embeddings=False,
    remat="full",
)

SMOKE = ArchConfig(
    name="pixtral-12b-smoke", family="vlm", n_layers=2, d_model=64,
    n_heads=4, n_kv=1, d_ff=128, vocab=512, d_head=16,
    n_patches=8, rope_theta=1000000.0, tied_embeddings=False,
)
