"""deepseek-v2-236b [moe]: 60L d5120 128H ff(expert)=1536 vocab102400,
MLA kv_lora=512, 2 shared + 160 routed top-6. [arXiv:2405.04434]

Assignment-exact: all 60 layers MoE (the HF release uses
first_k_dense_replace=1 with dense ff 12288 - we follow the assignment's
uniform spec; toggle first_k_dense/dense_ff to restore the HF layout).
"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v2-236b", family="mla_moe", n_layers=60, d_model=5120,
    n_heads=128, n_kv=128, d_ff=1536, vocab=102400,
    n_routed=160, n_shared=2, top_k=6, d_expert=1536,
    q_lora=1536, kv_lora=512, qk_nope=128, qk_rope=64, v_head=128,
    rope_theta=10000.0, tied_embeddings=False, remat="full",
)

SMOKE = ArchConfig(
    name="deepseek-v2-236b-smoke", family="mla_moe", n_layers=2,
    d_model=64, n_heads=4, n_kv=4, d_ff=32, vocab=512,
    n_routed=8, n_shared=1, top_k=2, d_expert=32,
    q_lora=32, kv_lora=16, qk_nope=16, qk_rope=8, v_head=16,
    rope_theta=10000.0, tied_embeddings=False,
)
