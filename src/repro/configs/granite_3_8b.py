"""granite-3-8b [dense]: 40L d4096 32H (GQA kv=8) ff12800 vocab49155.
[hf:ibm-granite/granite-3.0-8b-base family; assignment-exact numbers]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv=8, d_ff=12800, vocab=49155, d_head=128,
    rope_theta=10000.0, tied_embeddings=True, remat="full",
)

SMOKE = ArchConfig(
    name="granite-3-8b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=1, d_ff=128, vocab=512, d_head=16,
    rope_theta=10000.0, tied_embeddings=True,
)
