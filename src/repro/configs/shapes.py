"""Assigned input shapes (the 4 cells every architecture is paired with).

``decode_*`` / ``long_*`` lower serve_step (one new token against a KV
cache of seq_len), not train_step. long_500k requires sub-quadratic
sequence mixing: it runs for the ssm/hybrid families only (skips
documented in DESIGN.md section 4).
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# families allowed to run long_500k (sub-quadratic sequence mixing)
LONG_OK_FAMILIES = ("ssm", "hybrid")


def runnable(family: str, shape: str) -> bool:
    if shape == "long_500k":
        return family in LONG_OK_FAMILIES
    return True
