"""llama3.2-3b [dense]: 28L d3072 24H (GQA kv=8) ff8192 vocab128256.
[hf:meta-llama/Llama-3.2-3B family]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="llama3.2-3b", family="dense", n_layers=28, d_model=3072,
    n_heads=24, n_kv=8, d_ff=8192, vocab=128256, d_head=128,
    rope_theta=500000.0, tied_embeddings=True, remat="full",
)

SMOKE = ArchConfig(
    name="llama3.2-3b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv=2, d_ff=192, vocab=512, d_head=16,
    rope_theta=500000.0, tied_embeddings=True,
)
