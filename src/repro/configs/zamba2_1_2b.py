"""zamba2-1.2b [hybrid]: 38L d2048 32H (kv=32) ff8192 ssm_state=64 -
Mamba2 backbone + one shared attention block applied every 6 layers.
[arXiv:2411.15242] Per-site LoRA deltas omitted (DESIGN.md)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b", family="hybrid", n_layers=38, d_model=2048,
    n_heads=32, n_kv=32, d_ff=8192, vocab=32000,
    d_state=64, expand=2, ssm_head_dim=64, n_groups=1, attn_every=6,
    rope_theta=10000.0, tied_embeddings=True, remat="full",
)

SMOKE = ArchConfig(
    name="zamba2-1.2b-smoke", family="hybrid", n_layers=5, d_model=64,
    n_heads=4, n_kv=4, d_ff=128, vocab=512,
    d_state=16, expand=2, ssm_head_dim=16, n_groups=1, attn_every=2,
    rope_theta=10000.0, tied_embeddings=True,
)
