"""internlm2-20b [dense]: 48L d6144 48H (GQA kv=8) ff16384 vocab92544.
[arXiv:2403.17297]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b", family="dense", n_layers=48, d_model=6144,
    n_heads=48, n_kv=8, d_ff=16384, vocab=92544, d_head=128,
    rope_theta=1000000.0, tied_embeddings=False, remat="full",
)

SMOKE = ArchConfig(
    name="internlm2-20b-smoke", family="dense", n_layers=2, d_model=96,
    n_heads=6, n_kv=1, d_ff=192, vocab=512, d_head=16,
    rope_theta=1000000.0, tied_embeddings=False,
)
