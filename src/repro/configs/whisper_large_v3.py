"""whisper-large-v3 [audio]: enc-dec, 32+32L d1280 20H ff5120 vocab51866.
[arXiv:2212.04356] Conv/mel frontend is a stub: input_specs() provides
precomputed frame embeddings (B, 1500, d_model)."""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec", n_layers=32, d_model=1280,
    n_heads=20, n_kv=20, d_ff=5120, vocab=51866, d_head=64,
    n_enc_layers=32, src_len=1500, norm="ln", mlp="gelu",
    tied_embeddings=True, remat="full",
)

SMOKE = ArchConfig(
    name="whisper-large-v3-smoke", family="encdec", n_layers=2,
    d_model=64, n_heads=4, n_kv=4, d_ff=128, vocab=512, d_head=16,
    n_enc_layers=2, src_len=64, norm="ln", mlp="gelu",
    tied_embeddings=True,
)
