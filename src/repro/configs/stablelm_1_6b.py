"""stablelm-1.6b [dense]: 24L d2048 32H (kv=32, MHA) ff5632 vocab100352.
[hf:stabilityai/stablelm-2-1_6b; assignment-exact. Simplification:
full RoPE instead of stablelm's 25% partial rotary - DESIGN.md.]"""
from repro.models.transformer import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b", family="dense", n_layers=24, d_model=2048,
    n_heads=32, n_kv=32, d_ff=5632, vocab=100352, d_head=64,
    rope_theta=10000.0, tied_embeddings=False, remat="full",
)

SMOKE = ArchConfig(
    name="stablelm-1.6b-smoke", family="dense", n_layers=2, d_model=64,
    n_heads=4, n_kv=4, d_ff=128, vocab=512, d_head=16,
    rope_theta=10000.0, tied_embeddings=False,
)
