"""CLI driver for the scenario layer.

    python -m repro.sph list [--names]
    python -m repro.sph run <case> [--nsteps N] [--observe-every K]
                                   [--ds DS | --n N_TARGET]
                                   [--backend reference|xla|pallas]
                                   [--records fp32|fp16|bf16]
                                   [--guard] [--guard-block B]
                                   [--inject nan|teleport|cap|window|dt]
                                   [--set field=value ...]

``run`` builds the registered case, advances it under the production
persistent pipeline with in-scan observables, prints the observable
table, the final diagnostics, measured steps/sec, and the case's
analytic validation metrics where it defines them (e.g. the
Taylor–Green KE decay rate).

``--guard`` runs under the self-healing health guard (core/recovery.py):
in-scan divergence detection, checkpoint rollback, dt backoff, capacity
regrow, precision degrade. ``--inject`` arms one of the named faults
(and implies ``--guard``) — the CI smoke uses this to prove every case
recovers unattended. A guarded run that exhausts its policy exits 1
with the structured divergence report.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import logging
import sys

import numpy as np

from repro.core import cases as cases_lib
from repro.core import recovery
from repro.core.api import Simulation
from repro.core.precision import PrecisionPolicy


def _case_overrides(args) -> dict:
    over: dict = {}
    if args.ds is not None:
        over["ds"] = args.ds
    elif args.n is not None:
        over["ds"] = cases_lib.resolve_ds(args.case, args.n)
    if args.backend is not None:
        over["backend"] = args.backend
    if args.records is not None:
        over["policy"] = PrecisionPolicy(records=args.records)
    for item in args.set or []:
        key, _, val = item.partition("=")
        if not val:
            raise SystemExit(f"--set wants field=value, got {item!r}")
        try:
            over[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            over[key] = val
    return over


def cmd_list(args) -> int:
    if args.names:
        print("\n".join(cases_lib.case_names()))
        return 0
    print(f"{'case':14s} {'boundary':58s} validation")
    for name in cases_lib.case_names():
        cls = cases_lib.CASES[name]
        print(f"{name:14s} {getattr(cls, 'boundary', '-'):58s} "
              f"{getattr(cls, 'validation', '-')}")
    return 0


def cmd_run(args) -> int:
    sim = Simulation.from_case(args.case, **_case_overrides(args))
    case, cfg = sim.case, sim.cfg
    nsteps = args.nsteps or getattr(case, "default_nsteps", 400)
    every = args.observe_every or max(1, nsteps // 20)

    guard = args.guard or args.inject is not None
    policy = None
    if guard:
        logging.basicConfig(level=logging.WARNING)
        policy = recovery.GuardPolicy(
            block=args.guard_block or recovery.GuardPolicy.block
        )
        if args.inject is not None:
            sim.cfg = cfg = recovery.apply_named_fault(
                cfg, args.inject, nsteps, sim.n_particles
            )
    print(f"# {args.case}: N={sim.n_particles} ds={case.ds:.4g} "
          f"dt={cfg.dt:.3e} backend={cfg.resolved_backend} "
          f"records={cfg.policy.records} nsteps={nsteps} "
          f"observe_every={every}"
          + (f" guard=on inject={args.inject or '-'}" if guard else ""))

    try:
        if args.time:
            res, sps = sim.run_timed(nsteps, observe_every=every,
                                     guard=policy)
        else:
            res, sps = sim.run(nsteps, observe_every=every,
                               guard=policy), None
    except recovery.SimulationDiverged as e:
        print(f"# DIVERGED at step {e.step}: checks={e.checks} "
              f"stats={e.stats}", file=sys.stderr)
        for ev in e.events:
            print(f"#   tried {ev.action} at step {ev.step}: {ev.detail}",
                  file=sys.stderr)
        return 1

    obs = res.observables
    t = np.asarray(obs.t)
    ekin = np.asarray(obs.ekin)
    vmax = np.asarray(obs.vmax)
    rho_err = np.asarray(obs.rho_err)
    print(f"{'t':>10s} {'ekin':>12s} {'vmax':>10s} {'rho_err':>10s}")
    for row in zip(t, ekin, vmax, rho_err):
        print(f"{row[0]:10.4f} {row[1]:12.6e} {row[2]:10.4f} {row[3]:10.4f}")

    stats = res.stats
    print(f"# steps={int(stats.steps)} rebuilds={int(stats.rebuilds)} "
          f"overflow={bool(stats.overflow)}"
          + (f" steps/sec={sps:.1f}" if sps is not None else ""))
    if res.report is not None and res.report.recovered:
        rep = res.report
        print(f"# guard recovered: retries={rep.retries} "
              f"dt_halvings={rep.dt_halvings} regrows={rep.regrows} "
              f"records_degraded={rep.records_degraded} "
              f"final dt={rep.cfg.dt:.3e}")
        for ev in rep.events:
            print(f"#   step {ev.step}: {ev.checks} -> {ev.action} "
                  f"({ev.detail})")
    bad = (
        np.isnan(ekin).any() or np.isnan(vmax).any()
        or not np.isfinite(ekin[-1])
    )
    if bad:
        print("# FAILED: non-finite observables", file=sys.stderr)
        return 1
    if bool(stats.overflow):
        # dropped neighbor pairs = silently wrong physics — fail loudly
        print("# FAILED: neighbor/cell-capacity overflow (raise "
              "max_neighbors / capacity for this resolution)",
              file=sys.stderr)
        return 1

    if hasattr(case, "validate"):
        metrics = case.validate(t, ekin)
        for k, v in metrics.items():
            print(f"# {k} = {v:.4g}")
    if hasattr(case, "front_position"):
        print(f"# surge front x = {case.front_position(cfg, res.state):.4f} "
              f"(tank width {case.width})")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sph")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("list", help="list registered cases")
    lp.add_argument("--names", action="store_true",
                    help="bare case names only (for scripting)")
    lp.set_defaults(fn=cmd_list)

    rp = sub.add_parser("run", help="run a registered case")
    rp.add_argument("case", choices=cases_lib.case_names())
    rp.add_argument("--nsteps", type=int, default=None)
    rp.add_argument("--observe-every", type=int, default=None)
    rp.add_argument("--ds", type=float, default=None)
    rp.add_argument("--n", type=int, default=None,
                    help="target fluid particle count (sets ds)")
    rp.add_argument("--backend", default=None,
                    choices=["reference", "xla", "pallas"])
    rp.add_argument("--records", default=None,
                    choices=["fp32", "fp16", "bf16"])
    rp.add_argument("--time", action="store_true",
                    help="run twice and report steps/sec (compile excluded)")
    rp.add_argument("--guard", action="store_true",
                    help="run under the self-healing health guard")
    rp.add_argument("--guard-block", type=int, default=None,
                    help="steps per guarded block (default: policy's 32)")
    rp.add_argument("--inject", default=None,
                    choices=["nan", "teleport", "cap", "window", "dt"],
                    help="arm a named fault (implies --guard)")
    rp.add_argument("--set", action="append", metavar="FIELD=VALUE",
                    help="override any case dataclass field")
    rp.set_defaults(fn=cmd_run)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
