"""CLI driver for the scenario layer.

    python -m repro.sph list [--names]
    python -m repro.sph lint [check|trace|baseline] [args...]
    python -m repro.sph run <case> [--nsteps N] [--observe-every K]
                                   [--ds DS | --n N_TARGET]
                                   [--backend reference|xla|pallas]
                                   [--records fp32|fp16|bf16]
                                   [--guard] [--guard-block B]
                                   [--inject nan|teleport|cap|window|dt]
                                   [--set field=value ...]

``run`` builds the registered case, advances it under the production
persistent pipeline with in-scan observables, prints the observable
table, the final diagnostics, measured steps/sec, and the case's
analytic validation metrics where it defines them (e.g. the
Taylor–Green KE decay rate).

``--guard`` runs under the self-healing health guard (core/recovery.py):
in-scan divergence detection, checkpoint rollback, dt backoff, capacity
regrow, precision degrade. ``--inject`` arms one of the named faults
(and implies ``--guard``) — the CI smoke uses this to prove every case
recovers unattended. A guarded run that exhausts its policy exits 1
with the structured divergence report.
"""
from __future__ import annotations

import argparse
import ast
import dataclasses
import json
import logging
import signal
import sys

import numpy as np

from repro.core import cases as cases_lib
from repro.core import recovery
from repro.core.api import Simulation
from repro.core.precision import PrecisionPolicy


def _case_overrides(args) -> dict:
    over: dict = {}
    if args.ds is not None:
        over["ds"] = args.ds
    elif args.n is not None:
        over["ds"] = cases_lib.resolve_ds(args.case, args.n)
    if args.backend is not None:
        over["backend"] = args.backend
    if args.records is not None:
        over["policy"] = PrecisionPolicy(records=args.records)
    for item in args.set or []:
        key, _, val = item.partition("=")
        if not val:
            raise SystemExit(f"--set wants field=value, got {item!r}")
        try:
            over[key] = ast.literal_eval(val)
        except (ValueError, SyntaxError):
            over[key] = val
    return over


def cmd_list(args) -> int:
    if args.names:
        print("\n".join(cases_lib.case_names()))
        return 0
    print(f"{'case':14s} {'boundary':58s} validation")
    for name in cases_lib.case_names():
        cls = cases_lib.CASES[name]
        print(f"{name:14s} {getattr(cls, 'boundary', '-'):58s} "
              f"{getattr(cls, 'validation', '-')}")
    return 0


def cmd_run(args) -> int:
    sim = Simulation.from_case(args.case, **_case_overrides(args))
    case, cfg = sim.case, sim.cfg
    nsteps = args.nsteps or getattr(case, "default_nsteps", 400)
    every = args.observe_every or max(1, nsteps // 20)

    guard = args.guard or args.inject is not None
    policy = None
    if guard:
        logging.basicConfig(level=logging.WARNING)
        policy = recovery.GuardPolicy(
            block=args.guard_block or recovery.GuardPolicy.block
        )
        if args.inject is not None:
            sim.cfg = cfg = recovery.apply_named_fault(
                cfg, args.inject, nsteps, sim.n_particles
            )
    as_json = getattr(args, "json", False)
    # machine-readable mode: exactly one JSON document on stdout (schema
    # "repro.sph.run/1", documented in the README) — everything the
    # human table prints, plus the guard report, as data
    doc = {
        "schema": "repro.sph.run/1",
        "case": args.case,
        "n": sim.n_particles,
        "ds": float(case.ds),
        "dt": float(cfg.dt),
        "backend": cfg.resolved_backend,
        "records": cfg.policy.records,
        "nsteps": int(nsteps),
        "observe_every": int(every),
        "guard": guard,
        "inject": args.inject,
    }
    if not as_json:
        print(f"# {args.case}: N={sim.n_particles} ds={case.ds:.4g} "
              f"dt={cfg.dt:.3e} backend={cfg.resolved_backend} "
              f"records={cfg.policy.records} nsteps={nsteps} "
              f"observe_every={every}"
              + (f" guard=on inject={args.inject or '-'}" if guard else ""))

    try:
        if args.time:
            res, sps = sim.run_timed(nsteps, observe_every=every,
                                     guard=policy)
        else:
            res, sps = sim.run(nsteps, observe_every=every,
                               guard=policy), None
    except recovery.SimulationDiverged as e:
        if as_json:
            doc.update(status="diverged", exit=1, diverged={
                "step": int(e.step), "checks": list(e.checks),
                "word": int(e.word),
                "stats": {k: float(v) for k, v in (e.stats or {}).items()},
                "events": [ev.to_json() for ev in e.events],
            })
            print(json.dumps(doc))
            return 1
        print(f"# DIVERGED at step {e.step}: checks={e.checks} "
              f"stats={e.stats}", file=sys.stderr)
        for ev in e.events:
            print(f"#   tried {ev.action} at step {ev.step}: {ev.detail}",
                  file=sys.stderr)
        return 1

    obs = res.observables
    t = np.asarray(obs.t)
    ekin = np.asarray(obs.ekin)
    vmax = np.asarray(obs.vmax)
    rho_err = np.asarray(obs.rho_err)
    stats = res.stats
    bad = (
        np.isnan(ekin).any() or np.isnan(vmax).any()
        or not np.isfinite(ekin[-1])
    )
    overflow = bool(stats.overflow)
    metrics = (case.validate(t, ekin)
               if hasattr(case, "validate") and not bad else {})

    if as_json:
        doc.update(
            status=("nonfinite" if bad
                    else "overflow" if overflow else "ok"),
            exit=1 if (bad or overflow) else 0,
            observables={"t": t.tolist(), "ekin": ekin.tolist(),
                         "vmax": vmax.tolist(),
                         "rho_err": rho_err.tolist()},
            stats={"steps": int(stats.steps),
                   "rebuilds": int(stats.rebuilds),
                   "overflow": overflow},
            steps_per_sec=sps,
            validation={k: float(v) for k, v in metrics.items()},
        )
        if res.report is not None:
            doc["guard_report"] = res.report.to_json()
        print(json.dumps(doc))
        return doc["exit"]

    print(f"{'t':>10s} {'ekin':>12s} {'vmax':>10s} {'rho_err':>10s}")
    for row in zip(t, ekin, vmax, rho_err):
        print(f"{row[0]:10.4f} {row[1]:12.6e} {row[2]:10.4f} {row[3]:10.4f}")

    print(f"# steps={int(stats.steps)} rebuilds={int(stats.rebuilds)} "
          f"overflow={bool(stats.overflow)}"
          + (f" steps/sec={sps:.1f}" if sps is not None else ""))
    if res.report is not None and res.report.recovered:
        rep = res.report
        print(f"# guard recovered: retries={rep.retries} "
              f"dt_halvings={rep.dt_halvings} regrows={rep.regrows} "
              f"records_degraded={rep.records_degraded} "
              f"final dt={rep.cfg.dt:.3e}")
        if rep.dropped_obs_rows:
            # rollbacks discard rows from undone trajectory segments —
            # say so instead of printing a silently thinned table
            print(f"# {rep.dropped_obs_rows} observable row(s) dropped "
                  "by rollback (sampled on undone trajectory segments)")
        for ev in rep.events:
            print(f"#   step {ev.step}: {ev.checks} -> {ev.action} "
                  f"({ev.detail})")
    if bad:
        print("# FAILED: non-finite observables", file=sys.stderr)
        return 1
    if overflow:
        # dropped neighbor pairs = silently wrong physics — fail loudly
        print("# FAILED: neighbor/cell-capacity overflow (raise "
              "max_neighbors / capacity for this resolution)",
              file=sys.stderr)
        return 1

    for k, v in metrics.items():
        print(f"# {k} = {v:.4g}")
    if hasattr(case, "front_position"):
        print(f"# surge front x = {case.front_position(cfg, res.state):.4f} "
              f"(tank width {case.width})")
    return 0


def cmd_sweep(args) -> int:
    from repro.core import ensemble, health

    logging.basicConfig(level=logging.WARNING)
    over = _case_overrides(args)
    base_case = cases_lib.build_case(args.case, **{
        k: v for k, v in over.items()
        if k in {f.name for f in dataclasses.fields(cases_lib.CASES[args.case])}
    })
    cfg0, state0 = base_case.build()
    for k, v in over.items():
        if k in {f.name for f in dataclasses.fields(type(cfg0))}:
            cfg0 = dataclasses.replace(cfg0, **{k: v})
    nsteps = args.nsteps or getattr(base_case, "default_nsteps", 400)
    policy = recovery.GuardPolicy(
        block=args.block or recovery.GuardPolicy.block
    )

    # config variants: each --vary value is its own shape bucket
    variants = [("", cfg0)]
    if args.vary:
        field, _, vals = args.vary.partition("=")
        if not vals:
            raise SystemExit(f"--vary wants FIELD=V1,V2,..., got {args.vary!r}")
        variants = []
        for raw in vals.split(","):
            val = ast.literal_eval(raw)
            variants.append(
                (f"[{field}={raw}]", dataclasses.replace(cfg0, **{field: val}))
            )

    # members: per-variant batch of velocity-perturbed copies of the
    # case state (member 0 of each variant is the unperturbed reference)
    fault = None
    if args.inject is not None:
        fault = recovery.apply_named_fault(
            cfg0, args.inject, nsteps, int(state0.xn.shape[0])
        ).fault
    requests = []
    fluid = ~np.asarray(state0.fixed)
    for tag, vcfg in variants:
        for i in range(args.batch):
            st = state0
            if i > 0 and args.perturb > 0.0:
                rng = np.random.default_rng(args.seed + i)
                v = np.asarray(st.fluid.v).copy()
                v[fluid] += args.perturb * rng.standard_normal(
                    v[fluid].shape
                ).astype(v.dtype)
                st = st._replace(fluid=st.fluid._replace(v=v))
            requests.append(ensemble.SweepRequest(
                name=f"{args.case}{tag}#{i}", cfg=vcfg, state=st,
                fault=fault if len(requests) == args.inject_member else None,
            ))

    total = len(requests)
    print(f"# sweep {args.case}: members={total} batch={args.batch} "
          f"variants={len(variants)} N={int(state0.xn.shape[0])} "
          f"nsteps={nsteps} block={policy.block}"
          + (f" inject={args.inject} on member {args.inject_member}"
             if fault else "")
          + (f" checkpoint={args.checkpoint}" if args.checkpoint else "")
          + (" resume" if args.resume else ""))

    res = ensemble.run_sweep(
        requests, nsteps, policy,
        checkpoint_dir=args.checkpoint,
        checkpoint_every=args.checkpoint_every,
        keep=args.keep, resume=args.resume,
    )

    print(f"{'member':28s} {'status':12s} {'steps':>7s} {'retries':>7s} "
          f"{'dt_scale':>9s} {'events'}")
    for name, m in zip(res.names, res.members):
        evs = ", ".join(ev.action for ev in m.events) or "-"
        if m.solo_report is not None and m.solo_report.events:
            evs += " | solo: " + ", ".join(
                ev.action for ev in m.solo_report.events)
        print(f"{name:28s} {m.status:12s} {m.steps:7d} {m.retries:7d} "
              f"{m.dt_scale:9.4g} {evs}")
        if m.error is not None:
            print(f"#   quarantined: {m.error}")
    for j, rep in enumerate(res.reports):
        extra = ""
        if rep.resumed_from is not None:
            extra += f" resumed_from_block={rep.resumed_from}"
        if rep.dead_process_detected:
            extra += " dead_predecessor_process=yes"
        if rep.straggler_flagged:
            extra += " straggler=FLAGGED"
        print(f"# bucket {j}: blocks={rep.blocks} "
              f"slow_blocks={rep.slow_blocks}{extra}")
    counts = res.counts()
    print("# sweep summary: " + " ".join(
        f"{k}={v}" for k, v in counts.items()))
    nonfinite = any(
        not np.isfinite(np.asarray(st.fluid.v)).all()
        for st, m in zip(res.states, res.members)
        if m.status != "quarantined"
    )
    if nonfinite:
        print("# FAILED: non-finite final state on a non-quarantined "
              "member", file=sys.stderr)
        return 1
    return 0


def cmd_serve(args) -> int:
    from repro.core import recovery as _rec

    logging.basicConfig(level=logging.INFO)
    policy = _rec.GuardPolicy(
        block=args.block or _rec.GuardPolicy.block, snapshot_every=1)
    if args.single_process:
        from repro.sph.serve import SimServer

        srv = SimServer(
            host=args.host, port=args.port, slots=args.slots,
            queue=args.queue, policy=policy,
            checkpoint_dir=args.checkpoint,
        )
        mode = "single-process"
    else:
        from repro.sph.supervisor import FrontendServer

        srv = FrontendServer(
            host=args.host, port=args.port, slots=args.slots,
            queue=args.queue, policy=policy,
            checkpoint_dir=args.checkpoint,
            max_restarts=args.max_restarts,
            hang_timeout_s=args.hang_timeout,
            save_every=args.save_every,
            drain_timeout_s=args.drain_timeout,
            chaos=args.chaos,
        )
        mode = "multi-process"
    # SIGTERM/SIGINT -> graceful drain: stop admitting, checkpoint
    # in-flight lanes, answer RETRY_AFTER, exit 0
    for sig in (signal.SIGTERM, signal.SIGINT):
        signal.signal(sig, lambda *_: srv.request_drain())
    if args.case:
        srv.prewarm(args.case, n=args.n, ds=args.ds)
    print(f"# serving on {srv.host}:{srv.port} slots={srv.slots} "
          f"queue={srv.queue_cap} block={policy.block} mode={mode}"
          + (f" checkpoint={srv.ckdir}" if srv.ckdir else "")
          + (f" chaos={args.chaos}" if args.chaos else "")
          + (f" predecessor={srv.predecessor}" if srv.predecessor else ""),
          flush=True)
    srv.serve_forever()
    print("# drained cleanly", flush=True)
    return 0


def cmd_request(args) -> int:
    from repro.sph import client

    req: dict = {"case": args.case, "observe": args.observe}
    if args.resume_token:
        req = {"resume_token": args.resume_token}
    if args.nsteps is not None:
        req["nsteps"] = args.nsteps
    if args.n is not None:
        req["n"] = args.n
    if args.ds is not None:
        req["ds"] = args.ds
    if args.deadline_s is not None:
        req["deadline_s"] = args.deadline_s
    if args.inject is not None:
        req["inject"] = {"kind": args.inject}
    logging.basicConfig(level=logging.WARNING)
    if args.retry > 0:
        frames, term = client.run_request_resilient(
            args.host, args.port, req, timeout=args.timeout,
            retries=args.retry)
    else:
        frames, term = client.run_request(
            args.host, args.port, req, timeout=args.timeout)
    for f in frames:
        print(json.dumps(f))
    if term is None:
        print("# connection closed without a terminal reply",
              file=sys.stderr)
        return 1
    return 0 if term.get("type") in ("done", "stats") else 1


def cmd_lint(args) -> int:
    # alias for ``python -m tools.sphlint`` so the scenario CLI is the
    # single entry point; tools/ lives at the repo root, outside the
    # src/ package tree, so resolve it relative to this file
    from pathlib import Path

    repo_root = Path(__file__).resolve().parents[3]
    if not (repo_root / "tools" / "sphlint").is_dir():
        print("lint: tools/sphlint not found (running from an installed "
              "package? invoke it from a repo checkout)", file=sys.stderr)
        return 2
    if str(repo_root) not in sys.path:
        sys.path.insert(0, str(repo_root))
    from tools.sphlint.__main__ import main as sphlint_main

    return sphlint_main(args.sphlint_args or ["check"])


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.sph")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lp = sub.add_parser("list", help="list registered cases")
    lp.add_argument("--names", action="store_true",
                    help="bare case names only (for scripting)")
    lp.set_defaults(fn=cmd_list)

    rp = sub.add_parser("run", help="run a registered case")
    rp.add_argument("case", choices=cases_lib.case_names())
    rp.add_argument("--nsteps", type=int, default=None)
    rp.add_argument("--observe-every", type=int, default=None)
    rp.add_argument("--ds", type=float, default=None)
    rp.add_argument("--n", type=int, default=None,
                    help="target fluid particle count (sets ds)")
    rp.add_argument("--backend", default=None,
                    choices=["reference", "xla", "pallas"])
    rp.add_argument("--records", default=None,
                    choices=["fp32", "fp16", "bf16"])
    rp.add_argument("--time", action="store_true",
                    help="run twice and report steps/sec (compile excluded)")
    rp.add_argument("--guard", action="store_true",
                    help="run under the self-healing health guard")
    rp.add_argument("--guard-block", type=int, default=None,
                    help="steps per guarded block (default: policy's 32)")
    rp.add_argument("--inject", default=None,
                    choices=["nan", "teleport", "cap", "window", "dt"],
                    help="arm a named fault (implies --guard)")
    rp.add_argument("--set", action="append", metavar="FIELD=VALUE",
                    help="override any case dataclass field")
    rp.add_argument("--json", action="store_true",
                    help="machine-readable output: one JSON document "
                    "(schema repro.sph.run/1) instead of the table")
    rp.set_defaults(fn=cmd_run)

    sp = sub.add_parser(
        "sweep",
        help="run a batched fault-isolated ensemble sweep of a case",
    )
    sp.add_argument("case", choices=cases_lib.case_names())
    sp.add_argument("--batch", type=int, default=4,
                    help="members per config variant (default 4)")
    sp.add_argument("--nsteps", type=int, default=None)
    sp.add_argument("--perturb", type=float, default=0.01,
                    help="stddev of the per-member fluid velocity "
                    "perturbation (member 0 stays unperturbed)")
    sp.add_argument("--seed", type=int, default=0)
    sp.add_argument("--vary", default=None, metavar="FIELD=V1,V2,...",
                    help="sweep an SPHConfig field; each value is its "
                    "own shape bucket of --batch members")
    sp.add_argument("--block", type=int, default=None,
                    help="ensemble block length (= rebuild cadence; "
                    "default: policy's 32)")
    sp.add_argument("--ds", type=float, default=None)
    sp.add_argument("--n", type=int, default=None,
                    help="target fluid particle count (sets ds)")
    sp.add_argument("--backend", default=None,
                    choices=["reference", "xla", "pallas"])
    sp.add_argument("--records", default=None,
                    choices=["fp32", "fp16", "bf16"])
    sp.add_argument("--inject", default=None,
                    choices=["nan", "teleport"],
                    help="arm a deterministic fault on ONE member "
                    "(--inject-member); the lane-masked recovery must "
                    "leave the rest of the batch bit-identical")
    sp.add_argument("--inject-member", type=int, default=0,
                    help="flat member index the fault arms (default 0)")
    sp.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="durable sweep state under DIR (per-bucket "
                    "CheckpointManager subdirs + sweep.json manifest)")
    sp.add_argument("--checkpoint-every", type=int, default=1,
                    help="blocks between checkpoints (default 1)")
    sp.add_argument("--keep", type=int, default=3,
                    help="checkpoint steps to retain; 0 keeps all")
    sp.add_argument("--resume", action="store_true",
                    help="resume an interrupted sweep from the latest "
                    "valid checkpoint (bit-identical continuation)")
    sp.add_argument("--set", action="append", metavar="FIELD=VALUE",
                    help="override any case dataclass field")
    sp.set_defaults(fn=cmd_sweep)

    vp = sub.add_parser(
        "serve",
        help="online simulation service: live-batch lane admission "
        "over a socket",
    )
    vp.add_argument("case", nargs="?", default=None,
                    choices=cases_lib.case_names(),
                    help="optional case to prewarm (build + compile "
                    "one block before the first request)")
    vp.add_argument("--host", default="127.0.0.1")
    vp.add_argument("--port", type=int, default=7853,
                    help="listen port; 0 picks a free one (default 7853)")
    vp.add_argument("--slots", type=int, default=8,
                    help="lanes per shape bucket (default 8)")
    vp.add_argument("--queue", type=int, default=32,
                    help="admission queue bound; a full queue answers "
                    "REJECTED busy (default 32)")
    vp.add_argument("--block", type=int, default=None,
                    help="engine block length / streaming granularity "
                    "(default: policy's 32)")
    vp.add_argument("--checkpoint", default=None, metavar="DIR",
                    help="drain checkpoints + heartbeat under DIR "
                    "(enables RETRY_AFTER resume tokens); multi-process "
                    "mode defaults to a temp dir so per-block recovery "
                    "checkpoints always have a home")
    vp.add_argument("--ds", type=float, default=None,
                    help="prewarm resolution (spacing)")
    vp.add_argument("--n", type=int, default=None,
                    help="prewarm resolution (target fluid count)")
    vp.add_argument("--single-process", action="store_true",
                    help="run engines in the server process (legacy "
                    "mode: no crash containment, no worker restarts)")
    vp.add_argument("--max-restarts", type=int, default=3,
                    help="worker restarts per shape bucket before its "
                    "requests get RETRY_AFTER with resume tokens "
                    "(default 3)")
    vp.add_argument("--hang-timeout", type=float, default=600.0,
                    help="seconds without block progress before a "
                    "heartbeat-alive worker is declared hung and "
                    "SIGKILLed (default 600)")
    vp.add_argument("--save-every", type=int, default=1,
                    help="blocks between per-lane recovery checkpoints "
                    "inside each worker (default 1 = lose at most one "
                    "block on a crash)")
    vp.add_argument("--drain-timeout", type=float, default=60.0,
                    help="seconds to wait for workers to finish final "
                    "saves on SIGTERM drain (default 60)")
    vp.add_argument("--chaos", default=None,
                    choices=["kill", "hang", "oom-sim"],
                    help="fault-injection harness: once a worker is "
                    "busy and progressing, inject this fault (test/CI "
                    "only; proves unattended recovery)")
    vp.set_defaults(fn=cmd_serve)

    qp = sub.add_parser(
        "request",
        help="send one request to a running serve endpoint and print "
        "the reply frames as JSON lines",
    )
    qp.add_argument("case", nargs="?", default=None,
                    choices=cases_lib.case_names())
    qp.add_argument("--host", default="127.0.0.1")
    qp.add_argument("--port", type=int, default=7853)
    qp.add_argument("--nsteps", type=int, default=None)
    qp.add_argument("--n", type=int, default=None)
    qp.add_argument("--ds", type=float, default=None)
    qp.add_argument("--observe", action="store_true",
                    help="stream per-block observable frames")
    qp.add_argument("--deadline-s", type=float, default=None)
    qp.add_argument("--inject", default=None, choices=["nan", "teleport"],
                    help="poison the request (server answers DIVERGED "
                    "after its lane-masked ladder is exhausted)")
    qp.add_argument("--resume-token", default=None,
                    help="resume drained work from a RETRY_AFTER token")
    qp.add_argument("--timeout", type=float, default=300.0)
    qp.add_argument("--retry", type=int, default=3, metavar="N",
                    help="auto-recovery budget: on RETRY_AFTER resubmit "
                    "the resume token, on mid-stream EOF reconnect, with "
                    "capped exponential backoff (default 3; 0 disables)")
    qp.set_defaults(fn=cmd_request)

    tp = sub.add_parser(
        "lint",
        help="static trace-hygiene analysis (alias for python -m "
        "tools.sphlint; args pass through, e.g. "
        "`lint check src/repro` or `lint trace --backends xla`)",
    )
    tp.add_argument("sphlint_args", nargs=argparse.REMAINDER,
                    help="arguments forwarded to tools.sphlint "
                    "(default: check)")
    tp.set_defaults(fn=cmd_lint)

    args = ap.parse_args(argv)
    if getattr(args, "fn", None) is cmd_request and not (
            args.case or args.resume_token):
        qp.error("request wants a case or --resume-token")
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
