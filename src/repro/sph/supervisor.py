"""Crash-contained multi-process serving: frontend + engine workers.

:class:`FrontendServer` is the process clients connect to. It owns the
client listener, frame validation, and the bounded admission queue
(all inherited from :class:`repro.sph.serve.ServerBase`) — but no JAX
compute. Each shape bucket (normalized case+resolution+overrides, see
:func:`repro.sph.serve.request_key`) runs in its OWN engine-worker
process (:mod:`repro.sph.worker`), spawned on demand, connected back
over a localhost IPC socket speaking the same length-prefixed frame
protocol. A native crash in one bucket (XLA segfault, OOM kill,
runaway compile) kills one worker process; the frontend and every
sibling bucket keep streaming, bit-identical to solo runs.

The supervisor (part of the frontend's engine loop) detects worker
death three ways:

  1. IPC channel EOF / process exit — the fast path for clean crashes;
  2. stale heartbeat — ``HeartbeatMonitor.host_status() == "dead"`` on
     the worker's dir (mtime-based, immune to wall-clock steps): the
     process stopped beating without clearing;
  3. hang watchdog — heartbeat ALIVE but no progress frames past
     ``hang_timeout_s`` while requests are assigned: the engine loop is
     wedged (stuck native call); the supervisor SIGKILLs it. The
     watchdog arms only after the current process has reported at
     least one block of progress, so a long first compile is never
     mistaken for a hang.

On death the worker is restarted with capped exponential backoff; the
restarted process reclaims the dead pid's lockfiles (quietly — one
summary line, not one warning per lane) and every in-flight request is
re-admitted from its last per-lane block checkpoint (written
continuously, every healthy block — recovery loses at most
``save_every`` blocks). Clients see a streamed ``EVENT recovering``
then seamless OBS continuation. If the worker dies more than
``max_restarts`` times, its in-flight requests get a structured
``RETRY_AFTER`` with a resume token (the lane checkpoints stay on
disk; resubmitting the token respawns a fresh worker and resumes).

Chaos modes (``repro.sph serve --chaos kill|hang|oom-sim``) inject one
real fault into the first busy worker that completes a block: ``kill``
SIGKILLs it from the supervisor, ``hang`` wedges its engine loop while
its heartbeat keeps beating (exercises the hang watchdog), ``oom-sim``
makes it ``os._exit(137)`` right after a block (the OOM-killer shape).
The request must still finish — bit-identical to an uninterrupted run
— with no operator action; ``tests/chaos.py`` drives these.
"""
from __future__ import annotations

import glob
import json
import logging
import os
import secrets
import socket
import subprocess
import sys
import tempfile
import threading
import time
from collections import deque

import repro
from repro.core import recovery
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.sph.serve import (
    ServerBase,
    _Conn,
    _Pending,
    recv_frame,
    request_key,
    worker_tag,
)

log = logging.getLogger("repro.serve")

CHAOS_MODES = ("kill", "hang", "oom-sim")


class WorkerHandle:
    """Supervisor-side state for one engine-worker process."""

    def __init__(self, wid: int, wkey: str, tag: str, wdir: str):
        self.wid = wid
        self.wkey = wkey
        self.tag = tag
        self.dir = wdir
        self.secret: str | None = None
        self.proc: subprocess.Popen | None = None
        self.conn: _Conn | None = None
        self.pid: int | None = None
        # spawning -> ready -> (backoff -> spawning)* ; drained
        self.state = "spawning"
        self.restarts = 0
        self.restart_at = 0.0
        self.spawn_t = 0.0
        self.last_frame = 0.0
        self.blocks = 0
        self.progress_since_spawn = False
        self.eof = False
        self.drained_steps: dict[str, int] | None = None
        self.assigned: dict[str, _Pending] = {}  # rid -> request

    @property
    def alive_proc(self) -> bool:
        return self.proc is not None and self.proc.poll() is None


class FrontendServer(ServerBase):
    """Multi-process SPH service: routing frontend + worker supervisor.

    Drop-in for :class:`SimServer` at the socket: same client protocol,
    same drain semantics, same stats op (plus ``worker_restarts`` /
    ``recovered_lanes`` / ``workers``). Requires a checkpoint root (a
    private tempdir is created when none is given — in-flight recovery
    needs somewhere to write lane checkpoints).
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        slots: int = 8,
        queue: int = 32,
        policy: recovery.GuardPolicy | None = None,
        checkpoint_dir: str | None = None,
        heartbeat_timeout_s: float = 60.0,
        max_restarts: int = 3,
        hang_timeout_s: float = 600.0,
        backoff_base_s: float = 0.5,
        backoff_cap_s: float = 10.0,
        save_every: int = 1,
        drain_timeout_s: float = 60.0,
        spawn_timeout_s: float = 120.0,
        worker_hb_timeout_s: float = 10.0,
        chaos: str | None = None,
    ):
        self.policy = policy or recovery.GuardPolicy()
        self.slots = int(slots)
        self.max_restarts = int(max_restarts)
        self.hang_timeout_s = float(hang_timeout_s)
        self.backoff_base_s = float(backoff_base_s)
        self.backoff_cap_s = float(backoff_cap_s)
        self.save_every = int(save_every)
        self.drain_timeout_s = float(drain_timeout_s)
        self.spawn_timeout_s = float(spawn_timeout_s)
        self.worker_hb_timeout_s = float(worker_hb_timeout_s)
        if chaos is not None and chaos not in CHAOS_MODES:
            raise ValueError(f"chaos mode {chaos!r}; one of {CHAOS_MODES}")
        self.chaos = chaos
        self.chaos_fired_t: float | None = None
        self.last_recovery_s: float | None = None
        self.workers: dict[str, WorkerHandle] = {}  # wkey -> handle
        self.inflight: dict[str, _Pending] = {}     # rid -> request
        self.worker_restarts = 0
        self.recovered_lanes = 0
        self._next_wid = 0
        self._next_rid = 0
        self._by_secret: dict[str, WorkerHandle] = {}
        self._wframes: deque[tuple[WorkerHandle, dict]] = deque()
        self._prewarm_ok = threading.Event()
        if checkpoint_dir is None:
            checkpoint_dir = tempfile.mkdtemp(prefix="sph-serve-")
            log.warning("serve: no --checkpoint given; lane checkpoints "
                        "under %s (resume tokens die with it)",
                        checkpoint_dir)
        # the worker-facing IPC listener (localhost, secret-handshake)
        self.ipc_sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.ipc_sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.ipc_sock.bind(("127.0.0.1", 0))
        self.ipc_sock.listen(32)
        self.ipc_port = self.ipc_sock.getsockname()[1]
        super().__init__(host, port, queue=queue,
                         checkpoint_dir=checkpoint_dir,
                         heartbeat_timeout_s=heartbeat_timeout_s)
        # started after super().__init__: the loop needs self.stopped
        threading.Thread(target=self._ipc_accept_loop,
                         daemon=True).start()
        log.info("serve: frontend on %s:%d (ipc=%d slots=%d queue=%d "
                 "block=%d max_restarts=%d%s)", self.host, self.port,
                 self.ipc_port, self.slots, self.queue_cap,
                 self.policy.block, self.max_restarts,
                 f" chaos={chaos}" if chaos else "")

    def _has_resumables(self) -> bool:
        return (os.path.isdir(os.path.join(self.ckdir, "drain"))
                or bool(glob.glob(os.path.join(
                    self.ckdir, "workers", "*", "lanes", "*"))))

    # ---- monitoring -----------------------------------------------------
    def _live_steps(self) -> list[int]:
        return sorted(p.steps for p in list(self.inflight.values()))

    def _extra_stats(self) -> dict:
        return {
            "live": len(self.inflight),
            "buckets": len(self.workers),
            "worker_restarts": self.worker_restarts,
            "recovered_lanes": self.recovered_lanes,
            "chaos": self.chaos,
            "chaos_fired": self.chaos_fired_t is not None,
            "recovery_s": self.last_recovery_s,
            "workers": [
                {"wid": h.wid, "tag": h.tag, "pid": h.pid,
                 "state": h.state, "restarts": h.restarts,
                 "blocks": h.blocks, "assigned": len(h.assigned)}
                for h in list(self.workers.values())],
        }

    # ---- worker IPC (handshake + reader threads) ------------------------
    def _ipc_accept_loop(self):
        while not self.stopped.is_set():
            try:
                sock, _ = self.ipc_sock.accept()
            except OSError:
                return
            threading.Thread(target=self._ipc_reader, args=(sock,),
                             daemon=True).start()

    def _ipc_reader(self, sock: socket.socket):
        """Authenticate one worker connection, then pump its frames to
        the engine thread. IO only — all state changes happen on the
        engine thread via the _wframes queue."""
        try:
            sock.settimeout(10.0)
            hello = recv_frame(sock)
            if (not isinstance(hello, dict)
                    or hello.get("type") != "hello"):
                sock.close()
                return
            with self.cond:
                h = self._by_secret.pop(hello.get("secret"), None)
            if h is None:
                log.warning("serve: worker connection with unknown "
                            "secret rejected")
                sock.close()
                return
            sock.settimeout(None)
            h.conn = _Conn(sock)
            self._enqueue(h, hello)
            while True:
                f = recv_frame(sock)
                if f is None:
                    break
                self._enqueue(h, f)
        except (ValueError, OSError):
            pass
        if "h" in locals() and h is not None:
            h.eof = True
            with self.cond:
                self.cond.notify()

    def _enqueue(self, h: WorkerHandle, frame: dict):
        with self.cond:
            self._wframes.append((h, frame))
            self.cond.notify()

    def _drain_wframes(self) -> list[tuple[WorkerHandle, dict]]:
        with self.cond:
            out = list(self._wframes)
            self._wframes.clear()
        return out

    # ---- worker lifecycle ----------------------------------------------
    def _workers_root(self) -> str:
        return os.path.join(self.ckdir, "workers")

    def _ensure_worker(self, wkey: str, tag: str) -> WorkerHandle:
        h = self.workers.get(wkey)
        if h is None:
            wdir = os.path.join(self._workers_root(), tag)
            h = WorkerHandle(self._next_wid, wkey, tag, wdir)
            self._next_wid += 1
            self.workers[wkey] = h
            self._spawn(h)
        return h

    def _spawn(self, h: WorkerHandle):
        h.secret = secrets.token_hex(16)
        with self.cond:
            self._by_secret[h.secret] = h
        h.state = "spawning"
        h.spawn_t = time.monotonic()
        h.eof = False
        h.conn = None
        h.pid = None
        h.progress_since_spawn = False
        cmd = [sys.executable, "-m", "repro.sph.worker",
               "--connect", str(self.ipc_port), "--secret", h.secret,
               "--wid", str(h.wid), "--dir", h.dir,
               "--slots", str(self.slots),
               "--block", str(self.policy.block),
               "--save-every", str(self.save_every)]
        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(
            os.path.abspath(repro.__file__)))
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        h.proc = subprocess.Popen(cmd, env=env)
        log.info("serve: spawned worker w%d pid=%d for %s%s", h.wid,
                 h.proc.pid, h.tag,
                 f" (restart {h.restarts}/{self.max_restarts})"
                 if h.restarts else "")

    def _send_admit(self, h: WorkerHandle, p: _Pending):
        if h.conn is not None:
            h.conn.send({"type": "admit", "rid": p.rid,
                         "token": p.token, "req": p.req})

    # ---- routing --------------------------------------------------------
    def _resolve_token(self, token: str) -> dict | None:
        """Resume token -> the saved request, located by scanning the
        worker lane dirs (stable across frontend restarts)."""
        hits = glob.glob(os.path.join(
            self._workers_root(), "*", "lanes", token, "token.json"))
        for hit in hits:
            try:
                with open(hit) as f:
                    return json.load(f)["request"]
            except (OSError, json.JSONDecodeError, KeyError):
                continue
        return None

    def _route(self, p: _Pending) -> bool:
        """Try to hand one queued request to its bucket's worker.
        True if it left the queue (sent, or terminally answered);
        False to retry next tick (worker still spawning/backing off)."""
        if p.token is None:
            if "resume_token" in p.req:
                token = p.req["resume_token"]
                saved = self._resolve_token(token)
                if saved is None:
                    p.reply({"type": "error", "reason": "bad_token",
                             "detail": "unknown or corrupt resume "
                             f"token {token!r}"})
                    p.conn.close()
                    return True
                # merge: the original run, with the resubmission's
                # flags (observe/return_state/deadline) on top
                p.req = {**saved,
                         **{k: v for k, v in p.req.items()
                            if k != "resume_token"}}
                p.token = token
            else:
                p.token = secrets.token_hex(8)
        h = self._ensure_worker(request_key(p.req), worker_tag(p.req))
        if h.state != "ready":
            return False  # spawning or in backoff: stays queued
        if p.rid is None:
            p.rid = f"r{self._next_rid}"
            self._next_rid += 1
        p.wkey = h.wkey
        self.inflight[p.rid] = p
        h.assigned[p.rid] = p
        self._send_admit(h, p)
        return True

    # ---- worker frame handling (engine thread) --------------------------
    def _handle_worker_frame(self, h: WorkerHandle, f: dict):
        h.last_frame = time.monotonic()
        kind = f.get("type")
        if kind == "hello":
            h.pid = int(f.get("pid") or 0)
            h.state = "ready"
            log.info("serve: worker w%d (%s) ready, pid=%d", h.wid,
                     h.tag, h.pid)
            # crash recovery: re-admit everything it owed, from the
            # per-lane checkpoints its predecessor wrote
            for p in list(h.assigned.values()):
                self._send_admit(h, p)
            return
        if kind == "progress":
            h.blocks = int(f.get("blocks") or 0)
            h.progress_since_spawn = True
            for rid, steps in (f.get("steps") or {}).items():
                p = self.inflight.get(rid)
                if p is not None:
                    p.steps = int(steps)
            return
        if kind == "drained":
            h.drained_steps = {str(k): int(v) for k, v in
                               (f.get("steps") or {}).items()}
            h.state = "drained"
            return
        if kind == "prewarmed":
            self._prewarm_ok.set()
            return
        if kind == "pong":
            return
        rid = f.get("rid")
        p = self.inflight.get(rid) if rid is not None else None
        if p is None:
            if kind == "error":  # e.g. prewarm build failure
                log.warning("serve: worker w%d error: %s", h.wid,
                            f.get("detail"))
            return
        if kind == "accepted":
            p.nsteps = int(f.get("nsteps") or 0)
            p.observe = bool(p.req.get("observe"))
            p.return_state = bool(p.req.get("return_state"))
            if p.deadline is None and p.req.get("deadline_s") is not None:
                p.deadline = p.received + float(p.req["deadline_s"])
            if p.recovering:
                # re-admitted after a crash: the client already holds
                # an ACCEPTED; OBS now continues from the checkpoint
                p.recovering = False
                p.recovered = True
                self.recovered_lanes += 1
                log.info("serve: %s resumed on w%d at step %s", p.rid,
                         h.wid, f.get("steps_done"))
            else:
                p.reply({"type": "accepted", "lane": f.get("lane"),
                         "nsteps": p.nsteps, "block": self.policy.block,
                         "bucket": h.tag,
                         "resumed": bool(f.get("resumed"))})
            return
        if kind == "busy":
            # EngineFull/FaultBusy backpressure: back to the queue
            h.assigned.pop(rid, None)
            self.inflight.pop(rid, None)
            p.rid = None
            with self.cond:
                self.pending.append(p)
            return
        if kind == "obs":
            p.steps = int(f.get("step") or p.steps)
            if (self.chaos_fired_t is not None and p.recovered
                    and self.last_recovery_s is None):
                # chaos fire -> first post-restart OBS: the recovery
                # latency the --chaos benchmark records
                self.last_recovery_s = (
                    time.monotonic() - self.chaos_fired_t)
            if p.observe:
                relay = {k: v for k, v in f.items() if k != "rid"}
                if not p.reply(relay):
                    # client hung up mid-stream: free the lane
                    self._retire(h, p, discard=True)
            return
        if kind == "event":
            p.reply({k: v for k, v in f.items() if k != "rid"})
            return
        if kind in ("done", "diverged", "error"):
            p.reply({k: v for k, v in f.items() if k != "rid"})
            if kind == "done":
                self.completed += 1
            p.conn.close()
            h.assigned.pop(rid, None)
            self.inflight.pop(rid, None)
            return
        log.warning("serve: unknown worker frame %r from w%d", kind,
                    h.wid)

    def _retire(self, h: WorkerHandle, p: _Pending, *, discard: bool):
        if h.conn is not None:
            h.conn.send({"type": "retire", "rid": p.rid,
                         "discard": discard})
        h.assigned.pop(p.rid, None)
        self.inflight.pop(p.rid, None)
        p.conn.close()

    # ---- supervision ----------------------------------------------------
    def _supervise(self):
        now = time.monotonic()
        self._maybe_fire_chaos(now)
        for wkey, h in list(self.workers.items()):
            if h.state == "backoff":
                if now >= h.restart_at:
                    self._spawn(h)
                continue
            if h.state == "drained":
                continue
            if h.state == "spawning":
                if not h.alive_proc:
                    self._on_death(h, "exited during spawn")
                elif now - h.spawn_t > self.spawn_timeout_s:
                    self._kill(h)
                    self._on_death(h, "spawn timeout")
                continue
            # state == "ready"
            if h.eof or not h.alive_proc:
                self._on_death(h, "channel EOF" if h.eof
                               else "process exit")
                continue
            hb = HeartbeatMonitor(
                h.dir, timeout_s=self.worker_hb_timeout_s)
            if h.assigned and hb.host_status(0) == "dead":
                self._kill(h)
                self._on_death(h, "heartbeat stale")
                continue
            if (h.assigned and h.progress_since_spawn
                    and now - h.last_frame > self.hang_timeout_s):
                # heartbeat alive but no block progress: wedged engine
                self._kill(h)
                self._on_death(h, "hang (no progress past "
                               f"{self.hang_timeout_s:.0f}s)")

    def _kill(self, h: WorkerHandle):
        if h.alive_proc:
            try:
                h.proc.kill()
                h.proc.wait(timeout=10)
            except OSError:
                pass

    def _on_death(self, h: WorkerHandle, why: str):
        h.restarts += 1
        self.worker_restarts += 1
        if h.alive_proc:  # EOF with the process somehow lingering
            self._kill(h)
        log.warning("serve: worker w%d (%s) died: %s — %d in-flight, "
                    "restart %d/%d", h.wid, h.tag, why, len(h.assigned),
                    h.restarts, self.max_restarts)
        for p in list(h.assigned.values()):
            if not p.recovering:
                p.recovering = True
                p.reply({"type": "event", "action": "recovering",
                         "step": p.steps,
                         "detail": f"engine worker died ({why}); "
                         "restarting from last block checkpoint"})
        if h.restarts > self.max_restarts:
            log.error("serve: worker w%d exceeded max_restarts=%d; "
                      "shedding %d request(s) with resume tokens",
                      h.wid, self.max_restarts, len(h.assigned))
            for p in list(h.assigned.values()):
                p.reply({"type": "retry_after", "token": p.token,
                         "steps_done": p.steps, "nsteps": p.nsteps,
                         "detail": "engine worker exceeded "
                         f"max_restarts={self.max_restarts}; resume "
                         "later with the token"})
                p.conn.close()
                self.inflight.pop(p.rid, None)
            # drop the handle: lane checkpoints stay on disk, and a
            # later request (or token resubmission) starts a fresh
            # worker with a clean restart budget
            del self.workers[h.wkey]
            return
        delay = min(self.backoff_cap_s,
                    self.backoff_base_s * 2 ** (h.restarts - 1))
        h.state = "backoff"
        h.restart_at = time.monotonic() + delay
        log.info("serve: restarting w%d in %.1fs", h.wid, delay)

    def _maybe_fire_chaos(self, now: float):
        if self.chaos is None or self.chaos_fired_t is not None:
            return
        for h in self.workers.values():
            # blocks >= 2: the previous block's async checkpoint has
            # committed, so the kill exercises RESUME (lose <= 1 block),
            # not a from-scratch replay
            if (h.state == "ready" and h.assigned
                    and h.progress_since_spawn and h.blocks >= 2):
                log.warning("serve: CHAOS %s on worker w%d (pid=%s)",
                            self.chaos, h.wid, h.pid)
                self.chaos_fired_t = now
                if self.chaos == "kill":
                    self._kill(h)
                elif h.conn is not None:
                    h.conn.send({"type": "chaos", "mode": self.chaos})
                return

    # ---- the loop -------------------------------------------------------
    def prewarm(self, case: str, **req):
        """Spawn the bucket's worker and compile its block program
        before the first request (blocks until the worker reports
        ``prewarmed``). Must run before the engine loop starts."""
        if self._running:
            raise RuntimeError("prewarm() after the engine loop started")
        req = {"case": case,
               **{k: v for k, v in req.items() if v is not None}}
        h = self._ensure_worker(request_key(req), worker_tag(req))
        sent = False
        deadline = time.monotonic() + self.spawn_timeout_s + 600.0
        while time.monotonic() < deadline:
            for wh, f in self._drain_wframes():
                self._handle_worker_frame(wh, f)
            if h.state == "ready" and not sent:
                h.conn.send({"type": "prewarm", "req": req})
                sent = True
            if self._prewarm_ok.is_set():
                log.info("serve: prewarmed %s on w%d", case, h.wid)
                return
            if not h.alive_proc and h.state != "ready":
                raise RuntimeError(
                    f"prewarm worker for {case} died during startup")
            with self.cond:
                self.cond.wait(timeout=0.1)
        raise RuntimeError(f"prewarm of {case} timed out")

    def _tick(self):
        frames = self._drain_wframes()
        for h, f in frames:
            self._handle_worker_frame(h, f)
        with self.cond:
            queued = list(self.pending)
        for p in queued:
            try:
                left = self._route(p)
            except Exception:  # noqa: BLE001 - routing must not kill the loop
                log.exception("serve: routing failed")
                p.reply({"type": "error", "reason": "build_failed",
                         "detail": "request routing failed"})
                p.conn.close()
                left = True
            if left:
                with self.cond:
                    try:
                        self.pending.remove(p)
                    except ValueError:
                        pass
        self._supervise()
        if self.hb is not None:
            self.hb.beat(self.completed)
        now = time.monotonic()
        for rid, p in list(self.inflight.items()):
            if p.deadline is not None and now > p.deadline:
                p.reply({"type": "timeout",
                         "deadline_s": p.req["deadline_s"],
                         "steps_done": p.steps})
                h = self.workers.get(p.wkey)
                if h is not None:
                    self._retire(h, p, discard=True)
                else:
                    p.conn.close()
                    self.inflight.pop(rid, None)
        if not frames:
            with self.cond:
                if (not self.pending and not self._wframes
                        and not self.draining.is_set()):
                    self.cond.wait(timeout=0.05)

    # ---- drain ----------------------------------------------------------
    def _drain(self):
        log.warning("serve: draining (%d in-flight, %d queued, %d "
                    "workers)", len(self.inflight), len(self.pending),
                    len(self.workers))
        for h in self.workers.values():
            if h.state == "ready" and h.conn is not None:
                h.conn.send({"type": "drain"})
        deadline = time.monotonic() + self.drain_timeout_s
        while time.monotonic() < deadline:
            for h, f in self._drain_wframes():
                self._handle_worker_frame(h, f)
            busy = [h for h in self.workers.values()
                    if h.state == "ready" and h.assigned
                    and h.alive_proc]
            if not busy:
                break
            with self.cond:
                self.cond.wait(timeout=0.1)
        # every in-flight request gets its token: the lane checkpoints
        # are already on disk (continuous per-block saves), with the
        # drain's final save on top where the worker answered in time
        for rid, p in list(self.inflight.items()):
            h = self.workers.get(p.wkey)
            steps = p.steps
            if h is not None and h.drained_steps is not None:
                steps = h.drained_steps.get(rid, steps)
            p.reply({"type": "retry_after", "token": p.token,
                     "steps_done": int(steps), "nsteps": p.nsteps})
            p.conn.close()
        self.inflight.clear()
        with self.cond:
            queued, self.pending = list(self.pending), deque()
        for p in queued:
            p.reply({"type": "retry_after", "token": None,
                     "detail": "server is draining; resubmit"})
            p.conn.close()
        if self.hb is not None:
            self.hb.clear()

    def _shutdown(self):
        try:
            self.ipc_sock.close()
        except OSError:
            pass
        for h in self.workers.values():
            if h.alive_proc:
                try:
                    h.proc.terminate()
                except OSError:
                    pass
        for h in self.workers.values():
            if h.proc is not None:
                try:
                    h.proc.wait(timeout=10)
                except subprocess.TimeoutExpired:
                    self._kill(h)
