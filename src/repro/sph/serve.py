"""Online simulation service: live-batch admission over a socket.

``python -m repro.sph serve`` turns the PR 7 ensemble engine into an
always-on endpoint: clients submit case+parameter requests over a
length-prefixed JSON protocol, the server bins them into normalized-
config shape buckets, and each bucket is a live :class:`LaneEngine`
batch — free lanes sit masked-inactive, an admitted request warm-starts
its lane at the next block boundary WITHOUT recompiling its neighbors,
and completion/divergence/timeout frees the slot the same way.

Wire protocol (stdlib only): each frame is a 4-byte big-endian length
followed by that many bytes of UTF-8 JSON. One request per connection;
the server streams reply frames (ACCEPTED, then OBS/EVENT per block,
then one terminal DONE / DIVERGED / TIMEOUT / RETRY_AFTER / REJECTED /
ERROR frame) and closes.

Request fields (all optional unless noted):
  op            "run" (default) | "stats"
  case          registered case name (required for "run")
  n | ds        resolution (target fluid count, or spacing directly)
  nsteps        steps to advance (default: the case's default_nsteps;
                rounded UP to whole engine blocks)
  overrides     dict of case-field overrides (build_case kwargs)
  backend       "reference" | "xla" | "pallas"
  records       "fp32" | "fp16" | "bf16"
  observe       bool: stream an OBS frame per completed block
  deadline_s    wall-clock budget from receipt; exceeded -> TIMEOUT
  inject        {"kind": "nan"|"teleport", "step": int?} fault injection
                (treated as client poison: the disarm rung is skipped,
                so an unrecoverable injection ends in DIVERGED)
  return_state  bool: DONE carries the final state as base64 npz
                (bit-exact; the e2e test diffs it against a solo run)
  resume_token  token from a RETRY_AFTER reply: resume drained work
  request_id    opaque, echoed on every reply frame

Robustness semantics (the point of this module):
  * bounded admission queue — a full queue answers REJECTED busy
    immediately (load-shedding, never unbounded growth);
  * malformed frames answer ERROR malformed (structural validation in
    the reader thread; nothing malformed reaches the engine thread);
  * a poisoned request runs the PR 6/7 ladder's masked rungs on its own
    lane and dies with a structured DIVERGED reply — healthy in-flight
    requests stay bit-identical to solo runs (lane masking passes
    their bits through);
  * per-request deadlines cancel overdue lanes with a TIMEOUT reply;
  * SIGTERM/SIGINT drains gracefully: stop admitting, checkpoint every
    in-flight lane via :class:`CheckpointManager`, reply RETRY_AFTER
    with a resume token honored after restart (queued-but-unadmitted
    requests get RETRY_AFTER with token=null: resubmit).

Threading: the accept thread and per-connection reader threads do ONLY
socket IO + structural validation; a single engine thread owns every
JAX call (case building, admission splices, block stepping), so device
state is never touched concurrently.
"""
from __future__ import annotations

import base64
import dataclasses
import hashlib
import io
import json
import logging
import os
import secrets
import shutil
import socket
import struct
import threading
import time
from collections import deque

import numpy as np

from repro.checkpoint import manager as ckpt
from repro.core import cases as cases_lib
from repro.core import ensemble, health, recovery
from repro.core.api import Simulation
from repro.core.precision import PrecisionPolicy
from repro.runtime.fault_tolerance import (
    HeartbeatMonitor,
    HeartbeatWriter,
    StragglerWatchdog,
)

log = logging.getLogger("repro.serve")

MAX_FRAME = 64 << 20  # 64 MiB: a return_state reply at ~1M particles
_LEN = struct.Struct(">I")


# --------------------------------------------------------------------------
# Framing (shared with sph/client.py)
# --------------------------------------------------------------------------
def send_frame(sock: socket.socket, obj: dict):
    payload = json.dumps(obj).encode()
    sock.sendall(_LEN.pack(len(payload)) + payload)


def _recv_exact(sock: socket.socket, n: int) -> bytes | None:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            return None
        buf += chunk
    return buf


def recv_frame(sock: socket.socket, max_frame: int = MAX_FRAME):
    """One frame, parsed; None on clean EOF. Raises ValueError on an
    oversized or non-JSON frame (protocol violation, not EOF)."""
    head = _recv_exact(sock, _LEN.size)
    if head is None:
        return None
    (n,) = _LEN.unpack(head)
    if n > max_frame:
        raise ValueError(f"frame of {n} bytes exceeds cap {max_frame}")
    body = _recv_exact(sock, n)
    if body is None:
        raise ValueError("connection closed mid-frame")
    try:
        return json.loads(body.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise ValueError(f"frame is not JSON: {e}") from e


def encode_state(state) -> str:
    """Final SPHState -> base64 npz of its flat arrays (bit-exact)."""
    flat = {k: np.asarray(v) for k, v in ckpt._flatten(state).items()}
    bio = io.BytesIO()
    np.savez(bio, **flat)
    return base64.b64encode(bio.getvalue()).decode()


def decode_state(blob: str) -> dict:
    """Base64 npz -> flat {path: array} dict (client side)."""
    with np.load(io.BytesIO(base64.b64decode(blob))) as z:
        return {k: z[k] for k in z.files}


# --------------------------------------------------------------------------
# Request plumbing
# --------------------------------------------------------------------------
_INJECT_KINDS = ("nan", "teleport")


def build_overrides(req: dict) -> dict:
    """Request fields -> ``build_case`` override kwargs. Numpy-only
    (``resolve_ds`` never touches JAX), so the multi-process frontend
    can normalize and route requests without owning a JAX runtime."""
    over = dict(req.get("overrides") or {})
    if req.get("ds") is not None:
        over["ds"] = float(req["ds"])
    elif req.get("n") is not None:
        over["ds"] = cases_lib.resolve_ds(req["case"], int(req["n"]))
    if req.get("backend") is not None:
        over["backend"] = req["backend"]
    if req.get("records") is not None:
        over["policy"] = PrecisionPolicy(records=req["records"])
    return over


def request_key(req: dict) -> str:
    """Canonical build/routing key: two requests with the same key
    build byte-identical configs, so they share a build cache entry
    (in-process) or an engine-worker process (multi-process)."""
    over = build_overrides(req)
    return json.dumps({"case": req["case"],
                       "over": {k: repr(v) for k, v in over.items()}},
                      sort_keys=True)


def worker_tag(req: dict) -> str:
    """Filesystem-safe name for the engine worker owning a request's
    shape bucket (stable across frontend restarts: resume tokens are
    located by scanning ``workers/<tag>/lanes/<token>``)."""
    digest = hashlib.sha1(request_key(req).encode()).hexdigest()[:10]
    return f"{req['case']}-{digest}"


def build_request(req: dict, cache: dict):
    """Case -> (cfg, state, default_nsteps), memoized on
    :func:`request_key`: repeated requests for the same (case,
    resolution, overrides) reuse the built arrays instead of re-running
    the generator."""
    key = request_key(req)
    if key not in cache:
        sim = Simulation.from_case(req["case"], **build_overrides(req))
        cache[key] = (sim.cfg, sim.state,
                      int(getattr(sim.case, "default_nsteps", 400)))
    return cache[key]


def validate_request(req) -> str | None:
    """Structural validation (reader thread — never touches JAX).
    Returns an error string for a malformed request, else None."""
    if not isinstance(req, dict):
        return "request frame must be a JSON object"
    op = req.get("op", "run")
    if op == "stats":
        return None
    if op != "run":
        return f"unknown op {op!r}"
    if "resume_token" in req:
        tok = req["resume_token"]
        if not isinstance(tok, str) or not tok or "/" in tok or "." in tok:
            return "resume_token must be an opaque token string"
        return None
    case = req.get("case")
    if not isinstance(case, str) or case not in cases_lib.case_names():
        return (f"unknown case {case!r}; one of "
                f"{', '.join(cases_lib.case_names())}")
    for key, typ in (("n", (int,)), ("ds", (int, float)),
                     ("nsteps", (int,)), ("deadline_s", (int, float))):
        if req.get(key) is not None and not isinstance(req[key], typ):
            return f"{key} must be {typ[0].__name__}"
    if req.get("nsteps") is not None and req["nsteps"] < 1:
        return "nsteps must be >= 1"
    if req.get("overrides") is not None and not isinstance(
            req["overrides"], dict):
        return "overrides must be an object"
    inject = req.get("inject")
    if inject is not None:
        if (not isinstance(inject, dict)
                or inject.get("kind") not in _INJECT_KINDS):
            return (f"inject wants {{'kind': one of {_INJECT_KINDS}, "
                    "'step': int?}")
        if inject.get("step") is not None and not isinstance(
                inject["step"], int):
            return "inject.step must be int"
    return None


@dataclasses.dataclass
class _Pending:
    """One validated in-flight request."""

    conn: "_Conn"
    req: dict
    received: float
    lane: int | None = None
    bucket: tuple | None = None
    nsteps: int = 0
    observe: bool = False
    return_state: bool = False
    deadline: float | None = None
    meta: dict | None = None  # resume meta (dt_scale, halvings, ...)
    # multi-process routing state (FrontendServer only)
    rid: str | None = None
    token: str | None = None
    wkey: str | None = None
    steps: int = 0
    recovering: bool = False
    recovered: bool = False

    def reply(self, obj: dict) -> bool:
        if "request_id" in self.req:
            obj = {**obj, "request_id": self.req["request_id"]}
        return self.conn.send(obj)


class _Conn:
    """Socket + write lock (reader thread and engine thread both send)."""

    def __init__(self, sock: socket.socket):
        self.sock = sock
        self._wlock = threading.Lock()

    def send(self, obj: dict) -> bool:
        with self._wlock:
            try:
                send_frame(self.sock, obj)
                return True
            except OSError:
                return False

    def close(self):
        try:
            self.sock.close()
        except OSError:
            pass


# --------------------------------------------------------------------------
# The servers
# --------------------------------------------------------------------------
class ServerBase:
    """Shared socket plumbing for the serving processes.

    Owns the listener + accept thread, per-connection reader threads
    (socket IO + structural validation ONLY), the bounded admission
    queue, and the heartbeat/drain lifecycle. Subclasses implement one
    scheduling round (``_tick``), graceful shutdown (``_drain``), and
    the monitoring hooks (``_live_steps`` / ``_extra_stats``):
    :class:`SimServer` runs the engines in-process; the multi-process
    :class:`repro.sph.supervisor.FrontendServer` routes to per-bucket
    engine-worker processes.
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        queue: int = 32,
        checkpoint_dir: str | None = None,
        heartbeat_timeout_s: float = 60.0,
    ):
        self.queue_cap = int(queue)
        self.ckdir = checkpoint_dir
        self.pending: deque[_Pending] = deque()
        self.cond = threading.Condition()
        self.draining = threading.Event()
        self.stopped = threading.Event()
        self._thread: threading.Thread | None = None
        self._running = False
        self.completed = 0
        self.rejected = 0
        self.predecessor: str | None = None
        self.hb: HeartbeatWriter | None = None
        self.watchdog = StragglerWatchdog()
        if self.ckdir:
            os.makedirs(self.ckdir, exist_ok=True)
            status = HeartbeatMonitor(
                self.ckdir, timeout_s=heartbeat_timeout_s).host_status(0)
            if status == "dead":
                self.predecessor = "dead"
                log.warning(
                    "serve: stale heartbeat in %s — the previous server "
                    "process died without draining; drained tokens (if "
                    "any) are still honored", self.ckdir)
            elif status == "absent" and self._has_resumables():
                self.predecessor = "clean"
            self.hb = HeartbeatWriter(self.ckdir, 0)
        self.lsock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self.lsock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self.lsock.bind((host, port))
        self.lsock.listen(128)
        self.host, self.port = self.lsock.getsockname()
        self._accept_thread = threading.Thread(
            target=self._accept_loop, daemon=True)
        self._accept_thread.start()

    def _has_resumables(self) -> bool:
        """Do resume tokens from a previous (clean) run exist?"""
        return os.path.isdir(os.path.join(self.ckdir, "drain"))

    # ---- socket side (reader threads) ---------------------------------
    def _accept_loop(self):
        while not self.stopped.is_set():
            try:
                sock, _ = self.lsock.accept()
            except OSError:
                return  # listener closed during drain
            threading.Thread(
                target=self._reader, args=(_Conn(sock),),
                daemon=True).start()

    def _reader(self, conn: _Conn):
        try:
            try:
                req = recv_frame(conn.sock)
            except ValueError as e:
                conn.send({"type": "error", "reason": "malformed",
                           "detail": str(e)})
                return
            if req is None:
                return
            err = validate_request(req)
            rid = req.get("request_id") if isinstance(req, dict) else None
            if err is not None:
                reply = {"type": "error", "reason": "malformed",
                         "detail": err}
                if rid is not None:
                    reply["request_id"] = rid
                conn.send(reply)
                return
            if req.get("op") == "stats":
                conn.send({"type": "stats", **self.stats()})
                return
            p = _Pending(conn=conn, req=req, received=time.monotonic())
            with self.cond:
                if self.draining.is_set():
                    p.reply({"type": "retry_after", "token": None,
                             "detail": "server is draining"})
                    return
                if len(self.pending) >= self.queue_cap:
                    self.rejected += 1
                    p.reply({"type": "rejected", "reason": "busy",
                             "queue": self.queue_cap})
                    return
                self.pending.append(p)
                self.cond.notify()
            conn = None  # ownership passed to the engine thread
        finally:
            if conn is not None:
                conn.close()

    def stats(self) -> dict:
        out = {
            "queue": len(self.pending),
            # per-live-lane step counts at the last healthy boundary
            # (reader-thread read of host state: monitoring only)
            "live_steps": self._live_steps(),
            "queue_cap": self.queue_cap,
            "completed": self.completed,
            "rejected": self.rejected,
            "draining": self.draining.is_set(),
            "predecessor": self.predecessor,
        }
        out.update(self._extra_stats())
        return out

    def _live_steps(self) -> list[int]:
        return []

    def _extra_stats(self) -> dict:
        return {}

    # ---- the loop (shared skeleton) ------------------------------------
    def request_drain(self):
        """Programmatic SIGTERM equivalent (tests, embedders)."""
        self.draining.set()
        with self.cond:
            self.cond.notify()

    def start(self):
        self._thread = threading.Thread(
            target=self.serve_forever, daemon=True)
        self._thread.start()
        return self

    def join(self, timeout: float | None = None):
        if self._thread is not None:
            self._thread.join(timeout)

    def serve_forever(self):
        self._running = True
        try:
            while not self.draining.is_set():
                try:
                    self._tick()
                except Exception:  # noqa: BLE001
                    # an engine bug must not strand every connected
                    # client on a dead socket: log, then best-effort
                    # drain (checkpoint + RETRY_AFTER where possible)
                    log.exception("serve: engine tick failed — draining")
                    self.draining.set()
            self._drain()
        finally:
            self.stopped.set()
            try:
                self.lsock.close()
            except OSError:
                pass
            self._shutdown()

    def _shutdown(self):
        """Post-drain cleanup hook (the frontend reaps its workers)."""

    def _tick(self):
        raise NotImplementedError

    def _drain(self):
        raise NotImplementedError


class SimServer(ServerBase):
    """Live-batch SPH service with every engine in-process.

    ``serve_forever()`` runs the engine loop on the CALLING thread (the
    CLI runs it on the main thread so SIGTERM/SIGINT handlers can
    trigger the drain); ``start()`` spawns it on a daemon thread for
    in-process use (tests, the latency benchmark).
    """

    def __init__(
        self,
        host: str = "127.0.0.1",
        port: int = 0,
        *,
        slots: int = 8,
        queue: int = 32,
        policy: recovery.GuardPolicy | None = None,
        checkpoint_dir: str | None = None,
        heartbeat_timeout_s: float = 60.0,
    ):
        self.policy = policy or recovery.GuardPolicy()
        self.slots = int(slots)
        self.buckets: dict[tuple, ensemble.LaneEngine] = {}
        self.live: dict[tuple, _Pending] = {}  # (bucket, lane) -> req
        self._build_cache: dict[str, tuple] = {}
        super().__init__(host, port, queue=queue,
                         checkpoint_dir=checkpoint_dir,
                         heartbeat_timeout_s=heartbeat_timeout_s)
        log.info("serve: listening on %s:%d (slots=%d queue=%d block=%d)",
                 self.host, self.port, self.slots, self.queue_cap,
                 self.policy.block)

    def _live_steps(self) -> list[int]:
        return sorted(
            int(self.buckets[k].snap_steps[lane])
            for (k, lane) in list(self.live))

    def _extra_stats(self) -> dict:
        return {"live": len(self.live), "buckets": len(self.buckets)}

    # ---- engine side (single thread owns all JAX work) -----------------
    def _build(self, req: dict):
        return build_request(req, self._build_cache)

    def _blocks_of(self, nsteps: int) -> int:
        """Targets are whole blocks: the engine advances every lane in
        lockstep block strides, so a request's step count rounds UP."""
        block = max(1, self.policy.block)
        return -(-int(nsteps) // block) * block

    def _bucket_for(self, cfg, n: int) -> tuple:
        key = (ensemble.member_config(cfg, self.policy), n)
        if key not in self.buckets:
            self.buckets[key] = ensemble.LaneEngine(
                cfg, self.slots, policy=self.policy)
            log.info("serve: new shape bucket n=%d (total %d)",
                     n, len(self.buckets))
        return key

    def _admit(self, p: _Pending) -> bool:
        """Admit one queued request. True if it left the queue (admitted
        or terminally answered); False to retry next loop (EngineFull /
        FaultBusy backpressure)."""
        try:
            if "resume_token" in p.req:
                return self._admit_resume(p)
            cfg, state, default_nsteps = self._build(p.req)
            nsteps = self._blocks_of(p.req.get("nsteps") or default_nsteps)
            fault = None
            inject = p.req.get("inject")
            if inject is not None:
                fault = recovery.apply_named_fault(
                    cfg, inject["kind"], nsteps,
                    int(state.xn.shape[0])).fault
                if inject.get("step") is not None:
                    fault = dataclasses.replace(
                        fault, step=int(inject["step"]))
            key = self._bucket_for(cfg, int(state.xn.shape[0]))
            lane = self.buckets[key].admit(
                state, nsteps, fault=fault,
                disarmable=fault is None)
        except (ensemble.EngineFull, ensemble.FaultBusy):
            return False  # backpressure: stays queued
        except ensemble.AdmissionError as e:
            p.reply({"type": "diverged", "step": 0, "checks": e.checks,
                     "stats": e.stats, "events": [],
                     "detail": "failed init-time health checks"})
            p.conn.close()
            return True
        except Exception as e:  # noqa: BLE001 - a bad build must not kill the loop
            log.exception("serve: request build failed")
            p.reply({"type": "error", "reason": "build_failed",
                     "detail": f"{type(e).__name__}: {e}"})
            p.conn.close()
            return True
        self._register(p, key, lane, nsteps)
        return True

    def _register(self, p: _Pending, key, lane: int, nsteps: int):
        p.bucket, p.lane, p.nsteps = key, lane, nsteps
        p.observe = bool(p.req.get("observe"))
        p.return_state = bool(p.req.get("return_state"))
        if p.req.get("deadline_s") is not None:
            p.deadline = p.received + float(p.req["deadline_s"])
        self.live[(key, lane)] = p
        p.reply({"type": "accepted", "lane": lane, "nsteps": nsteps,
                 "block": self.policy.block, "bucket": f"n{key[1]}"})

    # ---- drain / resume -------------------------------------------------
    def _drain_dir(self, token: str) -> str:
        return os.path.join(self.ckdir, "drain", token)

    def _admit_resume(self, p: _Pending) -> bool:
        token = p.req["resume_token"]
        if not self.ckdir:
            p.reply({"type": "error", "reason": "bad_token",
                     "detail": "server has no checkpoint directory"})
            p.conn.close()
            return True
        tdir = self._drain_dir(token)
        try:
            with open(os.path.join(tdir, "token.json")) as f:
                saved = json.load(f)
        except (OSError, json.JSONDecodeError):
            p.reply({"type": "error", "reason": "bad_token",
                     "detail": f"unknown or corrupt resume token {token!r}"})
            p.conn.close()
            return True
        req, meta = saved["request"], saved["meta"]
        cfg, state, _ = self._build(req)
        key = self._bucket_for(cfg, int(state.xn.shape[0]))
        engine = self.buckets[key]
        template = {"carry": ensemble.solver.init_persistent(
            engine.cfg, state)}
        mgr = ckpt.CheckpointManager(tdir, keep=0)
        try:
            tree, step = mgr.restore(template)
        finally:
            mgr.close()
        if tree is None:
            p.reply({"type": "error", "reason": "bad_token",
                     "detail": f"resume token {token!r} has no valid "
                     "checkpoint"})
            p.conn.close()
            return True
        try:
            lane = engine.admit(
                None, meta["target"], carry_row=tree["carry"],
                steps_done=meta["steps_done"],
                dt_scale=meta["dt_scale"], halvings=meta["halvings"],
                disarmable=meta.get("disarmable", True))
        except (ensemble.EngineFull, ensemble.FaultBusy):
            return False
        # merge the original run flags (observe/return_state/deadline
        # restart from the resubmission)
        p.req = {**req, **p.req}
        self._register(p, key, lane, meta["target"])
        shutil.rmtree(tdir, ignore_errors=True)
        return True

    def _drain(self):
        """Checkpoint every live lane, hand out resume tokens, flush
        the queue with token-less RETRY_AFTER, stop listening."""
        log.warning("serve: draining (%d live, %d queued)",
                    len(self.live), len(self.pending))
        for (key, lane), p in sorted(self.live.items()):
            token = None
            if self.ckdir:
                token = secrets.token_hex(8)
                row, meta = self.buckets[key].lane_snapshot(lane)
                tdir = self._drain_dir(token)
                mgr = ckpt.CheckpointManager(tdir, keep=1)
                try:
                    mgr.save(meta["steps_done"], {"carry": row})
                finally:
                    mgr.close()
                clean_req = {k: v for k, v in p.req.items()
                             if k != "resume_token"}
                with open(os.path.join(tdir, "token.json"), "w") as f:
                    json.dump({"request": clean_req, "meta": meta}, f)
            p.reply({"type": "retry_after", "token": token,
                     "steps_done": int(self.buckets[key].snap_steps[lane]),
                     "nsteps": p.nsteps})
            p.conn.close()
            self.buckets[key].retire(lane)
        self.live.clear()
        with self.cond:
            queued, self.pending = list(self.pending), deque()
        for p in queued:
            p.reply({"type": "retry_after", "token": None,
                     "detail": "server is draining; resubmit"})
            p.conn.close()
        if self.hb is not None:
            self.hb.clear()  # clean shutdown: no stale-heartbeat ghost

    def prewarm(self, case: str, **req):
        """Build a case and run one throwaway lane to completion so the
        block program is compiled before the first real request.

        Must run BEFORE the engine loop starts (call it between
        construction and ``start()``/``serve_forever()``): the engine
        thread owns the donated batch carry once it is running, and a
        second thread stepping it trips XLA's donated-buffer check."""
        if self._running:
            raise RuntimeError("prewarm() after the engine loop started "
                               "would race the engine thread")
        cfg, state, _ = self._build({"case": case, **req})
        key = self._bucket_for(cfg, int(state.xn.shape[0]))
        engine = self.buckets[key]
        lane = engine.admit(state, max(1, self.policy.block))
        for _ in range(64):
            if any(e.lane == lane and e.kind in ("done", "diverged")
                   for e in engine.step_block()):
                break
        log.info("serve: prewarmed %s (n=%d)", case, key[1])

    def _tick(self):
        # 1) admit from the queue (FIFO per bucket; a full bucket does
        #    not head-of-line-block a different bucket's requests)
        with self.cond:
            queued = list(self.pending)
        for p in queued:
            if self._admit(p):
                with self.cond:
                    try:
                        self.pending.remove(p)
                    except ValueError:
                        pass
        # 2) one block per bucket with live lanes
        worked = False
        for key, engine in list(self.buckets.items()):
            if not engine.live_lanes:
                continue
            worked = True
            t0 = time.perf_counter()
            events = engine.step_block()
            slow = self.watchdog.observe(time.perf_counter() - t0)
            if slow:
                log.warning("serve: straggler block on bucket n=%d "
                            "(flagged=%s)", key[1], self.watchdog.flagged)
            for ev in events:
                self._dispatch(key, ev)
        if self.hb is not None:
            self.hb.beat(self.completed)
        # 3) deadlines
        now = time.monotonic()
        for (key, lane), p in list(self.live.items()):
            if p.deadline is not None and now > p.deadline:
                p.reply({"type": "timeout",
                         "deadline_s": p.req["deadline_s"],
                         "steps_done": int(
                             self.buckets[key].snap_steps[lane])})
                p.conn.close()
                self.buckets[key].retire(lane)
                del self.live[(key, lane)]
        if not worked:
            with self.cond:
                if not self.pending and not self.draining.is_set():
                    self.cond.wait(timeout=0.05)

    def _dispatch(self, key, ev: ensemble.LaneEvent):
        p = self.live.get((key, ev.lane))
        if p is None:
            return  # prewarm lane, or client already cancelled
        if ev.kind == "obs":
            if p.observe and not p.reply(
                    {"type": "obs", "step": ev.step, **ev.obs}):
                # client hung up mid-stream: free the lane
                self.buckets[key].retire(ev.lane)
                del self.live[(key, ev.lane)]
            return
        if ev.kind == "recovered":
            p.reply({"type": "event", "action": ev.action,
                     "step": ev.step,
                     "checks": list(health.check_names(ev.word))})
            return
        if ev.kind == "done":
            reply = {"type": "done", "steps": ev.step, "obs": ev.obs,
                     "events": [e.to_json() for e in ev.events or []]}
            if p.return_state:
                reply["state_npz"] = encode_state(ev.state)
            p.reply(reply)
            self.completed += 1
        elif ev.kind == "diverged":
            p.reply({"type": "diverged", "step": ev.step,
                     "checks": list(ev.checks), "stats": ev.stats,
                     "detail": ev.detail,
                     "events": [e.to_json() for e in ev.events or []]})
        p.conn.close()
        del self.live[(key, ev.lane)]
