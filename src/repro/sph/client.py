"""Client helper for the ``repro.sph serve`` endpoint.

One request per connection: :func:`request` opens a socket, sends the
request frame, and yields reply frames until a TERMINAL frame arrives
(done / diverged / timeout / retry_after / rejected / error);
:func:`run_request` collects them and returns ``(frames, terminal)``.
The CLI's ``python -m repro.sph request`` subcommand and the latency
benchmark both sit on these.

:func:`run_request_resilient` survives worker crashes end-to-end: a
``RETRY_AFTER`` carrying a resume token is resubmitted as a token
request after capped exponential backoff (the server resumes the lane
from its last block checkpoint, bit-identical), and a mid-stream EOF
(the server died before its supervisor could recover) reconnects and
re-requests the same way.
"""
from __future__ import annotations

import logging
import socket
import time

from repro.sph.serve import decode_state, recv_frame, send_frame

log = logging.getLogger("repro.client")

TERMINAL = frozenset({"done", "diverged", "timeout", "retry_after",
                      "rejected", "error", "stats"})


def request(host: str, port: int, req: dict, *, timeout: float = 300.0):
    """Generator of reply frames for one request; stops after the
    terminal frame (or on EOF — a server killed without drain)."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        send_frame(sock, req)
        while True:
            frame = recv_frame(sock)
            if frame is None:
                return
            yield frame
            if frame.get("type") in TERMINAL:
                return


def run_request(host: str, port: int, req: dict, *,
                timeout: float = 300.0) -> tuple[list, dict | None]:
    """All frames + the terminal frame (None if the connection died
    before one arrived)."""
    frames = list(request(host, port, req, timeout=timeout))
    last = frames[-1] if frames else None
    return frames, (last if last and last.get("type") in TERMINAL else None)


def run_request_resilient(
    host: str, port: int, req: dict, *,
    retries: int = 3, backoff_s: float = 0.5, backoff_cap_s: float = 8.0,
    timeout: float = 300.0,
) -> tuple[list, dict | None]:
    """:func:`run_request` with crash auto-recovery.

    Up to ``retries`` reconnect attempts (capped exponential backoff)
    are spent on the recoverable outcomes:

      * ``RETRY_AFTER`` with a resume token — resubmit the token (the
        server resumes the drained/shed lane from its checkpoint);
      * ``RETRY_AFTER`` without a token (queued work was flushed, or
        the server is draining) — resubmit the original request;
      * mid-stream EOF or a refused connection (server/worker died) —
        reconnect and re-request.

    Every other terminal (done/diverged/timeout/rejected/error) returns
    immediately. Returns the ACCUMULATED frames across attempts plus
    the final terminal frame (None only when the retry budget is
    exhausted without one).
    """
    all_frames: list = []
    cur = dict(req)
    attempt = 0
    while True:
        try:
            frames, term = run_request(host, port, cur, timeout=timeout)
            all_frames.extend(frames)
        except OSError as e:
            # refused/reset during server restart: retry like an EOF
            term = None
            log.warning("client: connection failed (%s)", e)
        if term is not None and term.get("type") != "retry_after":
            return all_frames, term
        if attempt >= retries:
            return all_frames, term
        token = term.get("token") if term is not None else None
        if token:
            cur = {"resume_token": token,
                   **{k: v for k, v in req.items()
                      if k in ("observe", "return_state", "deadline_s",
                               "request_id")}}
        elif "resume_token" not in cur:
            cur = dict(req)
        delay = min(backoff_cap_s, backoff_s * 2 ** attempt)
        attempt += 1
        log.warning(
            "client: %s — retry %d/%d in %.1fs%s",
            "server closed mid-stream" if term is None
            else "got RETRY_AFTER", attempt, retries, delay,
            f" (resume token {token})" if token else "")
        time.sleep(delay)


def final_state(done_frame: dict) -> dict:
    """Flat {path: array} dict of a DONE frame's ``state_npz`` payload
    (requested via ``return_state``) — bit-exact against the flattened
    solo-run state."""
    return decode_state(done_frame["state_npz"])
