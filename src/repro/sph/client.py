"""Client helper for the ``repro.sph serve`` endpoint.

One request per connection: :func:`request` opens a socket, sends the
request frame, and yields reply frames until a TERMINAL frame arrives
(done / diverged / timeout / retry_after / rejected / error);
:func:`run_request` collects them and returns ``(frames, terminal)``.
The CLI's ``python -m repro.sph request`` subcommand and the latency
benchmark both sit on these.
"""
from __future__ import annotations

import socket

from repro.sph.serve import decode_state, recv_frame, send_frame

TERMINAL = frozenset({"done", "diverged", "timeout", "retry_after",
                      "rejected", "error", "stats"})


def request(host: str, port: int, req: dict, *, timeout: float = 300.0):
    """Generator of reply frames for one request; stops after the
    terminal frame (or on EOF — a server killed without drain)."""
    with socket.create_connection((host, port), timeout=timeout) as sock:
        send_frame(sock, req)
        while True:
            frame = recv_frame(sock)
            if frame is None:
                return
            yield frame
            if frame.get("type") in TERMINAL:
                return


def run_request(host: str, port: int, req: dict, *,
                timeout: float = 300.0) -> tuple[list, dict | None]:
    """All frames + the terminal frame (None if the connection died
    before one arrived)."""
    frames = list(request(host, port, req, timeout=timeout))
    last = frames[-1] if frames else None
    return frames, (last if last and last.get("type") in TERMINAL else None)


def final_state(done_frame: dict) -> dict:
    """Flat {path: array} dict of a DONE frame's ``state_npz`` payload
    (requested via ``return_state``) — bit-exact against the flattened
    solo-run state."""
    return decode_state(done_frame["state_npz"])
