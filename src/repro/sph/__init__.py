"""``repro.sph`` — the user-facing SPH scenario entry point.

Re-exports the scenario API (cases registry, Simulation facade, physics
schemes, boundary builders) and hosts the CLI:

    python -m repro.sph list
    python -m repro.sph run taylor_green --nsteps 600 --observe-every 20
    python -m repro.sph run dam_break --n 2000 --backend xla
    python -m repro.sph run dam_break --json            # machine-readable
    python -m repro.sph sweep poiseuille --batch 8 --checkpoint ckpt/
    python -m repro.sph serve dam_break --checkpoint ck/   # online service
    python -m repro.sph request dam_break --observe

See ``repro/sph/__main__.py`` for the command surface. The serving
layer (``SimServer``, ``LaneEngine``, the frame protocol) lives in
``repro/sph/serve.py`` + ``repro/sph/client.py``.
"""
from repro.core.api import Observables, SimResult, Simulation  # noqa: F401
from repro.core.boundaries import (  # noqa: F401
    FLUID,
    WALL,
    box_wall_particles,
    fluid_lattice,
)
from repro.core.cases import (  # noqa: F401
    CASES,
    CaseSpec,
    build_case,
    case_names,
    register_case,
    resolve_ds,
)
from repro.core.ensemble import (  # noqa: F401
    AdmissionError,
    EngineFull,
    EnsembleReport,
    FaultBusy,
    LaneEngine,
    LaneEvent,
    MemberReport,
    SweepRequest,
    SweepResult,
    member_config,
    run_ensemble,
    run_sweep,
)
from repro.core.health import FaultSpec, SimulationDiverged  # noqa: F401
from repro.core.recovery import (  # noqa: F401
    GuardPolicy,
    GuardReport,
    run_guarded,
)
from repro.core.scheme import Scheme, wcsph  # noqa: F401
