"""Engine-worker process: one shape bucket's :class:`LaneEngine` behind
a local IPC channel.

``python -m repro.sph.worker`` is spawned by the multi-process frontend
(:mod:`repro.sph.supervisor`), connects BACK to the frontend's IPC
listener, authenticates with a one-shot secret, and then serves admit /
retire / drain / chaos commands over the same length-prefixed frame
protocol clients speak. The worker owns its own JAX runtime, its own
checkpoint directory (``<root>/workers/<tag>/``) with the PR 7 ``.lock``
exclusivity file, and its own :class:`HeartbeatWriter` — so a native
crash (XLA segfault, OOM kill, runaway compile) takes down ONE shape
bucket while the frontend and every sibling bucket keep streaming.

Crash containment contract:
  * every live lane is checkpointed at every healthy block boundary
    (``LaneEngine.take_dirty`` + :class:`CheckpointManager` under
    ``lanes/<token>/``), so a SIGKILL loses at most ``save_every``
    blocks of progress;
  * checkpoints are written BEFORE the block's frames are streamed — a
    kill between save and send re-delivers a block after restart
    (client-visible duplicate/gap in OBS), but acknowledged progress is
    never lost and the final state is bit-identical either way;
  * an admit for a token whose lane directory already holds a committed
    checkpoint RESUMES it (splice + replay, the PR 8 drain path) —
    fresh admission, supervisor re-admission after a crash, and client
    ``resume_token`` resubmission are the same code path;
  * the heartbeat is written from a dedicated thread, so a wedged main
    loop (chaos ``hang``, a stuck native call) still beats — that is
    exactly the "heartbeat alive but no block progress" state the
    supervisor's hang watchdog SIGKILLs;
  * after a crash restart, dead-pid locks are reclaimed QUIETLY
    (``quiet_reclaim``) and reported as one summary line, not one
    warning per resumed lane.

Worker frames (worker -> frontend), all rid-tagged where relevant:
  hello {wid, secret, pid}      authentication, sent once on connect
  accepted {rid, lane, nsteps, steps_done, resumed}
  busy {rid}                    EngineFull/FaultBusy: frontend requeues
  obs / event / done / diverged / error   relayed to the client
  progress {blocks, steps}      per engine tick: the hang-watchdog food
  drained {steps}               final checkpoints committed; exiting
  prewarmed {}                  compile finished (serve CLI startup)
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import logging
import os
import shutil
import socket
import sys
import threading
import time
from collections import deque

import numpy as np

from repro.checkpoint import manager as ckpt
from repro.core import ensemble, health, recovery
from repro.runtime.fault_tolerance import HeartbeatWriter
from repro.sph import serve

log = logging.getLogger("repro.worker")


def _meta_tree(meta: dict) -> dict:
    """Lane ladder meta -> numpy scalars stored INSIDE the checkpoint
    tree: atomic with the carry row (no token.json/save race)."""
    return {
        "steps_done": np.array(meta["steps_done"], np.int64),
        "target": np.array(meta["target"], np.int64),
        "dt_scale": np.array(meta["dt_scale"], np.float32),
        "halvings": np.array(meta["halvings"], np.int32),
        "armed": np.array(meta["armed"], bool),
        "disarmable": np.array(meta["disarmable"], bool),
    }


def _meta_template() -> dict:
    return {
        "steps_done": np.zeros((), np.int64),
        "target": np.zeros((), np.int64),
        "dt_scale": np.zeros((), np.float32),
        "halvings": np.zeros((), np.int32),
        "armed": np.zeros((), bool),
        "disarmable": np.zeros((), bool),
    }


class EngineWorker:
    """The worker's engine loop: single thread owns every JAX call."""

    def __init__(self, chan: serve._Conn, wdir: str, *, slots: int,
                 policy: recovery.GuardPolicy, save_every: int = 1,
                 hb_interval_s: float = 0.5):
        self.chan = chan
        self.wdir = wdir
        self.slots = int(slots)
        self.policy = policy
        self.save_every = max(1, int(save_every))
        self.cmds: deque[dict] = deque()
        self.wake = threading.Event()
        self.eof = threading.Event()
        self.stop = False
        self.hang = False
        self.oom_at_next_block = False
        self.build_cache: dict[str, tuple] = {}
        self.engines: dict[tuple, ensemble.LaneEngine] = {}
        self.live: dict[str, dict] = {}       # rid -> record
        self.lane_rid: dict[tuple, str] = {}  # (key, lane) -> rid
        self.blocks = 0
        os.makedirs(wdir, exist_ok=True)
        # the worker-dir lock: one engine process per bucket directory
        self.dirlock = ckpt.CheckpointManager(wdir, keep=0,
                                              quiet_reclaim=True)
        self.reclaimed = ([self.dirlock.reclaimed_from]
                          if self.dirlock.reclaimed_from is not None
                          else [])
        self.hb = HeartbeatWriter(wdir, 0)
        self._hb_interval = float(hb_interval_s)
        threading.Thread(target=self._read_loop, daemon=True).start()
        threading.Thread(target=self._beat_loop, daemon=True).start()

    # ---- background threads -------------------------------------------
    def _read_loop(self):
        try:
            while True:
                f = serve.recv_frame(self.chan.sock)
                if f is None:
                    break
                self.cmds.append(f)
                self.wake.set()
        except (ValueError, OSError):
            pass
        self.eof.set()
        self.wake.set()

    def _beat_loop(self):
        # Beats from its own thread so a wedged engine loop still looks
        # "alive" to HeartbeatMonitor — by design: process-death is the
        # heartbeat's job, hangs are the progress watchdog's.
        while not self.stop:
            self.hb.beat(self.blocks)
            time.sleep(self._hb_interval)

    # ---- the loop ------------------------------------------------------
    def run(self) -> int:
        try:
            while not self.stop:
                if self.hang:
                    time.sleep(0.2)  # chaos: wedged, heartbeat beating
                    continue
                if self.eof.is_set() and not self.cmds:
                    # frontend vanished: commit final checkpoints and
                    # exit — lanes are resumable by the next frontend
                    log.warning("worker: IPC channel closed; exiting "
                                "with %d live lane(s) checkpointed",
                                len(self.live))
                    self._final_save()
                    break
                self._handle_cmds()
                if self.stop or self.hang:
                    continue
                worked = self._step_engines()
                if not worked and not self.cmds:
                    self.wake.wait(0.05)
                    self.wake.clear()
        finally:
            self.stop = True
            self.hb.clear()
            for rec in self.live.values():
                if rec.get("mgr") is not None:
                    try:
                        rec["mgr"].close()
                    except Exception:  # noqa: BLE001 - exit path stays best-effort
                        log.exception("worker: lane manager close failed")
            self.dirlock.close()
        return 0

    def _handle_cmds(self):
        while self.cmds and not self.hang:
            c = self.cmds.popleft()
            kind = c.get("type")
            if kind == "admit":
                self._admit(c)
            elif kind == "retire":
                self._retire(c.get("rid"), remove_dir=bool(
                    c.get("discard", True)))
            elif kind == "drain":
                self._drain()
            elif kind == "chaos":
                self._chaos(c.get("mode"))
            elif kind == "prewarm":
                self._prewarm(c)
            elif kind == "ping":
                self.chan.send({"type": "pong"})
            else:
                log.warning("worker: unknown command %r", kind)

    # ---- chaos ---------------------------------------------------------
    def _chaos(self, mode: str):
        log.warning("worker: chaos %r armed", mode)
        if mode == "hang":
            # main loop wedges forever; the heartbeat thread keeps
            # beating -> only the supervisor's hang watchdog frees us
            self.hang = True
        elif mode == "oom-sim":
            # abrupt death right after the next stepped block, no
            # cleanup — the OOM-killer shape (see _step_engines)
            self.oom_at_next_block = True

    # ---- admission -----------------------------------------------------
    def _blocks_of(self, nsteps: int) -> int:
        block = max(1, self.policy.block)
        return -(-int(nsteps) // block) * block

    def _lane_dir(self, token: str) -> str:
        return os.path.join(self.wdir, "lanes", token)

    def _engine_for(self, cfg, n: int) -> tuple:
        key = (ensemble.member_config(cfg, self.policy), n)
        if key not in self.engines:
            self.engines[key] = ensemble.LaneEngine(
                cfg, self.slots, policy=self.policy)
        return key

    def _admit(self, c: dict):
        rid, token, req = c["rid"], c["token"], c["req"]
        mgr = None
        try:
            cfg, state, default_nsteps = serve.build_request(
                req, self.build_cache)
            n = int(state.xn.shape[0])
            key = self._engine_for(cfg, n)
            engine = self.engines[key]
            nsteps = self._blocks_of(req.get("nsteps") or default_nsteps)
            fault = None
            inject = req.get("inject")
            if inject is not None:
                fault = recovery.apply_named_fault(
                    cfg, inject["kind"], nsteps, n).fault
                if inject.get("step") is not None:
                    fault = dataclasses.replace(
                        fault, step=int(inject["step"]))
            lane_dir = self._lane_dir(token)
            mgr = ckpt.CheckpointManager(lane_dir, keep=2,
                                         quiet_reclaim=True)
            if mgr.reclaimed_from is not None:
                self.reclaimed.append(mgr.reclaimed_from)
            template = {
                "carry": ensemble.solver.init_persistent(
                    engine.cfg, state),
                "meta": _meta_template(),
            }
            tree, _ = mgr.restore(template)
            if tree is not None:
                meta = {k: v.item() for k, v in tree["meta"].items()}
                steps_done, target = int(meta["steps_done"]), int(
                    meta["target"])
                if steps_done >= target:
                    # crashed between the final save and the DONE
                    # frame: finalize straight from the checkpoint
                    self._finalize_from_checkpoint(
                        rid, req, engine, tree, steps_done, mgr,
                        lane_dir)
                    return
                lane = engine.admit(
                    None, target,
                    fault=fault if meta["armed"] else None,
                    disarmable=bool(meta["disarmable"]),
                    dt_scale=float(meta["dt_scale"]),
                    halvings=int(meta["halvings"]),
                    carry_row=tree["carry"], steps_done=steps_done)
                nsteps, resumed = target, True
            else:
                steps_done, resumed = 0, False
                lane = engine.admit(state, nsteps, fault=fault,
                                    disarmable=fault is None)
                clean_req = {k: v for k, v in req.items()
                             if k != "resume_token"}
                tmp = os.path.join(lane_dir, "token.json.tmp")
                with open(tmp, "w") as f:
                    json.dump({"request": clean_req}, f)
                os.replace(tmp, os.path.join(lane_dir, "token.json"))
        except (ensemble.EngineFull, ensemble.FaultBusy):
            if mgr is not None:
                mgr.close()
            self.chan.send({"type": "busy", "rid": rid})
            return
        except ensemble.AdmissionError as e:
            if mgr is not None:
                mgr.close()
            self.chan.send({"type": "diverged", "rid": rid, "step": 0,
                            "checks": e.checks, "stats": e.stats,
                            "events": [],
                            "detail": "failed init-time health checks"})
            return
        except Exception as e:  # noqa: BLE001 - a bad build must not kill the loop
            log.exception("worker: admit failed")
            if mgr is not None:
                mgr.close()
            self.chan.send({"type": "error", "rid": rid,
                            "reason": "build_failed",
                            "detail": f"{type(e).__name__}: {e}"})
            return
        self.live[rid] = {"key": key, "lane": lane, "token": token,
                          "mgr": mgr, "req": req, "target": nsteps}
        self.lane_rid[(key, lane)] = rid
        if self.reclaimed:
            pids, self.reclaimed = sorted(set(self.reclaimed)), []
            log.info("worker: reclaimed checkpoint lock(s) from dead "
                     "process(es) %s", pids)
        self.chan.send({"type": "accepted", "rid": rid, "lane": lane,
                        "nsteps": nsteps, "steps_done": steps_done,
                        "resumed": resumed})

    def _finalize_from_checkpoint(self, rid, req, engine, tree,
                                  steps_done, mgr, lane_dir):
        st = ensemble.solver.finalize_persistent(
            engine.cfg, recovery._to_device(tree["carry"]))
        obs = dict(zip(
            ("t", "ekin", "vmax", "rho_err"),
            (float(np.asarray(v))
             for v in health.observe_state(engine.cfg, st))))
        reply = {"type": "done", "rid": rid, "steps": steps_done,
                 "obs": obs, "events": []}
        if req.get("return_state"):
            reply["state_npz"] = serve.encode_state(st)
        self.chan.send(reply)
        mgr.close()
        shutil.rmtree(lane_dir, ignore_errors=True)

    def _retire(self, rid: str | None, remove_dir: bool = True):
        rec = self.live.get(rid)
        if rec is None:
            return
        self.engines[rec["key"]].retire(rec["lane"])
        self._cleanup(rid, remove_dir=remove_dir)

    def _cleanup(self, rid: str, remove_dir: bool):
        rec = self.live.pop(rid)
        self.lane_rid.pop((rec["key"], rec["lane"]), None)
        if rec["mgr"] is not None:
            rec["mgr"].close()
        if remove_dir:
            shutil.rmtree(self._lane_dir(rec["token"]),
                          ignore_errors=True)

    # ---- stepping ------------------------------------------------------
    def _step_engines(self) -> bool:
        worked = False
        for key, engine in list(self.engines.items()):
            if not engine.live_lanes:
                continue
            worked = True
            events = engine.step_block()
            self.blocks += 1
            # checkpoint BEFORE streaming: never lose acked progress
            self._save_dirty(key, engine)
            if self.oom_at_next_block:
                os._exit(137)
            for ev in events:
                self._dispatch(key, engine, ev)
        if worked:
            self.chan.send({
                "type": "progress", "blocks": self.blocks,
                "steps": {
                    rid: int(self.engines[r["key"]].snap_steps[r["lane"]])
                    for rid, r in self.live.items()},
            })
        return worked

    def _save_dirty(self, key, engine, force: bool = False):
        if not force and engine.blocks % self.save_every:
            return  # dirt accumulates; drained at the next save block
        for lane in engine.take_dirty():
            rid = self.lane_rid.get((key, lane))
            rec = self.live.get(rid) if rid is not None else None
            if rec is None or rec["mgr"] is None:
                continue  # prewarm lane: nothing to persist
            row, meta = engine.lane_snapshot(lane)
            rec["mgr"].save(int(meta["steps_done"]),
                            {"carry": row, "meta": _meta_tree(meta)},
                            blocking=False)

    def _dispatch(self, key, engine, ev: ensemble.LaneEvent):
        rid = self.lane_rid.get((key, ev.lane))
        if rid is None:
            return  # prewarm lane
        rec = self.live[rid]
        if ev.kind == "obs":
            self.chan.send({"type": "obs", "rid": rid, "step": ev.step,
                            **ev.obs})
        elif ev.kind == "recovered":
            self.chan.send({
                "type": "event", "rid": rid, "action": ev.action,
                "step": ev.step,
                "checks": list(health.check_names(ev.word))})
        elif ev.kind == "done":
            reply = {"type": "done", "rid": rid, "steps": ev.step,
                     "obs": ev.obs,
                     "events": [e.to_json() for e in ev.events or []]}
            if rec["req"].get("return_state"):
                reply["state_npz"] = serve.encode_state(ev.state)
            self.chan.send(reply)
            self._cleanup(rid, remove_dir=True)
        elif ev.kind == "diverged":
            self.chan.send({
                "type": "diverged", "rid": rid, "step": ev.step,
                "checks": list(ev.checks), "stats": ev.stats,
                "detail": ev.detail,
                "events": [e.to_json() for e in ev.events or []]})
            self._cleanup(rid, remove_dir=True)

    # ---- drain / prewarm ----------------------------------------------
    def _final_save(self):
        for rid, rec in list(self.live.items()):
            engine = self.engines[rec["key"]]
            row, meta = engine.lane_snapshot(rec["lane"])
            try:
                rec["mgr"].save(int(meta["steps_done"]),
                                {"carry": row, "meta": _meta_tree(meta)},
                                blocking=True)
            except Exception:  # noqa: BLE001 - drain the rest regardless
                log.exception("worker: final save failed for %s", rid)

    def _drain(self):
        self._final_save()
        self.chan.send({"type": "drained", "steps": {
            rid: int(self.engines[r["key"]].snap_steps[r["lane"]])
            for rid, r in self.live.items()}})
        self.stop = True

    def _prewarm(self, c: dict):
        req = dict(c.get("req") or {})
        try:
            cfg, state, _ = serve.build_request(req, self.build_cache)
            key = self._engine_for(cfg, int(state.xn.shape[0]))
            engine = self.engines[key]
            lane = engine.admit(state, max(1, self.policy.block))
            for _ in range(64):
                if any(e.lane == lane and e.kind in ("done", "diverged")
                       for e in engine.step_block()):
                    break
            log.info("worker: prewarmed %s (n=%d)", req.get("case"),
                     key[1])
            self.chan.send({"type": "prewarmed"})
        except Exception as e:  # noqa: BLE001 - report, don't die
            log.exception("worker: prewarm failed")
            self.chan.send({"type": "error", "reason": "build_failed",
                            "detail": f"{type(e).__name__}: {e}"})


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.sph.worker")
    ap.add_argument("--connect", type=int, required=True,
                    help="frontend IPC port on 127.0.0.1")
    ap.add_argument("--secret", required=True)
    ap.add_argument("--wid", type=int, required=True)
    ap.add_argument("--dir", required=True, help="worker state dir")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--block", type=int, default=32)
    ap.add_argument("--save-every", type=int, default=1)
    args = ap.parse_args(argv)
    logging.basicConfig(
        level=logging.INFO,
        format=f"%(asctime)s w{args.wid} %(name)s %(levelname)s "
               "%(message)s")
    sock = None
    for attempt in range(10):
        try:
            sock = socket.create_connection(
                ("127.0.0.1", args.connect), timeout=10)
            break
        except OSError:
            time.sleep(0.1 * (attempt + 1))
    if sock is None:
        log.error("worker: cannot reach frontend on :%d", args.connect)
        return 1
    sock.settimeout(None)  # connect timeout must not poison blocking reads
    chan = serve._Conn(sock)
    chan.send({"type": "hello", "wid": args.wid, "secret": args.secret,
               "pid": os.getpid()})
    policy = recovery.GuardPolicy(block=args.block, snapshot_every=1)
    w = EngineWorker(chan, args.dir, slots=args.slots, policy=policy,
                     save_every=args.save_every)
    return w.run()


if __name__ == "__main__":
    sys.exit(main())
