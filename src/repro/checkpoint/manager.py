"""Sharded, atomic, async checkpointing with restore-and-reshard.

Layout: <dir>/step_<N>/
  manifest.json          - pytree structure, shapes, dtypes, step, mesh
  arrays.npz             - flat {path: array} (host-gathered)
  .COMPLETE              - commit marker (written last, after fsync)

Atomicity: writes go to step_<N>.tmp/ then os.replace() to step_<N>
and the .COMPLETE marker is written inside. Readers ignore directories
without the marker, so a killed writer never corrupts restore.

Async: save() can hand off to a background thread (the train loop keeps
stepping); wait() joins before the next save or on exit.

Elastic restore: restore() returns host numpy; ``reshard()`` device_puts
onto any mesh/sharding - a different device count than the writer's is
fine, which is the restart-after-resize path.
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import numpy as np

import jax

SEP = "/"


def _flatten(tree, prefix=""):
    out = {}
    if tree is None:
        # Empty subtree (e.g. a PersistentCarry's unused optional
        # fields): nothing to persist — restore rebuilds it from the
        # template's matching None.
        return out
    if isinstance(tree, dict):
        it = tree.items()
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        it = ((str(i), v) for i, v in enumerate(tree))
    elif hasattr(tree, "_fields"):  # NamedTuple
        it = zip(tree._fields, tree)
    else:
        return {prefix or "leaf": tree}
    for k, v in it:
        p = f"{prefix}{SEP}{k}" if prefix else str(k)
        out.update(_flatten(v, p))
    return out


def _unflatten_into(template, flat, prefix=""):
    """Rebuild a pytree shaped like `template` from the flat dict."""
    if template is None:
        return None
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{SEP}{k}" if prefix else k)
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        vals = [
            _unflatten_into(v, flat,
                            f"{prefix}{SEP}{f}" if prefix else f)
            for f, v in zip(template._fields, template)
        ]
        return type(template)(*vals)
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat,
                            f"{prefix}{SEP}{i}" if prefix else str(i))
            for i, v in enumerate(template))
    return flat[prefix or "leaf"]


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ---- write ------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True):
        """Host-gather and persist `tree` at `step`."""
        self.wait()
        host = {k: np.asarray(v) for k, v in _flatten(tree).items()}

        def work():
            try:
                self._write(step, host)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                       for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, ".COMPLETE"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"),
                ignore_errors=True)

    # ---- read -------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, ".COMPLETE"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, template, step: int | None = None):
        """Load into host numpy, shaped like `template`. Returns
        (tree, step) or (None, None) when no checkpoint exists."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step:08d}")
        with np.load(os.path.join(path, "arrays.npz")) as z:
            flat = {k: z[k] for k in z.files}
        return _unflatten_into(template, flat), step


def reshard(tree_host, shardings):
    """device_put a host tree onto (possibly different) shardings -
    the elastic-restart path: works across device-count changes."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree_host, shardings)
