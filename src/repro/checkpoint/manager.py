"""Sharded, atomic, async checkpointing with restore-and-reshard.

Layout: <dir>/step_<N>/
  manifest.json          - pytree structure, shapes, dtypes, crc32, step
  arrays.npz             - flat {path: array} (host-gathered)
  .COMPLETE              - commit marker (written last, after fsync)

Atomicity: writes go to step_<N>.tmp/ then os.replace() to step_<N>
and the .COMPLETE marker is written inside. Readers ignore directories
without the marker, so a killed writer never corrupts restore.

Integrity: the manifest records a CRC32 per array; restore() verifies
every array against it and — when picking the step itself — falls back
to the previous .COMPLETE step with a loud warning on any mismatch or
unreadable payload (torn storage AFTER commit: a .COMPLETE marker only
proves the writer finished, not that the bytes survived).

Async: save() can hand off to a background thread (the train loop keeps
stepping); wait() joins before the next save or on exit. A process is
joined at interpreter exit too (atexit), so an async save that failed
after the last explicit wait() is reported instead of silently dropped.

Elastic restore: restore() returns host numpy; ``reshard()`` device_puts
onto any mesh/sharding - a different device count than the writer's is
fine, which is the restart-after-resize path.
"""
from __future__ import annotations

import atexit
import json
import logging
import os
import shutil
import sys
import threading
import time
import weakref
import zlib

import numpy as np

import jax

log = logging.getLogger("repro.checkpoint")

SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """An explicitly requested checkpoint step failed CRC verification."""


class CheckpointLockError(RuntimeError):
    """The checkpoint directory is locked by another LIVE process.

    Two writers interleaving saves into one directory silently corrupt
    each other's GC and step ordering, so opening is exclusive. The
    error carries the owner pid so callers (and their users) can see
    who holds it."""

    def __init__(self, directory: str, owner_pid: int):
        super().__init__(
            f"checkpoint directory {directory!r} is locked by live "
            f"process {owner_pid} — two writers would interleave saves; "
            "pick a different directory or stop the other process")
        self.directory = directory
        self.owner_pid = owner_pid


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:
        return True  # exists, owned by someone else
    return True


def _flatten(tree, prefix=""):
    out = {}
    if tree is None:
        # Empty subtree (e.g. a PersistentCarry's unused optional
        # fields): nothing to persist — restore rebuilds it from the
        # template's matching None.
        return out
    if isinstance(tree, dict):
        it = tree.items()
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        it = ((str(i), v) for i, v in enumerate(tree))
    elif hasattr(tree, "_fields"):  # NamedTuple
        it = zip(tree._fields, tree)
    else:
        return {prefix or "leaf": tree}
    for k, v in it:
        p = f"{prefix}{SEP}{k}" if prefix else str(k)
        out.update(_flatten(v, p))
    return out


def _unflatten_into(template, flat, prefix=""):
    """Rebuild a pytree shaped like `template` from the flat dict."""
    if template is None:
        return None
    if isinstance(template, dict):
        return {
            k: _unflatten_into(v, flat, f"{prefix}{SEP}{k}" if prefix else k)
            for k, v in template.items()
        }
    if hasattr(template, "_fields"):
        vals = [
            _unflatten_into(v, flat,
                            f"{prefix}{SEP}{f}" if prefix else f)
            for f, v in zip(template._fields, template)
        ]
        return type(template)(*vals)
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat,
                            f"{prefix}{SEP}{i}" if prefix else str(i))
            for i, v in enumerate(template))
    return flat[prefix or "leaf"]


def _crc(a: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(a).tobytes()) & 0xFFFFFFFF


def _atexit_join(ref):
    """Join a dangling async save at interpreter exit. Never raises
    (atexit swallows nothing gracefully) — a deferred save error is
    logged AND printed to stderr so it cannot vanish with the process."""
    mgr = ref()
    if mgr is None:
        return
    try:
        mgr.close()
    except Exception as e:  # pragma: no cover - exercised via unit test
        log.error("checkpoint: async save failed at process exit: %s", e)
        print(f"checkpoint: async save FAILED at process exit: {e}",
              file=sys.stderr)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3,
                 quiet_reclaim: bool = False):
        """``keep``: retain the newest ``keep`` committed steps, garbage-
        collecting older ones after each save. ``keep=0`` explicitly
        means KEEP ALL (no GC ever) — it is not "keep none".

        ``quiet_reclaim``: demote the dead-pid lock-reclaim warning to
        DEBUG. A supervisor restarting a killed worker reopens one
        manager per resumed lane — every one reclaims the dead pid's
        lock, and that is the EXPECTED recovery path, not an anomaly
        worth a warning per lane. The caller reports one summary line
        instead (``reclaimed_from`` records the dead owner's pid)."""
        self.dir = directory
        self.keep = keep
        self.quiet_reclaim = quiet_reclaim
        self.reclaimed_from: int | None = None
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None
        self._lock_path: str | None = None
        self._acquire_lock()
        atexit.register(_atexit_join, weakref.ref(self))

    # ---- exclusivity -------------------------------------------------------
    def _acquire_lock(self):
        """Take the directory's exclusive ``.lock`` file.

        Same-process re-open adopts the existing lock (re-entrant: the
        sweep service opens per-bucket managers under one root, and
        tests reopen directories to resume). A lock owned by a DEAD
        pid is reclaimed with a warning — a crashed writer must not
        brick its directory. A live foreign owner raises
        :class:`CheckpointLockError`."""
        path = os.path.join(self.dir, ".lock")
        payload = json.dumps({"pid": os.getpid(), "t": time.time()})
        for _ in range(3):
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                with os.fdopen(fd, "w") as f:
                    f.write(payload)
                self._lock_path = path
                return
            except FileExistsError:
                pass
            try:
                with open(path) as f:
                    owner = int(json.load(f)["pid"])
            except (OSError, ValueError, KeyError,
                    json.JSONDecodeError):
                # torn write by a dying owner: give it a beat, then
                # treat unreadable as dead
                time.sleep(0.05)
                owner = None
            if owner == os.getpid():
                self._lock_path = path  # re-entrant adopt
                return
            if owner is not None and _pid_alive(owner):
                raise CheckpointLockError(self.dir, owner)
            (log.debug if self.quiet_reclaim else log.warning)(
                "checkpoint: reclaiming %s from dead process %s",
                path, owner)
            self.reclaimed_from = owner
            try:
                os.remove(path)
            except FileNotFoundError:
                pass  # the dead owner's reaper beat us to it
        raise CheckpointLockError(self.dir, -1)

    def close(self):
        """Join any async save and release the directory lock."""
        self.wait()
        if self._lock_path is not None:
            try:
                os.remove(self._lock_path)
            except FileNotFoundError:
                pass
            self._lock_path = None

    # ---- write ------------------------------------------------------------
    def save(self, step: int, tree, blocking: bool = True):
        """Host-gather and persist `tree` at `step`.

        Host numpy leaves are COPIED (np.array), not aliased: with
        ``blocking=False`` the write races the caller's next mutation
        of those arrays otherwise (the ensemble driver mutates its lane
        vectors in place between blocks).
        """
        self.wait()
        host = {k: np.array(v) for k, v in _flatten(tree).items()}

        def work():
            try:
                self._write(step, host)
                self._gc()
            except Exception as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            work()
            self.wait()
        else:
            self._thread = threading.Thread(target=work, daemon=True)
            self._thread.start()

    def _write(self, step: int, host: dict):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        manifest = {
            "step": step,
            "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": _crc(v)}
                       for k, v in host.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        with open(os.path.join(tmp, ".COMPLETE"), "w") as f:
            f.write("ok")
            f.flush()
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        # keep=0 means keep all (see __init__) — the falsy short-circuit
        # below is that contract, not an accident.
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(
                os.path.join(self.dir, f"step_{s:08d}"),
                ignore_errors=True)

    # ---- read -------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in sorted(os.listdir(self.dir)):
            full = os.path.join(self.dir, name)
            if (name.startswith("step_") and not name.endswith(".tmp")
                    and os.path.exists(os.path.join(full, ".COMPLETE"))):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_verified(self, step: int) -> dict | None:
        """Load + CRC-verify one committed step. None on corruption."""
        path = os.path.join(self.dir, f"step_{step:08d}")
        try:
            with open(os.path.join(path, "manifest.json")) as f:
                manifest = json.load(f)
            with np.load(os.path.join(path, "arrays.npz")) as z:
                flat = {k: z[k] for k in z.files}
        except Exception as e:
            log.warning("checkpoint step %d unreadable (%s: %s)",
                        step, type(e).__name__, e)
            return None
        meta = manifest.get("arrays", {})
        if set(meta) != set(flat):
            log.warning(
                "checkpoint step %d: array set mismatch (manifest %d, "
                "payload %d)", step, len(meta), len(flat))
            return None
        for k, info in meta.items():
            want = info.get("crc32")
            if want is None:
                continue  # pre-integrity checkpoint: nothing to verify
            if _crc(flat[k]) != want:
                log.warning(
                    "checkpoint step %d: CRC mismatch on %r", step, k)
                return None
        return flat

    def restore(self, template, step: int | None = None):
        """Load into host numpy, shaped like `template`. Returns
        (tree, step) or (None, None) when no checkpoint exists.

        Every array is CRC-verified against the manifest. When ``step``
        is None (pick latest), a corrupt step falls back to the
        previous .COMPLETE step with a loud warning — torn storage
        after commit must cost one checkpoint interval, not the run.
        An explicitly requested corrupt ``step`` raises
        :class:`CheckpointCorruptError` instead (the caller asked for
        those bytes specifically)."""
        self.wait()
        if step is not None:
            flat = self._load_verified(step)
            if flat is None:
                raise CheckpointCorruptError(
                    f"checkpoint step {step} in {self.dir} failed "
                    "integrity verification")
            return _unflatten_into(template, flat), step
        for s in reversed(self.all_steps()):
            flat = self._load_verified(s)
            if flat is not None:
                return _unflatten_into(template, flat), s
            log.warning(
                "checkpoint: step %d failed integrity verification — "
                "falling back to the previous .COMPLETE step", s)
        return None, None


def reshard(tree_host, shardings):
    """device_put a host tree onto (possibly different) shardings -
    the elastic-restart path: works across device-count changes."""
    return jax.tree.map(
        lambda x, s: jax.device_put(x, s), tree_host, shardings)
