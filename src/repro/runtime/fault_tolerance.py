"""Fault-tolerance runtime: heartbeats, straggler watchdog, elastic
resize decisions. Pure-python control plane around the JAX data plane -
on a real cluster the heartbeat file is a per-host path on shared
storage (or a KV store); here it's local disk, which exercises the same
logic.

Components:
  * HeartbeatWriter  - each host touches <dir>/<host>.hb every step.
  * HeartbeatMonitor - coordinator reads all hb files; hosts silent for
    > timeout are dead -> triggers elastic restart (fewer hosts).
  * StragglerWatchdog - EMA of step wall-time; a step slower than
    mean * threshold is flagged; persistent stragglers are reported for
    exclusion (on TPU pods the controller would then re-slice).
  * plan_elastic_mesh - given surviving device count, pick the largest
    (data, model) mesh <= available and the batch re-spec.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time


class HeartbeatWriter:
    def __init__(self, directory: str, host_id: int):
        self.path = os.path.join(directory, f"host_{host_id}.hb")
        os.makedirs(directory, exist_ok=True)

    def beat(self, step: int):
        tmp = self.path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"step": step, "t": time.time()}, f)
        os.replace(tmp, self.path)

    def clear(self):
        """Remove the heartbeat file: the clean-shutdown marker.

        A missing file means "never started or exited cleanly"; a STALE
        file means "died mid-run" — so a clean exit must remove its
        file, or every later resume mistakes the previous clean run for
        a dead process."""
        for path in (self.path, self.path + ".tmp"):
            try:
                os.remove(path)
            except FileNotFoundError:
                pass


class HeartbeatMonitor:
    """Staleness is judged by the heartbeat FILE's mtime, not the wall
    time recorded inside it: the writer stamps ``t = time.time()``, so
    an NTP step or suspend/resume between write and read would shift
    the recorded clock and falsely flip hosts dead (or keep a dead one
    alive). ``os.replace`` gives the file a fresh mtime from the same
    filesystem clock the monitor stats it with, so the delta is immune
    to wall-clock jumps; ``skew_s`` absorbs coarse-mtime filesystems
    and NFS-style writer/reader clock offsets. The recorded ``t`` stays
    in the returned record as a diagnostic only.
    """

    def __init__(self, directory: str, timeout_s: float = 60.0,
                 skew_s: float = 2.0):
        self.dir = directory
        self.timeout = timeout_s
        self.skew = skew_s

    def _fresh(self, path: str) -> bool:
        """mtime-based staleness check; False if the file vanished."""
        try:
            age = time.time() - os.stat(path).st_mtime
        except OSError:
            return False
        return age <= self.timeout + self.skew

    def alive_hosts(self) -> dict[int, dict]:
        out = {}
        if not os.path.isdir(self.dir):
            return out
        for name in os.listdir(self.dir):
            if not name.endswith(".hb"):
                continue
            path = os.path.join(self.dir, name)
            try:
                with open(path) as f:
                    rec = json.load(f)
            except (json.JSONDecodeError, OSError):
                continue  # torn read: treat as missing this poll
            host = int(name.split("_")[1].split(".")[0])
            if self._fresh(path):
                out[host] = rec
        return out

    def dead_hosts(self, expected: int) -> list[int]:
        alive = self.alive_hosts()
        return [h for h in range(expected) if h not in alive]

    def host_status(self, host_id: int) -> str:
        """Tri-state for one host: "alive" (fresh heartbeat), "dead"
        (stale heartbeat — the process stopped beating without
        :meth:`HeartbeatWriter.clear`), or "absent" (no file: never
        started, or shut down cleanly)."""
        path = os.path.join(self.dir, f"host_{host_id}.hb")
        try:
            with open(path) as f:
                json.load(f)
        except FileNotFoundError:
            return "absent"
        except (json.JSONDecodeError, OSError):
            return "dead"  # torn/corrupt file from a mid-write kill
        return "alive" if self._fresh(path) else "dead"


@dataclasses.dataclass
class StragglerWatchdog:
    """Flags steps slower than ema * threshold; tracks repeat offenders."""

    threshold: float = 2.0
    decay: float = 0.9
    patience: int = 3

    ema: float | None = None
    consecutive_slow: int = 0
    flagged: bool = False

    def observe(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler event."""
        if self.ema is None:
            self.ema = step_time_s
            return False
        slow = step_time_s > self.threshold * self.ema
        # slow steps do not poison the baseline
        if not slow:
            self.ema = self.decay * self.ema + (1 - self.decay) * step_time_s
            self.consecutive_slow = 0
        else:
            self.consecutive_slow += 1
            if self.consecutive_slow >= self.patience:
                self.flagged = True
        return slow


def plan_elastic_mesh(n_devices: int, *, model_parallel: int = 16,
                      global_batch: int = 256):
    """Largest power-of-two data axis that fits the surviving devices,
    keeping TP fixed (reshaping TP would re-shard every weight).

    Returns dict(mesh_shape, drop_devices, per_device_batch).
    """
    data = max(1, n_devices // model_parallel)
    # round data axis down to a divisor of the global batch
    while data > 1 and global_batch % data != 0:
        data -= 1
    used = data * model_parallel
    return {
        "mesh_shape": (data, model_parallel),
        "axis_names": ("data", "model"),
        "drop_devices": n_devices - used,
        "per_device_batch": global_batch // data,
    }


@dataclasses.dataclass
class TrainGuard:
    """Bundles the per-step fault-tolerance bookkeeping for a driver."""

    heartbeat: HeartbeatWriter
    watchdog: StragglerWatchdog
    monitor: HeartbeatMonitor | None = None
    expected_hosts: int = 1

    def on_step(self, step: int, step_time_s: float) -> dict:
        self.heartbeat.beat(step)
        slow = self.watchdog.observe(step_time_s)
        dead = (self.monitor.dead_hosts(self.expected_hosts)
                if self.monitor else [])
        return {
            "straggler": slow,
            "straggler_flagged": self.watchdog.flagged,
            "dead_hosts": dead,
            "needs_resize": bool(dead),
        }
