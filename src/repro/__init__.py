"""lpNNPS4SPH-JAX: mixed-precision SPH with cell-based relative coordinates
on TPU, plus the assigned 10-architecture LM stack. See DESIGN.md."""
__version__ = "0.1.0"
