"""Deterministic synthetic data pipeline, shardable and restartable.

Tokens are a pure function of (seed, step, position) via a counter-mode
hash (threefry through jax.random, computed host-side with numpy for
zero device work) - so any host can materialize exactly its shard of any
global batch, and restart-with-skip-ahead is O(1): just set the step.

This is the honest stand-in for a real corpus reader: the *contract*
(global batch -> per-host shard -> device layout, deterministic resume)
is the part the framework needs; the bytes themselves are synthetic.
"""
from __future__ import annotations

import dataclasses

import numpy as np

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # repeat-block structure so cross-entropy has learnable signal:
    # each fresh token repeats `repeat` times -> next-token prediction
    # succeeds (repeat-1)/repeat of the time for a model that learns copy
    repeat: int = 4


def _hash_u32(a: np.ndarray) -> np.ndarray:
    """xxhash-ish integer mix, vectorized (deterministic across hosts)."""
    x = a.astype(np.uint64)
    x = (x ^ (x >> 33)) * np.uint64(0xFF51AFD7ED558CCD)
    x = (x ^ (x >> 33)) * np.uint64(0xC4CEB9FE1A85EC53)
    x = x ^ (x >> 33)
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


def global_batch_np(cfg: DataConfig, step: int) -> np.ndarray:
    """(global_batch, seq_len) int32 tokens for a given step."""
    B, L, V = cfg.global_batch, cfg.seq_len, cfg.vocab
    rows = np.arange(B, dtype=np.uint64)[:, None]
    cols = np.arange(L, dtype=np.uint64)[None, :]
    base = (np.uint64(cfg.seed) << np.uint64(32)) + np.uint64(step)
    r = max(1, cfg.repeat)
    block_cols = cols // np.uint64(r)
    h = _hash_u32(base * np.uint64(1_000_003) + rows * np.uint64(L)
                  + block_cols)
    return (h % np.uint32(V)).astype(np.int32)


def host_shard(cfg: DataConfig, step: int, host_id: int,
               n_hosts: int) -> np.ndarray:
    """This host's contiguous rows of the global batch."""
    assert cfg.global_batch % n_hosts == 0
    per = cfg.global_batch // n_hosts
    full = global_batch_np(cfg, step)
    return full[host_id * per : (host_id + 1) * per]


def make_batch(cfg: DataConfig, step: int, sharding=None) -> dict:
    """Device-ready {"tokens","labels"} (labels = tokens; loss shifts)."""
    tok = jnp.asarray(global_batch_np(cfg, step))
    if sharding is not None:
        tok = jax.device_put(tok, sharding)
    return {"tokens": tok, "labels": tok}


class DataIterator:
    """Stateful wrapper with O(1) skip-ahead for checkpoint resume."""

    def __init__(self, cfg: DataConfig, start_step: int = 0, sharding=None):
        self.cfg = cfg
        self.step = start_step
        self.sharding = sharding

    def __next__(self) -> dict:
        b = make_batch(self.cfg, self.step, self.sharding)
        self.step += 1
        return b

    def skip_to(self, step: int):
        self.step = step
