"""Pallas TPU kernel: cell-blocked RCLL pairwise distance / adjacency.

The paper's CUDA NNPS kernel walks per-thread linked lists; the TPU
adaptation (DESIGN.md section 2) makes the background cell *the tile*:

  * particles are binned to (cell, slot) with a static capacity ``cap``
    (a multiple of 128 -> full VPU lanes);
  * relative coordinates are laid out (C, d, cap): the tiny ``d`` axis
    sits on sublanes, ``cap`` on lanes, so one (cap_i x cap_j) distance
    tile is d fused broadcast-subtract-square passes on the VPU;
  * the 3^dim neighborhood is the grid's second axis: grid = (C, M).
    Block (c, k) loads the self cell's coordinates and the k-th neighbor
    cell's coordinates via scalar-prefetched ``nb_ids`` (the TPU analogue
    of the paper's warp-coalesced neighbor-cell loads - each neighbor
    tile is streamed HBM->VMEM exactly once per (cell, k));
  * the cell-index delta is the neighborhood offset itself (an exact
    small-integer anchor per Eq. 7), streamed as a tiny (1, d) block
    indexed by k.

Because binning orders particles by flat cell id, this layout *is* the
paper's Thrust xy-sort locality optimization (their 2.7x): spatially
adjacent tiles are adjacent in HBM.

Storage dtype is fp16 (paper) or bf16; arithmetic dtype defaults to fp32
(TPU VPU native - fp16 multiplies are upconverted anyway).

Two kernels share the tile layout: ``rcll_adjacency`` materializes the
dense (C, M, cap, cap) adjacency (accuracy tables / diagnostics), and
``rcll_neighbor_list_tables`` - the production neighbor producer used by
``solver`` via ``ops.rcll_neighbor_lists`` - emits K-compacted
per-particle neighbor id lists (C, cap, K) plus counts, compacting each
neighborhood block with a running-prefix one-hot scatter.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tiling

Array = jnp.ndarray


def _adjacency_kernel(
    # scalar prefetch
    nb_ref,
    # inputs
    off_ref,  # (1, d) neighborhood offset for this k
    rel_i_ref,  # (1, d, cap) self cell
    rel_j_ref,  # (1, d, cap) neighbor cell (prefetched index)
    occ_i_ref,  # (1, cap)
    occ_j_ref,  # (1, cap)
    # outputs
    adj_ref,  # (1, 1, cap, cap)
    cnt_ref,  # (1, cap) accumulated over k
    *,
    weights: tuple,
    r2_cell: float,
    compute_dtype,
):
    c, k = pl.program_id(0), pl.program_id(1)
    cap = rel_i_ref.shape[2]

    @pl.when(k == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    d2 = tiling.tile_r2_cell(
        rel_i_ref[0], rel_j_ref[0], off_ref[0], weights, compute_dtype
    )
    ok = d2 <= compute_dtype(r2_cell)
    # occupancy + self-pair exclusion (neighbor cell == self cell, same slot)
    ok = ok & tiling.tile_pair_mask(
        occ_i_ref[0], occ_j_ref[0], nb_ref[c, k] == c, cap
    )

    adj = ok.astype(jnp.float32)
    adj_ref[0, 0] = adj
    cnt_ref[...] += jnp.sum(adj, axis=1)[None]


def _neighbor_list_kernel(
    # scalar prefetch
    nb_ref,
    # inputs
    off_ref,  # (1, d) neighborhood offset for this k
    rel_i_ref,  # (1, d, cap) self cell
    rel_j_ref,  # (1, d, cap) neighbor cell (prefetched index)
    occ_i_ref,  # (1, cap)
    occ_j_ref,  # (1, cap)
    ids_j_ref,  # (1, cap) int32 particle ids in the neighbor cell row
    # outputs (both indexed by c only -> accumulated across the k axis)
    out_ref,  # (1, cap, K) int32 compacted neighbor ids, -1 padded
    cnt_ref,  # (1, cap) f32 running neighbor counts
    *,
    weights: tuple,
    r2_cell: float,
    k_slots: int,
    compute_dtype,
):
    """Append this neighbor cell's hits to each slot's compacted list.

    The compaction is a running-prefix scatter: slot i's hits in block k
    land at positions [cnt_i, cnt_i + hits) of its K-wide list. The
    scatter is expressed as a one-hot sum over candidate j (TPU has no
    per-lane scatter); the (cap, cap, K) one-hot intermediate bounds VMEM,
    so real-TPU deployments should tile K - interpret-mode CPU validation
    and the v5e roofline both fit comfortably at cap <= 128, K <= 128.
    """
    c, k = pl.program_id(0), pl.program_id(1)
    cap = rel_i_ref.shape[2]

    @pl.when(k == 0)
    def _init():
        out_ref[...] = jnp.full_like(out_ref, -1)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    d2 = tiling.tile_r2_cell(
        rel_i_ref[0], rel_j_ref[0], off_ref[0], weights, compute_dtype
    )
    ok = d2 <= compute_dtype(r2_cell)
    # occupancy + self-pair exclusion (neighbor cell == self cell, same slot)
    ok = ok & tiling.tile_pair_mask(
        occ_i_ref[0], occ_j_ref[0], nb_ref[c, k] == c, cap
    )

    # Compact: hit at (i, j) targets list slot prev_count_i + rank_j.
    prev = cnt_ref[0].astype(jnp.int32)  # (cap,)
    incl = jnp.cumsum(ok.astype(jnp.int32), axis=1)  # (cap, cap)
    target = prev[:, None] + incl - 1
    write = ok & (target < k_slots)
    slot_iota = jax.lax.broadcasted_iota(
        jnp.int32, (cap, cap, k_slots), 2
    )
    onehot = write[:, :, None] & (target[:, :, None] == slot_iota)
    ids_j = ids_j_ref[0].astype(jnp.int32)  # (cap,)
    # +1 so id 0 survives the masked sum; at most one j feeds each (i, t).
    contrib = jnp.sum(
        jnp.where(onehot, ids_j[None, :, None] + 1, 0), axis=1
    )  # (cap, K)
    out_ref[0] = jnp.where(contrib > 0, contrib - 1, out_ref[0])
    # Count the TRUE hits (not just the written ones): callers detect
    # K overflow exactly as in the jnp path's NeighborList.count.
    cnt_ref[...] += jnp.sum(ok.astype(jnp.float32), axis=1)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "offs", "weights", "r_cell", "k_slots", "compute_dtype", "interpret",
    ),
)
def rcll_neighbor_list_tables(
    rel: Array,  # (C, d, cap) storage dtype (fp16/bf16/f32)
    occ: Array,  # (C, cap) f32 {0,1}
    ids: Array,  # (C, cap) int32 particle ids (-1 empty)
    nb_ids: Array,  # (C, M) int32
    *,
    offs: tuple,  # ((dj...), ...) M x d neighborhood offsets (static)
    weights: tuple,  # (d,) anisotropy weights (static)
    r_cell: float,
    k_slots: int,
    compute_dtype=jnp.float32,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Per-slot compacted neighbor lists (C, cap, K) int32 + counts (C, cap).

    The production neighbor producer: instead of materializing the dense
    (C, M, cap, cap) adjacency (HBM traffic ~ M*cap^2 per cell), each cell
    block streams its 3^d neighborhood once and emits the K-compacted id
    lists directly (traffic ~ cap*K). List order is (neighborhood block k,
    slot j) - identical to the jnp candidate order, so the two backends
    agree on sets (and on ids when counts fit in K).
    """
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    offs_arr = jnp.asarray(np.asarray(offs, np.float32).reshape(M, d))

    kernel = functools.partial(
        _neighbor_list_kernel,
        weights=tuple(float(w) for w in weights),
        r2_cell=float(r_cell) ** 2,
        k_slots=int(k_slots),
        compute_dtype=jnp.dtype(compute_dtype).type,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda c, k, nb: (k, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (nb[c, k], 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, cap, k_slots), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, cap, k_slots), jnp.int32),
            jax.ShapeDtypeStruct((C, cap), jnp.float32),
        ],
        interpret=interpret,
    )(nb_ids, offs_arr, rel, rel, occ, occ, ids)


@functools.partial(
    jax.jit,
    static_argnames=(
        "offs", "weights", "r_cell", "compute_dtype", "interpret",
    ),
)
def rcll_adjacency(
    rel: Array,  # (C, d, cap) storage dtype (fp16/bf16/f32)
    occ: Array,  # (C, cap) f32 {0,1}
    nb_ids: Array,  # (C, M) int32
    *,
    offs: tuple,  # ((dj...), ...) M x d neighborhood offsets (static)
    weights: tuple,  # (d,) anisotropy weights (static)
    r_cell: float,
    compute_dtype=jnp.float32,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Adjacency (C, M, cap, cap) f32 {0,1} + neighbor counts (C, cap)."""
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    offs_arr = jnp.asarray(np.asarray(offs, np.float32).reshape(M, d))

    kernel = functools.partial(
        _adjacency_kernel,
        weights=tuple(float(w) for w in weights),
        r2_cell=float(r_cell) ** 2,
        compute_dtype=jnp.dtype(compute_dtype).type,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda c, k, nb: (k, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (nb[c, k], 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap, cap), lambda c, k, nb: (c, k, 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, M, cap, cap), jnp.float32),
            jax.ShapeDtypeStruct((C, cap), jnp.float32),
        ],
        interpret=interpret,
    )(nb_ids, offs_arr, rel, rel, occ, occ)
