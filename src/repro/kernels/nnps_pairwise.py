"""Pallas TPU kernel: cell-blocked RCLL pairwise distance / adjacency.

The paper's CUDA NNPS kernel walks per-thread linked lists; the TPU
adaptation (DESIGN.md section 2) makes the background cell *the tile*:

  * particles are binned to (cell, slot) with a static capacity ``cap``
    (a multiple of 128 -> full VPU lanes);
  * relative coordinates are laid out (C, d, cap): the tiny ``d`` axis
    sits on sublanes, ``cap`` on lanes, so one (cap_i x cap_j) distance
    tile is d fused broadcast-subtract-square passes on the VPU;
  * the 3^dim neighborhood is the grid's second axis: grid = (C, M).
    Block (c, k) loads the self cell's coordinates and the k-th neighbor
    cell's coordinates via scalar-prefetched ``nb_ids`` (the TPU analogue
    of the paper's warp-coalesced neighbor-cell loads - each neighbor
    tile is streamed HBM->VMEM exactly once per (cell, k));
  * the cell-index delta is the neighborhood offset itself (an exact
    small-integer anchor per Eq. 7), streamed as a tiny (1, d) block
    indexed by k.

Because binning orders particles by flat cell id, this layout *is* the
paper's Thrust xy-sort locality optimization (their 2.7x): spatially
adjacent tiles are adjacent in HBM.

Storage dtype is fp16 (paper) or bf16; arithmetic dtype defaults to fp32
(TPU VPU native - fp16 multiplies are upconverted anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray


def _adjacency_kernel(
    # scalar prefetch
    nb_ref,
    # inputs
    off_ref,  # (1, d) neighborhood offset for this k
    rel_i_ref,  # (1, d, cap) self cell
    rel_j_ref,  # (1, d, cap) neighbor cell (prefetched index)
    occ_i_ref,  # (1, cap)
    occ_j_ref,  # (1, cap)
    # outputs
    adj_ref,  # (1, 1, cap, cap)
    cnt_ref,  # (1, cap) accumulated over k
    *,
    weights: tuple,
    r2_cell: float,
    compute_dtype,
):
    c, k = pl.program_id(0), pl.program_id(1)
    d, cap = rel_i_ref.shape[1], rel_i_ref.shape[2]

    @pl.when(k == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)

    rel_i = rel_i_ref[0].astype(compute_dtype)  # (d, cap)
    rel_j = rel_j_ref[0].astype(compute_dtype)  # (d, cap)
    off_k = off_ref[0].astype(compute_dtype)  # (d,)

    d2 = jnp.zeros((cap, cap), compute_dtype)
    for a in range(d):  # static unroll over the 2-3 axes
        du = (rel_i[a][:, None] - rel_j[a][None, :]) * compute_dtype(0.5)
        du = (du - off_k[a]) * compute_dtype(weights[a])
        d2 = d2 + du * du

    ok = d2 <= compute_dtype(r2_cell)
    occ = (occ_i_ref[0][:, None] > 0) & (occ_j_ref[0][None, :] > 0)
    ok = ok & occ
    # self-pair exclusion: neighbor cell == self cell and same slot
    is_self_cell = nb_ref[c, k] == c
    eye = jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 0) == \
        jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 1)
    ok = ok & ~(is_self_cell & eye)

    adj = ok.astype(jnp.float32)
    adj_ref[0, 0] = adj
    cnt_ref[...] += jnp.sum(adj, axis=1)[None]


@functools.partial(
    jax.jit,
    static_argnames=(
        "offs", "weights", "r_cell", "compute_dtype", "interpret",
    ),
)
def rcll_adjacency(
    rel: Array,  # (C, d, cap) storage dtype (fp16/bf16/f32)
    occ: Array,  # (C, cap) f32 {0,1}
    nb_ids: Array,  # (C, M) int32
    *,
    offs: tuple,  # ((dj...), ...) M x d neighborhood offsets (static)
    weights: tuple,  # (d,) anisotropy weights (static)
    r_cell: float,
    compute_dtype=jnp.float32,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Adjacency (C, M, cap, cap) f32 {0,1} + neighbor counts (C, cap)."""
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    offs_arr = jnp.asarray(np.asarray(offs, np.float32).reshape(M, d))

    kernel = functools.partial(
        _adjacency_kernel,
        weights=tuple(float(w) for w in weights),
        r2_cell=float(r_cell) ** 2,
        compute_dtype=jnp.dtype(compute_dtype).type,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda c, k, nb: (k, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (nb[c, k], 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, cap, cap), lambda c, k, nb: (c, k, 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, M, cap, cap), jnp.float32),
            jax.ShapeDtypeStruct((C, cap), jnp.float32),
        ],
        interpret=interpret,
    )(nb_ids, offs_arr, rel, rel, occ, occ)
