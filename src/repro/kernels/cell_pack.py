"""Pallas TPU kernel: one-sweep cell-major packing of cell-sorted rows.

The Pallas force kernels consume dense cell-major tables ``(C+1, F,
cap)``. PR 2/3 built them with :func:`cells.to_cell_major`: a ``(C,
cap)`` id-table gather per field — 4-5 separate strided gathers per
step, each walking the whole table. But the persistent pipeline's
arrays are CELL-SORTED: cell c's particles are EXACTLY the contiguous
rows ``starts[c] .. starts[c] + counts[c] - 1`` (the counting-sort
invariant), so a cell tile is a contiguous slice copy, not a gather.

This kernel is that observation as a single sweep over cells: per grid
step c it DMAs the cell's row slice from HBM into VMEM (one 16-bit
record slab + one fp32 slab — the PR 3 record-row trick applied to the
*pack*), masks slots past the occupancy, transposes to the (F, cap)
sublane/lane layout, and emits the ``(C, cap)`` packed-id table as pure
``start + iota`` arithmetic in the same pass. One kernel launch
replaces every per-field ``to_cell_major`` gather, and the only HBM
reads are the contiguous row slabs themselves.

The pure-jnp mirror (:func:`cell_tables_ref`) computes identical
outputs from the same inputs (a gather formulation) and pins the
kernel in the agreement tests. Production follows the repo's kernel
convention: the Pallas kernel runs everywhere the pallas backend does
— interpreted on CPU (tiny test scales only), compiled on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray


def _pack_kernel(
    # scalar prefetch
    starts_ref,  # (C+1,) int32 packed start of each cell (sentinel: N)
    counts_ref,  # (C+1,) int32 occupancy (sentinel: 0)
    # inputs
    fill32_ref,  # (1, F32) f32 empty-slot fill per fp32 column
    rows16_ref,  # (N + cap, F16) u16 cell-sorted 16-bit record rows (HBM)
    rows32_ref,  # (N + cap, F32) f32 cell-sorted fp32 rows (HBM)
    # outputs
    t16_ref,  # (1, F16, cap) u16
    t32_ref,  # (1, F32, cap) f32
    ids_ref,  # (1, cap) int32 packed ids, -1 in empty slots
    # scratch
    s16_ref,  # (cap, F16) u16 VMEM
    s32_ref,  # (cap, F32) f32 VMEM
    sem16,
    sem32,
    *,
    cap: int,
):
    c = pl.program_id(0)
    start = starts_ref[c]
    count = counts_ref[c]
    dma16 = pltpu.make_async_copy(
        rows16_ref.at[pl.ds(start, cap), :], s16_ref, sem16
    )
    dma32 = pltpu.make_async_copy(
        rows32_ref.at[pl.ds(start, cap), :], s32_ref, sem32
    )
    dma16.start()
    dma32.start()
    slot_col = jax.lax.broadcasted_iota(jnp.int32, (cap, 1), 0)
    occ = slot_col < count
    slot_row = jax.lax.broadcasted_iota(jnp.int32, (1, cap), 1)
    ids_ref[...] = jnp.where(slot_row < count, start + slot_row, -1)
    dma16.wait()
    t16_ref[0] = jnp.where(occ, s16_ref[...], 0).T
    dma32.wait()
    t32_ref[0] = jnp.where(occ, s32_ref[...], fill32_ref[...]).T


@functools.partial(
    jax.jit, static_argnames=("cap", "interpret")
)
def cell_tables(
    rows16: Array,  # (N, F16) u16 cell-sorted 16-bit record rows
    rows32: Array,  # (N, F32) f32 cell-sorted fp32 rows
    starts: Array,  # (C,) int32 exclusive cumsum of counts
    counts: Array,  # (C,) int32 per-cell occupancy
    fill32: Array,  # (F32,) f32 empty-slot fill per fp32 column
    *,
    cap: int,
    interpret: bool = True,
) -> tuple[Array, Array, Array]:
    """One-sweep cell-major tables from cell-sorted rows.

    Returns ``(t16 (C+1, F16, cap) u16, t32 (C+1, F32, cap) f32,
    ids (C+1, cap) int32)`` — row C is the sentinel empty cell (fp32
    columns hold their fill so denominator fields stay finite). The
    id table is ``starts[c] + iota`` masked to -1 past the occupancy:
    identical to the counting-sort packed table
    (``cells._packed_table``), emitted for free in the same sweep.
    """
    n, f16 = rows16.shape
    f32 = rows32.shape[1]
    c_total = starts.shape[0]
    # Pad the row slabs so the fixed-size cap-slice never reads out of
    # bounds, and point the sentinel cell at the padding (count 0).
    pad16 = jnp.zeros((cap, f16), rows16.dtype)
    pad32 = jnp.zeros((cap, f32), rows32.dtype)
    rows16p = jnp.concatenate([rows16, pad16], axis=0)
    rows32p = jnp.concatenate([rows32, pad32], axis=0)
    starts_s = jnp.concatenate(
        [starts.astype(jnp.int32), jnp.full((1,), n, jnp.int32)]
    )
    counts_s = jnp.concatenate(
        [counts.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(c_total + 1,),
        in_specs=[
            pl.BlockSpec((1, f32), lambda c, s, k: (0, 0)),
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec(memory_space=pltpu.ANY),
        ],
        out_specs=[
            pl.BlockSpec((1, f16, cap), lambda c, s, k: (c, 0, 0)),
            pl.BlockSpec((1, f32, cap), lambda c, s, k: (c, 0, 0)),
            pl.BlockSpec((1, cap), lambda c, s, k: (c, 0)),
        ],
        scratch_shapes=[
            pltpu.VMEM((cap, f16), rows16.dtype),
            pltpu.VMEM((cap, f32), rows32.dtype),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
    )
    return pl.pallas_call(
        functools.partial(_pack_kernel, cap=cap),
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((c_total + 1, f16, cap), rows16.dtype),
            jax.ShapeDtypeStruct((c_total + 1, f32, cap), rows32.dtype),
            jax.ShapeDtypeStruct((c_total + 1, cap), jnp.int32),
        ],
        interpret=interpret,
    )(starts_s, counts_s, fill32.reshape(1, f32), rows16p, rows32p)


def cell_tables_ref(
    rows16: Array,
    rows32: Array,
    starts: Array,
    counts: Array,
    fill32: Array,
    *,
    cap: int,
) -> tuple[Array, Array, Array]:
    """Pure-jnp mirror of :func:`cell_tables` (gather formulation).

    Bit-identical outputs; the agreement test pins the kernel to it.
    Used as the production pack on hosts where Pallas interprets.
    """
    n = rows16.shape[0]
    starts_s = jnp.concatenate(
        [starts.astype(jnp.int32), jnp.full((1,), n, jnp.int32)]
    )
    counts_s = jnp.concatenate(
        [counts.astype(jnp.int32), jnp.zeros((1,), jnp.int32)]
    )
    slot = jnp.arange(cap, dtype=jnp.int32)[None, :]
    ids = starts_s[:, None] + slot  # (C+1, cap)
    occ = slot < counts_s[:, None]
    safe = jnp.clip(ids, 0, n - 1)
    t16 = jnp.where(occ[..., None], rows16[safe], 0)
    t32 = jnp.where(
        occ[..., None], rows32[safe], fill32[None, None, :]
    )
    return (
        t16.transpose(0, 2, 1),
        t32.transpose(0, 2, 1),
        jnp.where(occ, ids, -1),
    )
