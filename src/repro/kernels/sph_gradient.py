"""Pallas TPU kernel: fused RCLL neighbor-search + SPH A5 gradient.

This fuses the paper's two profiled kernels ('NNPS' + 'gradient
approximation', Table 6) into one pass: the (cap_i x cap_j) distance tile
is immediately consumed by the B-spline weight and the normalized-gradient
accumulators, so the adjacency never round-trips through HBM. The paper
identifies the O(N) NNPS as memory-bound (8% compute / 51% bandwidth) -
the fusion removes the intermediate neighbor-list write+read entirely,
the same "optimize memory, not FLOPs" lever as their sorted layout, taken
one step further (see EXPERIMENTS.md Perf-SPH).

Layout and blocking are identical to nnps_pairwise.py. Distance math runs
in the NNPS precision (fp16 faithful / fp32 TPU-native); kernel weights
and accumulators are fp32 (the paper's high-precision tier).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bspline
from repro.core.precision import NNPS_STORE
from repro.kernels import tiling

Array = jnp.ndarray


def _gradient_kernel(
    nb_ref,
    off_ref,  # (1, d)
    rel_i_ref,  # (1, d, cap)
    rel_j_ref,  # (1, d, cap)
    f_i_ref,  # (1, cap)
    f_j_ref,  # (1, cap)
    occ_i_ref,  # (1, cap)
    occ_j_ref,  # (1, cap)
    num_ref,  # (1, d, cap) accumulated over k
    den_ref,  # (1, d, cap)
    *,
    weights: tuple,
    r2_cell: float,
    hc_phys: tuple,
    h: float,
    dim: int,
    nnps_dtype,
):
    c, k = pl.program_id(0), pl.program_id(1)
    d, cap = rel_i_ref.shape[1], rel_i_ref.shape[2]

    @pl.when(k == 0)
    def _init():
        num_ref[...] = jnp.zeros_like(num_ref)
        den_ref[...] = jnp.zeros_like(den_ref)

    off_k = off_ref[0]  # (d,) f32

    # ---- NNPS tier (low precision): Eq. 7 distance + radius test --------
    d2_lo = tiling.tile_r2_cell(
        rel_i_ref[0], rel_j_ref[0], off_k, weights, nnps_dtype
    )
    ok = d2_lo <= nnps_dtype(r2_cell)
    ok = ok & tiling.tile_pair_mask(
        occ_i_ref[0], occ_j_ref[0], nb_ref[c, k] == c, cap
    )
    adj = ok.astype(jnp.float32)

    # ---- physics tier (fp32): B-spline dW/dr and A5 accumulators --------
    disp, r2 = tiling.tile_phys_disp(
        rel_i_ref[0], rel_j_ref[0], off_k, hc_phys
    )
    r = jnp.sqrt(r2)
    coef = adj * bspline.dw_over_r(r, h, dim)  # (cap_i, cap_j)

    df = f_j_ref[0][None, :] - f_i_ref[0][:, None]  # f_j - f_i
    for a in range(d):
        gw_a = coef * disp[a]  # ∂W/∂x_a tile
        num_ref[0, a] += jnp.sum(df * gw_a, axis=1)
        den_ref[0, a] += jnp.sum(-disp[a] * gw_a, axis=1)


@functools.partial(
    jax.jit,
    static_argnames=(
        "offs", "weights", "r_cell", "hc_phys", "h", "dim",
        "nnps_dtype", "interpret",
    ),
)
def rcll_gradient(
    rel: Array,  # (C, d, cap)
    f: Array,  # (C, cap) f32
    occ: Array,  # (C, cap) f32
    nb_ids: Array,  # (C, M) int32
    *,
    offs: tuple,
    weights: tuple,
    r_cell: float,
    hc_phys: tuple,
    h: float,
    dim: int,
    nnps_dtype=NNPS_STORE,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused search+gradient: returns (num, den), each (C, d, cap) f32."""
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    offs_arr = jnp.asarray(np.asarray(offs, np.float32).reshape(M, d))
    kernel = functools.partial(
        _gradient_kernel,
        weights=tuple(float(w) for w in weights),
        r2_cell=float(r_cell) ** 2,
        hc_phys=tuple(float(x) for x in hc_phys),
        h=float(h),
        dim=int(dim),
        nnps_dtype=jnp.dtype(nnps_dtype).type,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda c, k, nb: (k, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (nb[c, k], 0, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0)),
            pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, d, cap), jnp.float32),
            jax.ShapeDtypeStruct((C, d, cap), jnp.float32),
        ],
        interpret=interpret,
    )(nb_ids, offs_arr, rel, rel, f, f, occ, occ)
