"""Pallas TPU kernel: decode attention over a block-anchored quantized
KV cache ("RCLL-KV").

This is the paper's decomposition applied to the memory-bound tensor of
LM inference. RCLL stores position = cell_center(exact) + fp16 residual
normalized to [-1,1]; RCLL-KV stores, per 128-token cache block,

    kv = anchor(fp32, per-block mean) + scale(fp32) * residual(fp16/int8)

Decode attention is bandwidth-bound exactly like the paper's O(N) NNPS
(Table 6: 8% compute, 51% bandwidth): the KV cache is the stream. int8
residuals + per-block fp32 anchors cut streamed bytes ~4x vs bf16 at a
quantization error bounded per block (core.anchored.quantization_error_
bound) - the same accuracy argument as Table 2's RCLL column.

Kernel: grid (B*Hkv, nblk). Each step dequantizes one (blk, dh) K and V
tile in VMEM, runs the `rep` grouped query heads against it on the MXU
((rep, dh) x (dh, blk)), and carries online-softmax stats in scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _dequant(resid, anchor, scale):
    if resid.dtype == jnp.int8:
        r = resid.astype(jnp.float32) * (1.0 / 127.0)
    else:
        r = resid.astype(jnp.float32)
    return anchor + scale * r


def _decode_kernel(
    len_ref,  # scalar prefetch: (B,) int32 valid lengths
    q_ref,  # (1, rep, dh)
    kr_ref,  # (1, 1, blk, dh) residuals
    ka_ref,  # (1, 1, 1, dh) anchor
    ks_ref,  # (1, 1, 1, dh) scale
    vr_ref,
    va_ref,
    vs_ref,
    o_ref,  # (1, rep, dh)
    m_ref,  # (rep, 1)
    l_ref,  # (rep, 1)
    acc_ref,  # (rep, dh)
    *,
    scale: float,
    blk: int,
    nblk: int,
    hkv: int,
):
    bh, ib = pl.program_id(0), pl.program_id(1)
    b = bh // hkv

    @pl.when(ib == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (rep, dh)
    k = _dequant(kr_ref[0, 0], ka_ref[0, 0], ks_ref[0, 0])  # (blk, dh)
    v = _dequant(vr_ref[0, 0], va_ref[0, 0], vs_ref[0, 0])  # (blk, dh)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale  # (rep, blk)
    pos = ib * blk + jax.lax.broadcasted_iota(jnp.int32, (1, blk), 1)
    s = jnp.where(pos < len_ref[b], s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ib == nblk - 1)
    def _finalize():
        l = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def rcll_kv_decode(
    q: Array,  # (B, H, Dh)
    k_resid: Array,  # (B, Hkv, nblk, blk, Dh) fp16/bf16/int8
    k_anchor: Array,  # (B, Hkv, nblk, 1, Dh) f32
    k_scale: Array,  # (B, Hkv, nblk, 1, Dh) f32
    v_resid: Array,
    v_anchor: Array,
    v_scale: Array,
    length: Array,  # (B,) int32
    *,
    scale: float | None = None,
    interpret: bool = True,
) -> Array:
    B, H, Dh = q.shape
    _, Hkv, nblk, blk, _ = k_resid.shape
    rep = H // Hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dh))

    # (B, H, Dh) -> (B*Hkv, rep, Dh): group query heads by kv head
    qr = q.reshape(B, Hkv, rep, Dh).reshape(B * Hkv, rep, Dh)

    def flat5(x):
        return x.reshape(B * Hkv, nblk, x.shape[3], Dh)

    kernel = functools.partial(
        _decode_kernel, scale=scale, blk=blk, nblk=nblk, hkv=Hkv
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B * Hkv, nblk),
        in_specs=[
            pl.BlockSpec((1, rep, Dh), lambda bh, ib, ln: (bh, 0, 0)),
            pl.BlockSpec((1, 1, blk, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
            pl.BlockSpec((1, 1, blk, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
            pl.BlockSpec((1, 1, 1, Dh), lambda bh, ib, ln: (bh, ib, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, Dh), lambda bh, ib, ln: (bh, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, 1), jnp.float32),
            pltpu.VMEM((rep, Dh), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B * Hkv, rep, Dh), jnp.float32),
        interpret=interpret,
    )(
        length,
        qr,
        flat5(k_resid),
        flat5(k_anchor),
        flat5(k_scale),
        flat5(v_resid),
        flat5(v_anchor),
        flat5(v_scale),
    )
    return out.reshape(B, H, Dh)
