"""Pallas TPU kernel: fused cell-blocked WCSPH force evaluation.

The paper's bandwidth argument (Table 6: NNPS + gradient are ~8% compute
/ ~51% bandwidth) applied to the *force* stage: instead of gathering
per-pair arrays (disp, grad W, dv, m_j — all (N, K, d)-sized HBM round
trips), each (cell, neighbor-cell) tile decodes the relative coordinates
+ the exact integer cell offset (Eq. 7) in registers, evaluates the
B-spline gradient in place, and accumulates the continuity AND momentum
sums directly into fp32 VMEM accumulators indexed by the self cell — the
full WCSPH right-hand side in ONE pass over the neighbor tiles (the
solver integrates the standard explicit scheme, so both sums read the
same state). Layout, blocking, and scalar-prefetched neighbor ids are
identical to ``nnps_pairwise.py`` / ``sph_gradient.py`` (shared helpers
in ``kernels/tiling.py``); the pair physics goes through the same
primitives as the reference path (``core/bspline.py`` / ``core/sph.py``).

Half-width tile streams (the bandwidth round). The kernel's per-tile
inputs are sized by ``PrecisionPolicy.records``:

  * coordinates stream as the RAW storage-dtype relative coordinate
    (fp16 — lossless, it IS the RCLL state) plus an int8 stale-cell
    shift; the re-anchor ``rel' = rel + 2·(cell_now − cell_stale)``
    happens in fp32 registers (``tiling.tile_phys_disp_shifted``) — an
    exact decode at 3 bytes/axis instead of a pre-shifted fp32
    coordinate's 4;
  * v and m stream in the records dtype (fp16/bf16 production, fp32
    oracle) and upcast to fp32 in-register;
  * the density tier streams fp32 as the RECIPROCAL 1/ρ (full fp32
    density information, one reciprocal per particle at pack time):
    p/ρ² is recomputed division-free in-register through the scheme's
    EOS (``Scheme.por2_inv`` — linear or Tait) and the viscosity
    ρ-product division disappears — no p/ρ² table, no occupancy table
    (see below). 2-D bytes per slot per tile: 16 vs 32 for PR 2.

The physics terms themselves (EOS, viscosity channels, delta-SPH) come
from the static ``Scheme`` (core/scheme.py) — the same declarative spec
the reference and fused-XLA backends consume, so the kernel cannot
drift from them.

No neighbor list is consumed: the B-spline derivative vanishes
identically beyond the support 2h and at r = 0, so every out-of-support
candidate in the 3^dim neighborhood (and the self pair) contributes an
exact 0.0 — the kernel sums over the full tile and lets compact support
do the masking. Empty slots are killed by m_j = 0 (zero-filled tables;
1/ρ tables are 1/rho0-filled so every factor stays finite and the EOS
decode yields ~0); an occupancy mask adds nothing the m_j
factor and compact support don't already guarantee, so none is streamed.
Garbage accumulated into a vacant SELF slot (i empty, j occupied) is
finite and never read back — ``ops.unpack_per_particle`` gathers
occupied slots only. Consequence: the fused kernel never truncates at
K — it sees every in-support pair even where the K-compacted list would
overflow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import bspline
from repro.core import scheme as scheme_lib
from repro.kernels import tiling

Array = jnp.ndarray


def _force_kernel(
    # scalar prefetch
    nb_ref,
    # inputs
    off_ref,  # (1, d) neighborhood offset for this k
    rel_i_ref,  # (1, d, cap) self cell (raw storage-dtype rel)
    rel_j_ref,  # (1, d, cap) neighbor cell
    shift_i_ref,  # (1, d, cap) int8 stale-cell shift
    shift_j_ref,  # (1, d, cap)
    v_i_ref,  # (1, d, cap) records dtype
    v_j_ref,  # (1, d, cap)
    m_j_ref,  # (1, cap) records dtype (0 in empty slots)
    inv_i_ref,  # (1, cap) f32 reciprocal density (1/rho0 in empty slots)
    inv_j_ref,  # (1, cap) f32
    # outputs (indexed by c only -> accumulated across the k axis)
    drho_ref,  # (1, cap) f32
    acc_ref,  # (1, d, cap) f32
    *,
    hc_phys: tuple,
    h: float,
    dim: int,
    scheme: scheme_lib.Scheme,
):
    _, k = pl.program_id(0), pl.program_id(1)
    d = rel_i_ref.shape[1]

    @pl.when(k == 0)
    def _init():
        drho_ref[...] = jnp.zeros_like(drho_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    disp, r2 = tiling.tile_phys_disp_shifted(
        rel_i_ref[0], rel_j_ref[0], shift_i_ref[0], shift_j_ref[0],
        off_ref[0], hc_phys,
    )
    coef = bspline.dw_over_r(jnp.sqrt(r2), h, dim)

    mj = m_j_ref[0].astype(jnp.float32)[None, :]
    inv_i = inv_i_ref[0][:, None]
    inv_j = inv_j_ref[0][None, :]
    por2_i = scheme.por2_inv(inv_i_ref[0])
    por2_j = scheme.por2_inv(inv_j_ref[0])
    # Pair velocity deltas and dv·disp first: the scheme's ∇W-channel
    # coefficient (pressure + optional artificial viscosity) needs the
    # full dot product before the per-axis accumulation loop.
    dv = [
        v_i_ref[0, a].astype(jnp.float32)[:, None]
        - v_j_ref[0, a].astype(jnp.float32)[None, :]
        for a in range(d)
    ]
    dv_dot_disp = jnp.zeros_like(r2)
    for a in range(d):
        dv_dot_disp += dv[a] * disp[a]
    gc = scheme.gradw_pair_coef(
        mj, por2_i[:, None], por2_j[None, :], inv_i, inv_j,
        dv_dot_disp, r2, h=h,
    ) * coef
    if scheme.has_dv_term:
        # x·∇W = coef * Σ disp² = coef * r2 (gw tiles are coef * disp_a).
        vc = scheme.dv_pair_coef(mj, coef * r2, inv_i, inv_j, r2, h=h)
    for a in range(d):
        contrib = -gc * disp[a]
        if scheme.has_dv_term:
            contrib += vc * dv[a]
        acc_ref[0, a] += jnp.sum(contrib, axis=1)
    dterm = mj * coef * dv_dot_disp
    if scheme.has_delta_term:
        dterm += scheme.drho_pair_term(
            mj, inv_i, inv_j, coef * r2, r2, h=h
        )
    drho_ref[...] += jnp.sum(dterm, axis=1)[None]


def _cell_block(d, cap):
    return pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0))


def _nbcell_block(d, cap):
    return pl.BlockSpec((1, d, cap), lambda c, k, nb: (nb[c, k], 0, 0))


def _cell_row(cap):
    return pl.BlockSpec((1, cap), lambda c, k, nb: (c, 0))


def _nbcell_row(cap):
    return pl.BlockSpec((1, cap), lambda c, k, nb: (nb[c, k], 0))


@functools.partial(
    jax.jit,
    static_argnames=(
        "offs", "hc_phys", "h", "dim", "scheme", "interpret"
    ),
)
def rcll_force(
    rel: Array,  # (C, d, cap) raw storage-dtype relative coords
    shift: Array,  # (C, d, cap) int8 cell shift (cell_now - cell_stale)
    v: Array,  # (C, d, cap) records dtype
    m: Array,  # (C, cap) records dtype, 0 in empty slots
    inv_rho: Array,  # (C, cap) f32 reciprocal density, 1/rho0 in empty slots
    nb_ids: Array,  # (C, M) int32
    *,
    offs: tuple,  # M x d neighborhood offsets (static)
    hc_phys: tuple,  # (d,) physical cell edges (static)
    h: float,
    dim: int,
    scheme: scheme_lib.Scheme,
    interpret: bool = True,
) -> tuple[Array, Array]:
    """Fused SPH RHS: (drho (C, cap), acc (C, d, cap)), one tile pass.

    The physics terms (EOS, viscosity channels) come from the static
    ``scheme`` — the same declarative spec the XLA and reference
    backends consume (core/scheme.py).
    """
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    offs_arr = jnp.asarray(np.asarray(offs, np.float32).reshape(M, d))
    kernel = functools.partial(
        _force_kernel,
        hc_phys=tuple(float(x) for x in hc_phys),
        h=float(h),
        dim=int(dim),
        scheme=scheme,
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(C, M),
        in_specs=[
            pl.BlockSpec((1, d), lambda c, k, nb: (k, 0)),
            _cell_block(d, cap), _nbcell_block(d, cap),  # rel i, j
            _cell_block(d, cap), _nbcell_block(d, cap),  # shift i, j
            _cell_block(d, cap), _nbcell_block(d, cap),  # v i, j
            _nbcell_row(cap),  # m_j
            _cell_row(cap), _nbcell_row(cap),  # 1/rho i, j
        ],
        out_specs=[
            _cell_row(cap),
            pl.BlockSpec((1, d, cap), lambda c, k, nb: (c, 0, 0)),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((C, cap), jnp.float32),
            jax.ShapeDtypeStruct((C, d, cap), jnp.float32),
        ],
        interpret=interpret,
    )(nb_ids, offs_arr, rel, rel, shift, shift, v, v, m, inv_rho, inv_rho)
