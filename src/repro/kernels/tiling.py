"""Shared cell-pair tile math for the RCLL Pallas kernels.

Every cell-blocked kernel in this package (``nnps_pairwise``,
``sph_gradient``, ``rcll_force``) walks the same structure: grid (C, M),
block (c, k) holding the self cell's (d, cap) coordinate tile and the
k-th neighbor cell's tile (scalar-prefetched ``nb_ids``), with the
neighborhood offset as the exact Eq. (7) integer anchor. These helpers
are that structure's tile math, factored once so a change to the
distance arithmetic or masking cannot diverge between kernels.

All functions are plain jnp on (d, cap)/(cap,) tiles — they trace inside
``pallas_call`` bodies and in the pure-jnp oracles (``kernels/ref.py``)
identically.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

Array = jnp.ndarray


def tile_r2_cell(
    rel_i: Array,  # (d, cap) self-cell relative coords, arithmetic dtype
    rel_j: Array,  # (d, cap) neighbor-cell relative coords
    off_k: Array,  # (d,) neighborhood offset (j_cell - i_cell), f32
    weights: tuple,  # (d,) static anisotropy weights hc_a / hc_ref
    dtype,
) -> Array:
    """Eq. (7) squared distances in reference-cell units, (cap_i, cap_j).

    The NNPS tier: arithmetic runs in ``dtype`` (fp16 paper-faithful /
    fp32 TPU-native). Static unroll over the 2-3 axes.
    """
    d, cap = rel_i.shape
    ri = rel_i.astype(dtype)
    rj = rel_j.astype(dtype)
    d2 = jnp.zeros((cap, cap), dtype)
    for a in range(d):
        du = (ri[a][:, None] - rj[a][None, :]) * dtype(0.5)
        du = (du - off_k[a].astype(dtype)) * dtype(weights[a])
        d2 = d2 + du * du
    return d2


def tile_phys_disp(
    rel_i: Array,  # (d, cap) self-cell relative coords (any float dtype)
    rel_j: Array,  # (d, cap)
    off_k: Array,  # (d,) f32
    hc_phys: tuple,  # (d,) static physical cell edges
) -> tuple[list[Array], Array]:
    """Physics-tier (fp32) pair displacement x_i - x_j per axis.

    Returns (disp [d x (cap_i, cap_j)], r2 (cap_i, cap_j)). The cell
    delta I - J is ``-off_k`` (off is j's offset from i), so the decode
    is ``((rel_i - rel_j)/2 - off) * hc`` — the tile form of
    ``rcll.decode_pair_disp``.
    """
    ri = rel_i.astype(jnp.float32)
    rj = rel_j.astype(jnp.float32)
    d = ri.shape[0]
    disp = []
    r2 = None
    for a in range(d):
        du = (ri[a][:, None] - rj[a][None, :]) * 0.5 - off_k[a]
        dx = du * hc_phys[a]
        disp.append(dx)
        r2 = dx * dx if r2 is None else r2 + dx * dx
    return disp, r2


def tile_phys_disp_shifted(
    rel_i: Array,  # (d, cap) raw storage-dtype relative coords
    rel_j: Array,  # (d, cap)
    shift_i: Array,  # (d, cap) small-int cell shift (cell_now - cell_stale)
    shift_j: Array,  # (d, cap)
    off_k: Array,  # (d,) f32
    hc_phys: tuple,  # (d,) static physical cell edges
) -> tuple[list[Array], Array]:
    """Shift-anchored physics-tier pair displacement x_i - x_j per axis.

    The half-width force kernel streams the RAW fp16 relative coords
    plus an int8 per-particle cell shift instead of a pre-shifted fp32
    coordinate (half the coordinate bytes): the stale-binning re-anchor
    ``rel' = rel + 2 (cell_now - cell_stale)`` happens here in fp32
    registers — the shift is an exact small integer and fp32 addition of
    an fp16 payload and a small integer is exact, so the decode is
    bit-identical to pre-shifting. Everything else matches
    ``tile_phys_disp``.
    """
    d = rel_i.shape[0]
    disp = []
    r2 = None
    for a in range(d):
        ri = rel_i[a].astype(jnp.float32) + 2.0 * shift_i[a].astype(jnp.float32)
        rj = rel_j[a].astype(jnp.float32) + 2.0 * shift_j[a].astype(jnp.float32)
        du = (ri[:, None] - rj[None, :]) * 0.5 - off_k[a]
        dx = du * hc_phys[a]
        disp.append(dx)
        r2 = dx * dx if r2 is None else r2 + dx * dx
    return disp, r2


def tile_occ_pair(occ_i: Array, occ_j: Array) -> Array:
    """(cap_i, cap_j) bool: both slots occupied."""
    return (occ_i[:, None] > 0) & (occ_j[None, :] > 0)


def tile_self_mask(cap: int) -> Array:
    """(cap, cap) bool eye via iota (TPU needs >= 2-D iota)."""
    return jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 0) == \
        jax.lax.broadcasted_iota(jnp.int32, (cap, cap), 1)


def tile_pair_mask(
    occ_i: Array, occ_j: Array, is_self_cell: Array, cap: int
) -> Array:
    """Occupancy mask with the self-pair (same cell, same slot) removed."""
    return tile_occ_pair(occ_i, occ_j) & ~(is_self_cell & tile_self_mask(cap))
