"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function is the semantic ground truth the kernel sweeps in
tests/test_kernels.py assert against. They are written for clarity, not
speed, and share the exact dtype contracts of the kernels.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bspline

Array = jnp.ndarray


# --------------------------------------------------------------------------
# RCLL NNPS adjacency (kernels/nnps_pairwise.py)
# --------------------------------------------------------------------------
def ref_rcll_adjacency(
    rel: Array,  # (C, d, cap) relative coords, storage dtype
    occ: Array,  # (C, cap) {0,1} occupancy
    nb_ids: Array,  # (C, M) int32 neighbor-cell ids
    offs: np.ndarray,  # (M, d) int32 neighborhood offsets (j_cell - i_cell)
    weights: np.ndarray,  # (d,) anisotropy weights
    r_cell: float,  # search radius in reference-cell units
    compute_dtype=jnp.float32,
) -> tuple[Array, Array]:
    """Adjacency per (cell, neighborhood slot): (C, M, cap, cap) {0,1} f32,
    plus per-particle neighbor counts (C, cap) f32.

    adjacency[c, k, a, b] = 1 iff particle (c, a) and particle
    (nb_ids[c,k], b) are neighbors (distance <= r_cell in reference-cell
    units, both slots occupied, not the self-pair).
    """
    C, d, cap = rel.shape
    M = nb_ids.shape[1]
    rel_c = rel.astype(compute_dtype)
    w = jnp.asarray(weights, compute_dtype)
    rel_j = rel_c[nb_ids]  # (C, M, d, cap)
    # du[c,k,a,b,ax] = (rel_i[c,ax,a] - rel_j[c,k,ax,b]) / 2 - offs[k,ax]
    du = (
        rel_c[:, None, :, :, None] - rel_j[:, :, :, None, :]
    ) * 0.5 - jnp.asarray(offs, compute_dtype)[None, :, :, None, None]
    du = du * w[None, None, :, None, None]
    d2 = jnp.sum(du * du, axis=2)  # (C, M, cap, cap)
    ok = d2 <= jnp.asarray(r_cell, compute_dtype) ** 2
    occb = occ.astype(bool)
    ok = ok & occb[:, None, :, None] & occb[nb_ids][:, :, None, :]
    # self-pair: same cell id and same slot index
    same_cell = nb_ids == jnp.arange(C, dtype=nb_ids.dtype)[:, None]
    eye = jnp.eye(cap, dtype=bool)
    ok = ok & ~(same_cell[:, :, None, None] & eye[None, None])
    adj = ok.astype(jnp.float32)
    counts = adj.sum(axis=(1, 3))  # (C, cap)
    return adj, counts


# --------------------------------------------------------------------------
# Fused RCLL NNPS + A5 gradient (kernels/sph_gradient.py)
# --------------------------------------------------------------------------
def ref_rcll_gradient(
    rel: Array,  # (C, d, cap)
    f: Array,  # (C, cap) f32 field values
    occ: Array,  # (C, cap)
    nb_ids: Array,  # (C, M)
    offs: np.ndarray,  # (M, d)
    weights: np.ndarray,  # (d,)
    r_cell: float,
    hc_phys: np.ndarray,  # (d,) physical cell sizes
    h: float,
    dim: int,
    compute_dtype=jnp.float32,
) -> tuple[Array, Array]:
    """Fused neighbor-search + normalized (A5) gradient accumulators.

    Returns (num (C, d, cap), den (C, d, cap)): per-particle numerator
    Σ_j (f_j - f_i) ∂W/∂x_a and denominator Σ_j (x_j - x_i)_a ∂W/∂x_a,
    both in fp32. Gradient = num/den (computed by the caller).
    """
    adj, _ = ref_rcll_adjacency(
        rel, occ, nb_ids, offs, weights, r_cell, compute_dtype
    )
    C, d, cap = rel.shape
    rel32 = rel.astype(jnp.float32)
    rel_j = rel32[nb_ids]  # (C, M, d, cap)
    du = (
        rel32[:, None, :, :, None] - rel_j[:, :, :, None, :]
    ) * 0.5 - jnp.asarray(offs, jnp.float32)[None, :, :, None, None]
    # physical displacement x_i - x_j, per axis: (C, M, d, cap_i, cap_j)
    disp = du * jnp.asarray(hc_phys, jnp.float32)[None, None, :, None, None]
    r = jnp.sqrt(jnp.sum(disp * disp, axis=2))  # (C, M, cap, cap)
    dw = bspline.dw_dr(r, h, dim)
    rsafe = jnp.where(r > 1e-12, r, 1.0)
    gw = (dw / rsafe)[:, :, None] * disp  # (C, M, d, cap_i, cap_j)
    gw = gw * adj[:, :, None]
    fj = f[nb_ids]  # (C, M, cap_j)
    df = fj[:, :, None, :] - f[:, None, :, None]  # (C, M, cap_i, cap_j)
    num = jnp.sum(df[:, :, None] * gw, axis=(1, 4))  # (C, d, cap)
    den = jnp.sum((-disp) * gw, axis=(1, 4))  # (C, d, cap)
    return num, den


# --------------------------------------------------------------------------
# Flash attention (kernels/flash_attention.py)
# --------------------------------------------------------------------------
def ref_attention(
    q: Array,  # (B, H, Lq, Dh)
    k: Array,  # (B, Hkv, Lk, Dh)
    v: Array,  # (B, Hkv, Lk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
) -> Array:
    B, H, Lq, Dh = q.shape
    Hkv = k.shape[1]
    rep = H // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        Lk = k.shape[2]
        mask = jnp.tril(jnp.ones((Lq, Lk), bool), k=Lk - Lq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


# --------------------------------------------------------------------------
# RCLL-KV decode attention (kernels/rcll_kv_attention.py)
# --------------------------------------------------------------------------
def dequant(resid: Array, anchor: Array, scale: Array) -> Array:
    """anchor + scale * residual (int8 residuals span [-127, 127])."""
    if resid.dtype == jnp.int8:
        r = resid.astype(jnp.float32) / 127.0
    else:
        r = resid.astype(jnp.float32)
    return anchor + scale * r


def ref_rcll_kv_decode(
    q: Array,  # (B, H, Dh)
    k_resid: Array,  # (B, Hkv, nblk, blk, Dh) lo dtype
    k_anchor: Array,  # (B, Hkv, nblk, 1, Dh) f32
    k_scale: Array,  # (B, Hkv, nblk, 1, Dh) f32
    v_resid: Array,
    v_anchor: Array,
    v_scale: Array,
    length: Array,  # (B,) int32 valid KV length
    *,
    scale: float | None = None,
) -> Array:
    B, H, Dh = q.shape
    _, Hkv, nblk, blk, _ = k_resid.shape
    kk = dequant(k_resid, k_anchor, k_scale).reshape(B, Hkv, nblk * blk, Dh)
    vv = dequant(v_resid, v_anchor, v_scale).reshape(B, Hkv, nblk * blk, Dh)
    rep = H // Hkv
    kk = jnp.repeat(kk, rep, axis=1)
    vv = jnp.repeat(vv, rep, axis=1)
    sc = scale if scale is not None else 1.0 / np.sqrt(Dh)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kk) * sc
    pos = jnp.arange(nblk * blk)[None, None, :]
    s = jnp.where(pos < length[:, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", p, vv)
