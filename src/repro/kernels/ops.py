"""Public jit'd wrappers for the Pallas kernels + cell-table packing.

The kernels consume *cell-major* dense tables (C+1, d, cap) - the packing
here is the TPU analogue of the paper's particle sort (particles that share
a cell are contiguous; row-major cell order keeps spatial neighbors close
in HBM). Row C is a sentinel empty cell: out-of-domain neighborhood slots
point at it, so the kernels never branch on validity.

``interpret`` defaults to True on CPU (this container) and should be False
on real TPU. All wrappers are shape-polymorphic over (C, cap, d, M).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cells as cells_lib
from repro.core import nnps as nnps_lib
from repro.core import rcll as rcll_lib
from repro.core.domain import Domain
from repro.core.precision import NNPS_STORE
from repro.kernels import nnps_pairwise, rcll_force, sph_gradient

Array = jnp.ndarray


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def nb_with_sentinel(domain: Domain) -> Array:
    """(C+1, M) neighbor-cell ids; the sentinel row points at itself."""
    nb = jnp.asarray(cell_neighbor_ids(domain))
    return jnp.concatenate(
        [nb, jnp.full((1, nb.shape[1]), nb.shape[0], nb.dtype)], axis=0
    )


def _row_table(
    binning: cells_lib.CellBinning, f: Array, fill: float = 0.0
) -> Array:
    """(C+1, cap) f32 cell-major table of a per-particle scalar field.

    ``fill`` value for empty slots and the sentinel row — pass a nonzero
    fill for fields that appear in denominators (e.g. rho) so masked
    pair terms stay an exact 0 instead of 0 * inf = NaN.
    """
    ft = cells_lib.to_cell_major(binning, f.astype(jnp.float32), fill=fill)
    return jnp.concatenate(
        [ft, jnp.full((1, ft.shape[1]), fill, ft.dtype)], axis=0
    )


def cell_neighbor_ids(domain: Domain) -> np.ndarray:
    """(C, M) int32 flat neighbor-cell ids per cell; invalid -> sentinel C.

    Static (host-side numpy): the cell graph depends only on the Domain.
    """
    ncells = np.asarray(domain.ncells)
    C = int(np.prod(ncells))
    dim = domain.dim
    offs = cells_lib.neighbor_cell_offsets(dim)  # (M, d)
    coords = np.stack(
        np.meshgrid(*[np.arange(n) for n in ncells], indexing="ij"), -1
    ).reshape(C, dim)
    nb = coords[:, None, :] + offs[None, :, :]  # (C, M, d)
    per = np.asarray(domain.periodic)
    wrapped = np.where(per, nb % ncells, nb)
    valid = np.all((wrapped >= 0) & (wrapped < ncells), axis=-1)
    clipped = np.clip(wrapped, 0, ncells - 1)
    flat = clipped[..., 0]
    for a in range(1, dim):
        flat = flat * ncells[a] + clipped[..., a]
    return np.where(valid, flat, C).astype(np.int32)


def pack_cells(
    binning: cells_lib.CellBinning,
    rel: Array,  # (N, d) storage dtype
    *fields: Array,  # (N,) f32 each
) -> tuple[Array, Array, list[Array]]:
    """Pack per-particle data into cell-major tables with a sentinel row.

    Thin kernel-facing wrapper over ``cells.to_cell_major``: transposes
    rel to the (C, d, cap) sublane/lane layout and appends the sentinel
    empty-cell row the kernels' neighborhood indexing relies on.

    Returns (rel_table (C+1, d, cap), occ (C+1, cap), field_tables).
    """
    C, cap = binning.table.shape
    d = rel.shape[1]
    occ = (binning.table >= 0).astype(jnp.float32)
    rel_t = cells_lib.to_cell_major(binning, rel).transpose(0, 2, 1)
    rel_t = jnp.concatenate(
        [rel_t, jnp.zeros((1, d, cap), rel_t.dtype)], axis=0
    )
    occ = jnp.concatenate([occ, jnp.zeros((1, cap), occ.dtype)], axis=0)
    packed_fields = []
    for f in fields:
        ft = cells_lib.to_cell_major(binning, f.astype(jnp.float32))
        ft = jnp.concatenate([ft, jnp.zeros((1, cap), ft.dtype)], axis=0)
        packed_fields.append(ft)
    return rel_t, occ, packed_fields


def unpack_per_particle(
    table: Array, binning: cells_lib.CellBinning
) -> Array:
    """Gather per-particle values out of a (C+1, cap, ...) table -> (N, ...).

    Inverse of ``pack_cells`` outputs: drops the sentinel row and gathers
    each particle's slot via ``cells.from_cell_major``.
    """
    return cells_lib.from_cell_major(binning, table[: binning.table.shape[0]])


# --------------------------------------------------------------------------
# RCLL adjacency + neighbor counts (kernel wrapper)
# --------------------------------------------------------------------------
def rcll_adjacency_cells(
    domain: Domain,
    binning: cells_lib.CellBinning,
    rel: Array,  # (N, d) storage dtype
    *,
    compute_dtype=jnp.float32,
    interpret: bool | None = None,
) -> tuple[Array, Array]:
    """Cell-blocked adjacency via the Pallas kernel.

    Returns (adj (C+1, M, cap, cap) f32, counts per particle (N,) f32).
    """
    interpret = default_interpret() if interpret is None else interpret
    rel_t, occ, _ = pack_cells(binning, rel)
    nb = nb_with_sentinel(domain)
    offs = tuple(map(tuple, cells_lib.neighbor_cell_offsets(domain.dim)))
    adj, cnt = nnps_pairwise.rcll_adjacency(
        rel_t,
        occ,
        nb,
        offs=offs,
        weights=tuple(domain.cell_weights),
        r_cell=nnps_lib.rcll_radius_cell_units(domain),
        compute_dtype=compute_dtype,
        interpret=interpret,
    )
    counts = unpack_per_particle(cnt, binning)
    return adj, counts


# --------------------------------------------------------------------------
# RCLL packed neighbor lists (the production neighbor producer)
# --------------------------------------------------------------------------
def rcll_neighbor_lists(
    domain: Domain,
    binning: cells_lib.CellBinning,
    rel: Array,  # (N, d) storage dtype
    *,
    k: int,
    radius_cell: float | None = None,
    nnps_dtype=NNPS_STORE,
    compute_dtype=None,
    interpret: bool | None = None,
) -> nnps_lib.NeighborList:
    """Per-particle neighbor lists via the cell-blocked Pallas kernel.

    Returns a NeighborList whose ids live in the same indexing as the
    entries of ``binning.table`` - with the packed (cell-sorted) binning
    of the persistent pipeline these are packed indices, ready to gather
    from packed per-particle arrays with near-contiguous reads.

    radius_cell: search radius override in reference-cell units (the
    Verlet-skin inflated radius); defaults to the exact support radius.

    compute_dtype defaults to fp32 (TPU-native: fp16 storage upconverted
    by the VPU for free). fp32 arithmetic on fp16-quantized inputs is
    exact through Eq. (7)'s subtract/halve/shift, which makes the kernel
    agree with the jnp fallback bit-for-bit; fp16 arithmetic (the paper's
    A100 mode) can flip exactly-on-boundary pairs between backends.
    """
    interpret = default_interpret() if interpret is None else interpret
    cdt = compute_dtype or jnp.float32
    rel_t, occ, _ = pack_cells(binning, rel.astype(nnps_dtype))
    ids_t = jnp.concatenate(
        [binning.table,
         jnp.full((1, binning.table.shape[1]), -1, jnp.int32)], axis=0
    )
    nb = nb_with_sentinel(domain)
    offs = tuple(map(tuple, cells_lib.neighbor_cell_offsets(domain.dim)))
    if radius_cell is None:
        radius_cell = nnps_lib.rcll_radius_cell_units(domain)
    ids_out, cnt = nnps_pairwise.rcll_neighbor_list_tables(
        rel_t,
        occ,
        ids_t,
        nb,
        offs=offs,
        weights=tuple(domain.cell_weights),
        r_cell=float(radius_cell),
        k_slots=k,
        compute_dtype=cdt,
        interpret=interpret,
    )
    idx = unpack_per_particle(ids_out, binning)  # (N, K)
    mask = idx >= 0
    count = unpack_per_particle(cnt, binning).astype(jnp.int32)
    return nnps_lib.NeighborList(
        idx=jnp.maximum(idx, 0), mask=mask, count=count
    )


# --------------------------------------------------------------------------
# Fused RCLL search + A5 gradient (kernel wrapper)
# --------------------------------------------------------------------------
def rcll_gradient_particles(
    domain: Domain,
    binning: cells_lib.CellBinning,
    rel: Array,  # (N, d)
    f: Array,  # (N,) f32
    *,
    nnps_dtype=NNPS_STORE,
    interpret: bool | None = None,
    eps: float = 1e-12,
) -> Array:
    """Per-particle A5 gradient (N, d) via the fused Pallas kernel."""
    interpret = default_interpret() if interpret is None else interpret
    rel_t, occ, (f_t,) = pack_cells(binning, rel, f)
    nb = nb_with_sentinel(domain)
    offs = tuple(map(tuple, cells_lib.neighbor_cell_offsets(domain.dim)))
    hc_phys = tuple(domain.cell_sizes)
    num, den = sph_gradient.rcll_gradient(
        rel_t,
        f_t,
        occ,
        nb,
        offs=offs,
        weights=tuple(domain.cell_weights),
        r_cell=nnps_lib.rcll_radius_cell_units(domain),
        hc_phys=hc_phys,
        h=domain.h,
        dim=domain.dim,
        nnps_dtype=nnps_dtype,
        interpret=interpret,
    )
    den = jnp.where(jnp.abs(den) > eps, den, jnp.where(den >= 0, eps, -eps))
    grad_t = (num / den).transpose(0, 2, 1)  # (C+1, cap, d)
    return unpack_per_particle(grad_t, binning)


# --------------------------------------------------------------------------
# Fused RCLL force pass (kernels/rcll_force.py wrappers)
# --------------------------------------------------------------------------
def _typed_row_table(
    binning: cells_lib.CellBinning, f: Array, dtype, fill: float = 0.0
) -> Array:
    """(C+1, cap) cell-major table of a per-particle scalar at ``dtype``."""
    ft = cells_lib.to_cell_major(binning, f.astype(dtype), fill=fill)
    return jnp.concatenate(
        [ft, jnp.full((1, ft.shape[1]), fill, ft.dtype)], axis=0
    )


def mass_table(
    binning: cells_lib.CellBinning,
    m: Array,
    records_dtype,
    m_scale: Array | None = None,
) -> Array:
    """(C+1, cap) static cell-major mass table for the force kernel.

    Masses never change during a run, so the persistent solver builds
    this once per REBUILD (packed order changes there) instead of once
    per step; half-width layouts store ``m / m_scale``
    (``fused.mass_scale`` — see the subnormal-mass note there).
    """
    from repro.core import fused

    half = jnp.dtype(records_dtype).itemsize == 2
    if half:
        if m_scale is None:
            m_scale = fused.mass_scale(m)
        m = m.astype(jnp.float32) / m_scale
    return _typed_row_table(binning, m, records_dtype)


def rcll_force_particles(
    domain: Domain,
    binning: cells_lib.CellBinning,
    rc: "rcll_lib.RCLLState",  # CURRENT state, packed indexing
    v: Array,  # (N, d) f32
    m: Array,  # (N,) f32
    rho: Array,  # (N,) f32 current density
    *,
    mu: float = 0.0,
    c0: float | None = None,
    rho0: float = 1.0,
    records_dtype=jnp.float32,
    interpret: bool | None = None,
    scheme=None,
    m_scale: Array | None = None,
    m_table: Array | None = None,
) -> tuple[Array, Array]:
    """The full SPH pair RHS via the fused Pallas kernel.

    Returns (drho (N,), acc (N, d)); body force / wall-particle masking
    are per-particle terms applied by the caller. The physics terms come
    from the static ``scheme`` (core/scheme.py) — the legacy
    ``c0``/``rho0``/``mu`` kwargs build the WCSPH scheme (linear Tait +
    Morris) when ``scheme`` is omitted. Pressure is derived in-kernel
    from the streamed reciprocal density — no p/ρ² table.

    ``records_dtype`` is the storage dtype of the v/m tile streams
    (``PrecisionPolicy.records``): fp16/bf16 is the half-width
    production layout, fp32 the accuracy oracle. The coordinate tiles
    always stream the raw storage-dtype rel (lossless).

    REQUIRES the persistent pipeline's PACKED binning (the per-particle
    arrays are cell-sorted and ``binning.table`` holds consecutive
    packed ids): the cell-major tiles are then contiguous row slices,
    built by the one-sweep cell-pack kernel (``kernels/cell_pack.py``)
    from two record slabs — one 16-bit row ``[rel | shift | v]`` and
    one fp32 row ``[1/ρ]`` — instead of one id-table gather per field.
    ``m_table``/``m_scale``: optionally precomputed static mass tile
    (:func:`mass_table`) — the solver rebuilds it only when the packed
    order changes, so the per-step refresh touches exactly the
    coordinate/velocity/density halves.

    Between Verlet-skin rebuilds the binning is STALE: a particle may
    have migrated to an adjacent cell while still occupying its old slot.
    The decode stays exact by streaming the small-int cell shift
    cell_now - cell_stale (minimum-image wrapped) next to the raw rel
    and re-anchoring rel' = rel + 2·shift in fp32 registers — the shift
    is an exact small integer, so rel' decodes to the identical fp32
    position, and the skin invariant (drift <= skin/2 <= half a cell)
    keeps every true pair within the stale 3^dim neighborhood.
    """
    from repro.core import fused  # shared mass normalizer
    from repro.core import scheme as scheme_lib
    from repro.kernels import cell_pack

    if scheme is None:
        if c0 is None:
            raise ValueError("pass either scheme= or the legacy c0=")
        scheme = scheme_lib.wcsph(c0, rho0, mu)
    interpret = default_interpret() if interpret is None else interpret
    d = rc.rel.shape[1]
    delta = domain.wrap_cell_delta(rc.cell_xy - binning.cell_xy)
    half = jnp.dtype(records_dtype).itemsize == 2
    if not half:
        m_scale = jnp.float32(1.0)
    elif m_scale is None:
        m_scale = fused.mass_scale(m)
    if m_table is None:
        m_table = mass_table(binning, m, records_dtype, m_scale)

    def u16(x):
        return jax.lax.bitcast_convert_type(x, jnp.uint16)

    # One 16-bit record slab + one fp32 slab: the dynamic halves of the
    # step, packed cell-major in ONE sweep (contiguous slices — the
    # arrays are cell-sorted). Each field rides the slab of its OWN
    # storage width: rel keeps its raw storage bits (fp16/bf16 in the
    # 16-bit slab, fp32-coords policies like APPROACH_I in the fp32
    # slab — never quantized), shift is always an exact small int16,
    # v follows the records dtype.
    rel_half = jnp.dtype(rc.rel.dtype).itemsize == 2
    cols16 = [u16(delta.astype(jnp.int16))]
    cols32 = [(1.0 / rho).astype(jnp.float32)[:, None]]
    fill32 = [1.0 / scheme.rho0]
    if rel_half:
        cols16.insert(0, u16(rc.rel))
    else:
        cols32.append(rc.rel.astype(jnp.float32))
        fill32 += [0.0] * d
    if half:
        cols16.append(u16(v.astype(records_dtype)))
    else:
        cols32.append(v.astype(jnp.float32))
        fill32 += [0.0] * d
    starts = cells_lib.exclusive_cumsum(binning.counts)
    t16, t32, _ = cell_pack.cell_tables(
        jnp.concatenate(cols16, axis=1),
        jnp.concatenate(cols32, axis=1),
        starts,
        binning.counts,
        jnp.asarray(fill32, jnp.float32),
        cap=binning.table.shape[1],
        interpret=interpret,
    )
    o16 = d if rel_half else 0  # 16-bit slab offset past rel
    o32 = 1 + (0 if rel_half else d)  # fp32 slab offset past inv, rel
    if rel_half:
        rel_t = jax.lax.bitcast_convert_type(t16[:, :d], rc.rel.dtype)
    else:
        rel_t = t32[:, 1:1 + d]
    shift_t = jax.lax.bitcast_convert_type(t16[:, o16:o16 + d], jnp.int16)
    if half:
        v_t = jax.lax.bitcast_convert_type(
            t16[:, o16 + d:o16 + 2 * d], records_dtype
        )
    else:
        v_t = t32[:, o32:o32 + d]
    inv_t = t32[:, 0]
    m_t = m_table
    offs = tuple(map(tuple, cells_lib.neighbor_cell_offsets(domain.dim)))
    drho_t, acc_t = rcll_force.rcll_force(
        rel_t, shift_t, v_t, m_t, inv_t, nb_with_sentinel(domain),
        offs=offs,
        hc_phys=tuple(domain.cell_sizes),
        h=domain.h,
        dim=domain.dim,
        scheme=scheme,
        interpret=interpret,
    )
    drho = unpack_per_particle(drho_t, binning) * m_scale
    acc = unpack_per_particle(acc_t.transpose(0, 2, 1), binning) * m_scale
    return drho, acc
