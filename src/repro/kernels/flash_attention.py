"""Pallas TPU kernel: blocked (flash) causal attention for prefill.

Standard online-softmax tiling: grid (B*H, nQ, nK); one (bq, dh) query
tile revisits its output block across the nK inner steps, carrying running
max/denominator in VMEM scratch. GQA is handled in the K/V index maps
(query head h reads kv head h // (H/Hkv)) - no materialized repeat.

The causal mask is applied elementwise inside the tile; fully-masked K
tiles (ik*bk > (iq+1)*bq) still run - acceptable for the CPU-validated
target kernel, and noted as a skip-block optimization in EXPERIMENTS.md.

This kernel exists for the LM substrate of the assigned architectures;
the models default to the XLA path (attention_impl='xla') and switch to
this kernel on real TPU hardware.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

Array = jnp.ndarray

NEG_INF = -1e30


def _flash_kernel(
    q_ref,  # (1, bq, dh)
    k_ref,  # (1, bk, dh)
    v_ref,  # (1, bk, dh)
    o_ref,  # (1, bq, dh)
    m_ref,  # (bq, 1) scratch
    l_ref,  # (bq, 1) scratch
    acc_ref,  # (bq, dh) scratch
    *,
    scale: float,
    causal: bool,
    bq: int,
    bk: int,
    nk: int,
):
    iq, ik = pl.program_id(1), pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)  # (bq, dh)
    k = k_ref[0].astype(jnp.float32)  # (bk, dh)
    v = v_ref[0].astype(jnp.float32)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale  # (bq, bk)

    if causal:
        rows = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        cols = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        s = jnp.where(rows >= cols, s, NEG_INF)

    m_prev = m_ref[...]  # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    # guard: a fully-masked row keeps m at NEG_INF; exp(s - m) must be 0
    p = jnp.where(s > NEG_INF / 2, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: Array,  # (B, H, Lq, Dh)
    k: Array,  # (B, Hkv, Lk, Dh)
    v: Array,  # (B, Hkv, Lk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> Array:
    B, H, Lq, Dh = q.shape
    _, Hkv, Lk, _ = k.shape
    rep = H // Hkv
    scale = float(scale if scale is not None else 1.0 / np.sqrt(Dh))
    bq, bk = min(block_q, Lq), min(block_k, Lk)
    assert Lq % bq == 0 and Lk % bk == 0, (Lq, bq, Lk, bk)
    nq, nk = Lq // bq, Lk // bk

    qr = q.reshape(B * H, Lq, Dh)
    kr = k.reshape(B * Hkv, Lk, Dh)
    vr = v.reshape(B * Hkv, Lk, Dh)

    def kv_index(bh, iq, ik):
        b, h = bh // H, bh % H
        return (b * Hkv + h // rep, ik, 0)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, bq=bq, bk=bk, nk=nk
    )
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bk, Dh), kv_index),
            pl.BlockSpec((1, bk, Dh), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Lq, Dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(B, H, Lq, Dh)
