"""Shared benchmark helpers: timing + CSV emission.

CPU wall-times here are a *proxy* (the paper's hardware is an A100; our
deployment target is TPU v5e via the dry-run/roofline). What transfers
from CPU measurement: algorithmic scaling (O(N^2) vs O(N)), precision
byte-traffic ratios, and layout/locality effects. Absolute speedups
belong to the roofline analysis in EXPERIMENTS.md.
"""
from __future__ import annotations

import time

import jax


def time_fn(fn, *args, warmup: int = 1, repeats: int = 3) -> float:
    """Best-of wall time in seconds for a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best


def emit(table: str, row: dict):
    """One CSV-ish line: `table,key=value,...` (greppable, diffable)."""
    body = ",".join(f"{k}={v}" for k, v in row.items())
    print(f"{table},{body}", flush=True)
