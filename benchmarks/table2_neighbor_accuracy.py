"""Paper Table 2: % incorrect neighbor determinations vs particle
spacing, for absolute-coordinate fp16 (all-list == link-list) vs RCLL.

Two protocols reported (DESIGN.md):
  orig   - truth = fp32 determinations on the ORIGINAL coordinates
           (includes fp16 storage quantization, the paper's framing);
  stored - truth = fp32 determinations on the STORED coordinates (in
           approach III the stored state IS the position; this isolates
           arithmetic error and is exactly 0 in the TPU-native
           fp16-storage/fp32-compute mode).

Default sizes are scaled to CPU time; --full sweeps down to ds=5e-4
(N=4e6 equivalent via the elongated-domain construction).
"""
import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit
from repro.core import domain as D, nnps, rcll


def cell_counts(dom, xn, dtype, k):
    return nnps.cell_list_neighbors(dom, xn, dtype=dtype, k=k)


def main(full: bool = False):
    rng = np.random.default_rng(0)
    # unit square, N = 1/ds^2 (paper's construction), capped for CPU
    ds_list = (0.01, 0.005, 0.002) + ((0.00125, 0.001) if full else ())
    k = 64
    for ds in ds_list:
        n = int(round(1.0 / ds**2))
        dom = D.unit_square(h=1.2 * ds)
        x = rng.uniform(0, 1, (n, 2))
        xn = dom.normalize(jnp.asarray(x))
        truth = cell_counts(dom, xn, jnp.float32, k)
        total = int(jnp.sum(truth.count))
        abs16 = cell_counts(dom, xn, jnp.float16, k)
        st = rcll.init_state(dom, xn, dtype=jnp.float16)
        rcll16 = nnps.rcll_neighbors(dom, st.rel, st.cell_xy,
                                     dtype=jnp.float16, k=k)
        rcll16_f32c = nnps.rcll_neighbors(dom, st.rel, st.cell_xy,
                                          dtype=jnp.float16,
                                          compute_dtype=jnp.float32, k=k)
        xq = rcll.to_normalized(dom, st)
        truth_stored = cell_counts(dom, xq, jnp.float32, k)
        wrong = lambda t, a: 100.0 * int(
            nnps.count_wrong_determinations(t, a)) / max(total, 1)
        emit("table2_accuracy", {
            "ds": ds, "n": n,
            "abs_fp16_pct": round(wrong(truth, abs16), 4),
            "rcll_fp16_pct": round(wrong(truth, rcll16), 4),
            "rcll_fp16_stored_pct": round(
                wrong(truth_stored, rcll16), 4),
            "rcll_fp16_f32compute_stored_pct": round(
                wrong(truth_stored, rcll16_f32c), 4),
        })
    # elongated domain: same normalized spacing as the paper's finest
    # rows without 1e6 particles (ds/h_d = 1.25e-4 ~ paper ds=2.5e-4)
    for span in (40.0, 160.0):
        n = 4000
        ds = 0.02
        dom = D.Domain(lo=(0.0, 0.0), hi=(span, 1.0), h=1.2 * ds)
        x = np.stack([rng.uniform(0, span, n), rng.uniform(0, 1, n)], -1)
        xn = dom.normalize(jnp.asarray(x))
        truth = cell_counts(dom, xn, jnp.float32, k)
        total = int(jnp.sum(truth.count))
        abs16 = cell_counts(dom, xn, jnp.float16, k)
        st = rcll.init_state(dom, xn, dtype=jnp.float16)
        rcll16 = nnps.rcll_neighbors(dom, st.rel, st.cell_xy,
                                     dtype=jnp.float16,
                                     compute_dtype=jnp.float32, k=k)
        emit("table2_accuracy_elongated", {
            "ds_over_hd": ds / span, "n": n,
            "abs_fp16_pct": round(100.0 * int(
                nnps.count_wrong_determinations(truth, abs16))
                / max(total, 1), 3),
            "rcll_fp16_pct": round(100.0 * int(
                nnps.count_wrong_determinations(truth, rcll16))
                / max(total, 1), 4),
        })


if __name__ == "__main__":
    main()
