"""Serve endpoint latency/throughput under concurrent load.

Starts an in-process :class:`SimServer`, prewarms the dam-break bucket
(the compile is paid before measurement — a real deployment serves
long after its first request), then fires ``--concurrency`` dam-break
requests from a thread pool over REAL sockets and measures per-request
wall latency from connect to terminal frame.

Reported: p50/p95 latency (ms), completed sims/sec over the whole
burst, and the completed/rejected split. The queue is sized to hold the
full burst so the latency distribution measures the ENGINE (lane
admission + block batching), not deliberate load-shedding; the
``--shed`` flag flips that to a small queue to exercise the REJECTED
path instead.

Appends a ``label: "serve"`` record to BENCH_nnps.json — the ROADMAP
item 2 deliverable (~100 concurrent dam-break requests with p50/p95
latency and sims/sec on record; ``compare_bench`` flags p95 rises and
sims/sec drops beyond its threshold).

``--chaos`` adds a crash-recovery row: a multi-process
:class:`FrontendServer` with ``chaos="kill"`` SIGKILLs its own engine
worker mid-request and the row records ``recovery_s`` — kill to first
post-restart OBS frame (worker respawn + recompile + checkpoint
resume). ``compare_bench`` watches it like a latency: a rise beyond the
threshold is flagged.

  PYTHONPATH=src python -m benchmarks.serve_latency [--quick] [--chaos]
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import tempfile
import time
from concurrent.futures import ThreadPoolExecutor

import jax

from benchmarks._util import emit
from benchmarks.nnps_throughput import _append_record
from repro.core import recovery
from repro.sph import client
from repro.sph.serve import SimServer

CASE = "dam_break"
N_TARGET = 300
NSTEPS = 64
BLOCK = 32


def _fire(port: int, i: int, nsteps: int) -> tuple[str, float]:
    t0 = time.perf_counter()
    _, term = client.run_request(
        "127.0.0.1", port, {"case": CASE, "n": N_TARGET,
                            "nsteps": nsteps, "request_id": f"bench{i}"},
        timeout=600.0)
    return (term["type"] if term else "dead",
            time.perf_counter() - t0)


def _pct(sorted_vals: list[float], q: float) -> float:
    if not sorted_vals:
        return float("nan")
    idx = min(len(sorted_vals) - 1, round(q * (len(sorted_vals) - 1)))
    return sorted_vals[idx]


def run_burst(concurrency: int, slots: int, nsteps: int,
              shed: bool = False) -> dict:
    queue = slots if shed else max(concurrency, 1)
    policy = recovery.GuardPolicy(block=BLOCK, snapshot_every=1)
    srv = SimServer(slots=slots, queue=queue, policy=policy)
    srv.prewarm(CASE, n=N_TARGET)  # before start(): compile off-clock
    srv.start()
    try:
        t0 = time.perf_counter()
        with ThreadPoolExecutor(max_workers=min(64, concurrency)) as pool:
            outcomes = list(pool.map(
                lambda i: _fire(srv.port, i, nsteps), range(concurrency)))
        wall = time.perf_counter() - t0
    finally:
        srv.request_drain()
        srv.join(30)
    lat = sorted(t for kind, t in outcomes if kind == "done")
    completed = len(lat)
    rejected = sum(1 for kind, _ in outcomes if kind == "rejected")
    row = {
        "case": CASE,
        "n_target": N_TARGET,
        "backend": "xla",
        "records": "fp16",
        "nsteps": nsteps,
        "block": BLOCK,
        "concurrency": concurrency,
        "slots": slots,
        "queue": queue,
        "completed": completed,
        "rejected": rejected,
        "other": concurrency - completed - rejected,
        "p50_latency_ms": round(1e3 * _pct(lat, 0.50), 1),
        "p95_latency_ms": round(1e3 * _pct(lat, 0.95), 1),
        "sims_per_sec": round(completed / wall, 4) if wall else 0.0,
        "wall_s": round(wall, 3),
    }
    emit("serve_latency", row)
    return row


def run_chaos(slots: int = 2, nsteps: int = 96) -> dict:
    """One request against a multi-process server whose supervisor
    SIGKILLs the engine worker after its second block; the row's
    ``recovery_s`` is kill -> first post-restart OBS (respawn +
    recompile + checkpoint resume)."""
    from repro.sph.supervisor import FrontendServer

    block = 8  # fine-grained blocks: the kill lands mid-request
    policy = recovery.GuardPolicy(block=block, snapshot_every=1)
    ckdir = tempfile.mkdtemp(prefix="bench-chaos-")
    srv = FrontendServer(slots=slots, queue=8, policy=policy,
                         checkpoint_dir=ckdir, chaos="kill")
    try:
        srv.prewarm(CASE, n=N_TARGET)  # first compile off-clock
        srv.start()
        t0 = time.perf_counter()
        frames, term = client.run_request(
            "127.0.0.1", srv.port,
            {"case": CASE, "n": N_TARGET, "nsteps": nsteps,
             "observe": True}, timeout=600.0)
        wall = time.perf_counter() - t0
        stats = srv.stats()
    finally:
        srv.request_drain()
        srv.join(60)
        shutil.rmtree(ckdir, ignore_errors=True)
    done = term is not None and term["type"] == "done"
    recovered = [f for f in frames
                 if f.get("action") == "recovering"]
    if not (done and recovered and stats["recovery_s"]):
        raise RuntimeError(
            f"chaos row is meaningless: done={done} "
            f"recovering_events={len(recovered)} "
            f"recovery_s={stats['recovery_s']}")
    row = {
        "case": CASE,
        "n_target": N_TARGET,
        "backend": "xla",
        "records": "fp16",
        "nsteps": nsteps,
        "block": block,
        "concurrency": 1,
        "slots": slots,
        "queue": 8,
        "completed": 1,
        "rejected": 0,
        "other": 0,
        "chaos": "kill",
        "worker_restarts": stats["worker_restarts"],
        "p50_latency_ms": round(1e3 * wall, 1),
        "p95_latency_ms": round(1e3 * wall, 1),
        "sims_per_sec": round(1.0 / wall, 4),
        "wall_s": round(wall, 3),
        "recovery_s": round(stats["recovery_s"], 3),
    }
    emit("serve_latency", row)
    return row


def main(full: bool = True, append: bool = True, out: str | None = None,
         chaos: bool = False):
    tiers = [(100, 8)] if full else [(12, 4)]
    rows = [run_burst(conc, slots, NSTEPS) for conc, slots in tiers]
    if chaos:
        rows.append(run_chaos())
    record = {
        "label": "serve",
        "case": CASE,
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "cases": rows,
    }
    if append:
        _append_record(record)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="12 concurrent requests instead of 100")
    ap.add_argument("--no-append", action="store_true",
                    help="do not append to BENCH_nnps.json")
    ap.add_argument("--out", type=str, default=None,
                    help="also write the record to a standalone file")
    ap.add_argument("--chaos", action="store_true",
                    help="add a worker-kill recovery row (recovery_s: "
                    "SIGKILL to first post-restart OBS)")
    a = ap.parse_args()
    main(full=not a.quick, append=not a.no_append, out=a.out,
         chaos=a.chaos)
