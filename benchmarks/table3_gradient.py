"""Paper Table 3: RMSE of the SPH gradient of f=x^3 under fp64/fp16
NNPS across algorithms - FP16 neighbor lists do not degrade the
1st-order gradient."""
import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit
from repro.core import cases, nnps, rcll, sph


def main(full: bool = False):
    k = 64
    ds_list = (0.01, 0.005) + ((0.002,) if full else ())
    for ds in ds_list:
        dom, x = cases.gradient_test_particles(ds, jitter=0.2)
        xn = dom.normalize(jnp.asarray(x))
        f = jnp.asarray(cases.cubic_field(jnp.asarray(x)), jnp.float32)
        want = np.asarray(cases.cubic_gradient_x(jnp.asarray(x)))
        interior = (np.abs(x - 0.5) < 0.5 - 2.5 * dom.h).all(axis=1)
        row = {"ds": ds, "n": x.shape[0]}
        for label, make_nl in (
            ("fp32_cell", lambda: nnps.cell_list_neighbors(
                dom, xn, dtype=jnp.float32, k=k)),
            ("fp16_cell", lambda: nnps.cell_list_neighbors(
                dom, xn, dtype=jnp.float16, k=k)),
            ("fp16_rcll", None),
        ):
            if label == "fp16_rcll":
                st = rcll.init_state(dom, xn, dtype=jnp.float16)
                nl, _ = rcll.neighbors(dom, st, dtype=jnp.float16, k=k)
                disp, r = rcll.pair_displacements(dom, st, nl)
            else:
                nl = make_nl()
                xp = dom.denormalize(xn)
                disp = (xp[:, None, :] - xp[nl.idx])
                r = jnp.sqrt(jnp.sum(disp * disp, axis=-1))
            g = sph.gradient_normalized_pairs(
                f, disp, r, nl.idx, nl.mask, dom.h, 2)[:, 0]
            rmse = float(np.sqrt(np.mean(
                (np.asarray(g)[interior] - want[interior]) ** 2)))
            row[label] = f"{rmse:.3e}"
        emit("table3_gradient", row)


if __name__ == "__main__":
    main()
