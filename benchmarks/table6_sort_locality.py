"""Paper Table 6 / Fig 16: memory-layout effects.

(a) spatially sorted vs shuffled particle order for the cell-list
    search (the paper's Thrust-sort 2.7x; CPU caches show the same
    direction), and
(b) fused search+gradient vs two-pass (the beyond-paper fusion - the
    intermediate neighbor list never touches memory).
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import cells, domain as D, nnps, rcll, sph
from repro.kernels import ops


def main(full: bool = False):
    rng = np.random.default_rng(0)
    n = 64000 if full else 16000
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn_shuffled = dom.normalize(jnp.asarray(x))
    # spatially sorted order (the binning order IS the paper's sort)
    b0 = cells.bin_particles(dom, xn_shuffled,
                             cells.default_capacity(dom, n))
    xn_sorted = xn_shuffled[b0.order]
    k = 64
    f = jax.jit(lambda z: nnps.cell_list_neighbors(
        dom, z, dtype=jnp.float32, k=k).count)
    t_shuf = time_fn(f, xn_shuffled)
    t_sort = time_fn(f, xn_sorted)
    emit("table6_sort_locality", {
        "n": n, "unsorted_s": f"{t_shuf:.4f}", "sorted_s": f"{t_sort:.4f}",
        "speedup": f"{t_shuf / t_sort:.2f}"})

    # fused vs two-pass gradient (interpret-mode kernels; ratio only)
    n2 = 4000
    ds2 = (1.0 / n2) ** 0.5
    dom2 = D.unit_square(h=1.2 * ds2)
    x2 = rng.uniform(0, 1, (n2, 2))
    xn2 = dom2.normalize(jnp.asarray(x2))
    st = rcll.init_state(dom2, xn2, dtype=jnp.float16)
    b = cells.bin_by_cell_id(dom2, dom2.flat_cell_id(st.cell_xy),
                             st.cell_xy, 16)
    fval = jnp.asarray(x2[:, 0] ** 3, jnp.float32)

    def two_pass(rel, cxy, fv):
        nl = nnps.rcll_neighbors(dom2, rel, cxy, dtype=jnp.float16,
                                 k=48, binning=b)
        disp, r = rcll.pair_displacements(
            dom2, rcll.RCLLState(cxy, rel), nl)
        return sph.gradient_normalized_pairs(fv, disp, r, nl.idx,
                                             nl.mask, dom2.h, 2)

    t_two = time_fn(jax.jit(two_pass), st.rel, st.cell_xy, fval)
    t_fused = time_fn(
        jax.jit(lambda rel, fv: ops.rcll_gradient_particles(
            dom2, b, rel, fv, nnps_dtype=jnp.float16, interpret=True)),
        st.rel, fval)
    emit("table6_fusion", {
        "n": n2, "two_pass_s": f"{t_two:.4f}",
        "fused_interpret_s": f"{t_fused:.4f}",
        "note": "interpret-mode kernel; TPU ratio comes from roofline"})


if __name__ == "__main__":
    main()
