"""Schema validation for BENCH_nnps.json run records.

``compare_bench --candidate`` diffs a fresh record against history by
``(case, backend, records, …)`` key — a malformed row (typo'd field,
string-valued metric, missing ``cases`` list) silently matches nothing
and the regression check degrades to "nothing to compare". This module
makes that failure LOUD: :func:`validate_record` returns a list of
human-readable problems and compare_bench exits 2 when a candidate
fails.

Hand-rolled on purpose (stdlib only — no jsonschema dependency) and
deliberately permissive about EXTRA keys: benchmarks grow new columns
every few PRs, and the validator's job is catching malformed rows, not
freezing the schema.
"""
from __future__ import annotations

import numbers

#: Labels a record may carry; absent label means the oldest benchmark
#: (nnps_throughput's "rebuild_round") per compare_bench._label.
KNOWN_LABELS = (
    "rebuild_round", "fused_force", "half_records", "health_guard",
    "ensemble", "serve",
)

#: Per-label REQUIRED per-case-row metrics: the columns compare_bench
#: actually diffs. A row missing its label's metric can never flag a
#: regression, so it is malformed by definition.
ROW_REQUIRED = {
    "rebuild_round": ("steps_per_sec", "nsteps"),
    "fused_force": ("steps_per_sec", "nsteps"),
    "half_records": ("steps_per_sec", "nsteps", "records"),
    "health_guard": ("steps_per_sec", "guarded"),
    "ensemble": ("sims_per_sec", "mode", "batch"),
    "serve": ("sims_per_sec", "p95_latency_ms", "concurrency", "slots"),
}

#: Fields that must be numeric when present, across every label.
NUMERIC_FIELDS = (
    "steps_per_sec", "sims_per_sec", "physics_ms_per_step", "rebuild_ms",
    "p50_latency_ms", "p95_latency_ms", "nsteps", "n_target",
    "n_particles", "max_neighbors", "skin", "skin_frac_hc", "rebuilds",
    "rebuild_frequency", "wall_s", "batch", "block", "concurrency",
    "slots", "queue", "completed", "rejected", "cpu_count",
    "recovery_s", "worker_restarts",
    "hbm_model_bytes_per_step_gather", "hbm_model_bytes_per_step_fused",
)

#: Throughput/latency metrics that must additionally be positive.
POSITIVE_FIELDS = ("steps_per_sec", "sims_per_sec", "p95_latency_ms",
                   "nsteps", "recovery_s")


def _is_num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def validate_row(row, label: str, where: str) -> list[str]:
    problems: list[str] = []
    if not isinstance(row, dict):
        return [f"{where}: case row is {type(row).__name__}, not an object"]
    for field in ROW_REQUIRED.get(label, ()):
        if field not in row:
            problems.append(
                f"{where}: {label!r} row missing required field "
                f"{field!r}"
            )
    for field in NUMERIC_FIELDS:
        if field in row and not _is_num(row[field]):
            problems.append(
                f"{where}: field {field!r} must be numeric, got "
                f"{type(row[field]).__name__} ({row[field]!r})"
            )
    for field in POSITIVE_FIELDS:
        if field in row and _is_num(row[field]) and row[field] <= 0:
            problems.append(
                f"{where}: field {field!r} must be positive, got "
                f"{row[field]!r}"
            )
    if "backend" in row and not isinstance(row["backend"], str):
        problems.append(f"{where}: 'backend' must be a string")
    if "case" in row and row["case"] is not None \
            and not isinstance(row["case"], str):
        problems.append(f"{where}: 'case' must be a string")
    return problems


def validate_record(record, where: str = "record") -> list[str]:
    """All schema problems in one BENCH run record ([] = valid)."""
    if not isinstance(record, dict):
        return [f"{where}: record is {type(record).__name__}, not an "
                "object"]
    problems: list[str] = []
    label = record.get("label", "rebuild_round")
    if not isinstance(label, str) or label not in KNOWN_LABELS:
        problems.append(
            f"{where}: unknown label {label!r} (known: "
            f"{', '.join(KNOWN_LABELS)})"
        )
        label = "rebuild_round"
    cases = record.get("cases")
    if not isinstance(cases, list) or not cases:
        problems.append(
            f"{where}: 'cases' must be a non-empty list "
            f"(got {type(cases).__name__})"
        )
        cases = []
    for i, row in enumerate(cases):
        problems.extend(validate_row(row, label, f"{where}.cases[{i}]"))
    if label == "health_guard":
        frac = record.get("guard_overhead_frac")
        if frac is not None and (
            not isinstance(frac, dict)
            or not all(_is_num(v) for v in frac.values())
        ):
            problems.append(
                f"{where}: 'guard_overhead_frac' must map tier -> number"
            )
    return problems


def validate_history(history) -> list[str]:
    """Validate a whole BENCH history list."""
    if not isinstance(history, list):
        return [f"history is {type(history).__name__}, not a list"]
    problems: list[str] = []
    for i, rec in enumerate(history):
        problems.extend(validate_record(rec, where=f"history[{i}]"))
    return problems
