"""Benchmark harness entry: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only table2,...]

Output: `table,key=value,...` CSV lines (greppable); EXPERIMENTS.md
quotes these outputs directly.
"""
import argparse
import time
import traceback

from benchmarks import (fig7_scaling, fig13_precision, lm_roofline,
                        nnps_throughput, table1_circle,
                        table2_neighbor_accuracy, table3_gradient,
                        table5_poiseuille, table6_sort_locality)

MODULES = {
    "table1": table1_circle,
    "table2": table2_neighbor_accuracy,
    "table3": table3_gradient,
    "roofline": lm_roofline,
    "fig13": fig13_precision,
    "table6": table6_sort_locality,
    "fig7": fig7_scaling,
    "table5": table5_poiseuille,
    "nnps": nnps_throughput,
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sizes (slow on CPU)")
    ap.add_argument("--only", default="",
                    help="comma-separated module keys")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]
    failures = 0
    for name, mod in MODULES.items():
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"# --- {name} ({mod.__name__}) ---", flush=True)
        try:
            mod.main(full=args.full)
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()}",
                  flush=True)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
