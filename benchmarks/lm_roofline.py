"""Roofline table from the dry-run artifacts (EXPERIMENTS.md section
Roofline reads this output verbatim)."""
import glob
import json
import os

from benchmarks._util import emit


def main(full: bool = False, dryrun_dir: str = "experiments/dryrun"):
    files = sorted(glob.glob(os.path.join(dryrun_dir, "*.json")))
    if not files:
        emit("lm_roofline", {"note": "no dry-run artifacts; run "
                             "python -m repro.launch.dryrun --all first"})
        return
    for path in files:
        with open(path) as f:
            rec = json.load(f)
        if not rec.get("ok"):
            emit("lm_roofline", {"arch": rec["arch"], "shape": rec["shape"],
                                 "mesh": rec["mesh"], "ok": False})
            continue
        uf = rec.get("useful_flops_frac")
        emit("lm_roofline", {
            "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "variant": rec.get("variant", "baseline"),
            "probe": rec.get("probe", "raw"),
            "t_compute_ms": round(rec["t_compute"] * 1e3, 2),
            "t_memory_ms": round(rec["t_memory"] * 1e3, 2),
            "t_collective_ms": round(rec["t_collective"] * 1e3, 2),
            "bottleneck": rec["bottleneck"],
            "useful_flops_frac": round(uf, 3) if uf else None,
        })


if __name__ == "__main__":
    main()
