"""Paper Table 1: incorrect in/out determinations for particles on a
circle of radius 1 disturbed by +-dR, per float precision."""
import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit


def main(full: bool = False):
    rng = np.random.default_rng(7)
    n = 100
    theta = rng.uniform(0, 2 * np.pi, n)
    sign = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0)
    for dr in (1e-1, 1e-2, 1e-3, 1e-4, 1e-6, 1e-8):
        row = {"dR": dr}
        for name, dt in (("fp32", jnp.float32), ("fp16", jnp.float16)):
            r_true = 1.0 + sign * dr
            x = np.stack([r_true * np.cos(theta),
                          r_true * np.sin(theta)], -1)
            xl = jnp.asarray(x, dt)
            d2 = jnp.sum(xl * xl, axis=-1)
            inside = d2 <= jnp.asarray(1.0, dt)
            row[name] = int(jnp.sum(inside != (sign < 0)))
        emit("table1_circle", row)


if __name__ == "__main__":
    main()
