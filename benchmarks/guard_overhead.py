"""Health-guard overhead: guarded vs unguarded steps/sec (8k / 64k).

The guard's cost has three parts, all measured here together as the
end-to-end throughput delta:

  * the fused in-scan health reduction at each block boundary
    (``health.check_carry`` — a handful of O(N) reductions);
  * the per-block host read of the HealthWord scalars (the sync the
    driver pauses at anyway between donated segments);
  * the host snapshot of the carry after each healthy block (the
    rollback point — the dominant term, tunable via
    ``GuardPolicy.snapshot_every``).

Both sides run the SAME segmentation (one donated scan per block) so
the comparison isolates the guard work, not scan-length effects: the
unguarded side chains ``solver.run_persistent`` in ``block``-step
segments; the guarded side is ``recovery.run_guarded`` with the same
block. Appends a ``label: "health_guard"`` record to BENCH_nnps.json;
``compare_bench`` flags these records whenever overhead exceeds 5%.

  PYTHONPATH=src python -m benchmarks.guard_overhead [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from benchmarks.nnps_throughput import _append_record, _build, default_steps
from repro.core import recovery, solver

#: steps per guarded block for the benchmark (the GuardPolicy default).
BLOCK = 32


def _time_unguarded(cfg, st, nsteps: int, block: int) -> float:
    nblocks = max(1, nsteps // block)

    def run_once():
        # same structure as one run_guarded call: eager init + nblocks
        # donated block scans — everything except the guard work. The
        # init carry aliases st.t; sever it so the donated chain leaves
        # ``st`` reusable across timed runs.
        carry = solver.init_persistent(cfg, st)
        carry = carry._replace(
            st=carry.st._replace(t=jnp.copy(carry.st.t))
        )
        for _ in range(nblocks):
            carry = solver.run_persistent(cfg, carry, block)
        return jax.block_until_ready(carry)

    run_once()  # compile
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        run_once()
        times.append(time.perf_counter() - t0)
    return (nblocks * block) / min(times)


def _time_guarded(cfg, st, nsteps: int, block: int) -> float:
    nblocks = max(1, nsteps // block)
    n = nblocks * block
    policy = recovery.GuardPolicy(block=block)
    # one throwaway run pays the compile; timed runs restart from st
    # (run_guarded never donates its ``state`` argument's buffers — it
    # snapshots to host before the first donated block)
    out, _, rep, _ = recovery.run_guarded(cfg, st, block, policy)
    assert not rep.recovered, "benchmark case must be healthy"
    times = []
    for _ in range(3):
        t0 = time.perf_counter()
        out, _, _, _ = recovery.run_guarded(cfg, st, n, policy)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return n / min(times)


def run_tier(n_target: int, nsteps: int) -> list[dict]:
    # Amortize the eager init (inside every run_guarded call, but paid
    # once per RUN, not per block) over enough blocks that the measured
    # delta is the steady per-block guard cost — the number that scales.
    nsteps = max(nsteps, 10 * BLOCK)
    cfg, st, max_neighbors = _build(
        n_target, "xla", skin_frac_hc=0.5, records="fp16"
    )
    st = jax.block_until_ready(solver.simulate(cfg, st, 10))
    rows = []
    sps_plain = _time_unguarded(cfg, st, nsteps, BLOCK)
    sps_guard = _time_guarded(cfg, st, nsteps, BLOCK)
    overhead = sps_plain / sps_guard - 1.0
    for guarded, sps in ((False, sps_plain), (True, sps_guard)):
        rows.append({
            "case": "poiseuille",
            "dynamic": False,
            "guarded": guarded,
            "n_target": n_target,
            "n_particles": int(st.xn.shape[0]),
            "backend": "xla",
            "records": "fp16",
            "skin_frac_hc": 0.5,
            "max_neighbors": max_neighbors,
            "block": BLOCK,
            "nsteps": nsteps,
            "steps_per_sec": round(sps, 3),
        })
    rows[-1]["overhead_frac"] = round(overhead, 4)
    emit("guard_overhead", {
        "n_target": n_target, "unguarded": round(sps_plain, 2),
        "guarded": round(sps_guard, 2), "overhead": round(overhead, 4),
    })
    return rows


def main(full: bool = True, append: bool = True, out: str | None = None):
    targets = [8000, 64000] if full else [8000]
    rows, overhead = [], {}
    for n_target in targets:
        tier = run_tier(n_target, default_steps(n_target))
        rows.extend(tier)
        overhead[str(n_target)] = tier[-1]["overhead_frac"]
    record = {
        "label": "health_guard",
        "case": "poiseuille",
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "cases": rows,
        "guard_overhead_frac": overhead,
    }
    if append:
        _append_record(record)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    emit("guard_overhead_summary", overhead)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="8k only")
    ap.add_argument("--no-append", action="store_true",
                    help="do not append to BENCH_nnps.json")
    ap.add_argument("--out", type=str, default=None,
                    help="also write the record to a standalone file")
    a = ap.parse_args()
    main(full=not a.quick, append=not a.no_append, out=a.out)
