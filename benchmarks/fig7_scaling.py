"""Paper Fig 7: runtime scaling of all-list (O(N^2)) vs cell/RCLL (O(N)).

CPU wall-times (jit, best-of-3) - the scaling exponents and crossover
are the transferable result; absolute times are CPU-proxy (see _util).
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import domain as D, nnps, rcll


def main(full: bool = False):
    rng = np.random.default_rng(0)
    sizes = (1000, 4000, 16000) + ((64000,) if full else ())
    k = 64
    for n in sizes:
        ds = (1.0 / n) ** 0.5
        dom = D.unit_square(h=1.2 * ds)
        x = rng.uniform(0, 1, (n, 2))
        xn = dom.normalize(jnp.asarray(x))
        st = rcll.init_state(dom, xn, dtype=jnp.float16)

        t_all = time_fn(jax.jit(lambda z: nnps.all_list_count(
            z, dom.radius_norm, dtype=jnp.float32)), xn)
        t_cell = time_fn(jax.jit(lambda z: nnps.cell_list_neighbors(
            dom, z, dtype=jnp.float32, k=k).count), xn)
        t_rcll = time_fn(jax.jit(lambda r, c: nnps.rcll_neighbors(
            dom, r, c, dtype=jnp.float16, k=k).count), st.rel, st.cell_xy)
        emit("fig7_scaling", {
            "n": n, "all_list_s": f"{t_all:.4f}",
            "cell_list_s": f"{t_cell:.4f}", "rcll_s": f"{t_rcll:.4f}"})


if __name__ == "__main__":
    main()
