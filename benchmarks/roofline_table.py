"""Render the §Roofline markdown table (+ variant deltas) from the
dry-run artifacts."""
import glob
import json
import os
from collections import defaultdict


def load(dryrun_dir="experiments/dryrun"):
    recs = []
    for f in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def fmt_ms(x):
    return f"{x*1e3:.1f}"


def main(full=False, dryrun_dir="experiments/dryrun"):
    recs = [r for r in load(dryrun_dir) if r.get("ok")]
    base = [r for r in recs
            if r["mesh"] == "16x16" and r.get("variant", "baseline")
            == "baseline"]
    print("| arch | shape | compute ms | memory ms | collective ms |"
          " bottleneck | useful | probe |")
    print("|---|---|---|---|---|---|---|---|")
    for r in sorted(base, key=lambda r: (r["arch"], r["shape"])):
        uf = r.get("useful_flops_frac")
        print(f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute'])} "
              f"| {fmt_ms(r['t_memory'])} | {fmt_ms(r['t_collective'])} "
              f"| {r['bottleneck']} | "
              f"{'' if uf is None else round(uf, 3)} "
              f"| {r.get('probe', 'raw')} |")
    variants = [r for r in recs if r.get("variant", "baseline")
                != "baseline"]
    if variants:
        print("\n| arch | shape | variant | compute ms | memory ms |"
              " collective ms | temp GiB |")
        print("|---|---|---|---|---|---|---|")
        for r in sorted(variants,
                        key=lambda r: (r["arch"], r["shape"],
                                       r["variant"])):
            tmp = r["memory"].get("temp_size_in_bytes", 0) / 2**30
            print(f"| {r['arch']} | {r['shape']} | {r['variant']} "
                  f"| {fmt_ms(r['t_compute'])} | {fmt_ms(r['t_memory'])} "
                  f"| {fmt_ms(r['t_collective'])} | {tmp:.2f} |")


if __name__ == "__main__":
    main()
