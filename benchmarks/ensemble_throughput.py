"""Ensemble throughput: batched sims/sec vs sequential solo runs.

Three modes at N~2k per member, batch sizes 4 and 16:

  * sequential — B solo runs chained one after another (eager init +
    donated block scans each), the baseline a user without the ensemble
    engine pays for a parameter sweep;
  * batched    — one vmapped program stepping all B members together
    (block-entry rebuild + physics scan, NO health work): the raw
    batching win, bounding what the guard may cost;
  * guarded    — the full ``ensemble.run_ensemble`` driver (batched
    health reduction, host snapshots, lane bookkeeping).

Reported per (batch, mode): aggregate member-steps/sec
(``steps_per_sec``, so history tooling applies unchanged) and
``sims_per_sec`` (= B / wall). The record's acceptance numbers:
``speedup_vs_sequential`` (guarded batched aggregate over sequential —
the ISSUE asks >= 4x at batch 16) and ``ensemble_guard_overhead_frac``
(guarded vs batched-unguarded — <= 10%).

Appends a ``label: "ensemble"`` record to BENCH_nnps.json.

  PYTHONPATH=src python -m benchmarks.ensemble_throughput [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._util import emit
from benchmarks.nnps_throughput import _append_record, _build
from repro.core import ensemble, recovery, solver

BLOCK = 32
N_TARGET = 2000
REPS = 2


@partial(jax.jit, static_argnums=(0, 2), donate_argnums=(1,))
def _plain_block(cfg, carry, nsteps: int):
    """One batched UNGUARDED block: the ensemble block's structure
    (hoisted block-entry rebuild + physics scan) minus every piece of
    guard work — no health reduction, no lane masks, no fault hooks."""
    due = jax.vmap(lambda c: solver._needs_rebuild(cfg, c))(carry)
    rebuilt = jax.vmap(lambda c: solver._rebuild(cfg, c))(carry)
    carry = ensemble._select_members(due, rebuilt, carry)

    def body(c, _):
        return jax.vmap(lambda ci: solver._physics_step(cfg, ci))(c), None

    carry, _ = jax.lax.scan(body, carry, None, length=nsteps)
    return carry


def _member_states(cfg, st, B):
    rng = np.random.default_rng(0)
    out = []
    for i in range(B):
        v = np.array(st.fluid.v)
        if i:
            v = v + 1e-3 * rng.standard_normal(v.shape).astype(v.dtype)
        out.append(st._replace(fluid=st.fluid._replace(v=jnp.asarray(v))))
    return out


def _fresh(tree):
    return jax.tree.map(jnp.array, tree)


def _time(fn) -> float:
    fn()  # compile / warm
    times = []
    for _ in range(REPS):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return min(times)


def _run_sequential(cfg, states, nsteps: int):
    nblocks = nsteps // BLOCK
    outs = []
    for s in states:
        # copy: run_persistent donates its carry, whose leaves alias s
        carry = solver.init_persistent(cfg, _fresh(s))
        carry = carry._replace(st=carry.st._replace(t=jnp.copy(carry.st.t)))
        for _ in range(nblocks):
            carry = solver.run_persistent(cfg, carry, BLOCK)
        outs.append(carry)
    return jax.block_until_ready(outs)


def _run_batched(cfg, states, nsteps: int):
    nblocks = nsteps // BLOCK
    carry = ensemble._batch_init(cfg, ensemble.stack_states(states))
    carry = carry._replace(st=carry.st._replace(t=jnp.copy(carry.st.t)))
    for _ in range(nblocks):
        carry = _plain_block(cfg, carry, BLOCK)
    return jax.block_until_ready(carry)


def _run_guarded(cfg, states, nsteps: int, policy):
    outs, _, rep = ensemble.run_ensemble(cfg, states, nsteps, policy)
    assert all(m.status == "healthy" for m in rep.members), \
        "benchmark batch must stay healthy"
    return jax.block_until_ready(outs)


def run_batch(B: int, nsteps: int) -> tuple[list[dict], dict]:
    policy = recovery.GuardPolicy(block=BLOCK)
    cfg, st, max_neighbors = _build(
        N_TARGET, "xla", skin_frac_hc=0.5, records="fp16"
    )
    mcfg = ensemble.member_config(cfg, policy)
    st = jax.block_until_ready(solver.simulate(cfg, st, 10))
    states = _member_states(mcfg, st, B)

    t_seq = _time(lambda: _run_sequential(mcfg, states, nsteps))
    t_bat = _time(lambda: _run_batched(mcfg, states, nsteps))
    t_grd = _time(lambda: _run_guarded(mcfg, states, nsteps, policy))

    rows = []
    for mode, t in (("sequential", t_seq), ("batched", t_bat),
                    ("guarded", t_grd)):
        rows.append({
            "case": "poiseuille",
            "mode": mode,
            "batch": B,
            "guarded": mode == "guarded",
            "n_target": N_TARGET,
            "n_particles": int(st.xn.shape[0]),
            "backend": "xla",
            "records": "fp16",
            "skin_frac_hc": 0.5,
            "max_neighbors": max_neighbors,
            "block": BLOCK,
            "nsteps": nsteps,
            "steps_per_sec": round(B * nsteps / t, 3),  # aggregate
            "sims_per_sec": round(B / t, 4),
        })
    summary = {
        "speedup_vs_sequential": round(t_seq / t_grd, 3),
        "guard_overhead_frac": round(t_grd / t_bat - 1.0, 4),
    }
    emit("ensemble_throughput", {"batch": B, "nsteps": nsteps, **{
        r["mode"]: r["steps_per_sec"] for r in rows}, **summary})
    return rows, summary


def main(full: bool = True, append: bool = True, out: str | None = None):
    batches = (4, 16) if full else (4,)
    nsteps = 5 * BLOCK if full else 2 * BLOCK
    rows, speedup, overhead = [], {}, {}
    for B in batches:
        tier, summary = run_batch(B, nsteps)
        rows.extend(tier)
        speedup[str(B)] = summary["speedup_vs_sequential"]
        overhead[str(B)] = summary["guard_overhead_frac"]
    record = {
        "label": "ensemble",
        "case": "poiseuille",
        "backend": jax.default_backend(),
        "cpu_count": os.cpu_count(),
        "cases": rows,
        "speedup_vs_sequential": speedup,
        "ensemble_guard_overhead_frac": overhead,
    }
    if append:
        _append_record(record)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    emit("ensemble_summary", {
        "speedup_vs_sequential": speedup,
        "guard_overhead_frac": overhead,
    })
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="batch 4 only")
    ap.add_argument("--no-append", action="store_true",
                    help="do not append to BENCH_nnps.json")
    ap.add_argument("--out", type=str, default=None,
                    help="also write the record to a standalone file")
    a = ap.parse_args()
    main(full=not a.quick, append=not a.no_append, out=a.out)
