"""Diff consecutive BENCH_nnps.json run records and flag regressions.

The perf history file accumulates one record per benchmark run, oldest
first — ``nnps_throughput`` records (label "rebuild_round") interleaved
with ``guard_overhead`` records (label "health_guard"). This tool
compares the newest record against the newest EARLIER record of the
same label — or an out-of-history candidate record (``--candidate``,
produced by ``--no-append --out FILE``) against its label's newest
history record — matching cases by (case, dynamic, n_target, backend,
records, skin_frac_hc, guarded) and flagging, beyond ``--threshold``
(default 15%):

  * any steps/sec DROP (for dynamic rows this is the amortized
    physics+rebuild throughput — the metric the steady rows' rebuilds=0
    blind spot cannot see);
  * any rebuild_ms RISE — the rebuild cost is invisible to steady
    steps/sec, which is exactly how it grew 8x steps-worth before the
    rebuild round;
  * for ``serve`` records (serve_latency) any p95_latency_ms RISE or
    completed-sims/sec DROP — service regressions batch throughput
    rows cannot see;
  * for health_guard records additionally the ABSOLUTE bound: guarded
    throughput within ``--guard-limit`` (default 5%) of unguarded at
    every tier — this one needs no history and flags even the first
    record.

Exit status: 1 if any regression was flagged, 2 if a ``--candidate``
record fails ``bench_schema`` validation, else 0. CI runs this as a
NON-blocking step (``continue-on-error``): CPU runner timings are noisy
— the flag is a prompt to look, not a gate.

  PYTHONPATH=src python -m benchmarks.compare_bench
  PYTHONPATH=src python -m benchmarks.compare_bench --candidate smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys

try:
    from benchmarks.bench_schema import validate_record
except ImportError:  # invoked as a script from benchmarks/
    from bench_schema import validate_record


def _case_key(case: dict) -> tuple:
    return (
        # pre-scenario rows were poiseuille (older records carry no
        # "case" key, or an explicit None)
        case.get("case") or "poiseuille",
        bool(case.get("dynamic", False)),
        case.get("n_target"),
        case.get("backend"),
        case.get("records", "fp32"),  # pre-half-record rows were fp32
        case.get("skin_frac_hc"),
        bool(case.get("guarded", False)),  # health_guard A/B rows
        case.get("batch"),  # ensemble rows: batch size axis
        case.get("mode"),  # ensemble rows: sequential/batched/guarded
        case.get("concurrency"),  # serve rows: burst size
        case.get("slots"),  # serve rows: lanes per bucket
        case.get("chaos"),  # serve chaos rows: worker-kill recovery
    )


def _label(record: dict) -> str:
    # pre-label records are all the throughput benchmark's
    return record.get("label", "rebuild_round")


def check_guard_overhead(record: dict, limit: float) -> list:
    """The health_guard records' ABSOLUTE acceptance check: guarded
    throughput must stay within ``limit`` of unguarded at every tier
    (the ISSUE's 5% bound) — no history needed."""
    flagged = []
    for size, frac in (record.get("guard_overhead_frac") or {}).items():
        if frac > limit:
            flagged.append((size, frac))
    return flagged


def _load_history(path: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    return data if isinstance(data, list) else [data]


def compare(old: dict, new: dict, threshold: float) -> tuple[list, list]:
    """Returns (comparison rows, flagged regressions).

    Each comparison row is (key, metric, before, after, change,
    regressed): one row per watched metric — steps/sec (drop is bad;
    amortized throughput for dynamic cases) and rebuild_ms (rise is
    bad).
    """
    old_cases = {_case_key(c): c for c in old.get("cases", [])}
    rows, flagged = [], []
    for case in new.get("cases", []):
        key = _case_key(case)
        prev = old_cases.get(key)
        if prev is None:
            continue
        watched = [("steps/sec", "steps_per_sec", -1.0)]
        if case.get("rebuild_ms") and prev.get("rebuild_ms"):
            watched.append(("rebuild_ms", "rebuild_ms", +1.0))
        if case.get("p95_latency_ms") and prev.get("p95_latency_ms"):
            # serve rows: tail latency RISE and completed-sims/sec DROP
            # are the service regressions steady steps/sec cannot see
            watched.append(("p95_ms", "p95_latency_ms", +1.0))
            watched.append(("sims/sec", "sims_per_sec", -1.0))
        if case.get("recovery_s") and prev.get("recovery_s"):
            # chaos rows: a slower worker-kill -> first-OBS recovery is
            # a regression in the crash-containment path itself
            watched.append(("recovery_s", "recovery_s", +1.0))
        for label, field, bad_sign in watched:
            before, after = prev.get(field), case.get(field)
            if not before or after is None:
                continue
            change = (after - before) / before
            regressed = change * bad_sign > threshold
            rows.append((key, label, before, after, change, regressed))
            if regressed:
                flagged.append((key, label, before, after, change))
    return rows, flagged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default="BENCH_nnps.json",
                    help="perf history file (list of run records)")
    ap.add_argument("--candidate", default=None,
                    help="standalone record to compare against the newest "
                    "history record (else: the two newest history records)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative steps/sec drop that counts as a "
                    "regression (default 0.15)")
    ap.add_argument("--guard-limit", type=float, default=0.05,
                    help="max health-guard overhead (guarded vs "
                    "unguarded steps/sec) before a health_guard record "
                    "is flagged (default 0.05)")
    args = ap.parse_args(argv)

    history = _load_history(args.file)
    if args.candidate:
        with open(args.candidate) as f:
            new = json.load(f)
        problems = validate_record(new, where=args.candidate)
        if problems:
            # a malformed candidate silently matches no history rows and
            # the regression check degrades to a no-op — fail loudly
            print(f"compare_bench: candidate record failed schema "
                  f"validation ({len(problems)} problem(s)):")
            for p in problems:
                print(f"  {p}")
            return 2
        matches = [r for r in history if _label(r) == _label(new)]
    else:
        if len(history) < 2:
            print("compare_bench: fewer than two run records — nothing "
                  "to compare")
            return 0
        new = history[-1]
        matches = [r for r in history[:-1] if _label(r) == _label(new)]

    # health_guard records carry their own absolute acceptance bound
    guard_flagged = []
    if _label(new) == "health_guard":
        guard_flagged = check_guard_overhead(new, args.guard_limit)
        for size, frac in guard_flagged:
            print(f"health_guard n={size}: guarded run is {frac:+.1%} "
                  f"slower than unguarded (limit {args.guard_limit:.0%})"
                  "  << OVERHEAD")

    if not matches:
        # first record of its label: nothing historical to diff against
        print(f"compare_bench: no earlier {_label(new)!r} record — "
              "history comparison skipped")
        if guard_flagged:
            print(f"\n{len(guard_flagged)} tier(s) exceed the guard "
                  "overhead limit")
            return 1
        return 0
    old = matches[-1]

    rows, flagged = compare(old, new, args.threshold)
    if guard_flagged:
        flagged.extend(
            (("health_guard", s), "overhead", 0.0, f, f)
            for s, f in guard_flagged
        )
    if not rows:
        print("compare_bench: no matching cases between the two records "
              "(different sizes/backends) — nothing to compare")
        return 0

    print(f"{'case (name, dyn, n, backend, records, skin)':<52} "
          f"{'metric':>11} {'before':>10} {'after':>10} {'change':>8}")
    for key, label, before, after, change, regressed in rows:
        mark = "  << REGRESSION" if regressed else ""
        print(f"{str(key):<52} {label:>11} {before:>10.3f} "
              f"{after:>10.3f} {change:>+7.1%}{mark}")
    if flagged:
        print(f"\n{len(flagged)} metric(s) regressed more than "
              f"{args.threshold:.0%} (steps/sec drop or rebuild_ms "
              "rise)")
        return 1
    print("\nno steps/sec or rebuild_ms regressions beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
