"""Diff consecutive BENCH_nnps.json run records and flag regressions.

The perf history file accumulates one record per ``nnps_throughput``
run, oldest first. This tool compares the two most recent records —
or an out-of-history candidate record (``--candidate``, produced by
``nnps_throughput --no-append --out FILE``) against the newest history
record — matching cases by (n_target, backend, records, skin_frac_hc)
and flagging every case whose steps/sec dropped by more than
``--threshold`` (default 15%).

Exit status: 1 if any regression was flagged, else 0. CI runs this as a
NON-blocking step (``continue-on-error``): CPU runner timings are noisy
— the flag is a prompt to look, not a gate.

  PYTHONPATH=src python -m benchmarks.compare_bench
  PYTHONPATH=src python -m benchmarks.compare_bench --candidate smoke.json
"""
from __future__ import annotations

import argparse
import json
import sys


def _case_key(case: dict) -> tuple:
    return (
        case.get("case", "poiseuille"),  # pre-scenario rows were poiseuille
        case.get("n_target"),
        case.get("backend"),
        case.get("records", "fp32"),  # pre-half-record rows were fp32
        case.get("skin_frac_hc"),
    )


def _load_history(path: str) -> list[dict]:
    with open(path) as f:
        data = json.load(f)
    return data if isinstance(data, list) else [data]


def compare(old: dict, new: dict, threshold: float) -> tuple[list, list]:
    """Returns (comparison rows, flagged regressions)."""
    old_cases = {_case_key(c): c for c in old.get("cases", [])}
    rows, flagged = [], []
    for case in new.get("cases", []):
        key = _case_key(case)
        prev = old_cases.get(key)
        if prev is None:
            continue
        before, after = prev["steps_per_sec"], case["steps_per_sec"]
        change = (after - before) / before if before else 0.0
        regressed = change < -threshold
        rows.append((key, before, after, change, regressed))
        if regressed:
            flagged.append((key, before, after, change))
    return rows, flagged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--file", default="BENCH_nnps.json",
                    help="perf history file (list of run records)")
    ap.add_argument("--candidate", default=None,
                    help="standalone record to compare against the newest "
                    "history record (else: the two newest history records)")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="relative steps/sec drop that counts as a "
                    "regression (default 0.15)")
    args = ap.parse_args(argv)

    history = _load_history(args.file)
    if args.candidate:
        with open(args.candidate) as f:
            new = json.load(f)
        old = history[-1]
    else:
        if len(history) < 2:
            print("compare_bench: fewer than two run records — nothing "
                  "to compare")
            return 0
        old, new = history[-2], history[-1]

    rows, flagged = compare(old, new, args.threshold)
    if not rows:
        print("compare_bench: no matching cases between the two records "
              "(different sizes/backends) — nothing to compare")
        return 0

    print(f"{'case (n, backend, records, skin)':<44} "
          f"{'before':>10} {'after':>10} {'change':>8}")
    for key, before, after, change, regressed in rows:
        mark = "  << REGRESSION" if regressed else ""
        print(f"{str(key):<44} {before:>10.3f} {after:>10.3f} "
              f"{change:>+7.1%}{mark}")
    if flagged:
        print(f"\n{len(flagged)} case(s) regressed more than "
              f"{args.threshold:.0%} in steps/sec")
        return 1
    print("\nno steps/sec regressions beyond the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
