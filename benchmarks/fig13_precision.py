"""Paper Figs 13-15: float precision vs NNPS runtime, all-list and RCLL.

On CPU, fp16 arithmetic is emulated (no native half ALUs) so wall-time
ratios understate the paper's GPU gains; we therefore also report the
*bytes-streamed* model per search (the quantity that scales on TPU:
the paper's own Table 6 shows the O(N) search is bandwidth-bound).
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks._util import emit, time_fn
from repro.core import domain as D, nnps, rcll


def coord_bytes(n, dim, dtype_bytes, candidates):
    """bytes streamed per search: coords read once per candidate pair."""
    return n * candidates * dim * dtype_bytes


def main(full: bool = False):
    rng = np.random.default_rng(0)
    n = 16000 if full else 6000
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    k = 64
    cand = 9 * 8  # 3x3 cells x mean occupancy
    for name, dt in (("fp64", jnp.float64), ("fp32", jnp.float32),
                     ("bf16", jnp.bfloat16), ("fp16", jnp.float16)):
        if name == "fp64" and not jax.config.read("jax_enable_x64"):
            continue
        t_all = time_fn(jax.jit(lambda z: nnps.all_list_count(
            z, dom.radius_norm, dtype=dt)), xn)
        st = rcll.init_state(dom, xn, dtype=dt)
        t_rcll = time_fn(jax.jit(lambda r, c: nnps.rcll_neighbors(
            dom, r, c, dtype=dt, k=k).count), st.rel, st.cell_xy)
        nbytes = jnp.dtype(dt).itemsize
        emit("fig13_precision", {
            "precision": name, "n": n,
            "all_list_s": f"{t_all:.4f}",
            "rcll_s": f"{t_rcll:.4f}",
            "rcll_stream_bytes": coord_bytes(n, 2, nbytes, cand),
        })


if __name__ == "__main__":
    main()
