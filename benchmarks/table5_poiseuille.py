"""Paper Table 5: max location discrepancy vs the analytic solution for
approaches I (fp32 ref), II (fp16 absolute), III (fp16 RCLL).

The paper's breakdown of approach II needs ds/h_d < 1e-3; we reproduce
it with a long periodic channel (Lx >> 1) instead of 1e6+ particles.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks._util import emit
from repro.core import cases, solver
from repro.core.precision import PrecisionPolicy


def run_case(ds, lx, algo, policy, t_end):
    case = cases.PoiseuilleCase(ds=ds, Lx=lx, algo=algo, policy=policy)
    cfg, st = case.build()
    nst = int(round(t_end / cfg.dt))
    out = solver.simulate(cfg, st, nst)
    pos = solver.positions(cfg, out)
    y0 = np.asarray(solver.positions(cfg, st))[:, 1]
    fl = ~np.asarray(st.fixed)
    # x-displacement vs analytic (x wraps periodically: min-image)
    x0 = np.asarray(solver.positions(cfg, st))[:, 0]
    dx = np.asarray(pos)[:, 0] - x0
    dx = dx - np.round(dx / lx) * lx
    want = np.asarray(case.analytic_displacement(y0, float(out.t)))
    err = np.abs(dx[fl] - want[fl]).max() / ds
    return err


def main(full: bool = False):
    t_end = 0.36 if full else 0.18
    pol_hi = PrecisionPolicy(nnps="fp32", coords="fp32")
    pol_lo = PrecisionPolicy(nnps="fp16", coords="fp16")
    for ds, lx in ((0.05, 0.4), (0.025, 0.4)) + (
            ((0.05, 25.6),) if full else ((0.05, 6.4),)):
        row = {"ds": ds, "Lx": lx, "ds_over_hd": ds / max(lx, 1.0)}
        row["I_fp32_cell"] = round(
            run_case(ds, lx, "cell", pol_hi, t_end), 3)
        row["II_fp16_cell"] = round(
            run_case(ds, lx, "cell", pol_lo, t_end), 3)
        row["III_fp16_rcll"] = round(
            run_case(ds, lx, "rcll", pol_lo, t_end), 3)
        emit("table5_poiseuille_disc_in_ds", row)


if __name__ == "__main__":
    main()
