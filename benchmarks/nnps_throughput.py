"""Persistent-pipeline NNPS throughput: Verlet-skin reuse vs per-step
rebuild (the paper's third speedup round, made stateful).

Runs the Poiseuille channel with the production RCLL solver at
N in {8k, 64k} under two neighbor policies:

  * skin = 0       : the seed behavior - re-bin + re-search every step
                     (cell_factor 1, tight candidate matrix);
  * skin = 0.5 h_c : Verlet-skin reuse - search radius inflated to
                     r + skin (cells sized to cover it: cell_factor 2),
                     list rebuilt only when max displacement > skin/2.

Emits ``BENCH_nnps.json`` with steps/sec and the rebuild frequency so the
perf trajectory is tracked from this PR onward. CPU wall times are a
proxy (see _util); the *ratio* and the rebuild counts are the signal.
"""
from __future__ import annotations

import dataclasses
import json
import sys

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import cases, solver


def run_case(n_target: int, skin_frac_hc: float, nsteps: int) -> dict:
    ds = float((1.0 / n_target) ** 0.5)
    # skin is skin_frac_hc x the BASELINE cell size h_c = r (cell_factor 1);
    # the skinned run sizes its cells to cover r + skin exactly
    # (cell_factor = 1 + skin/r), keeping the candidate set as tight as
    # the coverage guarantee allows.
    cell_factor = 1.0 + skin_frac_hc
    max_neighbors = 64 if skin_frac_hc > 0 else 40
    case = cases.PoiseuilleCase(
        ds=ds,
        L=1.0,
        Lx=1.0,
        algo="rcll",
        cell_factor=cell_factor,
        max_neighbors=max_neighbors,
    )
    cfg, st = case.build()
    if skin_frac_hc > 0:
        skin = skin_frac_hc * cfg.domain.radius
        cfg = dataclasses.replace(cfg, skin=skin)
    n = int(st.xn.shape[0])

    t = time_fn(
        lambda: solver.simulate_stats(cfg, st, nsteps), warmup=1, repeats=2
    )
    _, stats = jax.block_until_ready(solver.simulate_stats(cfg, st, nsteps))
    rebuilds = int(stats.rebuilds)
    row = {
        "n_target": n_target,
        "n_particles": n,
        "skin_frac_hc": skin_frac_hc,
        "skin": float(getattr(cfg, "skin", 0.0)),
        "cell_factor": cell_factor,
        "max_neighbors": max_neighbors,
        "nsteps": nsteps,
        "time_s": round(t, 4),
        "steps_per_sec": round(nsteps / t, 3),
        "rebuilds": rebuilds,
        "rebuild_frequency": round(rebuilds / nsteps, 4),
        "overflow": bool(stats.overflow),
    }
    emit("nnps_throughput", row)
    return row


def main(full: bool = True):
    sizes = [(8000, 40), (64000, 16)] if full else [(8000, 40)]
    rows = []
    for n_target, nsteps in sizes:
        for skin_frac in (0.0, 0.5):
            rows.append(run_case(n_target, skin_frac, nsteps))

    speedups = {}
    for n_target, _ in sizes:
        base = next(
            r for r in rows
            if r["n_target"] == n_target and r["skin_frac_hc"] == 0.0
        )
        skinned = next(
            r for r in rows
            if r["n_target"] == n_target and r["skin_frac_hc"] > 0.0
        )
        speedups[str(n_target)] = round(
            skinned["steps_per_sec"] / base["steps_per_sec"], 3
        )
    out = {
        "backend": jax.default_backend(),
        "cases": rows,
        "steps_per_sec_speedup_skin_vs_none": speedups,
    }
    with open("BENCH_nnps.json", "w") as f:
        json.dump(out, f, indent=2)
    emit("nnps_throughput_summary", speedups)
    return out


if __name__ == "__main__":
    main(full="--quick" not in sys.argv)
