"""End-to-end step throughput: fused force pass vs the gather path, plus
the persistent-pipeline NNPS diagnostics (Verlet-skin reuse, rebuild
cost) and an HBM bytes/step model.

For each particle count the Poiseuille channel runs under the production
persistent RCLL solver with a Verlet skin (cells sized to cover r+skin):

  * ``reference``          - PR 1's gather path: per-pair arrays (disp,
    grad W, pair fields) materialized in HBM every step;
  * ``xla`` records=fp16   - the production half-width record sweep
    (core/fused.py): one uint16 record gather + one fp32 rho gather per
    pair, EOS-folded p/ρ², counting-sort rebuild, window search;
  * ``xla`` records=fp32   - the full-width record sweep (the PR 2
    layout) as the measured A/B for the record quantization.

Reported per case:
  * steps/sec measured on the donating scan entry point
    (``solver.run_persistent`` — chained segments, buffers updated in
    place, init/compile excluded);
  * physics-only ms/step (a scan of pure ``_physics_step``, no rebuild
    cond) vs the NNPS rebuild cost in ms and the observed rebuild
    frequency — the paper's Table 6 style split;
  * the analytic HBM bytes/step model for both paths and both record
    layouts (``fused.estimate_hbm_bytes_per_step``): CPU wall times are
    a proxy (see _util), the byte ratio is what transfers to TPU/GPU.

Results are APPENDED to ``BENCH_nnps.json`` (the file holds a list of
run records, oldest first) so the perf trajectory persists across PRs;
``benchmarks/compare_bench.py`` diffs consecutive records. CI smoke runs
pass ``--no-append`` (optionally with ``--out FILE``) so they never
pollute the history.

``--n 1000000`` reaches the paper's 1M-particle case (expect minutes per
backend on CPU; tiers above 200k run the production xla/fp16 combo only,
and a tier that OOMs is recorded as a skipped row with the reason);
``--quick`` runs the 8k case only. ``--dynamic`` adds dam-break rows
with a Verlet skin — the collapse keeps the rebuild ``lax.cond`` firing
inside the timed scan, so their steps/sec is the AMORTIZED physics +
rebuild throughput the steady poiseuille rows (rebuilds=0) cannot see,
reported alongside rebuilds_per_100_steps.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from functools import partial

import jax
import numpy as np

from benchmarks._util import emit, time_fn
from repro.core import cases, fused, solver
from repro.core.precision import PrecisionPolicy

BENCH_PATH = "BENCH_nnps.json"


@partial(jax.jit, static_argnums=(0, 2))
def _physics_only(cfg, carry, nsteps):
    """Scan of the raw physics step (no rebuild cond) for the time split."""

    def body(c, _):
        return solver._physics_step(cfg, c), None

    return jax.lax.scan(body, carry, None, length=nsteps)[0]


def _build(
    n_target: int,
    backend: str,
    skin_frac_hc: float,
    records: str,
    case_name: str = "poiseuille",
    dynamic: bool = False,
):
    if case_name == "poiseuille":
        # historical default: unit-square channel, skin-capable cells
        ds = float((1.0 / n_target) ** 0.5)
        cell_factor = 1.0 + skin_frac_hc
        max_neighbors = 64 if skin_frac_hc > 0 else 40
        case = cases.PoiseuilleCase(
            ds=ds, L=1.0, Lx=1.0, algo="rcll",
            cell_factor=cell_factor, max_neighbors=max_neighbors,
            backend=backend, policy=PrecisionPolicy(records=records),
        )
        cfg, st = case.build()
        if skin_frac_hc > 0:
            cfg = dataclasses.replace(
                cfg, skin=skin_frac_hc * cfg.domain.radius
            )
        return cfg, st, max_neighbors
    if dynamic and case_name == "dam_break":
        # The --dynamic mode: a dam-break column started at a
        # collapse-representative fall speed (v0) so the Verlet
        # criterion fires rebuilds INSIDE the short timed window (a
        # quiescent column needs O(sqrt(col_h/g)) of physical time —
        # thousands of steps at fine ds — before anything moves a
        # cell). Skin-capable cells sized like the poiseuille rows.
        ds = cases.resolve_ds(case_name, n_target)
        radius = 2.0 * cases.build_case(case_name, ds=ds).h  # support 2h
        case = cases.build_case(
            case_name, ds=ds, backend=backend,
            policy=PrecisionPolicy(records=records),
            cell_factor=1.0 + max(skin_frac_hc, 0.5),
            skin=max(skin_frac_hc, 0.5) * radius,
            max_neighbors=64,
            v0=1.0,  # ~sqrt(g * col_h)
        )
        cfg, st = case.build()
        return cfg, st, cfg.max_neighbors
    # any registered scenario (--case): scaled to n_target via the case
    # registry; these cases size their own cells (no Verlet skin knob),
    # so skin_frac_hc is ignored and the rebuild runs per step.
    case = cases.build_case(
        case_name,
        ds=cases.resolve_ds(case_name, n_target),
        backend=backend,
        policy=PrecisionPolicy(records=records),
    )
    cfg, st = case.build()
    return cfg, st, cfg.max_neighbors


def run_case(
    n_target: int,
    backend: str,
    nsteps: int,
    skin_frac_hc: float = 0.5,
    records: str = "fp16",
    case_name: str = "poiseuille",
    dynamic: bool = False,
) -> dict:
    if case_name != "poiseuille" and not dynamic:
        skin_frac_hc = 0.0
    cfg, st, max_neighbors = _build(
        n_target, backend, skin_frac_hc, records, case_name, dynamic
    )
    n = int(st.xn.shape[0])

    # warm the flow a little so velocities/densities are nontrivial
    st = jax.block_until_ready(solver.simulate(cfg, st, 10))

    # physics-only vs NNPS(rebuild) split (non-donating jits)
    carry = solver.init_persistent(cfg, st)
    np_steps = min(8, nsteps)
    t_phys = time_fn(
        lambda: _physics_only(cfg, carry, np_steps), warmup=1, repeats=2
    ) / np_steps
    reb = jax.jit(lambda c: solver._rebuild(cfg, c))
    t_rebuild = time_fn(lambda: reb(carry), warmup=1, repeats=2)

    # steps/sec on the donating scan entry point (init/compile excluded).
    # run_persistent donates the carry — and the carry aliases ``st``'s
    # buffers — so this phase runs LAST and rebinds carry each call.
    carry = jax.block_until_ready(solver.run_persistent(cfg, carry, nsteps))
    rebuilds_before = int(carry.rebuilds)
    times = []
    timed_segments = 3
    for _ in range(timed_segments):
        t0 = time.perf_counter()
        carry = jax.block_until_ready(
            solver.run_persistent(cfg, carry, nsteps)
        )
        times.append(time.perf_counter() - t0)
    t_run = min(times)
    # diagnostics from the SAME timed segments, not a separate run
    rebuilds = int(carry.rebuilds) - rebuilds_before
    rebuild_frequency = rebuilds / (timed_segments * nsteps)
    overflow = bool(carry.overflow)

    k, d = max_neighbors, cfg.domain.dim
    row = {
        "case": case_name,
        "dynamic": dynamic,
        "n_target": n_target,
        "n_particles": n,
        "backend": backend,
        "records": records,
        "skin_frac_hc": skin_frac_hc,
        "skin": float(cfg.skin),
        "max_neighbors": k,
        "nsteps": nsteps,
        # the donated-scan steps/sec INCLUDES every in-scan rebuild: in
        # --dynamic mode this IS the amortized throughput
        "steps_per_sec": round(nsteps / t_run, 3),
        "physics_ms_per_step": round(t_phys * 1e3, 3),
        "rebuild_ms": round(t_rebuild * 1e3, 3),
        "rebuilds": rebuilds,
        "rebuild_frequency": round(rebuild_frequency, 4),
        "rebuilds_per_100_steps": round(100.0 * rebuild_frequency, 1),
        "overflow": overflow,
        "hbm_model_bytes_per_step_gather": fused.estimate_hbm_bytes_per_step(
            n, k, d, fused=False
        ),
        "hbm_model_bytes_per_step_fused": fused.estimate_hbm_bytes_per_step(
            n, k, d, fused=True, records=records
        ),
    }
    if dynamic:
        # alias, emitted only where it means something (rebuilds fired
        # inside the timed scan)
        row["amortized_steps_per_sec"] = row["steps_per_sec"]
    emit("step_throughput", row)
    return row


def _append_record(record: dict) -> None:
    """BENCH_nnps.json holds a list of run records, oldest first."""
    history = []
    if os.path.exists(BENCH_PATH):
        with open(BENCH_PATH) as f:
            prev = json.load(f)
        history = prev if isinstance(prev, list) else [prev]
    history.append(record)
    with open(BENCH_PATH, "w") as f:
        json.dump(history, f, indent=2)


def default_steps(n: int) -> int:
    return max(8, min(48, int(3_000_000 / max(n, 1))))


#: Above this particle count only the production combo (xla, fp16) runs:
#: the gather/full-width A/Bs would triple a multi-minute CPU tier for a
#: ratio the smaller tiers already establish.
BIG_TIER = 200_000


def main(
    full: bool = True,
    sizes: list[tuple[int, int]] | None = None,
    skin_compare: bool = True,
    append: bool = True,
    out: str | None = None,
    case_name: str = "poiseuille",
    dynamic_sizes: list[tuple[int, int]] | None = None,
):
    """``full`` selects the 8k+64k grid (benchmarks.run interface);
    ``sizes`` overrides it with explicit (n_target, nsteps) pairs;
    ``case_name`` benchmarks any registered scenario (BENCH records are
    tagged with it); ``dynamic_sizes`` adds dam-break rows with a
    Verlet skin — rebuilds fire inside the timed scan, so their
    steps/sec is the amortized (physics + rebuild) throughput. Tiers
    that fail to build or run (e.g. an OOM at the 1M tier) are recorded
    as skipped rows with the reason, never crash the run."""
    if sizes is None:
        targets = [8000, 64000] if full else [8000]
        sizes = [(t, default_steps(t)) for t in targets]
    runs = [("reference", "fp32"), ("xla", "fp32"), ("xla", "fp16")]
    rows, skipped = [], []

    def attempt(n_target, backend, nsteps, **kw):
        try:
            rows.append(run_case(n_target, backend, nsteps, **kw))
        except Exception as e:  # best-effort tiers: record, don't crash
            reason = f"{type(e).__name__}: {e}"[:300]
            skipped.append({
                "case": kw.get("case_name", case_name),
                "dynamic": kw.get("dynamic", False),
                "n_target": n_target, "backend": backend,
                "records": kw.get("records", "fp16"), "skipped": reason,
            })
            emit("step_throughput_skipped", skipped[-1])

    for n_target, nsteps in sizes:
        combos = runs if n_target <= BIG_TIER else [("xla", "fp16")]
        for backend, records in combos:
            attempt(n_target, backend, nsteps, records=records,
                    case_name=case_name)
    for n_target, nsteps in dynamic_sizes or []:
        combos = (
            [("reference", "fp32"), ("xla", "fp16")]
            if n_target <= BIG_TIER else [("xla", "fp16")]
        )
        for backend, records in combos:
            attempt(n_target, backend, nsteps, records=records,
                    case_name="dam_break", dynamic=True)
    if skin_compare and case_name == "poiseuille":
        # PR 1's skin-vs-none tracking metric (fused backend, 8k)
        attempt(sizes[0][0], "xla", sizes[0][1], skin_frac_hc=0.0)

    if not rows:
        # every tier was skipped (e.g. a 1M-only invocation that OOMed):
        # the skip rows ARE the record — never crash past them
        record = {
            "label": "rebuild_round",
            "case": case_name,
            "backend": jax.default_backend(),
            "cpu_count": os.cpu_count(),
            "cases": [],
            "skipped": skipped,
        }
        if append:
            _append_record(record)
        if out:
            with open(out, "w") as f:
                json.dump(record, f, indent=2)
        emit("step_throughput_summary", {"skipped": len(skipped)})
        return record

    def pick(n_target, backend, records):
        for r in rows:
            if r.get("dynamic"):
                continue
            if (r["n_target"], r["backend"], r["records"]) == (
                n_target, backend, records
            ) and (r["skin_frac_hc"] > 0 or case_name != "poiseuille"):
                return r
        return None

    speedups, layout_speedups = {}, {}
    for n_target, _ in sizes:
        ref = pick(n_target, "reference", "fp32")
        h16 = pick(n_target, "xla", "fp16")
        f32 = pick(n_target, "xla", "fp32")
        if ref and h16:
            speedups[str(n_target)] = round(
                h16["steps_per_sec"] / ref["steps_per_sec"], 3
            )
        if f32 and h16:
            layout_speedups[str(n_target)] = round(
                h16["steps_per_sec"] / f32["steps_per_sec"], 3
            )
    k, d = rows[0]["max_neighbors"], 2
    n0 = rows[0]["n_particles"]
    record = {
        "label": "rebuild_round",
        "case": case_name,
        "backend": jax.default_backend(),
        # CPU wall-clocks are machine-sensitive: record the core count so
        # cross-record comparisons (compare_bench) can be read in context.
        "cpu_count": os.cpu_count(),
        "cases": rows,
        "steps_per_sec_speedup_fused_vs_gather": speedups,
        "steps_per_sec_half_vs_fp32_records": layout_speedups,
        "hbm_model_ratio_gather_over_fused": round(
            rows[0]["hbm_model_bytes_per_step_gather"]
            / fused.estimate_hbm_bytes_per_step(
                n0, k, d, fused=True, records="fp16"
            ), 2,
        ),
        "hbm_model_ratio_fp32_over_half_records": round(
            fused.estimate_hbm_bytes_per_step(n0, k, d, records="fp32")
            / fused.estimate_hbm_bytes_per_step(n0, k, d, records="fp16"),
            2,
        ),
    }
    if skipped:
        record["skipped"] = skipped
    if append:
        _append_record(record)
    if out:
        with open(out, "w") as f:
            json.dump(record, f, indent=2)
    emit("step_throughput_summary", speedups)
    return record


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--n", type=int, action="append", default=None,
        help="particle-count target (repeatable); e.g. --n 1000000 for "
        "the paper's 1M case. Default: 8000 and 64000.",
    )
    ap.add_argument("--quick", action="store_true", help="8k only")
    ap.add_argument(
        "--nsteps", type=int, default=None,
        help="timed steps per segment (default: scaled by size)",
    )
    ap.add_argument(
        "--no-append", action="store_true",
        help="do not append the run record to BENCH_nnps.json (CI smoke "
        "runs must not pollute the perf history)",
    )
    ap.add_argument(
        "--out", type=str, default=None,
        help="also write this run's record to a standalone JSON file "
        "(pairs with compare_bench --candidate)",
    )
    ap.add_argument(
        "--case", type=str, default="poiseuille",
        choices=cases.case_names(),
        help="registered scenario to benchmark (BENCH records are "
        "tagged with it); non-poiseuille cases run skinless",
    )
    ap.add_argument(
        "--dynamic", action="store_true",
        help="also run dam-break rows with a Verlet skin at the same "
        "tiers: rebuilds fire inside the timed scan, so steps/sec is "
        "the amortized physics+rebuild throughput (reported with "
        "rebuilds_per_100_steps)",
    )
    ap.add_argument(
        "--dynamic-n", type=int, action="append", default=None,
        help="override the --dynamic tier list (repeatable)",
    )
    args = ap.parse_args()
    if args.n:
        targets = args.n
    elif args.quick:
        targets = [8000]
    else:
        targets = [8000, 64000]
    sizes = [(t, args.nsteps or default_steps(t)) for t in targets]
    dynamic_sizes = None
    if args.dynamic or args.dynamic_n:
        dyn_targets = args.dynamic_n or targets
        # dynamic rows need enough steps for the Verlet criterion to
        # fire several rebuilds inside the timed segments (~1 rebuild
        # per ~25-30 steps at the v0 drop speed)
        dynamic_sizes = [
            (t, max(32, args.nsteps or default_steps(t)))
            for t in dyn_targets
        ]
    main(
        sizes=sizes,
        skin_compare=not args.n,
        append=not args.no_append,
        out=args.out,
        case_name=args.case,
        dynamic_sizes=dynamic_sizes,
    )
