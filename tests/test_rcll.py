"""Persistent RCLL state: Eq. 8 updates + migration."""
import numpy as np
import jax.numpy as jnp

from repro.core import domain as D, rcll


def test_advance_matches_direct_periodic(rng):
    dom = D.Domain(lo=(0., 0.), hi=(1., 1.), h=0.02, periodic=(True, True))
    n = 2000
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    st = rcll.init_state(dom, xn, dtype=jnp.float16)
    hc = max(dom.hc_norm_axes)
    direct = np.asarray(xn, np.float64)
    for step in range(5):
        dxn = rng.uniform(-1.5, 1.5, (n, 2)) * hc  # multi-cell moves
        st = rcll.advance(dom, st, jnp.asarray(dxn, jnp.float32))
        direct = direct + dxn
    dec = np.asarray(rcll.to_normalized(dom, st))
    org = np.asarray(dom.origin_norm)
    want = org + np.mod(direct - org, 2.0)
    err = np.abs(dec - want)
    err = np.minimum(err, 2.0 - err)
    # error accumulates ~1 ulp of rel per step
    assert err.max() < 6 * (hc / 2) * 2**-10


def test_migration_keeps_rel_in_range(rng):
    dom = D.Domain(lo=(0., 0.), hi=(1., 1.), h=0.02, periodic=(True, True))
    x = rng.uniform(0, 1, (500, 2))
    st = rcll.init_state(dom, dom.normalize(jnp.asarray(x)))
    for _ in range(10):
        dxn = jnp.asarray(
            rng.uniform(-2, 2, (500, 2)) * max(dom.hc_norm_axes),
            jnp.float32)
        st = rcll.advance(dom, st, dxn)
        assert float(jnp.max(jnp.abs(st.rel.astype(jnp.float32)))) <= 1.001
        assert np.all(np.asarray(st.cell_xy) >= 0)
        assert np.all(np.asarray(st.cell_xy) < np.asarray(dom.ncells))


def test_pair_displacements_match_absolute(rng):
    n = 1000
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    st = rcll.init_state(dom, xn, dtype=jnp.float16)
    nl, _ = rcll.neighbors(dom, st, dtype=jnp.float16, k=48)
    disp, r = rcll.pair_displacements(dom, st, nl)
    # against absolute positions (quantization-bounded error)
    xp = np.asarray(dom.denormalize(xn))
    want = xp[:, None, :] - xp[np.asarray(nl.idx)]
    err = np.abs(np.asarray(disp) - want) * np.asarray(nl.mask)[..., None]
    bound = 4 * max(dom.cell_sizes) / 2 * 2**-10
    assert err.max() < bound
    r_want = np.linalg.norm(want, axis=-1) * np.asarray(nl.mask)
    assert np.abs(np.asarray(r) * np.asarray(nl.mask) - r_want).max() < bound


def test_error_feedback_removes_quantization_drift(rng):
    """advance_ef tracks the exact trajectory even when per-step moves
    are below the fp16 ulp (where plain advance stalls/drifts)."""
    import jax.numpy as jnp
    dom = D.Domain(lo=(0., 0.), hi=(1., 1.), h=0.02, periodic=(True, True))
    n = 200
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    st_plain = rcll.init_state(dom, xn, dtype=jnp.float16)
    st_ef = st_plain
    carry = jnp.zeros((n, 2), jnp.float32)
    # displacement ~1e-5 cells/step: far below fp16 ulp of rel (~5e-4)
    v = rng.uniform(-1, 1, (n, 2))
    dxn = jnp.asarray(v * 1e-5 * max(dom.hc_norm_axes), jnp.float32)
    nsteps = 400
    for _ in range(nsteps):
        st_plain = rcll.advance(dom, st_plain, dxn)
        st_ef, carry = rcll.advance_ef(dom, st_ef, dxn, carry)
    exact = np.asarray(xn, np.float64) + nsteps * np.asarray(dxn)
    org = np.asarray(dom.origin_norm)
    exact = org + np.mod(exact - org, 2.0)

    def err(st, extra=0.0):
        dec = np.asarray(rcll.to_normalized(dom, st), np.float64) + extra
        e = np.abs(dec - exact)
        return np.minimum(e, 2.0 - e).max()

    quantum = max(dom.hc_norm_axes) / 2 * 2**-10
    # plain: each step's sub-ulp move is rounded away -> stall error of
    # the full accumulated displacement (>> 1 quantum)
    assert err(st_plain) > 1.5 * quantum
    # error feedback: decoded + carry tracks the exact trajectory to
    # fp32-accumulation accuracy (~400 steps of fp32 rounding)
    carry_norm = np.asarray(carry) * np.asarray(dom.hc_norm_axes) / 2
    assert err(st_ef, extra=carry_norm) < 3e-5
    # even the stored (quantized) EF position is within one quantum
    assert err(st_ef) < 1.1 * quantum
