"""Scenario-layer API: case registry, Scheme plumbing across backends,
wall boundaries (no-advection + moving lid), Taylor-Green analytic
decay, in-scan observables, and back-compat shim equivalence."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import boundaries, cases, fused, scheme as scheme_lib, solver
from repro.core.api import Simulation, observe_state
from repro.core.precision import FP32_RECORDS

ON_TPU = jax.default_backend() == "tpu"


# --------------------------------------------------------------------------
# registry
# --------------------------------------------------------------------------
def test_registry_ships_the_case_suite():
    names = cases.case_names()
    for required in ("poiseuille", "dam_break", "cavity", "taylor_green"):
        assert required in names
    for name in names:
        case = cases.build_case(name)
        assert isinstance(case, cases.CaseSpec)
        assert case.name == name
        assert case.fluid_area > 0


def test_build_case_overrides_and_unknown():
    case = cases.build_case("dam_break", ds=0.1, alpha=0.3)
    assert case.ds == 0.1 and case.alpha == 0.3
    with pytest.raises(ValueError, match="unknown case"):
        cases.build_case("nope")


def test_resolve_ds_targets_particle_count():
    ds = cases.resolve_ds("taylor_green", 400)
    cfg, st = cases.build_case("taylor_green", ds=ds).build()
    assert 300 <= st.xn.shape[0] <= 500


# --------------------------------------------------------------------------
# scheme plumbing
# --------------------------------------------------------------------------
def test_default_scheme_matches_legacy_kwargs_bitwise():
    """force_rhs(scheme=wcsph(...)) must be the identical computation to
    the legacy c0/rho0/mu kwargs (the back-compat contract)."""
    rng = np.random.default_rng(2)
    case = cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="rcll")
    cfg, st = case.build()
    carry = solver.init_persistent(cfg, st)
    fl = carry.st.fluid
    legacy = fused.force_rhs(
        cfg.domain, carry.st.rc, carry.nl, fl.v, fl.m, fl.rho,
        c0=cfg.c0, rho0=cfg.rho0, mu=cfg.mu,
    )
    via_scheme = fused.force_rhs(
        cfg.domain, carry.st.rc, carry.nl, fl.v, fl.m, fl.rho,
        scheme=scheme_lib.wcsph(cfg.c0, cfg.rho0, cfg.mu),
    )
    for a, b in zip(legacy, via_scheme):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_scheme_validation():
    with pytest.raises(ValueError, match="unknown eos"):
        scheme_lib.Scheme(c0=1.0, eos="stiffened")
    with pytest.raises(ValueError, match="unknown viscosity"):
        scheme_lib.Scheme(c0=1.0, viscosity="sutherland")


def test_tait_por2_inv_consistent_with_pressure():
    sch = scheme_lib.Scheme(c0=10.0, rho0=1.0, eos="tait", gamma=7.0)
    rho = jnp.asarray(np.linspace(0.9, 1.1, 11), jnp.float32)
    want = sch.pressure(rho) / (rho * rho)
    got = sch.por2_inv(1.0 / rho)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


# --------------------------------------------------------------------------
# backend agreement on the new cases (fp32 records: the exactness regime)
# --------------------------------------------------------------------------
def _agreement_case(name, nsteps, ds, backends):
    outs = {}
    for be in backends:
        case = cases.build_case(
            name, ds=ds, backend=be, policy=FP32_RECORDS
        )
        cfg, st = case.build()
        out = solver.simulate(cfg, st, nsteps)
        outs[be] = (
            np.asarray(solver.positions(cfg, out)),
            np.asarray(out.fluid.v),
            np.asarray(out.fluid.rho),
        )
    ref = outs[backends[0]]
    for be in backends[1:]:
        np.testing.assert_allclose(outs[be][0], ref[0], atol=1e-6,
                                   err_msg=f"{name}:{be} positions")
        np.testing.assert_allclose(outs[be][1], ref[1], atol=1e-6,
                                   err_msg=f"{name}:{be} velocities")
        np.testing.assert_allclose(outs[be][2], ref[2], atol=1e-6,
                                   err_msg=f"{name}:{be} densities")


def test_backends_agree_on_dam_break():
    """Tait EOS + artificial viscosity + delta-SPH through all three
    backends (pallas in interpret mode on CPU) — the scheme channels
    cannot drift between implementations."""
    backends = ["reference", "xla", "pallas"]
    _agreement_case("dam_break", nsteps=10, ds=0.1, backends=backends)


def test_backends_agree_on_taylor_green():
    _agreement_case(
        "taylor_green", nsteps=10, ds=1.0 / 16.0,
        backends=["reference", "xla", "pallas"],
    )


def test_backends_agree_on_cavity():
    _agreement_case(
        "cavity", nsteps=10, ds=0.1, backends=["reference", "xla"]
    )


# --------------------------------------------------------------------------
# wall boundaries
# --------------------------------------------------------------------------
def test_walls_never_advect_and_lid_keeps_speed():
    case = cases.build_case("cavity", ds=0.1)
    cfg, st0 = case.build()
    wall = np.asarray(st0.fixed)
    p0 = np.asarray(solver.positions(cfg, st0))
    out = solver.simulate(cfg, st0, 30)
    p1 = np.asarray(solver.positions(cfg, out))
    # walls: bitwise-frozen positions, fluid: must actually move
    np.testing.assert_array_equal(p1[wall], p0[wall])
    assert np.abs(p1[~wall] - p0[~wall]).max() > 0
    # lid rows keep their prescribed velocity exactly; other walls 0
    v = np.asarray(out.fluid.v)
    vw = np.asarray(st0.v_wall)
    np.testing.assert_array_equal(v[wall], vw[wall])
    lid = wall & (np.asarray(st0.v_wall)[:, 0] > 0)
    assert lid.sum() > 0
    np.testing.assert_array_equal(v[lid, 0], case.U)


def test_moving_lid_drags_fluid():
    """The lid's prescribed velocity must reach the fluid through the
    viscous pair term (i.e. through the shared v array / record rows)."""
    case = cases.build_case("cavity", ds=0.1)
    cfg, st0 = case.build()
    out = solver.simulate(cfg, st0, 150)
    pos = np.asarray(solver.positions(cfg, out))
    fl = ~np.asarray(out.fixed)
    # top fluid row: inside the lid's kernel support
    near_lid = fl & (pos[:, 1] > case.L - 1.5 * case.ds)
    assert near_lid.sum() > 0
    vx = np.asarray(out.fluid.v)[:, 0]
    assert vx[near_lid].mean() > 0.05 * case.U


def test_wall_generator_covers_corners_once():
    pos, v_wall = boundaries.box_wall_particles(
        (0.0, 0.0), (1.0, 1.0), 0.1, 2,
        sides=((1, 1), (1, 0), (0, 0), (0, 1)),
        velocities={(1, 1): (2.0, 0.0)},
    )
    # no duplicate particles (corners classified exactly once)
    assert len(np.unique(np.round(pos / 0.05).astype(int), axis=0)) == len(pos)
    # lid band (y > 1) moves, including its corners; floor band does not
    lid = pos[:, 1] > 1.0
    assert lid.sum() > 0 and np.all(v_wall[lid, 0] == 2.0)
    assert np.all(v_wall[pos[:, 1] < 0.0] == 0.0)
    # corner coverage: wall nodes exist outside both x and y bounds
    assert np.any((pos[:, 0] > 1.0) & (pos[:, 1] > 1.0))


# --------------------------------------------------------------------------
# Taylor-Green analytic decay
# --------------------------------------------------------------------------
def test_taylor_green_decay_matches_analytic():
    """KE decay rate within 5% of the analytic 4 nu k^2 over the
    validated window (first half-life) — the acceptance criterion."""
    sim = Simulation.from_case("taylor_green")
    res = sim.run(300, observe_every=10)
    obs = res.observables
    metrics = sim.case.validate(np.asarray(obs.t), np.asarray(obs.ekin))
    assert metrics["decay_rate_rel_err"] < 0.05, metrics
    # pointwise: KE tracks the analytic curve through the window too
    t = np.asarray(obs.t)
    e = np.asarray(obs.ekin)
    e0 = e[0] / np.exp(-sim.case.decay_rate * t[0])
    win = e >= 0.5 * e0
    ana = sim.case.analytic_ekin(e0, t[win])
    assert np.abs(e[win] / ana - 1.0).max() < 0.05


# --------------------------------------------------------------------------
# Simulation facade + observables
# --------------------------------------------------------------------------
def test_simulation_run_matches_simulate_shim():
    """Back-compat: Simulation.run == solver.simulate on Poiseuille."""
    case = cases.PoiseuilleCase(ds=0.05, Lx=0.4)
    cfg, st = case.build()
    want = solver.simulate(cfg, st, 50)
    sim = Simulation(cfg=cfg, state=st)
    res = sim.run(50)
    np.testing.assert_allclose(
        np.asarray(solver.positions(cfg, res.state)),
        np.asarray(solver.positions(cfg, want)), atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(res.state.fluid.v), np.asarray(want.fluid.v), atol=1e-7
    )
    assert int(res.stats.steps) == 50


def test_observed_run_matches_unobserved():
    """In-scan sampling must not perturb the trajectory: same steps with
    and without observables -> same final state."""
    case = cases.build_case("taylor_green", ds=1.0 / 16.0)
    cfg, st = case.build()
    plain = solver.simulate(cfg, st, 40)
    sim = Simulation(cfg=cfg, state=st)
    res = sim.run(40, observe_every=10)
    np.testing.assert_allclose(
        np.asarray(res.state.fluid.v), np.asarray(plain.fluid.v), atol=1e-7
    )
    obs = res.observables
    assert obs.t.shape == (4,)
    # the last observable row equals recomputing from the final state
    last = observe_state(cfg, res.state)
    np.testing.assert_allclose(float(obs.ekin[-1]), float(last[1]),
                               rtol=1e-6)
    np.testing.assert_allclose(float(obs.vmax[-1]), float(last[2]),
                               rtol=1e-6)
    # time advances uniformly
    np.testing.assert_allclose(
        np.diff(np.asarray(obs.t)), 10 * cfg.dt, rtol=1e-4
    )


def test_observables_exclude_walls():
    """Wall kinetic energy (the moving lid!) must not leak into ekin."""
    case = cases.build_case("cavity", ds=0.1)
    cfg, st = case.build()
    t, ekin, vmax, rho_err = observe_state(cfg, st)
    # initial fluid is at rest; lid moves at U=1 — fluid-only ekin is 0
    assert float(ekin) == 0.0
    assert float(vmax) == 0.0


def test_absolute_algo_through_facade():
    case = cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="cell")
    sim = Simulation.from_case(case)
    res = sim.run(20, observe_every=5)
    assert res.observables.t.shape == (4,)
    assert not bool(res.stats.overflow)
    assert np.isfinite(np.asarray(res.observables.ekin)).all()
