"""Generic anchored mixed-precision representation."""
import numpy as np
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import anchored


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 500),
    block=st.sampled_from([16, 64, 128]),
    scale=st.floats(1e-3, 1e3),
    offset=st.floats(-1e3, 1e3),
    dtype=st.sampled_from(["float16", "int8", "bfloat16"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_roundtrip_error_bound(n, block, scale, offset, dtype,
                                        seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(offset, scale, (n,)), jnp.float32)
    enc = anchored.encode(x, block=block, dtype=jnp.dtype(dtype))
    dec = anchored.decode(enc)
    bound = np.asarray(anchored.quantization_error_bound(enc)).max()
    err = float(jnp.max(jnp.abs(dec - x)))
    assert err <= bound * 2 + 1e-7, (err, bound)
    # the bound is scale-relative: anchoring removes the offset entirely
    assert bound <= 2 * scale * 4  # block max-dev bounded by data spread


def test_anchor_removes_offset_precision_loss():
    """The RCLL argument: a large common offset destroys raw fp16 but
    anchored fp16 is offset-invariant."""
    rng = np.random.default_rng(1)
    base = rng.normal(0, 1e-3, (256,))
    x = jnp.asarray(base + 1000.0, jnp.float32)
    raw16 = x.astype(jnp.float16).astype(jnp.float32)
    # raw fp16 flushes every deviation to the same representable value:
    # the sub-ulp signal is destroyed entirely
    dev = np.abs(base)
    raw_dev_kept = float(jnp.std(raw16))
    assert raw_dev_kept < 1e-6  # all values rounded to 1000.0 exactly
    enc = anchored.encode(x, block=128, dtype=jnp.float16)
    anc_err = float(jnp.max(jnp.abs(anchored.decode(enc) - x)))
    assert anc_err < dev.max() / 100  # signal preserved to ~fp16 eps


def test_axis_and_padding():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(3, 70, 5)), jnp.float32)
    enc = anchored.encode(x, block=32, axis=1, dtype=jnp.int8)
    dec = anchored.decode(enc)
    assert dec.shape == x.shape
    assert float(jnp.max(jnp.abs(dec - x))) < 0.05
