"""Fused cell-blocked force pass: backend agreement (reference / xla /
pallas-interpret), stale-binning re-anchoring under cell migration,
overflow surfacing, and the donating scan entry point."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import cases, cells, domain as D, fused, rcll, solver, sph

ON_TPU = jax.default_backend() == "tpu"


def _poiseuille(backend, *, ds=0.1, skin_frac=0.0, **kw):
    kw.setdefault("max_neighbors", 96 if skin_frac > 0 else 40)
    case = cases.PoiseuilleCase(
        ds=ds, Lx=0.8, algo="rcll", backend=backend,
        cell_factor=2.0 if skin_frac > 0 else 1.0,
        **kw,
    )
    cfg, st = case.build()
    if skin_frac > 0:
        cfg = dataclasses.replace(
            cfg, skin=skin_frac * min(cfg.domain.cell_sizes)
        )
    return cfg, st


def _cloud_setup(n=800, seed=0, k=256):
    """Random cloud + packed state + skin-inflated list (no overflow)."""
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=1.2 * ds, cell_factor=2.0)
    x = rng.uniform(0, 1, (n, 2))
    rc = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    cfg = solver.SPHConfig(
        domain=dom, ds=ds, dt=1e-3, max_neighbors=k, algo="rcll",
        skin=0.5 * min(dom.cell_sizes),
    )
    cfg.validate_skin()
    cap = cells.default_capacity(dom, n, safety=8.0)
    ps = rcll.pack_state(dom, rc, cap)
    nl = rcll.packed_neighbors(
        dom, ps, dtype=jnp.float16, compute_dtype=jnp.float32, k=k,
        radius_cell=cfg.search_radius_cell,
    )
    assert not bool(nl.overflowed)
    fields = dict(
        v=jnp.asarray(rng.normal(size=(n, 2)) * 0.1, jnp.float32),
        m=jnp.full((n,), 1.0 / n, jnp.float32),
        rho=jnp.asarray(1.0 + 0.01 * rng.normal(size=(n,)), jnp.float32),
    )
    return dom, cfg, ps, nl, fields


def _reference_rhs(dom, rc, nl, v, m, rho, *, h, mu, rho0=1.0, c0=1.25):
    disp, r = rcll.pair_displacements(dom, rc, nl)
    gw = sph.grad_w(disp, r, h, dom.dim, nl.mask)
    pf = sph.gather_pair_fields(v, m, nl.idx, nl.mask)
    drho = sph.continuity_rhs_pairs(pf, gw)
    p = sph.eos_tait(rho, rho0, c0)
    acc = sph.momentum_rhs_pairs(
        pf, rho, p, nl.idx, gw, disp, r, h=h, mu=mu,
        body_force=jnp.zeros((dom.dim,), jnp.float32),
    )
    return drho, acc, p


# --------------------------------------------------------------------------
# drho / acc agreement on a static configuration
# --------------------------------------------------------------------------
def test_fused_xla_rhs_matches_reference():
    dom, cfg, ps, nl, f = _cloud_setup()
    drho_r, acc_r, p = _reference_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    for chunk in (0, 100, 10**6):  # padded map, odd chunk, single chunk
        drho_f, acc_f = fused.force_rhs(
            dom, ps.rc, nl, f["v"], f["m"], f["rho"], p,
            chunk=chunk, mu=1.0,
        )
        np.testing.assert_allclose(drho_f, drho_r, rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(acc_f, acc_r, rtol=2e-5, atol=2e-3)


def test_fused_pallas_rhs_matches_reference():
    from repro.kernels import ops

    dom, cfg, ps, nl, f = _cloud_setup()
    drho_r, acc_r, p = _reference_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    drho_k, acc_k = ops.rcll_force_particles(
        dom, ps.packing.binning, ps.rc, f["v"], f["m"], f["rho"], p,
        mu=1.0, interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_k, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_k, acc_r, rtol=2e-5, atol=2e-3)


def test_fused_pallas_stale_binning_with_migrations():
    """Between Verlet rebuilds the binning is stale; particles that
    migrated cells must decode exactly via the re-anchored fp32 rel."""
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    dom, cfg, ps, nl, f = _cloud_setup(seed=3)
    n = ps.rc.rel.shape[0]
    # displace by < skin/2 in random directions -> boundary-adjacent
    # particles migrate cells while the neighbor list stays valid
    dxn = jnp.asarray(rng.uniform(-1, 1, (n, 2)), jnp.float32)
    dxn = dxn / jnp.linalg.norm(dxn, axis=1, keepdims=True) * (
        0.45 * cfg.skin_norm / 2
    )
    rc1 = rcll.advance(dom, ps.rc, dxn, dtype=jnp.float16)
    migrated = np.any(
        np.asarray(rc1.cell_xy) != np.asarray(ps.rc.cell_xy), axis=1
    )
    assert migrated.sum() > 0, "setup must actually migrate particles"

    drho_r, acc_r, p = _reference_rhs(
        dom, rc1, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    drho_k, acc_k = ops.rcll_force_particles(
        dom, ps.packing.binning, rc1, f["v"], f["m"], f["rho"], p,
        mu=1.0, interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_k, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_k, acc_r, rtol=2e-5, atol=2e-3)
    # fused xla path too (consumes the same stale list + current state)
    drho_f, acc_f = fused.force_rhs(
        dom, rc1, nl, f["v"], f["m"], f["rho"], p, mu=1.0
    )
    np.testing.assert_allclose(drho_f, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_f, acc_r, rtol=2e-5, atol=2e-3)


# --------------------------------------------------------------------------
# end-to-end trajectories across skin settings
# --------------------------------------------------------------------------
@pytest.mark.parametrize("skin_frac", [0.0, 0.5])
def test_backend_trajectories_agree(skin_frac):
    backends = ["reference", "xla", "pallas"]
    if ON_TPU is False and skin_frac > 0:
        # interpret-mode pallas is slow; the skinned pallas case is
        # covered by the stale-binning unit test above
        backends = ["reference", "xla"]
    nsteps = 15
    outs = {}
    for be in backends:
        # the skinned case needs cells covering r + skin AND >= 3 cells
        # on the periodic axis -> finer spacing
        cfg, st = _poiseuille(
            be, ds=0.05 if skin_frac > 0 else 0.1, skin_frac=skin_frac
        )
        out = solver.simulate(cfg, st, nsteps)
        outs[be] = (
            np.asarray(solver.positions(cfg, out)),
            np.asarray(out.fluid.v),
            np.asarray(out.fluid.rho),
        )
    ref = outs["reference"]
    for be in backends[1:]:
        np.testing.assert_allclose(outs[be][0], ref[0], atol=1e-6)
        np.testing.assert_allclose(outs[be][1], ref[1], atol=1e-7)
        np.testing.assert_allclose(outs[be][2], ref[2], atol=1e-6)


# --------------------------------------------------------------------------
# overflow surfacing
# --------------------------------------------------------------------------
def test_overflow_reported_in_stats():
    cfg, st = _poiseuille("xla", max_neighbors=4)  # far too small
    _, stats = solver.simulate_stats(cfg, st, 3)
    assert bool(stats.overflow)


def test_check_overflow_raises():
    cfg, st = _poiseuille("xla", max_neighbors=4)
    cfg = dataclasses.replace(cfg, check_overflow=True)
    with pytest.raises(Exception, match="overflow"):
        jax.block_until_ready(solver.simulate_stats(cfg, st, 3))


def test_check_overflow_silent_when_sized_right():
    cfg, st = _poiseuille("xla")
    cfg = dataclasses.replace(cfg, check_overflow=True)
    out, stats = solver.simulate_stats(cfg, st, 3)
    jax.block_until_ready(out)
    assert not bool(stats.overflow)


# --------------------------------------------------------------------------
# donating scan entry point
# --------------------------------------------------------------------------
def test_run_persistent_matches_simulate():
    cfg, st = _poiseuille("xla")
    want = solver.simulate(cfg, st, 12)
    carry = solver.init_persistent(cfg, st)
    for _ in range(3):  # chained segments, carry donated each call
        carry = solver.run_persistent(cfg, carry, 4)
    got = solver.finalize_persistent(cfg, carry)
    np.testing.assert_allclose(
        np.asarray(solver.positions(cfg, got)),
        np.asarray(solver.positions(cfg, want)), atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(got.fluid.v), np.asarray(want.fluid.v), atol=1e-7
    )
    assert int(carry.steps) == 12
