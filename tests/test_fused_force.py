"""Fused cell-blocked force pass: backend agreement (reference / xla /
pallas-interpret), half-width record quantization (derived tolerance +
bit-exactness), stale-binning re-anchoring under cell migration,
overflow surfacing, and the donating scan entry point."""
import dataclasses

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import cases, cells, domain as D, fused, rcll, solver, sph
from repro.core.precision import FP32_RECORDS, PrecisionPolicy

ON_TPU = jax.default_backend() == "tpu"

C0, RHO0 = 1.25, 1.0


def _poiseuille(backend, *, ds=0.1, skin_frac=0.0, records="fp32", **kw):
    kw.setdefault("max_neighbors", 96 if skin_frac > 0 else 40)
    case = cases.PoiseuilleCase(
        ds=ds, Lx=0.8, algo="rcll", backend=backend,
        cell_factor=2.0 if skin_frac > 0 else 1.0,
        policy=PrecisionPolicy(records=records),
        **kw,
    )
    cfg, st = case.build()
    if skin_frac > 0:
        cfg = dataclasses.replace(
            cfg, skin=skin_frac * min(cfg.domain.cell_sizes)
        )
    return cfg, st


def _cloud_setup(n=800, seed=0, k=256):
    """Random cloud + packed state + skin-inflated list (no overflow)."""
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=1.2 * ds, cell_factor=2.0)
    x = rng.uniform(0, 1, (n, 2))
    rc = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    cfg = solver.SPHConfig(
        domain=dom, ds=ds, dt=1e-3, max_neighbors=k, algo="rcll",
        skin=0.5 * min(dom.cell_sizes),
    )
    cfg.validate_skin()
    cap = cells.default_capacity(dom, n, safety=8.0)
    ps = rcll.pack_state(dom, rc, cap)
    nl = rcll.packed_neighbors(
        dom, ps, dtype=jnp.float16, compute_dtype=jnp.float32, k=k,
        radius_cell=cfg.search_radius_cell,
    )
    assert not bool(nl.overflowed)
    fields = dict(
        v=jnp.asarray(rng.normal(size=(n, 2)) * 0.1, jnp.float32),
        m=jnp.full((n,), 1.0 / n, jnp.float32),
        rho=jnp.asarray(1.0 + 0.01 * rng.normal(size=(n,)), jnp.float32),
    )
    return dom, cfg, ps, nl, fields


def _reference_rhs(dom, rc, nl, v, m, rho, *, h, mu, rho0=RHO0, c0=C0):
    disp, r = rcll.pair_displacements(dom, rc, nl)
    gw = sph.grad_w(disp, r, h, dom.dim, nl.mask)
    pf = sph.gather_pair_fields(v, m, nl.idx, nl.mask)
    drho = sph.continuity_rhs_pairs(pf, gw)
    p = sph.eos_tait(rho, rho0, c0)
    acc = sph.momentum_rhs_pairs(
        pf, rho, p, nl.idx, gw, disp, r, h=h, mu=mu,
        body_force=jnp.zeros((dom.dim,), jnp.float32),
    )
    return drho, acc, p


# --------------------------------------------------------------------------
# drho / acc agreement on a static configuration
# --------------------------------------------------------------------------
def test_fused_xla_rhs_matches_reference():
    dom, cfg, ps, nl, f = _cloud_setup()
    drho_r, acc_r, p = _reference_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    for chunk in (0, 100, 10**6):  # padded map, odd chunk, single chunk
        drho_f, acc_f = fused.force_rhs(
            dom, ps.rc, nl, f["v"], f["m"], f["rho"],
            c0=C0, rho0=RHO0, chunk=chunk, mu=1.0,
        )
        np.testing.assert_allclose(drho_f, drho_r, rtol=2e-5, atol=1e-5)
        np.testing.assert_allclose(acc_f, acc_r, rtol=2e-5, atol=2e-3)


def test_fused_pallas_rhs_matches_reference():
    from repro.kernels import ops

    dom, cfg, ps, nl, f = _cloud_setup()
    drho_r, acc_r, p = _reference_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    drho_k, acc_k = ops.rcll_force_particles(
        dom, ps.packing.binning, ps.rc, f["v"], f["m"], f["rho"],
        mu=1.0, c0=C0, rho0=RHO0, interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_k, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_k, acc_r, rtol=2e-5, atol=2e-3)


def test_fused_pallas_stale_binning_with_migrations():
    """Between Verlet rebuilds the binning is stale; particles that
    migrated cells must decode exactly via the int8 shift re-anchor."""
    from repro.kernels import ops

    rng = np.random.default_rng(3)
    dom, cfg, ps, nl, f = _cloud_setup(seed=3)
    n = ps.rc.rel.shape[0]
    # displace by < skin/2 in random directions -> boundary-adjacent
    # particles migrate cells while the neighbor list stays valid
    dxn = jnp.asarray(rng.uniform(-1, 1, (n, 2)), jnp.float32)
    dxn = dxn / jnp.linalg.norm(dxn, axis=1, keepdims=True) * (
        0.45 * cfg.skin_norm / 2
    )
    rc1 = rcll.advance(dom, ps.rc, dxn, dtype=jnp.float16)
    migrated = np.any(
        np.asarray(rc1.cell_xy) != np.asarray(ps.rc.cell_xy), axis=1
    )
    assert migrated.sum() > 0, "setup must actually migrate particles"

    drho_r, acc_r, p = _reference_rhs(
        dom, rc1, nl, f["v"], f["m"], f["rho"], h=dom.h, mu=1.0
    )
    drho_k, acc_k = ops.rcll_force_particles(
        dom, ps.packing.binning, rc1, f["v"], f["m"], f["rho"],
        mu=1.0, c0=C0, rho0=RHO0, interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_k, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_k, acc_r, rtol=2e-5, atol=2e-3)
    # fused xla path too (consumes the same stale list + current state)
    drho_f, acc_f = fused.force_rhs(
        dom, rc1, nl, f["v"], f["m"], f["rho"], c0=C0, rho0=RHO0, mu=1.0
    )
    np.testing.assert_allclose(drho_f, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_f, acc_r, rtol=2e-5, atol=2e-3)


# --------------------------------------------------------------------------
# half-width record quantization
# --------------------------------------------------------------------------
def _quantize(x, dtype):
    return jnp.asarray(x).astype(dtype).astype(jnp.float32)


@pytest.mark.parametrize("records", ["fp16", "bf16"])
def test_half_records_match_quantized_oracle(records):
    """The half-width sweep IS fp32 arithmetic on records-quantized v/m:
    it must tightly match the fp32 reference path evaluated on the
    pre-quantized inputs (same tolerances as the fp32-record tests)."""
    rdt = {"fp16": jnp.float16, "bf16": jnp.bfloat16}[records]
    dom, cfg, ps, nl, f = _cloud_setup(seed=5)
    vq = _quantize(f["v"], rdt)
    # m is stored normalized by the mean mass (fp16 subnormal guard);
    # quantize the oracle's m at the same point
    s = fused.mass_scale(f["m"])
    mq = _quantize(f["m"] / s, rdt) * s
    drho_r, acc_r, _ = _reference_rhs(
        dom, ps.rc, nl, vq, mq, f["rho"], h=dom.h, mu=1.0
    )
    drho_h, acc_h = fused.force_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"],
        c0=C0, rho0=RHO0, mu=1.0, records=records,
    )
    np.testing.assert_allclose(drho_h, drho_r, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_h, acc_r, rtol=2e-5, atol=2e-3)


def test_half_records_within_derived_tolerance():
    """drho under fp16 records agrees with fp32 records within the bound
    DERIVED from the actual quantization deltas:

      |Δdrho_i| <= Σ_j [ |Δm_j| |dv·∇W| + m_j Σ_a (|Δv_i|+|Δv_j|)_a |∇W_a| ]

    plus an fp32 round-off allowance."""
    dom, cfg, ps, nl, f = _cloud_setup(seed=7)
    v, m, rho = f["v"], f["m"], f["rho"]
    drho32, acc32 = fused.force_rhs(
        dom, ps.rc, nl, v, m, rho, c0=C0, rho0=RHO0, mu=1.0, records="fp32"
    )
    drho16, acc16 = fused.force_rhs(
        dom, ps.rc, nl, v, m, rho, c0=C0, rho0=RHO0, mu=1.0, records="fp16"
    )
    # derived per-particle bound from the true quantization deltas
    disp, r = rcll.pair_displacements(dom, ps.rc, nl)
    gw = np.abs(np.asarray(sph.grad_w(disp, r, dom.h, dom.dim, nl.mask)))
    # invalid slots hold the dummy id N (window-search padding): clip
    # for the numpy gathers below — every use is masked by ``mask``.
    idx = np.minimum(np.asarray(nl.idx), v.shape[0] - 1)
    mask = np.asarray(nl.mask)
    dv = np.abs(np.asarray(v)[:, None, :] - np.asarray(v)[idx])
    dm = np.abs(np.asarray(m) - np.asarray(_quantize(m, jnp.float16)))
    dv_err = np.abs(np.asarray(v) - np.asarray(_quantize(v, jnp.float16)))
    pair_dv_err = dv_err[:, None, :] + dv_err[idx]
    mj = np.where(mask, np.asarray(m)[idx], 0.0)
    bound = (
        np.sum(dm[idx] * mask * np.sum(dv * gw, -1), -1)
        + np.sum(mj * np.sum(pair_dv_err * gw, -1), -1)
    )
    slack = 1e-5 * (1.0 + np.abs(np.asarray(drho32)))
    err = np.abs(np.asarray(drho16) - np.asarray(drho32))
    assert np.all(err <= bound + slack), float((err - bound).max())
    # acc stays within the same order: quantization-dominated, bounded
    scale = np.abs(np.asarray(acc32)).max()
    assert np.abs(np.asarray(acc16) - np.asarray(acc32)).max() < 2e-3 * (
        1.0 + scale
    )


def test_half_records_bit_exact_on_grid():
    """Where v and m are exactly representable in fp16 the half-width
    sweep is BIT-identical to the fp32-record sweep: both decode to the
    same fp32 values (q = I + rel/2 is exact either way, the EOS fold is
    the same expression) and run the same ``_pair_rhs`` arithmetic."""
    dom, cfg, ps, nl, f = _cloud_setup(seed=9)
    n = ps.rc.rel.shape[0]
    rng = np.random.default_rng(9)
    # v on the 2^-8 grid, |v| < 1; m a power of two: all fp16-exact
    v = jnp.asarray(
        rng.integers(-256, 257, (n, 2)).astype(np.float32) / 256.0
    )
    m = jnp.full((n,), 2.0**-10, jnp.float32)
    for chunk in (0, 100):
        drho32, acc32 = fused.force_rhs(
            dom, ps.rc, nl, v, m, f["rho"],
            c0=C0, rho0=RHO0, chunk=chunk, mu=1.0, records="fp32",
        )
        drho16, acc16 = fused.force_rhs(
            dom, ps.rc, nl, v, m, f["rho"],
            c0=C0, rho0=RHO0, chunk=chunk, mu=1.0, records="fp16",
        )
        np.testing.assert_array_equal(
            np.asarray(drho16), np.asarray(drho32)
        )
        np.testing.assert_array_equal(np.asarray(acc16), np.asarray(acc32))


def test_half_records_survive_tiny_masses():
    """Raw SPH masses below fp16's subnormal range (< 6e-8) would store
    as exactly 0 and silently zero all forces; the mean-mass
    normalization keeps full precision at any resolution scale."""
    from repro.kernels import ops

    dom, cfg, ps, nl, f = _cloud_setup(seed=13)
    n = ps.rc.rel.shape[0]
    m_tiny = jnp.full((n,), 2e-8, jnp.float32)  # flushes to 0 in fp16
    assert float(m_tiny.astype(jnp.float16)[0]) == 0.0
    drho32, acc32 = fused.force_rhs(
        dom, ps.rc, nl, f["v"], m_tiny, f["rho"],
        c0=C0, rho0=RHO0, mu=1.0, records="fp32",
    )
    drho16, acc16 = fused.force_rhs(
        dom, ps.rc, nl, f["v"], m_tiny, f["rho"],
        c0=C0, rho0=RHO0, mu=1.0, records="fp16",
    )
    assert float(jnp.max(jnp.abs(drho32))) > 0
    # near-zero sums cancel, so tolerance scales with the field magnitude
    atol_d = 2e-3 * float(jnp.max(jnp.abs(drho32)))
    atol_a = 2e-3 * float(jnp.max(jnp.abs(acc32)))
    np.testing.assert_allclose(drho16, drho32, rtol=2e-3, atol=atol_d)
    np.testing.assert_allclose(acc16, acc32, rtol=2e-3, atol=atol_a)
    drho_p, acc_p = ops.rcll_force_particles(
        dom, ps.packing.binning, ps.rc, f["v"], m_tiny, f["rho"],
        mu=1.0, c0=C0, rho0=RHO0, records_dtype=jnp.float16,
        interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_p, drho32, rtol=2e-3, atol=atol_d)
    np.testing.assert_allclose(acc_p, acc32, rtol=2e-3, atol=atol_a)


def test_half_records_pallas_matches_xla():
    """Both half-width backends quantize identically and decode in fp32:
    they agree to reduction-order round-off."""
    from repro.kernels import ops

    dom, cfg, ps, nl, f = _cloud_setup(seed=11)
    drho_x, acc_x = fused.force_rhs(
        dom, ps.rc, nl, f["v"], f["m"], f["rho"],
        c0=C0, rho0=RHO0, mu=1.0, records="fp16",
    )
    drho_p, acc_p = ops.rcll_force_particles(
        dom, ps.packing.binning, ps.rc, f["v"], f["m"], f["rho"],
        mu=1.0, c0=C0, rho0=RHO0, records_dtype=jnp.float16,
        interpret=not ON_TPU,
    )
    np.testing.assert_allclose(drho_p, drho_x, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(acc_p, acc_x, rtol=2e-5, atol=2e-3)


def test_half_records_reject_huge_grids():
    """16-bit cell anchors cap the grid per axis (fp16: 2^11) — loudly."""
    from repro.core import nnps

    dom = D.Domain(lo=(0.0, 0.0), hi=(2000.0, 1.0), h=0.2)
    assert max(dom.ncells) >= 1 << 11
    n = 8
    rc = rcll.init_state(dom, jnp.zeros((n, 2)), jnp.float16)
    nl = nnps.NeighborList(
        idx=jnp.zeros((n, 4), jnp.int32),
        mask=jnp.zeros((n, 4), bool),
        count=jnp.zeros((n,), jnp.int32),
    )
    with pytest.raises(ValueError, match="16-bit"):
        fused.force_rhs(
            dom, rc, nl, jnp.zeros((n, 2)), jnp.ones((n,)), jnp.ones((n,)),
            c0=C0, rho0=RHO0, records="fp16",
        )
    # the solver degrades gracefully instead: fp32 layout past the cap
    cfg = solver.SPHConfig(domain=dom, ds=0.1, dt=1e-3, algo="rcll")
    assert solver._resolved_records(cfg) == "fp32"
    small = solver.SPHConfig(
        domain=D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=0.2),
        ds=0.1, dt=1e-3, algo="rcll",
    )
    assert solver._resolved_records(small) == "fp16"


# --------------------------------------------------------------------------
# end-to-end trajectories across skin settings
# --------------------------------------------------------------------------
@pytest.mark.parametrize("skin_frac", [0.0, 0.5])
def test_backend_trajectories_agree(skin_frac):
    """Cross-backend EXACTNESS oracle: pinned to fp32 records (the
    reference gather path has no record quantization to compare to)."""
    backends = ["reference", "xla", "pallas"]
    if ON_TPU is False and skin_frac > 0:
        # interpret-mode pallas is slow; the skinned pallas case is
        # covered by the stale-binning unit test above
        backends = ["reference", "xla"]
    nsteps = 15
    outs = {}
    for be in backends:
        # the skinned case needs cells covering r + skin AND >= 3 cells
        # on the periodic axis -> finer spacing
        cfg, st = _poiseuille(
            be, ds=0.05 if skin_frac > 0 else 0.1, skin_frac=skin_frac,
            records="fp32",
        )
        out = solver.simulate(cfg, st, nsteps)
        outs[be] = (
            np.asarray(solver.positions(cfg, out)),
            np.asarray(out.fluid.v),
            np.asarray(out.fluid.rho),
        )
    ref = outs["reference"]
    for be in backends[1:]:
        np.testing.assert_allclose(outs[be][0], ref[0], atol=1e-6)
        np.testing.assert_allclose(outs[be][1], ref[1], atol=1e-7)
        np.testing.assert_allclose(outs[be][2], ref[2], atol=1e-6)


def test_half_record_trajectory_tracks_fp32():
    """End-to-end: the default (fp16-record) production path stays within
    a small fraction of the particle spacing of the fp32-record oracle
    over a short run — record quantization perturbs forces at the fp16
    ulp level, it does not change the flow."""
    cfg16, st16 = _poiseuille("xla", records="fp16")
    cfg32, st32 = _poiseuille("xla", records="fp32")
    out16 = solver.simulate(cfg16, st16, 40)
    out32 = solver.simulate(cfg32, st32, 40)
    p16 = np.asarray(solver.positions(cfg16, out16))
    p32 = np.asarray(solver.positions(cfg32, out32))
    assert np.abs(p16 - p32).max() < 1e-3 * cfg32.ds
    v16, v32 = np.asarray(out16.fluid.v), np.asarray(out32.fluid.v)
    assert np.abs(v16 - v32).max() < 1e-6 + 1e-2 * np.abs(v32).max()


# --------------------------------------------------------------------------
# overflow surfacing
# --------------------------------------------------------------------------
def test_overflow_reported_in_stats():
    cfg, st = _poiseuille("xla", max_neighbors=4)  # far too small
    _, stats = solver.simulate_stats(cfg, st, 3)
    assert bool(stats.overflow)


def test_check_overflow_raises():
    cfg, st = _poiseuille("xla", max_neighbors=4)
    cfg = dataclasses.replace(cfg, check_overflow=True)
    with pytest.raises(Exception, match="overflow"):
        jax.block_until_ready(solver.simulate_stats(cfg, st, 3))


def test_check_overflow_silent_when_sized_right():
    cfg, st = _poiseuille("xla")
    cfg = dataclasses.replace(cfg, check_overflow=True)
    out, stats = solver.simulate_stats(cfg, st, 3)
    jax.block_until_ready(out)
    assert not bool(stats.overflow)


# --------------------------------------------------------------------------
# donating scan entry point
# --------------------------------------------------------------------------
def test_run_persistent_matches_simulate():
    cfg, st = _poiseuille("xla")
    want = solver.simulate(cfg, st, 12)
    carry = solver.init_persistent(cfg, st)
    for _ in range(3):  # chained segments, carry donated each call
        carry = solver.run_persistent(cfg, carry, 4)
    got = solver.finalize_persistent(cfg, carry)
    np.testing.assert_allclose(
        np.asarray(solver.positions(cfg, got)),
        np.asarray(solver.positions(cfg, want)), atol=1e-7,
    )
    np.testing.assert_allclose(
        np.asarray(got.fluid.v), np.asarray(want.fluid.v), atol=1e-7
    )
    assert int(carry.steps) == 12


# --------------------------------------------------------------------------
# dynamic case: backend agreement across in-scan rebuilds
# --------------------------------------------------------------------------
def test_dynamic_dam_break_backends_agree_with_rebuilds():
    """Acceptance criterion for the rebuild round: reference vs xla vs
    pallas agree on a DYNAMIC case whose Verlet criterion fires >= 3
    in-scan rebuilds (the dropped-column dam break the --dynamic
    benchmark runs). Pinned to fp32 records (the exactness oracle)."""
    from repro.core import cases

    nsteps = 120
    backends = ["reference", "xla"]
    if ON_TPU:
        backends.append("pallas")
    outs, rebuilds = {}, {}
    for be in backends:
        ds = 0.08
        radius = 2.0 * cases.build_case("dam_break", ds=ds).h
        case = cases.build_case(
            "dam_break", ds=ds, backend=be, cell_factor=1.5,
            skin=0.25 * radius, v0=1.0, max_neighbors=64,
            policy=FP32_RECORDS,
        )
        cfg, st = case.build()
        out, stats = solver.simulate_stats(cfg, st, nsteps)
        outs[be] = (
            np.asarray(solver.positions(cfg, out)),
            np.asarray(out.fluid.v),
            np.asarray(out.fluid.rho),
        )
        rebuilds[be] = int(stats.rebuilds)
        assert not bool(stats.overflow), be
    # init build + >= 3 genuinely dynamic in-scan rebuilds
    assert rebuilds["reference"] >= 4, rebuilds
    assert rebuilds["xla"] == rebuilds["reference"], rebuilds
    ref = outs["reference"]
    for be in backends[1:]:
        np.testing.assert_allclose(outs[be][0], ref[0], atol=2e-5)
        np.testing.assert_allclose(outs[be][1], ref[1], atol=2e-5)
        np.testing.assert_allclose(outs[be][2], ref[2], atol=2e-5)


def test_dynamic_dam_break_pallas_short():
    """The pallas backend on the same dynamic path (shorter horizon:
    interpret mode pays per-call overhead on CPU), including at least
    one in-scan rebuild with migrated particles re-anchored against the
    stale binning."""
    from repro.core import cases

    nsteps = 40
    outs = {}
    for be in ["reference", "pallas"]:
        ds = 0.1
        radius = 2.0 * cases.build_case("dam_break", ds=ds).h
        case = cases.build_case(
            "dam_break", ds=ds, backend=be, cell_factor=1.5,
            skin=0.125 * radius, v0=1.0, max_neighbors=64,
            policy=FP32_RECORDS,
        )
        cfg, st = case.build()
        out, stats = solver.simulate_stats(cfg, st, nsteps)
        outs[be] = np.asarray(solver.positions(cfg, out))
        assert int(stats.rebuilds) >= 2, be
    np.testing.assert_allclose(outs["pallas"], outs["reference"],
                               atol=1e-4)


def test_pallas_fp32_coords_not_quantized():
    """APPROACH_I stores rel as fp32; the cell-pack record slabs must
    stream it losslessly (fp32 slab), not quantize it through the
    16-bit row — the pallas RHS then matches the reference gather path
    to fp32 round-off, not fp16 coordinate granularity."""
    from repro.kernels import ops

    rng = np.random.default_rng(11)
    n = 600
    ds = (1.0 / n) ** 0.5
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    rc = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float32)
    assert rc.rel.dtype == jnp.float32
    cap = cells.default_capacity(dom, n, safety=8.0)
    ps = rcll.pack_state(dom, rc, cap)
    k = 96
    nl = rcll.packed_neighbors(
        dom, ps, dtype=jnp.float32, compute_dtype=jnp.float32, k=k
    )
    v = jnp.asarray(rng.normal(size=(n, 2)) * 0.1, jnp.float32)
    m = jnp.full((n,), 1.0 / n, jnp.float32)
    rho = jnp.asarray(1.0 + 0.01 * rng.normal(size=(n,)), jnp.float32)
    drho_r, acc_r, _ = _reference_rhs(
        dom, ps.rc, nl, v, m, rho, h=dom.h, mu=1.0
    )
    drho_k, acc_k = ops.rcll_force_particles(
        dom, ps.packing.binning, ps.rc, v, m, rho,
        mu=1.0, c0=C0, rho0=RHO0, interpret=not ON_TPU,
    )
    # fp16-quantized coordinates miss by ~1e-4 RELATIVE (measured when
    # the bug existed); fp32 summation round-off sits below ~3e-5, so
    # this tolerance separates the two regimes cleanly
    np.testing.assert_allclose(drho_k, drho_r, rtol=1e-5, atol=2e-6)
    np.testing.assert_allclose(acc_k, acc_r, rtol=1e-5, atol=1e-4)
