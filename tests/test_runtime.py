"""Fault-tolerance runtime logic."""
import time

from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, HeartbeatWriter, StragglerWatchdog,
    plan_elastic_mesh)


def test_heartbeat_roundtrip(tmp_path):
    w0 = HeartbeatWriter(str(tmp_path), 0)
    w1 = HeartbeatWriter(str(tmp_path), 1)
    w0.beat(5)
    w1.beat(5)
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=60)
    assert sorted(mon.alive_hosts()) == [0, 1]
    assert mon.dead_hosts(expected=3) == [2]


def test_heartbeat_timeout(tmp_path):
    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(1)
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=0.05)
    time.sleep(0.1)
    assert mon.dead_hosts(expected=1) == [0]


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, patience=2)
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)  # straggler event
    assert not wd.flagged  # needs `patience` consecutive
    assert wd.observe(5.0)
    assert wd.flagged
    # baseline not poisoned by slow steps
    assert wd.ema < 1.5


def test_plan_elastic_mesh():
    full = plan_elastic_mesh(256, model_parallel=16, global_batch=256)
    assert full["mesh_shape"] == (16, 16)
    assert full["drop_devices"] == 0
    # lose a host (8 chips): 248 available -> data axis shrinks
    sm = plan_elastic_mesh(248, model_parallel=16, global_batch=256)
    data = sm["mesh_shape"][0]
    assert data * 16 <= 248
    assert 256 % data == 0
