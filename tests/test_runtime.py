"""Fault-tolerance runtime logic."""
import os
import time

from repro.runtime.fault_tolerance import (
    HeartbeatMonitor, HeartbeatWriter, StragglerWatchdog,
    plan_elastic_mesh)


def test_heartbeat_roundtrip(tmp_path):
    w0 = HeartbeatWriter(str(tmp_path), 0)
    w1 = HeartbeatWriter(str(tmp_path), 1)
    w0.beat(5)
    w1.beat(5)
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=60)
    assert sorted(mon.alive_hosts()) == [0, 1]
    assert mon.dead_hosts(expected=3) == [2]


def test_heartbeat_timeout(tmp_path):
    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(1)
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=0.05, skew_s=0.0)
    time.sleep(0.1)
    assert mon.dead_hosts(expected=1) == [0]


def test_heartbeat_clear_removes_file(tmp_path):
    """Clean shutdown removes the heartbeat (and any torn .tmp), so a
    later resume reads "absent" instead of mistaking the clean exit
    for a dead process. clear() is idempotent."""
    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(7)
    with open(w.path + ".tmp", "w") as f:
        f.write("{")  # a torn in-flight write the crash left behind
    w.clear()
    assert not os.path.exists(w.path)
    assert not os.path.exists(w.path + ".tmp")
    w.clear()  # idempotent: nothing to remove is not an error


def test_host_status_tristate(tmp_path):
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=60)
    # never started
    assert mon.host_status(0) == "absent"
    # fresh beat
    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(1)
    assert mon.host_status(0) == "alive"
    # stale beat: the process stopped beating without clear()
    stale = HeartbeatMonitor(str(tmp_path), timeout_s=0.01, skew_s=0.0)
    time.sleep(0.05)
    assert stale.host_status(0) == "dead"
    # clean shutdown: back to absent, NOT dead
    w.clear()
    assert stale.host_status(0) == "absent"
    # corrupt file (killed mid-write after replace): counts as dead
    with open(w.path, "w") as f:
        f.write("{not json")
    assert mon.host_status(0) == "dead"


def test_heartbeat_staleness_ignores_forged_wall_time(tmp_path):
    """Liveness is judged by the heartbeat file's mtime, NOT the wall
    time recorded inside it: an NTP step or suspend/resume that shifts
    the writer's clock must not flip a beating host dead (or keep a
    dead one alive)."""
    import json

    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(3)
    with open(w.path) as f:
        rec = json.load(f)
    # forge `t` an hour in the past (writer clock stepped backward);
    # the file itself is fresh on disk -> still alive
    rec["t"] -= 3600.0
    with open(w.path, "w") as f:
        json.dump(rec, f)
    mon = HeartbeatMonitor(str(tmp_path), timeout_s=60)
    assert mon.host_status(0) == "alive"
    # the recorded wall time survives as a diagnostic in the record
    assert mon.alive_hosts()[0]["t"] == rec["t"]
    # forge `t` an hour in the FUTURE but age the file on disk past
    # timeout+skew -> dead, regardless of the optimistic record
    rec["t"] = time.time() + 3600.0
    with open(w.path, "w") as f:
        json.dump(rec, f)
    old = time.time() - 100.0
    os.utime(w.path, (old, old))
    stale = HeartbeatMonitor(str(tmp_path), timeout_s=60, skew_s=2.0)
    assert stale.host_status(0) == "dead"
    assert 0 not in stale.alive_hosts()


def test_heartbeat_skew_allowance(tmp_path):
    """skew_s widens the mtime staleness window (coarse-mtime or NFS
    filesystems); zero skew is the strict wall-clock-free check."""
    w = HeartbeatWriter(str(tmp_path), 0)
    w.beat(1)
    old = time.time() - 5.0
    os.utime(w.path, (old, old))
    lax = HeartbeatMonitor(str(tmp_path), timeout_s=4.0, skew_s=2.0)
    strict = HeartbeatMonitor(str(tmp_path), timeout_s=4.0, skew_s=0.0)
    assert lax.host_status(0) == "alive"
    assert strict.host_status(0) == "dead"


def test_straggler_watchdog():
    wd = StragglerWatchdog(threshold=2.0, patience=2)
    for _ in range(10):
        assert not wd.observe(1.0)
    assert wd.observe(5.0)  # straggler event
    assert not wd.flagged  # needs `patience` consecutive
    assert wd.observe(5.0)
    assert wd.flagged
    # baseline not poisoned by slow steps
    assert wd.ema < 1.5


def test_plan_elastic_mesh():
    full = plan_elastic_mesh(256, model_parallel=16, global_batch=256)
    assert full["mesh_shape"] == (16, 16)
    assert full["drop_devices"] == 0
    # lose a host (8 chips): 248 available -> data axis shrinks
    sm = plan_elastic_mesh(248, model_parallel=16, global_batch=256)
    data = sm["mesh_shape"][0]
    assert data * 16 <= 248
    assert 256 % data == 0
