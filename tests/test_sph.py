"""SPH discretization: kernel, gradient operators, governing equations."""
import numpy as np
import jax.numpy as jnp

from repro.core import cases, domain as D, nnps, rcll, sph


def test_kernel_normalization_2d():
    """integral of W over the plane = 1."""
    h = 0.1
    g = np.linspace(-2 * h, 2 * h, 201)
    X, Y = np.meshgrid(g, g)
    r = jnp.asarray(np.sqrt(X**2 + Y**2))
    w = sph.bspline_w(r, h, 2)
    integral = float(jnp.sum(w)) * (g[1] - g[0]) ** 2
    assert abs(integral - 1.0) < 1e-3


def test_kernel_compact_support_and_derivative():
    h = 0.1
    r = jnp.asarray([0.0, 0.5 * h, h, 1.9 * h, 2 * h, 3 * h])
    w = np.asarray(sph.bspline_w(r, h, 2))
    dw = np.asarray(sph.bspline_dw_dr(r, h, 2))
    assert w[-1] == 0 and w[-2] == 0
    assert dw[0] == 0  # extremum at r=0
    assert np.all(dw[1:4] < 0)  # monotone decreasing inside support


def _grad_setup(ds, jitter=0.2, dtype=jnp.float16):
    dom, x = cases.gradient_test_particles(ds, jitter=jitter)
    xn = dom.normalize(jnp.asarray(x))
    st = rcll.init_state(dom, xn, dtype=dtype)
    nl, _ = rcll.neighbors(dom, st, dtype=dtype,
                           k=64)
    disp, r = rcll.pair_displacements(dom, st, nl)
    return dom, x, nl, disp, r


def test_gradient_exact_on_linear_field():
    """The A5 normalized operator is exact for linear f by construction."""
    dom, x, nl, disp, r = _grad_setup(0.05)
    f = jnp.asarray(2.5 * x[:, 0] - 1.0, jnp.float32)
    g = sph.gradient_normalized_pairs(f, disp, r, nl.idx, nl.mask,
                                      dom.h, 2)
    interior = (np.abs(x - 0.5) < 0.4).all(axis=1)
    np.testing.assert_allclose(np.asarray(g)[interior, 0], 2.5, atol=2e-3)


def test_gradient_first_order_convergence_table3():
    """RMSE of d(x^3)/dx halves with ds (paper Table 3 trend), and the
    fp16-RCLL neighbor list gives the same RMSE as fp32 (Table 3's
    claim that FP16 NNPS does not degrade the gradient)."""
    errs = {}
    for ds in (0.04, 0.02, 0.01):
        for dtype in (jnp.float32, jnp.float16):
            dom, x, nl, disp, r = _grad_setup(ds, dtype=dtype)
            f = jnp.asarray(cases.cubic_field(jnp.asarray(x)), jnp.float32)
            g = sph.gradient_normalized_pairs(
                f, disp, r, nl.idx, nl.mask, dom.h, 2)[:, 0]
            want = np.asarray(cases.cubic_gradient_x(jnp.asarray(x)))
            interior = (np.abs(x - 0.5) < 0.5 - 2.5 * dom.h).all(axis=1)
            rmse = float(np.sqrt(np.mean(
                (np.asarray(g)[interior] - want[interior]) ** 2)))
            errs[(ds, dtype.__name__)] = rmse
    # 1st order: error ratio ~2 per halving (allow slack)
    assert errs[(0.02, 'float32')] < 0.75 * errs[(0.04, 'float32')]
    assert errs[(0.01, 'float32')] < 0.75 * errs[(0.02, 'float32')]
    for ds in (0.04, 0.02, 0.01):
        a, b = errs[(ds, 'float32')], errs[(ds, 'float16')]
        assert abs(a - b) / a < 0.05, (ds, a, b)


def test_density_summation_near_rho0(rng):
    ds = 0.025
    dom, x = cases.gradient_test_particles(ds, jitter=0.0)
    xn = dom.normalize(jnp.asarray(x))
    st = rcll.init_state(dom, xn, dtype=jnp.float32)
    nl, _ = rcll.neighbors(dom, st, dtype=jnp.float32, k=64)
    disp, r = rcll.pair_displacements(dom, st, nl)
    n = x.shape[0]
    fl = sph.FluidState(v=jnp.zeros((n, 2)),
                        rho=jnp.ones((n,)),
                        m=jnp.full((n,), ds * ds))
    rho = sph.density_summation(fl, nl.idx, nl.mask, r, dom.h, 2)
    interior = (np.abs(x - 0.5) < 0.5 - 2.5 * dom.h).all(axis=1)
    np.testing.assert_allclose(np.asarray(rho)[interior], 1.0, rtol=2e-2)
