"""Per-arch smoke tests (reduced configs, assignment requirement) +
decode-vs-forward consistency."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.models import registry


def _batch(cfg, B, L, rng):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, L)),
                                   jnp.int32)}
    batch["labels"] = batch["tokens"]
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.normal(size=(B, cfg.src_len, cfg.d_model)), jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch, rng):
    """Assignment: reduced config, one forward/train step on CPU,
    correct shapes, no NaNs."""
    cfg = registry.get_config(arch, smoke=True)
    mod = registry.get_module(cfg)
    params = mod.init_params(jax.random.key(0), cfg)
    B, L = 2, 32
    batch = _batch(cfg, B, L, rng)
    lg, _, _ = jax.jit(
        lambda p, b: mod.forward(p, b["tokens"], cfg, **{
            k: v for k, v in b.items()
            if k in ("frames", "patch_embeds")}))(params, batch)
    assert lg.shape == (B, L, cfg.vocab)
    assert not bool(jnp.any(jnp.isnan(lg)))
    loss, _ = jax.jit(lambda p, b: mod.loss_fn(p, b, cfg))(params, batch)
    grads = jax.jit(jax.grad(lambda p, b: mod.loss_fn(p, b, cfg)[0]))(
        params, batch)
    assert np.isfinite(float(loss))
    flat = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in flat)
    assert any(float(jnp.max(jnp.abs(g))) > 0 for g in flat)


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
def test_arch_smoke_prefill_decode(arch, rng):
    cfg = registry.get_config(arch, smoke=True)
    mod = registry.get_module(cfg)
    params = mod.init_params(jax.random.key(0), cfg)
    B, L, max_len = 2, 32, 64
    batch = _batch(cfg, B, L, rng)
    kw = {k: v for k, v in batch.items() if k in ("frames", "patch_embeds")}
    lg, cache = jax.jit(
        lambda p, t: mod.prefill(p, t, cfg, max_len, **kw))(
            params, batch["tokens"])
    assert lg.shape == (B, L, cfg.vocab)
    tok = jnp.argmax(lg[:, -1:], axis=-1).astype(jnp.int32)
    for _ in range(3):
        lg2, cache = jax.jit(
            lambda p, t, c: mod.decode_step(p, t, c, cfg))(
                params, tok, cache)
        assert lg2.shape == (B, 1, cfg.vocab)
        assert not bool(jnp.any(jnp.isnan(lg2)))
        tok = jnp.argmax(lg2, axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", [
    "llama3.2-3b", "mamba2-130m",
    # deepseek (mla_moe): the smoke config has a near-tie in the top-k
    # router, and XLA compiles the scanned full-sequence forward
    # differently from the decode path (different fusion -> different
    # bf16 round-off), which can flip one expert choice and swing the
    # logits of that batch row by ~2 — far past any tolerance. The MLA
    # cache itself is consistent (test_mla.py compares mla_full vs
    # mla_decode directly, and a layerwise probe shows <=0.04 hidden
    # drift with identical expert choices when both paths compile the
    # same way). Non-deterministic across BLAS stacks -> non-strict.
    pytest.param("deepseek-v2-236b",
                 marks=pytest.mark.xfail(
                     strict=False,
                     reason="top-k router near-tie flips under "
                     "forward-vs-decode XLA fusion differences")),
    "whisper-large-v3", "zamba2-1.2b"])
def test_decode_consistent_with_forward(arch, rng):
    """logits(prefill(t[:L]) then decode(t[L])) == logits(forward(t[:L+1]))
    at the last position - cache correctness across all cache types.

    MoE archs need ample expert capacity: GShard capacity drops are a
    batch-composition effect, so forward(B*L tokens) and decode(B tokens)
    legitimately diverge when tokens are dropped - not a cache bug."""
    import dataclasses
    cfg = registry.get_config(arch, smoke=True)
    if cfg.n_routed:
        cfg = dataclasses.replace(cfg, capacity_factor=16.0)
    mod = registry.get_module(cfg)
    params = mod.init_params(jax.random.key(0), cfg)
    B, L = 2, 31
    batch = _batch(cfg, B, L + 1, rng)
    kw = {k: v for k, v in batch.items() if k in ("frames", "patch_embeds")}
    full_lg, _, _ = mod.forward(params, batch["tokens"], cfg, **kw)
    lg_p, cache = mod.prefill(params, batch["tokens"][:, :L], cfg, L + 8,
                              **kw)
    lg_d, _ = mod.decode_step(params, batch["tokens"][:, L:], cache, cfg)
    a = np.asarray(full_lg[:, -1])
    b = np.asarray(lg_d[:, 0])
    # bf16 compute: compare top-1 and close logits
    np.testing.assert_allclose(a, b, rtol=0.1, atol=0.15)
    assert np.all(np.argmax(a, -1) == np.argmax(b, -1))


def test_anchored_kv_close_to_dense(rng):
    """RCLL-KV decode tracks the dense-cache decode (paper Table 5
    analogue on the LM side)."""
    import dataclasses
    cfg = registry.get_config("llama3.2-3b", smoke=True)
    mod = registry.get_module(cfg)
    params = mod.init_params(jax.random.key(0), cfg)
    B, L = 2, 32
    batch = _batch(cfg, B, L, rng)
    cfg_a = dataclasses.replace(cfg, kv_mode="anchored", kv_block=16)
    lg_d, cache_d = mod.prefill(params, batch["tokens"], cfg, 64)
    lg_a, cache_a = mod.prefill(params, batch["tokens"], cfg_a, 64)
    np.testing.assert_allclose(np.asarray(lg_d), np.asarray(lg_a),
                               rtol=1e-3, atol=1e-3)
    tok = jnp.argmax(lg_d[:, -1:], -1).astype(jnp.int32)
    out_d, _ = mod.decode_step(params, tok, cache_d, cfg)
    out_a, _ = mod.decode_step(params, tok, cache_a, cfg_a)
    assert np.all(np.argmax(np.asarray(out_d), -1)
                  == np.argmax(np.asarray(out_a), -1))


def test_registry_cells():
    cells = registry.runnable_cells()
    assert len(cells) == 32  # 10 archs x 4 shapes - 8 long_500k skips
    for arch, shape in cells:
        cfg = registry.get_config(arch, smoke=True)
        specs = registry.input_specs(
            cfg, __import__("repro.configs.shapes",
                            fromlist=["SHAPES"]).SHAPES[shape])
        assert specs
