"""MoE dispatch/combine correctness."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import moe


def _naive_moe(p, x, top_k):
    """Loop-over-tokens oracle (no capacity drops)."""
    w, idx, _ = moe.router_topk(p, x, top_k)
    out = np.zeros(x.shape, np.float32)
    xe = np.asarray(x, np.float32)
    for t in range(x.shape[0]):
        for j in range(top_k):
            e = int(idx[t, j])
            wg = np.asarray(p["experts"]["w_gate"][e], np.float32)
            wu = np.asarray(p["experts"]["w_up"][e], np.float32)
            wd = np.asarray(p["experts"]["w_down"][e], np.float32)
            g = xe[t] @ wg
            u = xe[t] @ wu
            h = g / (1 + np.exp(-g)) * u
            out[t] += float(w[t, j]) * (h @ wd)
    return out


def test_dispatch_combine_identity(rng):
    d, E, k, T = 16, 8, 2, 64
    p = moe.init_moe(jax.random.key(0), d, 32, E, 0)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w, idx, _ = moe.router_topk(p, x, k)
    cap = T  # ample capacity: nothing dropped
    buf, info = moe.dispatch_sort(x, idx, w, E, cap)
    assert float(info[4]) == 0.0  # drop_frac
    y = moe.expert_ffn(p["experts"], buf, compute_dtype=jnp.float32)
    out = moe.combine_sort(y, info, w, T)
    want = _naive_moe(p, x, k)
    np.testing.assert_allclose(np.asarray(out), want, rtol=2e-3, atol=2e-3)


def test_capacity_drops_counted(rng):
    d, E, k, T = 8, 4, 2, 64
    p = moe.init_moe(jax.random.key(1), d, 16, E, 0)
    x = jnp.asarray(rng.normal(size=(T, d)), jnp.float32)
    w, idx, _ = moe.router_topk(p, x, k)
    buf, info = moe.dispatch_sort(x, idx, w, E, capacity=4)
    assert 0.0 < float(info[4]) < 1.0


def test_moe_block_shapes_and_shared(rng):
    p = moe.init_moe(jax.random.key(2), 16, 32, 8, 2)
    x = jnp.asarray(rng.normal(size=(2, 10, 16)), jnp.float32)
    out, metrics = moe.moe_block(p, x, top_k=2, n_routed=8)
    assert out.shape == x.shape
    assert np.isfinite(float(metrics["aux_loss"]))
