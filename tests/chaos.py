"""Chaos harness for the multi-process serve stack.

Importable as ``import chaos`` (pytest inserts tests/ into sys.path,
same as ``faults.py``) and runnable standalone::

    PYTHONPATH=src python tests/chaos.py --mode kill --nsteps 96

Drives a REAL ``python -m repro.sph serve`` subprocess (multi-process
frontend + engine workers) and injects real faults mid-request — the
supervisor's built-in ``--chaos kill|hang|oom-sim`` modes for
deterministic engine-thread timing, or :func:`sigkill` /
:func:`sigstop` on a worker pid looked up through the stats op for
test-driven injection. ``tests/test_supervisor.py`` and the CI chaos
smoke sit on these helpers.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.sph import client  # noqa: E402


class ServerProc:
    """A ``repro.sph serve`` subprocess: banner-parsed port, captured
    output, SIGTERM drain."""

    def __init__(self, *extra_args: str, checkpoint: str,
                 block: int = 8, slots: int = 2, queue: int = 8,
                 env: dict | None = None, banner_timeout: float = 120.0):
        env = dict(env or os.environ)
        env.setdefault("PYTHONPATH", os.path.join(
            os.path.dirname(__file__), "..", "src"))
        self.proc = subprocess.Popen(
            [sys.executable, "-m", "repro.sph", "serve",
             "--port", "0", "--slots", str(slots),
             "--queue", str(queue), "--block", str(block),
             "--checkpoint", checkpoint, *extra_args],
            env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True)
        self.lines: list[str] = []
        self.port: int | None = None
        deadline = time.monotonic() + banner_timeout
        while time.monotonic() < deadline:
            line = self.proc.stdout.readline()
            if not line:
                raise AssertionError(
                    "server exited before its banner: "
                    + "\n".join(self.lines))
            self.lines.append(line.rstrip())
            if line.startswith("# serving on"):
                self.port = int(line.split()[3].split(":")[1])
                break
        if self.port is None:
            raise AssertionError("server never printed its banner")
        threading.Thread(target=self._pump, daemon=True).start()

    def _pump(self):
        for line in self.proc.stdout:
            self.lines.append(line.rstrip())

    def stats(self, timeout: float = 30.0) -> dict:
        _, st = client.run_request(
            "127.0.0.1", self.port, {"op": "stats"}, timeout=timeout)
        assert st is not None and st["type"] == "stats"
        return st

    def wait_stats(self, pred, timeout: float = 300.0,
                   what: str = "condition") -> dict:
        """Poll the stats op until ``pred(stats)`` is truthy."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            st = self.stats()
            if pred(st):
                return st
            time.sleep(0.1)
        raise AssertionError(f"server never reached {what}; last: {st}")

    def worker_pids(self) -> dict[str, int]:
        """tag -> pid of every live worker (via the stats op)."""
        return {w["tag"]: w["pid"] for w in self.stats()["workers"]
                if w["pid"] is not None and w["state"] == "ready"}

    def alive(self) -> bool:
        return self.proc.poll() is None

    def stop(self, timeout: float = 120.0) -> int:
        """SIGTERM drain; returns the exit code."""
        self.proc.send_signal(signal.SIGTERM)
        return self.proc.wait(timeout=timeout)

    def kill(self):
        if self.alive():
            self.proc.kill()
            self.proc.wait(timeout=30)


def sigkill(pid: int):
    """The real thing: what the OOM killer / a segfault looks like."""
    os.kill(pid, signal.SIGKILL)


def sigstop(pid: int):
    """Freeze a worker without killing it (exercises hang detection
    end-to-end: the process stops beating AND stops progressing)."""
    os.kill(pid, signal.SIGSTOP)


def sigcont(pid: int):
    os.kill(pid, signal.SIGCONT)


def main(argv=None) -> int:
    import argparse
    import tempfile

    ap = argparse.ArgumentParser(prog="tests/chaos.py", description=(
        "drive one chaos scenario against a live multi-process server"))
    ap.add_argument("--mode", default="kill",
                    choices=["kill", "hang", "oom-sim"])
    ap.add_argument("--case", default="taylor_green")
    ap.add_argument("--n", type=int, default=300)
    ap.add_argument("--nsteps", type=int, default=96)
    ap.add_argument("--block", type=int, default=8)
    ap.add_argument("--hang-timeout", type=float, default=8.0)
    args = ap.parse_args(argv)

    ck = tempfile.mkdtemp(prefix="chaos-ck-")
    srv = ServerProc("--chaos", args.mode,
                     "--hang-timeout", str(args.hang_timeout),
                     checkpoint=ck, block=args.block)
    print(f"# chaos {args.mode}: server on :{srv.port}", flush=True)
    frames, term = client.run_request(
        "127.0.0.1", srv.port,
        {"case": args.case, "n": args.n, "nsteps": args.nsteps,
         "observe": True}, timeout=600.0)
    recovering = [f for f in frames if f.get("action") == "recovering"]
    st = srv.stats()
    rc = srv.stop()
    ok = (term is not None and term["type"] == "done" and recovering
          and st["worker_restarts"] >= 1 and rc == 0)
    print(f"# terminal={term and term['type']} "
          f"recovering_events={len(recovering)} "
          f"worker_restarts={st['worker_restarts']} "
          f"recovery_s={st['recovery_s']} drain_rc={rc}", flush=True)
    print("# chaos", "PASS" if ok else "FAIL", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
