"""bench_schema: the BENCH record validator gating compare_bench."""
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from benchmarks.bench_schema import (  # noqa: E402
    KNOWN_LABELS,
    validate_history,
    validate_record,
)
from benchmarks.compare_bench import main as compare_main  # noqa: E402


def test_shipped_history_validates_clean():
    hist = json.loads((REPO_ROOT / "BENCH_nnps.json").read_text())
    assert validate_history(hist) == []


def test_non_dict_record_rejected():
    assert validate_record([1, 2])
    assert validate_record("nope")


def test_unknown_label_rejected():
    probs = validate_record({"label": "bogus",
                             "cases": [{"steps_per_sec": 1.0,
                                        "nsteps": 10}]})
    assert any("unknown label" in p for p in probs)


def test_missing_cases_rejected():
    assert any("'cases'" in p for p in validate_record({"label": "serve"}))
    assert any("'cases'" in p
               for p in validate_record({"label": "serve", "cases": []}))


@pytest.mark.parametrize("label,row", [
    ("rebuild_round", {"steps_per_sec": 5.0, "nsteps": 100}),
    ("serve", {"sims_per_sec": 2.0, "p95_latency_ms": 30.0,
               "concurrency": 4, "slots": 2}),
    ("ensemble", {"sims_per_sec": 2.0, "mode": "batched", "batch": 8}),
])
def test_minimal_valid_rows_pass(label, row):
    assert validate_record({"label": label, "cases": [row]}) == []


def test_label_required_metric_enforced():
    probs = validate_record({"label": "serve",
                             "cases": [{"steps_per_sec": 5.0}]})
    assert any("sims_per_sec" in p for p in probs)
    assert any("p95_latency_ms" in p for p in probs)


def test_numeric_and_positive_fields_enforced():
    probs = validate_record({
        "label": "rebuild_round",
        "cases": [{"steps_per_sec": "fast", "nsteps": -3}],
    })
    assert any("must be numeric" in p for p in probs)
    assert any("must be positive" in p for p in probs)


def test_extra_keys_tolerated():
    rec = {"label": "rebuild_round",
           "cases": [{"steps_per_sec": 5.0, "nsteps": 10,
                      "brand_new_column": "anything"}],
           "some_future_field": {"nested": True}}
    assert validate_record(rec) == []


def test_known_labels_cover_shipped_history():
    hist = json.loads((REPO_ROOT / "BENCH_nnps.json").read_text())
    for rec in hist:
        assert rec.get("label", "rebuild_round") in KNOWN_LABELS


def test_compare_bench_candidate_exit_2_on_malformed(tmp_path, capsys,
                                                     monkeypatch):
    monkeypatch.chdir(REPO_ROOT)
    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"label": "rebuild_round",
                               "cases": [{"steps_per_sec": "fast"}]}))
    rc = compare_main(["--candidate", str(bad)])
    out = capsys.readouterr().out
    assert rc == 2
    assert "failed schema validation" in out


def test_compare_bench_candidate_exit_0_on_valid(tmp_path, capsys,
                                                 monkeypatch):
    monkeypatch.chdir(REPO_ROOT)
    hist = json.loads((REPO_ROOT / "BENCH_nnps.json").read_text())
    cand = tmp_path / "cand.json"
    cand.write_text(json.dumps(hist[-1]))
    rc = compare_main(["--candidate", str(cand)])
    capsys.readouterr()
    assert rc == 0
