"""Online simulation service (``sph/serve.py`` + ``sph/client.py``).

The contract under test:

  * e2e over a REAL socket: concurrent requests across multiple shape
    buckets complete, healthy responses BIT-IDENTICAL to solo
    ``run_guarded`` runs, a poisoned request answered with a structured
    DIVERGED reply (its neighbors untouched);
  * backpressure: a full admission queue answers REJECTED busy, and the
    shed requests' acceptance does not depend on the engine thread
    (load-shedding happens in the reader);
  * malformed frames answer structured ERROR without reaching the
    engine;
  * deadlines cancel overdue lanes with a TIMEOUT reply;
  * SIGTERM drain hands out resume tokens honored by a RESTARTED server
    (subprocess test: real signal, real processes, bit-exact
    continuation to completion).
"""
import json
import os
import signal
import socket
import subprocess
import sys
import threading
import time

import numpy as np
import pytest

from repro.checkpoint.manager import _flatten
from repro.core import ensemble, recovery
from repro.core.api import Simulation
from repro.core.cases import resolve_ds
from repro.sph import client
from repro.sph.serve import SimServer, send_frame, recv_frame

BLOCK = 8
POLICY = recovery.GuardPolicy(block=BLOCK, snapshot_every=1)


def _server(**kw):
    kw.setdefault("slots", 4)
    kw.setdefault("queue", 16)
    kw.setdefault("policy", POLICY)
    return SimServer(**kw)


def _solo_state(n: int, nsteps: int):
    """The reference a healthy serve reply must bit-match: a solo
    guarded run under the engine's member config."""
    sim = Simulation.from_case(
        "taylor_green", ds=resolve_ds("taylor_green", n))
    mcfg = ensemble.member_config(sim.cfg, POLICY)
    state, _, report, _ = recovery.run_guarded(
        mcfg, sim.state, nsteps, POLICY)
    assert not report.recovered  # the oracle itself must stay clean
    return state


class TestE2E:
    def test_concurrent_buckets_poisoned_member_bit_identity(self):
        """8 concurrent requests, 2 shape buckets, 1 poisoned: every
        healthy reply bit-matches its solo run, the poisoned one gets a
        structured DIVERGED, and lane reuse never cross-contaminates."""
        srv = _server().start()
        reqs = []
        for i in range(4):  # bucket A: n=100
            reqs.append({"case": "taylor_green", "n": 100, "nsteps": 16,
                         "return_state": True, "request_id": f"a{i}"})
        for i in range(3):  # bucket B: n=150 (different shapes)
            reqs.append({"case": "taylor_green", "n": 150, "nsteps": 16,
                         "return_state": True, "request_id": f"b{i}"})
        reqs.append({"case": "taylor_green", "n": 100, "nsteps": 16,
                     "inject": {"kind": "nan", "step": 3},
                     "request_id": "poison"})
        results = {}

        def fire(req):
            _, term = client.run_request(
                "127.0.0.1", srv.port, req, timeout=600.0)
            results[req["request_id"]] = term

        threads = [threading.Thread(target=fire, args=(r,)) for r in reqs]
        for t in threads:
            t.start()
        for t in threads:
            t.join(600)
        srv.request_drain()
        srv.join(60)

        assert len(results) == 8
        poisoned = results.pop("poison")
        assert poisoned["type"] == "diverged"
        assert "nan_v" in poisoned["checks"]
        assert poisoned["stats"]["bad_v"] > 0
        # the ladder ran its masked rungs before giving up
        actions = [e["action"] for e in poisoned["events"]]
        assert "halve_dt" in actions and actions[-1] == "quarantine"

        assert all(t["type"] == "done" for t in results.values())
        for n, prefix in ((100, "a"), (150, "b")):
            want = {k: np.asarray(v)
                    for k, v in _flatten(_solo_state(n, 16)).items()}
            for rid in (r for r in results if r.startswith(prefix)):
                got = client.final_state(results[rid])
                assert set(got) == set(want), rid
                for k in want:
                    assert np.array_equal(got[k], want[k]), (rid, k)

    def test_streamed_observables_and_events(self):
        srv = _server().start()
        frames, term = client.run_request(
            "127.0.0.1", srv.port,
            {"case": "taylor_green", "n": 100, "nsteps": 24,
             "observe": True}, timeout=600.0)
        srv.request_drain()
        srv.join(60)
        kinds = [f["type"] for f in frames]
        assert kinds[0] == "accepted"
        assert term["type"] == "done" and term["steps"] == 24
        obs = [f for f in frames if f["type"] == "obs"]
        # 24 steps / block 8 = 3 block boundaries; the last one is the
        # DONE frame (which carries its own obs row), so 2 OBS frames
        assert [f["step"] for f in obs] == [8, 16]
        assert all(np.isfinite(f["ekin"]) for f in obs)
        assert np.isfinite(term["obs"]["ekin"])

    def test_nsteps_rounded_up_to_whole_blocks(self):
        srv = _server().start()
        frames, term = client.run_request(
            "127.0.0.1", srv.port,
            {"case": "taylor_green", "n": 100, "nsteps": 9},
            timeout=600.0)
        srv.request_drain()
        srv.join(60)
        assert frames[0]["nsteps"] == 16  # 9 -> 2 blocks of 8
        assert term["type"] == "done" and term["steps"] == 16


class TestRobustness:
    def test_queue_overflow_rejected_busy(self):
        """Load shedding is the READER's job: with the engine loop not
        yet running nothing drains the queue, so the (queue+1)-th
        concurrent request must be rejected — deterministically. The
        late-started engine then completes the queued ones (admission
        backlog survives a slow engine)."""
        srv = _server(slots=2, queue=2)  # NOT started yet
        results = []

        def fire(i):
            _, term = client.run_request(
                "127.0.0.1", srv.port,
                {"case": "taylor_green", "n": 100, "nsteps": 8,
                 "request_id": f"q{i}"}, timeout=600.0)
            results.append(term)

        threads = [threading.Thread(target=fire, args=(i,))
                   for i in range(3)]
        for t in threads:
            t.start()
        # all three frames are enqueued/rejected without any engine
        deadline = time.monotonic() + 10
        while len(srv.pending) < 2 and time.monotonic() < deadline:
            time.sleep(0.01)
        assert len(srv.pending) == 2
        srv.start()
        for t in threads:
            t.join(600)
        srv.request_drain()
        srv.join(60)
        kinds = sorted(t["type"] for t in results)
        assert kinds == ["done", "done", "rejected"]
        rej = next(t for t in results if t["type"] == "rejected")
        assert rej["reason"] == "busy" and rej["queue"] == 2

    def test_malformed_requests_structured_error(self):
        srv = _server().start()
        try:
            for bad, expect in (
                ({"case": "no_such_case"}, "unknown case"),
                ({"case": "taylor_green", "nsteps": 0}, "nsteps"),
                ({"case": "taylor_green",
                  "inject": {"kind": "meteor"}}, "inject"),
                ([1, 2, 3], "JSON object"),
            ):
                with socket.create_connection(
                        ("127.0.0.1", srv.port), timeout=30) as s:
                    send_frame(s, bad)
                    reply = recv_frame(s)
                assert reply["type"] == "error", bad
                assert reply["reason"] == "malformed"
                assert expect in reply["detail"]
            # a non-JSON frame must not crash the reader either
            with socket.create_connection(
                    ("127.0.0.1", srv.port), timeout=30) as s:
                s.sendall(b"\x00\x00\x00\x02{x")
                reply = recv_frame(s)
            assert reply["type"] == "error"
            # the server is still alive and serving
            _, term = client.run_request(
                "127.0.0.1", srv.port,
                {"case": "taylor_green", "n": 100, "nsteps": 8},
                timeout=600.0)
            assert term["type"] == "done"
        finally:
            srv.request_drain()
            srv.join(60)

    def test_deadline_timeout_cancels_lane(self):
        # slots=1: the follow-up request can only complete if the
        # timed-out lane was actually retired and its slot freed
        srv = _server(slots=1).start()
        t0 = time.monotonic()
        _, term = client.run_request(
            "127.0.0.1", srv.port,
            {"case": "poiseuille", "n": 400, "nsteps": 800_000,
             "deadline_s": 1.5}, timeout=600.0)
        elapsed = time.monotonic() - t0
        assert term["type"] == "timeout"
        assert elapsed < 300  # cancelled, not run to completion
        _, term = client.run_request(
            "127.0.0.1", srv.port,
            {"case": "poiseuille", "n": 400, "nsteps": 8},
            timeout=600.0)
        assert term["type"] == "done"
        srv.request_drain()
        srv.join(60)

    def test_unknown_resume_token_structured_error(self, tmp_path):
        srv = _server(checkpoint_dir=str(tmp_path)).start()
        _, term = client.run_request(
            "127.0.0.1", srv.port, {"resume_token": "deadbeef"},
            timeout=60.0)
        srv.request_drain()
        srv.join(60)
        assert term["type"] == "error" and term["reason"] == "bad_token"

    def test_stats_op(self):
        srv = _server().start()
        _, term = client.run_request(
            "127.0.0.1", srv.port, {"op": "stats"}, timeout=60.0)
        srv.request_drain()
        srv.join(60)
        assert term["type"] == "stats"
        assert term["queue_cap"] == 16 and term["draining"] is False


@pytest.mark.slow
class TestDrain:
    def test_sigterm_drain_restart_resumes_to_completion(self, tmp_path):
        """Real processes, real SIGTERM: the drained server checkpoints
        the in-flight lane and hands out a resume token; a RESTARTED
        server finishes the work from the checkpoint."""
        env = {**os.environ,
               "PYTHONPATH": os.path.join(os.path.dirname(__file__),
                                          "..", "src")}
        ckdir = str(tmp_path / "ck")

        def start_server():
            p = subprocess.Popen(
                [sys.executable, "-m", "repro.sph", "serve",
                 "--port", "0", "--slots", "2", "--queue", "4",
                 "--block", "8", "--checkpoint", ckdir],
                env=env, stdout=subprocess.PIPE,
                stderr=subprocess.STDOUT, text=True)
            for line in p.stdout:
                if line.startswith("# serving on"):
                    return p, int(line.split()[3].split(":")[1])
            raise AssertionError("server never printed its banner")

        srv, port = start_server()
        long_req = subprocess.Popen(
            [sys.executable, "-m", "repro.sph", "request",
             "--port", str(port), "poiseuille", "--n", "400",
             "--nsteps", "4000"],
            env=env, stdout=subprocess.PIPE, text=True)
        # wait until the lane has made some (but not all) progress —
        # the stats op reports live-lane step counts
        deadline = time.monotonic() + 300
        while time.monotonic() < deadline:
            _, st = client.run_request(
                "127.0.0.1", port, {"op": "stats"}, timeout=30.0)
            if st and any(s > 0 for s in st.get("live_steps", [])):
                break
            time.sleep(0.5)
        else:
            raise AssertionError("lane never made progress")
        srv.send_signal(signal.SIGTERM)
        out, _ = long_req.communicate(timeout=120)
        frames = [json.loads(line) for line in out.splitlines()]
        term = frames[-1]
        assert term["type"] == "retry_after"
        token = term["token"]
        assert token and 0 < term["steps_done"] < 4000
        assert srv.wait(timeout=60) == 0  # drained cleanly, exit 0
        # clean drain removed the heartbeat
        assert not os.path.exists(os.path.join(ckdir, "host_0.hb"))

        srv2, port2 = start_server()
        r = subprocess.run(
            [sys.executable, "-m", "repro.sph", "request",
             "--port", str(port2), "--resume-token", token,
             "--timeout", "600"],
            env=env, capture_output=True, text=True, timeout=600)
        frames = [json.loads(line) for line in r.stdout.splitlines()]
        assert frames[-1]["type"] == "done", frames[-1]
        assert frames[-1]["steps"] == 4000
        assert r.returncode == 0
        srv2.send_signal(signal.SIGTERM)
        assert srv2.wait(timeout=60) == 0
