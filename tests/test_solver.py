"""Mixed-precision WCSPH solver: Poiseuille physics + approach I/III
equivalence (paper Table 5)."""
import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import cases, solver
from repro.core.precision import PrecisionPolicy


def _run(algo, policy, ds=0.05, nsteps=400):
    case = cases.PoiseuilleCase(ds=ds, Lx=0.4, algo=algo, policy=policy)
    cfg, st = case.build()
    out = solver.simulate(cfg, st, nsteps)
    return case, cfg, out


def test_poiseuille_matches_analytic():
    case, cfg, st = _run("rcll", PrecisionPolicy(), nsteps=800)
    pos = solver.positions(cfg, st)
    y = np.asarray(pos[:, 1])
    vx = np.asarray(st.fluid.v[:, 0])
    fl = ~np.asarray(st.fixed)
    va = np.asarray(case.analytic_vx(y, float(st.t)))
    rel = np.abs(vx[fl] - va[fl]).max() / va[fl].max()
    assert rel < 0.2
    assert not np.isnan(vx).any()
    rho = np.asarray(st.fluid.rho)
    assert np.all(np.abs(rho - 1.0) < 0.05)  # weak compressibility


def test_approaches_I_and_III_agree():
    """Table 5: RCLL-fp16 (III) tracks the hi-precision reference (I)."""
    _, cfg1, st1 = _run("cell", PrecisionPolicy(nnps="fp32", coords="fp32"))
    case, cfg3, st3 = _run("rcll", PrecisionPolicy(nnps="fp16",
                                                   coords="fp16"))
    p1 = np.asarray(solver.positions(cfg1, st1))
    p3 = np.asarray(solver.positions(cfg3, st3))
    fl = ~np.asarray(st1.fixed)
    # paper reports ~0.1 ds level agreement; coarse run: allow 0.2 ds
    assert np.abs(p1[fl] - p3[fl]).max() < 0.2 * case.ds
    v1 = np.asarray(st1.fluid.v[fl])
    v3 = np.asarray(st3.fluid.v[fl])
    assert np.abs(v1 - v3).max() < 0.05 * np.abs(v1).max() + 1e-4


def test_all_list_algo_agrees_with_rcll():
    _, cfga, sta = _run("all", PrecisionPolicy(nnps="fp32", coords="fp32"),
                        nsteps=100)
    _, cfgr, str_ = _run("rcll", PrecisionPolicy(nnps="fp32",
                                                 coords="fp32"),
                         nsteps=100)
    pa = np.asarray(solver.positions(cfga, sta))
    pr = np.asarray(solver.positions(cfgr, str_))
    np.testing.assert_allclose(pa, pr, atol=5e-5)
