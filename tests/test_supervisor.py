"""Crash-contained multi-process serving (``sph/supervisor.py`` +
``sph/worker.py`` + the resilient client).

The contract under test:

  * a REAL SIGKILL of an engine worker mid-request is invisible to the
    request's outcome: the supervisor restarts the worker, the lane
    resumes from its last block checkpoint, and the final state is
    BIT-IDENTICAL to an uninterrupted solo run;
  * a sibling shape bucket streams through the whole episode untouched
    (no recovering event, bit-identical state) and the frontend process
    never exits;
  * the restarted worker reclaims its dead predecessor's lockfiles
    QUIETLY — one summary line, no per-lane warning spam;
  * ``--max-restarts`` exhaustion answers RETRY_AFTER with a resume
    token that a later resubmission (fresh worker, fresh restart
    budget) completes from the checkpoint;
  * ``client.run_request_resilient`` survives RETRY_AFTER-with-token
    and mid-stream EOF without manual intervention (unit-tested against
    an in-process fake server — no JAX).
"""
import socket
import threading
import time

import numpy as np
import pytest

import chaos
from repro.checkpoint.manager import _flatten
from repro.core import ensemble, recovery
from repro.core.api import Simulation
from repro.core.cases import resolve_ds
from repro.sph import client
from repro.sph.serve import recv_frame, request_key, send_frame, worker_tag

BLOCK = 8
POLICY = recovery.GuardPolicy(block=BLOCK, snapshot_every=1)


def _solo_state(n: int, nsteps: int):
    sim = Simulation.from_case(
        "taylor_green", ds=resolve_ds("taylor_green", n))
    mcfg = ensemble.member_config(sim.cfg, POLICY)
    state, _, report, _ = recovery.run_guarded(
        mcfg, sim.state, nsteps, POLICY)
    assert not report.recovered
    return {k: np.asarray(v) for k, v in _flatten(state).items()}


def _assert_state_equal(done_frame, want, label):
    got = client.final_state(done_frame)
    assert set(got) == set(want), label
    for k in want:
        assert np.array_equal(got[k], want[k]), (label, k)


class TestRouting:
    def test_request_key_buckets_by_case_and_overrides(self):
        a = {"case": "taylor_green", "n": 100, "nsteps": 16}
        b = {"case": "taylor_green", "n": 150, "nsteps": 16}
        c = {"case": "taylor_green", "n": 100, "nsteps": 999,
             "observe": True}
        assert request_key(a) != request_key(b)  # resolution = bucket
        assert request_key(a) == request_key(c)  # nsteps/flags don't
        assert worker_tag(a) != worker_tag(b)
        assert worker_tag(a).startswith("taylor_green-")


class _FakeServer:
    """Scripted frame server: each accepted connection plays the next
    scenario entry — a list of frames to send (after reading the
    request), or the string "eof" to hang up mid-stream."""

    def __init__(self, scenario):
        self.scenario = list(scenario)
        self.requests = []
        self.sock = socket.socket()
        self.sock.bind(("127.0.0.1", 0))
        self.sock.listen(8)
        self.port = self.sock.getsockname()[1]
        threading.Thread(target=self._serve, daemon=True).start()

    def _serve(self):
        for entry in self.scenario:
            try:
                conn, _ = self.sock.accept()
            except OSError:
                return
            with conn:
                self.requests.append(recv_frame(conn))
                if entry == "eof":
                    continue  # close without a terminal frame
                for frame in entry:
                    send_frame(conn, frame)
        self.sock.close()


class TestResilientClient:
    def test_retry_after_token_resubmitted(self):
        fake = _FakeServer([
            [{"type": "retry_after", "token": "tok-1", "steps_done": 8}],
            [{"type": "obs", "step": 16, "ekin": 1.0},
             {"type": "done", "steps": 16, "obs": {}}],
        ])
        frames, term = client.run_request_resilient(
            "127.0.0.1", fake.port,
            {"case": "taylor_green", "nsteps": 16, "observe": True},
            retries=3, backoff_s=0.01)
        assert term["type"] == "done"
        # the resubmission carried the token, not the original case
        assert fake.requests[1] == {"resume_token": "tok-1",
                                    "observe": True}
        # frames accumulate across attempts
        assert [f["type"] for f in frames] == ["retry_after", "obs",
                                               "done"]

    def test_midstream_eof_reconnects(self):
        fake = _FakeServer([
            "eof",
            [{"type": "done", "steps": 8, "obs": {}}],
        ])
        frames, term = client.run_request_resilient(
            "127.0.0.1", fake.port,
            {"case": "taylor_green", "nsteps": 8},
            retries=2, backoff_s=0.01)
        assert term["type"] == "done"
        assert len(fake.requests) == 2
        # both attempts sent the original request (no token yet)
        assert fake.requests[0] == fake.requests[1]

    def test_retry_budget_exhausted_returns_last_terminal(self):
        fake = _FakeServer([
            [{"type": "retry_after", "token": None}],
            [{"type": "retry_after", "token": None}],
        ])
        _, term = client.run_request_resilient(
            "127.0.0.1", fake.port, {"case": "taylor_green"},
            retries=1, backoff_s=0.01)
        assert term["type"] == "retry_after"
        assert len(fake.requests) == 2  # initial + one retry, then stop

    def test_nonrecoverable_terminal_passes_through(self):
        fake = _FakeServer([
            [{"type": "rejected", "reason": "busy", "queue": 1}],
        ])
        _, term = client.run_request_resilient(
            "127.0.0.1", fake.port, {"case": "taylor_green"},
            retries=3, backoff_s=0.01)
        assert term["type"] == "rejected"
        assert len(fake.requests) == 1  # no retries burned


@pytest.mark.slow
class TestSupervisorE2E:
    def test_sigkill_recovery_bit_identical_sibling_unaffected(
            self, tmp_path):
        """The tentpole proof: SIGKILL one engine worker mid-request
        (the supervisor's deterministic chaos-kill — a real SIGKILL
        timed right after a committed block checkpoint); its request
        must finish bit-identical to an uninterrupted run, a request in
        a DIFFERENT bucket must stream through undisturbed, and the
        frontend must never exit."""
        srv = chaos.ServerProc("--chaos", "kill",
                               checkpoint=str(tmp_path / "ck"),
                               block=BLOCK)
        results = {}

        def fire(rid, req):
            frames, term = client.run_request(
                "127.0.0.1", srv.port, req, timeout=600.0)
            results[rid] = (frames, term)

        ta = threading.Thread(target=fire, args=("a", {
            "case": "taylor_green", "n": 1000, "nsteps": 160,
            "observe": True, "return_state": True}))
        ta.start()
        # chaos-kill fires once the victim worker has >= 2 blocks; the
        # sibling starts only after the fire, so it runs exactly while
        # the victim's bucket is dead/restarting
        srv.wait_stats(lambda st: st["chaos_fired"], timeout=300,
                       what="chaos fire")
        assert srv.alive()
        tb = threading.Thread(target=fire, args=("b", {
            "case": "taylor_green", "n": 150, "nsteps": 64,
            "observe": True, "return_state": True}))
        tb.start()
        ta.join(600)
        tb.join(600)
        assert srv.alive(), "frontend died during worker recovery"

        frames_a, term_a = results["a"]
        frames_b, term_b = results["b"]
        assert term_a["type"] == "done" and term_a["steps"] == 160
        assert term_b["type"] == "done" and term_b["steps"] == 64
        # the killed bucket's client saw the recovery event...
        assert any(f.get("action") == "recovering" for f in frames_a)
        # ...the sibling bucket saw a clean, gap-free stream
        assert not any(f.get("action") == "recovering" for f in frames_b)
        obs_b = [f["step"] for f in frames_b if f["type"] == "obs"]
        assert obs_b == list(range(BLOCK, 64, BLOCK))
        # bit-identity for BOTH buckets
        _assert_state_equal(term_a, _solo_state(1000, 160), "killed")
        _assert_state_equal(term_b, _solo_state(150, 64), "sibling")
        # the killed bucket re-covered every block boundary (duplicates
        # around the kill point are allowed; gaps are not)
        obs_a = {f["step"] for f in frames_a if f["type"] == "obs"}
        assert obs_a == set(range(BLOCK, 160, BLOCK))

        st = srv.stats()
        assert st["worker_restarts"] >= 1
        assert st["recovered_lanes"] >= 1
        assert st["recovery_s"] is not None and st["recovery_s"] > 0
        assert srv.stop() == 0
        # quiet reclaim: the restarted worker logged ONE summary line,
        # not a per-lane lockfile warning
        spam = [ln for ln in srv.lines if "checkpoint: reclaiming" in ln]
        assert spam == [], spam
        assert any("reclaimed checkpoint lock(s)" in ln
                   for ln in srv.lines)
        assert any("# drained cleanly" in ln for ln in srv.lines)

    def test_max_restarts_exhaustion_token_resumes(self, tmp_path):
        """--max-restarts 0: the first real SIGKILL sheds the in-flight
        request as RETRY_AFTER with a resume token; resubmitting the
        token (fresh worker, fresh budget) finishes from the checkpoint
        bit-identical to an uninterrupted run."""
        srv = chaos.ServerProc("--max-restarts", "0",
                               checkpoint=str(tmp_path / "ck"),
                               block=BLOCK)
        box = {}

        def fire():
            box["r"] = client.run_request(
                "127.0.0.1", srv.port,
                {"case": "taylor_green", "n": 1000, "nsteps": 160,
                 "return_state": True}, timeout=600.0)

        t = threading.Thread(target=fire)
        t.start()
        # kill by hand (test-driven injection) once a block checkpoint
        # has certainly committed
        st = srv.wait_stats(
            lambda st: any(w["blocks"] >= 2 and w["assigned"]
                           for w in st["workers"]),
            timeout=300, what="2 blocks of progress")
        pids = srv.worker_pids()
        assert pids, st
        chaos.sigkill(next(iter(pids.values())))
        t.join(120)
        _, term = box["r"]
        assert term["type"] == "retry_after", term
        token = term["token"]
        assert token and term["steps_done"] > 0
        assert srv.alive()

        # the resilient client path: resubmit the token to completion
        frames, done = client.run_request_resilient(
            "127.0.0.1", srv.port,
            {"resume_token": token, "return_state": True},
            retries=3, timeout=600.0)
        assert done["type"] == "done" and done["steps"] == 160
        _assert_state_equal(done, _solo_state(1000, 160), "resumed")
        accepted = next(f for f in frames if f["type"] == "accepted")
        assert accepted["resumed"] is True
        assert srv.stop() == 0
