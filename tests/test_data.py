"""Deterministic synthetic data pipeline."""
import numpy as np

from repro.data.pipeline import (DataConfig, DataIterator, global_batch_np,
                                 host_shard)


def test_determinism():
    cfg = DataConfig(vocab=1000, seq_len=32, global_batch=8, seed=3)
    a = global_batch_np(cfg, 7)
    b = global_batch_np(cfg, 7)
    np.testing.assert_array_equal(a, b)
    c = global_batch_np(cfg, 8)
    assert not np.array_equal(a, c)
    assert a.min() >= 0 and a.max() < 1000


def test_host_shards_partition():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=8)
    full = global_batch_np(cfg, 0)
    parts = [host_shard(cfg, 0, h, 4) for h in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts, 0), full)


def test_iterator_skip_ahead():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    it1 = DataIterator(cfg)
    for _ in range(5):
        last = next(it1)
    it2 = DataIterator(cfg, start_step=4)
    np.testing.assert_array_equal(np.asarray(last["tokens"]),
                                  np.asarray(next(it2)["tokens"]))


def test_structure_learnable():
    """repeat-block structure: copying the previous token beats chance."""
    cfg = DataConfig(vocab=50, seq_len=64, global_batch=32, repeat=4)
    toks = global_batch_np(cfg, 0)
    agree = (toks[:, 1:] == toks[:, :-1]).mean()
    assert agree > 0.6
