"""NNPS equivalence + precision properties (paper Tables 1-2)."""
import numpy as np
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import domain as D, nnps, rcll


def _setup(n, seed=0, periodic=False):
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    per = (True, True) if periodic else (False, False)
    dom = D.Domain(lo=(0., 0.), hi=(1., 1.), h=1.2 * ds, periodic=per)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    return dom, xn


def test_all_cell_rcll_agree_fp32(rng):
    dom, xn = _setup(1500)
    k = 64
    a = nnps.all_list_neighbors(xn, dom.radius_norm, dtype=jnp.float32, k=k)
    c = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float32, k=k)
    st_ = rcll.init_state(dom, xn, dtype=jnp.float32)
    r = nnps.rcll_neighbors(dom, st_.rel, st_.cell_xy, dtype=jnp.float32,
                            k=k)
    assert int(nnps.count_wrong_determinations(a, c)) == 0
    assert int(nnps.count_wrong_determinations(a, r)) == 0
    assert bool(jnp.all(nnps.neighbor_sets_equal(a, c)))


def test_periodic_equivalence(rng):
    dom, xn = _setup(1500, periodic=True)
    k = 64
    a = nnps.all_list_neighbors(xn, dom.radius_norm, dtype=jnp.float32,
                                k=k, domain=dom)
    c = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float32, k=k)
    st_ = rcll.init_state(dom, xn, dtype=jnp.float32)
    r = nnps.rcll_neighbors(dom, st_.rel, st_.cell_xy, dtype=jnp.float32,
                            k=k)
    assert int(nnps.count_wrong_determinations(a, c)) == 0
    assert int(nnps.count_wrong_determinations(a, r)) == 0


def test_fp16_absolute_breaks_rcll_survives():
    """Paper Table 2's central claim, reproduced on an elongated domain
    (normalized spacing ~1e-4 < 1e-3 threshold -> absolute fp16 fails)."""
    rng = np.random.default_rng(3)
    n = 4000
    ds = 0.02
    dom = D.Domain(lo=(0.0, 0.0), hi=(160.0, 1.0), h=1.2 * ds)
    x = np.stack([rng.uniform(0, 160, n), rng.uniform(0, 1, n)], -1)
    xn = dom.normalize(jnp.asarray(x))
    k = 48
    truth = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float32, k=k)
    bad16 = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float16, k=k)
    st_ = rcll.init_state(dom, xn, dtype=jnp.float16)
    good16 = nnps.rcll_neighbors(dom, st_.rel, st_.cell_xy,
                                 dtype=jnp.float16,
                                 compute_dtype=jnp.float32, k=k)
    wrong_abs = int(nnps.count_wrong_determinations(truth, bad16))
    wrong_rcll = int(nnps.count_wrong_determinations(truth, good16))
    total = int(jnp.sum(truth.count))
    assert wrong_abs / total > 0.05, (wrong_abs, total)
    assert wrong_rcll / max(total, 1) < 1e-3, (wrong_rcll, total)


def test_rcll_fp16_exact_on_stored_coords():
    """Protocol (b): with storage fp16 + fp32 arithmetic (the TPU-native
    mode) RCLL reproduces the fp32 determinations on the stored
    coordinates exactly - the paper's '0 incorrect' column."""
    rng = np.random.default_rng(5)
    n = 3000
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    st_ = rcll.init_state(dom, xn, dtype=jnp.float16)
    xq = rcll.to_normalized(dom, st_)  # stored (quantized) positions
    k = 64
    truth_q = nnps.all_list_neighbors(xq, dom.radius_norm,
                                      dtype=jnp.float32, k=k)
    got = nnps.rcll_neighbors(dom, st_.rel, st_.cell_xy, dtype=jnp.float16,
                              compute_dtype=jnp.float32, k=k)
    assert int(nnps.count_wrong_determinations(truth_q, got)) == 0


def test_circle_disturbance_table1():
    """Paper Table 1: particles at radius 1 +- dR around a center; fp16
    distance misclassifies once dR drops below its precision."""
    rng = np.random.default_rng(7)
    n = 100
    theta = rng.uniform(0, 2 * np.pi, n)
    sign = np.where(rng.uniform(size=n) < 0.5, 1.0, -1.0)

    def wrong_count(dr, dtype):
        r_true = 1.0 + sign * dr
        x = np.stack([r_true * np.cos(theta), r_true * np.sin(theta)], -1)
        xl = jnp.asarray(x, dtype)
        d = jnp.sqrt(jnp.sum(xl * xl, axis=-1))
        inside = d <= jnp.asarray(1.0, dtype)
        return int(jnp.sum(inside != (sign < 0)))

    assert wrong_count(1e-1, jnp.float16) == 0
    assert wrong_count(1e-2, jnp.float16) == 0
    assert wrong_count(1e-4, jnp.float16) > 10  # fp16 has ~3 digits
    assert wrong_count(1e-4, jnp.float32) == 0


def test_select_k_deterministic():
    cand = jnp.asarray([[5, 9, 2, 7], [1, 1, 3, 4]], jnp.int32)
    ok = jnp.asarray([[True, False, True, True], [False, True, False, True]])
    idx, mask = nnps.select_k(cand, ok, 2)
    assert idx.tolist() == [[5, 2], [1, 4]]
    assert bool(jnp.all(mask))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(64, 600), seed=st.integers(0, 2**31 - 1),
       periodic=st.booleans())
def test_property_rcll_equals_alllist(n, seed, periodic):
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    per = (periodic, periodic)
    dom = D.Domain(lo=(0., 0.), hi=(1., 1.), h=1.2 * ds, periodic=per)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    k = 80
    a = nnps.all_list_neighbors(xn, dom.radius_norm, dtype=jnp.float32,
                                k=k, domain=dom if periodic else None)
    st_ = rcll.init_state(dom, xn, dtype=jnp.float32)
    r = nnps.rcll_neighbors(dom, st_.rel, st_.cell_xy, dtype=jnp.float32,
                            k=k)
    if int(jnp.max(a.count)) >= k:
        return  # k overflow: determinations truncated, not comparable
    assert int(nnps.count_wrong_determinations(a, r)) == 0
