"""Shared fault-injection fixtures for the health-guard test suite.

Importable as ``import faults`` (pytest inserts tests/ into sys.path,
same as ``_hypo.py``). Builders return SMALL CPU-friendly (cfg, state)
pairs whose CLEAN runs are healthy under the default guard thresholds —
each test then corrupts exactly one thing (an armed FaultSpec, an
undersized capacity, an overscale dt) so the recovery path under test
is the only one that fires.
"""
from __future__ import annotations

import dataclasses
import itertools

import numpy as np

from repro.core import cases as cases_lib
from repro.core import solver
from repro.core.domain import Domain


def lattice(cfg_kw=None, *, ds=0.05, h=0.1, seed=0, vel=0.05):
    """Periodic unit-box lattice with small random velocities.

    ~400 particles; ``max_neighbors`` is sized to the true demand so the
    clean guarded run takes no recovery action (the property the
    bit-match tests lean on).
    """
    dom = Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=h, periodic=(True, True))
    xs = np.arange(ds / 2, 1.0, ds)
    x = np.array(list(itertools.product(xs, xs)))
    n = len(x)
    rng = np.random.default_rng(seed)
    v = vel * rng.standard_normal((n, 2)).astype(np.float32)
    m = np.full(n, ds * ds, np.float32)
    rho = np.ones(n, np.float32)
    cfg = solver.SPHConfig(
        domain=dom, ds=ds, dt=1e-3, algo="rcll", max_neighbors=64,
        **(cfg_kw or {}),
    )
    return cfg, solver.init_state(cfg, x, v, m, rho)


def dam_break(**case_kw):
    """Coarse dam break (~300 particles incl. walls): the free-surface
    case every capacity/CFL incident in this repo's history hit."""
    case = cases_lib.DamBreakCase(ds=0.1, **case_kw)
    return case.build()


def thin_grid(ncells_x=2200, ds=0.05, h=0.1):
    """A long thin aperiodic domain whose x axis exceeds the fp16
    half-record cell-anchor limit (2^11 cells) with only a handful of
    particles — drives the records fp16 -> fp32 degrade path. Cells are
    sized by the support radius 2h, hence the factor below."""
    hi_x = ncells_x * 2 * h
    dom = Domain(
        lo=(0.0, 0.0), hi=(hi_x, 3 * h), h=h, periodic=(False, False)
    )
    xs = np.arange(ds / 2, 10 * h, ds)
    ys = np.arange(ds / 2, 3 * h, ds)
    x = np.array(list(itertools.product(xs, ys)))
    n = len(x)
    cfg = solver.SPHConfig(
        domain=dom, ds=ds, dt=1e-4, algo="rcll", max_neighbors=64,
    )
    rho = np.ones(n, np.float32)
    m = np.full(n, ds * ds, np.float32)
    return cfg, solver.init_state(cfg, x, np.zeros((n, 2)), m, rho)


def with_fault(cfg, **fault_kw):
    from repro.core import health

    return dataclasses.replace(cfg, fault=health.FaultSpec(**fault_kw))
