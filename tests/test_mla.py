"""MLA: absorbed decode == naive attention on the same latent cache."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import mla


def test_mla_decode_matches_full(rng):
    dims = mla.MLADims(n_heads=4, q_lora=24, kv_lora=16, qk_nope=8,
                       qk_rope=8, v_head=8)
    d_model = 32
    p = mla.init_mla(jax.random.key(0), d_model, dims.n_heads,
                     q_lora=dims.q_lora, kv_lora=dims.kv_lora,
                     qk_nope=dims.qk_nope, qk_rope=dims.qk_rope,
                     v_head=dims.v_head)
    B, L = 2, 12
    x = jnp.asarray(rng.normal(size=(B, L + 1, d_model)) * 0.3,
                    jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(L + 1)[None], (B, L + 1))
    out_full, (c_kv, k_rope) = mla.mla_full(p, x, pos, dims,
                                            compute_dtype=jnp.float32)
    # build the cache from prefill outputs, decode the last token
    pad = 4
    cache = mla.MLACache(
        c_kv=jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0))),
        k_rope=jnp.pad(k_rope, ((0, 0), (0, pad), (0, 0))),
        length=jnp.full((B,), L, jnp.int32))
    out_dec, _ = mla.mla_decode(p, x[:, L:], cache, dims,
                                compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, L]),
                               rtol=2e-3, atol=2e-3)
