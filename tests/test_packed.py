"""Persistent cell-packed neighbor pipeline: packing round trips,
Verlet-skin reuse exactness, and Pallas-vs-XLA backend agreement."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cases, cells, domain as D, nnps, rcll, solver


def _cloud(n, dim=2, seed=0, periodic=False):
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** (1.0 / dim)
    dom = D.Domain(
        lo=(0.0,) * dim, hi=(1.0,) * dim, h=1.2 * ds,
        periodic=(periodic,) * dim,
    )
    x = rng.uniform(0, 1, (n, dim))
    xn = dom.normalize(jnp.asarray(x))
    return dom, rcll.init_state(dom, xn, dtype=jnp.float16)


# --------------------------------------------------------------------------
# Packed <-> unpacked round trips
# --------------------------------------------------------------------------
def test_pack_roundtrip_identity(rng):
    dom, st = _cloud(900, seed=3)
    cap = cells.default_capacity(dom, 900)
    ps = rcll.pack_state(dom, st, cap)
    pk = ps.packing
    # order/inverse are mutually inverse permutations
    np.testing.assert_array_equal(
        np.asarray(pk.order)[np.asarray(pk.inverse)], np.arange(900)
    )
    np.testing.assert_array_equal(
        np.asarray(cells.inverse_permutation(pk.order)), np.asarray(pk.inverse)
    )
    # every per-particle array round-trips exactly
    np.testing.assert_array_equal(
        np.asarray(pk.unpack(ps.rc.rel)), np.asarray(st.rel)
    )
    np.testing.assert_array_equal(
        np.asarray(pk.unpack(ps.rc.cell_xy)), np.asarray(st.cell_xy)
    )
    extra = jnp.asarray(rng.normal(size=(900, 2)), jnp.float32)
    np.testing.assert_array_equal(
        np.asarray(pk.unpack(pk.pack(extra))), np.asarray(extra)
    )
    # packed arrays are sorted by flat cell id
    cid = np.asarray(dom.flat_cell_id(ps.rc.cell_xy))
    assert np.all(np.diff(cid) >= 0)
    # the packed binning's table rows are runs of consecutive packed ids
    tbl = np.asarray(pk.binning.table)
    for row in tbl:
        occ = row[row >= 0]
        if occ.size > 1:
            assert np.all(np.diff(occ) == 1)
    assert int(pk.binning.overflow) == 0


def test_cell_major_tables_roundtrip(rng):
    dom, st = _cloud(400, seed=5)
    ps = rcll.pack_state(dom, st, cells.default_capacity(dom, 400))
    b = ps.packing.binning
    t = cells.to_cell_major(b, ps.rc.rel)
    assert t.shape == b.table.shape + (2,)
    back = cells.from_cell_major(b, t)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(ps.rc.rel))


def test_simulate_returns_original_indexing():
    """finalize_persistent must undo the spatial sort at the API boundary."""
    case = cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="rcll")
    cfg, st = case.build()
    out = solver.simulate(cfg, st, 30)
    # fixed mask and (constant) masses identify particles: they must come
    # back exactly where they started even though the carry is cell-sorted
    np.testing.assert_array_equal(np.asarray(out.fixed), np.asarray(st.fixed))
    np.testing.assert_array_equal(
        np.asarray(out.fluid.m), np.asarray(st.fluid.m)
    )
    # wall particles never move: their decoded positions are unchanged
    p0 = np.asarray(solver.positions(cfg, st))
    p1 = np.asarray(solver.positions(cfg, out))
    w = np.asarray(st.fixed)
    assert np.abs(p1[w] - p0[w]).max() < 1e-3 * case.ds


# --------------------------------------------------------------------------
# Verlet-skin reuse: exact neighbor sets at every step
# --------------------------------------------------------------------------
def _to_original(nl: nnps.NeighborList, packed_to_orig) -> nnps.NeighborList:
    """Re-index a packed neighbor list into original particle indexing.

    Invalid slots may hold the dummy id N (the window search's padding
    convention); they are masked, so clip before the numpy gather.
    """
    p2o = np.asarray(packed_to_orig)
    idx = p2o[np.minimum(np.asarray(nl.idx), p2o.shape[0] - 1)]
    inv = np.argsort(p2o)
    return nnps.NeighborList(
        idx=jnp.asarray(idx)[inv],
        mask=nl.mask[jnp.asarray(inv)],
        count=nl.count[jnp.asarray(inv)],
    )


def test_skin_reuse_neighbor_sets_match_per_step_rebuild():
    """Acceptance criterion: with skin reuse, the exact-radius neighbor
    sets (refiltered from the inflated list) equal a fresh per-step
    rebuild's sets at EVERY step, while rebuilds << steps."""
    case = cases.PoiseuilleCase(
        ds=0.05, Lx=0.8, algo="rcll", cell_factor=2.0, max_neighbors=96
    )
    cfg, st = case.build()
    cfg = dataclasses.replace(cfg, skin=0.5 * min(cfg.domain.cell_sizes))
    n = st.xn.shape[0]
    pol = cfg.policy

    step_fn = jax.jit(solver.step_persistent, static_argnums=0)
    carry = solver.init_persistent(cfg, st)
    nsteps = 60
    for _ in range(nsteps):
        carry = step_fn(cfg, carry)
        # exact sets recovered from the reused (possibly stale) list
        exact = solver.exact_neighbor_list(cfg, carry)
        # fresh per-step rebuild at the current positions (same search
        # arithmetic as the solver's production rebuild)
        ps = rcll.pack_state(cfg.domain, carry.st.rc, cfg.cap(n))
        fresh = rcll.packed_neighbors(
            cfg.domain, ps, dtype=pol.nnps_dtype,
            compute_dtype=pol.nnps_compute_dtype, k=cfg.max_neighbors,
        )
        # align both to original particle indexing
        exact_o = _to_original(exact, carry.order)
        fresh_o = _to_original(fresh, np.asarray(carry.order)[
            np.asarray(ps.packing.order)])
        eq = nnps.neighbor_sets_equal(exact_o, fresh_o)
        assert bool(jnp.all(eq)), (
            f"neighbor sets diverged at step {int(carry.steps)}: "
            f"{int(jnp.sum(~eq))} particles differ"
        )
    assert not bool(carry.overflow)
    # measurably fewer rebuilds than steps
    assert int(carry.rebuilds) < nsteps // 2, int(carry.rebuilds)


def test_skin_zero_rebuilds_every_step():
    case = cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="rcll")
    cfg, st = case.build()
    _, stats = solver.simulate_stats(cfg, st, 25)
    assert int(stats.rebuilds) == 25  # init build + one per moving step
    assert int(stats.steps) == 25


def test_rebuild_every_static_cadence():
    case = cases.PoiseuilleCase(
        ds=0.05, Lx=0.8, algo="rcll", cell_factor=2.0, max_neighbors=96,
        rebuild_every=5,
    )
    cfg, st = case.build()
    _, stats = solver.simulate_stats(cfg, st, 25)
    # init + steps 5, 10, 15, 20 (step counter is pre-increment at check)
    assert int(stats.rebuilds) == 1 + 4
    assert not bool(stats.overflow)


def test_skin_physics_matches_per_step_rebuild():
    """Same domain/config: reused-list physics tracks per-step rebuild to
    fp round-off (extra skin pairs contribute exactly zero force)."""
    kw = dict(ds=0.05, Lx=0.8, algo="rcll", cell_factor=2.0,
              max_neighbors=96)
    cfg0, st0 = cases.PoiseuilleCase(**kw).build()
    cfg1, st1 = cases.PoiseuilleCase(**kw).build()
    cfg1 = dataclasses.replace(cfg1, skin=0.5 * min(cfg1.domain.cell_sizes))
    out0 = solver.simulate(cfg0, st0, 150)
    out1 = solver.simulate(cfg1, st1, 150)
    p0 = np.asarray(solver.positions(cfg0, out0))
    p1 = np.asarray(solver.positions(cfg1, out1))
    assert np.abs(p0 - p1).max() < 1e-3 * cfg0.ds
    v0, v1 = np.asarray(out0.fluid.v), np.asarray(out1.fluid.v)
    assert np.abs(v0 - v1).max() < 1e-6 + 1e-3 * np.abs(v0).max()


def test_skin_too_large_raises():
    import pytest

    case = cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="rcll")
    cfg, st = case.build()
    cfg = dataclasses.replace(cfg, skin=cfg.domain.radius)  # r+skin = 2r > hc
    with pytest.raises(ValueError, match="cell coverage"):
        solver.init_persistent(cfg, st)


# --------------------------------------------------------------------------
# Pallas kernel vs pure-jnp backend agreement (interpret mode)
# --------------------------------------------------------------------------
def test_pallas_xla_neighbor_lists_agree():
    from repro.kernels import ops

    for n, dim, periodic in [(700, 2, False), (600, 2, True), (400, 3, False)]:
        dom, st = _cloud(n, dim=dim, seed=7, periodic=periodic)
        # generous capacity: comparisons are only defined without overflow
        # (a dropped particle has no table slot for the kernel to read)
        cap = cells.default_capacity(dom, n, safety=8.0)
        ps = rcll.pack_state(dom, st, cap)
        k = 96
        nl_x = rcll.packed_neighbors(
            dom, ps, dtype=jnp.float16, compute_dtype=jnp.float32, k=k
        )
        nl_p = ops.rcll_neighbor_lists(
            dom, ps.packing.binning, ps.rc.rel, k=k,
            nnps_dtype=jnp.float16, interpret=True,
        )
        assert bool(jnp.all(nnps.neighbor_sets_equal(nl_x, nl_p)))
        np.testing.assert_array_equal(
            np.asarray(nl_x.count), np.asarray(nl_p.count)
        )


def test_pallas_backend_solver_matches_xla_backend():
    kw = dict(ds=0.1, Lx=0.8, algo="rcll")
    cfgx, stx = cases.PoiseuilleCase(**kw, backend="xla").build()
    cfgp, stp = cases.PoiseuilleCase(**kw, backend="pallas").build()
    outx = solver.simulate(cfgx, stx, 15)
    outp = solver.simulate(cfgp, stp, 15)
    px = np.asarray(solver.positions(cfgx, outx))
    pp = np.asarray(solver.positions(cfgp, outp))
    assert np.abs(px - pp).max() < 1e-6
    np.testing.assert_allclose(
        np.asarray(outx.fluid.v), np.asarray(outp.fluid.v), atol=1e-7
    )


# --------------------------------------------------------------------------
# Table-free window search vs the dense-table candidate search
# --------------------------------------------------------------------------
def test_window_search_matches_table_search():
    """Candidates from contiguous start/count windows must reproduce the
    (C, cap) table search's neighbor sets and counts exactly — across
    periodicity of leading and last axes (seam handling differs)."""
    rng = np.random.default_rng(11)
    for dim, periodic in [
        (2, (False, False)), (2, (True, False)),
        (2, (False, True)), (2, (True, True)),
        (3, (True, False, True)),
    ]:
        n = 600
        dom = D.Domain(
            lo=(0.0,) * dim, hi=(1.0,) * dim, h=0.07, cell_factor=1.4,
            periodic=periodic,
        )
        x = rng.uniform(0, 1, (n, dim))
        st = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
        cap = cells.default_capacity(dom, n, safety=5.0)
        ps = rcll.pack_state(dom, st, cap)
        k = 128
        for rad in (None, 1.3 * nnps.rcll_radius_cell_units(dom)):
            table = nnps.rcll_neighbors(
                dom, ps.rc.rel, ps.rc.cell_xy, dtype=jnp.float16,
                compute_dtype=jnp.float32, k=k,
                binning=ps.packing.binning, radius_cell=rad,
            )
            windows = rcll.packed_neighbors(
                dom, ps, dtype=jnp.float16, compute_dtype=jnp.float32,
                k=k, radius_cell=rad,
            )
            eq = nnps.neighbor_sets_equal(table, windows)
            assert bool(jnp.all(eq)), (dim, periodic, int(jnp.sum(~eq)))
            np.testing.assert_array_equal(
                np.asarray(table.count), np.asarray(windows.count)
            )


def test_window_truncation_flags_overflow():
    """A too-tight window must surface through NeighborList.overflowed
    (the k+1 count sentinel), not silently drop candidates."""
    rng = np.random.default_rng(12)
    dom = D.unit_square(h=0.12)
    x = rng.uniform(0, 1, (500, 2))
    st = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    ps = rcll.pack_state(dom, st, 64)
    assert not bool(
        rcll.packed_neighbors(dom, ps, k=192).overflowed
    )
    assert bool(
        rcll.packed_neighbors(dom, ps, k=192, window=4).overflowed
    )


# --------------------------------------------------------------------------
# Fused state permutation (the rebuild's one-gather row buffer)
# --------------------------------------------------------------------------
def test_statepack_roundtrip_exact(rng):
    from repro.core import statepack

    n = 257
    fields = (
        jnp.asarray(rng.normal(size=(n, 2)), jnp.float32),
        jnp.asarray(rng.normal(size=(n, 2)), jnp.float16),
        jnp.asarray(rng.integers(-5, 5, (n, 2)), jnp.int32),
        jnp.asarray(rng.integers(0, 2, (n,)), bool),
        jnp.asarray(rng.integers(-128, 127, (n,)), jnp.int8),
        None,
        jnp.asarray(rng.normal(size=(n,)), jnp.float32),
    )
    perm = jnp.asarray(rng.permutation(n), jnp.int32)
    out = statepack.permute_fields(fields, perm)
    for f, o in zip(fields, out):
        if f is None:
            assert o is None
            continue
        assert o.dtype == f.dtype and o.shape == f.shape
        np.testing.assert_array_equal(np.asarray(o), np.asarray(f[perm]))


def test_fused_permute_matches_per_field(rng):
    """The one-gather row permutation must be bit-identical to the
    per-field oracle — including optional fields (kind/v_wall) and the
    order array — for every backend's rebuild."""
    for case in (
        cases.PoiseuilleCase(ds=0.1, Lx=0.8, algo="rcll"),
        cases.build_case("cavity", ds=0.12),  # kind + v_wall present
    ):
        cfg, st = case.build()
        n = st.xn.shape[0]
        st = solver.simulate(cfg, st, 3)  # nontrivial v/rho
        ps = rcll.pack_state(cfg.domain, st.rc, cfg.cap(n))
        perm = ps.packing.order
        order = jnp.asarray(rng.permutation(n), jnp.int32)
        oracle = solver._permute_state(st, perm, ps.rc)
        fused_st, fused_order = solver._permute_state_fused(
            st, perm, ps.rc, order
        )
        np.testing.assert_array_equal(
            np.asarray(fused_order), np.asarray(order[perm])
        )
        for a, b in zip(jax.tree_util.tree_leaves(oracle),
                        jax.tree_util.tree_leaves(fused_st)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Window-as-default vs the dense-table oracle on every registered case
# --------------------------------------------------------------------------
def test_window_default_matches_table_oracle_on_all_cases():
    """Acceptance criterion: the production window search (the default)
    must produce neighbor sets identical to the (C, cap) table path
    (SPHConfig.window=None) on every registered case."""
    import dataclasses as dc

    for name in cases.case_names():
        case = cases.build_case(
            name, ds=cases.resolve_ds(name, 400), backend="xla"
        )
        cfg, st = case.build()
        assert cfg.window == 0  # auto window IS the default
        carry_w = solver.init_persistent(cfg, st)
        carry_t = solver.init_persistent(
            dc.replace(cfg, window=None), st
        )
        eq = nnps.neighbor_sets_equal(carry_w.nl, carry_t.nl)
        assert bool(jnp.all(eq)), (name, int(jnp.sum(~eq)))
        np.testing.assert_array_equal(
            np.asarray(carry_w.nl.count), np.asarray(carry_t.nl.count)
        )
        assert not bool(carry_w.overflow), name


# --------------------------------------------------------------------------
# Window truncation: raised loudly, recovered by a wider budget
# --------------------------------------------------------------------------
def test_window_truncation_raised_and_recovered():
    """A too-tight merged window must flag overflow end-to-end through
    the full simulate scan (and raise under check_overflow); widening
    the budget must recover table-oracle-identical neighbor sets."""
    import dataclasses as dc
    import pytest

    case = cases.PoiseuilleCase(ds=0.05, Lx=0.8, algo="rcll",
                                backend="xla")
    cfg, st = case.build()
    tight = dc.replace(cfg, window=8)
    _, stats = solver.simulate_stats(tight, st, 3)
    assert bool(stats.overflow)
    with pytest.raises(Exception, match="overflow"):
        out, stats = jax.block_until_ready(
            solver.simulate_stats(
                dc.replace(tight, check_overflow=True), st, 3
            )
        )
    # recovery: the default (auto) budget is truncation-free and equals
    # the dense-table oracle's sets
    carry_w = solver.init_persistent(cfg, st)
    carry_t = solver.init_persistent(dc.replace(cfg, window=None), st)
    assert not bool(carry_w.overflow)
    assert bool(jnp.all(nnps.neighbor_sets_equal(carry_w.nl, carry_t.nl)))


# --------------------------------------------------------------------------
# Counting-sort argsort fallback under >1-cell movers, through simulate
# --------------------------------------------------------------------------
def test_counting_sort_fallback_through_simulate(rng):
    """A particle that out-runs the 3^dim neighborhood between rebuilds
    violates the counting-sort precondition; the in-scan lax.cond must
    take the argsort branch and keep the permutation (and physics)
    exact. Oracle: the stateless per-step solver.step, whose cold pack
    always argsorts from scratch."""
    ds = 1.0 / 16
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=1.2 * ds,
                   periodic=(True, True))
    x = D.lattice_positions(dom, ds, jitter=0.05, seed=3)
    n = x.shape[0]
    cfg = solver.SPHConfig(
        domain=dom, ds=ds, dt=1e-3, c0=1.0, mu=0.0, body_force=(0.0, 0.0),
        max_neighbors=48, algo="rcll", backend="xla",
    )
    v = np.zeros((n, 2), np.float32)
    # particle 0 crosses ~2.5 cells per step: dxn = v dt 2/h_d,
    # cells/step = dxn / hc
    hc = dom.hc_norm_axes[0]
    v[0, 0] = 2.5 * hc * dom.h_d / (2.0 * cfg.dt)
    m = np.full((n,), ds * ds, np.float32)
    # massless tracer: the mover still violates the pack precondition
    # every step, but exerts no force — so the two runs' only
    # difference is the packing code path, not chaos amplification of
    # its (enormous) velocity through the pair sums
    m[0] = 0.0
    rho = np.ones((n,), np.float32)
    st = solver.init_state(cfg, x, v, m, rho)
    # sanity: the mover really violates the 1-cell precondition
    assert v[0, 0] * cfg.dt * 2.0 / dom.h_d / hc > 2.0

    nsteps = 8
    out = solver.simulate(cfg, st, nsteps)  # scan: prev-binning pack
    ref = st
    for _ in range(nsteps):  # stateless: cold argsort pack every step
        ref = solver.step(cfg, ref)
    p_out = np.asarray(solver.positions(cfg, out))
    p_ref = np.asarray(solver.positions(cfg, ref))
    assert np.all(np.isfinite(p_out))
    # identical permutation handling => identical physics to round-off
    np.testing.assert_allclose(p_out, p_ref, atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(out.fluid.rho), np.asarray(ref.fluid.rho),
        rtol=0, atol=1e-5,
    )
    # particles come back in original indexing (permutation validity)
    np.testing.assert_array_equal(
        np.asarray(out.fluid.m), np.asarray(st.fluid.m)
    )
