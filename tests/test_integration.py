"""End-to-end integration: train loss decreases; checkpoint-resume is
bitwise-consistent; serve agrees between dense and RCLL-KV caches."""
import numpy as np
import pytest
import jax

from repro.launch.serve import ServeRun
from repro.launch.train import TrainRun


@pytest.mark.slow
def test_train_loss_decreases(tmp_path):
    run = TrainRun(arch="llama3.2-3b", smoke=True, steps=60, batch=8,
                   seq=64, lr=3e-3, ckpt_dir=None)
    out = run.run()
    first = np.mean(out["losses"][:5])
    last = np.mean(out["losses"][-5:])
    assert last < first - 0.2, (first, last)


@pytest.mark.slow
def test_checkpoint_resume_matches_uninterrupted(tmp_path):
    kw = dict(arch="mamba2-130m", smoke=True, steps=24, batch=4, seq=64,
              lr=1e-3, ckpt_every=12)
    ref = TrainRun(ckpt_dir=None, **kw).run()
    # interrupted run: first 12 steps (checkpoint), then resume
    d = str(tmp_path / "ck")
    TrainRun(ckpt_dir=d, **{**kw, "steps": 12}).run()
    resumed = TrainRun(ckpt_dir=d, **kw).run()
    np.testing.assert_allclose(resumed["final_loss"], ref["final_loss"],
                               rtol=1e-4)
    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(resumed["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-5)


@pytest.mark.slow
def test_serve_dense_vs_anchored():
    """Both cache modes serve end-to-end; RCLL-KV streams fewer cache
    bytes per token. (Logit-level agreement of the two cache modes is
    asserted in tests/test_models.py::test_anchored_kv_close_to_dense -
    greedy *chains* at random init are chaotic, so token-sequence
    agreement is not a meaningful metric here.)"""
    dense = ServeRun(arch="llama3.2-3b", smoke=True, batch=2,
                     prompt_len=48, gen=12, kv_mode="dense").run()
    anch = ServeRun(arch="llama3.2-3b", smoke=True, batch=2,
                    prompt_len=48, gen=12, kv_mode="anchored").run()
    assert dense["tokens"].shape == anch["tokens"].shape
    assert np.isfinite(dense["decode_tok_s"])
    # int8 residuals + fp32 anchors + fp32 tail < bf16 dense at 32k:
    # here max_len is small so just assert both produced valid caches
    assert dense["cache_bytes"] > 0 and anch["cache_bytes"] > 0


@pytest.mark.slow
def test_poiseuille_example_runs():
    from repro.core import cases, solver
    case = cases.PoiseuilleCase(ds=0.05, algo="rcll")
    cfg, st = case.build()
    out = solver.simulate(cfg, st, 100)
    assert not np.isnan(np.asarray(out.fluid.v)).any()
