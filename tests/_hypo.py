"""Optional-hypothesis shim for the property tests.

The seed test modules import ``hypothesis`` unconditionally, which breaks
collection on images that don't ship it. This module re-exports the real
``given``/``settings``/``strategies`` when hypothesis is installed and
otherwise provides a tiny *deterministic* fallback: each strategy draws
from a seeded numpy Generator, so every CI run exercises the same example
set (no shrinking, no database - just fixed-seed property sampling).
"""
from __future__ import annotations

import functools

try:  # pragma: no cover - exercised only when hypothesis is present
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """A deterministic sampler standing in for a hypothesis strategy."""

        def __init__(self, sample, boundary=()):
            self._sample = sample  # (rng) -> value
            self._boundary = tuple(boundary)  # always-tried edge values

        def draw(self, rng, i):
            if i < len(self._boundary):
                return self._boundary[i]
            return self._sample(rng)

    class _Strategies:
        @staticmethod
        def integers(lo, hi):
            return _Strategy(
                lambda rng: int(rng.integers(lo, hi + 1)), boundary=(lo, hi)
            )

        @staticmethod
        def floats(lo, hi):
            return _Strategy(
                lambda rng: float(rng.uniform(lo, hi)), boundary=(lo, hi)
            )

        @staticmethod
        def booleans():
            return _Strategy(
                lambda rng: bool(rng.integers(0, 2)), boundary=(False, True)
            )

        @staticmethod
        def sampled_from(values):
            vals = list(values)
            return _Strategy(
                lambda rng: vals[int(rng.integers(0, len(vals)))],
                boundary=vals[:2],
            )

    st = _Strategies()

    def settings(max_examples: int = 10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            # Unwrap if @settings was applied below @given.
            n_examples = getattr(fn, "_max_examples", 10)

            @functools.wraps(fn)
            def wrapper():
                n = getattr(wrapper, "_max_examples", n_examples)
                # Seed from the test name: stable across runs/machines
                # (built-in hash() is salted per process; crc32 is not).
                seed = zlib.crc32(fn.__qualname__.encode()) % (2**31)
                rng = np.random.default_rng(seed)
                for i in range(min(n, 10)):
                    kwargs = {
                        k: s.draw(rng, i) for k, s in strategies.items()
                    }
                    fn(**kwargs)

            # Hide the strategy parameters from pytest's fixture resolver.
            del wrapper.__wrapped__
            wrapper.__signature__ = inspect.Signature([])
            return wrapper

        return deco
