"""Checkpoint manager: atomicity, GC, async, reshard, carry resume."""
import os
import numpy as np
import jax
import jax.numpy as jnp

import faults
from repro.checkpoint.manager import CheckpointManager, reshard
from repro.core import solver
from repro.optim import adamw


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "nested": {"b": jnp.arange(5)},
            "opt": adamw.OptState(
                step=jnp.asarray(7),
                mu={"a": jnp.ones((2,))}, nu={"a": jnp.zeros((2,))})}


def test_save_restore_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(10, tree)
    restored, step = mgr.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert isinstance(restored["opt"], adamw.OptState)


def test_incomplete_checkpoint_ignored(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(1, tree)
    # simulate a torn write: directory without .COMPLETE
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(tree)
    assert step == 1


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_empty(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    restored, step = mgr.restore(_tree(rng))
    assert restored is None and step is None


def test_persistent_carry_roundtrip_bit_identical_resume(tmp_path):
    """A PersistentCarry (None optional fields included) survives
    save -> restore, and a resumed run bit-matches the uninterrupted
    one: 5 steps + checkpoint + 5 steps == 10 straight steps."""
    cfg, st = faults.lattice()
    mgr = CheckpointManager(str(tmp_path))

    # template from a fresh init: same shapes/dtypes/None structure.
    # Built FIRST: run_persistent donates its carry, which invalidates
    # the buffers the carry aliases from ``st``.
    template = jax.tree.map(
        np.asarray, solver.init_persistent(cfg, st)
    )

    carry = solver.init_persistent(cfg, st)
    carry = solver.run_persistent(cfg, carry, 5)
    snap = jax.tree.map(np.asarray, carry)  # host copy BEFORE donation
    mgr.save(int(snap.steps), snap)
    final_a = solver.finalize_persistent(
        cfg, solver.run_persistent(cfg, carry, 5)
    )
    restored, step = mgr.restore(template)
    assert step == 5
    assert restored.m_table is None and restored.idx_dummy is None
    resumed = jax.tree.map(jnp.asarray, restored)
    final_b = solver.finalize_persistent(
        cfg, solver.run_persistent(cfg, resumed, 5)
    )
    for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    mgr.save(1, tree)
    host, _ = mgr.restore(tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    dev = reshard(host, sh)
    np.testing.assert_array_equal(np.asarray(dev["w"]),
                                  np.asarray(tree["w"]))
