"""Checkpoint manager: atomicity, GC, async, reshard, carry resume."""
import json
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

import faults
from repro.checkpoint.manager import (
    CheckpointLockError, CheckpointManager, reshard)
from repro.core import solver
from repro.optim import adamw


def _tree(rng):
    return {"a": jnp.asarray(rng.normal(size=(4, 3)), jnp.float32),
            "nested": {"b": jnp.arange(5)},
            "opt": adamw.OptState(
                step=jnp.asarray(7),
                mu={"a": jnp.ones((2,))}, nu={"a": jnp.zeros((2,))})}


def test_save_restore_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(10, tree)
    restored, step = mgr.restore(tree)
    assert step == 10
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert isinstance(restored["opt"], adamw.OptState)


def test_incomplete_checkpoint_ignored(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(1, tree)
    # simulate a torn write: directory without .COMPLETE
    broken = tmp_path / "step_00000002"
    broken.mkdir()
    (broken / "arrays.npz").write_bytes(b"garbage")
    assert mgr.latest_step() == 1
    restored, step = mgr.restore(tree)
    assert step == 1


def test_keep_k_gc(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = _tree(rng)
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]


def test_async_save(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(5, tree, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_restore_empty(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    restored, step = mgr.restore(_tree(rng))
    assert restored is None and step is None


def test_persistent_carry_roundtrip_bit_identical_resume(tmp_path):
    """A PersistentCarry (None optional fields included) survives
    save -> restore, and a resumed run bit-matches the uninterrupted
    one: 5 steps + checkpoint + 5 steps == 10 straight steps."""
    cfg, st = faults.lattice()
    mgr = CheckpointManager(str(tmp_path))

    # template from a fresh init: same shapes/dtypes/None structure.
    # Built FIRST: run_persistent donates its carry, which invalidates
    # the buffers the carry aliases from ``st``.
    template = jax.tree.map(
        np.asarray, solver.init_persistent(cfg, st)
    )

    carry = solver.init_persistent(cfg, st)
    carry = solver.run_persistent(cfg, carry, 5)
    snap = jax.tree.map(np.asarray, carry)  # host copy BEFORE donation
    mgr.save(int(snap.steps), snap)
    final_a = solver.finalize_persistent(
        cfg, solver.run_persistent(cfg, carry, 5)
    )
    restored, step = mgr.restore(template)
    assert step == 5
    assert restored.m_table is None and restored.idx_dummy is None
    resumed = jax.tree.map(jnp.asarray, restored)
    final_b = solver.finalize_persistent(
        cfg, solver.run_persistent(cfg, resumed, 5)
    )
    for a, b in zip(jax.tree.leaves(final_a), jax.tree.leaves(final_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_reshard_roundtrip(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(rng.normal(size=(8, 4)), jnp.float32)}
    mgr.save(1, tree)
    host, _ = mgr.restore(tree)
    mesh = jax.make_mesh((1,), ("data",))
    sh = {"w": jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))}
    dev = reshard(host, sh)
    np.testing.assert_array_equal(np.asarray(dev["w"]),
                                  np.asarray(tree["w"]))


# ---- integrity (CRC32) + durability semantics -----------------------------
def _corrupt_one_array(step_dir):
    """Flip bytes in arrays.npz WITHOUT touching .COMPLETE: torn storage
    after commit."""
    p = os.path.join(step_dir, "arrays.npz")
    data = bytearray(open(p, "rb").read())
    data[len(data) // 2] ^= 0xFF
    open(p, "wb").write(bytes(data))


def test_crc_mismatch_falls_back_to_previous_step(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    tree = _tree(rng)
    mgr.save(1, tree)
    mgr.save(2, tree)
    _corrupt_one_array(str(tmp_path / "step_00000002"))
    # step 2 still LOOKS committed...
    assert mgr.latest_step() == 2
    # ...but restore must reject it and land on step 1.
    restored, step = mgr.restore(tree)
    assert step == 1
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_truncated_npz_falls_back(tmp_path, rng):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    tree = _tree(rng)
    mgr.save(1, tree)
    mgr.save(2, tree)
    p = tmp_path / "step_00000002" / "arrays.npz"
    p.write_bytes(p.read_bytes()[: p.stat().st_size // 2])
    restored, step = mgr.restore(tree)
    assert step == 1 and restored is not None


def test_explicit_corrupt_step_raises(tmp_path, rng):
    from repro.checkpoint.manager import CheckpointCorruptError

    mgr = CheckpointManager(str(tmp_path))
    tree = _tree(rng)
    mgr.save(3, tree)
    _corrupt_one_array(str(tmp_path / "step_00000003"))
    try:
        mgr.restore(tree, step=3)
    except CheckpointCorruptError:
        pass
    else:
        raise AssertionError("explicit corrupt step must raise")


def test_keep_semantics(tmp_path, rng):
    """keep=1 retains exactly the newest step; keep=0 means KEEP ALL."""
    tree = _tree(rng)
    m1 = CheckpointManager(str(tmp_path / "one"), keep=1)
    for s in (1, 2, 3):
        m1.save(s, tree)
    assert m1.all_steps() == [3]
    m0 = CheckpointManager(str(tmp_path / "all"), keep=0)
    for s in (1, 2, 3):
        m0.save(s, tree)
    assert m0.all_steps() == [1, 2, 3]


def test_async_save_copies_host_arrays(tmp_path):
    """save(blocking=False) must snapshot host numpy leaves: the caller
    mutating them right after the call (the ensemble driver's lane
    vectors) cannot leak into the written checkpoint."""
    mgr = CheckpointManager(str(tmp_path))
    lane = np.ones(4, np.float32)
    mgr.save(1, {"lane": lane}, blocking=False)
    lane[:] = -1.0  # mutate immediately, racing the writer thread
    mgr.wait()
    restored, _ = mgr.restore({"lane": lane})
    np.testing.assert_array_equal(restored["lane"], np.ones(4, np.float32))


def test_async_save_error_surfaces_on_wait(tmp_path, monkeypatch):
    mgr = CheckpointManager(str(tmp_path))

    def boom(step, host):
        raise OSError("disk full")

    monkeypatch.setattr(mgr, "_write", boom)
    mgr.save(1, {"x": np.zeros(2)}, blocking=False)
    try:
        mgr.wait()
    except OSError as e:
        assert "disk full" in str(e)
    else:
        raise AssertionError("async save error must surface on wait()")
    # the error is consumed: a second wait() is clean
    mgr.wait()


# ---- directory lockfile ----------------------------------------------------

def test_lock_conflict_with_live_foreign_owner(tmp_path):
    """A second writer on a directory held by a LIVE process gets the
    structured conflict error (owner pid attached), not silent
    interleaved saves."""
    proc = subprocess.Popen([sys.executable, "-c",
                             "import time; time.sleep(60)"])
    try:
        with open(tmp_path / ".lock", "w") as f:
            json.dump({"pid": proc.pid, "t": 0.0}, f)
        with pytest.raises(CheckpointLockError) as exc:
            CheckpointManager(str(tmp_path))
        assert exc.value.owner_pid == proc.pid
        assert str(tmp_path) in str(exc.value)
    finally:
        proc.kill()
        proc.wait()


def test_lock_dead_owner_reclaimed(tmp_path, rng):
    """A crashed writer must not brick its directory: a lock held by a
    DEAD pid is reclaimed (with a warning) and the directory works."""
    proc = subprocess.Popen([sys.executable, "-c", "pass"])
    proc.wait()  # reaped: the pid is dead
    with open(tmp_path / ".lock", "w") as f:
        json.dump({"pid": proc.pid, "t": 0.0}, f)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tree(rng))
    assert mgr.all_steps() == [1]
    with open(tmp_path / ".lock") as f:
        assert json.load(f)["pid"] == os.getpid()
    mgr.close()


def test_lock_reentrant_same_process_and_close_releases(tmp_path, rng):
    """Same-process reopen adopts the lock (per-bucket managers under
    one root); close() releases it for the next process."""
    mgr1 = CheckpointManager(str(tmp_path))
    mgr2 = CheckpointManager(str(tmp_path))  # adopt, no conflict
    mgr2.save(1, _tree(rng))
    mgr1.close()
    mgr2.close()
    assert not os.path.exists(tmp_path / ".lock")
    # released: a fresh open takes the lock cleanly
    CheckpointManager(str(tmp_path)).close()


def test_lock_torn_unreadable_lockfile_reclaimed(tmp_path):
    """A torn lock write by a dying owner reads as dead after a beat —
    the directory is reclaimed, not bricked."""
    with open(tmp_path / ".lock", "w") as f:
        f.write("{pid: 12")  # not JSON
    mgr = CheckpointManager(str(tmp_path))
    assert os.path.exists(tmp_path / ".lock")
    mgr.close()
