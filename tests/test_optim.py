"""Optimizer + anchored gradient compression."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.optim import adamw, compress

# jax.shard_map is top-level only in newer jax releases.
shard_map = getattr(jax, "shard_map", None)
if shard_map is None:
    from jax.experimental.shard_map import shard_map


def test_adamw_minimizes_quadratic():
    cfg = adamw.OptConfig(lr=0.1, warmup_steps=5, total_steps=200,
                          weight_decay=0.0, clip_norm=100.0)
    params = {"w": jnp.asarray([3.0, -2.0, 5.0])}
    st = adamw.init(params)
    for _ in range(150):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, st, _ = adamw.apply_updates(cfg, params, g, st)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_clip_and_schedule():
    g = {"w": jnp.full((10,), 100.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    cfg = adamw.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                          min_lr_frac=0.1)
    assert float(adamw.schedule(cfg, jnp.asarray(5))) == 0.5
    assert abs(float(adamw.schedule(cfg, jnp.asarray(100))) - 0.1) < 1e-6


def test_compress_roundtrip_and_error_feedback(rng):
    g = jnp.asarray(rng.normal(2.0, 0.5, (1000,)), jnp.float32)
    c, carry = compress.compress(g)
    dec = compress.decompress(c, g.shape)
    # int8 on [-1,1]: rel err ~1/127 of block spread
    assert float(jnp.max(jnp.abs(dec - g))) < 0.5 * 2 / 127 * 4 + 1e-3
    # error feedback: carry equals the quantization error
    np.testing.assert_allclose(np.asarray(g - dec), np.asarray(carry),
                               atol=1e-6)
    # accumulated: compressing g+carry repeatedly is unbiased
    total = jnp.zeros_like(g)
    carry = jnp.zeros_like(g)
    for _ in range(50):
        c, carry = compress.compress(g, carry)
        total = total + compress.decompress(c, g.shape)
    np.testing.assert_allclose(np.asarray(total / 50), np.asarray(g),
                               atol=2e-3)


def test_compression_ratio():
    r = compress.compression_ratio((1024, 1024))
    assert r > 3.8  # ~4x vs fp32


def test_all_reduce_compressed_single_axis(rng):
    """shard_map over the single CPU device: collective semantics with
    axis size 1 (degenerate but exercises the full code path)."""
    mesh = jax.make_mesh((1,), ("d",))
    g = jnp.asarray(rng.normal(size=(512,)), jnp.float32)

    def f(x):
        mean, carry = compress.all_reduce_compressed(x, "d")
        return mean, carry

    out, carry = shard_map(
        f, mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
        out_specs=jax.sharding.PartitionSpec())(g)
    np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.02)
    np.testing.assert_allclose(np.asarray(g - out), np.asarray(carry),
                               atol=1e-6)
