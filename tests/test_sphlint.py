"""sphlint's own test suite: fixture corpus, baseline, CLI, jaxpr audit.

The fixture corpus (tools/sphlint/fixtures/) pairs each rule with a
minimized replay of the historical incident it encodes (bad_*) and the
idiomatic fixed form (good_*). The self-check test pins the committed
baseline to the current tree: new findings AND stale entries both fail.
"""
import json
import sys
from pathlib import Path

import pytest

REPO_ROOT = Path(__file__).resolve().parents[1]
if str(REPO_ROOT) not in sys.path:
    sys.path.insert(0, str(REPO_ROOT))

from tools.sphlint import baseline as bl  # noqa: E402
from tools.sphlint.__main__ import DEFAULT_PATHS, main  # noqa: E402
from tools.sphlint.engine import Finding, lint_paths  # noqa: E402
from tools.sphlint.rules import RULE_NAMES, default_rules  # noqa: E402

FIXTURES = REPO_ROOT / "tools" / "sphlint" / "fixtures"

RULE_FIXTURES = {
    "dtype-literal": "dtype_literal",
    "host-sync-in-scan": "host_sync",
    "cond-under-vmap": "cond_under_vmap",
    "static-arg-hashability": "static_arg",
    "donation-alias": "donation_alias",
    "silent-fallback": "silent_fallback",
}


def _lint(path: Path):
    return lint_paths([str(path)])


# --------------------------------------------------------------------------
# rule corpus: every rule trips on its incident replay, never on the fix
# --------------------------------------------------------------------------
def test_registry_covers_all_fixture_rules():
    assert set(RULE_FIXTURES) == set(RULE_NAMES)


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_bad_fixture_trips_its_rule(rule):
    findings = _lint(FIXTURES / f"bad_{RULE_FIXTURES[rule]}.py")
    assert any(f.rule == rule for f in findings), (
        f"{rule}: bad fixture produced {[f.rule for f in findings]}"
    )


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_bad_fixture_trips_only_its_rule(rule):
    findings = _lint(FIXTURES / f"bad_{RULE_FIXTURES[rule]}.py")
    assert {f.rule for f in findings} == {rule}


@pytest.mark.parametrize("rule", sorted(RULE_FIXTURES))
def test_good_fixture_is_clean(rule):
    findings = _lint(FIXTURES / f"good_{RULE_FIXTURES[rule]}.py")
    assert findings == [], [f.render() for f in findings]


def test_pragmas_suppress_findings():
    findings = _lint(FIXTURES / "pragma_suppressed.py")
    assert findings == [], [f.render() for f in findings]


def test_fixture_dir_excluded_from_directory_sweep():
    swept = lint_paths([str(FIXTURES.parent)])  # tools/sphlint as a dir
    assert swept == [], [f.render() for f in swept]


def test_severity_all_errors_for_gating_rules():
    # CI gates on errors; every incident rule must block the merge
    assert all(r.severity == "error" for r in default_rules())


# --------------------------------------------------------------------------
# baseline semantics: exact match, both directions
# --------------------------------------------------------------------------
def _sample_findings():
    return lint_paths([str(FIXTURES / "bad_dtype_literal.py"),
                       str(FIXTURES / "bad_silent_fallback.py")])


def test_baseline_round_trip(tmp_path):
    findings = _sample_findings()
    path = tmp_path / "baseline.json"
    bl.save(path, findings)
    loaded = bl.load(path)
    assert [f.key for f in loaded] == [f.key for f in findings]
    new, matched, stale = bl.partition(findings, loaded)
    assert new == [] and stale == [] and len(matched) == len(findings)


def test_unbaselined_finding_is_new():
    findings = _sample_findings()
    new, matched, stale = bl.partition(findings, findings[1:])
    assert new == [findings[0]]
    assert stale == []


def test_stale_baseline_entry_is_reported():
    findings = _sample_findings()
    ghost = Finding(rule="dtype-literal", path="deleted.py", line=1,
                    col=0, message="long-gone finding")
    new, matched, stale = bl.partition(findings, findings + [ghost])
    assert new == []
    assert stale == [ghost]


def test_baseline_matches_with_multiplicity():
    f = _sample_findings()[0]
    new, matched, stale = bl.partition([f, f], [f])
    assert len(new) == 1 and len(matched) == 1


def test_committed_baseline_exactly_matches_tree(monkeypatch):
    """The shipped tree must lint clean against the shipped baseline —
    a new finding fails, and so does a stale (already-fixed) entry."""
    monkeypatch.chdir(REPO_ROOT)
    base = bl.load(REPO_ROOT / bl.BASELINE_NAME)
    findings = lint_paths(DEFAULT_PATHS)
    new, matched, stale = bl.partition(findings, base)
    assert new == [], [f.render() for f in new]
    assert stale == [], [f.render() for f in stale]


# --------------------------------------------------------------------------
# CLI exit codes
# --------------------------------------------------------------------------
def test_cli_exit_nonzero_on_each_rule(capsys):
    for rule, stem in sorted(RULE_FIXTURES.items()):
        rc = main(["check", str(FIXTURES / f"bad_{stem}.py"),
                   "--no-baseline"])
        assert rc == 1, f"{rule}: expected exit 1"
    capsys.readouterr()


def test_cli_exit_zero_on_clean_tree(capsys, monkeypatch):
    monkeypatch.chdir(REPO_ROOT)
    assert main(["check"]) == 0
    capsys.readouterr()


def test_cli_subtree_check_scopes_baseline(capsys, monkeypatch):
    """Checking src/repro alone must not report the benchmarks-only
    baseline entries as stale — the baseline is scoped to linted paths."""
    monkeypatch.chdir(REPO_ROOT)
    assert main(["check", "src/repro"]) == 0
    out = capsys.readouterr()
    assert "0 stale" in out.out + out.err


def test_cli_baseline_regenerates_exactly(tmp_path, capsys, monkeypatch):
    monkeypatch.chdir(REPO_ROOT)
    out = tmp_path / "regen.json"
    assert main(["baseline", "--baseline", str(out)]) == 0
    committed = json.loads((REPO_ROOT / bl.BASELINE_NAME).read_text())
    regen = json.loads(out.read_text())
    assert regen["findings"] == committed["findings"]
    capsys.readouterr()


# --------------------------------------------------------------------------
# Layer B: the jaxpr auditor's own invariants (no SPH build needed)
# --------------------------------------------------------------------------
def test_audit_flags_f16_arithmetic():
    import jax
    import jax.numpy as jnp

    from tools.sphlint.trace import audit_jaxpr

    jaxpr = jax.make_jaxpr(lambda x: x * x + x)(
        jnp.ones((4,), jnp.float16))
    r = audit_jaxpr(jaxpr, "t")
    assert r["f16_violations"], r


def test_audit_allows_structural_f16():
    import jax
    import jax.numpy as jnp

    from tools.sphlint.trace import audit_jaxpr

    def f(x):
        h = x.astype(jnp.float16)  # convert: allowed
        g = h[jnp.array([0, 1])]  # gather: allowed
        return g.reshape(2, 1).astype(jnp.float32) * 2.0

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    r = audit_jaxpr(jaxpr, "t")
    assert r["f16_violations"] == [], r
    assert r["census"].get("float16", 0) >= 2


def test_audit_finds_f16_arithmetic_inside_scan():
    import jax
    import jax.numpy as jnp

    from tools.sphlint.trace import audit_jaxpr

    def f(x):
        def body(c, _):
            return c + jnp.float16(1.0), None  # f16 add inside the scan

        return jax.lax.scan(body, x, None, length=3)[0]

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float16))
    r = audit_jaxpr(jaxpr, "t")
    assert any("add" in v for v in r["f16_violations"]), r


def test_audit_flags_debug_callback():
    import jax
    import jax.numpy as jnp

    from tools.sphlint.trace import audit_jaxpr

    def f(x):
        jax.debug.print("x = {}", x)
        return x + 1.0

    jaxpr = jax.make_jaxpr(f)(jnp.ones((4,), jnp.float32))
    r = audit_jaxpr(jaxpr, "t")
    assert r["callback_violations"], r


def test_audit_census_counts_dtypes():
    import jax
    import jax.numpy as jnp

    from tools.sphlint.trace import audit_jaxpr

    jaxpr = jax.make_jaxpr(lambda x: (x + 1.0, (x > 0).astype(jnp.int32)))(
        jnp.ones((4,), jnp.float32))
    r = audit_jaxpr(jaxpr, "t")
    assert r["census"].get("float32", 0) >= 1
    assert r["census"].get("int32", 0) >= 1
