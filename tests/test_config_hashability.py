"""Hashability contract for the config-family dataclasses.

These classes ride ``jax.jit`` as static arguments and key the
serve/sweep normalized-config compile caches, so they must be frozen
with hashable leaves, hash stably, and bucket identically when equal —
the invariant sphlint's ``static-arg-hashability`` rule enforces
statically, checked here at runtime.
"""
import dataclasses

import pytest

from repro.core import cases as cases_lib
from repro.core.health import FaultSpec
from repro.core.precision import APPROACHES, PrecisionPolicy
from repro.core.recovery import GuardPolicy
from repro.core.scheme import Scheme


@pytest.mark.parametrize("name", ["dam_break", "taylor_green"])
def test_sphconfig_hash_stable_and_bucketed(name):
    ds = cases_lib.resolve_ds(name, 200)
    cfg, _ = cases_lib.build_case(name, ds=ds).build()
    cfg2, _ = cases_lib.build_case(name, ds=ds).build()
    assert cfg == cfg2
    assert hash(cfg) == hash(cfg)  # stable across calls
    assert hash(cfg) == hash(cfg2)  # equal configs, equal hashes
    bucket = {cfg: "compiled"}
    assert bucket[cfg2] == "compiled"  # cache hit, not a cache split


def test_sphconfig_field_change_changes_equality():
    ds = cases_lib.resolve_ds("taylor_green", 200)
    cfg, _ = cases_lib.build_case("taylor_green", ds=ds).build()
    cfg_b = dataclasses.replace(cfg, dt=cfg.dt * 0.5)
    assert cfg != cfg_b
    assert len({cfg: 1, cfg_b: 2}) == 2


@pytest.mark.parametrize("obj", [
    PrecisionPolicy(),
    *APPROACHES.values(),
    GuardPolicy(),
    FaultSpec(kind="nan_v", step=3),
    Scheme(c0=10.0, rho0=1.0),
], ids=lambda o: type(o).__name__)
def test_config_family_is_frozen_and_hashable(obj):
    assert dataclasses.fields(obj), "expected a dataclass"
    assert type(obj).__dataclass_params__.frozen
    assert hash(obj) == hash(obj)
    clone = dataclasses.replace(obj)
    assert obj == clone and hash(obj) == hash(clone)
    with pytest.raises(dataclasses.FrozenInstanceError):
        object.__setattr__  # appease linters; the real check below
        setattr(obj, dataclasses.fields(obj)[0].name, None)


def test_all_config_leaves_hashable():
    """Every leaf of every shipped config dataclass must be hashable —
    a list/dict leaf would crash jit static-arg hashing at trace time."""
    ds = cases_lib.resolve_ds("dam_break", 200)
    cfg, _ = cases_lib.build_case("dam_break", ds=ds).build()

    def walk(obj, path="cfg"):
        hash(obj)  # raises TypeError on an unhashable leaf
        if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
            for f in dataclasses.fields(obj):
                walk(getattr(obj, f.name), f"{path}.{f.name}")

    walk(cfg)
