"""Dry-run machinery unit tests (parser + sharding heuristics); the full
512-device dry-run runs via `python -m repro.launch.dryrun`."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.launch import shardings as sh


def test_collective_bytes_parser():
    from repro.launch import dryrun
    hlo = """
  %ag = f32[16,256]{1,0} all-gather(f32[16,16]{1,0} %p), dimensions={1}
  %ar.1 = bf16[1024]{0} all-reduce(bf16[1024]{0} %x), to_apply=%sum
  %rs = f32[8]{0} reduce-scatter(f32[64]{0} %y), dimensions={0}
  %tup = (f32[4]{0}, f32[8]{0}) all-reduce(f32[4] %a, f32[8] %b)
  %cp = u8[128]{0} collective-permute-start(u8[128]{0} %z)
  %notacoll = f32[9]{0} add(f32[9] %q, f32[9] %r)
"""
    out = dryrun.collective_bytes(hlo)
    assert out["by_op"]["all-gather"] == 16 * 256 * 4
    assert out["by_op"]["all-reduce"] == 1024 * 2 + (4 + 8) * 4
    assert out["by_op"]["reduce-scatter"] == 8 * 4
    assert out["by_op"]["collective-permute"] == 128
    assert out["counts"]["all-reduce"] == 2
    assert out["total"] == sum(out["by_op"].values())


def test_cache_sharding_heuristic():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cache = {
        "k": jax.ShapeDtypeStruct((4, 8, 1024, 16, 64), jnp.bfloat16),
        "length": jax.ShapeDtypeStruct((4, 8), jnp.int32),
    }
    out = sh.cache_shardings(mesh, cache, batch=8, seq_len=1024)
    spec_k = out["k"].spec
    assert spec_k[1] is not None  # batch axis sharded over dp
    # length (layers, B): batch axis may shard over dp, never over model
    lspec = tuple(out["length"].spec)
    assert "model" not in [e for e in lspec if isinstance(e, str)]


def test_model_flops_moe_vs_dense():
    from repro.launch.dryrun import model_flops
    from repro.configs.shapes import SHAPES
    from repro.models import registry
    dense_cfg = registry.get_config("llama3.2-3b")
    moe_cfg = registry.get_config("deepseek-moe-16b")
    sp = SHAPES["train_4k"]
    f_dense = model_flops(dense_cfg, 3_200_000_000, sp)
    assert abs(f_dense - 6 * 3.2e9 * 256 * 4096) / f_dense < 1e-6
    # MoE active < total
    n_total = 16_000_000_000
    f_moe = model_flops(moe_cfg, n_total, sp)
    assert f_moe < 6 * n_total * 256 * 4096
