"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret
mode (CPU validation of the TPU-target kernels)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.core import anchored, cells, domain as D, nnps, rcll
from repro.kernels import (flash_attention as fa, ops,
                           rcll_kv_attention as rk, ref as kref)


def _particle_setup(n, dim=2, seed=0, dtype=jnp.float16, cap=16):
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** (1.0 / dim)
    dom = (D.unit_square(h=1.2 * ds) if dim == 2
           else D.unit_cube(h=1.2 * ds))
    x = rng.uniform(0, 1, (n, dim))
    xn = dom.normalize(jnp.asarray(x))
    st = rcll.init_state(dom, xn, dtype=dtype)
    b = cells.bin_by_cell_id(dom, dom.flat_cell_id(st.cell_xy),
                             st.cell_xy, cap)
    assert int(b.overflow) == 0
    return dom, x, st, b


@pytest.mark.parametrize("n,dim,cap", [(500, 2, 16), (1500, 2, 24),
                                       (800, 3, 32), (200, 2, 128)])
@pytest.mark.parametrize("dtype", [jnp.float16, jnp.bfloat16, jnp.float32])
def test_nnps_adjacency_kernel_sweep(n, dim, cap, dtype):
    dom, x, st, b = _particle_setup(n, dim, dtype=dtype, cap=cap)
    adj_k, cnt_k = ops.rcll_adjacency_cells(dom, b, st.rel, interpret=True)
    rel_t, occ, _ = ops.pack_cells(b, st.rel)
    nb = jnp.asarray(ops.cell_neighbor_ids(dom))
    nb = jnp.concatenate(
        [nb, jnp.full((1, nb.shape[1]), nb.shape[0], nb.dtype)], axis=0)
    adj_r, _ = kref.ref_rcll_adjacency(
        rel_t, occ, nb, cells.neighbor_cell_offsets(dim),
        np.asarray(dom.cell_weights), nnps.rcll_radius_cell_units(dom))
    np.testing.assert_allclose(adj_k, adj_r)
    # counts agree with the core (non-kernel) search
    nl = nnps.rcll_neighbors(dom, st.rel, st.cell_xy, dtype=dtype,
                             compute_dtype=jnp.float32, k=96, binning=b)
    np.testing.assert_array_equal(
        np.asarray(cnt_k).astype(np.int32), np.asarray(nl.count))


@pytest.mark.parametrize("n,dim", [(600, 2), (400, 3)])
@pytest.mark.parametrize("nnps_dtype", [jnp.float16, jnp.float32])
def test_sph_gradient_kernel_sweep(n, dim, nnps_dtype):
    dom, x, st, b = _particle_setup(n, dim, cap=40)
    f = jnp.asarray(x[:, 0] ** 3, jnp.float32)
    g_k = ops.rcll_gradient_particles(dom, b, st.rel, f,
                                      nnps_dtype=nnps_dtype,
                                      interpret=True)
    rel_t, occ, (f_t,) = ops.pack_cells(b, st.rel, f)
    nb = jnp.asarray(ops.cell_neighbor_ids(dom))
    nb = jnp.concatenate(
        [nb, jnp.full((1, nb.shape[1]), nb.shape[0], nb.dtype)], axis=0)
    num, den = kref.ref_rcll_gradient(
        rel_t, f_t, occ, nb, cells.neighbor_cell_offsets(dim),
        np.asarray(dom.cell_weights), nnps.rcll_radius_cell_units(dom),
        np.asarray(dom.cell_sizes), dom.h, dim, compute_dtype=nnps_dtype)
    den = jnp.where(jnp.abs(den) > 1e-12,
                    den, jnp.where(den >= 0, 1e-12, -1e-12))
    g_r = ops.unpack_per_particle((num / den).transpose(0, 2, 1), b)
    np.testing.assert_allclose(g_k, g_r, rtol=2e-4, atol=2e-4)
    # physics: interior gradient approximates 3x^2 (skip if the domain
    # is too coarse to have interior particles, e.g. small 3-D sets)
    interior = (np.abs(x - 0.5) < 0.5 - 2.5 * dom.h).all(axis=1)
    if interior.sum() >= 10:
        want = 3 * x[interior, 0] ** 2
        got = np.asarray(g_k)[interior, 0]
        assert np.sqrt(np.mean((got - want) ** 2)) < 0.15


@pytest.mark.parametrize("B,H,Hkv,L,Dh,bq,bk", [
    (1, 2, 2, 128, 32, 64, 64),
    (2, 4, 2, 256, 64, 128, 64),
    (1, 8, 1, 512, 64, 128, 128),
])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("in_dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(B, H, Hkv, L, Dh, bq, bk, causal, in_dtype):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, L, Dh)), in_dtype)
    k = jnp.asarray(rng.normal(size=(B, Hkv, L, Dh)), in_dtype)
    v = jnp.asarray(rng.normal(size=(B, Hkv, L, Dh)), in_dtype)
    out = fa.flash_attention(q, k, v, causal=causal, block_q=bq,
                             block_k=bk, interpret=True)
    ref = kref.ref_attention(q, k, v, causal=causal)
    tol = 2e-5 if in_dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@pytest.mark.parametrize("B,H,Hkv,Dh,nblk,blk", [
    (1, 4, 4, 32, 2, 128),
    (2, 8, 2, 64, 4, 128),
    (3, 6, 2, 128, 3, 256),
])
@pytest.mark.parametrize("resid_dtype", [jnp.float16, jnp.int8])
def test_rcll_kv_decode_sweep(B, H, Hkv, Dh, nblk, blk, resid_dtype):
    rng = np.random.default_rng(1)
    L = nblk * blk
    q = jnp.asarray(rng.normal(size=(B, H, Dh)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, Hkv, L, Dh)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, Hkv, L, Dh)), jnp.float32)
    length = jnp.asarray(rng.integers(1, L + 1, (B,)), jnp.int32)
    ek = anchored.encode(k, block=blk, axis=2, dtype=resid_dtype)
    ev = anchored.encode(v, block=blk, axis=2, dtype=resid_dtype)
    out = rk.rcll_kv_decode(q, ek.residual, ek.anchor, ek.scale,
                            ev.residual, ev.anchor, ev.scale, length,
                            interpret=True)
    ref = kref.ref_rcll_kv_decode(q, ek.residual, ek.anchor, ek.scale,
                                  ev.residual, ev.anchor, ev.scale, length)
    np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-5)
    # quantization keeps attention output close to exact
    exact = kref.ref_attention(q[:, :, None], k, v, causal=False)[:, :, 0]
    # compare only rows with full length (mask semantics differ otherwise)
    full = np.asarray(length) == L
    if full.any():
        err = np.abs(np.asarray(out)[full] - np.asarray(exact)[full]).max()
        assert err < (0.01 if resid_dtype == jnp.int8 else 0.001)


def test_fused_gradient_matches_two_pass():
    """Fusion argument (Table 6): fused kernel == adjacency-then-gradient
    two-pass reference on the same tables."""
    dom, x, st, b = _particle_setup(700, 2, cap=24)
    f = jnp.asarray(np.sin(3 * x[:, 0]) + x[:, 1], jnp.float32)
    g_fused = ops.rcll_gradient_particles(dom, b, st.rel, f,
                                          nnps_dtype=jnp.float16,
                                          interpret=True)
    # two-pass: neighbor list from core search + pure-jnp A5 gradient
    from repro.core import sph
    nl = nnps.rcll_neighbors(dom, st.rel, st.cell_xy, dtype=jnp.float16,
                             k=64, binning=b)
    disp, r = rcll.pair_displacements(dom, st, nl)
    g_two = sph.gradient_normalized_pairs(f, disp, r, nl.idx, nl.mask,
                                          dom.h, 2)
    np.testing.assert_allclose(g_fused, g_two, rtol=2e-3, atol=2e-3)


# --------------------------------------------------------------------------
# One-sweep cell-pack kernel vs its jnp oracle
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n,dim,seed", [(500, 2, 0), (300, 3, 1)])
def test_cell_pack_kernel_matches_ref(n, dim, seed):
    from repro.kernels import cell_pack

    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** (1.0 / dim)
    dom = (D.unit_square(h=1.2 * ds) if dim == 2
           else D.unit_cube(h=1.2 * ds))
    x = rng.uniform(0, 1, (n, dim))
    st = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    cap = cells.default_capacity(dom, n, safety=6.0)
    ps = rcll.pack_state(dom, st, cap)
    b = ps.packing.binning
    starts = cells.exclusive_cumsum(b.counts)
    rows16 = jax.lax.bitcast_convert_type(ps.rc.rel, jnp.uint16)
    rows32 = jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)
    fill32 = jnp.asarray([1.0, 0.0], jnp.float32)
    out_k = cell_pack.cell_tables(
        rows16, rows32, starts, b.counts, fill32, cap=cap, interpret=True
    )
    out_r = cell_pack.cell_tables_ref(
        rows16, rows32, starts, b.counts, fill32, cap=cap
    )
    for a, c in zip(out_k, out_r):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(c))
    # the emitted id table IS the counting-sort packed table (+ sentinel)
    np.testing.assert_array_equal(
        np.asarray(out_k[2][:-1]), np.asarray(b.table)
    )
    assert np.all(np.asarray(out_k[2][-1]) == -1)
