"""Domain geometry + Eq. 5/6 coordinate transforms."""
import numpy as np
import pytest
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import domain as D


def test_normalize_roundtrip(rng):
    dom = D.Domain(lo=(-2.0, 1.0), hi=(3.0, 4.0), h=0.05)
    x = rng.uniform([-2, 1], [3, 4], (100, 2))
    xn = dom.normalize(jnp.asarray(x))
    assert float(jnp.max(jnp.abs(xn))) <= 1.0 + 1e-6
    back = dom.denormalize(xn)
    np.testing.assert_allclose(back, x, atol=1e-5)


def test_relative_roundtrip(rng):
    dom = D.unit_square(h=0.03)
    x = rng.uniform(0, 1, (500, 2))
    xn = dom.normalize(jnp.asarray(x))
    c = dom.cell_coords_of(xn)
    rel = dom.to_relative(xn, c, dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(rel))) <= 1.0 + 1e-4
    back = dom.from_relative(rel, c)
    np.testing.assert_allclose(back, xn, atol=1e-6)


def test_relative_fp16_error_bound(rng):
    dom = D.unit_square(h=0.01)
    x = rng.uniform(0, 1, (1000, 2))
    xn = dom.normalize(jnp.asarray(x))
    c = dom.cell_coords_of(xn)
    rel16 = dom.to_relative(xn, c, dtype=jnp.float16)
    back = dom.from_relative(rel16, c)
    # error bounded by fp16 eps * half cell
    bound = max(dom.hc_norm_axes) / 2 * 2 ** -10
    assert float(jnp.max(jnp.abs(back - xn))) <= bound


def test_periodic_grid_tiles_exactly():
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=0.013,
                   periodic=(True, True))
    for n, cs, span in zip(dom.ncells, dom.cell_sizes, dom.spans):
        assert abs(n * cs - span) < 1e-12
        assert cs >= dom.radius - 1e-12


def test_wall_grid_covers():
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=0.013)
    for n, cs, span in zip(dom.ncells, dom.cell_sizes, dom.spans):
        assert n * cs >= span - 1e-12


def test_periodic_needs_three_cells():
    with pytest.raises(AssertionError):
        D.Domain(lo=(0.0,), hi=(0.1,), h=0.02, periodic=(True,))


def test_wrap_cell_delta():
    dom = D.Domain(lo=(0.0, 0.0), hi=(1.0, 1.0), h=0.02,
                   periodic=(True, False))
    n = dom.ncells[0]
    delta = jnp.asarray([[n - 1, n - 1], [-(n - 1), 3]])
    wrapped = dom.wrap_cell_delta(delta)
    assert int(wrapped[0, 0]) == -1  # periodic axis wraps
    assert int(wrapped[0, 1]) == n - 1  # wall axis untouched
    assert int(wrapped[1, 0]) == 1


@settings(max_examples=25, deadline=None)
@given(
    h=st.floats(0.01, 0.2),
    span=st.floats(0.5, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_cell_assignment_consistent(h, span, seed):
    """cell_coords_of o from_relative o to_relative is stable."""
    dom = D.Domain(lo=(0.0, 0.0), hi=(span, span), h=h)
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, span, (64, 2))
    xn = dom.normalize(jnp.asarray(x))
    c = dom.cell_coords_of(xn)
    assert np.all(np.asarray(c) >= 0)
    assert np.all(np.asarray(c) < np.asarray(dom.ncells))
    rel = dom.to_relative(xn, c, dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(rel))) <= 1.0 + 1e-3
