"""SSD chunked scan vs naive recurrence; decode == forward."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.models import mamba2


def _naive_ssm(x, dt, a, B, C):
    """Direct recurrence oracle."""
    b, L, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = np.repeat(np.asarray(B), rep, axis=2)
    Ch = np.repeat(np.asarray(C), rep, axis=2)
    xn, dtn, an = map(np.asarray, (x, dt, a))
    state = np.zeros((b, h, p, n))
    y = np.zeros((b, L, h, p))
    for t in range(L):
        decay = np.exp(dtn[:, t] * an[None, :])  # (b,h)
        state = state * decay[..., None, None] + np.einsum(
            "bh,bhp,bhn->bhpn", dtn[:, t], xn[:, t], Bh[:, t])
        y[:, t] = np.einsum("bhpn,bhn->bhp", state, Ch[:, t])
    return y, state


def test_ssd_chunked_matches_recurrence(rng):
    b, L, h, p, g, n = 2, 64, 4, 8, 2, 16
    dims = mamba2.SSMDims(0, h * p, h, p, n, g, 4)
    x = jnp.asarray(rng.normal(size=(b, L, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.1, 0.9, (b, L, h)), jnp.float32)
    a = jnp.asarray(-rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = jnp.asarray(rng.normal(size=(b, L, g, n)), jnp.float32)
    C = jnp.asarray(rng.normal(size=(b, L, g, n)), jnp.float32)
    for chunk in (16, 32, 64):
        y, state = mamba2.ssd_chunked(x, dt, a, B, C, dims, chunk)
        y_ref, state_ref = _naive_ssm(x, dt, a, B, C)
        np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4,
                                   atol=2e-4)
        np.testing.assert_allclose(np.asarray(state), state_ref,
                                   rtol=2e-4, atol=2e-4)


def test_forward_then_decode_consistent(rng):
    dims = mamba2.make_dims(32, 16, expand=2, head_dim=16)
    p = mamba2.init_mamba2(jax.random.key(0), dims)
    B, L = 2, 16
    x = jnp.asarray(rng.normal(size=(B, L + 1, 32)) * 0.3, jnp.float32)
    out_full, _ = mamba2.mamba2_forward(p, x, dims, chunk=8,
                                        compute_dtype=jnp.float32)
    out_pre, cache = mamba2.mamba2_forward(p, x[:, :L], dims, chunk=8,
                                           compute_dtype=jnp.float32)
    out_dec, _ = mamba2.mamba2_decode(p, x[:, L:], cache, dims,
                                      compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out_dec[:, 0]),
                               np.asarray(out_full[:, L]),
                               rtol=2e-3, atol=2e-3)
