"""Fault-isolated batched ensemble engine (``core/ensemble.py``).

The contract under test, in order of importance:

  * isolation: one faulted member recovers (or quarantines) WITHOUT
    perturbing the others — every healthy member's final state is
    bit-identical to its own solo unguarded run, and no healthy member
    is ever rolled back or replayed;
  * clean batches are pure overhead: all members bit-match solo runs;
  * durability: a checkpointed ensemble killed mid-sweep (simulated by
    stopping after a partial run), even with the NEWEST checkpoint torn
    by the crash, resumes from the previous valid step and finishes
    bit-identical to the uninterrupted run;
  * sweep service: shape-bucketing, request-order results, per-bucket
    fault constraint.
"""
import dataclasses
import os
import time

import numpy as np
import pytest
import jax
import jax.numpy as jnp

import faults
from repro.checkpoint.manager import CheckpointManager
from repro.core import ensemble, health, recovery, solver


def _fresh(tree):
    """Deep-copy device leaves: solo runs DONATE their carry, which
    would invalidate buffers shared across member states."""
    return jax.tree.map(jnp.array, tree)


def _bitmatch(a, b) -> bool:
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def _members(cfg, state, B, scale=0.01):
    """B member states: #0 unperturbed, the rest with seeded velocity
    perturbations (distinct trajectories, same shapes)."""
    out = []
    for i in range(B):
        v = np.array(state.fluid.v)
        if i:
            rng = np.random.default_rng(100 + i)
            v = v + scale * rng.standard_normal(v.shape).astype(v.dtype)
        out.append(_fresh(state._replace(
            fluid=state.fluid._replace(v=jnp.asarray(v)))))
    return out


def _solo(mcfg, state, nsteps):
    carry = solver.init_persistent(mcfg, _fresh(state))
    carry = solver.run_persistent(mcfg, carry, nsteps)
    return solver.finalize_persistent(mcfg, carry)


class TestEnsembleCore:
    def test_clean_batch_bitmatches_solo_runs(self):
        """Healthy members pay zero numerical cost for batching: each
        lane bit-matches its own solo unguarded run, including across a
        target that is NOT a multiple of the block length."""
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        mcfg = ensemble.member_config(cfg, policy)
        states = _members(cfg, st, 4)
        outs, stats, rep = ensemble.run_ensemble(mcfg, states, 20, policy)
        assert [m.status for m in rep.members] == ["healthy"] * 4
        assert all(int(s.steps) == 20 for s in stats)
        for s, out in zip(states, outs):
            assert _bitmatch(out, _solo(mcfg, s, 20))

    def test_fault_isolation_b8(self):
        """ISSUE acceptance: B=8, one member faulted. The faulted lane
        recovers via lane-masked disarm+replay (bit-matching its clean
        solo trajectory); the other 7 are bit-identical to solo runs and
        were never rolled back (no batch-wide recovery)."""
        B, bad = 8, 3
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        mcfg = ensemble.member_config(cfg, policy)
        states = _members(cfg, st, B)
        fault = health.FaultSpec("nan_v", step=10)
        outs, stats, rep = ensemble.run_ensemble(
            mcfg, states, 24, policy, fault=fault, fault_members=(bad,))
        for i in range(B):
            m = rep.members[i]
            if i == bad:
                assert m.status == "recovered"
                assert m.retries == 1
                assert [e.action for e in m.events] == ["disarm"]
            else:
                assert m.status == "healthy"
                assert m.retries == 0 and m.events == []
            # disarm replay reproduces the UNFAULTED trajectory, so even
            # the faulted member bit-matches its clean solo run.
            assert _bitmatch(outs[i], _solo(mcfg, states[i], 24))

    def test_persistent_fault_quarantines_member_only(self):
        """A persistent fault defeats the ladder: the member is evicted
        to a solo probation leg, diverges there too, and is QUARANTINED
        with the structured error at its last healthy step — while the
        batch finishes and stays bit-exact."""
        B, bad = 4, 1
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(
            block=8, disarm_faults=False, max_dt_halvings=1,
            degrade_records=False)
        mcfg = ensemble.member_config(cfg, policy)
        states = _members(cfg, st, B)
        fault = health.FaultSpec("nan_v", step=10)
        outs, stats, rep = ensemble.run_ensemble(
            mcfg, states, 24, policy, fault=fault, fault_members=(bad,))
        m = rep.members[bad]
        assert m.status == "quarantined"
        assert isinstance(m.error, health.SimulationDiverged)
        assert m.steps < 24  # parked at its last healthy block boundary
        assert any(e.action == "halve_dt" for e in m.events)
        for i in range(B):
            if i == bad:
                continue
            assert rep.members[i].status == "healthy"
            assert _bitmatch(outs[i], _solo(mcfg, states[i], 24))

    def test_member_config_rejects_conflicting_cadence(self):
        cfg, _ = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        with pytest.raises(ValueError, match="rebuild_every"):
            ensemble.member_config(
                dataclasses.replace(cfg, rebuild_every=5), policy)


class TestLaneEngine:
    """Lane retirement edge cases (satellite): slots are freed and
    reused mid-sweep without perturbing live neighbors, and a slot
    previously occupied by a quarantined lane hands its next tenant a
    clean carry."""

    def test_mid_sweep_completion_frees_lane_neighbors_bit_exact(self):
        """A member finishing mid-sweep retires its lane while two
        longer neighbors keep running; a NEW request re-admitted into
        the freed slot runs next to them. Every final state — early
        finisher, both neighbors, and the late tenant — bit-matches its
        own solo run, so neither retirement nor the admission splice
        perturbed anyone."""
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8, snapshot_every=1)
        eng = ensemble.LaneEngine(cfg, slots=3, policy=policy)
        s = _members(cfg, st, 4)
        owner = {eng.admit(s[0], 16): 0,
                 eng.admit(s[1], 32): 1,
                 eng.admit(s[2], 32): 2}
        assert eng.free_lanes == []
        finals, readmitted = {}, False
        for _ in range(16):
            if not eng.live_lanes:
                break
            for ev in eng.step_block():
                if ev.kind != "done":
                    continue
                finals[owner.pop(ev.lane)] = ev.state
                if not readmitted:
                    # the early finisher freed its slot mid-sweep...
                    assert ev.lane in eng.free_lanes
                    assert len(eng.live_lanes) == 2
                    # ...and the replacement lands in that same slot
                    lane = eng.admit(s[3], 16)
                    assert lane == ev.lane
                    owner[lane] = 3
                    readmitted = True
        assert readmitted
        assert set(finals) == {0, 1, 2, 3}
        for idx, nsteps in ((0, 16), (1, 32), (2, 32), (3, 16)):
            assert _bitmatch(finals[idx], _solo(eng.cfg, s[idx], nsteps)), idx

    def test_readmission_after_quarantine_starts_from_clean_carry(self):
        """slots=1: a poisoned non-disarmable request burns through dt
        backoff into quarantine (structured diverged event, slot
        freed). The next tenant of that same slot must start from a
        clean carry — its final state bit-matches a solo run, proving
        no NaN rows or lane bookkeeping leaked from the quarantined
        occupant."""
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(
            block=8, snapshot_every=1, max_dt_halvings=1)
        eng = ensemble.LaneEngine(cfg, slots=1, policy=policy)
        s = _members(cfg, st, 2)
        fault = health.FaultSpec("nan_v", step=4)
        assert eng.admit(s[0], 16, fault=fault, disarmable=False) == 0
        diverged = None
        for _ in range(8):
            for ev in eng.step_block():
                if ev.kind == "diverged":
                    diverged = ev
            if diverged is not None:
                break
        assert diverged is not None
        assert "nan_v" in diverged.checks
        assert [e.action for e in diverged.events] == \
            ["halve_dt", "quarantine"]
        assert eng.free_lanes == [0]
        # same slot, clean tenant
        assert eng.admit(s[1], 16) == 0
        done = []
        for _ in range(8):
            done += [e for e in eng.step_block() if e.kind == "done"]
            if not eng.live_lanes:
                break
        assert len(done) == 1 and done[0].lane == 0
        assert done[0].events == []  # no ladder activity for the tenant
        assert _bitmatch(done[0].state, _solo(eng.cfg, s[1], 16))


class TestDurability:
    def test_kill_resume_with_torn_checkpoint_bit_identical(self, tmp_path):
        """ISSUE acceptance: simulate a SIGKILL mid-sweep (partial run,
        process state discarded) AND torn storage (newest checkpoint's
        arrays.npz truncated after commit). Resume must fall back to the
        previous valid step, re-run from there, and produce final states
        bit-identical to the uninterrupted run."""
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        mcfg = ensemble.member_config(cfg, policy)
        states = _members(cfg, st, 3)

        ref, _, _ = ensemble.run_ensemble(mcfg, states, 32, policy)

        # "crashed" run: advances 2 blocks (16 steps), checkpointing
        # each block boundary, then the process dies.
        ck = str(tmp_path / "ck")
        mgr = CheckpointManager(ck, keep=0)
        ensemble.run_ensemble(
            mcfg, states, 16, policy, checkpoint=mgr, checkpoint_every=1)
        assert mgr.all_steps() == [1, 2]

        # torn storage: the newest checkpoint LOOKS committed but its
        # payload did not survive the crash.
        p = os.path.join(ck, "step_00000002", "arrays.npz")
        with open(p, "rb") as f:
            data = f.read()
        with open(p, "wb") as f:
            f.write(data[: len(data) // 2])

        mgr2 = CheckpointManager(ck, keep=0)
        outs, stats, rep = ensemble.run_ensemble(
            mcfg, states, 32, policy, checkpoint=mgr2,
            checkpoint_every=1, resume=True)
        assert rep.resumed_from == 1  # fell back past the torn step 2
        assert all(int(s.steps) == 32 for s in stats)
        for a, b in zip(ref, outs):
            assert _bitmatch(a, b)

    def test_dead_process_heartbeat_detected_on_resume(self, tmp_path):
        from repro.runtime.fault_tolerance import HeartbeatWriter

        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        mcfg = ensemble.member_config(cfg, policy)
        states = _members(cfg, st, 2)
        mgr = CheckpointManager(str(tmp_path), keep=0)
        ensemble.run_ensemble(
            mcfg, states, 8, policy, checkpoint=mgr, checkpoint_every=1)
        # clean exit removes its heartbeat — a later resume must read
        # "clean predecessor", not mistake it for a dead process
        assert not os.path.exists(str(tmp_path / "host_0.hb"))
        _, _, rep = ensemble.run_ensemble(
            mcfg, states, 16, policy, checkpoint=mgr, checkpoint_every=1,
            resume=True, heartbeat_timeout_s=0.01)
        assert not rep.dead_process_detected
        assert rep.predecessor == "clean"
        assert rep.resumed_from == 1
        # plant a stale heartbeat: a predecessor that died mid-run.
        # Staleness is judged by file mtime (timeout + skew), so
        # backdate the file instead of sleeping past the skew window.
        w = HeartbeatWriter(str(tmp_path), 0)
        w.beat(123)
        old = time.time() - 60
        os.utime(w.path, (old, old))
        _, _, rep = ensemble.run_ensemble(
            mcfg, states, 24, policy, checkpoint=mgr, checkpoint_every=1,
            resume=True, heartbeat_timeout_s=0.01)
        assert rep.dead_process_detected
        assert rep.predecessor == "dead"


class TestSweep:
    def test_buckets_by_config_results_in_request_order(self, tmp_path):
        """Two dt variants -> two shape buckets, one compiled batch
        each; results come back in request order with correct names."""
        cfg, st = faults.lattice()
        policy = recovery.GuardPolicy(block=8)
        half = dataclasses.replace(cfg, dt=cfg.dt * 0.5)
        reqs = [
            ensemble.SweepRequest("a0", cfg, _members(cfg, st, 1)[0]),
            ensemble.SweepRequest("b0", half, _members(cfg, st, 1)[0]),
            ensemble.SweepRequest("a1", cfg, _members(cfg, st, 2)[1]),
        ]
        res = ensemble.run_sweep(
            reqs, 16, policy, checkpoint_dir=str(tmp_path / "sw"))
        assert res.names == ["a0", "b0", "a1"]
        assert res.buckets == [[0, 2], [1]]
        assert len(res.reports) == 2
        assert res.counts()["healthy"] == 3
        assert os.path.exists(str(tmp_path / "sw" / "sweep.json"))
        # interleaved bucket members bit-match their solo runs
        mcfg = ensemble.member_config(cfg, policy)
        assert _bitmatch(res.states[0], _solo(mcfg, reqs[0].state, 16))
        assert _bitmatch(res.states[2], _solo(mcfg, reqs[2].state, 16))

    def test_one_fault_per_bucket_enforced(self):
        cfg, st = faults.lattice()
        f1 = health.FaultSpec("nan_v", step=4)
        f2 = health.FaultSpec("nan_v", step=6)
        reqs = [
            ensemble.SweepRequest("m0", cfg, st, fault=f1),
            ensemble.SweepRequest("m1", cfg, st, fault=f2),
        ]
        with pytest.raises(ValueError, match="one distinct FaultSpec"):
            ensemble.run_sweep(reqs, 8, recovery.GuardPolicy(block=8))


class TestGuardReportObs:
    def test_dropped_obs_rows_counted(self):
        """Satellite: rollback used to drop observable rows recorded
        after the rollback point silently; the report now counts them.
        With snapshot_every=3 the snapshot lags the observations, so a
        trip at step 5 rolls back to step 0 and discards the rows
        already recorded at steps 2 and 4 (they are replayed)."""
        cfg, st = faults.lattice()
        cfgf = faults.with_fault(cfg, kind="nan_v", step=5)
        _, _, rep, rows = recovery.run_guarded(
            cfgf, st, 16,
            recovery.GuardPolicy(block=8, snapshot_every=3),
            observe_every=2)
        assert rep.dropped_obs_rows == 2
        assert len(rows) == 16 // 2  # replay restores uniform spacing
