"""Health guard: in-scan detection, rollback recovery, escalation.

Each test drives exactly one recovery path of ``core/recovery.py``
through the deterministic fault harness (``tests/faults.py``):

  * detection unit tests on :func:`health.check_carry` bits;
  * disarm:   injected NaN -> rollback -> clean replay, bit-matching
              the never-faulted trajectory;
  * regrow:   undersized cell capacity / search window -> demand-sized
              regrow, bit-matching a fresh run under the regrown config
              (capacity regrow bit-matches the ORIGINAL config too, as
              the cell table never enters the window-search trajectory);
  * backoff:  overscale dt on the dam break (the PR 5 water-hammer
              incident) -> bounded dt halving;
  * degrade:  >2^11-cells/axis grid -> records fp16 -> fp32 at init;
  * exhaust:  persistent fault + exhausted policy -> structured
              SimulationDiverged with the right step/checks.
"""
import dataclasses

import numpy as np
import pytest
import jax.numpy as jnp

import faults
from repro.core import health, recovery, solver
from repro.core.api import Simulation


def _fluid_finite(state) -> bool:
    fl = ~np.asarray(state.fixed)
    return bool(
        np.isfinite(np.asarray(state.fluid.v)[fl]).all()
        and np.isfinite(np.asarray(state.fluid.rho)[fl]).all()
    )


def _bitmatch(a, b) -> bool:
    return bool(
        jnp.array_equal(a.fluid.v, b.fluid.v)
        and jnp.array_equal(a.fluid.rho, b.fluid.rho)
        and jnp.array_equal(a.rc.rel, b.rc.rel)
    )


# --------------------------------------------------------------------------
# detection: the health word
# --------------------------------------------------------------------------
class TestCheckCarry:
    def test_clean_carry_is_healthy(self):
        cfg, st = faults.lattice()
        carry = solver.init_persistent(cfg, st)
        hw = health.check_carry(cfg, carry)
        assert int(hw.word) == 0
        assert int(hw.bad_x) == int(hw.bad_v) == int(hw.bad_rho) == 0
        assert float(hw.vmax) > 0

    def test_nan_bits_and_masked_stats(self):
        cfg, st = faults.lattice()
        carry = solver.init_persistent(cfg, st)
        fl = carry.st.fluid
        v = fl.v.at[3, 0].set(jnp.nan)
        rho = fl.rho.at[5].set(jnp.inf)
        carry = carry._replace(
            st=carry.st._replace(fluid=fl._replace(v=v, rho=rho))
        )
        hw = health.check_carry(cfg, carry)
        word = int(hw.word)
        assert word & health.NAN_V and word & health.NAN_RHO
        assert int(hw.bad_v) == 1 and int(hw.bad_rho) == 1
        # stats stay finite under poisoning (non-finite entries masked)
        assert np.isfinite(float(hw.vmax))
        assert np.isfinite(float(hw.rho_dev))

    def test_rho_dev_and_cfl_bits(self):
        cfg, st = faults.lattice()
        carry = solver.init_persistent(cfg, st)
        fl = carry.st.fluid
        carry2 = carry._replace(
            st=carry.st._replace(fluid=fl._replace(rho=fl.rho * 2.0))
        )
        assert int(health.check_carry(cfg, carry2).word) & health.RHO_DEV
        big_dt = dataclasses.replace(cfg, dt=1e3)
        assert int(health.check_carry(big_dt, carry).word) & health.CFL

    def test_enabled_mask_suppresses(self):
        cfg, st = faults.lattice()
        carry = solver.init_persistent(cfg, st)
        v = carry.st.fluid.v.at[0, 0].set(jnp.nan)
        carry = carry._replace(
            st=carry.st._replace(fluid=carry.st.fluid._replace(v=v))
        )
        enabled = health.ALL_CHECKS & ~(
            health.NAN_V | health.NAN_X | health.NAN_RHO
        )
        hw = health.check_carry(cfg, carry, enabled=enabled)
        assert int(hw.word) == 0  # disabled checks can never trip

    def test_check_names_and_faultspec_validation(self):
        names = health.check_names(health.NAN_V | health.CELL_OVERFLOW)
        assert names == ("nan_v", "cell_overflow")
        with pytest.raises(ValueError, match="unknown fault"):
            health.FaultSpec("bogus", step=1)


# --------------------------------------------------------------------------
# recovery paths
# --------------------------------------------------------------------------
class TestRecovery:
    def test_clean_guarded_run_matches_unguarded_bitwise(self):
        """The guard must OBSERVE, never perturb: a healthy guarded run
        takes no action and reproduces solver.simulate exactly."""
        cfg, st = faults.lattice()
        out, stats, rep, _ = recovery.run_guarded(
            cfg, st, 16, recovery.GuardPolicy(block=8)
        )
        assert rep.events == [] and not rep.recovered
        assert int(stats.steps) == 16
        assert _bitmatch(out, solver.simulate(cfg, st, 16))

    def test_nan_fault_disarm_bitmatches_unfaulted(self):
        """Transient NaN: detect -> rollback -> disarm -> replay. The
        poisoned block is fully discarded, so the recovered trajectory
        is bit-identical to one that never faulted."""
        cfg, st = faults.lattice()
        cfgf = faults.with_fault(cfg, kind="nan_v", step=5)
        out, _, rep, _ = recovery.run_guarded(
            cfgf, st, 16, recovery.GuardPolicy(block=8)
        )
        assert [e.action for e in rep.events] == ["disarm"]
        assert any("nan" in c for c in rep.events[0].checks)
        assert _bitmatch(out, solver.simulate(cfg, st, 16))

    def test_teleport_fault_recovers(self):
        """Teleport + velocity kick: the viscous lattice damps the
        transient below the default rho_dev limit within a block, so the
        test exercises the policy's tunable threshold — tight enough to
        catch the corruption's ~5x density jump, loose enough that the
        clean replay (dev ~0.002) stays healthy."""
        cfg, st = faults.lattice()
        cfgf = faults.with_fault(
            cfg, kind="teleport", step=5, particle=0, target=7
        )
        policy = recovery.GuardPolicy(block=8, rho_dev_limit=0.005)
        out, _, rep, _ = recovery.run_guarded(cfgf, st, 16, policy)
        assert rep.recovered and rep.events[0].action == "disarm"
        assert "rho_dev" in rep.events[0].checks
        assert _bitmatch(out, solver.simulate(cfg, st, 16))

    def test_cap_regrow_dam_break_bitmatches_unfaulted(self):
        """ISSUE acceptance: dam break with an undersized cell capacity
        completes unattended and bit-matches the adequately-sized run —
        the cell table never enters the window-search trajectory."""
        cfg, st = faults.dam_break()
        bad = dataclasses.replace(cfg, capacity=2)
        out, stats, rep, _ = recovery.run_guarded(
            bad, st, 40, recovery.GuardPolicy(block=20)
        )
        assert rep.regrows >= 1
        assert any(
            "cell_overflow" in e.checks for e in rep.events
        )
        assert not bool(stats.overflow)  # recovered, not just flagged
        assert _bitmatch(out, solver.simulate(cfg, st, 40))

    def test_window_regrow_bitmatches_regrown_config(self):
        """Undersized search window: demand-sized regrow; the recovered
        run bit-matches a fresh run under the regrown config (K changes
        pair-summation padding, so the original-config trajectory is
        only expected to match numerically, not bitwise)."""
        cfg, st = faults.lattice()
        bad = dataclasses.replace(cfg, window=8)
        out, _, rep, _ = recovery.run_guarded(
            bad, st, 16, recovery.GuardPolicy(block=8)
        )
        assert rep.regrows >= 1
        assert any("window_trunc" in e.checks for e in rep.events)
        assert rep.cfg.resolved_window() > 8
        assert _bitmatch(out, solver.simulate(rep.cfg, st, 16))

    def test_dt_backoff_water_hammer(self):
        """The PR 5 incident: an 8x-overscale dt NaNs the dam break
        unguarded (asserted, so this test cannot silently weaken); the
        guard halves dt until the run completes finite."""
        cfg, st = faults.dam_break()
        bad = dataclasses.replace(cfg, dt=cfg.dt * 8)
        blown = solver.simulate(bad, st, 40)
        assert not _fluid_finite(blown)  # the fault is real
        out, _, rep, _ = recovery.run_guarded(
            bad, st, 40, recovery.GuardPolicy(block=20)
        )
        assert rep.dt_halvings >= 1
        assert rep.cfg.dt < bad.dt
        assert _fluid_finite(out)

    def test_records_degrade_past_half_anchor_limit(self):
        """>2^11 cells/axis: the guard degrades records fp16 -> fp32 at
        init, loudly, where the solver's build-time fallback is silent."""
        cfg, st = faults.thin_grid()
        assert solver._resolved_records(cfg) == "fp32"  # silent fallback
        out, _, rep, _ = recovery.run_guarded(
            cfg, st, 4, recovery.GuardPolicy(block=4)
        )
        assert rep.records_degraded
        assert rep.cfg.policy.records == "fp32"
        assert rep.events[0].action == "degrade_records"

    def test_exhaustion_raises_structured(self):
        """A PERSISTENT fault (disarm disabled) defeats dt backoff; the
        run must fail with the structured report, not a NaN array."""
        cfg, st = faults.lattice()
        cfgf = faults.with_fault(cfg, kind="nan_v", step=5)
        policy = recovery.GuardPolicy(
            block=8, disarm_faults=False, max_dt_halvings=2,
            degrade_records=False,
        )
        with pytest.raises(health.SimulationDiverged) as ei:
            recovery.run_guarded(cfgf, st, 16, policy)
        e = ei.value
        assert e.step == 0  # rollback point: last healthy block boundary
        assert any("nan" in c for c in e.checks)
        assert len(e.events) == 2  # both halvings were attempted
        assert all(ev.action == "halve_dt" for ev in e.events)
        assert e.stats["bad_v"] >= 1

    def test_acceptance_combo_cap_and_dt(self):
        """ISSUE acceptance: undersized capacity AND overscale dt in one
        run — the guard regrows AND backs off, unattended."""
        cfg, st = faults.dam_break()
        bad = dataclasses.replace(cfg, capacity=2, dt=cfg.dt * 4)
        out, stats, rep, _ = recovery.run_guarded(
            bad, st, 40, recovery.GuardPolicy(block=20)
        )
        assert rep.regrows >= 1 and rep.dt_halvings >= 1
        assert _fluid_finite(out)
        assert int(stats.steps) == 40

    def test_strict_policy_raises_immediately(self):
        cfg, st = faults.dam_break()
        bad = dataclasses.replace(cfg, capacity=2)
        with pytest.raises(health.SimulationDiverged):
            recovery.run_guarded(
                bad, st, 20,
                recovery.GuardPolicy(block=20, strict=True),
            )


# --------------------------------------------------------------------------
# API + helpers
# --------------------------------------------------------------------------
class TestGuardApi:
    def test_simulation_run_guard_with_observables(self):
        cfg, st = faults.lattice()
        cfgf = faults.with_fault(cfg, kind="nan_v", step=5)
        sim = Simulation(cfg=cfgf, state=st)
        res = sim.run(16, observe_every=8, guard=True)
        assert res.report is not None and res.report.recovered
        assert sim.cfg.fault is None  # escalated config kept for chaining
        assert res.observables.t.shape == (2,)
        assert np.isfinite(np.asarray(res.observables.ekin)).all()
        # observable rows poisoned by the rolled-back block were dropped
        assert np.all(np.diff(np.asarray(res.observables.t)) > 0)

    def test_guard_requires_rcll(self):
        cfg, st = faults.lattice()
        sim = Simulation(cfg=dataclasses.replace(cfg, algo="all"), state=st)
        with pytest.raises(ValueError, match="rcll"):
            sim.run(4, guard=True)

    def test_apply_named_fault(self):
        cfg, _ = faults.lattice()
        assert recovery.apply_named_fault(cfg, "nan", 30, 100).fault.kind \
            == "nan_v"
        assert recovery.apply_named_fault(cfg, "cap", 30, 100).capacity == 2
        assert recovery.apply_named_fault(cfg, "window", 30, 100).window == 8
        assert recovery.apply_named_fault(cfg, "dt", 30, 100).dt \
            == pytest.approx(cfg.dt * 8)
        with pytest.raises(ValueError, match="unknown fault"):
            recovery.apply_named_fault(cfg, "gremlin", 30, 100)

    def test_rel_quantization_error_fp16_halves_of_cell_ulp(self):
        cfg, _ = faults.lattice()
        q16 = recovery.rel_quantization_error(cfg.domain, jnp.float16)
        q32 = recovery.rel_quantization_error(cfg.domain, jnp.float32)
        hc = max(cfg.domain.cell_sizes)
        assert q16 == pytest.approx(hc * 0.5 * 2.0**-11)
        assert q32 < q16 / 1000

    def test_check_overflow_alias_still_raises_with_overflow(self):
        """The deprecated strict alias: same exception contract (message
        mentions overflow) without the in-scan callback it used to cost."""
        cfg, st = faults.dam_break()
        bad = dataclasses.replace(cfg, capacity=2, check_overflow=True)
        with pytest.raises(Exception, match="overflow"):
            solver.simulate_stats(bad, st, 4)
