"""Background-cell binning (the static 'link list')."""
import numpy as np
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import cells, domain as D


def _brute_cells(dom, x):
    xn = dom.normalize(jnp.asarray(x))
    return np.asarray(dom.flat_cell_id(dom.cell_coords_of(xn)))


def test_binning_matches_bruteforce(rng):
    dom = D.unit_square(h=0.05)
    x = rng.uniform(0, 1, (300, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=32)
    want = _brute_cells(dom, x)
    np.testing.assert_array_equal(np.asarray(b.cell_id), want)
    # every particle appears exactly once in the table
    tbl = np.asarray(b.table)
    ids = tbl[tbl >= 0]
    assert sorted(ids.tolist()) == list(range(300))
    assert int(b.overflow) == 0
    # table row matches cell id
    for cid in range(tbl.shape[0]):
        for p in tbl[cid][tbl[cid] >= 0]:
            assert want[p] == cid


def test_binning_overflow_detected(rng):
    dom = D.unit_square(h=0.4)  # few cells
    x = rng.uniform(0, 1, (100, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=2)
    assert int(b.overflow) > 0


def test_spatial_sort_property(rng):
    """binning order sorts particles by flat cell id (the paper's
    locality optimization)."""
    dom = D.unit_square(h=0.06)
    x = rng.uniform(0, 1, (500, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=16)
    sorted_ids = np.asarray(b.cell_id)[np.asarray(b.order)]
    assert np.all(np.diff(sorted_ids) >= 0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 400), seed=st.integers(0, 2**31 - 1))
def test_property_candidates_superset_of_neighbors(n, seed):
    """Every true neighbor (r <= 2h) appears in the 3x3 cell candidates."""
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=cells.default_capacity(dom, n))
    if int(b.overflow):
        return  # capacity heuristic failed for this draw; not the property
    cand, mask = cells.gather_candidates(dom, b)
    cand = np.asarray(cand)
    mask = np.asarray(mask)
    d = np.linalg.norm(np.asarray(xn)[:, None] - np.asarray(xn)[None], axis=-1)
    radius = dom.radius_norm
    for i in range(n):
        true_nb = set(np.nonzero(d[i] <= radius)[0].tolist())
        got = set(cand[i][mask[i]].tolist())
        assert true_nb <= got | {i}
