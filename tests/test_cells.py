"""Background-cell binning (the static 'link list') and the
counting-sort pack."""
import numpy as np
import jax.numpy as jnp
from _hypo import given, settings, st

from repro.core import cells, domain as D, rcll


def _brute_cells(dom, x):
    xn = dom.normalize(jnp.asarray(x))
    return np.asarray(dom.flat_cell_id(dom.cell_coords_of(xn)))


def test_binning_matches_bruteforce(rng):
    dom = D.unit_square(h=0.05)
    x = rng.uniform(0, 1, (300, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=32)
    want = _brute_cells(dom, x)
    np.testing.assert_array_equal(np.asarray(b.cell_id), want)
    # every particle appears exactly once in the table
    tbl = np.asarray(b.table)
    ids = tbl[tbl >= 0]
    assert sorted(ids.tolist()) == list(range(300))
    assert int(b.overflow) == 0
    # table row matches cell id
    for cid in range(tbl.shape[0]):
        for p in tbl[cid][tbl[cid] >= 0]:
            assert want[p] == cid


def test_binning_overflow_detected(rng):
    dom = D.unit_square(h=0.4)  # few cells
    x = rng.uniform(0, 1, (100, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=2)
    assert int(b.overflow) > 0


def test_spatial_sort_property(rng):
    """binning order sorts particles by flat cell id (the paper's
    locality optimization)."""
    dom = D.unit_square(h=0.06)
    x = rng.uniform(0, 1, (500, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=16)
    sorted_ids = np.asarray(b.cell_id)[np.asarray(b.order)]
    assert np.all(np.diff(sorted_ids) >= 0)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(10, 400), seed=st.integers(0, 2**31 - 1))
def test_property_candidates_superset_of_neighbors(n, seed):
    """Every true neighbor (r <= 2h) appears in the 3x3 cell candidates."""
    rng = np.random.default_rng(seed)
    ds = (1.0 / n) ** 0.5
    dom = D.unit_square(h=1.2 * ds)
    x = rng.uniform(0, 1, (n, 2))
    xn = dom.normalize(jnp.asarray(x))
    b = cells.bin_particles(dom, xn, capacity=cells.default_capacity(dom, n))
    if int(b.overflow):
        return  # capacity heuristic failed for this draw; not the property
    cand, mask = cells.gather_candidates(dom, b)
    cand = np.asarray(cand)
    mask = np.asarray(mask)
    d = np.linalg.norm(np.asarray(xn)[:, None] - np.asarray(xn)[None], axis=-1)
    radius = dom.radius_norm
    for i in range(n):
        true_nb = set(np.nonzero(d[i] <= radius)[0].tolist())
        got = set(cand[i][mask[i]].tolist())
        assert true_nb <= got | {i}


# --------------------------------------------------------------------------
# Counting-sort pack: identical permutation/table to the argsort oracle
# --------------------------------------------------------------------------
def _assert_pack_equal(pk_fast, pk_oracle):
    np.testing.assert_array_equal(
        np.asarray(pk_fast.order), np.asarray(pk_oracle.order)
    )
    np.testing.assert_array_equal(
        np.asarray(pk_fast.inverse), np.asarray(pk_oracle.inverse)
    )
    np.testing.assert_array_equal(
        np.asarray(pk_fast.binning.table), np.asarray(pk_oracle.binning.table)
    )
    np.testing.assert_array_equal(
        np.asarray(pk_fast.binning.counts),
        np.asarray(pk_oracle.binning.counts),
    )
    np.testing.assert_array_equal(
        np.asarray(pk_fast.binning.cell_id),
        np.asarray(pk_oracle.binning.cell_id),
    )


def test_counting_pack_matches_argsort_under_migration(rng):
    """Advance a packed state (some particles migrate cells), then
    re-pack with prev=<old binning>: the counting-sort fast path must
    produce the argsort path's permutation and tables exactly."""
    for dim, periodic in [
        (2, (False, False)), (2, (True, False)), (2, (True, True)),
        (3, (False, True, False)),
    ]:
        n = 700
        dom = D.Domain(
            lo=(0.0,) * dim, hi=(1.0,) * dim, h=0.08, periodic=periodic
        )
        x = rng.uniform(0, 1, (n, dim))
        st0 = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
        cap = cells.default_capacity(dom, n)
        ps = rcll.pack_state(dom, st0, cap)  # cold start: argsort
        prc = ps.rc
        for step in range(3):
            dxn = jnp.asarray(
                rng.uniform(-0.4, 0.4, (n, dim)) * min(dom.hc_norm_axes),
                jnp.float32,
            )
            prc = rcll.advance(dom, prc, dxn)
            migrated = int(jnp.sum(
                dom.flat_cell_id(prc.cell_xy) != ps.packing.binning.cell_id
            ))
            assert migrated > 0, "setup must migrate particles"
            fast = rcll.pack_state(dom, prc, cap, prev=ps.packing.binning)
            oracle = rcll.pack_state(dom, prc, cap)
            _assert_pack_equal(fast.packing, oracle.packing)
            ps, prc = fast, fast.rc


def test_counting_pack_falls_back_on_long_jumps(rng):
    """Moves beyond the 3^d neighborhood violate the fast-path
    precondition; the lax.cond fallback must still be exact."""
    dom = D.unit_square(h=0.1, periodic=(True, False))
    n = 300
    x = rng.uniform(0, 1, (n, 2))
    st0 = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    pk0 = cells.pack_particles(
        dom, dom.flat_cell_id(st0.cell_xy), st0.cell_xy, 16
    )
    new_xy = jnp.asarray(
        rng.integers(0, np.asarray(dom.ncells), (n, 2)), jnp.int32
    )
    new_cid = dom.flat_cell_id(new_xy)
    fast = cells.pack_particles(dom, new_cid, new_xy, 16, prev=pk0.binning)
    oracle = cells.pack_particles(dom, new_cid, new_xy, 16)
    _assert_pack_equal(fast, oracle)


def test_packed_table_overflow_counts(rng):
    """The arithmetic (C, cap) table drops the same overflow the scatter
    table did and reports the dropped count."""
    dom = D.unit_square(h=0.4)  # few cells -> guaranteed overflow
    n = 120
    x = rng.uniform(0, 1, (n, 2))
    st0 = rcll.init_state(dom, dom.normalize(jnp.asarray(x)), jnp.float16)
    cid = dom.flat_cell_id(st0.cell_xy)
    pk = cells.pack_particles(dom, cid, st0.cell_xy, capacity=3)
    counts = np.asarray(pk.binning.counts)
    assert int(pk.binning.overflow) == int(np.maximum(counts - 3, 0).sum()) > 0
    tbl = np.asarray(pk.binning.table)
    # table rows are consecutive packed ids starting at the cell start
    starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
    for c in range(tbl.shape[0]):
        occ = tbl[c][tbl[c] >= 0]
        np.testing.assert_array_equal(
            occ, starts[c] + np.arange(min(counts[c], 3))
        )
