"""2-D dam break through the scenario API (the PR 4 showcase).

Drives the registered ``dam_break`` case (Tait EOS + Monaghan
artificial viscosity + delta-SPH density diffusion, no-slip dummy
walls, open top) through the ``Simulation`` facade, printing in-scan
observables and an ASCII rendering of the collapsing column — no
plotting dependencies, runs anywhere the tests run.

  PYTHONPATH=src python examples/dam_break.py [--ds 0.05] [--t 1.2]
"""
import argparse

import numpy as np

from repro.core import solver
from repro.core.api import Simulation


def render(cfg, state, case, gx=56, gy=14) -> str:
    pos = np.asarray(solver.positions(cfg, state))
    fl = ~np.asarray(state.fixed)
    p = pos[fl]
    grid = np.zeros((gy, gx), int)
    ix = np.clip((p[:, 0] / case.width * gx).astype(int), 0, gx - 1)
    iy = np.clip((p[:, 1] / case.height * gy).astype(int), 0, gy - 1)
    np.add.at(grid, (iy, ix), 1)
    lines = ["|" + "".join(
        "#" if c > 2 else ("." if c > 0 else " ") for c in row
    ) + "|" for row in grid[::-1]]
    lines.append("+" + "-" * gx + "+")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ds", type=float, default=0.05)
    ap.add_argument("--t", type=float, default=1.2)
    ap.add_argument("--frames", type=int, default=4)
    args = ap.parse_args()

    sim = Simulation.from_case("dam_break", ds=args.ds)
    case, cfg = sim.case, sim.cfg
    nsteps = int(round(args.t / cfg.dt))
    per_frame = max(1, nsteps // args.frames)
    print(f"# dam_break: N={sim.n_particles} ds={case.ds} dt={cfg.dt:.2e} "
          f"backend={cfg.resolved_backend} records={cfg.policy.records}")
    print(render(cfg, sim.state, case))

    for _ in range(args.frames):
        res = sim.run(per_frame, observe_every=max(1, per_frame // 4))
        obs = res.observables
        front = case.front_position(cfg, res.state)
        print(f"t={float(res.state.t):.2f}  front x={front:.2f}  "
              f"ekin={float(np.asarray(obs.ekin)[-1]):.3f}  "
              f"vmax={float(np.asarray(obs.vmax)[-1]):.2f}")
        print(render(cfg, res.state, case))

    # Martin & Moyce-style dimensionless front check: Z = x/a vs
    # T = t sqrt(2g/a); experiments give Z ~ 1.3-2 over T ~ 1-1.5.
    a = case.col_w
    T = float(res.state.t) * np.sqrt(2 * case.g / a)
    print(f"dimensionless front Z = {front / a:.2f} at T = {T:.2f} "
          "(Martin & Moyce: Z≈1.3 at T≈1, Z≈2 at T≈1.5)")


if __name__ == "__main__":
    main()
