"""End-to-end LM training driver with fault tolerance: trains a reduced
assigned-architecture config for a few hundred steps on CPU, with
checkpoint/restart, heartbeats, and straggler watchdog.

  PYTHONPATH=src python examples/train_lm.py --arch llama3.2-3b \
      --steps 200 --ckpt-dir /tmp/lm_ckpt

Kill it at any point and rerun: it resumes from the last complete
checkpoint with the data iterator skipped ahead (bitwise-identical to an
uninterrupted run - tests/test_integration.py asserts this).

On a real pod the same TrainRun drives the production mesh; the dry-run
(repro.launch.dryrun) proves the full-size configs lower and compile on
(16,16) and (2,16,16).
"""
import argparse

from repro.launch.train import TrainRun
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (multi-billion-param) config - "
                    "needs a real pod, not this CPU container")
    args = ap.parse_args()

    run = TrainRun(
        arch=args.arch,
        smoke=not args.full_config,
        steps=args.steps,
        batch=args.batch,
        seq=args.seq,
        lr=args.lr,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=50,
        heartbeat_dir=args.ckpt_dir + "/hb",
        log_every=20,
    )
    out = run.run()
    losses = out["losses"]
    if losses:
        k = max(1, len(losses) // 10)
        print(f"[example] loss: first-{k}-mean "
              f"{sum(losses[:k]) / k:.4f} -> last-{k}-mean "
              f"{sum(losses[-k:]) / k:.4f}")


if __name__ == "__main__":
    main()
