"""Quickstart: the paper's mixed-precision NNPS in ~40 lines.

Builds a random particle set, runs the three searches (all-list,
cell-list, RCLL) at fp32 and fp16, and shows the paper's core result:
absolute-coordinate fp16 misclassifies neighbors once spacing is small
relative to the domain, RCLL's cell-relative fp16 does not. Then runs
the production solver loop (``solver.run_persistent``: cell-packed
persistent state, Verlet-skin reuse, fused half-width-record force
pass — the default ``PrecisionPolicy.records``) and prints measured
steps/sec, so the quickstart doubles as a sanity benchmark.

  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cases, domain as D, nnps, rcll, solver
from repro.core.api import Simulation


def main():
    rng = np.random.default_rng(0)
    n = 4000
    ds = 0.02
    # elongated box: normalized spacing ds/h_d = 1.25e-4 < the paper's
    # 1e-3 fp16 breakdown threshold, with only 4k particles
    dom = D.Domain(lo=(0.0, 0.0), hi=(160.0, 1.0), h=1.2 * ds)
    x = np.stack([rng.uniform(0, 160, n), rng.uniform(0, 1, n)], -1)
    xn = dom.normalize(jnp.asarray(x))

    k = 48
    truth = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float32, k=k)
    total = int(jnp.sum(truth.count))
    print(f"{n} particles, {total} true neighbor pairs, "
          f"normalized spacing {ds / 160:.2e}")

    # approach II: absolute coordinates truncated to fp16
    abs16 = nnps.cell_list_neighbors(dom, xn, dtype=jnp.float16, k=k)
    wrong = int(nnps.count_wrong_determinations(truth, abs16))
    print(f"absolute fp16 : {wrong:6d} wrong determinations "
          f"({100 * wrong / total:.1f}%)")

    # approach III: RCLL - int cell index + fp16 cell-relative coordinate
    state = rcll.init_state(dom, xn, dtype=jnp.float16)
    good16 = nnps.rcll_neighbors(
        dom, state.rel, state.cell_xy, dtype=jnp.float16,
        compute_dtype=jnp.float32, k=k)
    wrong = int(nnps.count_wrong_determinations(truth, good16))
    print(f"RCLL fp16     : {wrong:6d} wrong determinations "
          f"({100 * wrong / total:.3f}%)")

    # the persistent state advances without ever touching absolute coords
    v = jnp.asarray(rng.normal(0, 0.5, (n, 2)), jnp.float32)
    dt = 0.01
    state2 = rcll.advance(dom, state, v * dt * (2.0 / dom.h_d))
    moved = int(jnp.sum(jnp.any(state2.cell_xy != state.cell_xy, axis=1)))
    print(f"advanced one step (Eq. 8): {moved} particles migrated cells")

    # full production solver loop: persistent carry, donated buffers,
    # fused half-width-record force pass (the default record policy)
    case = cases.PoiseuilleCase(ds=0.02, Lx=0.4, algo="rcll")
    cfg, st = case.build()
    carry = solver.init_persistent(cfg, st)
    seg = 50
    carry = jax.block_until_ready(solver.run_persistent(cfg, carry, seg))
    t0 = time.perf_counter()
    carry = jax.block_until_ready(solver.run_persistent(cfg, carry, seg))
    dt_wall = time.perf_counter() - t0
    print(f"solver [{cfg.resolved_backend} records={cfg.policy.records}]: "
          f"{st.xn.shape[0]} particles, {seg / dt_wall:.1f} steps/sec "
          f"({int(carry.rebuilds)} rebuilds over {int(carry.steps)} steps)")

    # the scenario API wraps all of the above behind one facade: any
    # registered case + in-scan observables (no host sync per sample).
    # `python -m repro.sph list` shows the case gallery.
    sim = Simulation.from_case("taylor_green", ds=1 / 24)
    res = sim.run(nsteps=120, observe_every=30)
    ekin = np.asarray(res.observables.ekin)
    metrics = sim.case.validate(np.asarray(res.observables.t), ekin)
    print(f"taylor_green [{sim.cfg.resolved_backend}]: "
          f"{sim.n_particles} particles, KE {ekin[0]:.4f} -> {ekin[-1]:.4f}, "
          f"decay rate {metrics['decay_rate_measured']:.2f} "
          f"(analytic {metrics['decay_rate_analytic']:.2f})")


if __name__ == "__main__":
    main()
