"""End-to-end SPH driver: 2D Poiseuille flow with the mixed-precision
RCLL framework (the paper's validation problem, Table 4/5, Figs 11-12).

Runs the full WCSPH solver (continuity + momentum + Morris viscosity +
Eq. 8 persistent relative coordinates), compares the velocity profile to
the analytic transient solution, and reports the approach I vs III
discrepancy.

The RCLL run goes through the production entry point
(``solver.run_persistent``: donated carry, cell-packed state, fused
half-width-record force pass — the default ``PrecisionPolicy.records``)
and prints measured steps/sec, so the example doubles as a sanity
benchmark.

  PYTHONPATH=src python examples/poiseuille_flow.py [--ds 0.05] [--t 0.2]
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import cases, solver
from repro.core.precision import PrecisionPolicy


def run(ds: float, t_end: float, algo: str, policy: PrecisionPolicy):
    case = cases.PoiseuilleCase(ds=ds, Lx=0.4, algo=algo, policy=policy)
    cfg, st = case.build()
    nsteps = int(round(t_end / cfg.dt))
    if algo != "rcll":
        return case, cfg, st, solver.simulate(cfg, st, nsteps)
    # Production path: persistent carry advanced in place (donation) in
    # chained segments; timing excludes init/compile (first segment).
    segments = max(2, min(8, nsteps))
    seg = max(1, nsteps // segments)
    carry = solver.init_persistent(cfg, st)
    carry = jax.block_until_ready(solver.run_persistent(cfg, carry, seg))
    done = seg
    t0 = time.perf_counter()
    while done < nsteps:
        step = min(seg, nsteps - done)
        carry = solver.run_persistent(cfg, carry, step)
        done += step
    jax.block_until_ready(carry)
    dt_wall = time.perf_counter() - t0
    print(f"  [{algo}/{cfg.resolved_backend} records={policy.records}] "
          f"{nsteps - seg} timed steps, "
          f"{(nsteps - seg) / dt_wall:.1f} steps/sec, "
          f"{int(carry.rebuilds)} rebuilds")
    return case, cfg, st, solver.finalize_persistent(cfg, carry)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ds", type=float, default=0.05)
    ap.add_argument("--t", type=float, default=0.2)
    args = ap.parse_args()

    print(f"# Poiseuille, ds={args.ds}, to t={args.t}")
    case, cfg, st0, out3 = run(args.ds, args.t, "rcll",
                               PrecisionPolicy(nnps="fp16", coords="fp16"))
    _, cfg1, _, out1 = run(args.ds, args.t, "cell",
                           PrecisionPolicy(nnps="fp32", coords="fp32"))

    pos = solver.positions(cfg, out3)
    fl = ~np.asarray(st0.fixed)
    y = np.asarray(pos[:, 1])[fl]
    vx = np.asarray(out3.fluid.v[:, 0])[fl]
    va = np.asarray(case.analytic_vx(jnp.asarray(y), float(out3.t)))
    print(f"t = {float(out3.t):.3f}  steps = {int(out3.t / cfg.dt)}")
    print(f"v_max  simulated {vx.max():.5f}  analytic {va.max():.5f}")
    print(f"velocity L_inf error vs analytic: "
          f"{np.abs(vx - va).max() / va.max():.3f} (relative)")

    # approach I vs III (paper Table 5: III tracks I)
    p1 = np.asarray(solver.positions(cfg1, out1))[fl]
    p3 = np.asarray(pos)[fl]
    print(f"approach I vs III max position gap: "
          f"{np.abs(p1 - p3).max() / args.ds:.4f} ds")

    # crude ASCII profile
    print("\nvelocity profile (x = analytic, o = SPH):")
    bins = np.linspace(0, 1, 21)
    for lo, hi in zip(bins[:-1], bins[1:]):
        sel = (y >= lo) & (y < hi)
        if not sel.any():
            continue
        vsim = vx[sel].mean()
        vana = float(case.analytic_vx(
            jnp.asarray([(lo + hi) / 2]), float(out3.t))[0])
        row = [" "] * 52
        row[int(50 * vana / (va.max() + 1e-9))] = "x"
        row[int(50 * vsim / (va.max() + 1e-9))] = "o"
        print(f"y={lo:.2f} |" + "".join(row))


if __name__ == "__main__":
    main()
