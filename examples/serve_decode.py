"""Batched serving with the paper's technique on the LM side: RCLL-KV
(block-anchored quantized KV cache) vs the dense bf16 baseline.

  PYTHONPATH=src python examples/serve_decode.py --arch llama3.2-3b

Prints tokens/s, cache bytes, and token agreement between the two cache
representations - the decode-side analogue of the paper's Table 5
(approach III tracks approach I while the memory-bound tensor shrinks).
"""
import argparse

import numpy as np

from repro.launch.serve import ServeRun
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=48)
    args = ap.parse_args()

    runs = {}
    for mode in ("dense", "anchored"):
        if registry.get_config(args.arch, smoke=True).family in (
                "ssm", "hybrid", "mla_moe", "encdec") and mode == "anchored":
            # anchored KV applies to the GQA dense-cache families here;
            # MLA gets it on the latent cache (see DESIGN.md), ssm has
            # no KV cache at all.
            continue
        runs[mode] = ServeRun(
            arch=args.arch, smoke=True, batch=args.batch,
            prompt_len=args.prompt_len, gen=args.gen, kv_mode=mode).run()
        r = runs[mode]
        print(f"[{mode:8s}] prefill {r['t_prefill_s']*1e3:7.0f} ms   "
              f"decode {r['decode_tok_s']:8.1f} tok/s   "
              f"cache {r['cache_bytes']/2**20:7.2f} MiB")

    if len(runs) == 2:
        agree = (runs["dense"]["tokens"]
                 == runs["anchored"]["tokens"]).mean()
        ratio = (runs["dense"]["cache_bytes"]
                 / max(runs["anchored"]["cache_bytes"], 1))
        print(f"token agreement dense vs RCLL-KV: {100*agree:.1f}%   "
              f"cache bytes ratio: {ratio:.2f}x")
        print("(int8 residuals + fp32 anchors: the KV stream shrinks "
              "~4x vs bf16 at matched outputs - the paper's Table 2 "
              "accuracy argument applied to decode)")


if __name__ == "__main__":
    main()
