"""Fixture corpus for sphlint's own tests.

``bad_*.py`` files are MINIMIZED REPLAYS of real incidents from this
repo's PR history — each must trip exactly its rule. ``good_*.py``
files are the idiomatic fixed forms and must lint clean. The directory
is skipped by directory sweeps (``engine.collect_files``); tests lint
these files explicitly.
"""
