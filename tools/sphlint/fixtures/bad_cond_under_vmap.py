"""BAD: the PR 7 batched rebuild-cadence bug, minimized.

``lax.cond`` inside a vmapped step: under batching the cond lowers to
``select`` and BOTH branches run for every lane — the "cheap" skip
branch never actually skips the rebuild.
"""
import jax
from jax import lax


def _rebuild(carry):
    return carry * 0


def _advance(carry):
    return carry + 1


def step_one(carry):
    return lax.cond(carry[0] > 0, _rebuild, _advance, carry)


step_batch = jax.vmap(step_one)
