"""BAD: the PR 3 donation-aliasing bug, minimized.

The same buffer expression passed both as the donated argument and as
a live argument: XLA either refuses the donation or the callee reads
an invalidated buffer (``st.rc.cell_xy`` vs ``binning.cell_xy``).
"""
from functools import partial

import jax


@partial(jax.jit, donate_argnums=(0,))
def advance(cell_xy, binning_xy):
    return cell_xy + 1, binning_xy


def run(st):
    return advance(st.rc.cell_xy, st.rc.cell_xy)
