"""GOOD: precision flows through core/precision.py."""
from repro.core.precision import NNPS_STORE, PrecisionPolicy


def init_rel(x, dtype=NNPS_STORE):
    return x.astype(dtype)


def build_records(encode, policy: PrecisionPolicy):
    return encode(records=policy.records)
