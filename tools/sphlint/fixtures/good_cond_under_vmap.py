"""GOOD: the branch decision hoisted OUT of the vmap (the PR 7 fix:
reduce the per-lane predicates, cond once at batch level)."""
import jax
from jax import lax


def _rebuild(batch):
    return batch * 0


def _advance(batch):
    return batch + 1


def _advance_lane(carry):
    return carry + 1


def step_batch(batch):
    any_due = (batch[:, 0] > 0).any()
    return lax.cond(any_due, _rebuild, _advance, batch)


advance_batch = jax.vmap(_advance_lane)
