"""Pragma mechanics fixture: every violation here is suppressed."""
import jax.numpy as jnp

REL_STORE = jnp.float16  # sphlint: disable=dtype-literal

# sphlint: disable=dtype-literal
PAD_STORE = jnp.float16


def encode(x):
    return x.astype(REL_STORE)
