"""BAD: the PR 6 in-scan overflow callback, minimized.

A ``jax.debug.callback`` plus host reads of the traced carry inside the
``lax.scan`` body — a device->host sync point on every step.
"""
import jax
import jax.numpy as jnp


def run(carry0, steps: int):
    def body(count, _):
        jax.debug.callback(lambda c: print("overflow", c), count)
        peak = float(count)
        sample = count.item()
        return count + 1, jnp.float32(peak + sample)

    return jax.lax.scan(body, carry0, None, length=steps)
